"""End-to-end driver (deliverable b): train a 2-layer GraphSAGE/GCN with
hidden 256 — the paper's model setup — for a few hundred iterations on a
synthetic papers100M-scaled graph, exercising the FULL system: hybrid
trainers, DRM, two-stage prefetching, checkpointing, fault injection.

    PYTHONPATH=src python examples/hybrid_gnn_training.py \
        --model sage --iters 200 --scale 2e-4
"""
import argparse
import os
import tempfile

import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import HybridConfig, HybridGNNTrainer
from repro.graph import GNNConfig, make_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="sage", choices=["sage", "gcn"])
    ap.add_argument("--dataset", default="ogbn-papers100M")
    ap.add_argument("--scale", type=float, default=2e-4)
    ap.add_argument("--iters", type=int, default=200)
    ap.add_argument("--batch", type=int, default=1024)
    ap.add_argument("--fanouts", default="10,5")
    ap.add_argument("--n-accel", type=int, default=2)
    ap.add_argument("--agg-impl", default="dense",
                    choices=["dense", "segsum", "pallas", "pallas_fused"])
    ap.add_argument("--cache-fraction", type=float, default=0.0,
                    help="pin this fraction of the hottest node features "
                         "on each accelerator (0 = off)")
    ap.add_argument("--cache-sharding", default="replicated",
                    choices=["replicated", "sharded"],
                    help="'sharded' partitions the hot set into disjoint "
                         "per-accelerator shards (n x effective capacity "
                         "at the same per-device budget): local misses "
                         "are served from peer shards over the device "
                         "interconnect before host PCIe, and the host "
                         "gathers the union of all trainers' miss sets "
                         "once, multicasting per-device slices "
                         "(losses stay bit-identical to replicated)")
    ap.add_argument("--shard-placement", default="hash",
                    choices=["hash", "degree"],
                    help="shard placement policy: 'hash' spreads rows "
                         "uniformly (balanced occupancy), 'degree' keeps "
                         "contiguous hotness-rank ranges co-resident")
    ap.add_argument("--recent-rows-batches", type=int, default=0,
                    help="cross-iteration device-side dedup: remember the "
                         "last N batches' shipped rows per accelerator "
                         "and reuse the device-resident copies instead "
                         "of re-shipping over PCIe (invalidated on cache "
                         "refresh; 0 = off)")
    ap.add_argument("--cache-refresh", action="store_true",
                    help="dynamic cache refresh: track observed per-slot / "
                         "uncached hotness and swap the coldest slots for "
                         "strictly-hotter uncached nodes whenever the "
                         "measured hit rate drifts from the rate the task "
                         "mapping was priced with (DistDGL-style "
                         "admission; versioned lookups keep in-flight TFP "
                         "batches bit-identical)")
    ap.add_argument("--cache-refresh-frac", type=float, default=0.25,
                    help="max fraction of cache slots swapped per refresh")
    ap.add_argument("--cache-refresh-decay", type=float, default=0.5,
                    help="hotness-counter decay applied at each refresh "
                         "window boundary (1.0 = never forget, smaller = "
                         "adapt faster to drift)")
    ap.add_argument("--cache-drift-threshold", type=float, default=0.05,
                    help="measured-vs-priced hit-rate drift (in rate "
                         "points) that triggers a cache refresh and a "
                         "task-mapping re-price")
    ap.add_argument("--feature-backend", default="auto",
                    choices=["auto", "dense", "hashed", "partitioned",
                             "mmap"],
                    help="feature storage tier: dense/hashed/partitioned "
                         "are RAM-resident; 'mmap' spills per-partition "
                         "blobs to disk (bounded spill RAM, lazily mapped "
                         "windows) for graphs larger than host memory")
    ap.add_argument("--spill-dir", default=None,
                    help="where 'mmap' places its partition blobs "
                         "(default: a private temp dir, removed on exit)")
    ap.add_argument("--prefetch-windows", type=int, default=0,
                    help="background window-prefetch queue depth: the "
                         "sample stage hands batch i+1's frontier to a "
                         "prefetch thread that pre-faults its mmap "
                         "partition windows while batch i trains, so the "
                         "load stage never blocks on cold disk reads "
                         "(0 = off; requires --feature-backend mmap)")
    ap.add_argument("--prefetch-dedup-history", type=int, default=2,
                    help="cross-batch prefetch dedup: the prefetcher "
                         "remembers the last N submitted frontiers and "
                         "strips already-warm rows from new submits, "
                         "cutting background read volume by the "
                         "cross-batch duplication factor (0 = off)")
    ap.add_argument("--cache-assemble", default="auto",
                    choices=["auto", "jnp", "pallas"],
                    help="device-side cache+miss combine path: 'auto' "
                         "picks pallas on TPU and jnp elsewhere; force "
                         "'pallas' to exercise the (interpret-mode) "
                         "kernels off-TPU, e.g. with a pipeline depth")
    ap.add_argument("--kernel-pipeline-depth", type=int, default=1,
                    help="Pallas combine/scatter DMA pipeline depth: 1 = "
                         "single-buffered, 2-4 = multi-buffered "
                         "DMA/compute overlap (output stays "
                         "bit-identical at every depth)")
    ap.add_argument("--mmap-lru-windows", type=int, default=0,
                    help="bound on simultaneously open mmap partition "
                         "windows: the LRU evicts with MADV_DONTNEED so "
                         "page-cache residency stays "
                         "O(lru_windows x window_bytes) instead of "
                         "trusting kernel reclaim (0 = unbounded)")
    ap.add_argument("--async-refresh", action="store_true",
                    help="stage the dynamic cache refresh's admitted-row "
                         "gather in a background thread; the iteration "
                         "boundary only pays the cheap table/device-block "
                         "commit (losses stay bit-identical — versioned "
                         "lookups)")
    ap.add_argument("--auto-tune", action="store_true",
                    help="model-predictive knob auto-tuning: the DRM "
                         "proposes bounded moves in prefetch depth, "
                         "window LRU, stage threads and refresh "
                         "cadence/fraction from the calibrated Eq. 7/8 "
                         "model, verifying each against measured "
                         "iteration time and rolling back regressions "
                         "(losses stay bit-identical — knobs never touch "
                         "RNG streams or batch composition)")
    ap.add_argument("--autotune-interval", type=int, default=3,
                    help="iterations per autotuner measurement window")
    ap.add_argument("--cache-refresh-period", type=int, default=1,
                    help="iteration boundaries between cache drift "
                         "checks (the refresh-cadence knob; 1 = every "
                         "boundary)")
    ap.add_argument("--inject-failure", type=int, default=0,
                    help="kill accel0 at this iteration (0 = off)")
    ap.add_argument("--fault-schedule", default=None,
                    help="JSON fault schedule for the data plane (a list "
                         "of FaultSpec dicts or {'seed':..,'schedule':..}) "
                         "— injects transient/permanent I/O errors, "
                         "delays or worker kills at named hooks "
                         "(storage.take, prefetch.worker, refresh.stage, "
                         "pipeline.<stage>, ...); deterministic per-op "
                         "call indexing makes every run replayable")
    ap.add_argument("--pipeline-watchdog", type=float, default=0.0,
                    help="TFP stage-stall watchdog (seconds): a pipeline "
                         "stage wedged past this deadline raises a "
                         "diagnostic PipelineStallError naming the stage "
                         "and queue depths instead of hanging (0 = off)")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    fanouts = tuple(int(x) for x in args.fanouts.split(","))
    ds = make_dataset(args.dataset, scale=args.scale, seed=0,
                      feature_backend=args.feature_backend,
                      spill_dir=args.spill_dir,
                      mmap_lru_windows=args.mmap_lru_windows)
    print(f"{ds.name}: |V|={ds.num_nodes:,} |E|={ds.num_edges:,} "
          f"dims={ds.layer_dims}")
    if args.feature_backend == "mmap":
        src = ds.features
        print(f"out-of-core features: {src.num_partitions} partitions of "
              f"{src.partition_rows} rows under {src.spill_dir} "
              f"(spill buffered <= {src.spill_peak_buffered_rows} rows)")
    gnn = GNNConfig(model=args.model, layer_dims=ds.layer_dims,
                    fanouts=fanouts, num_classes=ds.num_classes,
                    agg_impl=args.agg_impl)
    hcfg = HybridConfig(total_batch=args.batch, n_accel=args.n_accel,
                        hybrid=True, use_drm=True, tfp_depth=2, lr=3e-3,
                        cache_fraction=args.cache_fraction,
                        cache_sharding=args.cache_sharding,
                        shard_placement=args.shard_placement,
                        recent_rows_batches=args.recent_rows_batches,
                        cache_refresh=args.cache_refresh,
                        cache_refresh_frac=args.cache_refresh_frac,
                        cache_refresh_decay=args.cache_refresh_decay,
                        cache_drift_threshold=args.cache_drift_threshold,
                        cache_assemble=args.cache_assemble,
                        async_refresh=args.async_refresh,
                        prefetch_windows=args.prefetch_windows,
                        prefetch_dedup_history=args.prefetch_dedup_history,
                        kernel_pipeline_depth=args.kernel_pipeline_depth,
                        mmap_lru_windows=args.mmap_lru_windows,
                        pipeline_watchdog_seconds=args.pipeline_watchdog,
                        auto_tune=args.auto_tune,
                        autotune_interval=args.autotune_interval,
                        cache_refresh_period=args.cache_refresh_period,
                        ckpt_every=50 if args.ckpt_dir else 0)
    injector = None
    if args.fault_schedule:
        from repro.graph import FaultInjector
        injector = FaultInjector.from_json(args.fault_schedule)
        print(f"!! fault schedule armed: {len(injector.schedule)} specs "
              f"(seed {injector.seed}) from {args.fault_schedule}")
    tr = HybridGNNTrainer(ds, gnn, hcfg, fault_injector=injector)
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep=2)
        tr.set_checkpoint_callback(
            lambda step, p, o: mgr.save(step, {"params": p, "opt": o}))
    if args.inject_failure:
        tr.inject_failure("accel0", args.inject_failure)
        print(f"!! will inject accel0 failure at iter {args.inject_failure}")

    hist = tr.train(args.iters)
    for m in hist[:: max(args.iters // 10, 1)]:
        t = m.times
        print(f"it {m.iteration:4d} loss {m.loss:.3f} acc {m.acc:.3f} "
              f"| samp {t.t_sc*1e3:5.1f} load {t.t_load*1e3:5.1f} "
              f"tran {t.t_tran*1e3:5.1f} tc {t.t_tc*1e3:6.1f} "
              f"ta {t.t_ta*1e3:6.1f} ms | {m.mteps:6.2f} MTEPS "
              f"| shares {m.assignment}")
    accs = [m.acc for m in hist[-20:]]
    print(f"\nfinal: loss {hist[-1].loss:.3f}  acc(last20) "
          f"{np.mean(accs):.3f}  mean {tr.mean_mteps():.2f} MTEPS")
    if tr.cache is not None:
        tf = tr.feature_traffic()
        print(f"feature cache: hit {tf['hit_rate']:.3f} "
              f"(model {tr.cache.expected_hit_rate:.3f}), shipped "
              f"{tf['shipped_bytes']/1e6:.1f} MB, saved "
              f"{tf['saved_bytes']/1e6:.1f} MB "
              f"({tf['reduction']:.2f}x reduction)")
        if args.cache_sharding == "sharded" and hasattr(tr.cache, "shards"):
            print(f"sharded plane: {len(tr.cache.shards)} shards "
                  f"({args.shard_placement}), {tr.cache.capacity} resident "
                  f"rows, peer-served {tf['peer_rows']:.0f} rows "
                  f"({tf['peer_saved_bytes']/1e6:.1f} MB off PCIe), union "
                  f"gather saved {tf['union_saved_bytes']/1e6:.1f} MB, "
                  f"ICI {tf['ici_bytes']/1e6:.1f} MB")
        if args.recent_rows_batches:
            print(f"recent-rows LRU: {tf['recent_rows']:.0f} rows reused "
                  f"on device ({tf['recent_saved_bytes']/1e6:.1f} MB not "
                  f"re-shipped)")
        if args.cache_refresh:
            print(f"cache refresh: {tr.cache.refreshes} refreshes moved "
                  f"{tr.cache.refresh_swapped_rows} rows "
                  f"(version {tr.cache.version}, windowed hit "
                  f"{tr.cache.measured_hit_rate():.3f})")
    if args.prefetch_windows or args.mmap_lru_windows:
        io = tr.storage_io()
        print(f"storage I/O: stall {io['load_stall_seconds']*1e3:.1f} ms "
              f"({io['cold_fault_page_bytes']/1e6:.1f} MB cold), prefetch "
              f"hit {io['prefetch_hit_rate']:.2f} "
              f"({io['prefetched_window_bytes']/1e6:.1f} MB pre-faulted), "
              f"evicted {io['evicted_window_bytes']/1e6:.1f} MB over "
              f"{io['window_evictions']:.0f} window evictions")
        if "resubmitted_rows_skipped" in io:
            print(f"prefetch dedup: "
                  f"{io['resubmitted_rows_skipped']:.0f} already-warm rows "
                  f"stripped from resubmits")
    if args.auto_tune:
        rep = tr.autotune_report()
        k = rep["knobs"]
        print(f"autotune: {rep['trials']} trials, {rep['accepted']} "
              f"accepted, {rep['rollbacks']} rolled back -> prefetch "
              f"{k['prefetch_windows']}, lru {k['mmap_lru_windows']}, "
              f"threads {k['sample_threads']}/{k['load_threads']}/"
              f"{k['train_threads']}, refresh 1/{k['refresh_period']} "
              f"@ {k['refresh_frac']:.2f}")
        for mv in rep.get("moves", []):
            print(f"  + {mv['move']}: predicted "
                  f"{mv['baseline_predicted']*1e3:.2f} -> "
                  f"{mv['predicted']*1e3:.2f} ms, measured "
                  f"{mv['baseline_wall']*1e3:.2f} -> "
                  f"{mv['measured_wall']*1e3:.2f} ms")
    if tr._failed:
        print(f"survived failures: {sorted(tr._failed)}")
    h = tr.health()
    line = f"health: {h['status']}"
    if h["events"]:
        line += " — " + "; ".join(
            f"{e['component']} (it {e['iteration']}): {e['action']}"
            for e in h["events"])
    st = h["components"].get("storage", {})
    if st.get("io_errors") or st.get("fallback_gathers"):
        line += (f" | storage: {st['io_errors']} I/O errors, "
                 f"{st['io_retries']} retried, "
                 f"{st['fallback_gathers']} fallback gathers")
    print(line)
    if injector is not None:
        print(f"faults injected: {injector.report()}")
    tr.close()


if __name__ == "__main__":
    main()
