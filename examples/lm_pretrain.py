"""LM pretraining example: train the ~100M-class smollm-135m family
(reduced width for CPU speed; pass --full-135m for the real config) with
the TFP-prefetched token pipeline, AdamW + cosine schedule, checkpointing.

    PYTHONPATH=src python examples/lm_pretrain.py --steps 300
"""
import subprocess
import sys


def main():
    args = sys.argv[1:]
    full = "--full-135m" in args
    if full:
        args.remove("--full-135m")
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "smollm-135m",
           "--steps", "300", "--batch", "8", "--seq", "64",
           "--ckpt-dir", "/tmp/repro_lm_ckpt", "--ckpt-every", "100"]
    if not full:
        cmd.append("--reduced")
    cmd += args
    print(" ".join(cmd))
    raise SystemExit(subprocess.call(cmd))


if __name__ == "__main__":
    main()
