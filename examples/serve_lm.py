"""Batched-serving example: prefill + decode a batch of requests against
the per-layer KV/state caches (works for every assigned arch family —
attention, SWA ring-buffer, Mamba-2 and RWKV recurrent states).

    PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-1.6b
"""
import subprocess
import sys


def main():
    cmd = [sys.executable, "-m", "repro.launch.serve",
           "--reduced", "--batch", "4", "--prompt-len", "16", "--gen", "16"]
    if "--arch" not in sys.argv:
        cmd += ["--arch", "smollm-135m"]
    cmd += sys.argv[1:]
    print(" ".join(cmd))
    raise SystemExit(subprocess.call(cmd))


if __name__ == "__main__":
    main()
