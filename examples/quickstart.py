"""Quickstart: train a GraphSAGE model with the HyScale-GNN hybrid system
on a synthetic ogbn-products-like graph, in ~30 seconds on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import HybridConfig, HybridGNNTrainer
from repro.graph import GNNConfig, make_dataset


def main():
    # scaled-down ogbn-products (same degree distribution + feature dims)
    dataset = make_dataset("ogbn-products", scale=0.005, seed=0)
    print(f"dataset: {dataset.name}  |V|={dataset.num_nodes:,} "
          f"|E|={dataset.num_edges:,}  f0={dataset.feat_dim}")

    gnn = GNNConfig(model="sage", layer_dims=(100, 128, 47),
                    fanouts=(10, 5), num_classes=47)
    system = HybridConfig(
        total_batch=512,
        n_accel=2,          # two (logical) accelerator trainers
        hybrid=True,        # the CPU trains too (paper Section III)
        use_drm=True,       # dynamic resource management (Section IV-A)
        tfp_depth=2,        # two-stage feature prefetching (Section IV-B)
        lr=5e-3,
    )
    trainer = HybridGNNTrainer(dataset, gnn, system)
    history = trainer.train(num_iterations=20)

    for m in history[::4]:
        cpu_b, accel_b = m.assignment
        print(f"iter {m.iteration:3d}  loss {m.loss:.3f}  acc {m.acc:.3f}  "
              f"{m.iter_time*1e3:7.1f} ms  {m.mteps:6.2f} MTEPS  "
              f"shares: cpu={cpu_b} accel={accel_b}x{system.n_accel}")
    print(f"\nmean throughput: {trainer.mean_mteps():.2f} MTEPS")


if __name__ == "__main__":
    main()
