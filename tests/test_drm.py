"""DRM engine (Algorithm 1) unit + property tests."""
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import Assignment, DRMEngine, StageTimes


def _mk(cpu=256, accel=256, n=2, frac=0.5, threads=None):
    return Assignment(cpu_batch=cpu, accel_batch=accel, n_accel=n,
                      sample_frac_accel=frac,
                      threads=dict(threads or {"sample": 2, "load": 2,
                                               "train": 2}))


times_strategy = st.builds(
    StageTimes,
    t_sa=st.floats(0.0, 1.0), t_sc=st.floats(0.001, 1.0),
    t_load=st.floats(0.001, 1.0), t_tran=st.floats(0.0, 1.0),
    t_tc=st.floats(0.001, 1.0), t_ta=st.floats(0.0, 1.0))


@given(times_strategy, st.integers(0, 512), st.integers(1, 512),
       st.integers(0, 4))
@settings(max_examples=100, deadline=None)
def test_total_batch_conserved(times, cpu, accel, n_accel):
    # n_accel == 0 included: the CPU-only degenerate case used to leak
    # rows into accel_batch, which contributes accel_batch * 0 to the total
    a = _mk(cpu=cpu, accel=accel, n=n_accel)
    total = a.total_batch
    engine = DRMEngine(a)
    for _ in range(5):
        a = engine.step(times)
        assert a.total_batch == total, "balance_work must conserve batch"
        assert a.cpu_batch >= 0 and a.accel_batch >= 0


def test_cpu_only_balance_work_is_noop():
    """With no accelerators there is nowhere to move trainer rows: the
    cpu->accel branch must not add rows to the phantom accel_batch."""
    a = _mk(cpu=128, accel=7, n=0)
    engine = DRMEngine(a)
    # t_tc dominates and t_accel is nonzero -> would hit the cpu->accel
    # branch without the guard
    t = StageTimes(t_sa=0.0, t_sc=0.01, t_load=0.01, t_tran=0.001,
                   t_tc=0.5, t_ta=0.001)
    for _ in range(4):
        a = engine.step(t)
        assert a.total_batch == 128
        assert a.cpu_batch == 128 and a.accel_batch == 7


@given(times_strategy)
@settings(max_examples=100, deadline=None)
def test_threads_conserved_and_positive(times):
    a = _mk()
    total_threads = sum(a.threads.values())
    engine = DRMEngine(a)
    for _ in range(5):
        a = engine.step(times)
        assert sum(a.threads.values()) == total_threads
        assert all(v >= 1 for v in a.threads.values())


@given(times_strategy)
@settings(max_examples=50, deadline=None)
def test_sample_frac_in_bounds(times):
    engine = DRMEngine(_mk())
    for _ in range(8):
        a = engine.step(times)
        assert 0.0 <= a.sample_frac_accel <= 1.0


def test_bottleneck_accel_moves_work_to_cpu():
    """Algorithm 1 line 13: T_Accel bottleneck -> balance_work."""
    engine = DRMEngine(_mk(cpu=100, accel=100))
    t = StageTimes(t_sa=0.01, t_sc=0.01, t_load=0.01, t_tran=0.02,
                   t_tc=0.05, t_ta=0.5)
    a = engine.step(t)
    assert a.accel_batch < 100 and a.cpu_batch > 100


def test_bottleneck_cpu_trainer_moves_work_to_accel():
    """Algorithm 1 line 25 + fastest==T_Accel -> balance_work."""
    engine = DRMEngine(_mk(cpu=100, accel=100))
    t = StageTimes(t_sa=0.03, t_sc=0.03, t_load=0.04, t_tran=0.001,
                   t_tc=0.5, t_ta=0.001)
    a = engine.step(t)
    assert a.cpu_batch < 100


def test_bottleneck_loader_moves_threads():
    """Algorithm 1 line 15: T_Load bottleneck -> balance_thread."""
    engine = DRMEngine(_mk())
    t = StageTimes(t_sa=0.1, t_sc=0.01, t_load=0.5, t_tran=0.1,
                   t_tc=0.2, t_ta=0.1)
    a = engine.step(t)
    assert a.threads["load"] == 3
    assert a.threads["sample"] == 1  # fastest CPU task donated


def test_drm_converges_on_synthetic_imbalance():
    """Feedback loop in a realistic regime (sampling/loading costs are
    comparable to training, as in the paper's pipeline): times
    proportional to shares -> DRM equalizes the trainer shares."""
    a = _mk(cpu=480, accel=16, n=1)
    engine = DRMEngine(a, damping=0.5)
    for _ in range(60):
        total = a.total_batch
        t = StageTimes(t_sa=0.0,
                       t_sc=0.3 * total,          # CPU sampling
                       t_load=0.4 * total,        # feature loading
                       t_tran=0.2 * a.accel_batch,
                       t_tc=1.0 * a.cpu_batch,
                       t_ta=1.0 * a.accel_batch)
        a = engine.step(t)
    assert abs(a.cpu_batch - a.accel_batch) < 0.2 * a.total_batch


def test_stall_excluded_from_balancing_signal():
    """Regression: the balancing signal must subtract t_load_stall.

    A loader whose wall time is dominated by storage-I/O stall (cold mmap
    faults) is not compute-bound: rebalancing threads or rows cannot
    shrink the stall (the prefetcher exists for that).  Folding the stall
    in made the loader look like the system bottleneck and stole a thread
    from the real pipeline.  The stall-bound system must take the same
    action as its stall-free twin (identical compute profile)."""
    stalled = StageTimes(t_sa=0.0, t_sc=0.10, t_load=0.50, t_load_stall=0.48,
                         t_tran=0.20, t_tc=0.05, t_ta=0.30)
    clean = StageTimes(t_sa=0.0, t_sc=0.10, t_load=0.02,
                       t_tran=0.20, t_tc=0.05, t_ta=0.30)
    e1, e2 = DRMEngine(_mk()), DRMEngine(_mk())
    a1, a2 = e1.step(stalled), e2.step(clean)
    assert e1.log[-1][1] == e2.log[-1][1], \
        "stall-bound and stall-free twins must take the same action"
    # the effective bottleneck is t_accel -> rows move accel->cpu, and the
    # loader is NOT granted a thread at the trainers' expense
    assert a1.threads == {"sample": 2, "load": 2, "train": 2}
    assert a1.cpu_batch > 256 and a1.accel_batch < 256
    assert (a1.cpu_batch, a1.accel_batch) == (a2.cpu_batch, a2.accel_batch)


def test_accel_only_inactive_trainer_never_donates():
    """Regression: ``cpu_ranked`` ranked over the raw stage dict without
    the zero-time activity filter, so a stage that never ran — t_tc == 0
    with no CPU trainer — was 'fastest CPU task' and donated a thread
    every iteration, bleeding the train stage's pool dry in accel-only
    configs.  The donor must come from stages that actually ran."""
    engine = DRMEngine(_mk(cpu=0, accel=256, n=2, frac=0.0))
    t = StageTimes(t_sa=0.0, t_sc=0.05, t_load=0.5, t_tran=0.01,
                   t_tc=0.0, t_ta=0.02)
    a = engine.step(t)
    # load is the bottleneck; among the stages that ran, sample (0.05) is
    # the fastest CPU task and donates — NOT the inactive CPU trainer
    assert a.threads == {"sample": 1, "load": 3, "train": 2}
    # repeated steps never drain the inactive trainer's pool
    for _ in range(8):
        a = engine.step(t)
    assert a.threads["train"] == 2


@given(st.floats(0.0, 1.0), st.floats(0.0, 1.0))
@settings(max_examples=100, deadline=None)
def test_balanced_sampler_pair_zero_drift(t_eq, frac):
    """Regression: at t_sc == t_sa (including both 0 in a probe
    iteration) the 1e-9 clamp on t_fast made the step negative, and the
    ``t_sc > t_sa`` branch — False at equality — subtracted it from the
    accel share on every call: a perfectly balanced sampler pair drifted.
    Equality must be a no-op, repeated indefinitely."""
    engine = DRMEngine(_mk(frac=frac))
    t = StageTimes(t_sa=t_eq, t_sc=t_eq, t_load=0.1, t_tran=0.1,
                   t_tc=0.1, t_ta=0.1)
    for _ in range(10):
        engine._balance_work_sample(t)
        assert engine.assign.sample_frac_accel == frac, \
            "balanced sampler pair must produce zero drift"


def test_stall_exceeding_wall_time_clamps():
    """Pool-thread-summed stall can exceed the wall-clock t_load: the
    effective load signal clamps at 0 (inactive) instead of going
    negative and ranking the loader 'fastest CPU task'."""
    t = StageTimes(t_sa=0.0, t_sc=0.30, t_load=0.20, t_load_stall=0.55,
                   t_tran=0.32, t_tc=0.40, t_ta=0.35)
    a = DRMEngine(_mk()).step(t)
    # bottleneck is t_tc; fastest CPU task must be sample-or-load by
    # *compute* time — with the clamp, load (0.0) donates the thread
    assert a.threads["train"] == 3
    assert a.threads["load"] == 1
