"""Sharded hot-feature plane (CPU-mesh parity suite).

Placement invariants (disjoint shards, full coverage, for both hash and
degree-range policies), union-lookup classification counts against a
brute-force oracle, the union-gather's strict byte reduction and stats
identity, the peer-exchange collective + shard-aware assemble (jnp and
Pallas-interpret paths), sharded-vs-replicated loss bit-identity end to
end (including a dynamic refresh mid-run), and the cross-iteration
recent-rows LRU (skip / count / invalidate-on-refresh)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import HybridConfig, HybridGNNTrainer
from repro.dist.collectives import exchange_peer_rows, ring_order
from repro.graph import (FeatureLoader, GNNConfig, HashedFeatures,
                         ShardMissBlock, ShardPlacement, ShardedFeatureCache,
                         make_dataset)
from repro.kernels.ops import assemble_features_sharded, gather_rows

N, F = 400, 12


def _plane(n_shards=2, capacity=30, placement="hash", seed=0):
    src = HashedFeatures(N, F, seed=seed)
    hotness = np.arange(N, 0, -1, dtype=np.float64)  # node 0 hottest
    return src, ShardedFeatureCache(src, hotness, capacity, n_shards,
                                    placement=placement)


def _ds():
    return make_dataset("ogbn-products", scale=0.002, seed=0)


def _gcfg(ds):
    return GNNConfig(model="sage", layer_dims=(ds.feat_dim, 32, 47),
                     fanouts=(4, 3), num_classes=47)


# ------------------------------------------------- placement invariants


@pytest.mark.parametrize("policy", ShardPlacement.POLICIES)
@pytest.mark.parametrize("n_shards", [2, 3, 4])
def test_placement_disjoint_and_exhaustive(policy, n_shards):
    hotness = np.arange(N, 0, -1, dtype=np.float64)
    pl = ShardPlacement(N, n_shards, policy, hotness)
    assert pl.owner.shape == (N,)
    # every node owned by exactly one shard, every shard non-trivial
    assert pl.owner.min() >= 0 and pl.owner.max() < n_shards
    assert len(np.unique(pl.owner)) == n_shards
    assert np.array_equal(pl.owner_of(np.arange(N)), pl.owner)


def test_degree_placement_is_contiguous_rank_ranges():
    hotness = np.arange(N, 0, -1, dtype=np.float64)
    pl = ShardPlacement(N, 4, "degree", hotness)
    span = -(-N // 4)
    # hotness is rank order here, so ownership follows id blocks
    assert np.array_equal(pl.owner, np.arange(N) // span)


@pytest.mark.parametrize("policy", ShardPlacement.POLICIES)
def test_shards_are_disjoint_and_owned(policy):
    _, plane = _plane(n_shards=3, capacity=40, placement=policy)
    all_ids = np.concatenate([s.cached_ids for s in plane.shards])
    assert len(np.unique(all_ids)) == len(all_ids), "shards must be disjoint"
    for d, s in enumerate(plane.shards):
        assert np.all(plane.placement.owner[s.cached_ids] == d), \
            "a shard may only pin ids it owns"
    # n x effective capacity at the same per-device budget
    assert plane.capacity == sum(s.capacity for s in plane.shards)
    assert plane.capacity > max(s.capacity for s in plane.shards)


def test_shards_stay_disjoint_after_refresh():
    _, plane = _plane(n_shards=2, capacity=30)
    plane.track_hotness = True
    rng = np.random.default_rng(0)
    for _ in range(6):
        plane.lookup_union(
            {"accel0": rng.integers(0, N, 200),
             "accel1": rng.integers(0, N, 200)},
            {"accel0": 0, "accel1": 1})
    assert plane.refresh(max_swap=8) >= 0
    all_ids = np.concatenate([s.cached_ids for s in plane.shards])
    assert len(np.unique(all_ids)) == len(all_ids)
    for d, s in enumerate(plane.shards):
        assert np.all(plane.placement.owner[s.cached_ids] == d)


# ------------------------------------- union classification vs brute force


def test_union_lookup_classification_counts():
    src, plane = _plane(n_shards=3, capacity=35)
    rng = np.random.default_rng(1)
    frontiers = {f"accel{i}": rng.integers(0, N, 150) for i in range(3)}
    ordinals = {f"accel{i}": i for i in range(3)}
    union = plane.lookup_union(frontiers, ordinals, record=False)
    owner = plane.placement.owner
    cached = [set(s.cached_ids.tolist()) for s in plane.shards]
    for name, sl in union.per_trainer.items():
        me = ordinals[name]
        ids = frontiers[name]
        uniq = np.unique(ids)
        exp_local = [i for i in uniq if owner[i] == me and i in cached[me]]
        exp_peer = [i for i in uniq
                    if owner[i] != me and i in cached[owner[i]]]
        exp_fresh = [i for i in uniq
                     if i not in cached[owner[i]]]
        look = sl.look
        # num_hit counts POSITIONS served by the local shard
        exp_local_pos = int(np.isin(ids, np.asarray(exp_local)).sum())
        assert look.num_hit == exp_local_pos
        assert sl.local_positions == exp_local_pos
        assert sl.peer_rows == len(exp_peer)
        assert look.num_miss == len(exp_fresh)
        assert sorted(look.miss_ids.tolist()) == sorted(exp_fresh)
        # peer requests follow ring order with correct owners
        order = [p for p, _, _ in sl.peer_requests]
        assert order == [p for p in ring_order(3, me)
                         if any(owner[i] == p for i in exp_peer)]
        # position counts partition the frontier
        assert (sl.local_positions + sl.peer_positions
                + int(np.isin(ids, np.asarray(exp_fresh)).sum())
                == ids.shape[0])


# ------------------------------------------- union gather + stats identity


def _loader_with_plane(n_shards=2, capacity=40):
    ds = _ds()
    plane = ShardedFeatureCache(ds.feature_source, ds.feature_hotness(),
                                capacity, n_shards)
    loader = FeatureLoader(ds, cache=plane)
    return ds, plane, loader


class _FakeBatch:
    """Minimal MiniBatch stand-in: only the last-hop frontier is read."""

    fanouts = (1,)

    def __init__(self, ids):
        self._ids = np.asarray(ids, dtype=np.int64)

    def frontier(self, depth):
        return self._ids


def test_union_ships_strictly_fewer_bytes_than_per_trainer_dedup():
    _, plane, loader = _loader_with_plane()
    rng = np.random.default_rng(2)
    shared = rng.integers(0, 2000, 300)   # heavy overlap between trainers
    b0 = _FakeBatch(np.concatenate([shared, rng.integers(0, 2000, 100)]))
    b1 = _FakeBatch(np.concatenate([shared, rng.integers(0, 2000, 100)]))
    blocks = loader.load_union({"accel0": b0, "accel1": b1},
                               {"accel0": 0, "accel1": 1})
    s = loader.stats
    per_trainer_rows = sum(b.lookup.num_miss for b in blocks.values())
    assert s.rows < per_trainer_rows, \
        "union gather must ship strictly fewer rows than per-trainer dedup"
    assert s.union_saved_bytes == \
        (per_trainer_rows - s.rows) * plane.row_bytes
    assert s.ici_bytes >= s.union_saved_bytes


def test_union_stats_identity():
    """Every requested frontier position is accounted exactly once:
    positions x row_bytes = local + peer + dedup + union + shipped."""
    _, plane, loader = _loader_with_plane()
    rng = np.random.default_rng(3)
    for _ in range(3):
        shared = rng.integers(0, 2000, 200)
        loader.load_union(
            {"accel0": _FakeBatch(np.concatenate(
                [shared, rng.integers(0, 2000, 150)])),
             "accel1": _FakeBatch(np.concatenate(
                [shared, rng.integers(0, 2000, 150)]))},
            {"accel0": 0, "accel1": 1})
    s = loader.stats
    assert s.total_rows * plane.row_bytes == (
        s.saved_bytes + s.peer_saved_bytes + s.dedup_saved_bytes
        + s.union_saved_bytes + s.recent_saved_bytes
        + (s.bytes - s.padding_bytes))


def test_union_multicast_slices_match_source():
    """Each trainer's block carries exactly its fresh rows (its slice of
    the one union gather), value-identical to a direct source gather."""
    ds, plane, loader = _loader_with_plane()
    rng = np.random.default_rng(4)
    batches = {f"accel{i}": _FakeBatch(rng.integers(0, 2000, 250))
               for i in range(2)}
    blocks = loader.load_union(batches, {"accel0": 0, "accel1": 1})
    for name, block in blocks.items():
        assert isinstance(block, ShardMissBlock)
        want = ds.feature_source.take(block.lookup.miss_ids)
        assert np.array_equal(block.rows, want.astype(block.rows.dtype))


# --------------------------------------- peer exchange + sharded assemble


def test_gather_rows_jnp_pallas_parity():
    rng = np.random.default_rng(5)
    block = jnp.asarray(rng.normal(size=(64, F)).astype(np.float32))
    slots = rng.integers(0, 64, 17).astype(np.int32)
    ref = np.asarray(gather_rows(block, slots, use_pallas=False))
    pal = np.asarray(gather_rows(block, slots, use_pallas=True,
                                 pipeline_depth=2))
    assert np.array_equal(ref, pal)
    assert np.array_equal(ref, np.asarray(block)[slots])


def test_exchange_peer_rows_preserves_request_order():
    rng = np.random.default_rng(6)
    blocks = {d: jnp.asarray(rng.normal(size=(32, F)).astype(np.float32))
              for d in (1, 2)}
    reqs = [(1, np.array([3, 0, 7], np.int32), 0),
            (2, np.array([5, 5], np.int32), 0)]
    dev = jax.devices()[0]
    out = exchange_peer_rows(reqs, lambda p, v: blocks[p], dev)
    assert len(out) == 2
    assert np.array_equal(np.asarray(out[0]),
                          np.asarray(blocks[1])[[3, 0, 7]])
    assert np.array_equal(np.asarray(out[1]), np.asarray(blocks[2])[[5, 5]])


@pytest.mark.parametrize("use_pallas", [False, True])
def test_sharded_assemble_reconstructs_frontier(use_pallas):
    """Local block + ring-ordered peer rows + fresh host rows must
    assemble into exactly the positional [frontier, F] source rows."""
    src, plane = _plane(n_shards=3, capacity=35)
    rng = np.random.default_rng(7)
    frontiers = {f"accel{i}": rng.integers(0, N, 120) for i in range(3)}
    ordinals = {f"accel{i}": i for i in range(3)}
    union = plane.lookup_union(frontiers, ordinals, pin=True, record=False)
    dev = jax.devices()[0]
    for name, sl in union.per_trainer.items():
        look = sl.look
        local = plane.shards[sl.shard].data_on(dev, version=look.version)
        peers = exchange_peer_rows(
            sl.peer_requests,
            lambda p, v: plane.shards[p].data_on(dev, version=v),
            dev, use_pallas=use_pallas)
        fresh = jnp.asarray(src.take(look.miss_ids).astype(np.float32))
        x = assemble_features_sharded(local, peers + [fresh], look.slots,
                                      look.miss_index,
                                      use_pallas=use_pallas)
        want = src.take(frontiers[name]).astype(np.float32)
        assert np.array_equal(np.asarray(x), want)
        plane.release_union(sl)


# -------------------------------------------- end-to-end trainer parity


def _losses(ds, g, iters=5, **kw):
    cfg = HybridConfig(total_batch=128, n_accel=2, hybrid=False,
                       use_drm=False, tfp_depth=2, cache_fraction=0.05,
                       seed=0, **kw)
    tr = HybridGNNTrainer(ds, g, cfg)
    hist = tr.train(iters)
    tr.close()
    return [m.loss for m in hist], tr


@pytest.mark.parametrize("placement", ShardPlacement.POLICIES)
def test_sharded_replicated_losses_bit_identical(placement):
    ds = _ds()
    g = _gcfg(ds)
    l_rep, _ = _losses(ds, g)
    l_sh, tr = _losses(ds, g, cache_sharding="sharded",
                       shard_placement=placement)
    assert l_rep == l_sh, "sharding must only move bytes, never values"
    ft = tr.feature_traffic()
    assert ft["union_saved_bytes"] > 0 or ft["peer_saved_bytes"] > 0


def test_sharded_bit_identical_with_refresh_mid_run():
    """A dynamic refresh (per-shard stage/commit under the pin protocol)
    mid-pipeline must stay bit-invisible on the sharded plane too."""
    ds = _ds()
    g = _gcfg(ds)
    kw = dict(cache_refresh=True, cache_drift_threshold=0.0)
    l_rep, _ = _losses(ds, g, iters=6, **kw)
    l_sh, tr = _losses(ds, g, iters=6, cache_sharding="sharded", **kw)
    assert l_rep == l_sh
    assert tr.cache.version > 0, "the refresh must actually have fired"


def test_sharded_reduces_shipped_bytes_at_4_accel():
    """The acceptance gate's quantity at small scale: >= 1.5x fewer
    host->device bytes at n_accel=4 vs the replicated plane at equal
    per-device capacity, losses bit-identical."""
    ds = _ds()
    g = _gcfg(ds)
    base = dict(total_batch=256, n_accel=4, hybrid=False, use_drm=False,
                tfp_depth=1, cache_fraction=0.05, seed=0)
    t1 = HybridGNNTrainer(ds, g, HybridConfig(**base))
    h1 = t1.train(4)
    t1.close()
    t2 = HybridGNNTrainer(ds, g, HybridConfig(
        **base, cache_sharding="sharded"))
    h2 = t2.train(4)
    t2.close()
    assert [m.loss for m in h1] == [m.loss for m in h2]
    rep = t1.feature_traffic()["shipped_bytes"]
    sh = t2.feature_traffic()["shipped_bytes"]
    assert rep >= 1.5 * sh


# ------------------------------------------------ recent-rows LRU (PCIe)


def _compact_loader(recent_batches):
    ds = _ds()
    from repro.graph import build_cache
    cache = build_cache(ds, 0.05)
    return ds, cache, FeatureLoader(ds, cache=cache,
                                    recent_batches=recent_batches)


def test_recent_lru_skips_resident_rows_and_counts_them():
    ds, cache, loader = _compact_loader(recent_batches=2)
    rng = np.random.default_rng(8)
    ids = rng.integers(0, ds.num_nodes, 300)
    b1 = loader.load_compact(_FakeBatch(ids), recent_key="accel0")
    assert b1.shipped is not None and b1.recent == []
    shipped_first = b1.rows.shape[0]
    # same frontier again: every unique miss is already device-resident
    b2 = loader.load_compact(_FakeBatch(ids), recent_key="accel0")
    assert b2.rows.shape[0] == 0, "resident rows must not re-ship"
    assert len(b2.recent) == 1
    entry, idx = b2.recent[0]
    assert entry is b1.shipped and idx.shape[0] == shipped_first
    s = loader.stats
    assert s.recent_rows == shipped_first
    assert s.recent_saved_bytes == shipped_first * cache.row_bytes
    # stats identity holds with the recent term
    assert s.total_rows * cache.row_bytes == (
        s.saved_bytes + s.dedup_saved_bytes + s.recent_saved_bytes
        + (s.bytes - s.padding_bytes))


def test_recent_lru_is_per_consumer_and_bounded():
    ds, _, loader = _compact_loader(recent_batches=1)
    rng = np.random.default_rng(9)
    ids_a = rng.integers(0, ds.num_nodes, 200)
    loader.load_compact(_FakeBatch(ids_a), recent_key="accel0")
    # a different consumer never matches another's residency
    b = loader.load_compact(_FakeBatch(ids_a), recent_key="accel1")
    assert b.recent == [] and b.rows.shape[0] > 0
    # depth-1 history: an intervening disjoint batch evicts the first
    ids_b = rng.integers(0, ds.num_nodes, 200)
    loader.load_compact(_FakeBatch(ids_b), recent_key="accel0")
    b2 = loader.load_compact(_FakeBatch(ids_a), recent_key="accel0")
    overlap = np.intersect1d(np.unique(ids_a), np.unique(ids_b))
    matched = sum(idx.shape[0] for _, idx in b2.recent)
    assert matched <= len(overlap), \
        "evicted history must not serve rows (only ids also in batch b)"


def test_recent_lru_invalidated_on_cache_refresh():
    ds, cache, loader = _compact_loader(recent_batches=4)
    cache.track_hotness = True
    rng = np.random.default_rng(10)
    ids = rng.integers(0, ds.num_nodes, 300)
    loader.load_compact(_FakeBatch(ids), recent_key="accel0")
    for _ in range(4):
        cache.lookup(rng.integers(0, ds.num_nodes, 400))
    assert cache.refresh(max_swap=16) > 0
    b = loader.load_compact(_FakeBatch(ids), recent_key="accel0")
    assert b.recent == [], \
        "a cache refresh must invalidate cross-iteration residency"
    assert b.rows.shape[0] > 0


def test_recent_lru_trainer_bit_identical_and_saves_bytes():
    ds = _ds()
    g = _gcfg(ds)
    base = dict(total_batch=128, n_accel=2, hybrid=False, use_drm=False,
                tfp_depth=2, cache_fraction=0.05, seed=0)
    t1 = HybridGNNTrainer(ds, g, HybridConfig(**base))
    h1 = t1.train(6)
    t1.close()
    t2 = HybridGNNTrainer(ds, g, HybridConfig(**base, recent_rows_batches=3))
    h2 = t2.train(6)
    t2.close()
    assert [m.loss for m in h1] == [m.loss for m in h2]
    f1, f2 = t1.feature_traffic(), t2.feature_traffic()
    assert f2["recent_saved_bytes"] > 0
    assert f2["shipped_bytes"] < f1["shipped_bytes"]
