"""Checkpoint: roundtrip, integrity, rotation, async, resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step, restore, save,
                              save_async, wait_for_async)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w1": jax.random.normal(k, (8, 16)),
                       "b1": jnp.zeros(16, jnp.bfloat16)},
            "opt": {"step": jnp.asarray(7, jnp.int32),
                    "m": {"w1": jnp.ones((8, 16)),
                          "b1": jnp.ones(16, jnp.float32)}}}


def test_roundtrip(tmp_path):
    tree = _tree()
    save(str(tmp_path), 42, tree, meta={"note": "x"})
    step, restored = restore(str(tmp_path), None, tree)
    assert step == 42
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype  # bf16 round-trips


def test_integrity_detects_corruption(tmp_path):
    save(str(tmp_path), 1, _tree())
    ckpt = os.path.join(str(tmp_path), "step_00000001")
    victim = [f for f in os.listdir(ckpt) if f.endswith(".bin")][0]
    path = os.path.join(ckpt, victim)
    raw = bytearray(open(path, "rb").read())
    raw[0] ^= 0xFF
    open(path, "wb").write(bytes(raw))
    with pytest.raises(IOError, match="sha256"):
        restore(str(tmp_path), 1, _tree())


def test_shape_mismatch_rejected(tmp_path):
    save(str(tmp_path), 1, _tree())
    bad = _tree()
    bad["params"]["w1"] = jnp.zeros((4, 4))
    with pytest.raises(ValueError, match="shape"):
        restore(str(tmp_path), 1, bad)


def test_rotation_keeps_last_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(str(tmp_path)))
    assert steps == [3, 4]


def test_async_save_then_restore(tmp_path):
    save_async(str(tmp_path), 9, _tree(3))
    wait_for_async()
    assert latest_step(str(tmp_path)) == 9
    step, restored = restore(str(tmp_path), None, _tree())
    assert step == 9


def test_restore_latest_resumes(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    t = _tree(1)
    mgr.save(5, t)
    mgr.finalize()
    got = mgr.restore_latest(_tree(0))
    assert got is not None
    step, tree = got
    assert step == 5
    np.testing.assert_array_equal(np.asarray(tree["params"]["w1"]),
                                  np.asarray(t["params"]["w1"]))
