"""Launch-layer coverage: run the dry-run machinery end-to-end on a SMALL
forced-device mesh in a subprocess (the 512-device production sweep lives
in launch/dryrun.py; tests must not pollute this process's jax device
count, so we fork)."""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from repro.launch.dryrun import run_cell
mesh = jax.make_mesh((4, 2), ("data", "model"))
out = []
for arch, shape, policy in [("smollm-135m", "train_4k", "tp2d"),
                            ("smollm-135m", "decode_32k", "serve2d"),
                            ("rwkv6-1.6b", "prefill_32k", "tp2d")]:
    r = run_cell(arch, shape, mesh, verbose=False, policy=policy)
    out.append({k: r[k] for k in ("arch", "shape", "status")}
               | {"frac": r.get("roofline", {}).get("roofline_fraction"),
                  "coll": r.get("collectives", {}).get("total")})
print("RESULT:" + json.dumps(out))
"""


@pytest.mark.slow
def test_dryrun_cells_compile_on_small_mesh():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT:")][0]
    results = json.loads(line[len("RESULT:"):])
    assert len(results) == 3
    for r in results:
        assert r["status"] == "ok", r
        assert r["frac"] is not None
    # the partitioned programs actually contain collectives
    assert any((r["coll"] or 0) > 0 for r in results)
