"""Per-kernel allclose sweeps: Pallas (interpret mode) vs pure-jnp oracle,
over shapes × dtypes, forward and backward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [
    # (n_dst, fanout, f_in, f_out)
    (8, 3, 16, 8),
    (64, 5, 100, 47),       # ogbn-products dims
    (128, 25, 128, 256),    # papers100M layer-1 dims
    (17, 3, 33, 9),         # ragged/padded path
    (256, 10, 256, 172),
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _inputs(d, fan, f, o, dtype, seed=0):
    rng = np.random.default_rng(seed)
    mk = lambda *s: jnp.asarray(rng.normal(size=s), jnp.float32).astype(dtype)
    return dict(x_self=mk(d, f), x_nbr=mk(d * fan, f), w_edge=mk(d * fan),
                self_scale=mk(d), w_self=mk(f, o) * 0.1, w_agg=mk(f, o) * 0.1,
                bias=mk(o))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_segment_sum_kernel(shape, dtype):
    d, fan, f, o = shape
    i = _inputs(d, fan, f, o, dtype)
    got = ops.segment_weighted_sum_regular(i["x_nbr"], i["w_edge"], fan)
    want = ref.segment_weighted_sum_regular(i["x_nbr"], i["w_edge"], fan)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_fused_update_kernel(shape, dtype):
    d, fan, f, o = shape
    i = _inputs(d, fan, f, o, dtype)
    got = ops.fused_gnn_update(i["x_self"], i["x_nbr"], i["w_edge"],
                               i["self_scale"], i["w_self"], i["w_agg"],
                               i["bias"], fan)
    want = ref.fused_gnn_update(i["x_self"], i["x_nbr"], i["w_edge"],
                                i["self_scale"], i["w_self"], i["w_agg"],
                                i["bias"], fan)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("shape", SHAPES[:3])
def test_fused_kernel_grads_match_oracle(shape):
    d, fan, f, o = shape
    i = _inputs(d, fan, f, o, jnp.float32)
    args = (i["x_self"], i["x_nbr"], i["w_edge"], i["self_scale"],
            i["w_self"], i["w_agg"], i["bias"])

    gk = jax.grad(lambda a: ops.fused_gnn_update(*a, fan).sum())(args)
    gr = jax.grad(lambda a: ref.fused_gnn_update(*a, fanout=fan).sum())(args)
    for got, want in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


def test_segment_sum_grads():
    d, fan, f = 16, 4, 24
    i = _inputs(d, fan, f, 8, jnp.float32)
    gk = jax.grad(lambda a: ops.segment_weighted_sum_regular(
        a[0], a[1], fan).sum())((i["x_nbr"], i["w_edge"]))
    gr = jax.grad(lambda a: ref.segment_weighted_sum_regular(
        a[0], a[1], fan).sum())((i["x_nbr"], i["w_edge"]))
    for got, want in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


# ------------------------------- cache scatter update (refresh path)

UPDATE_CASES = [
    # (cache_rows, feat_dim, n_updates)
    (8, 16, 3),
    (64, 128, 12),       # aligned dims
    (17, 33, 9),         # ragged rows/cols (padded F path)
    (300, 100, 40),
    (5, 7, 1),
]


@pytest.mark.parametrize("case", UPDATE_CASES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("use_pallas", [False, True])
def test_cache_update_matches_oracle(case, dtype, use_pallas):
    """Scatter-update parity: both dispatch paths must reproduce the
    sequential (last-writer-wins) oracle bit-for-bit — the update is a
    pure row copy, so equality is exact even in bf16.  Slots are drawn
    with replacement, so update sets routinely alias the same slot."""
    k, f, m = case
    rng = np.random.default_rng(k * 1000 + f)
    cache = jnp.asarray(rng.normal(size=(k, f)), jnp.float32).astype(dtype)
    rows = jnp.asarray(rng.normal(size=(m, f)), jnp.float32).astype(dtype)
    slots = rng.integers(0, k, m).astype(np.int32)
    want = ref.cache_update(cache, rows, jnp.asarray(slots))
    got = ops.update_cache_rows(cache, np.asarray(rows), slots,
                                use_pallas=use_pallas)
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))
    assert got.dtype == cache.dtype


@pytest.mark.parametrize("use_pallas", [False, True])
def test_cache_update_all_aliased_one_slot(use_pallas):
    """Every update row targeting one slot: the last row must win."""
    cache = jnp.zeros((6, 8), jnp.float32)
    rows = jnp.arange(1, 5, dtype=jnp.float32)[:, None] * jnp.ones((4, 8))
    slots = np.full(4, 3, np.int32)
    got = np.asarray(ops.update_cache_rows(cache, np.asarray(rows), slots,
                                           use_pallas=use_pallas))
    want = np.asarray(ref.cache_update(cache, rows, jnp.asarray(slots)))
    np.testing.assert_array_equal(got, want)
    assert np.all(got[3] == 4.0)
    assert np.all(np.delete(got, 3, axis=0) == 0.0)


@pytest.mark.parametrize("use_pallas", [False, True])
def test_cache_update_empty_is_identity(use_pallas):
    cache = jnp.asarray(np.random.default_rng(0).normal(size=(9, 5)),
                        jnp.float32)
    got = ops.update_cache_rows(cache, np.zeros((0, 5), np.float32),
                                np.zeros(0, np.int32),
                                use_pallas=use_pallas)
    assert got is cache       # no-op refresh never touches the device
    want = ref.cache_update(cache, jnp.zeros((0, 5)), jnp.zeros(0, jnp.int32))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ------------------------- pipelined (multi-buffered DMA) kernel parity

ASSEMBLE_CASES = [
    # (cache_rows, feat_dim, n_positions, n_miss)
    (64, 100, 130, 9),
    (128, 128, 257, 33),    # ragged position tail
    (17, 33, 41, 5),        # ragged rows/cols (padded F path)
    (256, 64, 512, 48),
]
PIPELINE_DEPTHS = [1, 2, 3, 4]


def _assemble_case(k, f, n, m, dtype, seed=0):
    rng = np.random.default_rng(seed + k * 31 + n)
    cache = jnp.asarray(rng.normal(size=(k, f)), jnp.float32).astype(dtype)
    miss = jnp.asarray(rng.normal(size=(m, f)), jnp.float32).astype(dtype)
    # slots drawn with replacement: many positions alias one cached row /
    # one shipped miss row (the dedup fan-out the kernel exists for)
    slots = rng.integers(-1, k, n).astype(np.int32)
    miss_index = rng.integers(0, m, n).astype(np.int32)
    return cache, miss, slots, miss_index


@pytest.mark.parametrize("case", ASSEMBLE_CASES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_assemble_pipelined_matches_oracle_all_depths(case, dtype):
    """The pipeline depth is a pure scheduling knob: every depth must
    reproduce the jnp oracle AND the depth-1 kernel bit-for-bit (the
    pipelined combine runs the same one-hot f32 matmul over the same
    window values, just with the slab DMAs multi-buffered)."""
    k, f, n, m = case
    cache, miss, slots, miss_index = _assemble_case(k, f, n, m, dtype)
    want = np.asarray(ref.assemble_features(
        cache, miss, jnp.asarray(slots), jnp.asarray(miss_index)
        ).astype(jnp.float32))
    d1 = None
    for depth in PIPELINE_DEPTHS:
        got = np.asarray(ops.assemble_features(
            cache, miss, slots, miss_index, use_pallas=True,
            pipeline_depth=depth).astype(jnp.float32))
        np.testing.assert_array_equal(got, want, err_msg=f"depth={depth}")
        if d1 is None:
            d1 = got
        np.testing.assert_array_equal(got, d1, err_msg=f"depth={depth}")


@pytest.mark.parametrize("case", UPDATE_CASES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("depth", PIPELINE_DEPTHS[1:])
def test_cache_update_pipelined_matches_oracle(case, dtype, depth):
    """Pipelined scatter-update parity: slots drawn with replacement, so
    aliased update sets exercise the host-side keep-last compaction the
    concurrent write DMAs require — still bit-identical to the
    sequential last-writer-wins oracle and the depth-1 kernel."""
    k, f, m = case
    rng = np.random.default_rng(k * 1000 + f)
    cache = jnp.asarray(rng.normal(size=(k, f)), jnp.float32).astype(dtype)
    rows = jnp.asarray(rng.normal(size=(m, f)), jnp.float32).astype(dtype)
    slots = rng.integers(0, k, m).astype(np.int32)
    want = ref.cache_update(cache, rows, jnp.asarray(slots))
    d1 = ops.update_cache_rows(cache, np.asarray(rows), slots,
                               use_pallas=True)
    got = ops.update_cache_rows(cache, np.asarray(rows), slots,
                                use_pallas=True, pipeline_depth=depth)
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(d1, np.float32))
    assert got.dtype == cache.dtype


@pytest.mark.parametrize("depth", PIPELINE_DEPTHS[1:])
def test_cache_update_pipelined_all_aliased_one_slot(depth):
    cache = jnp.zeros((6, 8), jnp.float32)
    rows = jnp.arange(1, 5, dtype=jnp.float32)[:, None] * jnp.ones((4, 8))
    slots = np.full(4, 3, np.int32)
    got = np.asarray(ops.update_cache_rows(cache, np.asarray(rows), slots,
                                           use_pallas=True,
                                           pipeline_depth=depth))
    assert np.all(got[3] == 4.0)
    assert np.all(np.delete(got, 3, axis=0) == 0.0)


def test_pipelined_kernels_reject_bad_depth():
    from repro.kernels import gather_scatter_mm as gsm
    src = jnp.zeros((512, 128), jnp.float32)
    base = np.zeros(1, np.int32)
    local = np.zeros((1, 128), np.int32)
    with pytest.raises(ValueError, match="depth must be >= 1"):
        gsm.cache_combine_pipelined_kernel_call(src, base, local, depth=0)
    cache = jnp.zeros((8, 128), jnp.float32)
    rows = jnp.zeros((8, 128), jnp.float32)
    slots = jnp.arange(8, dtype=jnp.int32)
    with pytest.raises(ValueError, match="depth must be >= 1"):
        gsm.cache_update_pipelined_kernel_call(cache, rows, slots, depth=0)


def test_vmem_scratch_budget():
    """The depth-4 target window (128x128 f32 tiles, 4W-row slabs) must
    fit the VMEM scratch budget; an over-budget request raises with the
    knobs to turn, and the kernel entry point enforces it."""
    from repro.kernels import gather_scatter_mm as gsm
    # target window at depth 4: 4 slabs x (4*128 rows x 128 cols) x 4 B
    target = 4 * 4 * 128 * 128 * 4
    assert target <= gsm.VMEM_SCRATCH_BUDGET_BYTES
    gsm.check_vmem_scratch(target, "combine depth=4")    # must not raise
    with pytest.raises(ValueError, match="exceeds the"):
        gsm.check_vmem_scratch(gsm.VMEM_SCRATCH_BUDGET_BYTES + 1, "probe")
    # the combine entry point itself rejects an over-budget config:
    # depth 33 x 4*128x128 f32 slabs = 8.25 MiB > 8 MiB
    src = jnp.zeros((4 * 128 + 128, 128), jnp.float32)
    base = np.zeros(1, np.int32)
    local = np.zeros((1, 128), np.int32)
    with pytest.raises(ValueError, match="exceeds the"):
        gsm.cache_combine_pipelined_kernel_call(src, base, local,
                                                t_n=128, t_f=128, depth=33)


@pytest.mark.parametrize("shape", [(2, 32, 2, 2, 16), (1, 64, 1, 4, 32)])
def test_flash_attention_matches_blocked(shape):
    from repro.models.layers import attention
    b, s, hkv, g, d = shape
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, s, hkv * g, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, d))
    blocked = attention(q, k, v, q_block=16, impl="blocked")
    flash = attention(q, k, v, q_block=16, impl="flash")
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(flash),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_grads():
    from repro.models.layers import attention
    key = jax.random.PRNGKey(3)
    b, s, h, d = 2, 32, 2, 16
    q = jax.random.normal(key, (b, s, h, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, d))

    def loss(impl):
        return jax.grad(lambda a: (attention(*a, q_block=16,
                                             impl=impl) ** 2).sum())((q, k, v))

    for a, b_ in zip(loss("blocked"), loss("flash")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=2e-4, atol=2e-4)
