"""Per-kernel allclose sweeps: Pallas (interpret mode) vs pure-jnp oracle,
over shapes × dtypes, forward and backward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [
    # (n_dst, fanout, f_in, f_out)
    (8, 3, 16, 8),
    (64, 5, 100, 47),       # ogbn-products dims
    (128, 25, 128, 256),    # papers100M layer-1 dims
    (17, 3, 33, 9),         # ragged/padded path
    (256, 10, 256, 172),
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _inputs(d, fan, f, o, dtype, seed=0):
    rng = np.random.default_rng(seed)
    mk = lambda *s: jnp.asarray(rng.normal(size=s), jnp.float32).astype(dtype)
    return dict(x_self=mk(d, f), x_nbr=mk(d * fan, f), w_edge=mk(d * fan),
                self_scale=mk(d), w_self=mk(f, o) * 0.1, w_agg=mk(f, o) * 0.1,
                bias=mk(o))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_segment_sum_kernel(shape, dtype):
    d, fan, f, o = shape
    i = _inputs(d, fan, f, o, dtype)
    got = ops.segment_weighted_sum_regular(i["x_nbr"], i["w_edge"], fan)
    want = ref.segment_weighted_sum_regular(i["x_nbr"], i["w_edge"], fan)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_fused_update_kernel(shape, dtype):
    d, fan, f, o = shape
    i = _inputs(d, fan, f, o, dtype)
    got = ops.fused_gnn_update(i["x_self"], i["x_nbr"], i["w_edge"],
                               i["self_scale"], i["w_self"], i["w_agg"],
                               i["bias"], fan)
    want = ref.fused_gnn_update(i["x_self"], i["x_nbr"], i["w_edge"],
                                i["self_scale"], i["w_self"], i["w_agg"],
                                i["bias"], fan)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("shape", SHAPES[:3])
def test_fused_kernel_grads_match_oracle(shape):
    d, fan, f, o = shape
    i = _inputs(d, fan, f, o, jnp.float32)
    args = (i["x_self"], i["x_nbr"], i["w_edge"], i["self_scale"],
            i["w_self"], i["w_agg"], i["bias"])

    gk = jax.grad(lambda a: ops.fused_gnn_update(*a, fan).sum())(args)
    gr = jax.grad(lambda a: ref.fused_gnn_update(*a, fanout=fan).sum())(args)
    for got, want in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


def test_segment_sum_grads():
    d, fan, f = 16, 4, 24
    i = _inputs(d, fan, f, 8, jnp.float32)
    gk = jax.grad(lambda a: ops.segment_weighted_sum_regular(
        a[0], a[1], fan).sum())((i["x_nbr"], i["w_edge"]))
    gr = jax.grad(lambda a: ref.segment_weighted_sum_regular(
        a[0], a[1], fan).sum())((i["x_nbr"], i["w_edge"]))
    for got, want in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


# ------------------------------- cache scatter update (refresh path)

UPDATE_CASES = [
    # (cache_rows, feat_dim, n_updates)
    (8, 16, 3),
    (64, 128, 12),       # aligned dims
    (17, 33, 9),         # ragged rows/cols (padded F path)
    (300, 100, 40),
    (5, 7, 1),
]


@pytest.mark.parametrize("case", UPDATE_CASES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("use_pallas", [False, True])
def test_cache_update_matches_oracle(case, dtype, use_pallas):
    """Scatter-update parity: both dispatch paths must reproduce the
    sequential (last-writer-wins) oracle bit-for-bit — the update is a
    pure row copy, so equality is exact even in bf16.  Slots are drawn
    with replacement, so update sets routinely alias the same slot."""
    k, f, m = case
    rng = np.random.default_rng(k * 1000 + f)
    cache = jnp.asarray(rng.normal(size=(k, f)), jnp.float32).astype(dtype)
    rows = jnp.asarray(rng.normal(size=(m, f)), jnp.float32).astype(dtype)
    slots = rng.integers(0, k, m).astype(np.int32)
    want = ref.cache_update(cache, rows, jnp.asarray(slots))
    got = ops.update_cache_rows(cache, np.asarray(rows), slots,
                                use_pallas=use_pallas)
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))
    assert got.dtype == cache.dtype


@pytest.mark.parametrize("use_pallas", [False, True])
def test_cache_update_all_aliased_one_slot(use_pallas):
    """Every update row targeting one slot: the last row must win."""
    cache = jnp.zeros((6, 8), jnp.float32)
    rows = jnp.arange(1, 5, dtype=jnp.float32)[:, None] * jnp.ones((4, 8))
    slots = np.full(4, 3, np.int32)
    got = np.asarray(ops.update_cache_rows(cache, np.asarray(rows), slots,
                                           use_pallas=use_pallas))
    want = np.asarray(ref.cache_update(cache, rows, jnp.asarray(slots)))
    np.testing.assert_array_equal(got, want)
    assert np.all(got[3] == 4.0)
    assert np.all(np.delete(got, 3, axis=0) == 0.0)


@pytest.mark.parametrize("use_pallas", [False, True])
def test_cache_update_empty_is_identity(use_pallas):
    cache = jnp.asarray(np.random.default_rng(0).normal(size=(9, 5)),
                        jnp.float32)
    got = ops.update_cache_rows(cache, np.zeros((0, 5), np.float32),
                                np.zeros(0, np.int32),
                                use_pallas=use_pallas)
    assert got is cache       # no-op refresh never touches the device
    want = ref.cache_update(cache, jnp.zeros((0, 5)), jnp.zeros(0, jnp.int32))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("shape", [(2, 32, 2, 2, 16), (1, 64, 1, 4, 32)])
def test_flash_attention_matches_blocked(shape):
    from repro.models.layers import attention
    b, s, hkv, g, d = shape
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, s, hkv * g, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, d))
    blocked = attention(q, k, v, q_block=16, impl="blocked")
    flash = attention(q, k, v, q_block=16, impl="flash")
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(flash),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_grads():
    from repro.models.layers import attention
    key = jax.random.PRNGKey(3)
    b, s, h, d = 2, 32, 2, 16
    q = jax.random.normal(key, (b, s, h, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, d))

    def loss(impl):
        return jax.grad(lambda a: (attention(*a, q_block=16,
                                             impl=impl) ** 2).sum())((q, k, v))

    for a, b_ in zip(loss("blocked"), loss("flash")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=2e-4, atol=2e-4)
