"""Dynamic cache-refresh subsystem: admission-policy property tests,
versioned in-flight consistency (a refresh between _stage_load and
_stage_transfer must be semantically invisible, including the n_accel=0
CPU-only path), the epoch-window stats reset, and the windowed feedback
into the perf-model mapping."""
import hypothesis.strategies as st
import jax
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import HybridConfig, HybridGNNTrainer
from repro.graph import (FeatureCache, FeatureLoader, GNNConfig,
                         HashedFeatures, NumpySampler, make_dataset)
from repro.kernels.ops import assemble_features

N, F = 300, 16


def _cache(capacity=40, seed=0, **kw):
    src = HashedFeatures(N, F, seed=seed)
    hotness = np.arange(N, 0, -1, dtype=np.float64)  # node 0 hottest
    cache = FeatureCache(src, hotness, capacity, **kw)
    cache.track_hotness = True       # opt-in: these tests drive refresh()
    return src, cache


def _consistent_inverse(cache):
    """slot_of and cached_ids must stay exact inverses of each other."""
    assert cache.cached_ids.shape == (cache.capacity,)
    assert np.unique(cache.cached_ids).shape == (cache.capacity,)
    assert np.array_equal(cache.slot_of[cache.cached_ids],
                          np.arange(cache.capacity, dtype=np.int32))
    assert np.count_nonzero(cache.slot_of >= 0) == cache.capacity


# ------------------------------------------- admission-policy properties


@given(st.integers(1, 120), st.integers(2, 60), st.integers(0, 10_000),
       st.integers(1, 5))
@settings(max_examples=25, deadline=None)
def test_refresh_policy_invariants(capacity, batch, seed, rounds):
    """Hypothesis-driven id streams: refresh never shrinks the cache,
    never admits a node colder than an evicted one (under the decayed
    counters), keeps ``slot_of`` a consistent inverse of the slot table,
    and leaves ``nbytes`` constant."""
    src, cache = _cache(capacity=capacity, seed=1)
    rng = np.random.default_rng(seed)
    nbytes0, ids0 = cache.nbytes, cache.cached_ids.copy()
    for _ in range(rounds):
        for _ in range(3):
            cache.lookup(rng.integers(0, N, size=batch).astype(np.int64))
        pre_slot = cache.slot_hotness()
        pre_ids = cache.cached_ids.copy()
        pre_node = cache.uncached_hotness(np.arange(N))
        ver = cache.version
        swapped = cache.refresh()
        # never shrinks, never re-sizes the pinned device block
        assert cache.capacity == capacity
        assert cache.nbytes == nbytes0
        _consistent_inverse(cache)
        admitted = np.setdiff1d(cache.cached_ids, pre_ids)
        evicted = np.setdiff1d(pre_ids, cache.cached_ids)
        assert admitted.shape[0] == evicted.shape[0] == swapped
        if swapped:
            assert cache.version == ver + 1
            # hottest-vs-coldest pairing: even the coldest admitted node
            # is strictly hotter (pre-refresh estimates) than the hottest
            # evicted one
            evict_est = pre_slot[[int(np.flatnonzero(pre_ids == e)[0])
                                  for e in evicted]]
            assert pre_node[admitted].min() > evict_est.max()
        else:
            assert cache.version == ver
        # host rows always mirror the source for the resident set
        assert np.array_equal(cache._host_rows,
                              src.take(cache.cached_ids))
    # ids0 only documents the boot set; the policy may keep or evolve it
    assert cache.cached_ids.shape == ids0.shape


def test_refresh_without_traffic_is_noop():
    _, cache = _cache()
    ids, ver = cache.cached_ids.copy(), cache.version
    assert cache.refresh() == 0
    assert cache.version == ver and np.array_equal(cache.cached_ids, ids)


def test_refresh_max_swap_caps_movement():
    _, cache = _cache(capacity=40)
    rng = np.random.default_rng(0)
    for _ in range(4):
        cache.lookup(rng.integers(100, N, size=200).astype(np.int64))
    assert cache.refresh(max_swap=3) == 3


def test_refresh_respects_max_refresh_frac():
    _, cache = _cache(capacity=40, max_refresh_frac=0.1)
    rng = np.random.default_rng(0)
    for _ in range(4):
        cache.lookup(rng.integers(100, N, size=200).astype(np.int64))
    assert 0 < cache.refresh() <= 4          # 10% of 40 slots


def test_refresh_decay_forgets_old_hotness():
    """A burst heated long ago must lose an admission contest against a
    steady recent stream of the same per-window volume."""
    _, cache = _cache(capacity=10, refresh_decay=0.5)
    old = np.full(50, 100, np.int64)       # uncached id 100, early burst
    new = np.full(50, 200, np.int64)       # uncached id 200, recent
    cache.lookup(np.concatenate([old, old]))
    for _ in range(3):
        cache.refresh(max_swap=0)          # window boundaries: decay only
        cache.lookup(new)
    est = cache.uncached_hotness(np.array([100, 200]))
    assert est[1] > est[0]


# ------------------------------------------- staged refresh (stage/commit)


def _heat(cache, lo, hi, rounds=4, reps=4, seed=0):
    rng = np.random.default_rng(seed)
    for _ in range(rounds):
        cache.lookup(np.repeat(rng.integers(lo, hi, 60), reps))


def test_stage_commit_matches_one_shot_refresh():
    """Identical traffic through the split protocol and the one-shot
    refresh() must land the identical cache state (same plan, same swap,
    one decay per boundary)."""
    _, a = _cache(capacity=30, seed=2)
    _, b = _cache(capacity=30, seed=2)
    _heat(a, 100, N)
    _heat(b, 100, N)
    planned = a.stage()
    assert a.staged_ready and a.staged_swaps == planned > 0
    assert a.commit() == planned
    assert b.refresh() == planned
    assert np.array_equal(a.cached_ids, b.cached_ids)
    assert np.array_equal(a.slot_of, b.slot_of)
    assert np.array_equal(a._host_rows, b._host_rows)
    assert np.array_equal(a.slot_hotness(), b.slot_hotness())
    assert a.version == b.version == 1
    _consistent_inverse(a)


def test_commit_without_stage_is_noop_without_decay():
    _, cache = _cache(capacity=20)
    _heat(cache, 100, N)
    hot0 = cache.slot_hotness()
    assert cache.commit() == 0
    assert cache.version == 0
    # no staged plan -> not a window boundary: counters must NOT decay
    assert np.array_equal(cache.slot_hotness(), hot0)


def test_stale_staged_plan_discarded_after_concurrent_refresh():
    """A plan staged against version v must be dropped (not applied) when
    another refresh commits first: its victims/candidates were computed
    against a retired slot table."""
    src, cache = _cache(capacity=30)
    _heat(cache, 100, 200)
    assert cache.stage() > 0
    plan = cache._staged                 # hold the staged plan aside
    _heat(cache, 200, N, seed=1)
    assert cache.refresh() > 0           # bumps version past the plan
    cache._staged = plan                 # resurrect the now-stale plan
    ver = cache.version
    ids = cache.cached_ids.copy()
    assert cache.commit() == 0           # stale: discarded
    assert cache.version == ver
    assert np.array_equal(cache.cached_ids, ids)
    _consistent_inverse(cache)
    assert np.array_equal(cache._host_rows, src.take(cache.cached_ids))


def test_stage_gather_runs_outside_the_cache_lock():
    """The expensive admitted-row gather must not hold the cache lock:
    lookups proceed while a slow FeatureSource gather is in flight (the
    disk-tier iteration boundary this PR removes)."""
    import threading
    import time

    class SlowSource:
        def __init__(self, inner):
            self.inner = inner
            self.shape = inner.shape
            self.slow = False
            self.in_take = threading.Event()

        @property
        def dtype(self):
            return self.inner.dtype

        def take(self, rows):
            if self.slow:
                self.in_take.set()
                time.sleep(0.6)
            return self.inner.take(rows)

    slow = SlowSource(HashedFeatures(N, F, seed=1))
    hotness = np.arange(N, 0, -1, dtype=np.float64)
    cache = FeatureCache(slow, hotness, 30)
    cache.track_hotness = True
    _heat(cache, 100, N)
    slow.slow = True
    t = threading.Thread(target=cache.stage)
    t.start()
    assert slow.in_take.wait(5.0)        # stage is inside the slow gather
    t0 = time.perf_counter()
    for _ in range(5):
        cache.lookup(np.arange(50, 90))
    lookup_time = time.perf_counter() - t0
    t.join()
    assert lookup_time < 0.3, f"lookups blocked {lookup_time:.2f}s on stage"
    assert cache.commit() > 0            # the staged plan still lands


# ------------------------------------------------- admission hysteresis


@given(st.integers(4, 40), st.floats(1.01, 1.2), st.integers(1, 4))
@settings(max_examples=20, deadline=None)
def test_hysteresis_oscillating_adversary_never_swaps(capacity, amp,
                                                      rounds):
    """An adversary oscillating a boundary set's hotness within the
    hysteresis factor (default 1.25) must produce ZERO swaps — the
    thrash the margin exists to stop.  Counters decay identically on
    both sides, so the ratio (not the decayed magnitude) is what the
    policy sees."""
    src = HashedFeatures(N, F, seed=0)
    hotness = np.zeros(N)
    hotness[:capacity] = 1.0             # boot set: ids [0, capacity)
    cache = FeatureCache(src, hotness, capacity)
    cache.track_hotness = True
    cached = np.arange(capacity, dtype=np.int64)
    shadow = np.arange(capacity, 2 * capacity, dtype=np.int64)
    base = 8
    swaps = 0
    for r in range(rounds):
        # cached side sees `base` positions each, shadow side oscillates
        # between base/amp and base*amp around it — never past the margin
        hi = max(1, int(round(base * (amp if r % 2 == 0 else 1.0 / amp))))
        cache.lookup(np.repeat(cached, base))
        cache.lookup(np.repeat(shadow, hi))
        swaps += cache.refresh()
    assert swaps == 0
    assert np.array_equal(np.sort(cache.cached_ids), cached)


def test_hysteresis_two_x_hotter_candidate_lands():
    """A candidate genuinely 2x hotter than the coldest slot clears the
    1.25 margin and is admitted."""
    src = HashedFeatures(N, F, seed=0)
    hotness = np.zeros(N)
    hotness[:20] = 1.0
    cache = FeatureCache(src, hotness, 20)
    cache.track_hotness = True
    assert cache.refresh_hysteresis == 1.25          # the documented default
    cached = np.arange(20, dtype=np.int64)
    cache.lookup(np.repeat(cached, 4))               # every slot at 4
    cache.lookup(np.repeat(np.int64(250), 8))        # uncached id at 8 = 2x
    assert cache.refresh() == 1
    assert cache.slot_of[250] >= 0
    _consistent_inverse(cache)


def test_hysteresis_respects_commit_time_revalidation():
    """A victim that heats past the margin while the staged gather runs
    is spared at commit: the pair is re-validated against commit-time
    counters."""
    src = HashedFeatures(N, F, seed=0)
    hotness = np.zeros(N)
    hotness[:10] = 1.0
    cache = FeatureCache(src, hotness, 10)
    cache.track_hotness = True
    cache.lookup(np.repeat(np.arange(10, dtype=np.int64), 2))   # slots at 2
    cache.lookup(np.repeat(np.int64(250), 8))        # candidate at 8 (4x)
    assert cache.stage() == 1
    # between stage and commit the victim (coldest slot) reheats hard
    victim_slot = int(np.argmin(cache.slot_hotness()))
    victim_id = int(cache.cached_ids[victim_slot])
    cache.lookup(np.repeat(np.int64(victim_id), 50))
    assert cache.commit() == 0                       # pair no longer valid
    assert cache.slot_of[250] < 0


# ------------------------------------- versioned in-flight consistency


def test_versioned_assemble_is_refresh_invariant():
    """A lookup classified at version v combined against the version-v
    device block must equal the direct host gather, even after a refresh
    has reshuffled the slot table and device rows."""
    src, cache = _cache(capacity=40)
    dev = jax.devices()[0]
    rng = np.random.default_rng(3)
    frontier = rng.integers(0, N, size=128).astype(np.int64)
    look = cache.lookup(frontier)
    miss = src.take(look.miss_ids) if look.num_miss else \
        np.zeros((1, F), np.float32)
    truth = src.take(frontier)

    def assembled():
        data = cache.data_on(dev, version=look.version)
        return np.asarray(assemble_features(data, jax.numpy.asarray(miss),
                                            look.slots, look.miss_index))

    before = assembled()
    assert np.array_equal(before, truth)
    # heat a disjoint set so the refresh genuinely moves rows
    for _ in range(5):
        cache.lookup(np.repeat(np.arange(250, 280), 4))
    assert cache.refresh(max_swap=40) > 0
    assert cache.version == 1
    # the in-flight lookup still combines against its own version
    assert np.array_equal(assembled(), truth)
    # sanity (the test has teeth): the *current* block differs from v0
    v0 = np.asarray(cache.data_on(dev, version=0))
    v1 = np.asarray(cache.data_on(dev))
    assert not np.array_equal(v0, v1)


def test_new_device_can_place_retained_old_version():
    """Regression: a device that never placed a block before a refresh
    (e.g. a trainer whose share was 0 at boot) must still be able to
    materialize a *retained* old version for an in-flight lookup — only
    versions past the retention window may raise."""
    src, cache = _cache(capacity=30)
    dev = jax.devices()[0]
    look = cache.lookup(np.arange(50, 120))      # classified at v0; the
    ids_v0 = cache.cached_ids.copy()             # device holds nothing yet
    for _ in range(5):
        cache.lookup(np.repeat(np.arange(200, 230), 4))
    assert cache.refresh(max_swap=10) > 0
    block = np.asarray(cache.data_on(dev, version=look.version))
    assert np.array_equal(block, src.take(ids_v0))
    assert not np.array_equal(block, np.asarray(cache.data_on(dev)))


def test_stale_version_requests_raise():
    _, cache = _cache(capacity=20)
    cache.keep_versions = 1
    dev = jax.devices()[0]
    cache.data_on(dev)
    for _ in range(4):
        cache.lookup(np.repeat(np.arange(100, 140), 3))
    assert cache.refresh(max_swap=5) > 0
    with pytest.raises(RuntimeError, match="retired"):
        cache.data_on(dev, version=0)


def _small_ds():
    ds = make_dataset("ogbn-products", scale=0.002, seed=0)
    g = GNNConfig(model="sage", layer_dims=ds.layer_dims, fanouts=(4, 3),
                  num_classes=ds.num_classes)
    return ds, g


def _forced_refresh_trainer(ds, g, n_accel, force, iters=6):
    """Trainer whose transfer stage (once, mid-run, with TFP prefetch in
    flight) heats a cold id set and forces a cache refresh — i.e. the
    refresh lands between _stage_load and _stage_transfer of the batches
    already inside the pipeline."""
    hcfg = HybridConfig(total_batch=128, n_accel=n_accel,
                        hybrid=(n_accel == 0), use_drm=False, tfp_depth=2,
                        seed=0, use_accel_sampler=False, cache_fraction=0.2)
    tr = HybridGNNTrainer(ds, g, hcfg)
    if force:
        orig = tr._stage_transfer
        fired = []

        def transfer(item):
            if not fired and item.payload["iteration"] == 2:
                fired.append(True)
                # the trainer disabled tracking (cache_refresh off);
                # enable it just to stage a genuine swap
                tr.cache.track_hotness = True
                cold = np.flatnonzero(tr.cache.slot_of < 0)[:64]
                for _ in range(6):
                    tr.cache.lookup(np.repeat(cold, 4))
                assert tr.cache.refresh() > 0
                tr.loader.reset_window()
            return orig(item)

        tr._stage_transfer = transfer
    tr.train(iters)
    return tr


@pytest.mark.parametrize("n_accel", [2, 0])
def test_refresh_in_flight_losses_bit_identical(n_accel):
    """Forcing a refresh while prefetched batches are between load and
    transfer must not change a single loss bit (the versioned-lookup
    guarantee).  n_accel=0 covers the CPU-only path, where the cache
    exists but no transfer-path lookup ever consults it."""
    ds, g = _small_ds()
    base = _forced_refresh_trainer(ds, g, n_accel, force=False)
    forced = _forced_refresh_trainer(ds, g, n_accel, force=True)
    l0 = [m.loss for m in base.history]
    l1 = [m.loss for m in forced.history]
    assert np.array_equal(l0, l1)
    if n_accel > 0:
        assert forced.cache.version > 0      # the refresh really happened
    base.loader.close()
    forced.loader.close()


def test_trainer_dynamic_refresh_bit_identical_end_to_end():
    """cache_refresh=True with a zero drift threshold (refresh pressure
    every iteration) vs cache_refresh=False: bit-identical losses."""
    ds, g = _small_ds()

    def run(refresh):
        hcfg = HybridConfig(total_batch=128, n_accel=2, hybrid=False,
                            use_drm=False, tfp_depth=2, seed=0,
                            use_accel_sampler=False, cache_fraction=0.2,
                            cache_refresh=refresh,
                            cache_drift_threshold=0.0)
        tr = HybridGNNTrainer(ds, g, hcfg)
        tr.train(6)
        return tr

    off, on = run(False), run(True)
    assert np.array_equal([m.loss for m in off.history],
                          [m.loss for m in on.history])
    assert on.cache.version > 0
    assert off.cache.version == 0
    off.loader.close()
    on.loader.close()


# ------------------------------------------- epoch stats window / feedback


def test_epoch_stats_reset_on_refresh():
    """Regression: ``measured_hit_rate`` used to average over pre-refresh
    epochs.  After a refresh it must reflect only post-refresh lookups."""
    _, cache = _cache(capacity=40)
    rng = np.random.default_rng(5)
    # phase 1: ~all misses (cold tail), drags the lifetime average down
    for _ in range(5):
        cache.lookup(rng.integers(200, N, size=100).astype(np.int64))
    low = cache.measured_hit_rate()
    assert low < 0.2
    assert cache.refresh(max_swap=40) > 0
    assert cache.epoch_stats.total_rows == 0
    # phase 2: hit the freshly-admitted rows
    hot = cache.cached_ids[:20]
    for _ in range(3):
        cache.lookup(np.repeat(hot, 5))
    assert cache.measured_hit_rate() == 1.0         # windowed, not averaged
    assert cache.stats.hit_rate < 1.0               # lifetime still carries it


def test_loader_window_resets_and_feeds_feedback():
    """The mapping feedback must re-price on the post-refresh window rate,
    not the lifetime average (regression for the PR 2 drift loop)."""
    import dataclasses
    ds, g = _small_ds()
    hcfg = HybridConfig(total_batch=128, n_accel=2, hybrid=True,
                        use_drm=False, tfp_depth=0, seed=0,
                        use_accel_sampler=False, cache_fraction=0.2,
                        cache_refresh=False)
    tr = HybridGNNTrainer(ds, g, hcfg)
    # at this toy scale the model maps the whole batch onto the CPU and
    # the transfer path never runs: pin the shares (in share-quantum
    # units) so accel trainers generate cache-classified windowed traffic
    tr.runtime.assignment.cpu_batch = 0
    tr.runtime.assignment.accel_batch = 64
    tr.train(3)
    # enable the refresh hook only now, so the auto-trigger during train()
    # cannot have already consumed the window we assert on
    tr.cfg = dataclasses.replace(tr.cfg, cache_refresh=True)
    tr.cache.track_hotness = True
    assert tr.loader.window.total_rows > 0
    # heat a cold set so a refresh moves rows, then let the trainer's own
    # drift hook fire: the window must reset with the swap
    cold = np.flatnonzero(tr.cache.slot_of < 0)[:64]
    for _ in range(6):
        tr.cache.lookup(np.repeat(cold, 4))
    tr._model_hit_rate = 0.99                       # force the drift signal
    assert tr._maybe_refresh_cache()
    assert tr.loader.window.total_rows == 0
    assert tr.loader.stats.total_rows > 0           # lifetime is untouched
    # an empty window defers the mapping re-price to post-refresh traffic
    assert not tr._maybe_refresh_mapping()
    # post-refresh traffic re-prices the mapping on the *window* rate, not
    # the lifetime average: craft a window whose rate differs from both
    from repro.graph import LoadStats
    rb = tr.cache.row_bytes
    tr.loader.window.merge(LoadStats(
        rows=20, bytes=20 * rb, total_rows=100, unique_rows=80,
        hit_rows=70, saved_bytes=70 * rb, dedup_saved_bytes=10 * rb))
    assert tr.loader.window.hit_rate != tr.loader.stats.hit_rate
    tr._model_hit_rate = 0.2                        # far from 0.70
    assert tr._maybe_refresh_mapping()
    assert tr._model_hit_rate == tr.loader.window.hit_rate == 0.70
    tr.loader.close()


def test_refresh_reprices_mapping_before_window_reset():
    """Regression: under sustained drift the refresh resets the window
    every iteration, so the mapping re-price must happen *at refresh
    time* (on the drifted pre-refresh measurement) — deferring it to
    _maybe_refresh_mapping would starve it on an always-empty window."""
    from repro.graph import LoadStats
    ds, g = _small_ds()
    hcfg = HybridConfig(total_batch=128, n_accel=2, hybrid=True,
                        use_drm=False, tfp_depth=0, seed=0,
                        use_accel_sampler=False, cache_fraction=0.2,
                        cache_refresh=True)
    tr = HybridGNNTrainer(ds, g, hcfg)
    cold = np.flatnonzero(tr.cache.slot_of < 0)[:64]
    for _ in range(6):
        tr.cache.lookup(np.repeat(cold, 4))      # stage a genuine swap
    rb = tr.cache.row_bytes
    tr.loader.window.merge(LoadStats(
        rows=20, bytes=20 * rb, total_rows=100, unique_rows=80,
        hit_rows=70, saved_bytes=70 * rb, dedup_saved_bytes=10 * rb))
    tr._model_hit_rate = 0.2                     # force the drift signal
    assert tr._maybe_refresh_cache()
    assert tr.loader.window.total_rows == 0      # window reset by refresh
    assert tr._model_hit_rate == 0.70            # mapping already re-priced
    tr.loader.close()


def test_hotness_tracking_gated_on_refresh_knob():
    """Static-cache runs (the default) must not pay the hotness-counter
    cost: the trainer disables tracking and the full-length uncached
    estimate is never allocated."""
    ds, g = _small_ds()
    hcfg = HybridConfig(total_batch=128, n_accel=2, hybrid=False,
                        use_drm=False, tfp_depth=0, seed=0,
                        use_accel_sampler=False, cache_fraction=0.2,
                        cache_refresh=False)
    tr = HybridGNNTrainer(ds, g, hcfg)
    tr.train(2)
    assert not tr.cache.track_hotness
    assert tr.cache._node_hot is None
    assert tr.cache.refresh() == 0               # nothing tracked, no swaps
    tr.loader.close()


# --------------------------------------------- async (staged) refresh path


def test_async_refresh_trainer_bit_identical_and_commits():
    """async_refresh=True under constant drift pressure: the staged
    gather runs off the critical path, commits land at later iteration
    boundaries, and losses stay bit-identical to sync refresh AND to
    refresh off (the versioned-lookup guarantee)."""
    ds, g = _small_ds()

    def run(refresh, asynchronous):
        hcfg = HybridConfig(total_batch=128, n_accel=2, hybrid=False,
                            use_drm=False, tfp_depth=2, seed=0,
                            use_accel_sampler=False, cache_fraction=0.2,
                            cache_refresh=refresh,
                            cache_drift_threshold=0.0,
                            async_refresh=asynchronous)
        tr = HybridGNNTrainer(ds, g, hcfg)
        tr.train(8)
        tr.close()
        return tr

    off = run(False, False)
    sync = run(True, False)
    asy = run(True, True)
    l_off = [m.loss for m in off.history]
    assert np.array_equal(l_off, [m.loss for m in sync.history])
    assert np.array_equal(l_off, [m.loss for m in asy.history])
    # the async path genuinely staged + committed (version advanced), one
    # boundary later than the sync path at the earliest
    assert asy.cache.version > 0
    assert sync.cache.version >= asy.cache.version


def test_async_refresh_stage_error_surfaces_at_next_boundary():
    """A stage() gather that dies in the background thread (e.g. the
    disk tier lost a blob) must raise at the next iteration boundary —
    not vanish, not deadlock."""
    ds, g = _small_ds()
    hcfg = HybridConfig(total_batch=128, n_accel=2, hybrid=False,
                        use_drm=False, tfp_depth=0, seed=0,
                        use_accel_sampler=False, cache_fraction=0.2,
                        cache_refresh=True, cache_drift_threshold=0.0,
                        async_refresh=True, degrade_on_failure=False)
    tr = HybridGNNTrainer(ds, g, hcfg)
    tr.train(2)                           # generate windowed traffic
    # drain any stage the run itself left in flight
    if tr._refresh_thread is not None:
        tr._refresh_thread.join(10.0)
        tr._maybe_refresh_cache()         # commits (or discards) it
    assert tr._refresh_thread is None
    # heat genuine admission candidates, then break the storage tier
    cold = np.flatnonzero(tr.cache.slot_of < 0)[:64]
    for _ in range(6):
        tr.cache.lookup(np.repeat(cold, 4))

    def bad_take(rows):
        raise RuntimeError("spill blob gone")

    tr.cache.source = type("Broken", (), {
        "take": staticmethod(bad_take), "shape": tr.cache.source.shape,
        "dtype": np.float32})()
    from repro.graph import LoadStats
    rb = tr.cache.row_bytes
    tr.loader.window.merge(LoadStats(     # re-arm windowed traffic
        rows=20, bytes=20 * rb, total_rows=100, unique_rows=80,
        hit_rows=70, saved_bytes=70 * rb))
    tr._model_hit_rate = 0.99             # force the drift signal
    assert not tr._maybe_refresh_cache()  # kicks the failing stage thread
    assert tr._refresh_thread is not None
    tr._refresh_thread.join(10.0)
    with pytest.raises(RuntimeError, match="async cache-refresh"):
        tr._maybe_refresh_cache()
    # the error is consumed: the subsequent boundary starts clean
    assert tr._refresh_error is None and tr._refresh_thread is None
    tr.close()


def test_refresh_disabled_without_flag():
    ds, g = _small_ds()
    hcfg = HybridConfig(total_batch=128, n_accel=2, hybrid=False,
                        use_drm=False, tfp_depth=0, seed=0,
                        use_accel_sampler=False, cache_fraction=0.2,
                        cache_refresh=False, cache_drift_threshold=0.0)
    tr = HybridGNNTrainer(ds, g, hcfg)
    tr.train(3)
    assert not tr._maybe_refresh_cache()
    assert tr.cache.version == 0
    tr.loader.close()


# ----------------------------------- pinned-lookup eager version retirement


def _heat_and_refresh(cache, lo, hi, max_swap=40):
    for _ in range(5):
        cache.lookup(np.repeat(np.arange(lo, hi), 4))
    assert cache.refresh(max_swap=max_swap) > 0


def test_pinned_lookup_retires_eagerly_on_release():
    """A pinned lookup holds its classification version alive through any
    number of refreshes; the release retires every older full [K, F]
    block immediately instead of waiting out ``keep_versions``."""
    src, cache = _cache(capacity=40)
    cache.keep_versions = 10          # generous window: eager must win
    dev = jax.devices()[0]
    look = cache.lookup(np.arange(50, 120), pin=True)
    _heat_and_refresh(cache, 250, 280)
    _heat_and_refresh(cache, 200, 230)
    assert cache.version == 2
    assert cache.retained_versions() == [0, 1, 2]
    # the pinned version is still combinable mid-flight
    block = np.asarray(cache.data_on(dev, version=look.version))
    assert block.shape == (40, F)
    cache.release_lookup(look)
    # everything below the current version dropped at the release
    assert cache.retained_versions() == [2]
    with pytest.raises(RuntimeError, match="retired"):
        cache.data_on(dev, version=0)


def test_pin_floor_is_oldest_inflight_version():
    src, cache = _cache(capacity=40)
    cache.keep_versions = 10
    look0 = cache.lookup(np.arange(0, 60), pin=True)       # v0
    _heat_and_refresh(cache, 250, 280)
    look1 = cache.lookup(np.arange(60, 120), pin=True)     # v1
    _heat_and_refresh(cache, 200, 230)
    assert cache.retained_versions() == [0, 1, 2]
    cache.release_lookup(look0)
    # v1 is still pinned: only versions below it retire
    assert cache.retained_versions() == [1, 2]
    cache.release_lookup(look1)
    assert cache.retained_versions() == [2]


def test_release_unpinned_lookup_is_noop_and_window_unchanged():
    """Without the pin opt-in the keep_versions window is untouched —
    full back-compat for non-pinning callers."""
    src, cache = _cache(capacity=40)
    cache.keep_versions = 2
    look = cache.lookup(np.arange(50, 120))               # NOT pinned
    _heat_and_refresh(cache, 250, 280)
    cache.release_lookup(look)                            # no-op
    assert cache.retained_versions() == [0, 1]            # window intact
    _heat_and_refresh(cache, 200, 230)
    assert cache.retained_versions() == [1, 2]            # plain window


def test_leaked_pin_self_heals_at_the_keep_versions_bound():
    """A pin whose release was dropped (a crashed batch) must not pin
    device memory forever: commit() ages leaked registrations below the
    keep_versions low-water mark, so retirement re-arms."""
    src, cache = _cache(capacity=40)
    cache.keep_versions = 2
    leaked = cache.lookup(np.arange(50, 120), pin=True)   # never released
    _heat_and_refresh(cache, 250, 280, max_swap=10)
    # within the keep_versions grace window the leak holds its version
    assert cache.retained_versions() == [0, 1]
    _heat_and_refresh(cache, 200, 230, max_swap=10)
    # past the window commit() ages the leaked registration, and with no
    # pins left the eager floor collapses retention to the current block
    assert cache.retained_versions() == [2]
    # a fresh pin/release cycle still works after the self-heal
    look = cache.lookup(np.arange(0, 50), pin=True)
    _heat_and_refresh(cache, 150, 180, max_swap=10)
    assert cache.retained_versions() == [2, 3]   # pinned v2 held
    cache.release_lookup(look)
    assert cache.retained_versions() == [3]
    del leaked


def test_loader_pin_passthrough_and_trainer_drain(tmp_path):
    """load_compact(pin=True) registers in-flight; the hybrid trainer's
    assemble releases each pin, so after a run with refreshes the cache
    holds exactly the current version (keep_versions memory drained)."""
    ds, g = _small_ds()
    hcfg = HybridConfig(total_batch=128, n_accel=1, hybrid=True,
                        use_drm=False, tfp_depth=2, seed=0,
                        use_accel_sampler=False, cache_fraction=0.2,
                        cache_refresh=True, cache_drift_threshold=0.0,
                        async_refresh=False)
    tr = HybridGNNTrainer(ds, g, hcfg)
    tr.train(8)
    try:
        assert tr.cache.version > 0          # refreshes really happened
        assert tr.cache.retained_versions() == [tr.cache.version]
    finally:
        tr.close()


def test_measured_hit_rate_blocks_on_inflight_merge():
    """Regression (torn read): measured_hit_rate() must serialize against
    record_lookup's window merge.  A merge is gated open mid-flight; the
    reader must block until it completes rather than observe hit_rows
    without the matching totals."""
    import threading

    src, cache = _cache(capacity=40)
    in_merge, release = threading.Event(), threading.Event()
    stats_cls = type(cache.epoch_stats)

    class GatedStats(stats_cls):
        def merge(self, other):
            in_merge.set()
            release.wait(5.0)
            return super().merge(other)

    gated = GatedStats()
    gated.__dict__.update(cache.epoch_stats.__dict__)
    cache.epoch_stats = gated
    look = cache.lookup(np.arange(0, 40), record=False)
    writer = threading.Thread(target=cache.record_lookup, args=(look,))
    writer.start()
    assert in_merge.wait(5.0)
    got = []
    reader = threading.Thread(
        target=lambda: got.append(cache.measured_hit_rate()))
    reader.start()
    reader.join(0.3)
    assert reader.is_alive(), \
        "measured_hit_rate returned mid-merge: torn-read lock fix regressed"
    release.set()
    writer.join(5.0)
    reader.join(5.0)
    assert not reader.is_alive()
    assert 0.0 <= got[0] <= 1.0


# -------------------------------------------- undo-log version retention


def test_retention_is_undo_log_bounded():
    """Satellite bugfix: old versions are retained as O(swapped_rows)
    undo entries, not full [K, F] host blocks.  The retained footprint
    must be bounded by the total rows actually swapped and stay strictly
    below even ONE full block per retained old version."""
    src, cache = _cache(capacity=40)
    cache.keep_versions = 8
    total_swapped = 0
    for r in range(4):
        for _ in range(4):
            cache.lookup(np.repeat(np.arange(100 + 40 * r, 140 + 40 * r), 5))
        total_swapped += cache.refresh(max_swap=6)
    assert cache.version == 4 and total_swapped > 0
    row_undo = F * src.take(np.arange(1)).dtype.itemsize + np.dtype(
        np.int32).itemsize
    assert cache.retained_bytes() <= total_swapped * row_undo
    n_old = len(cache.retained_versions()) - 1
    full_blocks = n_old * cache.capacity * F * 4
    assert cache.retained_bytes() < full_blocks


def test_undo_log_reconstructs_multi_version_chain():
    """Every retained old version must rebuild exactly (walking the undo
    chain back from the current table), even several refreshes later and
    on a device that never placed that version."""
    src, cache = _cache(capacity=40)
    cache.keep_versions = 8
    dev = jax.devices()[0]
    tables = {0: cache.cached_ids.copy()}
    for r in range(3):
        for _ in range(4):
            cache.lookup(np.repeat(np.arange(120 + 30 * r, 160 + 30 * r), 5))
        assert cache.refresh(max_swap=8) > 0
        tables[cache.version] = cache.cached_ids.copy()
    for ver, ids in tables.items():
        block = np.asarray(cache.data_on(dev, version=ver))
        assert np.array_equal(block, cache._cast_rows(src.take(ids))), \
            f"version {ver} must rebuild bit-exactly from the undo log"
