"""Data-layer coverage: FeatureSource backend parity, the device-resident
hot-feature cache, the cache-combine kernel, and end-to-end loss
equivalence of cached vs uncached training."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import HybridConfig, HybridGNNTrainer
from repro.graph import (DenseFeatures, FeatureCache, FeatureLoader,
                         GNNConfig, HashedFeatures, NumpySampler,
                         PartitionedFeatures, as_feature_source, build_cache,
                         make_dataset)
from repro.kernels import ops, ref


def _rows(rng, n, size):
    # duplicates + arbitrary order on purpose
    return rng.integers(0, n, size=size).astype(np.int64)


# ------------------------------------------------------- backend parity


def test_feature_backends_byte_identical():
    n, f = 1000, 32
    hashed = HashedFeatures(n, f, seed=3)
    dense = DenseFeatures(hashed.take(np.arange(n)))
    part = PartitionedFeatures.from_source(hashed, partition_rows=96)
    assert part.num_partitions == -(-n // 96)
    rng = np.random.default_rng(0)
    for size in (1, 7, 500):
        rows = _rows(rng, n, size)
        a, b, c = hashed.take(rows), dense.take(rows), part.take(rows)
        assert a.tobytes() == b.tobytes() == c.tobytes()
        assert a.dtype == b.dtype == c.dtype


def test_make_dataset_backends_agree():
    for backend in ("dense", "hashed", "partitioned"):
        ds = make_dataset("ogbn-products", scale=0.001, seed=0,
                          feature_backend=backend, partition_rows=500)
        rows = np.arange(0, ds.num_nodes, 7)
        x = ds.take_features(rows)
        assert x.shape == (rows.shape[0], ds.feat_dim)
        if backend == "dense":
            ref_x = x
    ds_h = make_dataset("ogbn-products", scale=0.001, seed=0,
                        feature_backend="hashed")
    assert np.array_equal(ds_h.take_features(rows), ref_x)


def test_as_feature_source_rejects_garbage():
    with pytest.raises(TypeError):
        as_feature_source(42)


# ------------------------------------------------------------- the cache


def _toy_cache(n=200, f=8, capacity=50, seed=0):
    src = HashedFeatures(n, f, seed=seed)
    hotness = np.arange(n, 0, -1, dtype=np.float64)  # node 0 hottest
    return src, FeatureCache(src, hotness, capacity)


def test_cache_picks_hottest_and_lookup_partitions():
    src, cache = _toy_cache()
    # hotness is strictly decreasing, so the cache holds exactly [0, 50)
    assert np.array_equal(np.sort(cache.cached_ids), np.arange(50))
    ids = np.array([0, 49, 50, 199, 0, 150], dtype=np.int64)
    look = cache.lookup(ids)
    assert look.num_rows == 6 and look.num_hit == 3 and look.num_miss == 3
    # dedup path: miss block holds the *sorted unique* miss ids
    assert np.array_equal(look.miss_ids, [50, 150, 199])
    assert np.array_equal(look.unique_ids, [0, 49, 50, 150, 199])
    assert np.array_equal(look.unique_ids[look.inverse], ids)
    # slots point at the right cached rows
    hit = look.slots >= 0
    got = src.take(cache.cached_ids)[look.slots[hit]]
    assert np.array_equal(got, src.take(ids[hit]))
    # miss_index maps each miss position at its unique row
    assert np.array_equal(look.miss_ids[look.miss_index[~hit]], ids[~hit])
    # stats accounting (positional hits/misses; dup hit position 0 saved
    # twice by the cache, no duplicate misses here)
    assert cache.stats.hit_rows == 3 and cache.stats.miss_rows == 3
    assert cache.stats.saved_bytes == 3 * 8 * 4
    assert cache.stats.dedup_saved_bytes == 0
    assert cache.stats.unique_rows == 5
    assert cache.expected_hit_rate > 0.25  # top quarter of a linear ramp


def test_legacy_lookup_matches_pr1_layout():
    src, cache = _toy_cache()
    ids = np.array([0, 49, 50, 199, 0, 150], dtype=np.int64)
    look = cache.lookup(ids, dedup=False)
    # one miss row per miss *position*, in frontier order
    assert np.array_equal(look.miss_ids, [50, 199, 150])
    hit = look.slots >= 0
    assert np.array_equal(look.miss_index[~hit], [0, 1, 2])
    assert look.num_unique == look.num_rows
    assert look.dup_miss_rows == 0


def test_lookup_dedup_compacts_duplicate_misses():
    src, cache = _toy_cache()
    ids = np.array([60, 60, 60, 7, 60, 80, 80], dtype=np.int64)
    look = cache.lookup(ids)
    assert look.num_hit == 1                 # node 7 (positional)
    assert look.miss_positions == 6
    assert look.num_miss == 2                # unique misses {60, 80}
    assert look.dup_miss_rows == 4
    assert np.array_equal(look.miss_ids, [60, 80])
    # reconstruction: every position resolves to its own id's row
    hit = look.slots >= 0
    assert np.array_equal(look.miss_ids[look.miss_index[~hit]], ids[~hit])
    assert cache.stats.dedup_saved_bytes == 4 * 8 * 4


def test_cache_capacity_clamped_and_build_cache_off():
    src, cache = _toy_cache(capacity=10_000)
    assert cache.capacity == 200  # clamped to |V|
    ds = make_dataset("ogbn-products", scale=0.001, seed=0)
    assert build_cache(ds, 0.0) is None


# ----------------------------------------------------- assemble / kernel


@pytest.mark.parametrize("use_pallas", [False, True])
def test_assemble_features_reconstructs_rows(use_pallas):
    rng = np.random.default_rng(1)
    src, cache = _toy_cache(n=300, f=16, capacity=64)
    ids = _rows(rng, 300, 128)
    look = cache.lookup(ids)
    miss = jnp.asarray(src.take(look.miss_ids))
    out = ops.assemble_features(
        jnp.asarray(src.take(cache.cached_ids)), miss,
        jnp.asarray(look.slots), jnp.asarray(look.miss_index),
        use_pallas=use_pallas)
    assert np.array_equal(np.asarray(out), src.take(ids))


def test_assemble_all_hits_empty_miss_block():
    src, cache = _toy_cache(n=100, f=8, capacity=100)
    ids = np.arange(40, dtype=np.int64)
    look = cache.lookup(ids)
    assert look.num_miss == 0
    out = ops.assemble_features(
        jnp.asarray(src.take(cache.cached_ids)),
        jnp.zeros((0, 8), jnp.float32),
        jnp.asarray(look.slots), jnp.asarray(look.miss_index))
    assert np.array_equal(np.asarray(out), src.take(ids))


def test_ref_assemble_matches_kernel_fuzz():
    rng = np.random.default_rng(2)
    for _ in range(3):
        k, m, n, f = 31, 9, 57, 12
        cache = jnp.asarray(rng.normal(size=(k, f)), jnp.float32)
        miss = jnp.asarray(rng.normal(size=(m, f)), jnp.float32)
        slots = rng.integers(-1, k, size=n).astype(np.int32)
        mi = np.where(slots < 0, rng.integers(0, m, size=n), 0).astype(np.int32)
        a = ref.assemble_features(cache, miss, jnp.asarray(slots),
                                  jnp.asarray(mi))
        b = ops.assemble_features(cache, miss, jnp.asarray(slots),
                                  jnp.asarray(mi), use_pallas=True)
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------- loader + trainer


def test_loader_miss_only_gather_and_stats():
    ds = make_dataset("ogbn-products", scale=0.002, seed=0)
    cache = build_cache(ds, 0.2)
    loader = FeatureLoader(ds, cache=cache)
    sampler = NumpySampler(ds.graph, fanouts=(4, 3), seed=1)
    rng = np.random.default_rng(0)
    tgt = rng.integers(0, ds.num_nodes, 64)
    mb = sampler.sample(tgt, ds.labels[tgt])
    block = loader.load_misses(mb)
    frontier = np.asarray(mb.frontier(2))
    assert block.num_rows == frontier.shape[0]
    assert block.rows.shape[0] == block.lookup.num_miss < frontier.shape[0]
    # the miss block holds exactly the uncached frontier rows
    assert np.array_equal(block.rows, ds.take_features(block.lookup.miss_ids))
    s = loader.stats
    assert s.total_rows == frontier.shape[0]
    assert s.rows == block.lookup.num_miss
    assert s.bytes == block.rows.nbytes
    assert s.saved_bytes == block.lookup.num_hit * ds.feat_dim * 4
    assert 0.0 < s.hit_rate < 1.0


def test_cached_training_loss_equivalent_and_saves_bytes():
    """Same seed => identical losses with and without the cache, while the
    cache cuts shipped feature bytes (the tentpole acceptance check)."""
    ds = make_dataset("ogbn-products", scale=0.003, seed=0)
    g = GNNConfig(model="sage", layer_dims=(100, 64, 47), fanouts=(4, 3),
                  num_classes=47)

    def run(frac):
        cfg = HybridConfig(total_batch=128, n_accel=2, hybrid=False,
                           use_drm=False, tfp_depth=2, seed=0,
                           cache_fraction=frac)
        tr = HybridGNNTrainer(ds, g, cfg)
        tr.train(4)
        return tr

    base, cached = run(0.0), run(0.2)
    assert [m.loss for m in base.history] == [m.loss for m in cached.history]
    tf_base, tf_cached = base.feature_traffic(), cached.feature_traffic()
    # frac=0 still dedups (default): no cache savings, but dedup savings
    assert tf_base["saved_bytes"] == 0.0
    assert tf_base["dedup_saved_bytes"] > 0.0
    assert tf_base["reduction"] > 1.0 and tf_base["dup_factor"] > 1.0
    # cache on top of dedup: strictly less shipped than dedup alone
    assert tf_cached["reduction"] > tf_base["reduction"]
    assert tf_cached["saved_bytes"] > 0.0
    assert tf_cached["shipped_bytes"] < tf_base["shipped_bytes"]
    assert cached.history[-1].cache_hit_rate > 0.3


def test_cached_training_with_cpu_trainer_and_drm():
    """Hybrid mode: the CPU trainer reads the full frontier (dense path)
    while accelerators run miss-only; DRM keeps the batch conserved."""
    ds = make_dataset("ogbn-products", scale=0.003, seed=0)
    g = GNNConfig(model="sage", layer_dims=(100, 64, 47), fanouts=(4, 3),
                  num_classes=47)
    cfg = HybridConfig(total_batch=256, n_accel=2, hybrid=True, use_drm=True,
                       tfp_depth=2, share_quantum=32, seed=0,
                       cache_fraction=0.2)
    tr = HybridGNNTrainer(ds, g, cfg)
    hist = tr.train(6)
    assert all(np.isfinite(m.loss) for m in hist)
    for m in hist:
        cpu_b, accel_b = m.assignment
        assert cpu_b + accel_b * cfg.n_accel == cfg.total_batch
    assert tr.loader.stats.saved_bytes > 0
