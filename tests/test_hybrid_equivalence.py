"""The paper's central semantics claim (Section II-B): synchronous SGD over
multiple trainers with (possibly unequal) mini-batch shares is
algorithmically EQUIVALENT to single-device training with the combined
mini-batch.  We verify the gradient identity exactly:

    Σ_i (B_i / B) · grad_i  ==  grad(combined batch)

which holds because each trainer's loss is a mean over its share.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Synchronizer
from repro.graph import (GNNConfig, MiniBatch, NumpySampler, init_params,
                         loss_fn, make_dataset)


def _concat_minibatches(a: MiniBatch, b: MiniBatch) -> MiniBatch:
    """Blockwise union of two sampled mini-batches (per-hop concat).

    Valid because the regular layout is per-destination contiguous and
    frontiers of different trainers are independent.
    """
    assert a.fanouts == b.fanouts
    # hop arrays must interleave per frontier ordering: frontier(l) =
    # concat(frontier(l-1), hop_src l).  Concatenating two batches requires
    # re-interleaving: combined frontier(l) = [A_f(l-1), B_f(l-1),
    # A_src(l), B_src(l)] which does NOT match the layout unless we rebuild
    # hop arrays so that each hop's dst order is [A dsts..., B dsts...].
    # Our layout keys edges only by dst position within the hop, so
    # concatenating per-hop arrays IS the combined batch as long as
    # features are gathered with the same frontier() convention.
    return MiniBatch(
        targets=jnp.concatenate([a.targets, b.targets]),
        labels=jnp.concatenate([a.labels, b.labels]),
        hop_src=tuple(jnp.concatenate([x, y])
                      for x, y in zip(a.hop_src, b.hop_src)),
        hop_src_deg=tuple(jnp.concatenate([x, y])
                          for x, y in zip(a.hop_src_deg, b.hop_src_deg)),
        hop_dst_deg=tuple(jnp.concatenate([x, y])
                          for x, y in zip(a.hop_dst_deg, b.hop_dst_deg)),
        fanouts=a.fanouts,
    )


def test_weighted_gradient_equivalence():
    ds = make_dataset("ogbn-products", scale=0.002, seed=0)
    cfg = GNNConfig(model="sage", layer_dims=(100, 32, 47), fanouts=(3, 2))
    params = init_params(jax.random.PRNGKey(0), cfg)
    sampler = NumpySampler(ds.graph, cfg.fanouts, seed=1)

    t_a = np.arange(0, 24)          # trainer A: 24 rows
    t_b = np.arange(24, 32)         # trainer B: 8 rows (unequal shares)
    mb_a = sampler.sample(t_a, ds.labels[t_a])
    mb_b = sampler.sample(t_b, ds.labels[t_b])

    def grads_for(mb):
        x0 = jnp.asarray(ds.take_features(
            np.asarray(mb.frontier(len(cfg.fanouts)))))
        g, _ = jax.grad(loss_fn, has_aux=True)(params, cfg, mb, x0)
        return g

    g_a, g_b = grads_for(mb_a), grads_for(mb_b)
    w_a, w_b = 24 / 32, 8 / 32
    g_weighted = jax.tree.map(lambda x, y: w_a * x + w_b * y, g_a, g_b)

    # single-device equivalent: train on the union mini-batch.  The
    # combined hop layout keeps A's and B's dst blocks contiguous per hop,
    # but features must be gathered per sub-batch and stacked in the
    # combined frontier order.
    mb_u = _concat_minibatches(mb_a, mb_b)
    L = len(cfg.fanouts)
    # combined frontier(L) order per MiniBatch.frontier: [targetsA+B,
    # hop1A+B, hop2A+B]; build features accordingly
    x0_u = jnp.asarray(ds.take_features(np.asarray(mb_u.frontier(L))))

    # but forward() assumes frontier(l) == x[:n_l] self rows; in the
    # combined layout frontier(1) = [tA, tB, src1A, src1B] while hop-2 dst
    # blocks are ordered [frontier1A, frontier1B]... the per-hop regular
    # reshape requires dst order == frontier order, which now differs.
    # => equivalence must therefore be checked per-trainer-block: compute
    # the union loss as the weighted sum of block losses — which is
    # exactly what the Synchronizer computes.  The identity reduces to
    # linearity of grad over the weighted sum:
    def union_loss(p):
        x_a = jnp.asarray(ds.take_features(np.asarray(mb_a.frontier(L))))
        x_b = jnp.asarray(ds.take_features(np.asarray(mb_b.frontier(L))))
        la, _ = loss_fn(p, cfg, mb_a, x_a)
        lb, _ = loss_fn(p, cfg, mb_b, x_b)
        return w_a * la + w_b * lb   # == mean over the union of 32 rows

    g_union = jax.grad(union_loss)(params)
    for k in params:
        np.testing.assert_allclose(np.asarray(g_weighted[k]),
                                   np.asarray(g_union[k]),
                                   rtol=1e-5, atol=1e-6)


def test_synchronizer_weighted_average():
    sync = Synchronizer(3)
    g1 = {"w": jnp.ones(4)}
    g2 = {"w": 2 * jnp.ones(4)}
    g3 = {"w": 4 * jnp.ones(4)}
    sync.submit(0, g1, 1.0)
    sync.submit(1, g2, 1.0)
    sync.submit(2, g3, 2.0)
    avg = sync.all_reduce()
    np.testing.assert_allclose(np.asarray(avg["w"]),
                               (1 + 2 + 8) / 4 * np.ones(4))


def test_synchronizer_zero_weight_failed_trainer():
    """A failed trainer submits zero-weight grads; average unaffected."""
    sync = Synchronizer(2)
    sync.submit(0, {"w": jnp.ones(2)}, 32.0)
    sync.submit(1, {"w": jnp.full((2,), 99.0)}, 0.0)
    avg = sync.all_reduce()
    np.testing.assert_allclose(np.asarray(avg["w"]), np.ones(2))
