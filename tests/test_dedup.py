"""Frontier-deduplication coverage: unique/inverse round trips, the tiled
combine kernel, traffic-accounting invariants, dedup-vs-legacy loss bit
identity, the perf-model duplication factor, and the measured-hit-rate
feedback loop."""
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import HybridConfig, HybridGNNTrainer, WorkloadSpec
from repro.core.perfmodel import (PLATFORMS, initial_task_mapping, t_load,
                                  t_trans)
from repro.graph import (FeatureCache, FeatureLoader, GNNConfig,
                         HashedFeatures, NumpySampler, build_cache,
                         compact_lookup, make_dataset)
from repro.kernels import ops, ref
from repro.kernels.gather_scatter_mm import cache_combine_kernel_call


def _toy_cache(n=200, f=8, capacity=50, seed=0):
    src = HashedFeatures(n, f, seed=seed)
    hotness = np.arange(n, 0, -1, dtype=np.float64)  # node 0 hottest
    return src, FeatureCache(src, hotness, capacity)


# --------------------------------------------- unique / inverse round trip


@given(st.integers(1, 400), st.integers(2, 500), st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_compact_lookup_round_trip(size, universe, seed):
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, universe, size=size).astype(np.int64)
    look = compact_lookup(ids)
    # inverse map reconstructs the positional frontier exactly
    assert np.array_equal(look.unique_ids[look.inverse], ids)
    assert np.array_equal(look.unique_ids, np.unique(ids))
    # cache-less: every unique id is a miss, in sorted unique order
    assert np.array_equal(look.miss_ids, look.unique_ids)
    assert look.num_hit == 0
    assert np.array_equal(look.miss_ids[look.miss_index], ids)
    # counting identities behind the byte accounting
    assert look.num_rows == look.num_miss + look.dup_miss_rows
    assert look.dup_factor >= 1.0


@given(st.integers(1, 300), st.integers(1, 199), st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_cached_compact_lookup_round_trip(size, capacity, seed):
    rng = np.random.default_rng(seed)
    src, cache = _toy_cache(capacity=capacity, seed=1)
    ids = rng.integers(0, 200, size=size).astype(np.int64)
    look = cache.lookup(ids)
    hit = look.slots >= 0
    # every position resolves to its own id's feature row
    out = np.empty((size, 8), np.float32)
    out[hit] = src.take(cache.cached_ids)[look.slots[hit]]
    out[~hit] = src.take(look.miss_ids)[look.miss_index[~hit]]
    assert np.array_equal(out, src.take(ids))
    # hit/miss position counts + unique-miss compaction are consistent
    assert look.num_hit + look.miss_positions == look.num_rows
    assert look.num_miss == np.unique(ids[~hit]).shape[0] if (~hit).any() \
        else look.num_miss == 0


# ------------------------------------------------- tiled kernel parity


@pytest.mark.parametrize("k,m,n,f", [
    (31, 9, 57, 12),      # everything ragged
    (64, 1, 1, 100),      # single output row
    (1, 3, 8, 8),         # tiny cache
    (200, 7, 129, 257),   # odd feature dim, n just past a tile
    (128, 128, 512, 128), # fully tile-aligned
])
def test_tiled_combine_matches_ref_and_legacy_kernel(k, m, n, f):
    rng = np.random.default_rng(n * 7 + f)
    cache = jnp.asarray(rng.normal(size=(k, f)), jnp.float32)
    miss = jnp.asarray(rng.normal(size=(m, f)), jnp.float32)
    slots = rng.integers(-1, k, size=n).astype(np.int32)
    mi = np.where(slots < 0, rng.integers(0, m, size=n), 0).astype(np.int32)
    a = ref.assemble_features(cache, miss, jnp.asarray(slots),
                              jnp.asarray(mi))
    b = ops.assemble_features(cache, miss, jnp.asarray(slots),
                              jnp.asarray(mi), use_pallas=True)
    assert np.array_equal(np.asarray(a), np.asarray(b))
    # the retired one-row-per-grid-step kernel is the parity baseline
    sel = (slots < 0).astype(np.int32)
    row = np.where(slots < 0, mi, slots).astype(np.int32)
    c = cache_combine_kernel_call(cache, miss, jnp.asarray(sel),
                                  jnp.asarray(row), interpret=True)
    assert np.array_equal(np.asarray(a), np.asarray(c))


def test_tiled_combine_duplicated_rows_and_no_cache():
    """Many positions -> one shipped row (the dedup expansion contract)."""
    rng = np.random.default_rng(5)
    rows = jnp.asarray(rng.normal(size=(6, 40)), jnp.float32)
    inverse = rng.integers(0, 6, size=333).astype(np.int32)
    slots = np.full(333, -1, np.int32)
    out = ops.assemble_features(None, rows, jnp.asarray(slots),
                                jnp.asarray(inverse), use_pallas=True)
    assert np.array_equal(np.asarray(out),
                          np.asarray(ref.expand_rows(rows, inverse)))


def test_tiled_combine_bf16_bit_identical():
    rng = np.random.default_rng(9)
    cache = jnp.asarray(rng.normal(size=(33, 20)), jnp.bfloat16)
    miss = jnp.asarray(rng.normal(size=(5, 20)), jnp.bfloat16)
    slots = rng.integers(-1, 33, size=90).astype(np.int32)
    mi = np.where(slots < 0, rng.integers(0, 5, size=90), 0).astype(np.int32)
    a = ref.assemble_features(cache, miss, jnp.asarray(slots), jnp.asarray(mi))
    b = ops.assemble_features(cache, miss, jnp.asarray(slots), jnp.asarray(mi),
                              use_pallas=True)
    assert a.dtype == b.dtype == jnp.bfloat16
    assert np.array_equal(np.asarray(a), np.asarray(b))


# -------------------------------------------- traffic-stats invariants


def _loss_list(tr):
    return [m.loss for m in tr.history]


def _run_trainer(ds, g, *, dedup, frac, hybrid=False, iters=4, seed=0,
                 n_accel=2, total_batch=128, use_drm=False):
    cfg = HybridConfig(total_batch=total_batch, n_accel=n_accel,
                       hybrid=hybrid, use_drm=use_drm, tfp_depth=2,
                       seed=seed, cache_fraction=frac, dedup=dedup)
    tr = HybridGNNTrainer(ds, g, cfg)
    tr.train(iters)
    return tr


@pytest.fixture(scope="module")
def small_ds():
    ds = make_dataset("ogbn-products", scale=0.003, seed=0)
    g = GNNConfig(model="sage", layer_dims=(100, 64, 47), fanouts=(4, 3),
                  num_classes=47)
    return ds, g


@pytest.mark.parametrize("dedup,frac", [(True, 0.0), (True, 0.2),
                                        (False, 0.2)])
def test_traffic_accounting_sums_to_legacy_baseline(small_ds, dedup, frac):
    ds, g = small_ds
    tr = _run_trainer(ds, g, dedup=dedup, frac=frac)
    s = tr.loader.stats
    row_bytes = ds.feat_dim * 4
    # every transfer-path frontier position is accounted exactly once:
    # shipped (minus padding) + cache-saved + dedup-saved == positional
    # baseline
    assert (s.bytes - s.padding_bytes) + s.saved_bytes \
        + s.dedup_saved_bytes == s.total_rows * row_bytes
    # row-level identity matching the byte identity
    gathered_rows = (s.bytes - s.padding_bytes) // row_bytes
    assert gathered_rows + s.hit_rows \
        + (s.dedup_saved_bytes // row_bytes) == s.total_rows
    tf = tr.feature_traffic()
    assert tf["reduction"] >= 1.0
    if dedup:
        assert s.unique_rows < s.total_rows
        assert tf["dup_factor"] > 1.0
    else:
        assert s.dedup_saved_bytes == 0


def test_dedup_ships_fewer_bytes_than_legacy_smoke(small_ds):
    """tier1 smoke: deduped shipped bytes < legacy shipped bytes on the
    synthetic power-law graph, cache on or off."""
    ds, g = small_ds
    legacy = _run_trainer(ds, g, dedup=False, frac=0.0)
    dedup = _run_trainer(ds, g, dedup=True, frac=0.0)
    assert dedup.loader.stats.bytes < legacy.loader.stats.bytes
    legacy_c = _run_trainer(ds, g, dedup=False, frac=0.2)
    dedup_c = _run_trainer(ds, g, dedup=True, frac=0.2)
    assert dedup_c.loader.stats.bytes < legacy_c.loader.stats.bytes


# ------------------------------------------------ loss bit-identity


def test_dedup_loss_bit_identical_to_legacy(small_ds):
    """Dedup reshapes the transfer, never the math: losses must be
    bit-identical to the legacy positional path, cached and uncached."""
    ds, g = small_ds
    legacy_uncached = _run_trainer(ds, g, dedup=False, frac=0.0)
    dedup_uncached = _run_trainer(ds, g, dedup=True, frac=0.0)
    assert _loss_list(legacy_uncached) == _loss_list(dedup_uncached)
    legacy_cached = _run_trainer(ds, g, dedup=False, frac=0.2)
    dedup_cached = _run_trainer(ds, g, dedup=True, frac=0.2)
    assert _loss_list(legacy_cached) == _loss_list(dedup_cached)
    # and the cache itself is semantically invisible as before
    assert _loss_list(legacy_uncached) == _loss_list(dedup_cached)


def test_dedup_pallas_combine_loss_bit_identical(small_ds):
    """The tiled kernel path must reproduce the jnp combine bitwise."""
    ds, g = small_ds
    base = _run_trainer(ds, g, dedup=True, frac=0.2)
    cfg = HybridConfig(total_batch=128, n_accel=2, hybrid=False,
                       use_drm=False, tfp_depth=2, seed=0,
                       cache_fraction=0.2, dedup=True,
                       cache_assemble="pallas")
    tr = HybridGNNTrainer(ds, g, cfg)
    tr.train(4)
    assert _loss_list(base) == _loss_list(tr)


# ------------------------------------------------ loader / pool details


def test_persistent_gather_pool_reused(small_ds):
    ds, _ = small_ds
    loader = FeatureLoader(ds, num_threads=4)
    rows = np.arange(0, ds.num_nodes, 2, dtype=np.int64)
    a = loader._gather(rows)
    pool = loader._pool
    assert pool is not None
    b = loader._gather(rows)
    assert loader._pool is pool          # reused, not rebuilt per call
    assert np.array_equal(a, b)
    assert np.array_equal(a, ds.take_features(rows))
    loader.num_threads = 2               # DRM knob change -> new pool
    loader._gather(rows)
    assert loader._pool is not pool
    loader.close()
    assert loader._pool is None


def test_load_compact_without_cache(small_ds):
    ds, _ = small_ds
    loader = FeatureLoader(ds)
    sampler = NumpySampler(ds.graph, fanouts=(4, 3), seed=1)
    tgt = np.random.default_rng(0).integers(0, ds.num_nodes, 64)
    mb = sampler.sample(tgt, ds.labels[tgt])
    block = loader.load_compact(mb)
    frontier = np.asarray(mb.frontier(2))
    assert block.lookup.num_hit == 0
    assert block.rows.shape[0] == np.unique(frontier).shape[0]
    assert np.array_equal(
        block.rows[block.lookup.miss_index], ds.take_features(frontier))
    assert loader.stats.unique_rows == block.rows.shape[0]
    assert loader.stats.dedup_saved_bytes == \
        (frontier.shape[0] - block.rows.shape[0]) * ds.feat_dim * 4


# ------------------------------------- perf model: duplication factor


def test_perfmodel_dedup_factor_scales_eq7_eq8():
    host, accel = PLATFORMS["epyc-7763"], PLATFORMS["tpu-v5e"]
    w_full = WorkloadSpec(1024, (25, 10), (100, 256, 47))
    w_half = WorkloadSpec(1024, (25, 10), (100, 256, 47), dedup_factor=0.5)
    assert abs(t_load(w_half, host, 1) / t_load(w_full, host, 1) - 0.5) < 1e-9
    assert abs(t_trans(w_half, accel) / t_trans(w_full, accel) - 0.5) < 1e-9
    # composes multiplicatively with the cache term
    w_both = WorkloadSpec(1024, (25, 10), (100, 256, 47),
                          cache_hit_rate=0.5, dedup_factor=0.5)
    assert abs(t_trans(w_both, accel) / t_trans(w_full, accel) - 0.25) < 1e-9


def test_mapping_shifts_toward_accel_with_dedup():
    host, accel = PLATFORMS["epyc-7763"], PLATFORMS["rtx-a5000"]
    kw = dict(n_accel=1, total_batch=1024, fanouts=(25, 10),
              layer_dims=(100, 256, 47))
    base = initial_task_mapping(host, accel, **kw)
    deduped = initial_task_mapping(host, accel, dedup_factor=0.3, **kw)
    # cheaper transfer -> the accelerator can absorb at least as much work
    assert deduped["accel_each"] >= base["accel_each"]
    assert deduped["cpu"] + deduped["accel_each"] <= 1024


def test_trainer_probes_dup_factor(small_ds):
    ds, g = small_ds
    # the probe runs only when its consumer (the hybrid mapping) exists
    tr = _run_trainer(ds, g, dedup=True, frac=0.0, hybrid=True, iters=2)
    assert 0.0 < tr.measured_dedup_alpha < 1.0
    legacy = _run_trainer(ds, g, dedup=False, frac=0.0, hybrid=True, iters=2)
    assert legacy.measured_dedup_alpha == 1.0
    accel_only = _run_trainer(ds, g, dedup=True, frac=0.0, iters=2)
    assert accel_only.measured_dedup_alpha == 1.0


def test_probe_alpha_consults_cache(small_ds):
    """Design-time alpha must exclude cached positions from both the
    numerator and the denominator: hub ids are the most-cached AND the
    most-duplicated, so the old unique/total ratio double-counted the
    overlap the mapping's (1 - h) cache term already removed."""
    ds, g = small_ds
    uncached = _run_trainer(ds, g, dedup=True, frac=0.0, hybrid=True,
                            iters=1)
    cached = _run_trainer(ds, g, dedup=True, frac=0.3, hybrid=True, iters=1)
    # caching the hot hubs removes the most-duplicated ids from the miss
    # traffic, so the residual alpha is strictly larger (less duplicated)
    assert cached.measured_dedup_alpha > uncached.measured_dedup_alpha
    assert 0.0 < cached.measured_dedup_alpha <= 1.0


def test_init_and_refresh_alpha_agree_on_same_traffic(small_ds):
    """The init-time probe (compact_lookup against cache.slot_of) and the
    refresh-time loader-stats formula must compute the same alpha =
    unique-miss / positional-miss rows for the same measured traffic."""
    ds, g = small_ds
    cache = build_cache(ds, 0.2)
    loader = FeatureLoader(ds, cache=cache)
    sampler = NumpySampler(ds.graph, g.fanouts, seed=17)
    rng = np.random.default_rng(17)
    tgt = rng.integers(0, ds.num_nodes, 64)
    mb = sampler.sample(tgt, ds.labels[tgt])
    loader.load_compact(mb)
    # refresh-time definition (_maybe_refresh_mapping, from LoadStats)
    s = loader.stats
    miss_positions = s.total_rows - s.hit_rows
    refresh_alpha = (1.0 - (s.dedup_saved_bytes // cache.row_bytes)
                     / miss_positions)
    # init-time definition (_probe_dup_factor, from compact_lookup)
    frontier = np.asarray(mb.frontier(len(g.fanouts)))
    look = compact_lookup(frontier, cache.slot_of)
    probe_alpha = look.num_miss / look.miss_positions
    assert probe_alpha == pytest.approx(refresh_alpha)
    loader.close()


# ------------------------------------------- measured-hit-rate feedback


def test_hit_rate_feedback_refreshes_mapping(small_ds):
    ds, g = small_ds
    tr = _run_trainer(ds, g, dedup=True, frac=0.2, hybrid=True, iters=3,
                      total_batch=256)
    # force a drift far beyond the 5-point threshold and refresh
    tr._model_hit_rate = 0.99
    before = tr._model_hit_rate
    assert tr._maybe_refresh_mapping()
    assert tr._model_hit_rate == tr.loader.stats.hit_rate != before
    a = tr.runtime.assignment
    assert a.cpu_batch + a.accel_batch * a.n_accel == 256
    # within the threshold: no refresh
    assert not tr._maybe_refresh_mapping()


def test_hit_rate_feedback_noop_without_cache_or_hybrid(small_ds):
    ds, g = small_ds
    tr = _run_trainer(ds, g, dedup=True, frac=0.0, hybrid=True, iters=2)
    assert not tr._maybe_refresh_mapping()
    tr2 = _run_trainer(ds, g, dedup=True, frac=0.2, hybrid=False, iters=2)
    assert not tr2._maybe_refresh_mapping()


# ----------------------------------------------- accel device indexing


def test_accel_device_indexed_by_ordinal(small_ds):
    """accel0 must map to accel_devices[0] even when the CPU trainer is
    active (the enumeration index used to count the cpu entry)."""
    ds, g = small_ds
    cfg = HybridConfig(total_batch=256, n_accel=2, hybrid=True,
                       use_drm=False, tfp_depth=0, seed=0)
    tr = HybridGNNTrainer(ds, g, cfg)
    assert tr._accel_device("accel0") is tr.accel_devices[0]
    assert tr._accel_device("accel1") is tr.accel_devices[1]
