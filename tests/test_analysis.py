"""repro.analysis engine + rule tests.

Per rule: a minimal violating fixture (positive), a compliant twin
(negative), a suppressed twin (noqa), and the unused-suppression
meta-check.  Plus the walker property test (every node visited exactly
once) and the self-check that the analyzer is clean over ``src/`` at
head — the findings-as-errors gate tier-1 runs.
"""
import ast
from pathlib import Path

import pytest

from repro.analysis import (Engine, default_rules, guarded_by,
                            requires_lock, run_paths)

SRC = Path(__file__).resolve().parents[1] / "src"


def check(source, path="fixture.py"):
    """Run the full default rule set over one in-memory fixture."""
    eng = Engine(default_rules())
    raw = eng.check_file(path, source=source, raw=True)
    for rule in eng.rules:
        raw.extend(f for f in rule.finish() if f.path == path)
    return eng._apply_noqa(raw, eng._collect_noqa(source), path)


def rules_of(findings):
    return sorted(f.rule for f in findings)


# --------------------------------------------------------------------------
# RPR1xx lock discipline
# --------------------------------------------------------------------------

GUARDED_HEADER = """\
import threading
from repro.analysis.annotations import guarded_by, requires_lock

@guarded_by("_lock", "pending", "done")
class S:
    def __init__(self):
        self._lock = threading.Lock()
        self.pending = 0
        self.done = 0
"""


class TestLockDiscipline:
    def test_rpr101_read_outside_lock(self):
        src = GUARDED_HEADER + """
    def peek(self):
        return self.pending
"""
        assert rules_of(check(src)) == ["RPR101"]

    def test_rpr101_negative_read_under_lock(self):
        src = GUARDED_HEADER + """
    def peek(self):
        with self._lock:
            return self.pending
"""
        assert check(src) == []

    def test_rpr101_noqa_suppresses_and_is_used(self):
        src = GUARDED_HEADER + """
    def peek(self):
        return self.pending  # noqa: RPR101 - single writer, benign
"""
        assert check(src) == []

    def test_rpr000_unused_noqa_reported(self):
        src = GUARDED_HEADER + """
    def peek(self):
        with self._lock:
            return self.pending  # noqa: RPR101 - stale
"""
        out = check(src)
        assert rules_of(out) == ["RPR000"]
        assert "unused suppression" in out[0].message

    def test_rpr000_cannot_be_suppressed(self):
        src = GUARDED_HEADER + """
    def peek(self):
        with self._lock:
            return self.done  # noqa: RPR000
"""
        assert rules_of(check(src)) == ["RPR000"]

    def test_rpr104_write_outside_lock(self):
        src = GUARDED_HEADER + """
    def reset(self):
        self.pending = 0
"""
        assert rules_of(check(src)) == ["RPR104"]

    def test_rpr303_augassign_outside_lock(self):
        src = GUARDED_HEADER + """
    def bump(self):
        self.pending += 1
"""
        assert rules_of(check(src)) == ["RPR303"]

    def test_init_exempt(self):
        # the unlocked writes in __init__ above must not fire
        assert check(GUARDED_HEADER) == []

    def test_requires_lock_treats_body_as_locked(self):
        src = GUARDED_HEADER + """
    @requires_lock("_lock")
    def _drain_locked(self):
        self.pending = 0
        self.done += 1
"""
        assert check(src) == []

    def test_nested_def_and_lambda_start_unlocked(self):
        # a closure made under the lock may run on another thread later:
        # the lexical lock must NOT be inherited
        src = GUARDED_HEADER + """
    def spawn(self):
        with self._lock:
            def worker():
                self.pending += 1
            fn = lambda: self.done
            return worker, fn
"""
        assert rules_of(check(src)) == ["RPR101", "RPR303"]

    def test_undeclared_attribute_not_policed(self):
        src = GUARDED_HEADER + """
    def other(self):
        self.monitor = 1
        return self.monitor
"""
        assert check(src) == []

    def test_unannotated_class_not_policed(self):
        src = """
class Plain:
    def peek(self):
        return self.pending
"""
        assert check(src) == []

    def test_rpr102_lock_order_inversion_both_sites(self):
        src = """
import threading
from repro.analysis.annotations import guarded_by

@guarded_by("_a", "x")
@guarded_by("_b", "y")
class S:
    def one(self):
        with self._a:
            with self._b:
                pass

    def two(self):
        with self._b:
            with self._a:
                pass
"""
        out = check(src)
        assert rules_of(out) == ["RPR102", "RPR102"]
        assert {f.line for f in out} == {10, 15}

    def test_rpr102_negative_consistent_order(self):
        src = """
import threading
from repro.analysis.annotations import guarded_by

@guarded_by("_a", "x")
@guarded_by("_b", "y")
class S:
    def one(self):
        with self._a:
            with self._b:
                pass

    def two(self):
        with self._a:
            with self._b:
                pass
"""
        assert check(src) == []

    def test_rpr103_blocking_calls_under_lock(self):
        src = GUARDED_HEADER + """
    def bad(self, src, rows, t):
        with self._lock:
            block = src.take(rows)
            t.join()
            time.sleep(0.1)
            open("f")
        return block
"""
        assert rules_of(check(src)) == ["RPR103"] * 4

    def test_rpr103_cheap_receivers_exempt(self):
        src = GUARDED_HEADER + """
    def ok(self, rows):
        import os
        with self._lock:
            a = np.take(rows, rows)
            s = ", ".join(["x"])
            p = os.path.join("a", "b")
        return a, s, p
"""
        assert check(src) == []

    def test_rpr103_only_fires_while_held(self):
        src = GUARDED_HEADER + """
    def ok(self, src, rows):
        with self._lock:
            pending = self.pending
        return src.take(rows), pending
"""
        assert check(src) == []


# --------------------------------------------------------------------------
# RPR2xx Pallas kernel invariants
# --------------------------------------------------------------------------

PALLAS_HEADER = """\
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
import numpy as np
"""


class TestKernelInvariants:
    def test_rpr201_side_effects_in_kernel(self):
        src = PALLAS_HEADER + """
def scatter_kernel(x_ref, o_ref):
    print("dbg")
    np.random.rand(3)
    time.time()
"""
        # np.random.rand is both a kernel side effect (RPR201) and a
        # global-state draw (RPR301): both families fire independently
        assert rules_of(check(src)) == ["RPR201"] * 3 + ["RPR301"]

    def test_rpr201_global_in_kernel(self):
        src = PALLAS_HEADER + """
COUNT = 0

def scatter_kernel(x_ref, o_ref):
    global COUNT
    COUNT = 1
"""
        assert rules_of(check(src)) == ["RPR201"]

    def test_rpr201_negative_outside_kernel(self):
        # same calls in a non-kernel function of a pallas module: fine
        src = PALLAS_HEADER + """
def driver(x):
    print("ok")
    return x
"""
        assert check(src) == []

    def test_rpr201_negative_non_pallas_module(self):
        src = """
def scatter_kernel(x_ref, o_ref):
    print("not a pallas module, not a kernel")
"""
        assert check(src) == []

    def test_rpr203_start_without_wait(self):
        src = PALLAS_HEADER + """
def copy_kernel(x_ref, o_ref, sem):
    pltpu.make_async_copy(x_ref, o_ref, sem).start()
"""
        out = check(src)
        assert rules_of(out) == ["RPR203"]
        assert "'sem'" in out[0].message

    def test_rpr203_negative_matched_pair(self):
        src = PALLAS_HEADER + """
def copy_kernel(x_ref, o_ref, sem):
    pltpu.make_async_copy(x_ref, o_ref, sem).start()
    pltpu.make_async_copy(x_ref, o_ref, sem).wait()
"""
        assert check(src) == []

    def test_rpr203_helper_def_and_nested_when(self):
        # the repo idiom: a local helper returns the async copy, and the
        # start/wait sites sit inside nested pl.when closures
        src = PALLAS_HEADER + """
def gather_kernel(x_ref, o_ref, rd_sem, wr_sem):
    def block_read(slot, i):
        return pltpu.make_async_copy(x_ref, o_ref, rd_sem.at[slot])

    @pl.when(True)
    def _start():
        block_read(0, 0).start()
        pltpu.make_async_copy(o_ref, x_ref, wr_sem).start()

    @pl.when(True)
    def _wait():
        block_read(0, 0).wait()
"""
        out = check(src)
        assert rules_of(out) == ["RPR203"]
        assert "'wr_sem'" in out[0].message

    def test_rpr204_depth_param_without_scratch_check(self):
        src = PALLAS_HEADER + """
def run(x, depth=2):
    return pl.pallas_call(lambda r, o: None)(x)
"""
        assert rules_of(check(src)) == ["RPR204"]

    def test_rpr204_negative_with_scratch_check(self):
        src = PALLAS_HEADER + """
def run(x, depth=2):
    check_vmem_scratch(depth * 4, "run")
    return pl.pallas_call(lambda r, o: None)(x)
"""
        assert check(src) == []

    def test_rpr202_unmarked_caller_of_aliasing_wrapper(self):
        src = PALLAS_HEADER + """
def scatter(data, rows, slots):
    return pl.pallas_call(lambda r, o: None,
                          input_output_aliases={2: 0})(data, rows, slots)

def update(cache, rows, slots):
    return scatter(cache, rows, slots)
"""
        out = check(src)
        assert rules_of(out) == ["RPR202"]
        assert "'update'" in out[0].message

    def test_rpr202_negative_caller_calls_unique(self):
        src = PALLAS_HEADER + """
def scatter(data, rows, slots):
    return pl.pallas_call(lambda r, o: None,
                          input_output_aliases={2: 0})(data, rows, slots)

def update(cache, rows, slots):
    keep = np.unique(slots)
    return scatter(cache, rows, keep)
"""
        assert check(src) == []

    def test_rpr202_negative_docstring_contract_two_hops(self):
        src = PALLAS_HEADER + '''
def scatter(data, rows, slots):
    return pl.pallas_call(lambda r, o: None,
                          input_output_aliases={2: 0})(data, rows, slots)

def mid(cache, rows, slots):
    return scatter(cache, rows, slots)

def update_rows(cache, rows, slots):
    """Scatter rows; duplicate slots dedupe keep-last (last writer wins)."""
    return mid(cache, rows, slots)
'''
        assert check(src) == []


# --------------------------------------------------------------------------
# RPR3xx determinism & accounting
# --------------------------------------------------------------------------

class TestDeterminism:
    def test_rpr301_global_state_np_random(self):
        src = """
import numpy as np
np.random.seed(0)
x = np.random.randint(10)
"""
        assert rules_of(check(src)) == ["RPR301", "RPR301"]

    def test_rpr301_negative_seeded_generator(self):
        src = """
import numpy as np
rng = np.random.default_rng(7)
x = rng.integers(10)
g = np.random.Generator(np.random.PCG64(3))
"""
        assert check(src) == []

    def test_rpr302_bare_except_swallows(self):
        src = """
def f(job):
    try:
        job()
    except:
        pass
"""
        assert rules_of(check(src)) == ["RPR302"]

    def test_rpr302_base_exception_swallows(self):
        src = """
def f(job):
    try:
        job()
    except BaseException:
        pass
"""
        assert rules_of(check(src)) == ["RPR302"]

    def test_rpr302_negative_reraise(self):
        src = """
def f(job):
    try:
        job()
    except BaseException:
        raise
"""
        assert check(src) == []

    def test_rpr302_negative_records_bound_exception(self):
        src = """
def f(job, log):
    try:
        job()
    except BaseException as e:
        log.append(e)
"""
        assert check(src) == []

    def test_rpr302_negative_except_exception_ok(self):
        # except Exception cannot catch WorkerKilled: the sanctioned idiom
        src = """
def f(job):
    try:
        job()
    except Exception:
        pass
"""
        assert check(src) == []


# --------------------------------------------------------------------------
# Engine mechanics
# --------------------------------------------------------------------------

class TestEngine:
    def test_rpr999_syntax_error(self):
        out = check("def broken(:\n")
        assert rules_of(out) == ["RPR999"]

    def test_findings_sorted_and_rendered(self):
        src = GUARDED_HEADER + """
    def two(self):
        self.pending = 0
        return self.done
"""
        out = check(src)
        assert out == sorted(out)
        rendered = out[0].render()
        assert "fixture.py:" in rendered and "[fix:" in rendered

    def test_walker_visits_every_node_exactly_once(self):
        # property test over the real repo: the single-pass walk must
        # touch each AST node exactly once (visited_nodes == |ast.walk|,
        # and no node object is entered twice)
        files = sorted(SRC.rglob("*.py"))[:25]
        assert files, "no source files found"
        for path in files:
            source = path.read_text()
            expected = sum(1 for _ in ast.walk(ast.parse(source)))
            seen = set()
            # subscribe a counting rule to every node type in the file
            node_types = {type(n) for n in ast.walk(ast.parse(source))}

            from repro.analysis.engine import Rule

            class Counter(Rule):
                types = tuple(node_types)

                def __init__(self):
                    self.visits = 0

                def visit(self, node, ctx):
                    self.visits += 1
                    # CPython interns expr_context/operator leaves (one
                    # shared ast.Load() instance): identity-uniqueness
                    # only holds for positioned nodes
                    if hasattr(node, "lineno"):
                        assert id(node) not in seen, "node visited twice"
                        seen.add(id(node))

            counter = Counter()
            eng = Engine([counter])
            eng.check_file(str(path), source=source, raw=True)
            assert eng.visited_nodes == expected
            assert counter.visits == expected

    def test_report_only_restricts_output_not_analysis(self, tmp_path):
        # cross-file RPR202 context comes from file A; the finding lands
        # in file B; --changed (report_only={B}) must still surface it
        a = tmp_path / "wrapper.py"
        a.write_text(PALLAS_HEADER + """
def scatter(data, rows, slots):
    return pl.pallas_call(lambda r, o: None,
                          input_output_aliases={2: 0})(data, rows, slots)
""")
        b = tmp_path / "caller.py"
        b.write_text("""
def update(cache, rows, slots):
    return scatter(cache, rows, slots)
""")
        out = run_paths([str(a), str(b)], report_only={str(b)})
        assert rules_of(out) == ["RPR202"]
        assert out[0].path == str(b)
        # and restricting to an unrelated file reports nothing
        assert run_paths([str(a), str(b)], report_only={str(a)}) == []

    def test_self_check_src_is_clean(self):
        # findings-as-errors over the whole tree: tier-1 runs this via
        # scripts/lint.sh, and the suite enforces it directly too
        files = sorted(str(p) for p in SRC.rglob("*.py"))
        findings = run_paths(files)
        assert findings == [], "\n".join(f.render() for f in findings)


# --------------------------------------------------------------------------
# annotations runtime behaviour
# --------------------------------------------------------------------------

class TestAnnotations:
    def test_guarded_by_merges_per_lock(self):
        @guarded_by("_a", "x", "y")
        @guarded_by("_b", "z")
        @guarded_by("_a", "w")
        class C:
            pass

        assert C.__guarded_by__ == {"_a": ("w", "x", "y"), "_b": ("z",)}

    def test_guarded_by_zero_runtime_cost(self):
        class C:
            pass

        D = guarded_by("_l", "a")(C)
        assert D is C

    def test_requires_lock_metadata(self):
        @requires_lock("_l", "_m")
        def f():
            pass

        assert f.__requires_lock__ == ("_l", "_m")

    def test_validation(self):
        with pytest.raises(ValueError):
            guarded_by("")
        with pytest.raises(ValueError):
            guarded_by("_l", "")
        with pytest.raises(ValueError):
            requires_lock()
