"""MoE dispatch properties: gather-based routing == dense per-token
reference; capacity drops; router weight normalization."""
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.models.moe import init_moe_params, moe_ffn, router_assignment


def _dense_reference(x, params, top_k):
    """Per-token dense evaluation of the selected experts (no capacity)."""
    b, s, d = x.shape
    logits = jnp.einsum("bsd,de->bse", x, params["router"])
    w, experts = router_assignment(logits.reshape(b * s, -1), top_k)
    xf = x.reshape(b * s, d)
    out = jnp.zeros_like(xf)
    for i in range(b * s):
        acc = jnp.zeros((d,), x.dtype)
        for j in range(top_k):
            e = int(experts[i, j])
            h = (jax.nn.silu(xf[i] @ params["w1"][e])
                 * (xf[i] @ params["w3"][e]))
            acc = acc + w[i, j] * (h @ params["w2"][e])
        out = out.at[i].set(acc)
    return out.reshape(b, s, d)


def test_moe_matches_dense_reference_when_capacity_ample():
    key = jax.random.PRNGKey(0)
    params = init_moe_params(key, 16, 32, n_experts=4)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 6, 16))
    got, _ = moe_ffn(x, params, top_k=2, capacity_factor=8.0)
    want = _dense_reference(x, params, top_k=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


@given(st.integers(0, 30), st.integers(1, 2))
@settings(max_examples=10, deadline=None)
def test_moe_capacity_drop_reduces_norm(seed, top_k):
    """With tight capacity some tokens are dropped -> output norm cannot
    exceed the ample-capacity output norm."""
    key = jax.random.PRNGKey(seed)
    params = init_moe_params(key, 8, 16, n_experts=2)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, 16, 8))
    full, _ = moe_ffn(x, params, top_k=top_k, capacity_factor=16.0)
    tight, _ = moe_ffn(x, params, top_k=top_k, capacity_factor=0.25)
    # dropped tokens output exactly 0 -> fewer nonzero rows
    nz_full = int((jnp.abs(full[0]).sum(-1) > 1e-6).sum())
    nz_tight = int((jnp.abs(tight[0]).sum(-1) > 1e-6).sum())
    assert nz_tight <= nz_full


def test_router_weights_normalized():
    key = jax.random.PRNGKey(3)
    logits = jax.random.normal(key, (32, 8))
    w, experts = router_assignment(logits, top_k=2)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), np.ones(32),
                               rtol=1e-6)
    assert int(experts.max()) < 8 and int(experts.min()) >= 0
    # top-k experts are distinct per token
    assert bool((experts[:, 0] != experts[:, 1]).all())


def test_moe_grads_flow_to_all_param_groups():
    key = jax.random.PRNGKey(4)
    params = init_moe_params(key, 8, 16, n_experts=4)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, 8))

    def loss(p):
        y, aux = moe_ffn(x, p, top_k=2, capacity_factor=4.0)
        return (y ** 2).mean() + 0.01 * aux

    g = jax.grad(loss)(params)
    for name, leaf in g.items():
        assert float(jnp.abs(leaf).sum()) > 0, f"no grad into {name}"
