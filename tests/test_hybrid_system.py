"""End-to-end integration tests for the hybrid training system:
protocol + TFP + DRM + synchronizer driving real GNN training, plus
fault tolerance (trainer failure mid-run) and checkpointing."""
import jax
import numpy as np
import pytest

from repro.core import HybridConfig, HybridGNNTrainer
from repro.graph import GNNConfig, make_dataset


def _dataset():
    return make_dataset("ogbn-products", scale=0.003, seed=0)


def _gcfg(**kw):
    base = dict(model="sage", layer_dims=(100, 64, 47), fanouts=(4, 3),
                num_classes=47)
    base.update(kw)
    return GNNConfig(**base)


def test_full_system_trains(tmp_path):
    ds = _dataset()
    # learnable task: label = sign of the first input feature, so a few
    # SGD steps must reduce the loss (random labels would only test
    # memorization, too slow for a unit test)
    feats = ds.take_features(np.arange(ds.num_nodes))
    ds.labels = (feats[:, 0] > 0).astype(np.int32)
    hcfg = HybridConfig(total_batch=256, n_accel=2, hybrid=True,
                        use_drm=True, tfp_depth=2, lr=5e-3,
                        share_quantum=32, seed=0)
    tr = HybridGNNTrainer(ds, _gcfg(num_classes=2), hcfg)
    hist = tr.train(10)
    assert len(hist) == 10
    losses = [m.loss for m in hist]
    assert all(np.isfinite(losses))
    assert min(losses[5:]) < losses[0]
    assert tr.mean_mteps() > 0
    # the assignment always conserves the total batch
    for m in hist:
        cpu_b, accel_b = m.assignment
        assert cpu_b + accel_b * hcfg.n_accel == hcfg.total_batch


def test_ablation_modes_all_run():
    ds = _dataset()
    modes = dict(
        baseline=HybridConfig(total_batch=128, n_accel=2, hybrid=False,
                              use_drm=False, tfp_depth=0, seed=1),
        hybrid=HybridConfig(total_batch=128, n_accel=2, hybrid=True,
                            use_drm=False, tfp_depth=0, seed=1),
        drm=HybridConfig(total_batch=128, n_accel=2, hybrid=True,
                         use_drm=True, tfp_depth=0, seed=1),
        tfp=HybridConfig(total_batch=128, n_accel=2, hybrid=True,
                         use_drm=True, tfp_depth=2, seed=1),
    )
    for name, hcfg in modes.items():
        tr = HybridGNNTrainer(ds, _gcfg(), hcfg)
        hist = tr.train(4)
        assert len(hist) == 4, name
        assert all(np.isfinite(m.loss) for m in hist), name


def test_trainer_failure_is_survived():
    """Kill accel0 at iteration 2: the system drops it, rebalances, and
    keeps training (straggler/fault mitigation via the DRM machinery)."""
    ds = _dataset()
    hcfg = HybridConfig(total_batch=128, n_accel=2, hybrid=True,
                        use_drm=True, tfp_depth=0, share_quantum=16, seed=2)
    tr = HybridGNNTrainer(ds, _gcfg(), hcfg)
    tr.inject_failure("accel0", at_iteration=2)
    hist = tr.train(8)
    assert len(hist) == 8
    # iterations after the failure still make progress with finite loss
    assert all(np.isfinite(m.loss) for m in hist[3:])
    assert "accel0" in tr._failed
    # total work is still conserved across surviving trainers
    cpu_b, accel_b = hist[-1].assignment
    assert cpu_b + accel_b * tr.runtime.assignment.n_accel \
        == hcfg.total_batch


def test_checkpoint_callback_fires(tmp_path):
    ds = _dataset()
    hcfg = HybridConfig(total_batch=128, n_accel=1, tfp_depth=0,
                        ckpt_every=2, seed=3)
    tr = HybridGNNTrainer(ds, _gcfg(), hcfg)
    saved = []
    tr.set_checkpoint_callback(lambda step, p, o: saved.append(step))
    tr.train(5)
    assert saved == [1, 3]


def test_gradient_compression_modes():
    ds = _dataset()
    for method in ("bf16", "int8"):
        hcfg = HybridConfig(total_batch=64, n_accel=1, tfp_depth=0,
                            compression=method, seed=4)
        tr = HybridGNNTrainer(ds, _gcfg(), hcfg)
        hist = tr.train(3)
        assert all(np.isfinite(m.loss) for m in hist), method


def test_straggler_mitigation_shifts_share():
    """A persistently SLOW (not dead) trainer: the DRM engine must shift
    mini-batch share away from it — the paper's balance_work acting as
    continuous straggler mitigation.  Driven through the same Runtime
    path the trainer uses (deterministic synthetic stage times: the
    'accelerator' is 5x slower per row)."""
    from repro.core import StageTimes
    ds = _dataset()
    hcfg = HybridConfig(total_batch=256, n_accel=1, hybrid=True,
                        use_drm=True, tfp_depth=0, share_quantum=16,
                        drm_damping=0.5, seed=5)
    tr = HybridGNNTrainer(ds, _gcfg(), hcfg)
    a0 = tr.runtime.assignment.accel_batch
    for _ in range(12):
        a = tr.runtime.assignment
        times = StageTimes(t_sa=0.0, t_sc=0.01, t_load=0.01, t_tran=0.001,
                           t_tc=a.cpu_batch * 1.0,
                           t_ta=a.accel_batch * 5.0)
        tr.runtime.end_iteration(times)
    assert tr.runtime.assignment.accel_batch < a0, \
        "DRM failed to shift work away from the straggler"
    assert tr.runtime.assignment.total_batch == 256


def test_inflight_batch_survives_share_requantize():
    """With TFP prefetch in flight the DRM can re-quantize a share to 0
    after a batch was sampled; the batch still belongs to the trainers it
    was sampled for (regression: the stage consumers used to intersect
    with the *current* assignment, which could come up empty and crash
    the synchronizer)."""
    ds = _dataset()
    hcfg = HybridConfig(total_batch=256, n_accel=2, hybrid=True,
                        use_drm=False, tfp_depth=0, seed=0,
                        cache_fraction=0.2)
    tr = HybridGNNTrainer(ds, _gcfg(), hcfg)
    item = tr._make_payload(0)
    assert set(item.payload["minibatch"]) == set()  # built lazily by stages
    tr._stage_sample(item)
    tr._stage_load(item)
    tr._stage_transfer(item)
    sampled_for = set(item.payload["minibatch"])
    assert "accel0" in sampled_for
    # the DRM flips everything onto the CPU trainer mid-pipeline
    tr.runtime.assignment.accel_batch = 0
    tr.runtime.assignment.cpu_batch = hcfg.total_batch
    grads, ttimes, metrics = tr._run_trainers(item)
    assert np.isfinite(metrics["loss"])
    assert grads is not None
    tr.loader.close()
