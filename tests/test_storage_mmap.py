"""Out-of-core MmapFeatures backend: byte parity with the RAM backends
(duplicates, arbitrary order, empty requests, partition-boundary ids,
ragged last partition), bounded-RAM spill + reopen round trip, the
FeatureCache-over-mmap composition, the loader's partition-aligned
chunked gather, and end-to-end loss bit-identity vs the dense backend."""
import gc
import os

import hypothesis.strategies as st
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import HybridConfig, HybridGNNTrainer
from repro.graph import (DenseFeatures, FeatureCache, FeatureLoader,
                         GNNConfig, HashedFeatures, MmapFeatures,
                         NumpySampler, make_dataset)

N, F, PROWS = 1000, 32, 96  # deliberately ragged: 1000 % 96 != 0

# module-level singleton (not a fixture: the hypothesis property test
# below cannot take fixture arguments under the deterministic shim).
# The spill lives in an owned temp dir, removed at GC/interpreter exit.
_CACHED = None


def _sources():
    global _CACHED
    if _CACHED is None:
        hashed = HashedFeatures(N, F, seed=3)
        dense = DenseFeatures(hashed.take(np.arange(N)))
        mm = MmapFeatures.spill(hashed, partition_rows=PROWS)
        _CACHED = (dense, mm)
    return _CACHED


@pytest.fixture(scope="module")
def sources():
    return _sources()


# ------------------------------------------------------------ byte parity


@given(st.lists(st.integers(0, N - 1), min_size=0, max_size=400))
@settings(max_examples=30, deadline=None)
def test_mmap_parity_property(rows):
    dense, mm = _sources()
    rows = np.asarray(rows, dtype=np.int64)
    a, b = dense.take(rows), mm.take(rows)
    assert a.tobytes() == b.tobytes()
    assert a.dtype == b.dtype and a.shape == b.shape


def test_mmap_parity_partition_boundaries(sources):
    dense, mm = sources
    # first/last row of each window, the ragged tail, dups, reverse order
    edges = []
    for pid in range(mm.num_partitions):
        lo = pid * PROWS
        edges += [lo, min(lo + PROWS, N) - 1]
    rows = np.array(edges + [N - 1, N - 1, 0] + edges[::-1], dtype=np.int64)
    assert dense.take(rows).tobytes() == mm.take(rows).tobytes()


def test_mmap_empty_request(sources):
    dense, mm = sources
    rows = np.empty(0, dtype=np.int64)
    out = mm.take(rows)
    assert out.shape == (0, F) and out.dtype == dense.dtype


def test_mmap_out_of_range_raises(sources):
    _, mm = sources
    with pytest.raises(IndexError):
        mm.take(np.array([N], dtype=np.int64))
    with pytest.raises(IndexError):
        mm.take(np.array([-1], dtype=np.int64))


# ------------------------------------------- spill writer + reopen


def test_spill_bounded_ram_and_layout(sources):
    _, mm = sources
    # the bounded-RAM guarantee: never more than one partition buffered
    assert 0 < mm.spill_peak_buffered_rows <= PROWS
    assert mm.num_partitions == -(-N // PROWS)
    assert mm.shape == (N, F)
    assert mm.nbytes_on_disk == N * F * 4
    # ragged last partition holds exactly the leftover rows
    last = mm._part(mm.num_partitions - 1)
    assert last.shape[0] == N - (mm.num_partitions - 1) * PROWS


def test_spill_reopen_round_trip(sources):
    dense, mm = sources
    reopened = MmapFeatures(mm.spill_dir)
    assert reopened.shape == mm.shape
    assert reopened.dtype == mm.dtype
    assert reopened.partition_rows == mm.partition_rows
    rows = np.arange(0, N, 3, dtype=np.int64)
    assert reopened.take(rows).tobytes() == dense.take(rows).tobytes()


def test_lazy_windows_and_touch_accounting(sources):
    _, mm = sources
    fresh = MmapFeatures(mm.spill_dir)
    assert fresh.resident_window_bytes == 0          # nothing mapped yet
    fresh.take(np.arange(8, dtype=np.int64))         # touches window 0 only
    assert fresh.resident_window_bytes == PROWS * F * 4
    assert 0 < fresh.last_gather_page_bytes <= PROWS * F * 4 + 4096
    assert fresh.touched_page_bytes >= fresh.last_gather_page_bytes
    fresh.reset_touch_stats()
    assert fresh.touched_page_bytes == 0
    fresh.close()
    assert fresh.resident_window_bytes == 0


def test_madvise_random_on_window_open(sources):
    """Every lazily-opened partition window gets the MADV_RANDOM readahead
    hint (where the platform supports it), and the hint changes nothing
    about gather results — madvise is advisory, byte parity must hold."""
    import mmap as mmap_mod
    dense, mm = sources
    fresh = MmapFeatures(mm.spill_dir)
    assert fresh.madvise_calls == 0                  # nothing mapped yet
    rows = np.arange(0, N, 7, dtype=np.int64)        # touches every window
    assert fresh.take(rows).tobytes() == dense.take(rows).tobytes()
    if hasattr(mmap_mod, "MADV_RANDOM"):             # guarded platforms
        assert fresh.madvise_calls == len(fresh._parts) > 0
        # reuse of an already-open window does not re-hint
        before = fresh.madvise_calls
        fresh.take(rows[:5])
        assert fresh.madvise_calls == before
    fresh.close()


# ----------------------------------------------- window LRU + prefetch

_LRU = None


def _lru_sources():
    global _LRU
    if _LRU is None:
        hashed = HashedFeatures(N, F, seed=3)
        dense = DenseFeatures(hashed.take(np.arange(N)))
        mm = MmapFeatures.spill(hashed, partition_rows=PROWS)
        _LRU = (dense, mm)
    return _LRU


def _window_nbytes(mm, pid):
    rows = min(mm.partition_rows, mm.shape[0] - pid * mm.partition_rows)
    return rows * mm.shape[1] * mm.dtype.itemsize


@given(st.integers(1, 5),
       st.lists(st.integers(0, -(-N // PROWS) - 1), min_size=1,
                max_size=60))
@settings(max_examples=30, deadline=None)
def test_window_lru_bound_order_and_accounting(k, pids):
    """Window-LRU properties against an exact model: the open-window
    count never exceeds ``lru_windows``, eviction order is LRU (the model
    is an ordered dict with move-to-front-on-access), and
    ``evicted_window_bytes`` accounting is exact (ragged last window
    included)."""
    dense, base = _lru_sources()
    mm = MmapFeatures(base.spill_dir, lru_windows=k)
    model: dict = {}            # insertion order == recency
    expect_evicted = expect_count = 0
    for pid in pids:
        mm.take(np.array([pid * PROWS], dtype=np.int64))
        model.pop(pid, None)
        model[pid] = True
        while len(model) > k:
            old = next(iter(model))
            del model[old]
            expect_evicted += _window_nbytes(mm, old)
            expect_count += 1
        assert mm.open_windows == len(model) <= k
        assert list(mm._parts) == list(model)       # exact LRU order
    assert mm.evicted_window_bytes == expect_evicted
    assert mm.window_evictions == expect_count
    # re-opened (previously evicted) windows reproduce gathers bit-for-bit
    rows = np.arange(0, N, 3, dtype=np.int64)
    assert mm.take(rows).tobytes() == dense.take(rows).tobytes()
    assert mm.open_windows <= max(k, 1)
    mm.close()


def test_window_lru_eviction_issues_dontneed(sources):
    import mmap as mmap_mod
    _, base = sources
    mm = MmapFeatures(base.spill_dir, lru_windows=1)
    for pid in range(3):
        mm.take(np.array([pid * PROWS], dtype=np.int64))
    assert mm.window_evictions == 2
    if hasattr(mmap_mod, "MADV_DONTNEED"):
        assert mm.madvise_dontneed_calls == 2
    mm.close()


def test_window_lru_tightened_after_open_trims_on_access(sources):
    """Setting ``lru_windows`` after windows are already mapped (the
    trainer wires the bound before the cache boot gather, but users can
    tighten it any time) takes effect on the next access."""
    _, base = sources
    mm = MmapFeatures(base.spill_dir)
    rows = np.arange(0, N, 7, dtype=np.int64)          # touches every window
    mm.take(rows)
    assert mm.open_windows == mm.num_partitions
    mm.lru_windows = 2
    mm.take(np.array([0], dtype=np.int64))
    assert mm.open_windows <= 2
    mm.close()


def test_window_lru_zero_is_unbounded_legacy(sources):
    _, base = sources
    mm = MmapFeatures(base.spill_dir)                  # lru_windows=0
    mm.take(np.arange(0, N, 7, dtype=np.int64))
    assert mm.window_evictions == 0
    assert mm.evicted_window_bytes == 0
    assert mm.open_windows == mm.num_partitions
    mm.close()


def test_prefetch_rows_warms_pages_and_counters(sources):
    dense, base = sources
    mm = MmapFeatures(base.spill_dir, lru_windows=4)
    rng = np.random.default_rng(11)
    rows = np.unique(rng.integers(0, 2 * PROWS, 120)).astype(np.int64)
    new = mm.prefetch_rows(rows)
    assert new > 0 and mm.prefetched_window_bytes == new
    cold0 = mm.cold_fault_page_bytes
    out = mm.take(rows)
    assert out.tobytes() == dense.take(rows).tobytes()
    assert mm.cold_fault_page_bytes == cold0           # fully pre-faulted
    assert mm.prefetch_hit_rate == 1.0
    # an unprefetched window is a cold fault + prefetch miss
    mm.take(np.array([3 * PROWS], dtype=np.int64))
    assert mm.cold_fault_page_bytes > cold0
    assert mm.prefetch_miss_windows == 1
    # re-prefetching already-resident pages faults nothing new
    assert mm.prefetch_rows(rows) == 0
    mm.reset_prefetch_stats()
    assert mm.prefetched_window_bytes == 0
    assert mm.prefetch_hit_rate == 0.0
    mm.close()


def test_prefetch_rows_out_of_range_raises(sources):
    _, base = sources
    mm = MmapFeatures(base.spill_dir)
    with pytest.raises(IndexError):
        mm.prefetch_rows(np.array([N], dtype=np.int64))
    mm.close()


def test_eviction_makes_pages_cold_again(sources):
    """An evicted window's pages were dropped: the next gather of the
    same rows must account them cold again (and still be bit-correct)."""
    dense, base = sources
    mm = MmapFeatures(base.spill_dir, lru_windows=1)
    rows = np.arange(8, dtype=np.int64)                 # window 0
    mm.take(rows)
    cold1 = mm.cold_fault_page_bytes
    mm.take(rows)                                       # warm: no new cold
    assert mm.cold_fault_page_bytes == cold1
    mm.take(np.array([PROWS], dtype=np.int64))          # evicts window 0
    out = mm.take(rows)                                 # re-fault: cold again
    assert mm.cold_fault_page_bytes > cold1
    assert out.tobytes() == dense.take(rows).tobytes()
    mm.close()


def test_prefetch_pinned_window_survives_lru_pressure(sources):
    """Regression at LRU bound == prefetched-working-set size: windows a
    prefetch pre-faulted are pinned until their first post-prefetch
    gather, so unrelated accesses squeezing the LRU cannot throw the
    prefetch work away right before its consumer arrives.  The LRU runs
    transiently over-bound instead (counted), and re-trims once the
    gather releases the pins."""
    dense, base = sources
    mm = MmapFeatures(base.spill_dir, lru_windows=2)
    rng = np.random.default_rng(5)
    # the prefetched working set spans exactly lru_windows windows {0, 1}
    rows = np.unique(rng.integers(0, 2 * PROWS, 100)).astype(np.int64)
    mm.prefetch_rows(rows)
    # unrelated accesses push past the bound: the unpinned newcomer is
    # the only legal victim, the pinned prefetched windows must survive
    mm.take(np.array([2 * PROWS], dtype=np.int64))
    mm.take(np.array([3 * PROWS], dtype=np.int64))
    assert 0 in mm._parts and 1 in mm._parts
    assert mm.pin_blocked_evictions >= 1
    assert mm.open_windows == 3                  # transiently over-bound
    # the consumer's gather: zero cold faults (the pinned pages survived),
    # bit-identical bytes, and the pins release
    cold0 = mm.cold_fault_page_bytes
    out = mm.take(rows)
    assert out.tobytes() == dense.take(rows).tobytes()
    assert mm.cold_fault_page_bytes == cold0
    assert mm.prefetch_hit_windows >= 2
    assert not mm._pinned
    # with the pins gone the next access re-trims under the bound
    mm.take(np.array([4 * PROWS], dtype=np.int64))
    assert mm.open_windows <= 2
    mm.close()


def test_unpinned_eviction_order_unchanged(sources):
    """Without a prefetch in flight the pin set is empty: eviction stays
    plain LRU and the bound holds exactly (the pre-pinning contract)."""
    _, base = sources
    mm = MmapFeatures(base.spill_dir, lru_windows=2)
    for pid in range(4):
        mm.take(np.array([pid * PROWS], dtype=np.int64))
        assert mm.open_windows <= 2
    assert mm.window_evictions == 2
    assert mm.pin_blocked_evictions == 0
    mm.close()


def test_owned_tempdir_spill_cleans_up_on_gc():
    mm = MmapFeatures.spill(HashedFeatures(64, 4, seed=0), partition_rows=16)
    spill = mm.spill_dir
    assert os.path.exists(os.path.join(spill, "manifest.json"))
    del mm
    gc.collect()
    assert not os.path.exists(spill)


def test_reopen_rejects_non_spill_dir(tmp_path):
    with pytest.raises(FileNotFoundError):
        MmapFeatures(str(tmp_path))


# --------------------------------------------------- composition layers


def test_feature_cache_over_mmap(sources):
    dense, mm = sources
    hotness = np.arange(N, 0, -1, dtype=np.float64)  # node 0 hottest
    cache = FeatureCache(mm, hotness, capacity=64)
    assert np.array_equal(np.sort(cache.cached_ids), np.arange(64))
    ids = np.array([0, 63, 64, N - 1, 0, 500], dtype=np.int64)
    look = cache.lookup(ids)
    hit = look.slots >= 0
    got = np.empty((ids.shape[0], F), np.float32)
    got[hit] = cache._host_rows[look.slots[hit]]
    got[~hit] = mm.take(look.miss_ids)[look.miss_index[~hit]]
    assert np.array_equal(got, dense.take(ids))


def test_make_dataset_mmap_matches_dense(tmp_path):
    kw = dict(scale=0.001, seed=0, partition_rows=512)
    ds_m = make_dataset("ogbn-products", feature_backend="mmap",
                        spill_dir=str(tmp_path / "spill"), **kw)
    ds_d = make_dataset("ogbn-products", feature_backend="dense",
                        scale=0.001, seed=0)
    assert isinstance(ds_m.features, MmapFeatures)
    rows = np.arange(0, ds_m.num_nodes, 7, dtype=np.int64)
    assert np.array_equal(ds_m.take_features(rows), ds_d.take_features(rows))


def test_loader_partition_aligned_chunks_disjoint(tmp_path):
    ds = make_dataset("ogbn-products", scale=0.002, seed=0,
                      feature_backend="mmap",
                      spill_dir=str(tmp_path / "spill"), partition_rows=256)
    loader = FeatureLoader(ds, num_threads=4)
    rng = np.random.default_rng(1)
    rows = rng.integers(0, ds.num_nodes, 4000).astype(np.int64)
    # chunks cut at partition boundaries -> threads fault disjoint windows
    chunks, order = loader._split_chunks(rows)
    assert order is not None
    touched = [set(np.unique(c // 256).tolist()) for c in chunks]
    for i in range(len(touched)):
        for j in range(i + 1, len(touched)):
            assert not (touched[i] & touched[j]), "windows overlap"
    assert sum(c.shape[0] for c in chunks) == rows.shape[0]
    # and the gather stays byte-identical to the single-thread path
    assert np.array_equal(loader._gather(rows), ds.take_features(rows))
    loader.close()


def test_loader_unpartitioned_split_unchanged():
    ds = make_dataset("ogbn-products", scale=0.001, seed=0,
                      feature_backend="dense")
    loader = FeatureLoader(ds, num_threads=3)
    rows = np.arange(300, dtype=np.int64)[::-1].copy()
    chunks, order = loader._split_chunks(rows)
    assert order is None          # legacy order-preserving array_split
    assert np.array_equal(np.concatenate(chunks), rows)
    assert np.array_equal(loader._gather(rows), ds.take_features(rows))
    loader.close()


# ------------------------------------------------ end-to-end bit identity


def test_mmap_training_loss_bit_identical(tmp_path):
    """The acceptance check at test scale: training over the mmap backend
    is bit-identical to the dense backend at the same seed (the backend is
    purely a capacity knob), and the trainer prices the disk tier."""
    g = GNNConfig(model="sage", layer_dims=(100, 64, 47), fanouts=(4, 3),
                  num_classes=47)

    def run(backend, **kw):
        ds = make_dataset("ogbn-products", scale=0.003, seed=0,
                          feature_backend=backend, **kw)
        cfg = HybridConfig(total_batch=128, n_accel=2, hybrid=False,
                           use_drm=False, tfp_depth=2, seed=0,
                           cache_fraction=0.2)
        tr = HybridGNNTrainer(ds, g, cfg)
        tr.train(4)
        return tr

    dense = run("dense")
    mmap = run("mmap", spill_dir=str(tmp_path / "spill"),
               partition_rows=1024)
    assert [m.loss for m in dense.history] == [m.loss for m in mmap.history]
    assert mmap.feature_tier == "disk" and dense.feature_tier == "ram"
    # the mmap run's gather working set stayed a strict subset of the
    # matrix: only touched pages are resident
    src = mmap.dataset.features
    assert 0 < src.touched_page_bytes
    mmap.loader.close()
    dense.loader.close()


def test_hybrid_mapping_prices_disk_tier(tmp_path):
    """feature_tier plumbing: a hybrid trainer over mmap features prices
    Eq. 7 at storage bandwidth; shares stay conserved."""
    g = GNNConfig(model="sage", layer_dims=(100, 64, 47), fanouts=(4, 3),
                  num_classes=47)
    ds = make_dataset("ogbn-products", scale=0.003, seed=0,
                      feature_backend="mmap",
                      spill_dir=str(tmp_path / "spill"), partition_rows=1024)
    cfg = HybridConfig(total_batch=256, n_accel=2, hybrid=True,
                       use_drm=True, tfp_depth=2, share_quantum=32, seed=0,
                       cache_fraction=0.2)
    tr = HybridGNNTrainer(ds, g, cfg)
    assert tr.feature_tier == "disk"
    hist = tr.train(4)
    assert all(np.isfinite(m.loss) for m in hist)
    for m in hist:
        cpu_b, accel_b = m.assignment
        assert cpu_b + accel_b * cfg.n_accel == cfg.total_batch
    tr.loader.close()
