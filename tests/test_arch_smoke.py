"""Per-architecture smoke tests: instantiate the REDUCED config of each
assigned arch family and run one forward/train step (and one decode step)
on CPU, asserting output shapes and the absence of NaNs.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct,
no allocation) — see launch/dryrun.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.models import (init_decode_cache, init_params, loss_fn,
                          make_serve_step, make_train_step)
from repro.optim import adamw

B, S = 2, 32


def _batch(cfg, key):
    if cfg.frontend == "audio_stub":
        emb = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
        labels = jax.random.randint(key, (B, S), 0, cfg.vocab)
        return {"embeds": emb.astype(cfg.jdtype), "labels": labels}
    if cfg.frontend == "vision_stub":
        nv = cfg.vision_tokens
        toks = jax.random.randint(key, (B, S - nv), 0, cfg.vocab)
        vis = jax.random.normal(key, (B, nv, cfg.d_model), jnp.float32)
        return {"tokens": toks, "vision_embeds": vis.astype(cfg.jdtype),
                "labels": toks}
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    return {"tokens": toks, "labels": toks}


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_step_smoke(arch):
    cfg = get_arch(arch, reduced=True)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    opt = adamw(1e-3)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))
    batch = _batch(cfg, key)
    params2, opt_state2, metrics = step(params, opt_state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch}: non-finite loss {loss}"
    assert loss > 0
    # params actually changed and stayed finite
    for p_old, p_new in zip(jax.tree.leaves(params), jax.tree.leaves(params2)):
        assert p_new.shape == p_old.shape
        assert bool(jnp.isfinite(p_new).all()), f"{arch}: NaN in params"
    changed = any(bool(jnp.any(a != b)) for a, b in
                  zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert changed, f"{arch}: train step did not update params"


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_serve_step_smoke(arch):
    cfg = get_arch(arch, reduced=True)
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    step = jax.jit(make_serve_step(cfg))
    cache = init_decode_cache(cfg, B, seq_len=64)
    toks = jax.random.randint(key, (B, 1), 0, cfg.vocab)
    logits, cache2 = step(params, cache, {"tokens": toks})
    assert logits.shape == (B, 1, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: NaN logits"
    # a second step must also work (cache threading)
    logits2, _ = step(params, cache2, {"tokens": toks})
    assert bool(jnp.isfinite(logits2).all())


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    spec = {
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
        "smollm-360m": (32, 960, 15, 5, 2560, 49152),
        "minitron-4b": (32, 3072, 24, 8, 9216, 256000),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
        "rwkv6-1.6b": (24, 2048, 32, 32, 7168, 65536),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
    }[arch]
    cfg = get_arch(arch)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_ff,
           cfg.vocab)
    assert got == spec, f"{arch}: {got} != {spec}"
