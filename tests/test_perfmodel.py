"""Performance model (Eqs. 5-13) sanity and invariants."""
import hypothesis.strategies as st
import pytest
from hypothesis import given, settings

from repro.core import (PLATFORMS, WorkloadSpec, initial_task_mapping,
                        mteps, predict, predict_epoch_time)
from repro.core.perfmodel import (t_aggregate, t_load, t_sync, t_trainer,
                                  t_trans, t_update)

W = WorkloadSpec(batch_size=1024, fanouts=(25, 10), layer_dims=(100, 256, 47))
HOST = PLATFORMS["epyc-7763"]
GPU = PLATFORMS["rtx-a5000"]
FPGA = PLATFORMS["alveo-u250"]


def test_frontier_math_matches_paper_setup():
    # batch 1024, fanouts (25,10): |V0| = 1024*26*11
    assert W.frontier_sizes() == (1024, 1024 * 26, 1024 * 26 * 11)
    assert W.loaded_rows() == 1024 * 286
    assert W.total_edges() == 1024 * 25 + 1024 * 26 * 10


def test_eq7_eq8_load_transfer_scaling():
    t1 = t_load(W, HOST, n_trainers=1)
    t4 = t_load(W, HOST, n_trainers=4)
    assert abs(t4 / t1 - 4.0) < 1e-9       # Eq. 7 linear in n
    assert t_trans(W, GPU) > 0
    # PCIe slower than host RAM -> transfer slower than a 1-trainer load
    assert t_trans(W, GPU) > t_load(W, HOST, 1)


def test_eq7_disk_tier_priced_at_storage_bandwidth():
    w_disk = WorkloadSpec(batch_size=1024, fanouts=(25, 10),
                          layer_dims=(100, 256, 47), feature_tier="disk")
    ram, disk = t_load(W, HOST, 1), t_load(w_disk, HOST, 1)
    # epyc has the storage knob (7 GB/s NVMe << 205 GB/s RAM)
    assert abs(disk / ram - HOST.mem_bw_gbps / HOST.storage_bw_gbps) < 1e-9
    # a platform without the knob falls back to RAM pricing
    no_knob = HOST.__class__(**{**HOST.__dict__, "storage_bw_gbps": 0.0})
    assert t_load(w_disk, no_knob, 1) == t_load(W, no_knob, 1)
    # slower gathers shrink (or keep) the share the mapping risks on any
    # single trainer's load-bound path; total is always conserved
    kw = dict(n_accel=1, total_batch=1024, fanouts=(25, 10),
              layer_dims=(100, 256, 47))
    m = initial_task_mapping(HOST, GPU, feature_tier="disk", **kw)
    assert m["cpu"] + m["accel_each"] <= 1024
    assert m["cpu"] >= 0 and m["accel_each"] >= 0


def test_eq10_pipelined_faster_or_equal():
    """⊕ = max (FPGA, pipelined) <= ⊕ = sum (CPU/GPU style)."""
    w = W
    t_pipe = t_trainer(w, FPGA)
    unpipelined = FPGA.__class__(**{**FPGA.__dict__,
                                    "pipelined_agg_update": False})
    assert t_pipe <= t_trainer(w, unpipelined)


def test_eq13_sync_counts_model_twice():
    one = t_sync(W, GPU, compression_ratio=1.0)
    half = t_sync(W, GPU, compression_ratio=0.5)
    assert abs(one / half - 2.0) < 1e-9


@given(st.integers(64, 4096))
@settings(max_examples=20, deadline=None)
def test_trainer_time_monotonic_in_batch(batch):
    w1 = WorkloadSpec(batch, (25, 10), (100, 256, 47))
    w2 = WorkloadSpec(batch * 2, (25, 10), (100, 256, 47))
    for dev in (HOST, GPU, FPGA):
        assert t_trainer(w2, dev) > t_trainer(w1, dev)


def test_initial_task_mapping_conserves_batch():
    m = initial_task_mapping(HOST, FPGA, n_accel=4, total_batch=1024,
                             fanouts=(25, 10), layer_dims=(100, 256, 47))
    assert m["cpu"] + 4 * m["accel_each"] <= 1024
    assert m["cpu"] >= 0 and m["accel_each"] >= 0
    # hybrid must not be slower than accel-only per the model itself
    w_cpu = WorkloadSpec(m["cpu"], (25, 10), (100, 256, 47))
    w_acc = WorkloadSpec(m["accel_each"], (25, 10), (100, 256, 47))
    hybrid = predict(HOST, FPGA, 4, w_cpu, w_acc).t_execution
    w0 = WorkloadSpec(0, (25, 10), (100, 256, 47))
    wall = WorkloadSpec(1024 // 4, (25, 10), (100, 256, 47))
    accel_only = predict(HOST, FPGA, 4, w0, wall).t_execution
    assert hybrid <= accel_only * (1 + 1e-9)


def test_mteps_and_epoch_time():
    pred = predict(HOST, FPGA, 4,
                   WorkloadSpec(0, (25, 10), (100, 256, 47)),
                   WorkloadSpec(256, (25, 10), (100, 256, 47)))
    assert pred.t_execution > 0
    assert mteps(1_000_000, 0.5) == 2.0
    epoch = predict_epoch_time(2_449_029, 1024, pred)
    assert epoch > pred.t_execution
