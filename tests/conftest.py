"""Test-session setup.

* Installs the deterministic ``hypothesis`` shim (tests/_hypothesis_shim.py)
  when the real package is missing, so the property-based modules run
  everywhere the repo's baked-in toolchain runs.  ``pip install -r
  requirements-dev.txt`` swaps in real hypothesis transparently.
"""
import importlib.util
import os
import sys

if importlib.util.find_spec("hypothesis") is None:
    _here = os.path.dirname(__file__)
    _spec = importlib.util.spec_from_file_location(
        "hypothesis", os.path.join(_here, "_hypothesis_shim.py"))
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    _mod.strategies.__name__ = "hypothesis.strategies"
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies
