"""Fault-injection harness + graceful-degradation tests for the
storage/prefetch/refresh data plane.

Covers the failure protocol end to end: the deterministic
``FaultInjector`` itself; ``MmapFeatures`` retry-with-backoff, bounded
fallback gathers, spill-ENOSPC cleanup and advisory-hint counters;
``FeatureLoader`` stats integrity under a mid-gather fault;
``WindowPrefetcher`` supervision (restart budget, permanent failure,
legacy fail-fast); the ``PrefetchPipeline`` stage watchdog; trainer-level
degradation + ``health()``.  The ``chaos`` marker runs whole-trainer
fault scenarios (deterministic: every schedule is seeded and indexed by
per-op call counts, so runs replay exactly)."""
import errno
import glob
import json
import os
import time

import numpy as np
import pytest

from repro.core import (HybridConfig, HybridGNNTrainer, PipelineItem,
                        PipelineStallError, PrefetchPipeline, Stage)
from repro.graph import (DenseFeatures, FaultInjector, FaultSpec,
                         GNNConfig, HashedFeatures, MmapFeatures,
                         NumpySampler, WindowPrefetcher, WorkerKilled,
                         build_cache, make_dataset)
from repro.graph.featload import FeatureLoader

N, F, PROWS = 600, 32, 64


def _mmap_pair(tmp_path, name="spill", injector=None):
    hashed = HashedFeatures(N, F, seed=5)
    dense = DenseFeatures(hashed.take(np.arange(N)))
    mm = MmapFeatures.spill(hashed, spill_dir=str(tmp_path / name),
                            partition_rows=PROWS, fault_injector=injector)
    return dense, mm


def _gnn(ds, fanouts=(4, 3)):
    return GNNConfig(model="sage", layer_dims=ds.layer_dims,
                     fanouts=fanouts, num_classes=ds.num_classes)


# ------------------------------------------------------ injector mechanics


def test_spec_matching_and_kinds():
    s = FaultSpec(op="storage.take", kind="transient", start=2, count=3)
    assert [s.matches(i) for i in range(7)] == [
        False, False, True, True, True, False, False]
    p = FaultSpec(op="storage.take", kind="permanent", start=4)
    assert not p.matches(3) and p.matches(4) and p.matches(4000)
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec(op="x", kind="flaky")


def test_injector_fires_on_exact_call_indices():
    inj = FaultInjector([FaultSpec(op="storage.take", kind="transient",
                                   start=1, count=2)])
    hits = []
    for i in range(5):
        try:
            inj.fire("storage.take")
            hits.append(False)
        except OSError as e:
            assert e.errno == errno.EIO and f"call {i}" in str(e)
            hits.append(True)
    assert hits == [False, True, True, False, False]
    inj.fire("storage.prefetch")        # unscheduled op: counted, no fault
    rep = inj.report()
    assert rep["calls"] == {"storage.take": 5, "storage.prefetch": 1}
    assert rep["injected"] == {"storage.take": 2}
    assert rep["faults_raised"] == 2


def test_injector_delay_and_kill():
    inj = FaultInjector([
        FaultSpec(op="pipeline.load", kind="delay", delay=0.05, count=1),
        FaultSpec(op="prefetch.worker", kind="kill", start=0, count=1,
                  message="simulated worker death"),
    ])
    t0 = time.perf_counter()
    inj.fire("pipeline.load")
    assert time.perf_counter() - t0 >= 0.04
    with pytest.raises(WorkerKilled, match="simulated worker death"):
        inj.fire("prefetch.worker")
    inj.fire("prefetch.worker")         # count=1: next call is clean
    rep = inj.report()
    assert rep["delays_injected"] == 1
    assert rep["total_delay_seconds"] == pytest.approx(0.05)
    # WorkerKilled escapes `except Exception` by design
    assert not isinstance(WorkerKilled("x"), Exception)


def test_injector_json_roundtrip(tmp_path):
    inj = FaultInjector([FaultSpec(op="storage.take", start=3, count=2,
                                   errno=errno.ENOSPC)], seed=7)
    path = str(tmp_path / "schedule.json")
    with open(path, "w") as fh:
        fh.write(inj.to_json())
    for loaded in (FaultInjector.from_json(path),
                   FaultInjector.from_json(json.loads(inj.to_json()))):
        assert loaded.seed == 7
        assert loaded.schedule == inj.schedule
    # a bare list of spec dicts also loads
    bare = FaultInjector.from_json([{"op": "storage.prefetch"}])
    assert bare.schedule == [FaultSpec(op="storage.prefetch")]


def test_probabilistic_spec_is_deterministic():
    def pattern(seed):
        inj = FaultInjector([FaultSpec(op="storage.take", kind="transient",
                                       start=0, count=200,
                                       probability=0.5)], seed=seed)
        out = []
        for _ in range(200):
            try:
                inj.fire("storage.take")
                out.append(0)
            except OSError:
                out.append(1)
        return out
    a, b, c = pattern(3), pattern(3), pattern(4)
    assert a == b                       # same seed: identical fault pattern
    assert a != c                       # different seed: different pattern
    assert 0 < sum(a) < 200             # actually probabilistic


# ------------------------------------------- storage retries and fallbacks


def test_take_retries_transient_fault_bit_identical(tmp_path):
    inj = FaultInjector([FaultSpec(op="storage.take", kind="transient",
                                   start=0, count=2)])
    dense, mm = _mmap_pair(tmp_path, injector=inj)
    rows = np.random.default_rng(0).integers(0, N, 300).astype(np.int64)
    out = mm.take(rows)                 # calls 0,1 fault; call 2 succeeds
    assert out.tobytes() == dense.take(rows).tobytes()
    assert mm.io_errors == 2
    assert mm.io_retries == 2
    assert mm.io_retry_seconds > 0.0
    assert mm.fallback_gathers == 0     # retries absorbed it — no fallback


def test_take_exhausts_retries_and_raises_without_fallback(tmp_path):
    inj = FaultInjector([FaultSpec(op="storage.take", kind="permanent")])
    _, mm = _mmap_pair(tmp_path, injector=inj)
    mm.fallback_source = None           # storage tier alone: must raise
    with pytest.raises(OSError):
        mm.take(np.arange(10, dtype=np.int64))
    assert mm.io_errors == mm.io_retry_attempts
    assert mm.io_retries == mm.io_retry_attempts - 1


def test_take_falls_back_to_backing_source(tmp_path):
    inj = FaultInjector([FaultSpec(op="storage.take", kind="permanent")])
    dense, mm = _mmap_pair(tmp_path, injector=inj)
    rows = np.random.default_rng(1).integers(0, N, 200).astype(np.int64)
    out = mm.take(rows)                 # blob unreadable -> backing gather
    assert out.tobytes() == dense.take(rows).tobytes()
    assert mm.fallback_gathers > 0
    assert mm.fallback_rows == sum(
        np.count_nonzero(rows // PROWS == p)
        for p in np.unique(rows // PROWS))
    # fallback rows never came from the blob: no pages were touched
    assert mm.touched_page_bytes == 0


def test_fallback_budget_exhaustion_raises(tmp_path):
    inj = FaultInjector([FaultSpec(op="storage.take", kind="permanent")])
    _, mm = _mmap_pair(tmp_path, injector=inj)
    mm.fallback_row_budget = 8
    with pytest.raises(OSError, match="fallback gather budget"):
        mm.take(np.arange(32, dtype=np.int64))


def test_prefetch_rows_retries_transient_fault(tmp_path):
    inj = FaultInjector([FaultSpec(op="storage.prefetch", kind="transient",
                                   start=0, count=1)])
    _, mm = _mmap_pair(tmp_path, injector=inj)
    mm.prefetch_rows(np.arange(PROWS, dtype=np.int64))
    assert mm.io_retries == 1
    assert mm.prefetched_window_bytes > 0


def test_madvise_failure_counted_not_raised(tmp_path):
    inj = FaultInjector([FaultSpec(op="storage.madvise", kind="permanent")])
    dense, mm = _mmap_pair(tmp_path, injector=inj)
    rows = np.arange(0, N, 3, dtype=np.int64)
    out = mm.take(rows)                 # hint fails on every window open
    assert out.tobytes() == dense.take(rows).tobytes()
    assert mm.madvise_failures > 0
    assert mm.madvise_calls == 0        # no hint ever landed


def test_fadvise_failure_counted_not_raised(tmp_path):
    inj = FaultInjector([FaultSpec(op="storage.fadvise", kind="permanent",
                                   errno=errno.EBADF)])
    dense, mm = _mmap_pair(tmp_path, injector=inj)
    mm.drop_page_cache()                # every fadvise fails, none raise
    assert mm.fadvise_failures == mm.num_partitions
    rows = np.arange(50, dtype=np.int64)
    assert mm.take(rows).tobytes() == dense.take(rows).tobytes()


def test_spill_enospc_cleans_partial_blobs(tmp_path):
    inj = FaultInjector([FaultSpec(op="storage.spill", kind="permanent",
                                   start=2, errno=errno.ENOSPC)])
    spill = tmp_path / "enospc"
    hashed = HashedFeatures(N, F, seed=5)
    with pytest.raises(OSError) as ei:
        MmapFeatures.spill(hashed, spill_dir=str(spill),
                           partition_rows=PROWS, fault_injector=inj)
    # the error names the spill dir, the failing partition and the bytes
    # already written — and no partial blobs (or manifest) survive
    msg = str(ei.value)
    assert str(spill) in msg and "bytes written" in msg
    assert ei.value.errno == errno.ENOSPC
    expect = 2 * PROWS * F * 4
    assert f"after {expect} bytes" in msg
    assert glob.glob(str(spill / "part-*.bin")) == []
    assert not any(p.name.endswith(".json") for p in spill.iterdir())


# ------------------------------------------------- loader stats integrity


def test_loader_pool_fault_surfaces_once_stats_intact():
    ds = make_dataset("ogbn-products", scale=0.002, seed=0,
                      feature_backend="mmap", partition_rows=128)
    src = ds.feature_source
    cache = build_cache(ds, 0.2)        # boot gather runs clean
    inj = FaultInjector([FaultSpec(op="storage.take", kind="transient",
                                   start=0, count=1)])
    src.fault_injector = inj
    src.io_retry_attempts = 1           # no retries: the fault must surface
    src.fallback_source = None          # and no fallback to absorb it
    loader = FeatureLoader(ds, num_threads=2, cache=cache)
    sampler = NumpySampler(ds.graph, fanouts=(4, 3), seed=0)
    tgt = np.arange(64, dtype=np.int64)
    mb = sampler.sample(tgt, ds.labels[tgt])
    stats0 = (loader.stats.rows, loader.stats.total_rows,
              cache.stats.lookups, cache.stats.hit_rows)
    with pytest.raises(OSError):
        loader.load_compact(mb)         # one pool chunk faults mid-gather
    # the failed batch left every stats window untouched: the lookup was
    # classify-only and the accounting commits after the gather
    assert (loader.stats.rows, loader.stats.total_rows,
            cache.stats.lookups, cache.stats.hit_rows) == stats0
    assert loader.window.total_rows == 0
    # the loader is not poisoned: the next load works and accounts once
    block = loader.load_compact(mb)
    assert block.rows.shape[0] == loader.stats.rows
    assert cache.stats.lookups == 1
    loader.close()


# --------------------------------------------------- prefetch supervision


def test_prefetcher_restarts_killed_worker_within_budget(tmp_path):
    inj = FaultInjector([FaultSpec(op="prefetch.worker", kind="kill",
                                   start=0, count=1)])
    dense, mm = _mmap_pair(tmp_path)
    pf = WindowPrefetcher(mm, restart_budget=2, restart_backoff=0.001,
                          raise_on_failure=False, fault_injector=inj)
    rows = np.arange(PROWS, dtype=np.int64)
    assert pf.submit(rows)              # worker dies on this item
    assert pf.wait_idle(10.0)
    assert isinstance(pf.error, WorkerKilled)
    assert pf.submit(rows)              # supervisor respawns, item works
    assert pf.wait_idle(10.0)
    assert pf.restarts == 1 and pf.completed == 1
    assert pf.healthy and not pf.failed
    pf.close()


def test_prefetcher_fails_permanently_past_budget(tmp_path):
    # open-ended count: every respawned worker's first item kills it too
    inj = FaultInjector([FaultSpec(op="prefetch.worker", kind="kill",
                                   count=1 << 30)])
    _, mm = _mmap_pair(tmp_path)
    pf = WindowPrefetcher(mm, restart_budget=1, restart_backoff=0.001,
                          raise_on_failure=False, fault_injector=inj)
    rows = np.arange(PROWS, dtype=np.int64)
    ok = []
    for _ in range(4):                  # every respawned worker dies again
        ok.append(pf.submit(rows))
        pf.wait_idle(10.0)
    assert pf.failed and not pf.healthy
    assert ok[-1] is False              # degraded: drops, does not raise
    assert pf.restarts == 1
    assert not pf.submit(rows)          # permanently refusing, still calm
    pf.close()


def test_prefetcher_failed_raises_under_legacy_contract(tmp_path):
    inj = FaultInjector([FaultSpec(op="prefetch.worker", kind="kill")])
    _, mm = _mmap_pair(tmp_path)
    pf = WindowPrefetcher(mm, restart_budget=0, fault_injector=inj)
    rows = np.arange(PROWS, dtype=np.int64)
    pf.submit(rows)
    pf.wait_idle(10.0)
    with pytest.raises(RuntimeError,
                       match="prefetch worker failed") as ei:
        pf.submit(rows)
    assert isinstance(ei.value.__cause__, WorkerKilled)
    pf.close()


# ------------------------------------------------------- pipeline watchdog


def _items(n):
    return [PipelineItem(seq=i, payload=i) for i in range(n)]


def test_watchdog_raises_naming_wedged_stage():
    def wedge(item):
        if item.seq == 2:
            time.sleep(30.0)            # dead NFS mount / wedged gather
        return item

    pipe = PrefetchPipeline([Stage("sample", lambda it: it),
                             Stage("load", wedge)],
                            depth=2, watchdog_seconds=0.5)
    t0 = time.perf_counter()
    with pytest.raises(PipelineStallError) as ei:
        list(pipe.run(_items(8)))
    assert time.perf_counter() - t0 < 10.0   # a diagnosis, not a hang
    err = ei.value
    assert err.stage == "load"
    assert err.stalled_seconds >= 0.5
    assert set(err.queue_depths) == {"sample_in", "load_in", "output_in"}
    assert err.completed["load"] == 2   # items 0,1 passed; 2 wedged
    assert "wedged" in str(err) and "'load'" in str(err)


def test_watchdog_quiet_on_clean_and_sequential_runs():
    stages = [Stage("a", lambda it: it), Stage("b", lambda it: it)]
    for depth in (0, 2):
        pipe = PrefetchPipeline(stages, depth=depth, watchdog_seconds=0.2)
        out = list(pipe.run(_items(30)))
        assert [o.seq for o in out] == list(range(30))


def test_injected_delay_backs_queues_up_into_storm():
    # a long delay on the LAST stage wedges it; bounded queues upstream
    # fill behind it (the queue-full storm) and the watchdog's snapshot
    # shows the backlog
    inj = FaultInjector([FaultSpec(op="pipeline.slow", kind="delay",
                                   start=1, count=1, delay=30.0)])
    pipe = PrefetchPipeline([Stage("fast", lambda it: it),
                             Stage("slow", lambda it: it)],
                            depth=1, watchdog_seconds=0.5,
                            fault_injector=inj)
    with pytest.raises(PipelineStallError) as ei:
        list(pipe.run(_items(8)))
    assert ei.value.stage == "slow"
    assert ei.value.queue_depths["slow_in"] == 1   # full behind the wedge


def test_injected_stage_error_uses_failure_protocol():
    inj = FaultInjector([FaultSpec(op="pipeline.load", kind="transient",
                                   start=1, count=1)])
    pipe = PrefetchPipeline([Stage("load", lambda it: it)], depth=2,
                            fault_injector=inj)
    with pytest.raises(OSError):
        list(pipe.run(_items(6)))
    # the pipeline is reusable after the failure (per-run state)
    pipe.fault_injector = None
    assert len(list(pipe.run(_items(6)))) == 6


# ------------------------------------------- trainer-level degraded modes


def _small_trainer(tmp_path=None, fault_injector=None, **over):
    ds = make_dataset("ogbn-products", scale=0.002, seed=0,
                      feature_backend="mmap", partition_rows=512)
    cfg = dict(total_batch=128, n_accel=2, hybrid=False, use_drm=False,
               tfp_depth=0, seed=0, use_accel_sampler=False,
               cache_fraction=0.2)
    cfg.update(over)
    hcfg = HybridConfig(**cfg)
    return HybridGNNTrainer(ds, _gnn(ds), hcfg,
                            fault_injector=fault_injector)


def test_refresh_failure_degrades_then_disables():
    tr = _small_trainer(cache_refresh=True, cache_drift_threshold=0.0,
                        refresh_failure_budget=2)
    tr.train(2)
    # break the refresh gather tier, then arm the drift signal
    def bad_take(rows):
        raise RuntimeError("spill blob gone")
    tr.cache.source = type("Broken", (), {
        "take": staticmethod(bad_take), "shape": tr.cache.source.shape,
        "dtype": np.float32})()
    from repro.graph import LoadStats
    rb = tr.cache.row_bytes
    v0 = tr.cache.version
    for i in range(2):
        tr.loader.window.merge(LoadStats(
            rows=20, bytes=20 * rb, total_rows=100, unique_rows=80,
            hit_rows=70, saved_bytes=70 * rb))
        tr._model_hit_rate = 0.99
        assert not tr._maybe_refresh_cache()   # degrades, never raises
        assert tr._refresh_failures == i + 1
    assert tr._refresh_disabled                # budget spent: off for good
    assert tr.cache.version == v0              # old version kept serving
    assert tr.cache._staged is None            # failed plan was discarded
    h = tr.health()
    assert h["status"] == "degraded" and "refresh" in h["degraded"]
    assert not h["components"]["refresh"]["enabled"]
    assert not tr._maybe_refresh_cache()       # disabled: cheap no-op now
    tr.close()


def test_health_report_shape_on_clean_run():
    tr = _small_trainer(prefetch_windows=2)
    tr.train(2)
    h = tr.health()
    assert h["status"] == "ok" and h["degraded"] == [] and h["events"] == []
    assert h["components"]["prefetcher"]["healthy"]
    assert h["components"]["storage"]["io_errors"] == 0
    tr.close()
    assert set(tr.storage_io()) >= {
        "io_retries", "io_retry_seconds", "io_errors", "fallback_gathers",
        "fallback_rows", "madvise_failures", "fadvise_failures"}


# ------------------------------------------------------------ chaos suite


@pytest.mark.chaos
def test_chaos_transient_faults_bit_identical_losses():
    """Transient storage faults fully absorbed by retries must be
    invisible to training: losses bit-identical to a fault-free twin."""
    def run(injector):
        ds = make_dataset("ogbn-products", scale=0.002, seed=0,
                          feature_backend="mmap", partition_rows=512)
        cfg = HybridConfig(total_batch=128, n_accel=2, hybrid=False,
                           use_drm=False, tfp_depth=2, seed=0,
                           use_accel_sampler=False, cache_fraction=0.2,
                           prefetch_windows=2)
        tr = HybridGNNTrainer(ds, _gnn(ds), cfg, fault_injector=injector)
        hist = tr.train(4)
        losses = [m.loss for m in hist]
        io = dict(tr.storage_io())
        tr.close()
        return losses, io

    inj = FaultInjector([
        FaultSpec(op="storage.take", kind="transient", start=0, count=1),
        FaultSpec(op="storage.take", kind="transient", start=7, count=2),
        FaultSpec(op="storage.prefetch", kind="transient", start=1,
                  count=1),
    ], seed=0)
    clean_losses, clean_io = run(None)
    fault_losses, fault_io = run(inj)
    assert fault_losses == clean_losses            # bit-identical
    assert fault_io["io_retries"] >= 3             # the faults DID happen
    assert fault_io["io_errors"] >= 3
    assert clean_io["io_errors"] == 0
    assert inj.report()["faults_raised"] >= 3


@pytest.mark.chaos
def test_chaos_prefetcher_death_mid_epoch_degrades():
    """Kill the prefetch worker past its restart budget mid-run: training
    completes on synchronous loads, health() reports the degradation and
    the overlap discount re-prices to zero."""
    inj = FaultInjector([FaultSpec(op="prefetch.worker", kind="kill",
                                   start=2, count=1 << 30)])
    ds = make_dataset("ogbn-products", scale=0.002, seed=0,
                      feature_backend="mmap", partition_rows=512)
    cfg = HybridConfig(total_batch=128, n_accel=2, hybrid=False,
                       use_drm=False, tfp_depth=2, seed=0,
                       use_accel_sampler=False, cache_fraction=0.2,
                       prefetch_windows=2, prefetch_restart_budget=1)
    tr = HybridGNNTrainer(ds, _gnn(ds), cfg, fault_injector=inj)
    hist = tr.train(8)                  # survives the mid-epoch death
    assert len(hist) == 8
    assert all(np.isfinite(m.loss) for m in hist)
    assert tr.prefetcher.failed and not tr.prefetcher.healthy
    assert tr._measured_prefetch_overlap() == 0.0
    h = tr.health()
    assert h["status"] == "degraded"
    assert "prefetcher" in h["degraded"]
    (ev,) = [e for e in h["events"] if e["component"] == "prefetcher"]
    assert "synchronously" in ev["action"]
    assert h["components"]["prefetcher"]["restarts"] == 1
    tr.close()                          # degraded close stays clean


@pytest.mark.chaos
def test_chaos_watchdog_converts_wedged_stage_to_diagnosis():
    """An injected 30 s wedge in the TFP load stage raises a diagnostic
    PipelineStallError within the watchdog deadline instead of hanging
    the epoch."""
    inj = FaultInjector([FaultSpec(op="pipeline.load", kind="delay",
                                   start=2, count=1, delay=30.0)])
    ds = make_dataset("ogbn-products", scale=0.002, seed=0,
                      feature_backend="mmap", partition_rows=512)
    cfg = HybridConfig(total_batch=128, n_accel=2, hybrid=False,
                       use_drm=False, tfp_depth=2, seed=0,
                       use_accel_sampler=False, cache_fraction=0.2,
                       pipeline_watchdog_seconds=1.0)
    tr = HybridGNNTrainer(ds, _gnn(ds), cfg, fault_injector=inj)
    t0 = time.perf_counter()
    with pytest.raises(PipelineStallError) as ei:
        tr.train(8)
    assert time.perf_counter() - t0 < 15.0
    assert ei.value.stage == "load"
    assert ei.value.watchdog_seconds == 1.0
