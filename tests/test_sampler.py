"""Property tests for the mini-batch sampler (hypothesis)."""
import hypothesis.strategies as st
import numpy as np
import pytest
from hypothesis import given, settings

from repro.graph import (NumpySampler, frontier_sizes, make_dataset,
                         synth_powerlaw_graph)


@st.composite
def graph_and_batch(draw):
    n = draw(st.integers(50, 400))
    deg = draw(st.floats(1.0, 8.0))
    seed = draw(st.integers(0, 100))
    batch = draw(st.integers(1, 16))
    fanouts = draw(st.sampled_from([(2,), (3, 2), (4, 3, 2)]))
    return n, deg, seed, batch, fanouts


@given(graph_and_batch())
@settings(max_examples=25, deadline=None)
def test_sampled_edges_exist_in_graph(params):
    n, deg, seed, batch, fanouts = params
    g = synth_powerlaw_graph(n, deg, seed=seed)
    s = NumpySampler(g, fanouts=fanouts, seed=seed)
    rng = np.random.default_rng(seed)
    targets = rng.integers(0, n, batch)
    mb = s.sample(targets, np.zeros(batch, np.int32))

    sizes = frontier_sizes(batch, fanouts)
    degs = np.diff(g.indptr)
    frontier = np.asarray(targets, np.int64)
    for hop, fan in enumerate(fanouts):
        src = np.asarray(mb.hop_src[hop])
        assert src.shape == (sizes[hop] * fan,)
        dst = np.repeat(frontier, fan)
        for u, v in zip(src, dst):
            if degs[v] == 0:
                assert u == v, "deg-0 vertex must self-loop"
            else:
                nbrs = g.indices[g.indptr[v]:g.indptr[v + 1]]
                assert u in nbrs, f"sampled edge ({u}<-{v}) not in graph"
        frontier = np.concatenate([frontier, src])
    assert frontier.shape[0] == sizes[len(fanouts)]


@given(graph_and_batch())
@settings(max_examples=15, deadline=None)
def test_frontier_and_edge_counts(params):
    n, deg, seed, batch, fanouts = params
    g = synth_powerlaw_graph(n, deg, seed=seed)
    s = NumpySampler(g, fanouts=fanouts, seed=seed)
    targets = np.arange(min(batch, n))
    mb = s.sample(targets, np.zeros(len(targets), np.int32))
    sizes = frontier_sizes(len(targets), fanouts)
    # MTEPS numerator (Eq. 5): total sampled edges
    expect = sum(sizes[h] * f for h, f in enumerate(fanouts))
    assert mb.edges_traversed() == expect
    for l in range(len(fanouts) + 1):
        assert mb.frontier(l).shape[0] == sizes[l]


def test_jax_sampler_matches_shapes():
    import jax
    import jax.numpy as jnp
    from repro.graph import sample_minibatch_jax
    g = synth_powerlaw_graph(200, 4.0, seed=1)
    targets = np.arange(8)
    mb = sample_minibatch_jax(jax.random.PRNGKey(0),
                              jnp.asarray(g.indptr), jnp.asarray(g.indices),
                              jnp.asarray(targets),
                              jnp.zeros(8, jnp.int32), fanouts=(3, 2))
    sizes = frontier_sizes(8, (3, 2))
    assert mb.frontier(2).shape[0] == sizes[2]
    # all sampled vertices are valid ids
    for hop in range(2):
        src = np.asarray(mb.hop_src[hop])
        assert (src >= 0).all() and (src < 200).all()


def test_dataset_scaling_preserves_dims():
    ds = make_dataset("ogbn-papers100M", scale=1e-4, seed=0)
    assert ds.layer_dims == (128, 256, 172)
    assert ds.feat_dim == 128
    x = ds.take_features(np.array([0, 5, 7]))
    assert x.shape == (3, 128)
    # deterministic features
    x2 = ds.take_features(np.array([0, 5, 7]))
    np.testing.assert_array_equal(x, x2)
