"""Model-substrate consistency properties:
  * one-token decode == teacher-forced forward, every block kind
  * RWKV chunked WKV == sequential scan (hypothesis)
  * Mamba-2 chunked SSD == recurrent step
  * SWA == full attention when window >= seq
  * microbatched (grad-accum) train step == single-shot step
"""
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.models import (ModelConfig, forward, init_decode_cache,
                          init_params, loss_fn, make_serve_step,
                          make_train_step)
from repro.optim import adamw


def tiny(kind, **kw):
    base = dict(name="t", kind=kind, n_layers=3, d_model=64, n_heads=4,
                n_kv=2, d_ff=128, vocab=97, remat=False, q_block=8,
                dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


KINDS = [tiny("dense"), tiny("dense", window=5),
         tiny("moe", moe_experts=4, moe_top_k=2, capacity_factor=8.0),
         tiny("rwkv", n_heads=4, n_kv=4),
         tiny("zamba", n_layers=7, mamba_per_attn=3, ssm_state=16,
              ssm_head_dim=32)]


@pytest.mark.parametrize("cfg", KINDS, ids=lambda c: c.name + c.kind +
                         ("w" if c.window else ""))
def test_decode_matches_forward(cfg):
    key = jax.random.PRNGKey(0)
    p = init_params(key, cfg)
    S = 16
    toks = jax.random.randint(key, (2, S), 0, cfg.vocab)
    logits_f, _, _ = forward(p, cfg, {"tokens": toks})
    step = jax.jit(make_serve_step(cfg))
    cache = init_decode_cache(cfg, 2, S)
    outs = []
    for t in range(S):
        lg, cache = step(p, cache, {"tokens": toks[:, t:t + 1]})
        outs.append(lg)
    logits_d = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_f[..., :cfg.vocab]),
        np.asarray(logits_d[..., :cfg.vocab]), rtol=2e-4, atol=2e-4)


@given(st.integers(0, 50), st.integers(1, 4),
       st.sampled_from([8, 16, 32]), st.floats(0.05, 0.98))
@settings(max_examples=15, deadline=None)
def test_wkv_chunked_equals_sequential(seed, b, t, wmax):
    from repro.models import rwkv as R
    key = jax.random.PRNGKey(seed)
    h, k_dim = 2, 8
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (b, t, h, k_dim))
    k = jax.random.normal(ks[1], (b, t, h, k_dim))
    v = jax.random.normal(ks[2], (b, t, h, k_dim))
    w = jax.random.uniform(ks[3], (b, t, h, k_dim), minval=0.02,
                           maxval=wmax)
    u = jax.random.normal(ks[4], (h, k_dim)) * 0.1
    s0 = jnp.zeros((b, h, k_dim, k_dim))
    y1, s1 = R._wkv_scan(r, k, v, w, u, s0)
    y2, s2 = R._wkv_chunked(r, k, v, w, u, s0, chunk=8)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-3, atol=1e-3)


@given(st.integers(0, 50))
@settings(max_examples=10, deadline=None)
def test_mamba_chunked_equals_step(seed):
    from repro.models import ssm as S
    key = jax.random.PRNGKey(seed)
    d, n, hd, t = 32, 16, 16, 12
    mp = S.init_mamba_params(key, d, n, head_dim=hd)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, t, d))
    y_chunk = S.mamba_forward(mp, x, d_state=n, head_dim=hd, chunk=4)
    cache = S.init_mamba_cache(2, d, n, hd, dtype=jnp.float32)
    ys = []
    for i in range(t):
        yt, cache = S.mamba_step(mp, cache, x[:, i:i + 1], d_state=n,
                                 head_dim=hd)
        ys.append(yt)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               rtol=2e-4, atol=2e-4)


def test_swa_equals_full_when_window_covers_seq():
    from repro.models.layers import attention
    key = jax.random.PRNGKey(0)
    b, s, hq, hkv, dh = 2, 32, 4, 2, 16
    q = jax.random.normal(key, (b, s, hq, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, dh))
    full = attention(q, k, v, window=0, q_block=8)
    swa = attention(q, k, v, window=s, q_block=8)
    np.testing.assert_allclose(np.asarray(full), np.asarray(swa),
                               rtol=1e-5, atol=1e-5)


def test_swa_restricts_receptive_field():
    """Token t must be unaffected by tokens < t - window."""
    from repro.models.layers import attention
    key = jax.random.PRNGKey(0)
    b, s, h, dh, w = 1, 24, 2, 8, 4
    q = jax.random.normal(key, (b, s, h, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, dh))
    out1 = attention(q, k, v, window=w, q_block=8)
    k2 = k.at[:, :8].set(99.0)   # clobber tokens 0..7
    v2 = v.at[:, :8].set(99.0)
    out2 = attention(q, k2, v2, window=w, q_block=8)
    # queries at positions >= 8 + w - 1 see none of 0..7
    np.testing.assert_allclose(np.asarray(out1[:, 8 + w:]),
                               np.asarray(out2[:, 8 + w:]),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("cfg", [tiny("dense"),
                                 tiny("moe", moe_experts=4, moe_top_k=1,
                                      capacity_factor=8.0)],
                         ids=["dense", "moe"])
def test_microbatched_step_equals_single(cfg):
    """Gradient-accumulation semantics: with a LINEAR optimizer (SGD) the
    microbatched step equals the single-shot step exactly (Adam's
    rsqrt(v)+eps amplifies fp32 summation-order noise, so it is not the
    right probe for this identity)."""
    from repro.optim import sgd
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    opt = sgd(1e-2)
    toks = jax.random.randint(key, (8, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}

    s1 = make_train_step(cfg, opt, microbatches=1)
    s4 = make_train_step(cfg, opt, microbatches=4)
    p1, _, m1 = s1(params, opt.init(params), batch)
    p4, _, m4 = s4(params, opt.init(params), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"].mean()),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
