"""Hierarchical collective schedule: equivalence with the flat mean on a
small multi-pod mesh (subprocess, forced host devices)."""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np
from repro.dist import use_mesh
from repro.dist.collectives import hierarchical_psum_mean

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
rng = np.random.default_rng(0)
grads = {"w": jnp.asarray(rng.normal(size=(16, 8)), jnp.float32),
         "b": jnp.asarray(rng.normal(size=(8,)), jnp.float32)}

with use_mesh(mesh):
    # grads replicated on all 8 devices: the hierarchical mean must return
    # sum(8 copies)/8 == the original values.  A scaling bug anywhere in
    # the reduce-scatter -> cross-pod psum -> all-gather chain (e.g. a
    # missing /n) breaks this by an 8x-class factor.
    out = jax.jit(hierarchical_psum_mean)(grads)
    err = max(float(jnp.abs(a - b).max())
              for a, b in zip(jax.tree.leaves(grads), jax.tree.leaves(out)))
    # schedule check: compiled program uses scoped collectives
    txt = jax.jit(hierarchical_psum_mean).lower(grads).compile().as_text()
    kinds = {k: txt.count(k) for k in
             ("reduce-scatter", "all-reduce", "all-gather")}
print("RESULT:" + __import__("json").dumps({"err": err, "kinds": kinds}))
"""


@pytest.mark.slow
def test_hierarchical_mean_matches_flat():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT:")][0]
    res = json.loads(line[len("RESULT:"):])
    assert res["err"] < 1e-6
    # the hierarchical schedule is visible in the compiled program
    assert res["kinds"]["all-reduce"] >= 1
    assert (res["kinds"]["reduce-scatter"] >= 1
            or res["kinds"]["all-gather"] >= 1), res["kinds"]
