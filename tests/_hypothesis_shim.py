"""Minimal stand-in for ``hypothesis`` when the real package is absent.

The container this repo targets has no ``hypothesis`` wheel baked in, but
five test modules are property-based.  Rather than skip them wholesale,
this shim implements the tiny strategy surface they use (``integers``,
``floats``, ``sampled_from``, ``builds``, ``composite``) with a
deterministic per-test RNG, and runs each ``@given`` test for
``max_examples`` generated examples.  No shrinking, no database — a
failing example's repr is attached to the assertion instead.

Installed by ``tests/conftest.py`` as ``sys.modules["hypothesis"]`` only
when ``import hypothesis`` fails; with the real package installed
(``pip install -r requirements-dev.txt``) this file is inert.
"""
from __future__ import annotations

import functools
import types
import zlib

import numpy as np

__all__ = ["given", "settings", "assume", "strategies", "HealthCheck"]


class Strategy:
    def __init__(self, sample, label="strategy"):
        self._sample = sample
        self._label = label

    def sample(self, rng: np.random.Generator):
        return self._sample(rng)

    def map(self, f):
        return Strategy(lambda rng: f(self._sample(rng)),
                        f"{self._label}.map")

    def filter(self, pred, max_tries: int = 100):
        def sample(rng):
            for _ in range(max_tries):
                v = self._sample(rng)
                if pred(v):
                    return v
            raise ValueError(f"filter on {self._label} found no example")
        return Strategy(sample, f"{self._label}.filter")

    def __repr__(self):
        return self._label


def _integers(min_value, max_value):
    return Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)),
                    f"integers({min_value}, {max_value})")


def _floats(min_value, max_value, **_kw):
    return Strategy(lambda rng: float(rng.uniform(min_value, max_value)),
                    f"floats({min_value}, {max_value})")


def _booleans():
    return Strategy(lambda rng: bool(rng.integers(0, 2)), "booleans()")


def _sampled_from(seq):
    items = list(seq)
    return Strategy(lambda rng: items[int(rng.integers(0, len(items)))],
                    f"sampled_from({len(items)} items)")


def _lists(elements: Strategy, min_size=0, max_size=10, **_kw):
    def sample(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.sample(rng) for _ in range(n)]
    return Strategy(sample, "lists(...)")


def _tuples(*strats):
    return Strategy(lambda rng: tuple(s.sample(rng) for s in strats),
                    "tuples(...)")


def _just(value):
    return Strategy(lambda rng: value, f"just({value!r})")


def _builds(target, *args, **kwargs):
    def sample(rng):
        a = [s.sample(rng) if isinstance(s, Strategy) else s for s in args]
        k = {n: (s.sample(rng) if isinstance(s, Strategy) else s)
             for n, s in kwargs.items()}
        return target(*a, **k)
    return Strategy(sample, f"builds({getattr(target, '__name__', target)})")


def _composite(f):
    @functools.wraps(f)
    def make(*args, **kwargs):
        def sample(rng):
            def draw(strategy):
                return strategy.sample(rng)
            return f(draw, *args, **kwargs)
        return Strategy(sample, f"composite({f.__name__})")
    return make


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = _integers
strategies.floats = _floats
strategies.booleans = _booleans
strategies.sampled_from = _sampled_from
strategies.lists = _lists
strategies.tuples = _tuples
strategies.just = _just
strategies.builds = _builds
strategies.composite = _composite
strategies.SearchStrategy = Strategy


class _Assume(Exception):
    pass


def assume(condition) -> bool:
    if not condition:
        raise _Assume()
    return True


def settings(max_examples: int = 20, **_ignored):
    def deco(test):
        test._shim_max_examples = max_examples
        return test
    return deco


class HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"
    all = classmethod(lambda cls: [])


def given(*strats, **kw_strats):
    def deco(test):
        n_default = getattr(test, "_shim_max_examples", 20)

        def run():
            n = getattr(run, "_shim_max_examples", n_default)
            seed = zlib.crc32(test.__qualname__.encode())
            rng = np.random.default_rng(seed)
            for i in range(n):
                args = [s.sample(rng) for s in strats]
                kwargs = {k: s.sample(rng) for k, s in kw_strats.items()}
                try:
                    test(*args, **kwargs)
                except _Assume:
                    continue
                except Exception as e:
                    raise AssertionError(
                        f"{test.__qualname__} failed on generated example "
                        f"#{i}: args={args!r} kwargs={kwargs!r}") from e
        functools.update_wrapper(run, test)
        # pytest resolves fixtures through __wrapped__'s signature; the
        # generated arguments are NOT fixtures, so hide the original.
        del run.__wrapped__
        run.__dict__.pop("_shim_max_examples", None)
        run._shim_max_examples = n_default
        run.hypothesis = types.SimpleNamespace(inner_test=test)
        return run
    return deco
