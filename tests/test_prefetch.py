"""Background storage-I/O subsystem: WindowPrefetcher unit + error-path
tests (a failing prefetch thread must surface without deadlocking the
pipeline feeder; close() idempotent under a half-drained queue), the
Eq. 7 prefetch-overlap discount, trainer wiring of the prefetcher / LRU /
stall stats, and the concurrency stress suite — forced interleavings of
the prefetcher, staged-refresh commit() and the TFP stages across
depths 1-3 and n_accel in {0, 1, 2}, asserting loss bit-identity and
that mid-gather window evictions never corrupt an in-flight gather."""
import os
import threading
import time

import numpy as np
import pytest

from repro.core import HybridConfig, HybridGNNTrainer
from repro.core.perfmodel import (PLATFORMS, WorkloadSpec,
                                  initial_task_mapping, t_load)
from repro.core.pipeline import PipelineItem, PrefetchPipeline, Stage
from repro.graph import (DenseFeatures, GNNConfig, HashedFeatures,
                         MmapFeatures, WindowPrefetcher, make_dataset)

N, F, PROWS = 600, 32, 64


def _mmap_pair(tmp_path, name="spill", lru=0):
    hashed = HashedFeatures(N, F, seed=5)
    dense = DenseFeatures(hashed.take(np.arange(N)))
    mm = MmapFeatures.spill(hashed, spill_dir=str(tmp_path / name),
                            partition_rows=PROWS, lru_windows=lru)
    return dense, mm


class _StubSource:
    """Minimal prefetchable source for error/queue tests."""

    shape = (N, F)

    def __init__(self, delay=0.0, fail=False):
        self.calls = 0
        self.delay = delay
        self.fail = fail
        self.window_evictions = 0
        self.seen = []                  # rows each worker call received

    def prefetch_rows(self, rows):
        self.calls += 1
        self.seen.append(np.asarray(rows).copy())
        if self.delay:
            time.sleep(self.delay)
        if self.fail:
            raise RuntimeError("spill blob gone")


# --------------------------------------------------------- prefetch basics


def test_prefetcher_prefaults_and_gather_is_warm(tmp_path):
    dense, mm = _mmap_pair(tmp_path)
    rng = np.random.default_rng(0)
    rows = rng.integers(0, N, 400).astype(np.int64)
    pf = WindowPrefetcher(mm, max_queue=4)
    assert pf.submit(rows)
    assert pf.wait_idle(30.0)
    assert pf.completed == 1
    assert mm.prefetched_window_bytes > 0
    cold0 = mm.cold_fault_page_bytes
    out = mm.take(rows)
    # every page the gather needed was pre-faulted: zero load-stage stall,
    # and the bytes are identical to the dense reference
    assert mm.cold_fault_page_bytes == cold0
    assert mm.prefetch_hit_rate == 1.0
    assert out.tobytes() == dense.take(rows).tobytes()
    pf.close()


def test_prefetcher_requires_prefetchable_source():
    dense = DenseFeatures(np.zeros((8, 4), np.float32))
    with pytest.raises(TypeError, match="prefetch_rows"):
        WindowPrefetcher(dense)


def test_prefetcher_full_queue_drops_not_blocks():
    src = _StubSource(delay=0.2)
    pf = WindowPrefetcher(src, max_queue=1)
    rows = np.arange(4)
    sent = [pf.submit(rows) for _ in range(8)]
    # the first fills the worker, the second fills the queue; the rest
    # must return False immediately instead of stalling the sample stage
    assert sent[0] and not all(sent)
    assert pf.dropped == sent.count(False) > 0
    assert pf.wait_idle(30.0)
    pf.close()


# --------------------------------------------------- cross-batch dedup


def test_dedup_strips_already_warm_rows():
    """Consecutive frontiers overlap on hub nodes: with dedup on, a
    resubmitted id must not reach the worker again while its submit is
    in the history window."""
    src = _StubSource()
    pf = WindowPrefetcher(src, max_queue=4, dedup_history=2)
    a = np.arange(0, 100)
    b = np.arange(50, 150)          # 50 rows overlap with a
    assert pf.submit(a) and pf.wait_idle(30.0)
    assert pf.submit(b) and pf.wait_idle(30.0)
    assert pf.resubmitted_rows_skipped == 50
    assert np.array_equal(src.seen[0], a)
    assert np.array_equal(src.seen[1], np.arange(100, 150))   # fresh only
    # fully-warm submit: succeeds without touching the worker at all
    assert pf.submit(np.arange(120, 140))
    assert pf.wait_idle(30.0)
    assert src.calls == 2
    assert pf.resubmitted_rows_skipped == 70
    pf.close()


def test_dedup_history_window_ages_out():
    """Only the last ``dedup_history`` submits stay warm: an id older
    than the window is prefetched again."""
    src = _StubSource()
    pf = WindowPrefetcher(src, max_queue=4, dedup_history=1)
    a, b = np.arange(0, 50), np.arange(50, 100)
    for rows in (a, b, a):          # a has aged out by the third submit
        assert pf.submit(rows) and pf.wait_idle(30.0)
    assert src.calls == 3
    assert np.array_equal(src.seen[2], a)
    assert pf.resubmitted_rows_skipped == 0
    pf.close()


def test_dedup_history_clears_on_source_eviction():
    """Any LRU eviction invalidates the warm assumption: the next submit
    after ``window_evictions`` moves must prefetch everything again."""
    src = _StubSource()
    pf = WindowPrefetcher(src, max_queue=4, dedup_history=4)
    rows = np.arange(0, 80)
    assert pf.submit(rows) and pf.wait_idle(30.0)
    src.window_evictions += 1       # an eviction landed on the source
    assert pf.submit(rows) and pf.wait_idle(30.0)
    assert src.calls == 2
    assert np.array_equal(src.seen[1], rows)
    assert pf.resubmitted_rows_skipped == 0
    pf.close()


class _RacingSource(_StubSource):
    """``window_evictions`` moves BETWEEN submit()'s two reads: the
    pre-strip check sees 0, the post-strip re-check sees 1 — modelling
    the worker's LRU evicting a remembered window while the strip is
    being computed on the sample thread."""

    def __init__(self):
        self._ev_reads = 0
        super().__init__()

    @property
    def window_evictions(self):
        self._ev_reads += 1
        # reads: 1 = WindowPrefetcher.__init__, 2 = submit(a) pre-strip
        # check (history empty, no re-check), 3 = submit(b) pre-strip
        # check, 4 = submit(b) post-strip re-check
        return 0 if self._ev_reads < 4 else 1

    @window_evictions.setter
    def window_evictions(self, v):      # _StubSource.__init__ assigns 0
        pass


def test_eviction_during_dedup_strip_falls_back_to_full_rows():
    """Regression (RPR101 find): submit() used to consult the source's
    eviction counter only BEFORE computing the dedup strip, so rows
    stripped as 'warm' could be evicted (cold again) by the time the
    request was enqueued.  The post-strip re-check must fall back to
    the FULL row set and discard the stale history."""
    src = _RacingSource()
    pf = WindowPrefetcher(src, max_queue=4, dedup_history=2)
    a = np.arange(0, 100)
    b = np.arange(50, 150)          # 50 rows the strip would have cut
    assert pf.submit(a) and pf.wait_idle(30.0)
    assert pf.submit(b) and pf.wait_idle(30.0)
    assert np.array_equal(src.seen[1], b)       # whole set, not stripped
    assert pf.resubmitted_rows_skipped == 0     # nothing credited as warm
    # the suspect history was dropped; only b (re-remembered on its
    # enqueue) is warm, so resubmitting a strips just the a∩b overlap
    assert pf.submit(a) and pf.wait_idle(30.0)
    assert np.array_equal(src.seen[2], np.arange(0, 50))
    assert pf.resubmitted_rows_skipped == 50
    pf.close()


def test_dedup_off_by_default():
    src = _StubSource()
    pf = WindowPrefetcher(src, max_queue=4)
    rows = np.arange(0, 30)
    assert pf.submit(rows) and pf.wait_idle(30.0)
    assert pf.submit(rows) and pf.wait_idle(30.0)
    assert src.calls == 2           # no dedup without the knob
    assert pf.resubmitted_rows_skipped == 0
    pf.close()


def test_dropped_submit_leaves_no_warm_marks():
    """A queue-full drop prefetches nothing, so it must not record its
    rows as warm: the retry after the queue drains is worked in full."""
    gate = threading.Event()

    class _Gated(_StubSource):
        def prefetch_rows(self, rows):
            gate.wait(30.0)
            _StubSource.prefetch_rows(self, rows)

    src = _Gated()
    pf = WindowPrefetcher(src, max_queue=1, dedup_history=4)
    assert pf.submit(np.arange(0, 10))      # worker picks this up, blocks
    for _ in range(500):                    # wait for the dequeue
        if pf._q.empty():
            break
        time.sleep(0.01)
    assert pf.submit(np.arange(10, 20))     # fills the queue
    fresh = np.arange(100, 160)
    assert not pf.submit(fresh)             # queue full: dropped
    gate.set()
    assert pf.wait_idle(30.0)
    assert pf.submit(fresh)                 # no warm marks from the drop
    assert pf.wait_idle(30.0)
    assert any(np.array_equal(s, fresh) for s in src.seen)
    pf.close()


def test_dedup_real_mmap_cuts_prefetch_volume(tmp_path):
    """On the real mmap tier: resubmitting an overlapping frontier with
    dedup on faults no new pages for the warm rows and the gather stays
    byte-identical."""
    dense, mm = _mmap_pair(tmp_path, name="spill-dedup")
    rng = np.random.default_rng(3)
    a = rng.integers(0, N // 2, 200).astype(np.int64)
    b = np.concatenate([a[:100], rng.integers(N // 2, N, 100)])
    pf = WindowPrefetcher(mm, max_queue=4, dedup_history=2)
    assert pf.submit(np.unique(a)) and pf.wait_idle(30.0)
    assert pf.submit(np.unique(b)) and pf.wait_idle(30.0)
    assert pf.resubmitted_rows_skipped > 0
    out = mm.take(b)
    assert out.tobytes() == dense.take(b).tobytes()
    assert mm.prefetch_hit_rate == 1.0
    pf.close()


# ----------------------------------------------------------- error paths


def test_prefetcher_error_latches_and_raises_on_next_submit(tmp_path):
    """A deleted spill blob mid-run: the worker fails, keeps draining,
    and the NEXT submit raises with the original error chained."""
    _, mm = _mmap_pair(tmp_path, name="spill-err")
    os.remove(os.path.join(mm.spill_dir, MmapFeatures._part_name(1)))
    pf = WindowPrefetcher(mm, max_queue=4)
    bad = np.arange(PROWS, 2 * PROWS, dtype=np.int64)   # partition 1
    assert pf.submit(bad)
    assert pf.wait_idle(30.0)
    assert pf.error is not None
    with pytest.raises(RuntimeError, match="prefetch worker failed"):
        pf.submit(bad)
    pf.close()                # still clean to shut down


def test_prefetcher_error_surfaces_through_pipeline_without_deadlock():
    """The trainer's sample stage submits to the prefetcher: after the
    worker dies, the next run() surfaces the failure through the stage
    protocol (feeder stops, no deadlock, pipeline reusable)."""
    src = _StubSource(fail=True)
    pf = WindowPrefetcher(src, max_queue=2)
    produced = []

    def gen(n):
        for i in range(n):
            produced.append(i)
            yield PipelineItem(seq=i, payload=i)

    def sample(item):
        pf.submit(np.arange(4))
        time.sleep(0.005)       # let the worker hit the failure
        return item

    pipe = PrefetchPipeline([Stage("sample", sample)], depth=2)
    with pytest.raises(RuntimeError, match="prefetch worker failed"):
        list(pipe.run(gen(100)))
    assert len(produced) < 50   # feeder stopped consuming payloads
    pf.close()
    # a fresh prefetcher on a clean run works again
    pf2 = WindowPrefetcher(_StubSource(), max_queue=2)

    def sample2(item):
        pf2.submit(np.arange(4))
        return item

    pipe2 = PrefetchPipeline([Stage("sample", sample2)], depth=2)
    assert [it.seq for it in pipe2.run(
        PipelineItem(seq=i, payload=i) for i in range(5))] == list(range(5))
    pf2.close()


def test_prefetcher_close_idempotent_under_half_drained_queue():
    src = _StubSource(delay=0.1)
    pf = WindowPrefetcher(src, max_queue=8)
    for _ in range(6):
        pf.submit(np.arange(4))
    t0 = time.perf_counter()
    pf.close()                  # queue half-drained: must not deadlock
    pf.close()                  # idempotent
    assert time.perf_counter() - t0 < 10.0
    assert not pf._thread.is_alive()
    assert not pf.submit(np.arange(4))    # closed: drop, don't enqueue


def test_prefetcher_wait_idle_reports_completion():
    src = _StubSource(delay=0.05)
    pf = WindowPrefetcher(src, max_queue=4)
    pf.submit(np.arange(4))
    assert not pf.wait_idle(0.001)        # still working
    assert pf.wait_idle(30.0)
    assert pf.completed == pf.submitted == 1
    pf.close()


# ------------------------------------------------- Eq. 7 overlap discount


def test_eq7_prefetch_overlap_discount():
    host = PLATFORMS["epyc-7763"]
    w = lambda ov, tier="disk": WorkloadSpec(
        1024, (10, 5), (128, 256, 172), feature_tier=tier,
        prefetch_overlap=ov)
    t_off = t_load(w(0.0), host, 1)
    t_half = t_load(w(0.5), host, 1)
    t_full = t_load(w(1.0), host, 1)
    t_ram = t_load(w(0.0, tier="ram"), host, 1)
    # overlap=0 reproduces the plain disk pricing; more overlap strictly
    # cheaper; full overlap leaves exactly the RAM-speed gather exposed
    assert t_off > t_half > t_full
    assert t_full == pytest.approx(t_ram)
    # the RAM tier has no storage stream to hide: the knob is inert
    assert t_load(w(1.0, tier="ram"), host, 1) == t_ram


def test_mapping_accepts_prefetch_overlap():
    host, accel = PLATFORMS["epyc-7763"], PLATFORMS["tpu-v5e"]
    kw = dict(fanouts=(10, 5), layer_dims=(128, 256, 172),
              feature_tier="disk")
    m0 = initial_task_mapping(host, accel, 2, 1024, **kw)
    m1 = initial_task_mapping(host, accel, 2, 1024, prefetch_overlap=1.0,
                              **kw)
    for m in (m0, m1):
        assert m["cpu"] + 2 * m["accel_each"] <= 1024
        assert m["accel_each"] >= 0 and m["cpu"] >= 0


# --------------------------------------------------------- trainer wiring


def _gnn(ds, fanouts=(4, 3)):
    return GNNConfig(model="sage", layer_dims=ds.layer_dims,
                     fanouts=fanouts, num_classes=ds.num_classes)


def test_trainer_wires_background_io(tmp_path):
    ds = make_dataset("ogbn-products", scale=0.002, seed=0,
                      feature_backend="mmap",
                      spill_dir=str(tmp_path / "spill"), partition_rows=512)
    cfg = HybridConfig(total_batch=128, n_accel=2, hybrid=False,
                       use_drm=False, tfp_depth=2, seed=0,
                       use_accel_sampler=False, cache_fraction=0.2,
                       prefetch_windows=2, mmap_lru_windows=4)
    tr = HybridGNNTrainer(ds, _gnn(ds), cfg)
    assert tr.prefetcher is not None
    assert tr.loader.source.lru_windows == 4
    assert tr.prefetch_overlap == 1.0
    hist = tr.train(4)
    assert all(np.isfinite(m.loss) for m in hist)
    io = tr.storage_io()
    assert io["prefetch_submitted"] > 0
    assert io["open_windows"] <= 4
    # the residual stall is DRM-visible (aggregate gather-thread seconds:
    # a multi-threaded chunked gather can sum past the wall-clock t_load)
    for m in hist:
        assert m.times.t_load_stall >= 0.0
    tr.close()
    tr.close()                  # idempotent


def test_trainer_storage_io_exposes_dedup_and_pin_counters(tmp_path):
    """The trainer threads prefetch_dedup_history into the prefetcher and
    surfaces resubmitted_rows_skipped / pin_blocked_evictions through
    storage_io(); consecutive frontiers share hubs, so the dedup counter
    actually moves."""
    ds = make_dataset("ogbn-products", scale=0.002, seed=0,
                      feature_backend="mmap",
                      spill_dir=str(tmp_path / "spill"), partition_rows=512)
    cfg = HybridConfig(total_batch=128, n_accel=2, hybrid=False,
                       use_drm=False, tfp_depth=2, seed=0,
                       use_accel_sampler=False, prefetch_windows=2,
                       prefetch_dedup_history=2)
    tr = HybridGNNTrainer(ds, _gnn(ds), cfg)
    hist = tr.train(4)
    assert all(np.isfinite(m.loss) for m in hist)
    io = tr.storage_io()
    assert io["resubmitted_rows_skipped"] > 0
    assert io["pin_blocked_evictions"] >= 0.0
    tr.close()


def test_trainer_without_mmap_has_no_prefetcher():
    ds = make_dataset("ogbn-products", scale=0.002, seed=0,
                      feature_backend="dense")
    cfg = HybridConfig(total_batch=128, n_accel=1, hybrid=False,
                       use_drm=False, tfp_depth=0, seed=0,
                       use_accel_sampler=False, prefetch_windows=4,
                       mmap_lru_windows=4)
    tr = HybridGNNTrainer(ds, _gnn(ds), cfg)
    assert tr.prefetcher is None
    assert tr.prefetch_overlap == 0.0
    assert tr.storage_io()["prefetched_window_bytes"] == 0.0
    tr.close()


def test_boot_and_refresh_gathers_excluded_from_stall_stats(tmp_path):
    """Maintenance gathers — the cache boot block and staged-refresh
    admission rows — are not load-stage traffic: they must not seed the
    stall/prefetch-hit counters the task mapping re-prices on (the boot
    gather touches EVERY window before training starts and would pin the
    measured overlap near 0 forever)."""
    from repro.graph import FeatureCache
    ds = make_dataset("ogbn-products", scale=0.002, seed=0,
                      feature_backend="mmap",
                      spill_dir=str(tmp_path / "spill"), partition_rows=512)
    cfg = HybridConfig(total_batch=128, n_accel=2, hybrid=False,
                       use_drm=False, tfp_depth=0, seed=0,
                       use_accel_sampler=False, cache_fraction=0.2,
                       prefetch_windows=2, mmap_lru_windows=4)
    tr = HybridGNNTrainer(ds, _gnn(ds), cfg)
    src = tr.loader.source
    assert src.prefetch_miss_windows == 0       # boot gather untracked
    assert src.cold_fault_page_bytes == 0
    assert src.cold_gather_seconds == 0.0
    assert tr._measured_prefetch_overlap() == 1.0   # design estimate intact
    tr.close()
    # staged-refresh admission gathers are equally excluded
    hashed = HashedFeatures(N, F, seed=5)
    mm = MmapFeatures.spill(hashed, spill_dir=str(tmp_path / "spill2"),
                            partition_rows=PROWS)
    cache = FeatureCache(mm, np.arange(N, 0, -1, np.float64), 40)
    cache.track_hotness = True
    rng = np.random.default_rng(0)
    for _ in range(4):
        cache.lookup(rng.integers(100, N, 200).astype(np.int64))
    before = (mm.cold_fault_page_bytes, mm.prefetch_miss_windows,
              mm.cold_gather_seconds, mm.warm_gather_seconds)
    assert cache.stage() > 0
    assert cache.commit() > 0
    assert (mm.cold_fault_page_bytes, mm.prefetch_miss_windows,
            mm.cold_gather_seconds, mm.warm_gather_seconds) == before


def test_prefetch_submits_cpu_full_frontier_and_accel_misses(tmp_path):
    """The device cache only serves accelerator trainers: the CPU
    trainer gathers its FULL frontier from the source, so the prefetch
    submission must keep its cache-hit rows (they fault like any other
    on the disk tier) and drop them only from accel frontiers."""
    ds = make_dataset("ogbn-products", scale=0.002, seed=0,
                      feature_backend="mmap",
                      spill_dir=str(tmp_path / "spill"), partition_rows=512)
    cfg = HybridConfig(total_batch=128, n_accel=1, hybrid=True,
                       use_drm=False, tfp_depth=0, seed=0,
                       use_accel_sampler=False, cache_fraction=0.2,
                       prefetch_windows=2)
    tr = HybridGNNTrainer(ds, _gnn(ds), cfg)
    tr.runtime.assignment.cpu_batch = 64
    tr.runtime.assignment.accel_batch = 64
    got = []
    tr.prefetcher.submit = lambda ids: got.append(np.asarray(ids))
    item = tr._stage_sample(tr._make_payload(0))
    parts = []
    for name, mb in item.payload["minibatch"].items():
        ids = np.unique(np.asarray(mb.frontier(2)))
        if name != "cpu":
            ids = ids[tr.cache.slot_of[ids] < 0]
        parts.append(ids)
    expect = np.unique(np.concatenate(parts))
    assert len(got) == 1
    assert np.array_equal(got[0], expect)
    # the CPU frontier's cached hubs are in the submission
    cpu_ids = np.unique(np.asarray(item.payload["minibatch"]["cpu"]
                                   .frontier(2)))
    cached_cpu = cpu_ids[tr.cache.slot_of[cpu_ids] >= 0]
    assert cached_cpu.size > 0 and np.isin(cached_cpu, got[0]).all()
    tr.close()


def test_overlap_drift_alone_triggers_mapping_reprice(tmp_path):
    """An underperforming prefetcher (measured overlap far from the
    priced one) must re-price Eq. 7 even when the cache hit rate sits
    rock-stable inside its drift threshold."""
    from repro.graph import LoadStats
    ds = make_dataset("ogbn-products", scale=0.002, seed=0,
                      feature_backend="mmap",
                      spill_dir=str(tmp_path / "spill"), partition_rows=512)
    cfg = HybridConfig(total_batch=256, n_accel=2, hybrid=True,
                       use_drm=False, tfp_depth=0, seed=0,
                       use_accel_sampler=False, cache_fraction=0.2,
                       prefetch_windows=2)
    tr = HybridGNNTrainer(ds, _gnn(ds), cfg)
    assert tr._model_prefetch_overlap == 1.0      # design-time estimate
    rb = tr.cache.row_bytes
    tr.loader.window.merge(LoadStats(
        rows=10, bytes=10 * rb, total_rows=1000, unique_rows=1000,
        hit_rows=500, saved_bytes=500 * rb))
    tr._model_hit_rate = tr.loader.window.hit_rate   # zero hit drift
    src = tr.loader.source
    src.prefetch_miss_windows = 100               # every touch missed
    assert tr._measured_prefetch_overlap() == 0.0
    assert tr._maybe_refresh_mapping()            # overlap drift alone
    assert tr._model_prefetch_overlap == 0.0      # re-priced + anchored
    assert not tr._maybe_refresh_mapping()        # drift consumed
    tr.close()


def test_close_raises_latched_background_errors(tmp_path):
    """A background failure that latches after the last chance to raise
    in-line (final staged gather, final prefetch) must surface from
    close(), once — not vanish."""
    ds = make_dataset("ogbn-products", scale=0.002, seed=0,
                      feature_backend="mmap",
                      spill_dir=str(tmp_path / "spill"), partition_rows=512)
    cfg = HybridConfig(total_batch=128, n_accel=2, hybrid=False,
                       use_drm=False, tfp_depth=0, seed=0,
                       use_accel_sampler=False, cache_fraction=0.2,
                       cache_refresh=True, async_refresh=True,
                       prefetch_windows=2, degrade_on_failure=False)
    tr = HybridGNNTrainer(ds, _gnn(ds), cfg)
    tr._refresh_error = RuntimeError("late stage failure")
    with pytest.raises(RuntimeError, match="async cache-refresh"):
        tr.close()
    tr.prefetcher.error = RuntimeError("late prefetch failure")
    with pytest.raises(RuntimeError, match="prefetch worker failed"):
        tr.close()
    tr.close()                  # both latches raised: now idempotent


# ------------------------------------------------- concurrency stress suite

def _stress_ds():
    # a fresh spill per trainer run: features are deterministic in the
    # seed, so separate instantiations are bit-identical, while each
    # trainer gets its OWN mmap window/LRU state — sharing one source
    # across the compared runs would let warm state leak between them
    return make_dataset("ogbn-products", scale=0.002, seed=0,
                        feature_backend="mmap", partition_rows=512)


def _stress_run(n_accel, depth, stressed, iters=3):
    ds = _stress_ds()
    cfg = HybridConfig(
        total_batch=96, n_accel=n_accel, hybrid=(n_accel == 0),
        use_drm=False, tfp_depth=depth, seed=0, use_accel_sampler=False,
        cache_fraction=0.2,
        cache_refresh=stressed, cache_drift_threshold=0.0,
        async_refresh=stressed,
        prefetch_windows=2 if stressed else 0,
        mmap_lru_windows=3 if stressed else 0)
    tr = HybridGNNTrainer(ds, _gnn(ds), cfg)
    tr.train(iters)
    losses = [m.loss for m in tr.history]
    tr.close()
    ds.features.close()
    return losses, tr


@pytest.mark.stress
@pytest.mark.parametrize("n_accel", [0, 1, 2])
@pytest.mark.parametrize("depth", [1, 2, 3])
def test_stress_interleavings_bit_identical(n_accel, depth):
    """The whole background-I/O subsystem racing the TFP pipeline —
    window prefetcher + LRU evictions + async staged refresh commits at
    iteration boundaries — must be bit-invisible: losses equal a vanilla
    (everything off, depth 2) run at every depth and trainer mix.  The
    baseline is depth-independent because payload generation is
    sequential and the DRM is off; comparing stressed depths 1-3 against
    it also pins that property."""
    base, _ = _stress_run(n_accel, depth=2, stressed=False)
    stressed, tr = _stress_run(n_accel, depth=depth, stressed=True)
    assert np.array_equal(base, stressed), (n_accel, depth)
    if n_accel > 0:
        io = tr.storage_io()
        assert io["prefetch_submitted"] > 0   # the race actually happened
        assert io["open_windows"] <= 3


@pytest.mark.stress
def test_mid_gather_eviction_never_corrupts_inflight_gather(tmp_path):
    """Hammer threads force LRU evictions (lru_windows=1) while reader
    threads gather large cross-window requests: an eviction mid-gather
    must only re-fault pages, never corrupt bytes."""
    dense, mm = _mmap_pair(tmp_path, name="spill-race", lru=1)
    rng = np.random.default_rng(7)
    rows = [rng.integers(0, N, 500).astype(np.int64) for _ in range(4)]
    truth = [dense.take(r).tobytes() for r in rows]
    stop = threading.Event()
    errors = []

    def hammer():
        i = 0
        while not stop.is_set():
            mm.take(np.array([(i * PROWS) % N], dtype=np.int64))
            i += 1

    def reader(idx):
        try:
            for _ in range(10):
                if mm.take(rows[idx]).tobytes() != truth[idx]:
                    errors.append(f"reader {idx} corrupted")
                    return
        except Exception as e:  # pragma: no cover - failure path
            errors.append(repr(e))

    threads = [threading.Thread(target=hammer) for _ in range(2)] + \
        [threading.Thread(target=reader, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads[2:]:
        t.join()
    stop.set()
    for t in threads[:2]:
        t.join()
    assert not errors, errors
    assert mm.window_evictions > 0        # the race actually evicted


@pytest.mark.stress
def test_staged_commit_between_load_and_transfer_bit_identical():
    """Force a staged-refresh commit() to land while TFP-prefetched
    batches sit between _stage_load and _stage_transfer (with the window
    prefetcher and LRU racing underneath): versioned lookups must keep
    losses bit-identical to an undisturbed run."""
    def run(force):
        ds = _stress_ds()
        cfg = HybridConfig(total_batch=96, n_accel=2, hybrid=False,
                           use_drm=False, tfp_depth=2, seed=0,
                           use_accel_sampler=False, cache_fraction=0.2,
                           prefetch_windows=2, mmap_lru_windows=3)
        tr = HybridGNNTrainer(ds, _gnn(ds), cfg)
        if force:
            orig = tr._stage_transfer
            fired = []

            def transfer(item):
                if not fired and item.payload["iteration"] == 2:
                    fired.append(True)
                    tr.cache.track_hotness = True
                    cold = np.flatnonzero(tr.cache.slot_of < 0)[:48]
                    for _ in range(6):
                        tr.cache.lookup(np.repeat(cold, 4))
                    assert tr.cache.stage() > 0
                    assert tr.cache.commit() > 0    # mid-flight commit
                    tr.loader.reset_window()
                return orig(item)

            tr._stage_transfer = transfer
        tr.train(6)
        losses = [m.loss for m in tr.history]
        ver = tr.cache.version
        tr.close()
        ds.features.close()
        return losses, ver

    base, _ = run(False)
    forced, ver = run(True)
    assert np.array_equal(base, forced)
    assert ver > 0
