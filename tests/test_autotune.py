"""Model-predictive knob autotuner: search, rollback, bounds, and
end-to-end loss bit-identity (ISSUE 10 tentpole)."""
import pytest

from repro.core import (Assignment, DRMEngine, KnobAutoTuner, KnobBounds,
                        KnobState, StageTimes)
from repro.core.perfmodel import (PLATFORMS, CalibratedKnobModel,
                                  SignalSnapshot)


def _engine():
    return DRMEngine(Assignment(cpu_batch=128, accel_batch=128, n_accel=1,
                                sample_frac_accel=0.0,
                                threads={"sample": 2, "load": 2,
                                         "train": 2}))


def _bounds():
    return KnobBounds(prefetch_windows=(0, 64), mmap_lru_windows=(1, 64),
                      min_stage_threads=1, total_threads=6,
                      refresh_period=(1, 16), refresh_frac=(0.05, 0.5))


def _times(scale=1.0):
    return StageTimes(t_sa=0.005 * scale, t_sc=0.01 * scale,
                      t_load=0.08 * scale, t_tran=0.004 * scale,
                      t_tc=0.03 * scale, t_ta=0.008 * scale,
                      t_load_stall=0.04 * scale)


def _fixed_model(ref: KnobState) -> CalibratedKnobModel:
    """One calibrated model, anchored once: a fixed objective over knob
    space, so greedy descent must be monotone."""
    sig = SignalSnapshot(t_sc=0.01, t_sa=0.005, t_load=0.08,
                         t_load_stall=0.04, t_tran=0.004, t_tc=0.03,
                         t_ta=0.008, dup_factor=1.5, hit_rate=0.6,
                         prefetch_hit_rate=0.0, prefetch_drop_rate=0.0,
                         touched_windows=16, loaded_rows_per_iter=1000,
                         refresh_bytes_per_iter=1e6,
                         hit_decay_per_iter=0.001, row_bytes=4,
                         disk_tier=True)
    return CalibratedKnobModel(host=PLATFORMS["epyc-7763"],
                               accel=PLATFORMS["tpu-v5e"],
                               ref=ref, signals=sig)


def test_predicted_time_non_increasing_across_accepted():
    """Convergence property: with a fixed predictor and no measured
    regressions, every accepted proposal's predicted iteration time is
    below its baseline by min_gain, and the accepted trajectory is
    non-increasing overall."""
    start = KnobState(prefetch_windows=0, mmap_lru_windows=1)
    model = _fixed_model(start)
    tuner = KnobAutoTuner(_engine(), _bounds(), interval=2,
                          warmup_windows=0, min_gain=0.02)
    current = start
    for _ in range(40):
        nxt = tuner.step(_times(), lambda mean, n: model, current)
        if nxt is not None:
            current = nxt
    assert tuner.accepted, "fixed beatable model must yield accepted moves"
    assert tuner.rollbacks == 0  # constant measured walls: nothing regresses
    preds = [tuner.accepted[0].baseline_predicted] + \
        [t.predicted for t in tuner.accepted]
    for a, b in zip(preds, preds[1:]):
        assert b <= a * (1.0 - tuner.min_gain) + 1e-12, \
            f"accepted move raised predicted time {a} -> {b}"
    # converged: at the final state the search finds nothing else
    prop = tuner.engine.propose_knobs(model, current, tuner.bounds,
                                      min_gain=tuner.min_gain)
    assert prop is None


def test_rejected_proposal_rolls_back_exactly():
    """A trial whose measured window regresses past the hysteresis band
    returns the EXACT pre-move knob state, and the move is vetoed."""
    start = KnobState(prefetch_windows=0, mmap_lru_windows=1)
    model = _fixed_model(start)
    tuner = KnobAutoTuner(_engine(), _bounds(), interval=1,
                          warmup_windows=0, hysteresis=0.10)
    # window 1: propose
    prop = tuner.step(_times(), lambda mean, n: model, start)
    assert prop is not None and prop != start
    # window 2 measures 3x slower: rollback must return `start` exactly
    back = tuner.step(_times(scale=3.0), lambda mean, n: model, prop)
    assert back == start
    assert tuner.rollbacks == 1 and not tuner.accepted
    rolled_move = [m for ev, m in tuner.log if ev == "rollback"][0]
    assert rolled_move in tuner.report()["vetoed"], \
        "rolled-back move must be vetoed"
    # the vetoed move is not re-proposed while the veto holds
    nxt = tuner.step(_times(), lambda mean, n: model, start)
    if nxt is not None:
        assert tuner._trial.move != rolled_move


class _HostileModel:
    """Adversarial predictor: rewards the most extreme knob state it can
    see (negative pseudo-times, monotone in every knob), trying to drag
    the search out of bounds."""

    def predict(self, k: KnobState) -> float:
        return -(k.prefetch_windows * 1e6 + k.mmap_lru_windows * 1e3
                 + k.load_threads * 1e2 + k.refresh_period
                 + k.refresh_frac)


def test_knob_bounds_respected_under_hostile_predictor():
    bounds = _bounds()
    tuner = KnobAutoTuner(_engine(), bounds, interval=1, warmup_windows=0)
    current = KnobState(prefetch_windows=0, mmap_lru_windows=1)
    total0 = current.total_threads
    for _ in range(60):
        nxt = tuner.step(_times(), lambda mean, n: _HostileModel(), current)
        if nxt is not None:
            current = nxt
        lo, hi = bounds.prefetch_windows
        assert lo <= current.prefetch_windows <= hi
        lo, hi = bounds.mmap_lru_windows
        assert lo <= current.mmap_lru_windows <= hi
        lo, hi = bounds.refresh_period
        assert lo <= current.refresh_period <= hi
        lo, hi = bounds.refresh_frac
        assert lo <= current.refresh_frac <= hi
        assert current.total_threads == total0
        assert min(current.sample_threads, current.load_threads,
                   current.train_threads) >= bounds.min_stage_threads
    # the hostile model drove every geometric knob to its ceiling —
    # and no further
    assert current.prefetch_windows == bounds.prefetch_windows[1]
    assert current.mmap_lru_windows == bounds.mmap_lru_windows[1]


@pytest.mark.parametrize("n_accel", [0, 1, 2])
def test_losses_bit_identical_autotune_on_off(n_accel, tmp_path):
    """Knob moves never touch RNG streams or batch composition: the
    autotuner-on run's losses equal the static-knob twin bit-for-bit at
    every accelerator count (0 = CPU-only hybrid)."""
    from repro.core import HybridConfig, HybridGNNTrainer
    from repro.graph import GNNConfig, make_dataset

    def run(auto):
        ds = make_dataset("ogbn-papers100M", scale=2e-4, seed=0,
                          feature_backend="mmap", partition_rows=2048,
                          spill_dir=str(tmp_path / f"spill-{auto}"),
                          mmap_lru_windows=1)
        gnn = GNNConfig(fanouts=(3, 3), layer_dims=ds.layer_dims,
                        model="sage")
        cfg = HybridConfig(total_batch=128, n_accel=n_accel,
                           hybrid=(n_accel == 0), use_drm=False,
                           tfp_depth=2, seed=0, mmap_lru_windows=1,
                           initial_threads=(4, 1, 1), auto_tune=auto,
                           autotune_interval=2, autotune_warmup_windows=0)
        tr = HybridGNNTrainer(ds, gnn, cfg)
        hist = tr.train(8)
        rep = tr.autotune_report()
        tr.close()
        return [m.loss for m in hist], rep

    on, rep_on = run(True)
    off, rep_off = run(False)
    assert on == off, f"autotune on/off losses diverged at n_accel={n_accel}"
    assert rep_on["enabled"] and not rep_off["enabled"]
