"""System-level behaviour checks crossing module boundaries."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (ARCHS, SHAPES, all_cells, cell_applicable,
                           get_arch, input_specs)
from repro.models import init_decode_cache, init_params


def test_cell_matrix_counts():
    cells = list(all_cells())
    assert len(cells) == 40  # 10 archs x 4 shapes
    runnable = [c for c in cells if c[3]]
    skipped = [c for c in cells if not c[3]]
    assert len(runnable) == 33
    # exactly the sub-quadratic archs run long_500k
    long_ok = {c[0] for c in runnable if c[2].name == "long_500k"}
    assert long_ok == {"mixtral-8x22b", "zamba2-7b", "rwkv6-1.6b"}
    for _, _, shape, _, reason in skipped:
        assert shape.name == "long_500k" and "sub-quadratic" in reason


def test_input_specs_shapes():
    for name in ARCHS:
        cfg = get_arch(name)
        for shape in SHAPES.values():
            ok, _ = cell_applicable(cfg, shape)
            if not ok:
                continue
            spec = input_specs(cfg, shape)
            if shape.step == "decode":
                assert spec["tokens"].shape == (shape.global_batch, 1)
            else:
                total = sum(v.shape[1] for k, v in spec.items()
                            if k in ("tokens", "embeds", "vision_embeds"))
                assert total == shape.seq_len, (name, shape.name)


def test_decode_cache_abstract_sizes():
    """Cache pytrees build abstractly (no allocation) for every decode
    cell, and SWA caches are capped at the window size."""
    for name in ARCHS:
        cfg = get_arch(name)
        shape = SHAPES["decode_32k"]
        cache = jax.eval_shape(
            lambda: init_decode_cache(cfg, shape.global_batch,
                                      shape.seq_len))
        leaves = jax.tree.leaves(cache)
        assert leaves, name
        if cfg.window:
            kv = cache["attn"].k
            assert kv.shape[-3] == min(cfg.window, shape.seq_len)


def test_reduced_configs_are_small():
    for name in ARCHS:
        red = get_arch(name, reduced=True)
        p = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), red))
        n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(p))
        assert n < 20e6, f"{name} reduced config too large ({n/1e6:.1f}M)"
