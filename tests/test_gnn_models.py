"""GNN model paths: dense / segsum / pallas / pallas_fused agree in value
AND gradient; GCN degree normalization; compression roundtrips."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.graph import (GNNConfig, NumpySampler, init_params, loss_fn,
                         make_dataset)
from repro.optim import CompressionSpec, compress_grads, decompress_grads

IMPLS = ["dense", "segsum", "pallas", "pallas_fused"]


@pytest.fixture(scope="module")
def setup():
    ds = make_dataset("ogbn-products", scale=0.002, seed=0)
    s = NumpySampler(ds.graph, fanouts=(5, 3), seed=1)
    t = np.arange(32)
    mb = s.sample(t, ds.labels[t])
    x0 = jnp.asarray(ds.take_features(np.asarray(mb.frontier(2))))
    return ds, mb, x0


@pytest.mark.parametrize("model", ["sage", "gcn"])
def test_agg_impls_agree(setup, model):
    ds, mb, x0 = setup
    results = {}
    for impl in IMPLS:
        cfg = GNNConfig(model=model, layer_dims=(100, 64, 47),
                        fanouts=(5, 3), agg_impl=impl)
        p = init_params(jax.random.PRNGKey(0), cfg)
        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            p, cfg, mb, x0)
        results[impl] = (float(loss), grads)
    base_loss, base_grads = results["dense"]
    for impl in IMPLS[1:]:
        loss, grads = results[impl]
        assert abs(loss - base_loss) < 1e-4, (model, impl)
        for a, b in zip(jax.tree.leaves(base_grads), jax.tree.leaves(grads)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=1e-5)


def test_compression_roundtrip_error_bounds():
    key = jax.random.PRNGKey(0)
    g = {"w": jax.random.normal(key, (64, 32)),
         "b": jax.random.normal(jax.random.fold_in(key, 1), (32,)) * 10}
    for method, tol in [("bf16", 2e-2), ("int8", 2e-1)]:
        spec = CompressionSpec(method)
        comp = compress_grads(g, spec)
        back = decompress_grads(comp, spec, g)
        for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(back)):
            err = float(jnp.abs(a - b).max() / (jnp.abs(a).max() + 1e-9))
            assert err < tol, (method, err)
    assert CompressionSpec("int8").ratio == 0.25
    assert CompressionSpec("none").ratio == 1.0


def test_pspec_degrades_without_mesh():
    from repro.dist import pspec
    from jax.sharding import PartitionSpec as P
    assert pspec("data", None, "model") == P(None, None, None)


def test_param_pspec_rules():
    from repro.dist.sharding import param_pspec
    from jax.sharding import PartitionSpec as P
    import jax.tree_util as jtu

    class Leaf:
        def __init__(self, ndim):
            self.ndim = ndim

    def path(*keys):
        return tuple(jtu.DictKey(k) for k in keys)

    # without a mesh the specs degrade to fully-replicated (None) —
    # the rule table itself is exercised in the dry-run
    assert param_pspec(path("embed"), Leaf(2)) == P(None, None)
    assert param_pspec(path("layers", "ln1"), Leaf(2)) == P(None, None)
