"""Two-stage prefetch pipeline: pipelined == sequential, order preserved,
stage times recorded, errors propagate."""
import time

import pytest

from repro.core import PipelineItem, PrefetchPipeline, Stage


def _items(n):
    return (PipelineItem(seq=i, payload=i) for i in range(n))


def _stages():
    return [Stage("sample", lambda it: _apply(it, lambda x: x * 2)),
            Stage("load", lambda it: _apply(it, lambda x: x + 1)),
            Stage("transfer", lambda it: _apply(it, lambda x: x * 10))]


def _apply(item, fn):
    item.payload = fn(item.payload)
    return item


@pytest.mark.parametrize("depth", [0, 1, 2, 4])
def test_pipeline_results_match_sequential(depth):
    pipe = PrefetchPipeline(_stages(), depth=depth)
    out = [(it.seq, it.payload) for it in pipe.run(_items(20))]
    assert out == [(i, (i * 2 + 1) * 10) for i in range(20)]


def test_stage_timings_recorded():
    pipe = PrefetchPipeline(_stages(), depth=2)
    names = {"sample", "load", "transfer"}
    for it in pipe.run(_items(3)):
        # service time per stage plus the queue-wait (starvation) stall
        assert set(it.timings) == names | {n + "_wait" for n in names}
        assert all(t >= 0 for t in it.timings.values())


def test_sequential_mode_records_no_waits():
    pipe = PrefetchPipeline(_stages(), depth=0)
    for it in pipe.run(_items(3)):
        assert set(it.timings) == {"sample", "load", "transfer"}


def test_pipeline_overlaps_stages():
    """With depth>=1 total wall time < sum of all stage times (overlap).

    Stages sleep, releasing the GIL, so even this 1-core container
    overlaps them — exactly the paper's claim that Feature Loading and
    Data Transfer use different resources concurrently.
    """
    def slow(name, dt):
        def fn(item):
            time.sleep(dt)
            return item
        return Stage(name, fn)

    stages = [slow("a", 0.02), slow("b", 0.02), slow("c", 0.02)]
    n = 10
    t0 = time.perf_counter()
    list(PrefetchPipeline(stages, depth=2).run(_items(n)))
    t_pipe = time.perf_counter() - t0
    t0 = time.perf_counter()
    list(PrefetchPipeline(stages, depth=0).run(_items(n)))
    t_seq = time.perf_counter() - t0
    assert t_pipe < 0.75 * t_seq, (t_pipe, t_seq)


def test_error_propagates():
    def boom(item):
        if item.seq == 3:
            raise ValueError("boom")
        return item

    pipe = PrefetchPipeline([Stage("s", boom)], depth=2)
    with pytest.raises(ValueError, match="boom"):
        list(pipe.run(_items(10)))


@pytest.mark.parametrize("depth", [0, 2])
def test_error_state_cleared_between_runs(depth):
    """A reused pipeline must not re-raise a stale exception on a clean
    run (regression: _error survived across run() calls)."""
    arm = {"on": True}

    def maybe_boom(item):
        if arm["on"] and item.seq == 1:
            raise ValueError("boom")
        return item

    pipe = PrefetchPipeline([Stage("s", maybe_boom)], depth=depth)
    with pytest.raises(ValueError, match="boom"):
        list(pipe.run(_items(5)))
    arm["on"] = False
    out = [it.seq for it in pipe.run(_items(5))]
    assert out == list(range(5))


def test_feeder_stops_consuming_payloads_after_failure():
    """After a stage fails, the feeder must stop draining the payload
    generator (regression: payload side effects — e.g. the trainer's
    epoch cursor — kept advancing for batches that were silently
    dropped)."""
    produced = []

    def gen(n):
        for i in range(n):
            produced.append(i)
            yield PipelineItem(seq=i, payload=i)

    def boom(item):
        if item.seq == 1:
            raise ValueError("boom")
        time.sleep(0.002)
        return item

    pipe = PrefetchPipeline([Stage("s", boom)], depth=2)
    with pytest.raises(ValueError, match="boom"):
        list(pipe.run(gen(200)))
    # a few in-flight payloads may slip through (queue depth + one in
    # hand), but nothing close to the full generator
    assert len(produced) < 50, f"feeder drained {len(produced)} payloads"
