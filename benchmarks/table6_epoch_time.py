"""Table VI / Fig. 10 reproduction: epoch-time comparison against the
published numbers of PaGraph, P^3 and DistDGLv2 (copied from the paper's
Table VI), with our system's epoch time projected through the performance
model on the paper's platform (2×EPYC 7763 + 4×U250), using each
baseline's own model configuration (sample size, hidden dim) as Table V
prescribes.  The paper's own measured numbers are also listed so the
projection can be sanity-checked against what the authors report.
"""
from __future__ import annotations

from repro.core import PLATFORMS, WorkloadSpec, predict, predict_epoch_time
from repro.graph import DATASET_STATS

from .common import emit

# published epoch times (s) from paper Table VI
PUBLISHED = {
    # system: {(dataset, model): epoch_s}
    "pagraph": {("ogbn-products", "gcn"): 1.18,
                ("ogbn-products", "sage"): 0.25,
                ("ogbn-papers100M", "gcn"): 4.00,
                ("ogbn-papers100M", "sage"): 1.18},
    "p3": {("ogbn-products", "gcn"): 1.11,
           ("ogbn-products", "sage"): 1.23,
           ("ogbn-papers100M", "gcn"): 2.61,
           ("ogbn-papers100M", "sage"): 3.11},
    "distdglv2": {("ogbn-products", "sage"): 0.30,
                  ("ogbn-papers100M", "sage"): 4.16},
}
# the paper's own measured epoch times for This-Work (CPU-FPGA, 4xU250)
PAPER_THIS_WORK = {
    "pagraph": {("ogbn-products", "gcn"): 0.27,
                ("ogbn-products", "sage"): 0.49,
                ("ogbn-papers100M", "gcn"): 0.58,
                ("ogbn-papers100M", "sage"): 1.91},
    "p3": {("ogbn-products", "gcn"): 0.27,
           ("ogbn-products", "sage"): 0.28,
           ("ogbn-papers100M", "gcn"): 0.57,
           ("ogbn-papers100M", "sage"): 0.59},
    "distdglv2": {("ogbn-products", "sage"): 1.69,
                  ("ogbn-papers100M", "sage"): 3.67},
}
# per-baseline model config (Table V): (fanouts, hidden)
BASELINE_CFG = {
    "pagraph": ((25, 10), 256),
    "p3": ((25, 10), 32),
    "distdglv2": ((15, 10, 5), 256),
}


def _project_ours(dataset: str, model: str, fanouts, hidden) -> float:
    from repro.graph.storage import TRAIN_SPLIT
    host = PLATFORMS["epyc-7763"]
    fpga = PLATFORMS["alveo-u250"]
    nv, ne, f0, _, f2, _ = DATASET_STATS[dataset]
    dims = (f0,) + (hidden,) * (len(fanouts) - 1) + (f2,)
    total_batch = 1024 * (4 + 1)
    w_cpu = WorkloadSpec(1024, fanouts, dims, model=model)
    w_acc = WorkloadSpec(1024, fanouts, dims, model=model)
    samp = 1024 * sum(w_cpu.edges_per_layer()) / 5e7  # calibrated CPU rate
    pred = predict(host, fpga, 4, w_cpu, w_acc, t_samp=samp / 1024)
    # an epoch iterates the OGB train split (paper setup), not all nodes
    return predict_epoch_time(TRAIN_SPLIT[dataset], total_batch, pred)


def run() -> None:
    import numpy as np
    for system, rows in PUBLISHED.items():
        fanouts, hidden = BASELINE_CFG[system]
        speedups = []
        for (dataset, model), their_s in rows.items():
            ours_s = _project_ours(dataset, model, fanouts, hidden)
            paper_s = PAPER_THIS_WORK[system][(dataset, model)]
            speedups.append(their_s / ours_s)
            emit(f"table6/{system}/{dataset}-{model}", ours_s * 1e6,
                 f"published={their_s}s paper_this_work={paper_s}s "
                 f"speedup={their_s/ours_s:.2f}x")
        geo = float(np.exp(np.mean(np.log(speedups))))
        emit(f"table6/{system}/geomean-speedup", 0.0, f"{geo:.2f}x")


if __name__ == "__main__":
    run()
