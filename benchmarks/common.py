"""Shared benchmark utilities: container calibration + CSV emission."""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.perfmodel import PlatformSpec

_ROWS: List[Tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    _ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived}")


def rows():
    return list(_ROWS)


def calibrate_container(seed: int = 0) -> PlatformSpec:
    """Measure THIS container's effective matmul FLOP/s and memory
    bandwidth so the performance model (Eqs. 7-13) can be validated
    against wall-clock measurements (Fig. 8 reproduction)."""
    # matmul throughput at GNN-layer-like (tall-skinny) shapes
    rng = np.random.default_rng(seed)
    m, k, n = 16384, 256, 256
    a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    f = jax.jit(lambda x, y: x @ y)
    f(a, w).block_until_ready()
    t0 = time.perf_counter()
    reps = 8
    for _ in range(reps):
        out = f(a, w)
    out.block_until_ready()
    dt = (time.perf_counter() - t0) / reps
    flops = 2 * m * k * n / dt

    # host memory bandwidth under feature-loader-like gathers
    table = rng.normal(size=(1 << 20, 64)).astype(np.float32)  # 256 MB
    idx = rng.integers(0, table.shape[0], 1 << 18)
    np.take(table, idx, axis=0)
    t0 = time.perf_counter()
    for _ in range(3):
        got = np.take(table, idx, axis=0)
    dt = (time.perf_counter() - t0) / 3
    bw = 2 * got.nbytes / dt  # read + write

    return PlatformSpec(
        name="container-cpu", peak_tflops=flops / 1e12,
        mem_bw_gbps=bw / 1e9, interconnect_gbps=bw / 1e9 / 4,
        onchip_mb=32.0, mac_parallelism=max(int(flops / 2 / 2.45e9), 1),
        freq_ghz=2.45, pipelined_agg_update=False)
