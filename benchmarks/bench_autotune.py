"""Closed-DRM-loop gate: model-predictive knob auto-tuning recovers a
deliberately misconfigured run.

Three end-to-end trainer runs on the disk (mmap) feature tier, identical
RNG seeds and batch composition throughout:

  hand        — hand-tuned knobs (prefetch queue, window LRU, balanced
                stage threads), autotuner OFF: the target steady state;
  bad-static  — knob-misconfigured start (no prefetch windows, a
                one-window LRU, stage threads skewed away from the load
                bottleneck), autotuner OFF: what the misconfiguration
                costs when nothing fixes it;
  bad-auto    — the SAME misconfigured start with the autotuner ON: the
                DRM's knob search must walk the knobs back toward the
                hand-tuned point from measured signals alone.

Gates (tier-1, --smoke):
  * convergence: bad-auto's steady-state iteration time (trimmed mean of
    the last third, after the tuner had its windows) is within 15% of
    hand's steady state;
  * bit-identity: bad-auto and bad-static losses are bit-identical — the
    knob moves never touch RNG streams or batch composition;
  * liveness: the tuner accepted at least one proposal (the convergence
    gate must not pass by the misconfiguration being cheap).

Writes BENCH_autotune.json at the repo root (smoke included — smoke is
the only mode CI runs).
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

from repro.core import HybridConfig, HybridGNNTrainer
from repro.graph import GNNConfig, make_dataset

from .common import emit

DATASET = "ogbn-papers100M"

HAND = dict(prefetch_windows=4, mmap_lru_windows=8,
            initial_threads=(2, 2, 2))
BAD = dict(prefetch_windows=0, mmap_lru_windows=1,
           initial_threads=(4, 1, 1))


def _run_one(label: str, scale: float, iters: int, batch: int,
             partition_rows: int, spill_dir: str, knobs: dict,
             auto: bool, interval: int) -> dict:
    """One trainer run; fresh dataset per run (same seed -> same graph,
    features and labels) so page-cache state never leaks across runs."""
    ds = make_dataset(DATASET, scale=scale, seed=0, feature_backend="mmap",
                      partition_rows=partition_rows, spill_dir=spill_dir,
                      mmap_lru_windows=knobs["mmap_lru_windows"])
    gnn = GNNConfig(fanouts=(5, 5), layer_dims=ds.layer_dims, model="sage")
    cfg = HybridConfig(total_batch=batch, n_accel=1, hybrid=False,
                       use_drm=False, tfp_depth=2, seed=0,
                       prefetch_windows=knobs["prefetch_windows"],
                       mmap_lru_windows=knobs["mmap_lru_windows"],
                       initial_threads=knobs["initial_threads"],
                       auto_tune=auto, autotune_interval=interval,
                       autotune_warmup_windows=1)
    tr = HybridGNNTrainer(ds, gnn, cfg)
    t0 = time.perf_counter()
    hist = tr.train(iters)
    wall = time.perf_counter() - t0
    report = tr.autotune_report()
    io = tr.storage_io()
    tr.close()
    times = [m.iter_time for m in hist]
    tail = sorted(times[-max(len(times) // 3, 3):])
    steady = float(np.mean(tail[:-1] or tail))  # trim the worst outlier
    emit(f"autotune,{label}", steady * 1e6,
         f"iters={iters} accepted={report.get('accepted', 0)} "
         f"rollbacks={report.get('rollbacks', 0)}")
    return {"label": label, "steady_iter_s": steady, "wall_s": wall,
            "iter_times_s": times,
            "losses": [float(m.loss) for m in hist],
            "load_stall_s": io["load_stall_seconds"],
            "autotune": report}


def run(scale: float = 1e-3, iters: int = 36, batch: int = 192,
        partition_rows: int = 2048, interval: int = 2,
        out_path: str = "BENCH_autotune.json") -> dict:
    res = {"dataset": DATASET, "scale": scale, "iters": iters,
           "batch": batch, "partition_rows": partition_rows,
           "hand_knobs": {k: list(v) if isinstance(v, tuple) else v
                          for k, v in HAND.items()},
           "bad_knobs": {k: list(v) if isinstance(v, tuple) else v
                         for k, v in BAD.items()},
           "runs": {}}
    with tempfile.TemporaryDirectory(prefix="bench-autotune-") as td:
        for label, knobs, auto in (("hand", HAND, False),
                                   ("bad_static", BAD, False),
                                   ("bad_auto", BAD, True)):
            res["runs"][label] = _run_one(
                label, scale, iters, batch, partition_rows,
                os.path.join(td, label), knobs, auto, interval)
    hand = res["runs"]["hand"]["steady_iter_s"]
    auto = res["runs"]["bad_auto"]["steady_iter_s"]
    static = res["runs"]["bad_static"]["steady_iter_s"]
    res["steady_ratio_auto_vs_hand"] = auto / hand
    res["steady_ratio_static_vs_hand"] = static / hand
    res["loss_bit_identical"] = (res["runs"]["bad_auto"]["losses"]
                                 == res["runs"]["bad_static"]["losses"])
    res["accepted_moves"] = res["runs"]["bad_auto"]["autotune"].get(
        "accepted", 0)
    emit("autotune,ratio_auto_vs_hand", 0.0,
         f"{res['steady_ratio_auto_vs_hand']:.3f} "
         f"(static {res['steady_ratio_static_vs_hand']:.3f})")
    if out_path:
        with open(out_path, "w") as fh:
            json.dump(res, fh, indent=2)
        emit("autotune,written", 0.0, os.path.abspath(out_path))
    return res


def _asserts(res: dict, ratio_max: float = 1.15) -> None:
    # convergence gate: the misconfigured start, tuned online, lands
    # within 15% of the hand-tuned steady state
    ratio = res["steady_ratio_auto_vs_hand"]
    assert ratio <= ratio_max, \
        (f"autotuned steady-state {ratio:.3f}x of hand-tuned "
         f"(> {ratio_max}); static misconfig was "
         f"{res['steady_ratio_static_vs_hand']:.3f}x")
    # bit-identity gate: knob moves never touch RNG/batch composition
    assert res["loss_bit_identical"], \
        "autotuner-on losses diverged from the static-knob twin"
    # liveness gate: convergence must come from the tuner doing work,
    # not from the misconfiguration being cheap at this scale
    assert res["accepted_moves"] >= 1, \
        "autotuner accepted no proposals on a misconfigured start"


def run_smoke() -> dict:
    """Tier-1 gate (~90 s): the 3-run sweep at test scale with all three
    gates (convergence within 15%, loss bit-identity, >= 1 accepted
    move).  Writes BENCH_autotune.json."""
    res = run()
    _asserts(res)
    return res


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tier-1 gates at test scale (scripts/tier1.sh)")
    ap.add_argument("--scale", type=float, default=3e-3)
    ap.add_argument("--iters", type=int, default=60)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.smoke:
        run_smoke()
    else:
        res = run(scale=args.scale, iters=args.iters)
        _asserts(res)
