"""Fig. 11 reproduction: impact of each optimization, MEASURED on this
container with the real system (not the model):

  baseline      accel-only task mapping (CPU only samples/loads)
  +hybrid       CPU trainer joins with a static perf-model mapping
  +DRM          dynamic resource management fine-tunes shares/threads
  +TFP          two-stage feature prefetching overlaps the stages

Paper result: cumulative speedups up to 1.13x / 1.33x / 1.79x.  On a
1-core container the hybrid win is muted (the "CPU" and "accelerator"
trainers share one core) but TFP and DRM still show: the pipeline
overlaps stage latencies (threads release the GIL inside XLA/numpy) and
DRM re-balances shares.
"""
from __future__ import annotations

import numpy as np

from repro.core import HybridConfig, HybridGNNTrainer
from repro.graph import GNNConfig, make_dataset

from .common import emit

MODES = [
    ("baseline", dict(hybrid=False, use_drm=False, tfp_depth=0)),
    ("hybrid", dict(hybrid=True, use_drm=False, tfp_depth=0)),
    ("hybrid+drm", dict(hybrid=True, use_drm=True, tfp_depth=0)),
    ("hybrid+drm+tfp", dict(hybrid=True, use_drm=True, tfp_depth=2)),
]


def run(scale: float = 0.003, iters: int = 34, model: str = "sage") -> None:
    ds = make_dataset("ogbn-products", scale=scale, seed=0)
    gcfg = GNNConfig(model=model, layer_dims=ds.layer_dims, fanouts=(10, 5),
                     num_classes=ds.num_classes)
    base_time = None
    for name, kw in MODES:
        # share_quantum=128 bounds the number of distinct mini-batch
        # shapes the DRM can create, so jit recompiles settle quickly
        hcfg = HybridConfig(total_batch=512, n_accel=2, seed=0,
                            use_accel_sampler=False, share_quantum=128,
                            **kw)
        tr = HybridGNNTrainer(ds, gcfg, hcfg)
        tr.train(iters)
        # measure the steady state: DRM share changes early in the run
        # trigger jit recompiles (an XLA artifact the paper's CUDA/HLS
        # trainers don't have); by ~iter 20 the shape set is warm
        t = tr.mean_iter_time(skip=24)
        rate = tr.mean_mteps(skip=24)
        if base_time is None:
            base_time = t
        emit(f"fig11/measured-1core/{name}", t * 1e6,
             f"MTEPS={rate:.2f} speedup={base_time/t:.2f}x "
             f"(1-core container: hybrid/DRM/TFP need parallel resources; "
             f"see projected rows)")


def run_projected() -> None:
    """Fig. 11 on the paper's platform (2xEPYC + 4xU250) via Eqs. 5-13.

    The optimizations map onto the model exactly:
      baseline     accel-only shares, stages run sequentially (Σ stages)
      +hybrid      perf-model static CPU share, still sequential
      +DRM         best share assignment (fine-tuned), still sequential
      +TFP         stages overlap: T = max(stages)  — Eq. 6
    """
    from repro.core import PLATFORMS, WorkloadSpec, predict
    from repro.core.perfmodel import initial_task_mapping
    host, fpga = PLATFORMS["epyc-7763"], PLATFORMS["alveo-u250"]
    for dataset, dims in [("ogbn-products", (100, 256, 47)),
                          ("ogbn-papers100M", (128, 256, 172))]:
        total = 1024 * 5
        samp = total * 285 / 5e7 / 1024

        def stages(cpu_share, accel_each):
            w_c = WorkloadSpec(cpu_share, (25, 10), dims, model="sage")
            w_a = WorkloadSpec(accel_each, (25, 10), dims, model="sage")
            p = predict(host, fpga, 4, w_c, w_a, t_samp=samp)
            return [p.t_samp, p.t_load, p.t_trans, p.t_prop]

        base = sum(stages(0, total // 4))
        static = initial_task_mapping(host, fpga, 4, total, (25, 10), dims,
                                      model="sage")
        hyb = sum(stages(static["cpu"], static["accel_each"]))
        # DRM: fine-tune the share by search (the engine's fixed point)
        best = min(sum(stages(c, (total - c) // 4))
                   for c in range(0, total // 2, total // 64))
        tfp = min(max(stages(c, (total - c) // 4))
                  for c in range(0, total // 2, total // 64))
        for name, t in [("baseline", base), ("hybrid", hyb),
                        ("hybrid+drm", best), ("hybrid+drm+tfp", tfp)]:
            emit(f"fig11/projected-{dataset}/{name}", t * 1e6,
                 f"speedup={base/t:.2f}x")


if __name__ == "__main__":
    run()
    run_projected()
