"""Table VII reproduction: epoch time normalized by platform peak TFLOPS
(sec × TFLOPS), the paper's hardware-efficiency metric.  Platform peaks
from Table V setups; ours = 2×3.6 (EPYC) + 4×0.6 (U250) = 9.6 TFLOPS.
"""
from __future__ import annotations

import numpy as np

from .common import emit
from .table6_epoch_time import BASELINE_CFG, PUBLISHED, _project_ours

PLATFORM_TFLOPS = {
    # Table V platforms (fp32 peaks)
    "pagraph": 2 * 3.8 + 8 * 15.7,       # 2x Xeon 8163 + 8x V100
    "p3": 4 * (0.6 + 4 * 9.3),           # 4 nodes x (Xeon E5 + 4x P100)
    "distdglv2": 8 * (3.0 + 8 * 8.1),    # 8 nodes x (96 vCPU + 8x T4)
    "ours": 2 * 3.6 + 4 * 0.6,
}


def run() -> None:
    for system, rows in PUBLISHED.items():
        fanouts, hidden = BASELINE_CFG[system]
        speedups = []
        for (dataset, model), their_s in rows.items():
            ours_s = _project_ours(dataset, model, fanouts, hidden)
            theirs_norm = their_s * PLATFORM_TFLOPS[system]
            ours_norm = ours_s * PLATFORM_TFLOPS["ours"]
            speedups.append(theirs_norm / ours_norm)
            emit(f"table7/{system}/{dataset}-{model}", ours_norm * 1e6,
                 f"ours={ours_norm:.1f} theirs={theirs_norm:.1f} "
                 f"sxTFLOPS speedup={theirs_norm/ours_norm:.1f}x")
        geo = float(np.exp(np.mean(np.log(speedups))))
        emit(f"table7/{system}/geomean-normalized-speedup", 0.0,
             f"{geo:.1f}x")


if __name__ == "__main__":
    run()
