"""Fig. 8 reproduction: performance-model prediction vs measured epoch
(iteration) time.  The paper reports 5-14% average error on its hardware;
we calibrate the model's platform constants to THIS container (measured
matmul FLOP/s + memory bandwidth) and compare predicted vs measured
per-iteration time of the real hybrid trainer.
"""
from __future__ import annotations

import numpy as np

from repro.core import (HybridConfig, HybridGNNTrainer, StageTimes,
                        WorkloadSpec, predict)
from repro.graph import GNNConfig, make_dataset

from .common import calibrate_container, emit


def run(scale: float = 0.003, iters: int = 8) -> None:
    host = calibrate_container()
    for model in ("gcn", "sage"):
        ds = make_dataset("ogbn-products", scale=scale, seed=0)
        gcfg = GNNConfig(model=model, layer_dims=ds.layer_dims,
                         fanouts=(10, 5), num_classes=ds.num_classes)
        hcfg = HybridConfig(total_batch=512, n_accel=1, hybrid=True,
                            use_drm=False, tfp_depth=0, seed=0,
                            use_accel_sampler=False)
        tr = HybridGNNTrainer(ds, gcfg, hcfg)
        hist = tr.train(iters)
        meas = hist[2:]  # skip compile iterations
        t_meas = float(np.mean([m.iter_time for m in meas]))
        t_load_meas = float(np.mean([m.times.t_load for m in meas]))
        t_prop_meas = float(np.mean([max(m.times.t_tc, m.times.t_ta)
                                     for m in meas]))

        cpu_b, accel_b = tr.runtime.quantized_shares()
        w_cpu = WorkloadSpec(cpu_b, gcfg.fanouts, gcfg.layer_dims,
                             model=model)
        w_acc = WorkloadSpec(accel_b, gcfg.fanouts, gcfg.layer_dims,
                             model=model)
        t_samp = float(np.mean([m.times.t_sc for m in meas]))
        pred = predict(host, host, 1, w_cpu, w_acc, t_samp=t_samp)

        err_iter = abs(pred.t_execution - t_meas) / t_meas * 100
        err_load = (abs(pred.t_load - t_load_meas)
                    / max(t_load_meas, 1e-9) * 100)
        err_prop = (abs(pred.t_prop - t_prop_meas)
                    / max(t_prop_meas, 1e-9) * 100)
        emit(f"fig8/{model}-iter-time-measured", t_meas * 1e6,
             f"pred={pred.t_execution*1e6:.0f}us err={err_iter:.1f}%")
        emit(f"fig8/{model}-load-stage", t_load_meas * 1e6,
             f"pred={pred.t_load*1e6:.0f}us err={err_load:.1f}%")
        emit(f"fig8/{model}-prop-stage", t_prop_meas * 1e6,
             f"pred={pred.t_prop*1e6:.0f}us err={err_prop:.1f}%")


if __name__ == "__main__":
    run()
