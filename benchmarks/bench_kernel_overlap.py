"""Kernel-level DMA/compute overlap microbench (pipelined Pallas kernels).

PRs 1-5 minimized host traffic, so the per-iteration latency left sits
inside the feature kernels themselves: the single-buffered combine
kernel serializes four aligned block DMAs before each 128-row tile's
one-hot MXU expansion, and the scatter-update kernel issues one row DMA
per admitted node.  The multi-buffered variants (paper §IV's prefetch
argument applied at the VMEM level) hold ``depth`` tile windows in
scratch and issue tile i+1's slab copy while tile i computes.

This bench sweeps pipeline depth × tile size × feature width for both
kernels, gates every depth>1 result bit-identical to the depth=1 kernel
AND the jnp oracle (f32 and bf16), measures wall time (best-of-reps)
and achieved read bandwidth against the container's calibrated memory
roofline, and writes ``BENCH_kernel_overlap.json``.

``--smoke`` is the tier-1 gate (~60 s): a small sweep asserting
  * depth-2/4 outputs bit-identical to depth-1 and the oracle (incl.
    bf16 and aliased update slots),
  * depth>1 wall time no worse than depth=1 (interpret mode runs one
    Python step per grid point, so the pipelined kernels' smaller grid
    and single-slab DMAs are faster here too; a small tolerance absorbs
    scheduler noise),
  * VMEM scratch for the target window fits the budget at depth 4,
  * end-to-end trainer losses bit-identical with the pipelined kernels
    enabled (combine + refresh scatter both exercised).

Interpret-mode wall numbers are a functional proxy (each grid step runs
in Python); the roofline fraction column is what a real-TPU run of the
same sweep would be judged against.

Usage:  PYTHONPATH=src python -m benchmarks.bench_kernel_overlap [--smoke]
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.gather_scatter_mm import (
    VMEM_SCRATCH_BUDGET_BYTES, cache_combine_pipelined_kernel_call,
    cache_combine_tiled_kernel_call, cache_update_kernel_call,
    cache_update_pipelined_kernel_call)

from .common import calibrate_container, emit

DEPTHS = (1, 2, 4)
# wall-clock tolerance for the smoke's no-worse gate: interpret mode
# schedules Python per grid step, so single runs jitter; measured, the
# pipelined kernels are ~3-4x FASTER here (one slab DMA replaces four
# BlockSpec block reads), leaving this margin far from the decision edge
SMOKE_WALL_TOLERANCE = 1.25


def _best_of(f, reps: int) -> float:
    f().block_until_ready()                   # compile / warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        f().block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def _combine_schedule(n: int, t_n: int, dup: float = 0.75):
    """Monotone dense-rank schedule over ``H = dup*n`` distinct source
    rows — the shape ops._assemble_tiled produces after sorting positions
    by rank.  ``rank[i] = i*H//n`` keeps every tile's rank span <= t_n+1,
    so the 4-block window invariant holds by construction."""
    h = max(int(n * dup), 1)
    ranks = (np.arange(n, dtype=np.int64) * h // n).astype(np.int32)
    tiles = ranks.reshape(n // t_n, t_n)
    base = (tiles[:, 0] // t_n).astype(np.int32)
    local = (tiles - base[:, None] * t_n).astype(np.int32)
    return base, local, h


def bench_combine(n: int, f: int, t_n: int, t_f: int, depth: int,
                  dtype, reps: int, want: np.ndarray = None) -> dict:
    rng = np.random.default_rng(n * 7 + f)
    base, local, h = _combine_schedule(n, t_n)
    src = jnp.asarray(rng.normal(size=(h + 4 * t_n, f)),
                      jnp.float32).astype(dtype)
    if depth > 1:
        call = jax.jit(lambda: cache_combine_pipelined_kernel_call(
            src, base, local, t_n=t_n, t_f=t_f, depth=depth, interpret=True))
        scratch = depth * 4 * t_n * t_f * src.dtype.itemsize
    else:
        call = jax.jit(lambda: cache_combine_tiled_kernel_call(
            src, base, local, t_n=t_n, t_f=t_f, interpret=True))
        scratch = 4 * t_n * t_f * src.dtype.itemsize
    out = np.asarray(call().astype(jnp.float32))
    # jnp oracle: the schedule IS the gather — out[i] = src[rank-row of i]
    oracle = np.asarray(jnp.take(
        src, jnp.asarray(base[:, None] * t_n + local).reshape(-1), axis=0
        ).astype(jnp.float32))
    dt = _best_of(call, reps)
    nf = f // t_f
    read = (n // t_n) * nf * 4 * t_n * t_f * src.dtype.itemsize
    write = n * f * src.dtype.itemsize
    return {
        "kernel": "combine", "depth": depth, "n": n, "f": f,
        "t_n": t_n, "t_f": t_f, "dtype": np.dtype(dtype).name,
        "us": dt * 1e6, "read_bytes": read, "write_bytes": write,
        "achieved_gbps": (read + write) / dt / 1e9,
        "vmem_scratch_bytes": scratch,
        "bit_identical_vs_oracle": bool(np.array_equal(out, oracle)),
        "bit_identical_vs_depth1": (bool(np.array_equal(out, want))
                                    if want is not None else None),
        "_out": out,
    }


def bench_update(k: int, f: int, m: int, t_f: int, depth: int, dtype,
                 reps: int, aliased: bool = False,
                 want: np.ndarray = None) -> dict:
    rng = np.random.default_rng(k * 13 + m)
    cache = jnp.asarray(rng.normal(size=(k, f)), jnp.float32).astype(dtype)
    rows = jnp.asarray(rng.normal(size=(m, f)), jnp.float32).astype(dtype)
    if aliased:
        slots_np = rng.integers(0, k, m).astype(np.int32)
    else:
        slots_np = rng.permutation(k)[:m].astype(np.int32)
    if depth > 1:
        # the pipelined kernel's write DMAs are concurrent: destinations
        # must be unique, so compact aliased slots keep-last on the host
        # (exactly what ops.update_cache_rows does) — parity then holds
        # against the sequential kernel bit-for-bit
        _, first_in_rev = np.unique(slots_np[::-1], return_index=True)
        keep = np.sort(slots_np.shape[0] - 1 - first_in_rev)
        rows_k, slots_k = rows[keep], jnp.asarray(slots_np[keep])
        b = 8
        mp = -(-rows_k.shape[0] // b) * b
        rows_k = jnp.pad(rows_k, ((0, mp - rows_k.shape[0]), (0, 0)))
        call = jax.jit(lambda: cache_update_pipelined_kernel_call(
            cache, rows_k, slots_k, t_f=t_f, depth=depth, row_block=b,
            interpret=True))
        scratch = depth * b * t_f * cache.dtype.itemsize
    else:
        call = jax.jit(lambda: cache_update_kernel_call(
            cache, rows, jnp.asarray(slots_np), t_f=t_f, interpret=True))
        scratch = t_f * cache.dtype.itemsize
    out = np.asarray(call().astype(jnp.float32))
    oracle = np.array(cache.astype(jnp.float32))    # writable copy
    for i in range(m):                      # sequential last-writer-wins
        oracle[slots_np[i]] = np.asarray(rows[i].astype(jnp.float32))
    dt = _best_of(call, reps)
    moved = 2 * m * f * cache.dtype.itemsize          # rows in + rows out
    return {
        "kernel": "update", "depth": depth, "k": k, "f": f, "m": m,
        "t_f": t_f, "dtype": np.dtype(dtype).name, "aliased": aliased,
        "us": dt * 1e6, "moved_bytes": moved,
        "achieved_gbps": moved / dt / 1e9,
        "vmem_scratch_bytes": scratch,
        "bit_identical_vs_oracle": bool(np.array_equal(out, oracle)),
        "bit_identical_vs_depth1": (bool(np.array_equal(out, want))
                                    if want is not None else None),
        "_out": out,
    }


def e2e_bit_identity(depths=(1, 2), scale: float = 1e-3, iters: int = 3,
                     batch: int = 128) -> dict:
    """Trainer losses across kernel_pipeline_depth values with the Pallas
    combine + refresh scatter forced on: the pipeline depth is a pure
    scheduling knob, so losses must be bit-identical."""
    from repro.core import HybridConfig, HybridGNNTrainer
    from repro.graph import GNNConfig, make_dataset

    losses = {}
    g = None
    for depth in depths:
        ds = make_dataset("ogbn-papers100M", scale=scale, seed=0)
        if g is None:
            g = GNNConfig(model="sage", layer_dims=ds.layer_dims,
                          fanouts=(10, 5), num_classes=ds.num_classes)
        cfg = HybridConfig(total_batch=batch, n_accel=2, hybrid=False,
                           use_drm=False, tfp_depth=2, seed=0,
                           cache_fraction=0.2, cache_assemble="pallas",
                           cache_refresh=True, cache_drift_threshold=0.0,
                           kernel_pipeline_depth=depth)
        tr = HybridGNNTrainer(ds, g, cfg)
        tr.train(iters)
        losses[depth] = [m.loss for m in tr.history]
        tr.close()
    base = losses[depths[0]]
    identical = all(np.array_equal(base, v) for v in losses.values())
    emit("kernel_overlap,e2e_bit_identity", 0.0,
         f"depths={list(depths)} identical={identical} last={base[-1]:.4f}")
    return {"e2e_depths": list(depths),
            "e2e_loss_bit_identical": identical,
            "e2e_losses": {str(k): v for k, v in losses.items()}}


def run(combine_sweep=None, update_sweep=None, depths=DEPTHS,
        dtypes=(jnp.float32, jnp.bfloat16), reps: int = 3,
        e2e_depths=(1, 2, 4), e2e_iters: int = 3,
        out_path: str = "BENCH_kernel_overlap.json") -> dict:
    if combine_sweep is None:
        # (n, f, t_n, t_f): tile size x feature width
        combine_sweep = [(1024, 128, 128, 128), (1024, 256, 128, 128),
                         (1024, 256, 256, 128), (2048, 64, 128, 64),
                         (1024, 128, 128, 64)]
    if update_sweep is None:
        # (k, f, m, t_f, aliased) — m sized like a real refresh commit
        # (up to cache_refresh_frac of the slots), where the multi-row
        # block DMAs amortize; single-row updates stay on depth 1
        update_sweep = [(1024, 128, 256, 128, False),
                        (512, 128, 128, 128, True),
                        (512, 64, 96, 64, True)]
    spec = calibrate_container()
    results = {"roofline_mem_gbps": spec.mem_bw_gbps,
               "vmem_budget_bytes": VMEM_SCRATCH_BUDGET_BYTES,
               "combine": [], "update": []}
    for (n, f, t_n, t_f) in combine_sweep:
        for dtype in dtypes:
            want = None
            for depth in depths:
                r = bench_combine(n, f, t_n, t_f, depth, dtype, reps,
                                  want=want)
                if depth == 1:
                    want = r.pop("_out")
                else:
                    r.pop("_out")
                r["roofline_fraction"] = r["achieved_gbps"] / spec.mem_bw_gbps
                results["combine"].append(r)
                emit(f"kernel_overlap,combine,d{depth},n{n},f{f},"
                     f"t{t_n}x{t_f},{r['dtype']}", r["us"],
                     f"{r['achieved_gbps']:.2f}GB/s "
                     f"roof={r['roofline_fraction']:.3f} "
                     f"oracle={r['bit_identical_vs_oracle']} "
                     f"d1={r['bit_identical_vs_depth1']}")
    for (k, f, m, t_f, aliased) in update_sweep:
        for dtype in dtypes:
            want = None
            for depth in depths:
                r = bench_update(k, f, m, t_f, depth, dtype, reps,
                                 aliased=aliased, want=want)
                if depth == 1:
                    want = r.pop("_out")
                else:
                    r.pop("_out")
                r["roofline_fraction"] = r["achieved_gbps"] / spec.mem_bw_gbps
                results["update"].append(r)
                emit(f"kernel_overlap,update,d{depth},k{k},f{f},m{m},"
                     f"{r['dtype']}{',aliased' if aliased else ''}",
                     r["us"],
                     f"{r['achieved_gbps']:.2f}GB/s "
                     f"oracle={r['bit_identical_vs_oracle']} "
                     f"d1={r['bit_identical_vs_depth1']}")
    results.update(e2e_bit_identity(depths=e2e_depths, iters=e2e_iters))
    if out_path:
        with open(out_path, "w") as fh:
            json.dump(results, fh, indent=2)
        emit("kernel_overlap,written", 0.0, os.path.abspath(out_path))
    return results


def _asserts(res: dict) -> None:
    rows = res["combine"] + res["update"]
    for r in rows:
        assert r["bit_identical_vs_oracle"], f"oracle mismatch: {r}"
        if r["depth"] > 1:
            assert r["bit_identical_vs_depth1"], f"depth-1 mismatch: {r}"
        # the satellite VMEM assertion: every swept config's scratch fits
        assert r["vmem_scratch_bytes"] <= res["vmem_budget_bytes"], r
    # no-worse wall gate per config: best pipelined depth vs depth 1
    for kind in ("combine", "update"):
        by_cfg = {}
        for r in res[kind]:
            key = tuple((k, v) for k, v in sorted(r.items())
                        if k in ("n", "f", "k", "m", "t_n", "t_f", "dtype",
                                 "aliased"))
            by_cfg.setdefault(key, {})[r["depth"]] = r["us"]
        for key, us in by_cfg.items():
            if 1 not in us or len(us) < 2:
                continue
            best_piped = min(v for d, v in us.items() if d > 1)
            assert best_piped <= us[1] * SMOKE_WALL_TOLERANCE, \
                (f"{kind} {key}: pipelined {best_piped:.1f}us worse than "
                 f"single-buffered {us[1]:.1f}us")
    assert res["e2e_loss_bit_identical"], \
        "kernel_pipeline_depth changed trainer losses"


def run_smoke() -> dict:
    """Tier-1 gate (~60 s): small sweep — depth>1 bit-identical to
    depth=1 and the jnp oracle (f32 + bf16, aliased slots), scratch
    within the VMEM budget, pipelined wall time no worse than
    single-buffered (interpret-mode CPU), and e2e trainer losses
    bit-identical across depths."""
    res = run(combine_sweep=[(512, 128, 128, 128), (512, 64, 128, 64)],
              update_sweep=[(512, 128, 128, 128, False),
                            (512, 64, 96, 64, True)],
              depths=(1, 2, 4), reps=3, e2e_depths=(1, 2), e2e_iters=3,
              out_path="")
    _asserts(res)
    return res


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small assert-only sweep (scripts/tier1.sh)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.smoke:
        run_smoke()
    else:
        res = run()
        _asserts(res)
