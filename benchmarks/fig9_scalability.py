"""Fig. 9 reproduction: scalability of the hybrid system to 1..16
accelerators, projected with the performance model on the paper's
CPU-FPGA platform (dual EPYC 7763 + Alveo U250s, Table II constants).

Expected qualitative result (paper Section VI-D): near-linear scaling to
~12 accelerators, then the CPU memory bandwidth (Feature Loading, Eq. 7)
saturates; GCN/ogbn-products saturates earliest (Data-Transfer-bound).
"""
from __future__ import annotations

from repro.core import PLATFORMS, WorkloadSpec, mteps, predict
from repro.graph import DATASET_STATS

from .common import emit

CASES = [
    ("gcn", "ogbn-products", (100, 256, 47)),
    ("sage", "ogbn-products", (100, 256, 47)),
    ("gcn", "ogbn-papers100M", (128, 256, 172)),
    ("sage", "ogbn-papers100M", (128, 256, 172)),
    ("sage", "mag240m-homo", (756, 256, 153)),
]


def run() -> None:
    host = PLATFORMS["epyc-7763"]
    fpga = PLATFORMS["alveo-u250"]
    for model, dataset, dims in CASES:
        base = None
        saturation = None
        for n_accel in (1, 2, 4, 8, 12, 16):
            batch_each = 1024 // 1  # 1024 per trainer, paper setup
            w_cpu = WorkloadSpec(256, (25, 10), dims, model=model)
            w_acc = WorkloadSpec(batch_each, (25, 10), dims, model=model)
            pred = predict(host, fpga, n_accel, w_cpu, w_acc,
                           t_samp=0.8 * pred_samp(dims))
            edges = (w_cpu.total_edges()
                     + n_accel * w_acc.total_edges())
            rate = mteps(edges, pred.t_execution)
            if base is None:
                base = rate
            speedup = rate / base
            if saturation is None and n_accel > 1:
                ideal = n_accel * 0.75
                if speedup < ideal:
                    saturation = n_accel
            emit(f"fig9/{model}-{dataset}-n{n_accel}",
                 pred.t_execution * 1e6,
                 f"MTEPS={rate:.0f} speedup={speedup:.2f}x "
                 f"bound={_bound(pred)}")


def pred_samp(dims) -> float:
    # sampling calibrated at design time; use a fixed per-edge cost
    return 1024 * (25 + 26 * 10) * 2e-8


def _bound(pred) -> str:
    stages = {"samp": pred.t_samp, "load": pred.t_load,
              "trans": pred.t_trans, "prop": pred.t_prop}
    return max(stages, key=stages.get)


if __name__ == "__main__":
    run()
