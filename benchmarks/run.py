# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows (see benchmarks/common.py).
#
#   fig8   performance-model validation (predicted vs measured)
#   fig9   scalability projection to 16 accelerators
#   fig10  cross-platform epoch time (PyG baseline vs hybrid CPU-GPU/FPGA)
#   table6 epoch-time comparison vs PaGraph / P^3 / DistDGLv2
#   table7 TFLOPS-normalized epoch-time comparison
#   fig11  optimization ablation (baseline/+hybrid/+DRM/+TFP), measured
#   cache  device feature-cache ablation (fraction x dataset), measured
#   cache_refresh  static vs dynamic cache policy on a drifting-hub trace
#   outofcore  dense/partitioned/mmap gather throughput + resident set
#   roofline  per-(arch x shape x mesh) terms from the dry-run JSON
def main() -> None:
    print("name,us_per_call,derived")
    from . import (bench_outofcore, fig8_perfmodel, fig9_scalability,
                   fig10_crossplatform, fig11_ablation, fig_cache_ablation,
                   roofline, table6_epoch_time, table7_normalized)
    fig8_perfmodel.run()
    fig9_scalability.run()
    fig10_crossplatform.run()
    table6_epoch_time.run()
    table7_normalized.run()
    fig11_ablation.run()
    fig11_ablation.run_projected()
    fig_cache_ablation.run()
    fig_cache_ablation.run_refresh_sweep()
    bench_outofcore.run()
    roofline.run()

if __name__ == '__main__':
    main()
