"""Sharded hot-feature plane vs replicated cache: shipped-bytes sweep.

For each (n_accel, placement) cell this runs the real pipelined trainer
twice at EQUAL per-device cache capacity — once with the legacy
replicated cache (every accelerator pins the same top-K rows, every
trainer dedups and ships its own misses) and once with the sharded plane
(disjoint per-device shards, peer rows over the accelerator
interconnect, one union gather multicast to the devices that need each
row) — and reports:

  * host->device PCIe bytes shipped and the sharded/replicated
    reduction factor (the headline: the union gather collapses the n
    per-trainer gathers into one, and peer shards absorb misses the
    replicated cache would ship),
  * ICI bytes (peer row hops + multicast fan-out copies) — the traffic
    the sharded plane *moves* onto the fast device fabric rather than
    eliminates,
  * effective capacity (resident rows across the plane) at the same
    per-device byte budget,
  * loss bit-identity: sharding only changes where bytes travel, never
    the assembled feature values.

Results go to ``BENCH_shard.json``.  The tier-1 smoke gates that (a) at
n_accel >= 2 the union gather ships strictly fewer bytes than the
replicated per-trainer dedup path, (b) sharded and replicated losses
are bit-identical, and (c) the n_accel=4 cell clears the >= 1.5x
shipped-byte reduction the acceptance criteria name.

Usage:  PYTHONPATH=src python -m benchmarks.bench_shard [--smoke]
        (both modes write BENCH_shard.json)
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.core import HybridConfig, HybridGNNTrainer
from repro.graph import GNNConfig, make_dataset

from .common import emit

N_ACCELS = (2, 4)
PLACEMENTS = ("hash", "degree")
FRACTION = 0.05            # per-device budget, identical in both planes


def _gcfg(ds) -> GNNConfig:
    return GNNConfig(model="sage", layer_dims=ds.layer_dims,
                     fanouts=(10, 5), num_classes=ds.num_classes)


def _trainer(ds, gcfg, n_accel: int, iters: int,
             **kw) -> HybridGNNTrainer:
    hcfg = HybridConfig(total_batch=64 * n_accel, n_accel=n_accel,
                        hybrid=False, use_drm=False, tfp_depth=2, seed=0,
                        use_accel_sampler=False, cache_fraction=FRACTION,
                        **kw)
    tr = HybridGNNTrainer(ds, gcfg, hcfg)
    tr.train(iters)
    tr.close()
    return tr


def run(scale: float = 0.002, iters: int = 6, n_accels=N_ACCELS,
        placements=PLACEMENTS, dataset: str = "ogbn-products",
        out_path: str = "BENCH_shard.json") -> dict:
    ds = make_dataset(dataset, scale=scale, seed=0)
    gcfg = _gcfg(ds)
    results: dict = {"dataset": dataset, "scale": scale,
                     "fraction_per_device": FRACTION, "cells": []}
    for n_accel in n_accels:
        rep = _trainer(ds, gcfg, n_accel, iters)
        rep_tf = rep.feature_traffic()
        rep_losses = [m.loss for m in rep.history]
        rep_capacity = rep.cache.capacity if rep.cache else 0
        for placement in placements:
            sh = _trainer(ds, gcfg, n_accel, iters,
                          cache_sharding="sharded",
                          shard_placement=placement)
            tf = sh.feature_traffic()
            losses = [m.loss for m in sh.history]
            cell = {
                "n_accel": n_accel, "placement": placement,
                "replicated_shipped_bytes": rep_tf["shipped_bytes"],
                "sharded_shipped_bytes": tf["shipped_bytes"],
                "shipped_reduction":
                    rep_tf["shipped_bytes"] / max(tf["shipped_bytes"], 1.0),
                "union_saved_bytes": tf["union_saved_bytes"],
                "peer_saved_bytes": tf["peer_saved_bytes"],
                "ici_bytes": tf["ici_bytes"],
                "hit_rate_replicated": rep_tf["hit_rate"],
                "hit_rate_sharded": tf["hit_rate"],
                # same per-device budget, n x the resident rows
                "effective_rows_replicated": rep_capacity,
                "effective_rows_sharded":
                    sh.cache.capacity if sh.cache else 0,
                "t_iter_replicated": rep.mean_iter_time(skip=2),
                "t_iter_sharded": sh.mean_iter_time(skip=2),
                "loss_bit_identical":
                    bool(np.array_equal(losses, rep_losses)),
            }
            results["cells"].append(cell)
            emit(f"shard_plane,{dataset},n={n_accel},{placement}",
                 cell["t_iter_sharded"] * 1e6,
                 f"shipped={tf['shipped_bytes']/1e6:.1f}MB "
                 f"(repl {rep_tf['shipped_bytes']/1e6:.1f}MB, "
                 f"{cell['shipped_reduction']:.2f}x) "
                 f"ici={tf['ici_bytes']/1e6:.1f}MB "
                 f"hit={tf['hit_rate']:.3f} "
                 f"loss_ok={cell['loss_bit_identical']}")
    with open(out_path, "w") as fh:
        json.dump(results, fh, indent=2)
    emit("shard_plane,written", 0.0, os.path.abspath(out_path))
    return results


def _shard_asserts(res: dict) -> None:
    cells = res["cells"]
    assert cells, "empty sweep"
    # sharding must never change training semantics
    assert all(c["loss_bit_identical"] for c in cells), \
        "a sharded cell's losses diverged from the replicated plane"
    for c in cells:
        # the union gather must ship strictly fewer PCIe bytes than the
        # replicated plane's n independent per-trainer dedup gathers
        assert c["sharded_shipped_bytes"] < c["replicated_shipped_bytes"], \
            (f"n={c['n_accel']} {c['placement']}: union gather shipped "
             f"{c['sharded_shipped_bytes']:.0f} >= replicated "
             f"{c['replicated_shipped_bytes']:.0f}")
        # its savings must actually come from the union/peer machinery
        assert c["union_saved_bytes"] + c["peer_saved_bytes"] > 0
        # n x effective capacity at the same per-device budget
        assert c["effective_rows_sharded"] > c["effective_rows_replicated"]
    best_at_4 = max((c["shipped_reduction"] for c in cells
                     if c["n_accel"] == 4), default=None)
    if best_at_4 is not None:
        # the acceptance gate: >= 1.5x fewer host->device bytes at 4
        # accelerators vs the replicated cache at equal per-device budget
        assert best_at_4 >= 1.5, \
            f"n_accel=4 shipped-byte reduction {best_at_4:.2f}x < 1.5x"


def run_smoke() -> dict:
    """~60 s tier-1 gate: the n_accel=2 strict-reduction + bit-identity
    invariants plus the n_accel=4 >= 1.5x acceptance cell, at small
    scale (hash placement only — degree runs in the full sweep)."""
    res = run(scale=0.001, iters=4, n_accels=(2, 4),
              placements=("hash",))
    _shard_asserts(res)
    return res


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="~60s sharded-plane gate (used by "
                         "scripts/tier1.sh)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.smoke:
        run_smoke()
    else:
        res = run()
        _shard_asserts(res)
