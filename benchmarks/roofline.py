"""Roofline table (deliverable g): read the dry-run results JSON produced
by ``python -m repro.launch.dryrun --out results.json`` and print the
per-(arch × shape × mesh) roofline terms + bottleneck.
"""
from __future__ import annotations

import json
import os
import sys

from .common import emit

DEFAULT = os.path.join(os.path.dirname(__file__), "..",
                       "dryrun_optimized_single.json")


def run(path: str = DEFAULT) -> None:
    if not os.path.exists(path):
        emit("roofline/missing", 0.0,
             f"run `python -m repro.launch.dryrun --out {path}` first")
        return
    with open(path) as f:
        results = json.load(f)
    for r in results:
        if r.get("status") != "ok":
            continue
        roof = r["roofline"]
        mesh = "x".join(str(m) for m in r["mesh"])
        name = f"roofline/{r['arch']}/{r['shape']}/{mesh}"
        emit(name, roof["t_compute_s"] * 1e6,
             f"mem={roof['t_memory_s']*1e6:.0f}us "
             f"coll={roof['t_collective_s']*1e6:.0f}us "
             f"bottleneck={roof['bottleneck']} "
             f"frac={roof['roofline_fraction']:.3f} "
             f"mb={r.get('microbatches', 1)} "
             f"fits={r.get('fits_16gb')}")


if __name__ == "__main__":
    run(sys.argv[1] if len(sys.argv) > 1 else DEFAULT)
