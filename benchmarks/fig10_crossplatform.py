"""Fig. 10 reproduction: cross-platform epoch time — multi-GPU PyG
baseline vs hybrid CPU-GPU vs hybrid CPU-FPGA, projected with the
performance model on the paper's platforms (Table II).

Paper's result: hybrid CPU-GPU up to 2.08x over the PyG multi-GPU
baseline; CPU-FPGA a further 5-6x over CPU-GPU (customized datapath keeps
intermediates on-chip — in the model this is the ⊕=max pipelined Trainer,
Eq. 10, plus the FPGA's effective memory behaviour).
"""
from __future__ import annotations

from repro.core import PLATFORMS, WorkloadSpec, predict, predict_epoch_time
from repro.graph.storage import TRAIN_SPLIT

from .common import emit

CASES = [("ogbn-products", (100, 256, 47)),
         ("ogbn-papers100M", (128, 256, 172)),
         ("mag240m-homo", (756, 256, 153))]


def run(model: str = "sage") -> None:
    host = PLATFORMS["epyc-7763"]
    gpu = PLATFORMS["rtx-a5000"]
    fpga = PLATFORMS["alveo-u250"]
    for dataset, dims in CASES:
        total = 1024 * 5
        samp = 285 * 1024 / 5e7

        def epoch(accel, cpu_share, tfp=True):
            n_accel = 4
            accel_each = (total - cpu_share) // n_accel
            w_c = WorkloadSpec(cpu_share, (25, 10), dims, model=model)
            w_a = WorkloadSpec(accel_each, (25, 10), dims, model=model)
            p = predict(host, accel, n_accel, w_c, w_a, t_samp=samp)
            t = (p.t_execution if tfp
                 else p.t_samp + p.t_load + p.t_trans + p.t_prop)
            iters = -(-TRAIN_SPLIT[dataset] // total)
            return iters * t

        pyg = epoch(gpu, 0, tfp=False)          # accel-only, no overlap
        cpu_gpu = epoch(gpu, total // 5)        # hybrid + TFP
        cpu_fpga = epoch(fpga, total // 5)
        emit(f"fig10/{dataset}/pyg-4gpu-baseline", pyg * 1e6, "1.00x")
        emit(f"fig10/{dataset}/hybrid-cpu-gpu", cpu_gpu * 1e6,
             f"{pyg/cpu_gpu:.2f}x vs baseline")
        emit(f"fig10/{dataset}/hybrid-cpu-fpga", cpu_fpga * 1e6,
             f"{pyg/cpu_fpga:.2f}x vs baseline, "
             f"{cpu_gpu/cpu_fpga:.2f}x vs CPU-GPU")


if __name__ == "__main__":
    run()
