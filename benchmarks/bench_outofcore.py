"""Out-of-core FeatureSource benchmark: dense vs partitioned vs mmap.

For papers100M (scaled) this measures, per backend:

  * gather throughput over sampled-frontier unique ids (rows/s and GB/s —
    the Feature Loader's host-side workload),
  * the resident-set ceiling: bytes of feature storage that must sit in
    host RAM at once.  The RAM backends hold the whole O(N*F) matrix; the
    mmap backend needs only the current gather's touched pages plus the
    spill writer's one-partition buffer — O(touched partitions), which is
    what lets a MAG240M-sized matrix (202 GB) train on a small host,

plus the spill writer's peak buffered rows (the bounded-RAM guarantee:
never more than one partition) and an end-to-end loss bit-identity check
of mmap-backed vs dense-backed training at the same seed.

Writes BENCH_outofcore.json.  ``--smoke`` is the tier-1 gate: a small-
scale run in a temp dir (cleaned up on exit) asserting dense/mmap gather
parity, the one-partition spill bound, a bounded gather working set, and
e2e loss bit-identity.

The background-I/O sweep (``run_prefetch`` / ``--smoke-prefetch``,
writes BENCH_prefetch.json) measures the *load-stage stall* on the disk
tier with the window prefetcher off vs on: each sampled frontier is
handed to the ``WindowPrefetcher`` one step ahead of its gather (the
lookahead the TFP sample stage provides in the real pipeline), so with
prefetch on the gather's cold-fault bytes/seconds collapse to ~0 while
the window LRU keeps page-cache residency under
``lru_windows × window_bytes``.  Gates: prefetch-on stall strictly below
prefetch-off, residency bounded, and trainer losses bit-identical across
the {prefetch on/off} × {async_refresh on/off} matrix.

Usage:  PYTHONPATH=src python -m benchmarks.bench_outofcore
            [--smoke] [--smoke-prefetch]
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

from repro.core import HybridConfig, HybridGNNTrainer
from repro.graph import (GNNConfig, MmapFeatures, NumpySampler,
                         WindowPrefetcher, make_dataset)

from .common import emit

DATASET = "ogbn-papers100M"
FANOUTS = (10, 5)


def _frontiers(ds, iters: int, batch: int, seed: int = 1):
    """Unique ids of ``iters`` sampled frontiers (the deduped transfer
    path's gather requests — one row per unique id)."""
    sampler = NumpySampler(ds.graph, FANOUTS, seed=seed)
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(iters):
        tgt = rng.integers(0, ds.num_nodes, batch)
        mb = sampler.sample(tgt, ds.labels[tgt])
        out.append(np.unique(np.asarray(mb.frontier(len(FANOUTS)))))
    return out


def bench_backend(backend: str, scale: float, iters: int, batch: int,
                  partition_rows: int, spill_dir=None) -> dict:
    ds = make_dataset(DATASET, scale=scale, seed=0,
                      feature_backend=backend,
                      partition_rows=partition_rows, spill_dir=spill_dir)
    src = ds.feature_source
    full_bytes = ds.num_nodes * ds.feat_dim * 4
    frontiers = _frontiers(ds, iters, batch)
    src.take(frontiers[0][:64])            # warm the take path
    if isinstance(src, MmapFeatures):
        src.reset_touch_stats()
    rows = nbytes = 0
    peak_gather_pages = 0
    t0 = time.perf_counter()
    for f in frontiers:
        x = src.take(f)
        rows += x.shape[0]
        nbytes += x.nbytes
        if isinstance(src, MmapFeatures):
            peak_gather_pages = max(peak_gather_pages,
                                    src.last_gather_page_bytes)
    dt = time.perf_counter() - t0
    res = {
        "backend": backend,
        "gather_rows_per_s": rows / dt,
        "gather_gbps": nbytes / dt / 1e9,
        "gathered_rows": rows,
        "full_matrix_bytes": full_bytes,
    }
    if isinstance(src, MmapFeatures):
        # ceiling = one gather's faulted pages + the spill writer's single
        # partition buffer (pages from previous gathers are evictable)
        spill_buf = partition_rows * ds.feat_dim * 4
        res.update({
            "resident_bytes": peak_gather_pages + spill_buf,
            "peak_gather_page_bytes": peak_gather_pages,
            "spill_buffer_bytes": spill_buf,
            "spill_peak_buffered_rows": src.spill_peak_buffered_rows,
            "cumulative_touched_page_bytes": src.touched_page_bytes,
            "mapped_window_bytes": src.resident_window_bytes,
        })
    else:
        # RAM backends hold the whole matrix for the run's lifetime
        res["resident_bytes"] = full_bytes
    emit(f"outofcore,{backend},scale={scale:g}", dt / iters * 1e6,
         f"{res['gather_rows_per_s']/1e6:.2f}Mrows/s "
         f"resident={res['resident_bytes']/1e6:.1f}MB "
         f"(full {full_bytes/1e6:.1f}MB)")
    return res


def e2e_bit_identity(scale: float, iters: int, batch: int,
                     partition_rows: int, spill_dir=None) -> dict:
    """Train dense-backed and mmap-backed runs at the same seed; the
    backend is purely a capacity knob, so losses must be bit-identical."""
    g = None
    losses = {}
    for backend in ("dense", "mmap"):
        kw = (dict(spill_dir=spill_dir, partition_rows=partition_rows)
              if backend == "mmap" else {})
        ds = make_dataset(DATASET, scale=scale, seed=0,
                          feature_backend=backend, **kw)
        if g is None:
            g = GNNConfig(model="sage", layer_dims=ds.layer_dims,
                          fanouts=FANOUTS, num_classes=ds.num_classes)
        cfg = HybridConfig(total_batch=batch, n_accel=2, hybrid=False,
                           use_drm=False, tfp_depth=2, seed=0)
        tr = HybridGNNTrainer(ds, g, cfg)
        tr.train(iters)
        losses[backend] = [m.loss for m in tr.history]
        tr.loader.close()
    identical = bool(np.array_equal(losses["dense"], losses["mmap"]))
    emit("outofcore,e2e_bit_identity", 0.0,
         f"identical={identical} last={losses['mmap'][-1]:.4f}")
    return {"e2e_loss_bit_identical": identical,
            "losses_mmap": losses["mmap"]}


def _band_rows(num_nodes: int, iters: int, rows_per_iter: int, bands: int,
               partition_rows: int, seed: int = 1):
    """Per-iteration gather requests from a *rotating locality band* of
    ``bands`` contiguous partitions: iteration i's working set fits the
    window LRU but drifts across iterations (the access pattern a
    bounded page cache + lookahead prefetcher serve — think
    locality-reordered features or region-batched sampling; a uniform
    frontier over the whole id space touches every partition at once and
    no O(lru) page cache can help it, prefetched or not)."""
    rng = np.random.default_rng(seed)
    num_parts = -(-num_nodes // partition_rows)
    out = []
    for i in range(iters):
        p0 = (i * bands) % max(num_parts - bands + 1, 1)
        lo = p0 * partition_rows
        hi = min((p0 + bands) * partition_rows, num_nodes)
        out.append(np.unique(rng.integers(lo, hi, rows_per_iter)))
    return out


def bench_prefetch_mode(prefetch: bool, scale: float, iters: int,
                        batch: int, partition_rows: int, lru_windows: int,
                        spill_dir: str) -> dict:
    """Drive ``iters`` banded gathers over a fresh spill with the window
    prefetcher off/on and account the load-stage stall (cold page-fault
    bytes/seconds the gather paid itself).

    With prefetch on, request i is submitted and drained *before* its
    gather — the deterministic stand-in for the real pipeline's overlap,
    where the sample stage submits batch i+1 while batch i gathers (the
    wall-clock overlap itself is exercised by the e2e matrix below)."""
    ds = make_dataset(DATASET, scale=scale, seed=0, feature_backend="mmap",
                      partition_rows=partition_rows, spill_dir=spill_dir,
                      mmap_lru_windows=lru_windows)
    src = ds.feature_source
    src.drop_page_cache()            # the spill just wrote (= warmed) them
    frontiers = _band_rows(ds.num_nodes, iters, rows_per_iter=batch * 40,
                           bands=max(lru_windows - 1, 1),
                           partition_rows=partition_rows)
    pf = WindowPrefetcher(src, max_queue=4) if prefetch else None
    peak_open = 0
    t0 = time.perf_counter()
    for f in frontiers:
        if pf is not None:
            pf.submit(f)
            assert pf.wait_idle(60.0), "prefetch worker wedged"
        src.take(f)
        peak_open = max(peak_open, src.open_windows)
    dt = time.perf_counter() - t0
    if pf is not None:
        pf.close()
    res = {
        "prefetch": prefetch,
        "lru_windows": lru_windows,
        "load_stall_bytes": int(src.cold_fault_page_bytes),
        "load_stall_seconds": src.cold_gather_seconds,
        "warm_gather_seconds": src.warm_gather_seconds,
        "prefetched_window_bytes": int(src.prefetched_window_bytes),
        "evicted_window_bytes": int(src.evicted_window_bytes),
        "window_evictions": int(src.window_evictions),
        "prefetch_hit_rate": src.prefetch_hit_rate,
        "peak_open_windows": peak_open,
        "resident_window_bytes": int(src.resident_window_bytes),
        "residency_bound_bytes": lru_windows * src.window_bytes,
    }
    emit(f"prefetch,{'on' if prefetch else 'off'},scale={scale:g}",
         dt / iters * 1e6,
         f"stall={res['load_stall_bytes']/1e6:.2f}MB "
         f"hit={res['prefetch_hit_rate']:.2f} "
         f"open<={peak_open}/{lru_windows}")
    src.close()
    return res


def prefetch_bit_identity(scale: float, iters: int, batch: int,
                          partition_rows: int, td: str) -> dict:
    """Trainer losses across {prefetch on/off} x {async_refresh on/off}
    (all four on the mmap tier with dynamic cache refresh under constant
    drift pressure): the whole background-I/O subsystem must be
    bit-invisible."""
    g = None
    losses = {}
    for prefetch in (0, 4):
        for async_refresh in (False, True):
            key = f"prefetch{prefetch}_async{int(async_refresh)}"
            ds = make_dataset(DATASET, scale=scale, seed=0,
                              feature_backend="mmap",
                              partition_rows=partition_rows,
                              spill_dir=os.path.join(td, f"spill-{key}"))
            if g is None:
                g = GNNConfig(model="sage", layer_dims=ds.layer_dims,
                              fanouts=FANOUTS, num_classes=ds.num_classes)
            cfg = HybridConfig(total_batch=batch, n_accel=2, hybrid=False,
                               use_drm=False, tfp_depth=2, seed=0,
                               cache_fraction=0.2, cache_refresh=True,
                               cache_drift_threshold=0.0,
                               async_refresh=async_refresh,
                               prefetch_windows=prefetch,
                               mmap_lru_windows=3)
            tr = HybridGNNTrainer(ds, g, cfg)
            tr.train(iters)
            losses[key] = [m.loss for m in tr.history]
            tr.close()
    base = losses["prefetch0_async0"]
    identical = all(np.array_equal(base, v) for v in losses.values())
    emit("prefetch,bit_identity_matrix", 0.0,
         f"configs={len(losses)} identical={identical} last={base[-1]:.4f}")
    return {"matrix_loss_bit_identical": identical,
            "losses": {k: v for k, v in losses.items()}}


def run_prefetch(scale: float = 1e-3, iters: int = 6, batch: int = 192,
                 e2e_iters: int = 4, partition_rows: int = 2048,
                 lru_windows: int = 4,
                 out_path: str = "BENCH_prefetch.json") -> dict:
    """Background storage-I/O sweep -> BENCH_prefetch.json."""
    results = {"dataset": DATASET, "scale": scale, "iters": iters,
               "batch": batch, "partition_rows": partition_rows,
               "lru_windows": lru_windows, "modes": {}}
    with tempfile.TemporaryDirectory(prefix="bench-prefetch-") as td:
        for mode in (False, True):
            results["modes"]["on" if mode else "off"] = bench_prefetch_mode(
                mode, scale, iters, batch, partition_rows, lru_windows,
                spill_dir=os.path.join(td, f"spill-{int(mode)}"))
        results.update(prefetch_bit_identity(
            scale, e2e_iters, batch, partition_rows, td))
    if out_path:
        with open(out_path, "w") as fh:
            json.dump(results, fh, indent=2)
        emit("prefetch,written", 0.0, os.path.abspath(out_path))
    return results


def _prefetch_asserts(res: dict) -> None:
    on, off = res["modes"]["on"], res["modes"]["off"]
    # the point of the subsystem: the load stage's cold-fault stall
    # collapses when the prefetcher pre-faults the windows
    assert on["load_stall_bytes"] < off["load_stall_bytes"], \
        (f"prefetch-on stall {on['load_stall_bytes']} not below "
         f"prefetch-off {off['load_stall_bytes']}")
    assert on["prefetch_hit_rate"] > 0.0
    # page-cache residency bounded by the window LRU in BOTH modes (the
    # prefetcher opens windows through the same LRU)
    for mode in (on, off):
        assert mode["peak_open_windows"] <= res["lru_windows"], mode
        assert mode["resident_window_bytes"] <= \
            mode["residency_bound_bytes"], mode
    assert res["matrix_loss_bit_identical"], \
        "background-I/O configs diverged trainer losses"


def run_prefetch_smoke() -> dict:
    """Tier-1 gate (~60 s): the prefetch on/off disk-tier sweep at test
    scale — prefetch-on load-stage stall strictly below prefetch-off,
    page-cache residency bounded by the window LRU, and the 4-config
    {prefetch, async_refresh} trainer matrix bit-identical.  Writes
    BENCH_prefetch.json (smoke is the only mode CI runs, so the smoke
    run must produce the artifact gen_roofline_md.py renders)."""
    res = run_prefetch(scale=1e-3, iters=6, batch=128, e2e_iters=3,
                       partition_rows=2048, lru_windows=4)
    _prefetch_asserts(res)
    return res


def run(scale: float = 1e-2, iters: int = 4, batch: int = 256,
        e2e_iters: int = 4, partition_rows: int = 8192,
        out_path: str = "BENCH_outofcore.json") -> dict:
    results = {"dataset": DATASET, "scale": scale, "iters": iters,
               "batch": batch, "partition_rows": partition_rows,
               "backends": {}}
    with tempfile.TemporaryDirectory(prefix="bench-outofcore-") as td:
        for backend in ("dense", "partitioned", "mmap"):
            spill = os.path.join(td, "spill") if backend == "mmap" else None
            results["backends"][backend] = bench_backend(
                backend, scale, iters, batch, partition_rows,
                spill_dir=spill)
        results.update(e2e_bit_identity(
            scale, e2e_iters, batch, partition_rows,
            spill_dir=os.path.join(td, "spill-e2e")))
    if out_path:
        with open(out_path, "w") as fh:
            json.dump(results, fh, indent=2)
        emit("outofcore,written", 0.0, os.path.abspath(out_path))
    return results


def _asserts(res: dict, resident_frac_max: float) -> None:
    mm = res["backends"]["mmap"]
    dense = res["backends"]["dense"]
    # bounded-RAM spill: never more than one partition buffered
    assert 0 < mm["spill_peak_buffered_rows"] <= res["partition_rows"], \
        f"spill buffered {mm['spill_peak_buffered_rows']} rows > partition"
    # the out-of-core promise: resident set is O(touched pages + spill
    # buffer), not O(N*F)
    frac = mm["resident_bytes"] / dense["resident_bytes"]
    assert frac < resident_frac_max, \
        f"mmap resident {frac:.2f}x of full matrix (>{resident_frac_max})"
    assert res["e2e_loss_bit_identical"], "mmap-backed losses diverged"


def run_smoke() -> dict:
    """Tier-1 gate (~60 s): small-scale papers100M in a temp dir (cleaned
    on exit) — dense/mmap gather parity, the one-partition spill bound, a
    bounded gather working set, and e2e loss bit-identity.  Writes
    BENCH_outofcore.json (smoke is the only mode CI runs, so the smoke
    run must produce the artifact gen_roofline_md.py renders)."""
    with tempfile.TemporaryDirectory(prefix="outofcore-smoke-") as td:
        # explicit byte-parity gate on one dataset instance
        ds_d = make_dataset(DATASET, scale=1e-3, seed=0,
                            feature_backend="dense")
        ds_m = make_dataset(DATASET, scale=1e-3, seed=0,
                            feature_backend="mmap", partition_rows=4096,
                            spill_dir=os.path.join(td, "parity"))
        rng = np.random.default_rng(0)
        rows = rng.integers(0, ds_m.num_nodes, 10_000).astype(np.int64)
        a = ds_d.take_features(rows)
        b = ds_m.take_features(rows)
        assert a.tobytes() == b.tobytes(), "mmap gather != dense gather"
        emit("outofcore,smoke_parity", 0.0, f"rows={rows.shape[0]} OK")
    res = run(scale=1e-3, iters=4, batch=128, e2e_iters=3,
              partition_rows=4096)
    _asserts(res, resident_frac_max=0.7)
    return res


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small-scale assert-only run (scripts/tier1.sh)")
    ap.add_argument("--smoke-prefetch", action="store_true",
                    help="background-I/O gate: prefetch on/off stall, "
                         "window-LRU residency bound, 4-config "
                         "bit-identity (scripts/tier1.sh)")
    ap.add_argument("--scale", type=float, default=1e-2)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.smoke:
        run_smoke()
    elif args.smoke_prefetch:
        run_prefetch_smoke()
    else:
        res = run(scale=args.scale)
        _asserts(res, resident_frac_max=0.5)
        pres = run_prefetch(scale=args.scale)
        _prefetch_asserts(pres)
