"""Out-of-core FeatureSource benchmark: dense vs partitioned vs mmap.

For papers100M (scaled) this measures, per backend:

  * gather throughput over sampled-frontier unique ids (rows/s and GB/s —
    the Feature Loader's host-side workload),
  * the resident-set ceiling: bytes of feature storage that must sit in
    host RAM at once.  The RAM backends hold the whole O(N*F) matrix; the
    mmap backend needs only the current gather's touched pages plus the
    spill writer's one-partition buffer — O(touched partitions), which is
    what lets a MAG240M-sized matrix (202 GB) train on a small host,

plus the spill writer's peak buffered rows (the bounded-RAM guarantee:
never more than one partition) and an end-to-end loss bit-identity check
of mmap-backed vs dense-backed training at the same seed.

Writes BENCH_outofcore.json.  ``--smoke`` is the tier-1 gate: a small-
scale run in a temp dir (cleaned up on exit) asserting dense/mmap gather
parity, the one-partition spill bound, a bounded gather working set, and
e2e loss bit-identity.

Usage:  PYTHONPATH=src python -m benchmarks.bench_outofcore [--smoke]
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

from repro.core import HybridConfig, HybridGNNTrainer
from repro.graph import GNNConfig, MmapFeatures, NumpySampler, make_dataset

from .common import emit

DATASET = "ogbn-papers100M"
FANOUTS = (10, 5)


def _frontiers(ds, iters: int, batch: int, seed: int = 1):
    """Unique ids of ``iters`` sampled frontiers (the deduped transfer
    path's gather requests — one row per unique id)."""
    sampler = NumpySampler(ds.graph, FANOUTS, seed=seed)
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(iters):
        tgt = rng.integers(0, ds.num_nodes, batch)
        mb = sampler.sample(tgt, ds.labels[tgt])
        out.append(np.unique(np.asarray(mb.frontier(len(FANOUTS)))))
    return out


def bench_backend(backend: str, scale: float, iters: int, batch: int,
                  partition_rows: int, spill_dir=None) -> dict:
    ds = make_dataset(DATASET, scale=scale, seed=0,
                      feature_backend=backend,
                      partition_rows=partition_rows, spill_dir=spill_dir)
    src = ds.feature_source
    full_bytes = ds.num_nodes * ds.feat_dim * 4
    frontiers = _frontiers(ds, iters, batch)
    src.take(frontiers[0][:64])            # warm the take path
    if isinstance(src, MmapFeatures):
        src.reset_touch_stats()
    rows = nbytes = 0
    peak_gather_pages = 0
    t0 = time.perf_counter()
    for f in frontiers:
        x = src.take(f)
        rows += x.shape[0]
        nbytes += x.nbytes
        if isinstance(src, MmapFeatures):
            peak_gather_pages = max(peak_gather_pages,
                                    src.last_gather_page_bytes)
    dt = time.perf_counter() - t0
    res = {
        "backend": backend,
        "gather_rows_per_s": rows / dt,
        "gather_gbps": nbytes / dt / 1e9,
        "gathered_rows": rows,
        "full_matrix_bytes": full_bytes,
    }
    if isinstance(src, MmapFeatures):
        # ceiling = one gather's faulted pages + the spill writer's single
        # partition buffer (pages from previous gathers are evictable)
        spill_buf = partition_rows * ds.feat_dim * 4
        res.update({
            "resident_bytes": peak_gather_pages + spill_buf,
            "peak_gather_page_bytes": peak_gather_pages,
            "spill_buffer_bytes": spill_buf,
            "spill_peak_buffered_rows": src.spill_peak_buffered_rows,
            "cumulative_touched_page_bytes": src.touched_page_bytes,
            "mapped_window_bytes": src.resident_window_bytes,
        })
    else:
        # RAM backends hold the whole matrix for the run's lifetime
        res["resident_bytes"] = full_bytes
    emit(f"outofcore,{backend},scale={scale:g}", dt / iters * 1e6,
         f"{res['gather_rows_per_s']/1e6:.2f}Mrows/s "
         f"resident={res['resident_bytes']/1e6:.1f}MB "
         f"(full {full_bytes/1e6:.1f}MB)")
    return res


def e2e_bit_identity(scale: float, iters: int, batch: int,
                     partition_rows: int, spill_dir=None) -> dict:
    """Train dense-backed and mmap-backed runs at the same seed; the
    backend is purely a capacity knob, so losses must be bit-identical."""
    g = None
    losses = {}
    for backend in ("dense", "mmap"):
        kw = (dict(spill_dir=spill_dir, partition_rows=partition_rows)
              if backend == "mmap" else {})
        ds = make_dataset(DATASET, scale=scale, seed=0,
                          feature_backend=backend, **kw)
        if g is None:
            g = GNNConfig(model="sage", layer_dims=ds.layer_dims,
                          fanouts=FANOUTS, num_classes=ds.num_classes)
        cfg = HybridConfig(total_batch=batch, n_accel=2, hybrid=False,
                           use_drm=False, tfp_depth=2, seed=0)
        tr = HybridGNNTrainer(ds, g, cfg)
        tr.train(iters)
        losses[backend] = [m.loss for m in tr.history]
        tr.loader.close()
    identical = bool(np.array_equal(losses["dense"], losses["mmap"]))
    emit("outofcore,e2e_bit_identity", 0.0,
         f"identical={identical} last={losses['mmap'][-1]:.4f}")
    return {"e2e_loss_bit_identical": identical,
            "losses_mmap": losses["mmap"]}


def run(scale: float = 1e-2, iters: int = 4, batch: int = 256,
        e2e_iters: int = 4, partition_rows: int = 8192,
        out_path: str = "BENCH_outofcore.json") -> dict:
    results = {"dataset": DATASET, "scale": scale, "iters": iters,
               "batch": batch, "partition_rows": partition_rows,
               "backends": {}}
    with tempfile.TemporaryDirectory(prefix="bench-outofcore-") as td:
        for backend in ("dense", "partitioned", "mmap"):
            spill = os.path.join(td, "spill") if backend == "mmap" else None
            results["backends"][backend] = bench_backend(
                backend, scale, iters, batch, partition_rows,
                spill_dir=spill)
        results.update(e2e_bit_identity(
            scale, e2e_iters, batch, partition_rows,
            spill_dir=os.path.join(td, "spill-e2e")))
    if out_path:
        with open(out_path, "w") as fh:
            json.dump(results, fh, indent=2)
        emit("outofcore,written", 0.0, os.path.abspath(out_path))
    return results


def _asserts(res: dict, resident_frac_max: float) -> None:
    mm = res["backends"]["mmap"]
    dense = res["backends"]["dense"]
    # bounded-RAM spill: never more than one partition buffered
    assert 0 < mm["spill_peak_buffered_rows"] <= res["partition_rows"], \
        f"spill buffered {mm['spill_peak_buffered_rows']} rows > partition"
    # the out-of-core promise: resident set is O(touched pages + spill
    # buffer), not O(N*F)
    frac = mm["resident_bytes"] / dense["resident_bytes"]
    assert frac < resident_frac_max, \
        f"mmap resident {frac:.2f}x of full matrix (>{resident_frac_max})"
    assert res["e2e_loss_bit_identical"], "mmap-backed losses diverged"


def run_smoke() -> dict:
    """Tier-1 gate (~60 s): small-scale papers100M in a temp dir (cleaned
    on exit) — dense/mmap gather parity, the one-partition spill bound, a
    bounded gather working set, and e2e loss bit-identity."""
    with tempfile.TemporaryDirectory(prefix="outofcore-smoke-") as td:
        # explicit byte-parity gate on one dataset instance
        ds_d = make_dataset(DATASET, scale=1e-3, seed=0,
                            feature_backend="dense")
        ds_m = make_dataset(DATASET, scale=1e-3, seed=0,
                            feature_backend="mmap", partition_rows=4096,
                            spill_dir=os.path.join(td, "parity"))
        rng = np.random.default_rng(0)
        rows = rng.integers(0, ds_m.num_nodes, 10_000).astype(np.int64)
        a = ds_d.take_features(rows)
        b = ds_m.take_features(rows)
        assert a.tobytes() == b.tobytes(), "mmap gather != dense gather"
        emit("outofcore,smoke_parity", 0.0, f"rows={rows.shape[0]} OK")
    res = run(scale=1e-3, iters=4, batch=128, e2e_iters=3,
              partition_rows=4096, out_path="")
    _asserts(res, resident_frac_max=0.7)
    return res


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small-scale assert-only run (scripts/tier1.sh)")
    ap.add_argument("--scale", type=float, default=1e-2)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.smoke:
        run_smoke()
    else:
        res = run(scale=args.scale)
        _asserts(res, resident_frac_max=0.5)
