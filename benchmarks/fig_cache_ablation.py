"""Device feature-cache + frontier-dedup ablation: dedup on/off ×
cache-fraction × dataset sweep.

For each cell this measures, with the real pipelined trainer (accel-only
mapping so every loaded row is transfer-eligible and runs are
deterministic):

  * measured cache hit rate vs the design-time estimate
    (``FeatureCache.expected_hit_rate`` — the perf model's Eq. 7/8 term),
  * the measured frontier duplication factor (positions per unique id —
    the perf model's ``dedup_factor`` alpha),
  * host->device feature bytes shipped, and the reduction factor vs the
    legacy one-row-per-frontier-position baseline,
  * mean iteration time.

The headline claims this reproduces: on power-law graphs (a) a static
degree-ordered cache of ~20% of the nodes absorbs >= 50% of feature
traffic (>= 2x byte reduction), because sampled frontiers are dominated
by hub nodes; and (b) shipping one row per *unique* id (the paper's
Feature Duplicator applied across the interconnect) cuts bytes by the
batch duplication factor (>= 2x at paper-scale fanouts) with no cache at
all, and composes multiplicatively with the cache.  Loss-equivalence
checks verify both knobs are semantically invisible: every configuration
with the same seed produces bit-identical losses.

A third sweep compares the *static* degree-ordered cache policy against
the *dynamic* refresh policy (DistDGL-style admission: decayed hotness
counters + evict-coldest/admit-hottest swaps) on a drifting-hub synthetic
trace — the workload the static snapshot is provably wrong for.  Hub
identity rotates every phase, so the static cache's hit rate decays to
the uniform background while the dynamic cache tracks the observed
distribution; results go to BENCH_cache_refresh.json and the tier-1
smoke gates that (a) the dynamic policy's steady-state hit rate >= the
static policy's, (b) dynamic ships strictly fewer bytes, and (c) a full
trainer run's losses are bit-identical with refresh on vs off (the
versioned in-flight consistency guarantee).

Usage:  PYTHONPATH=src python -m benchmarks.fig_cache_ablation
            [--smoke] [--smoke-refresh]
        (the full run also writes BENCH_dedup.json + BENCH_cache_refresh.json)
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.core import HybridConfig, HybridGNNTrainer
from repro.graph import FeatureCache, GNNConfig, HashedFeatures, make_dataset

from .common import emit

FRACTIONS = (0.0, 0.05, 0.1, 0.2, 0.4)
DATASETS = ("ogbn-products", "ogbn-papers100M")
DEDUP_FRACTIONS = (0.0, 0.2)


def _trainer(ds, gcfg, fraction: float, iters: int,
             dedup: bool = True) -> HybridGNNTrainer:
    hcfg = HybridConfig(total_batch=256, n_accel=2, hybrid=False,
                        use_drm=False, tfp_depth=2, seed=0,
                        use_accel_sampler=False,
                        cache_fraction=fraction, dedup=dedup)
    tr = HybridGNNTrainer(ds, gcfg, hcfg)
    tr.train(iters)
    return tr


def _gcfg(ds) -> GNNConfig:
    return GNNConfig(model="sage", layer_dims=ds.layer_dims,
                     fanouts=(10, 5), num_classes=ds.num_classes)


def run(scale: float = 0.002, iters: int = 8,
        fractions=FRACTIONS, datasets=DATASETS) -> dict:
    results: dict = {}
    for name in datasets:
        ds = make_dataset(name, scale=scale, seed=0)
        gcfg = _gcfg(ds)
        for frac in fractions:
            tr = _trainer(ds, gcfg, frac, iters)
            tf = tr.feature_traffic()
            t_iter = tr.mean_iter_time(skip=2)
            expected = tr.cache.expected_hit_rate if tr.cache else 0.0
            results[(name, frac)] = dict(tf, t_iter=t_iter,
                                         expected_hit=expected)
            emit(f"cache_ablation,{name},frac={frac:.2f}",
                 t_iter * 1e6,
                 f"hit={tf['hit_rate']:.3f} (model {expected:.3f}) "
                 f"dup={tf['dup_factor']:.2f} "
                 f"shipped={tf['shipped_bytes']/1e6:.1f}MB "
                 f"reduction={tf['reduction']:.2f}x")

    # loss-curve equivalence: the cache must not change training semantics
    ds = make_dataset(datasets[-1], scale=scale, seed=0)
    gcfg = _gcfg(ds)
    base = _trainer(ds, gcfg, 0.0, max(4, iters // 2))
    cached = _trainer(ds, gcfg, 0.2, max(4, iters // 2))
    l0 = [m.loss for m in base.history]
    l1 = [m.loss for m in cached.history]
    equal = bool(np.array_equal(l0, l1))
    results["loss_equivalent"] = equal
    emit("cache_ablation,loss_equivalence", 0.0,
         f"identical={equal} base={l0[-1]:.4f} cached={l1[-1]:.4f}")
    return results


def run_dedup_sweep(scale: float = 0.002, iters: int = 8,
                    fractions=DEDUP_FRACTIONS, datasets=DATASETS,
                    out_path: str = "BENCH_dedup.json") -> dict:
    """Dedup on/off × cache-fraction sweep -> BENCH_dedup.json.

    Reports shipped host->device bytes, the measured duplication factor,
    iteration time, and the reduction vs the legacy positional baseline
    (dedup off, cache off); checks the losses of every cell are
    bit-identical to that baseline.
    """
    # the legacy positional baseline cell (dedup off, cache off) anchors
    # every reduction/bit-identity comparison: always sweep it
    fractions = tuple(sorted({0.0, *fractions}))
    results: dict = {"scale": scale, "iters": iters, "cells": []}
    for name in datasets:
        ds = make_dataset(name, scale=scale, seed=0)
        gcfg = _gcfg(ds)
        legacy_bytes = None
        legacy_losses = None
        for dedup in (False, True):
            for frac in fractions:
                tr = _trainer(ds, gcfg, frac, iters, dedup=dedup)
                tf = tr.feature_traffic()
                losses = [m.loss for m in tr.history]
                if not dedup and frac == 0.0:
                    legacy_bytes = tf["shipped_bytes"]
                    legacy_losses = losses
                cell = {
                    "dataset": name, "dedup": dedup, "cache_fraction": frac,
                    "shipped_bytes": tf["shipped_bytes"],
                    "dedup_saved_bytes": tf["dedup_saved_bytes"],
                    "saved_bytes": tf["saved_bytes"],
                    "dup_factor": tf["dup_factor"],
                    "hit_rate": tf["hit_rate"],
                    "t_iter": tr.mean_iter_time(skip=2),
                    "reduction_vs_legacy":
                        legacy_bytes / max(tf["shipped_bytes"], 1.0),
                    "loss_bit_identical":
                        bool(np.array_equal(losses, legacy_losses)),
                }
                results["cells"].append(cell)
                emit(f"dedup_sweep,{name},dedup={int(dedup)},"
                     f"frac={frac:.2f}",
                     cell["t_iter"] * 1e6,
                     f"shipped={cell['shipped_bytes']/1e6:.1f}MB "
                     f"dup={cell['dup_factor']:.2f} "
                     f"red={cell['reduction_vs_legacy']:.2f}x "
                     f"loss_ok={cell['loss_bit_identical']}")
    with open(out_path, "w") as fh:
        json.dump(results, fh, indent=2)
    emit("dedup_sweep,written", 0.0, os.path.abspath(out_path))
    return results


def _dedup_asserts(res: dict, dataset: str) -> None:
    cells = {(c["dedup"], c["cache_fraction"]): c
             for c in res["cells"] if c["dataset"] == dataset}
    fracs = sorted({f for _, f in cells})
    dedup_only = cells[(True, 0.0)]
    # dedup alone must at least halve shipped bytes at paper-scale fanouts
    assert dedup_only["reduction_vs_legacy"] >= 2.0, \
        f"dedup-only reduction {dedup_only['reduction_vs_legacy']:.2f}x < 2x"
    cache_frac = fracs[-1]
    if cache_frac > 0.0:
        cache_only = cells[(False, cache_frac)]
        both = cells[(True, cache_frac)]
        # the cache alone must keep PR 1's >= 2x cut (dedup off, so this
        # gate cannot be satisfied by dedup savings)
        assert cache_only["reduction_vs_legacy"] >= 2.0, \
            f"cache-only reduction {cache_only['reduction_vs_legacy']:.2f}x"
        # composed with the cache, dedup must beat both single levers
        assert both["reduction_vs_legacy"] > cache_only["reduction_vs_legacy"], \
            "dedup+cache not better than cache alone"
        assert both["shipped_bytes"] < dedup_only["shipped_bytes"]
    # tier1 smoke invariant: dedup ships strictly less than legacy at the
    # same cache fraction
    for frac in fracs:
        assert cells[(True, frac)]["shipped_bytes"] < \
            cells[(False, frac)]["shipped_bytes"]
    # semantics untouched everywhere
    assert all(c["loss_bit_identical"] for c in res["cells"]
               if c["dataset"] == dataset), "a dedup/cache cell diverged"


# ------------------------------ static vs dynamic policy (refresh sweep)


def _drift_trace(num_nodes: int, phases: int, batches_per_phase: int,
                 batch: int, hub_frac: float, seed: int) -> list:
    """Drifting-hub id trace: each phase draws Zipf-shaped ids from a hub
    window that rotates half its members every phase (plus a uniform
    background), so the phase-0-optimal static cache decays while an
    adaptive policy can track the drift."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(num_nodes)
    n_hub = max(1, int(num_nodes * hub_frac))
    shift = n_hub // 2
    trace = []
    for p in range(phases):
        hubs = perm[(p * shift + np.arange(n_hub)) % num_nodes]
        batches = []
        for _ in range(batches_per_phase):
            u = rng.random(batch)
            ranks = np.minimum((u ** 3 * n_hub).astype(np.int64), n_hub - 1)
            batches.append(np.concatenate(
                [hubs[ranks], rng.integers(0, num_nodes, batch // 8)]))
        trace.append(batches)
    return trace


def _phase0_hotness(trace: list, num_nodes: int) -> np.ndarray:
    """The distribution the static cache is built for (and the dynamic one
    boots from): phase 0's empirical access counts."""
    counts = np.zeros(num_nodes)
    for ids in trace[0]:
        counts += np.bincount(ids, minlength=num_nodes)
    return counts + 1e-3


def _run_policy(trace: list, num_nodes: int, capacity: int, dynamic: bool,
                refresh_every: int = 4, feat_dim: int = 32) -> dict:
    src = HashedFeatures(num_nodes, feat_dim, seed=0)
    cache = FeatureCache(src, _phase0_hotness(trace, num_nodes), capacity)
    cache.track_hotness = True    # both policies pay identical lookup cost
    shipped = 0
    rates = []
    step = 0
    for batches in trace:
        hits = rows = 0
        for ids in batches:
            look = cache.lookup(ids)
            shipped += look.num_miss * cache.row_bytes
            hits += look.num_hit
            rows += look.num_rows
            step += 1
            if dynamic and step % refresh_every == 0:
                cache.refresh()
        rates.append(hits / max(rows, 1))
    # admitted rows cross PCIe too (the scatter-update DMA): charge them,
    # or the dynamic policy's byte cut would be overstated
    admission = cache.refresh_swapped_rows * cache.row_bytes
    return {"phase_hit_rates": rates, "shipped_bytes": float(shipped),
            "admission_bytes": float(admission),
            "total_pcie_bytes": float(shipped + admission),
            "refreshes": int(cache.refreshes), "version": int(cache.version),
            "swapped_rows": int(cache.refresh_swapped_rows)}


def _refresh_bit_identity(scale: float, iters: int) -> dict:
    """Full pipelined trainer, refresh on vs off: the versioned-lookup
    protocol makes the refresh semantically invisible, so losses must be
    bit-identical (drift threshold 0 forces refreshes every iteration —
    maximal churn against the in-flight TFP payloads)."""
    ds = make_dataset(DATASETS[-1], scale=scale, seed=0)
    gcfg = _gcfg(ds)

    def t(refresh: bool) -> HybridGNNTrainer:
        hcfg = HybridConfig(total_batch=256, n_accel=2, hybrid=False,
                            use_drm=False, tfp_depth=2, seed=0,
                            use_accel_sampler=False, cache_fraction=0.2,
                            cache_refresh=refresh,
                            cache_drift_threshold=0.0)
        tr = HybridGNNTrainer(ds, gcfg, hcfg)
        tr.train(iters)
        return tr

    off, on = t(False), t(True)
    return {
        "losses_bit_identical": bool(np.array_equal(
            [m.loss for m in off.history], [m.loss for m in on.history])),
        "refresh_version": int(on.cache.version),
        "refreshes": int(on.cache.refreshes),
        "shipped_bytes_off": float(off.feature_traffic()["shipped_bytes"]),
        "shipped_bytes_on": float(on.feature_traffic()["shipped_bytes"]),
    }


def run_refresh_sweep(num_nodes: int = 4000, capacity: int = 400,
                      phases: int = 5, batches_per_phase: int = 12,
                      batch: int = 512, hub_frac: float = 0.15,
                      trainer_scale: float = 0.001, trainer_iters: int = 6,
                      out_path: str = "BENCH_cache_refresh.json") -> dict:
    """Static vs dynamic cache policy on the drifting-hub trace
    -> BENCH_cache_refresh.json (plus the trainer bit-identity check)."""
    trace = _drift_trace(num_nodes, phases, batches_per_phase, batch,
                         hub_frac, seed=7)
    static = _run_policy(trace, num_nodes, capacity, dynamic=False)
    dynamic = _run_policy(trace, num_nodes, capacity, dynamic=True)
    bit = _refresh_bit_identity(trainer_scale, trainer_iters)
    results = {
        "trace": {"num_nodes": num_nodes, "capacity": capacity,
                  "phases": phases, "batches_per_phase": batches_per_phase,
                  "batch": batch, "hub_frac": hub_frac},
        "static": static, "dynamic": dynamic, "trainer": bit,
    }
    with open(out_path, "w") as fh:
        json.dump(results, fh, indent=2)
    emit("cache_refresh,static", 0.0,
         f"steady_hit={static['phase_hit_rates'][-1]:.3f} "
         f"pcie={static['total_pcie_bytes']/1e6:.2f}MB")
    emit("cache_refresh,dynamic", 0.0,
         f"steady_hit={dynamic['phase_hit_rates'][-1]:.3f} "
         f"pcie={dynamic['total_pcie_bytes']/1e6:.2f}MB "
         f"(admission {dynamic['admission_bytes']/1e6:.2f}MB) "
         f"refreshes={dynamic['refreshes']}")
    emit("cache_refresh,bit_identity", 0.0,
         f"losses_ok={bit['losses_bit_identical']} "
         f"version={bit['refresh_version']}")
    emit("cache_refresh,written", 0.0, os.path.abspath(out_path))
    return results


def _refresh_asserts(res: dict) -> None:
    static, dynamic, bit = res["static"], res["dynamic"], res["trainer"]
    # under drift the adaptive policy must at least match the static
    # steady-state hit rate (in practice it is far ahead: the static cache
    # decays to the uniform background once the phase-0 hubs rotate out)
    assert dynamic["phase_hit_rates"][-1] >= static["phase_hit_rates"][-1], \
        (f"dynamic steady-state hit {dynamic['phase_hit_rates'][-1]:.3f} < "
         f"static {static['phase_hit_rates'][-1]:.3f}")
    # gate on TOTAL PCIe traffic (miss rows + refresh admission DMAs):
    # the dynamic policy must win even after paying for its own swaps
    assert dynamic["total_pcie_bytes"] < static["total_pcie_bytes"], \
        "dynamic policy did not cut total PCIe bytes under drift"
    assert dynamic["refreshes"] > 0, "dynamic policy never refreshed"
    # the refresh must be semantically invisible (versioned lookups)
    assert bit["losses_bit_identical"], \
        "refresh on/off losses diverged — in-flight consistency broken"
    assert bit["refresh_version"] > 0, \
        "trainer bit-identity ran without any refresh firing"


def run_refresh_smoke() -> dict:
    """~30 s static-vs-dynamic gate for the tier1 runner."""
    res = run_refresh_sweep(num_nodes=2000, capacity=200, phases=4,
                            batches_per_phase=8, batch=256,
                            trainer_scale=0.001, trainer_iters=5)
    _refresh_asserts(res)
    return res


def run_smoke() -> dict:
    """~60 s two-sweep check for the tier1 runner: papers100M at the
    paper-relevant 20% fraction must cut shipped bytes >= 2x, dedup alone
    must cut >= 2x and compose with the cache, and every configuration's
    losses must be bit-identical to the legacy positional path."""
    res = run(scale=0.001, iters=5, fractions=(0.0, 0.2),
              datasets=("ogbn-papers100M",))
    cell = res[("ogbn-papers100M", 0.2)]
    # composed gate (dedup is on by default in run()); the cache-only
    # >= 2x gate lives in _dedup_asserts where dedup is actually off
    assert cell["reduction"] >= 2.0, \
        f"composed reduction regressed: {cell['reduction']:.2f}x < 2x"
    assert res["loss_equivalent"], "cached run diverged from uncached"
    dres = run_dedup_sweep(scale=0.001, iters=5,
                           datasets=("ogbn-papers100M",))
    _dedup_asserts(dres, "ogbn-papers100M")
    return {"cache": res, "dedup": dres}


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="two-sweep ~60s check (used by scripts/tier1.sh)")
    ap.add_argument("--smoke-refresh", action="store_true",
                    help="~30s static-vs-dynamic cache-refresh gate "
                         "(used by scripts/tier1.sh)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.smoke:
        run_smoke()
    if args.smoke_refresh:
        run_refresh_smoke()
    if not (args.smoke or args.smoke_refresh):
        run()
        res = run_dedup_sweep()
        for name in DATASETS:
            _dedup_asserts(res, name)
        rres = run_refresh_sweep()
        _refresh_asserts(rres)
