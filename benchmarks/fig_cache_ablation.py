"""Device feature-cache + frontier-dedup ablation: dedup on/off ×
cache-fraction × dataset sweep.

For each cell this measures, with the real pipelined trainer (accel-only
mapping so every loaded row is transfer-eligible and runs are
deterministic):

  * measured cache hit rate vs the design-time estimate
    (``FeatureCache.expected_hit_rate`` — the perf model's Eq. 7/8 term),
  * the measured frontier duplication factor (positions per unique id —
    the perf model's ``dedup_factor`` alpha),
  * host->device feature bytes shipped, and the reduction factor vs the
    legacy one-row-per-frontier-position baseline,
  * mean iteration time.

The headline claims this reproduces: on power-law graphs (a) a static
degree-ordered cache of ~20% of the nodes absorbs >= 50% of feature
traffic (>= 2x byte reduction), because sampled frontiers are dominated
by hub nodes; and (b) shipping one row per *unique* id (the paper's
Feature Duplicator applied across the interconnect) cuts bytes by the
batch duplication factor (>= 2x at paper-scale fanouts) with no cache at
all, and composes multiplicatively with the cache.  Loss-equivalence
checks verify both knobs are semantically invisible: every configuration
with the same seed produces bit-identical losses.

Usage:  PYTHONPATH=src python -m benchmarks.fig_cache_ablation [--smoke]
        (the full run also writes BENCH_dedup.json with the dedup sweep)
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.core import HybridConfig, HybridGNNTrainer
from repro.graph import GNNConfig, make_dataset

from .common import emit

FRACTIONS = (0.0, 0.05, 0.1, 0.2, 0.4)
DATASETS = ("ogbn-products", "ogbn-papers100M")
DEDUP_FRACTIONS = (0.0, 0.2)


def _trainer(ds, gcfg, fraction: float, iters: int,
             dedup: bool = True) -> HybridGNNTrainer:
    hcfg = HybridConfig(total_batch=256, n_accel=2, hybrid=False,
                        use_drm=False, tfp_depth=2, seed=0,
                        use_accel_sampler=False,
                        cache_fraction=fraction, dedup=dedup)
    tr = HybridGNNTrainer(ds, gcfg, hcfg)
    tr.train(iters)
    return tr


def _gcfg(ds) -> GNNConfig:
    return GNNConfig(model="sage", layer_dims=ds.layer_dims,
                     fanouts=(10, 5), num_classes=ds.num_classes)


def run(scale: float = 0.002, iters: int = 8,
        fractions=FRACTIONS, datasets=DATASETS) -> dict:
    results: dict = {}
    for name in datasets:
        ds = make_dataset(name, scale=scale, seed=0)
        gcfg = _gcfg(ds)
        for frac in fractions:
            tr = _trainer(ds, gcfg, frac, iters)
            tf = tr.feature_traffic()
            t_iter = tr.mean_iter_time(skip=2)
            expected = tr.cache.expected_hit_rate if tr.cache else 0.0
            results[(name, frac)] = dict(tf, t_iter=t_iter,
                                         expected_hit=expected)
            emit(f"cache_ablation,{name},frac={frac:.2f}",
                 t_iter * 1e6,
                 f"hit={tf['hit_rate']:.3f} (model {expected:.3f}) "
                 f"dup={tf['dup_factor']:.2f} "
                 f"shipped={tf['shipped_bytes']/1e6:.1f}MB "
                 f"reduction={tf['reduction']:.2f}x")

    # loss-curve equivalence: the cache must not change training semantics
    ds = make_dataset(datasets[-1], scale=scale, seed=0)
    gcfg = _gcfg(ds)
    base = _trainer(ds, gcfg, 0.0, max(4, iters // 2))
    cached = _trainer(ds, gcfg, 0.2, max(4, iters // 2))
    l0 = [m.loss for m in base.history]
    l1 = [m.loss for m in cached.history]
    equal = bool(np.array_equal(l0, l1))
    results["loss_equivalent"] = equal
    emit("cache_ablation,loss_equivalence", 0.0,
         f"identical={equal} base={l0[-1]:.4f} cached={l1[-1]:.4f}")
    return results


def run_dedup_sweep(scale: float = 0.002, iters: int = 8,
                    fractions=DEDUP_FRACTIONS, datasets=DATASETS,
                    out_path: str = "BENCH_dedup.json") -> dict:
    """Dedup on/off × cache-fraction sweep -> BENCH_dedup.json.

    Reports shipped host->device bytes, the measured duplication factor,
    iteration time, and the reduction vs the legacy positional baseline
    (dedup off, cache off); checks the losses of every cell are
    bit-identical to that baseline.
    """
    # the legacy positional baseline cell (dedup off, cache off) anchors
    # every reduction/bit-identity comparison: always sweep it
    fractions = tuple(sorted({0.0, *fractions}))
    results: dict = {"scale": scale, "iters": iters, "cells": []}
    for name in datasets:
        ds = make_dataset(name, scale=scale, seed=0)
        gcfg = _gcfg(ds)
        legacy_bytes = None
        legacy_losses = None
        for dedup in (False, True):
            for frac in fractions:
                tr = _trainer(ds, gcfg, frac, iters, dedup=dedup)
                tf = tr.feature_traffic()
                losses = [m.loss for m in tr.history]
                if not dedup and frac == 0.0:
                    legacy_bytes = tf["shipped_bytes"]
                    legacy_losses = losses
                cell = {
                    "dataset": name, "dedup": dedup, "cache_fraction": frac,
                    "shipped_bytes": tf["shipped_bytes"],
                    "dedup_saved_bytes": tf["dedup_saved_bytes"],
                    "saved_bytes": tf["saved_bytes"],
                    "dup_factor": tf["dup_factor"],
                    "hit_rate": tf["hit_rate"],
                    "t_iter": tr.mean_iter_time(skip=2),
                    "reduction_vs_legacy":
                        legacy_bytes / max(tf["shipped_bytes"], 1.0),
                    "loss_bit_identical":
                        bool(np.array_equal(losses, legacy_losses)),
                }
                results["cells"].append(cell)
                emit(f"dedup_sweep,{name},dedup={int(dedup)},"
                     f"frac={frac:.2f}",
                     cell["t_iter"] * 1e6,
                     f"shipped={cell['shipped_bytes']/1e6:.1f}MB "
                     f"dup={cell['dup_factor']:.2f} "
                     f"red={cell['reduction_vs_legacy']:.2f}x "
                     f"loss_ok={cell['loss_bit_identical']}")
    with open(out_path, "w") as fh:
        json.dump(results, fh, indent=2)
    emit("dedup_sweep,written", 0.0, os.path.abspath(out_path))
    return results


def _dedup_asserts(res: dict, dataset: str) -> None:
    cells = {(c["dedup"], c["cache_fraction"]): c
             for c in res["cells"] if c["dataset"] == dataset}
    fracs = sorted({f for _, f in cells})
    dedup_only = cells[(True, 0.0)]
    # dedup alone must at least halve shipped bytes at paper-scale fanouts
    assert dedup_only["reduction_vs_legacy"] >= 2.0, \
        f"dedup-only reduction {dedup_only['reduction_vs_legacy']:.2f}x < 2x"
    cache_frac = fracs[-1]
    if cache_frac > 0.0:
        cache_only = cells[(False, cache_frac)]
        both = cells[(True, cache_frac)]
        # the cache alone must keep PR 1's >= 2x cut (dedup off, so this
        # gate cannot be satisfied by dedup savings)
        assert cache_only["reduction_vs_legacy"] >= 2.0, \
            f"cache-only reduction {cache_only['reduction_vs_legacy']:.2f}x"
        # composed with the cache, dedup must beat both single levers
        assert both["reduction_vs_legacy"] > cache_only["reduction_vs_legacy"], \
            "dedup+cache not better than cache alone"
        assert both["shipped_bytes"] < dedup_only["shipped_bytes"]
    # tier1 smoke invariant: dedup ships strictly less than legacy at the
    # same cache fraction
    for frac in fracs:
        assert cells[(True, frac)]["shipped_bytes"] < \
            cells[(False, frac)]["shipped_bytes"]
    # semantics untouched everywhere
    assert all(c["loss_bit_identical"] for c in res["cells"]
               if c["dataset"] == dataset), "a dedup/cache cell diverged"


def run_smoke() -> dict:
    """~60 s two-sweep check for the tier1 runner: papers100M at the
    paper-relevant 20% fraction must cut shipped bytes >= 2x, dedup alone
    must cut >= 2x and compose with the cache, and every configuration's
    losses must be bit-identical to the legacy positional path."""
    res = run(scale=0.001, iters=5, fractions=(0.0, 0.2),
              datasets=("ogbn-papers100M",))
    cell = res[("ogbn-papers100M", 0.2)]
    # composed gate (dedup is on by default in run()); the cache-only
    # >= 2x gate lives in _dedup_asserts where dedup is actually off
    assert cell["reduction"] >= 2.0, \
        f"composed reduction regressed: {cell['reduction']:.2f}x < 2x"
    assert res["loss_equivalent"], "cached run diverged from uncached"
    dres = run_dedup_sweep(scale=0.001, iters=5,
                           datasets=("ogbn-papers100M",))
    _dedup_asserts(dres, "ogbn-papers100M")
    return {"cache": res, "dedup": dres}


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="two-sweep ~60s check (used by scripts/tier1.sh)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.smoke:
        run_smoke()
    else:
        run()
        res = run_dedup_sweep()
        for name in DATASETS:
            _dedup_asserts(res, name)
