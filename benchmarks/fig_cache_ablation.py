"""Device feature-cache ablation: cache fraction × dataset sweep.

For each (dataset, cache_fraction) cell this measures, with the real
pipelined trainer (accel-only mapping so every loaded row is
cache-eligible and runs are deterministic):

  * measured cache hit rate vs the design-time estimate
    (``FeatureCache.expected_hit_rate`` — the perf model's Eq. 7/8 term),
  * host->device feature bytes shipped, and the reduction factor vs the
    uncached baseline (``saved/shipped + 1``),
  * mean iteration time.

The headline claim this reproduces: on power-law graphs a static
degree-ordered cache of ~20% of the nodes absorbs >= 50% of feature
traffic (>= 2x byte reduction), because sampled frontiers are dominated
by hub nodes.  A final loss-equivalence check verifies the cache is
semantically invisible: cached and uncached runs with the same seed
produce identical losses.

Usage:  PYTHONPATH=src python -m benchmarks.fig_cache_ablation [--smoke]
"""
from __future__ import annotations

import numpy as np

from repro.core import HybridConfig, HybridGNNTrainer
from repro.graph import GNNConfig, make_dataset

from .common import emit

FRACTIONS = (0.0, 0.05, 0.1, 0.2, 0.4)
DATASETS = ("ogbn-products", "ogbn-papers100M")


def _trainer(ds, gcfg, fraction: float, iters: int) -> HybridGNNTrainer:
    hcfg = HybridConfig(total_batch=256, n_accel=2, hybrid=False,
                        use_drm=False, tfp_depth=2, seed=0,
                        use_accel_sampler=False,
                        cache_fraction=fraction)
    tr = HybridGNNTrainer(ds, gcfg, hcfg)
    tr.train(iters)
    return tr


def run(scale: float = 0.002, iters: int = 8,
        fractions=FRACTIONS, datasets=DATASETS) -> dict:
    results: dict = {}
    for name in datasets:
        ds = make_dataset(name, scale=scale, seed=0)
        gcfg = GNNConfig(model="sage", layer_dims=ds.layer_dims,
                         fanouts=(10, 5), num_classes=ds.num_classes)
        for frac in fractions:
            tr = _trainer(ds, gcfg, frac, iters)
            tf = tr.feature_traffic()
            t_iter = tr.mean_iter_time(skip=2)
            expected = tr.cache.expected_hit_rate if tr.cache else 0.0
            results[(name, frac)] = dict(tf, t_iter=t_iter,
                                         expected_hit=expected)
            emit(f"cache_ablation,{name},frac={frac:.2f}",
                 t_iter * 1e6,
                 f"hit={tf['hit_rate']:.3f} (model {expected:.3f}) "
                 f"shipped={tf['shipped_bytes']/1e6:.1f}MB "
                 f"reduction={tf['reduction']:.2f}x")

    # loss-curve equivalence: the cache must not change training semantics
    ds = make_dataset(datasets[-1], scale=scale, seed=0)
    gcfg = GNNConfig(model="sage", layer_dims=ds.layer_dims,
                     fanouts=(10, 5), num_classes=ds.num_classes)
    base = _trainer(ds, gcfg, 0.0, max(4, iters // 2))
    cached = _trainer(ds, gcfg, 0.2, max(4, iters // 2))
    l0 = [m.loss for m in base.history]
    l1 = [m.loss for m in cached.history]
    equal = bool(np.array_equal(l0, l1))
    results["loss_equivalent"] = equal
    emit("cache_ablation,loss_equivalence", 0.0,
         f"identical={equal} base={l0[-1]:.4f} cached={l1[-1]:.4f}")
    return results


def run_smoke() -> dict:
    """~30 s single-cell check for the tier1 runner: papers100M at the
    paper-relevant 20% fraction must cut shipped bytes >= 2x."""
    res = run(scale=0.001, iters=5, fractions=(0.0, 0.2),
              datasets=("ogbn-papers100M",))
    cell = res[("ogbn-papers100M", 0.2)]
    assert cell["reduction"] >= 2.0, \
        f"cache reduction regressed: {cell['reduction']:.2f}x < 2x"
    assert res["loss_equivalent"], "cached run diverged from uncached"
    return res


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single-cell ~30s check (used by scripts/tier1.sh)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.smoke:
        run_smoke()
    else:
        run()
