"""Generate the EXPERIMENTS.md §Roofline / §Dry-run markdown tables from
dry-run JSON results.

    PYTHONPATH=src python -m benchmarks.gen_roofline_md \
        dryrun_single.json dryrun_multi.json > roofline_tables.md
"""
from __future__ import annotations

import json
import sys


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/2**30:.2f}"


def fmt_ms(s):
    return f"{s*1e3:.1f}"


def table(results, title):
    out = [f"### {title}", "",
           "| arch | shape | mb | GiB/dev | fits | t_comp ms | t_mem ms | "
           "t_coll ms | bottleneck | useful | roofline_frac |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in results:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | - |"
                       f" - | SKIP | - | - |")
            continue
        if r["status"] == "error":
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | - |"
                       f" - | ERROR | - | - |")
            continue
        roof = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r.get('microbatches', 1)} | "
            f"{fmt_bytes(r.get('bytes_per_device'))} | "
            f"{'Y' if r.get('fits_16gb') else 'N'} | "
            f"{fmt_ms(roof['t_compute_s'])} | {fmt_ms(roof['t_memory_s'])} | "
            f"{fmt_ms(roof['t_collective_s'])} | {roof['bottleneck']} | "
            f"{roof['useful_flops_ratio']:.2f} | "
            f"{roof['roofline_fraction']:.3f} |")
    out.append("")
    return "\n".join(out)


def main():
    parts = []
    for path in sys.argv[1:]:
        with open(path) as f:
            results = json.load(f)
        mesh = "x".join(str(m) for m in results[0]["mesh"])
        parts.append(table(results, f"mesh {mesh} ({results[0]['chips']} "
                           f"chips) — {path}"))
    print("\n".join(parts))


if __name__ == "__main__":
    main()
