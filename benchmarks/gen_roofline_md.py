"""Generate the EXPERIMENTS.md §Roofline / §Dry-run markdown tables from
dry-run JSON results, plus the §Kernel overlap table from the
``bench_kernel_overlap`` depth-sweep JSON (detected by shape: the
dry-run files are lists, ``BENCH_kernel_overlap.json`` is a dict with
``combine``/``update`` sweeps).

    PYTHONPATH=src python -m benchmarks.gen_roofline_md \
        dryrun_single.json dryrun_multi.json BENCH_kernel_overlap.json \
        > roofline_tables.md
"""
from __future__ import annotations

import json
import sys


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/2**30:.2f}"


def fmt_ms(s):
    return f"{s*1e3:.1f}"


def table(results, title):
    out = [f"### {title}", "",
           "| arch | shape | mb | GiB/dev | fits | t_comp ms | t_mem ms | "
           "t_coll ms | bottleneck | useful | roofline_frac |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in results:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | - |"
                       f" - | SKIP | - | - |")
            continue
        if r["status"] == "error":
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | - |"
                       f" - | ERROR | - | - |")
            continue
        roof = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r.get('microbatches', 1)} | "
            f"{fmt_bytes(r.get('bytes_per_device'))} | "
            f"{'Y' if r.get('fits_16gb') else 'N'} | "
            f"{fmt_ms(roof['t_compute_s'])} | {fmt_ms(roof['t_memory_s'])} | "
            f"{fmt_ms(roof['t_collective_s'])} | {roof['bottleneck']} | "
            f"{roof['useful_flops_ratio']:.2f} | "
            f"{roof['roofline_fraction']:.3f} |")
    out.append("")
    return "\n".join(out)


def _overlap_rows(rows, kind):
    out = [f"#### {kind} kernel", "",
           "| config | dtype | depth | us | GB/s | roofline_frac | "
           "VMEM scratch | == depth-1 | == oracle |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if kind == "combine":
            cfg = (f"n={r['n']} f={r['f']} "
                   f"tile {r['t_n']}x{r['t_f']}")
        else:
            cfg = (f"k={r['k']} f={r['f']} m={r['m']}"
                   f"{' aliased' if r.get('aliased') else ''}")
        d1 = r.get("bit_identical_vs_depth1")
        out.append(
            f"| {cfg} | {r['dtype']} | {r['depth']} | {r['us']:.1f} | "
            f"{r['achieved_gbps']:.2f} | "
            f"{r.get('roofline_fraction', 0.0):.3f} | "
            f"{r['vmem_scratch_bytes']/1024:.0f} KiB | "
            f"{'-' if d1 is None else ('Y' if d1 else 'N')} | "
            f"{'Y' if r['bit_identical_vs_oracle'] else 'N'} |")
    out.append("")
    return out


def overlap_table(res, title):
    """§Kernel overlap: the bench_kernel_overlap depth sweep — wall time
    and achieved bandwidth per (kernel x tile x feature width x dtype x
    depth), with the bit-identity columns the tier-1 gate asserts."""
    out = [f"### {title}", "",
           f"Memory roofline (calibrated container): "
           f"{res['roofline_mem_gbps']:.1f} GB/s; VMEM scratch budget "
           f"{res['vmem_budget_bytes']/2**20:.0f} MiB.", ""]
    out += _overlap_rows(res["combine"], "combine")
    out += _overlap_rows(res["update"], "update")
    if "e2e_loss_bit_identical" in res:
        out.append(f"End-to-end trainer losses across pipeline depths "
                   f"{res.get('e2e_depths')}: "
                   f"{'bit-identical' if res['e2e_loss_bit_identical'] else 'DIVERGED'}.")
        out.append("")
    return "\n".join(out)


def main():
    parts = []
    for path in sys.argv[1:]:
        # a bench artifact may legitimately be absent (its bench has not
        # run on this checkout yet): skip with a visible note instead of
        # failing the whole render
        try:
            with open(path) as f:
                results = json.load(f)
        except FileNotFoundError:
            parts.append(f"### {path}\n\n_Skipped: {path} not found — "
                         f"run its benchmark to regenerate._\n")
            continue
        except json.JSONDecodeError as e:
            parts.append(f"### {path}\n\n_Skipped: {path} is not valid "
                         f"JSON ({e})._\n")
            continue
        if isinstance(results, dict) and "combine" in results:
            parts.append(overlap_table(results,
                                       f"Kernel overlap — {path}"))
            continue
        if not (isinstance(results, list) and results
                and "mesh" in results[0]):
            # some other bench's artifact (out-of-core, prefetch,
            # autotune, ...): note what it is rather than crash on an
            # unexpected shape
            keys = (sorted(results)[:8] if isinstance(results, dict)
                    else [type(results).__name__])
            parts.append(f"### {path}\n\n_Skipped: no roofline/overlap "
                         f"tables in this artifact (top-level: "
                         f"{', '.join(map(str, keys))})._\n")
            continue
        mesh = "x".join(str(m) for m in results[0]["mesh"])
        parts.append(table(results, f"mesh {mesh} ({results[0]['chips']} "
                           f"chips) — {path}"))
    print("\n".join(parts))


if __name__ == "__main__":
    main()
