"""Device-resident hot-feature cache (static, degree-ordered).

HyScale-GNN hides host->device feature traffic behind prefetching; the
complementary lever (DistDGL-style hybrid systems, and the dominant one on
feature-traffic-bound workloads) is to *not send* the hottest rows at all:
power-law frontiers are dominated by hub nodes, so pinning the top-K
hottest node features in device memory converts most of each iteration's
gather into a device-local lookup.

The cache is static: hotness is the expected gather frequency under
neighbor sampling (``GraphDataset.feature_hotness`` — in-edge mass + 1),
known at dataset-build time, so there is no invalidation protocol and the
id->slot table never changes during training.  A dynamic refresh policy is
future work (see ROADMAP).

Components:

  * ``slot_of``  — vectorized id->slot lookup, one int32 per node, -1 for
    uncached.  4 B/node of host memory buys O(1) batch partitioning
    (papers100M scale: ~440 MB, far below the feature matrix it indexes).
  * ``data_on(device)`` — the [K, F] hot-row block, placed once per
    trainer device and reused every iteration.
  * ``lookup(ids)`` — splits a frontier into (slots, miss_index, miss_ids)
    and accounts hit/miss rows and bytes saved.

The loader (``featload.FeatureLoader``) gathers only ``miss_ids`` on the
host; the transfer stage ships the misses and a combine step (Pallas
``cache_combine`` kernel or its jnp reference) assembles the dense layer-0
input on device.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import numpy as np

from .storage import FeatureSource, as_feature_source

__all__ = ["CacheLookup", "CacheStats", "FeatureCache", "build_cache"]


@dataclasses.dataclass
class CacheLookup:
    """Result of partitioning one frontier against the cache."""
    ids: np.ndarray         # int64 [N] the queried node ids
    slots: np.ndarray       # int32 [N] cache slot per row, -1 = miss
    miss_index: np.ndarray  # int32 [N] row into the miss block (0 for hits)
    miss_ids: np.ndarray    # int64 [M] node ids to gather on the host

    @property
    def num_rows(self) -> int:
        return int(self.ids.shape[0])

    @property
    def num_miss(self) -> int:
        return int(self.miss_ids.shape[0])

    @property
    def num_hit(self) -> int:
        return self.num_rows - self.num_miss

    @property
    def hit_rate(self) -> float:
        return self.num_hit / max(self.num_rows, 1)


@dataclasses.dataclass
class CacheStats:
    lookups: int = 0
    hit_rows: int = 0
    miss_rows: int = 0
    saved_bytes: int = 0     # host->device bytes avoided by cache hits

    @property
    def total_rows(self) -> int:
        return self.hit_rows + self.miss_rows

    @property
    def hit_rate(self) -> float:
        return self.hit_rows / max(self.total_rows, 1)

    def merge(self, other: "CacheStats") -> None:
        self.lookups += other.lookups
        self.hit_rows += other.hit_rows
        self.miss_rows += other.miss_rows
        self.saved_bytes += other.saved_bytes


class FeatureCache:
    """Static top-K hot-row cache over any ``FeatureSource``.

    ``capacity`` rows are chosen by descending ``hotness``; the hot block
    is materialized once on the host (in ``transfer_dtype``) and placed
    per device on first use.
    """

    def __init__(self, source: "FeatureSource | np.ndarray",
                 hotness: np.ndarray, capacity: int,
                 transfer_dtype: str = "float32"):
        source = as_feature_source(source)
        num_nodes, feat_dim = source.shape
        capacity = int(max(0, min(capacity, num_nodes)))
        hotness = np.asarray(hotness, dtype=np.float64)
        if hotness.shape[0] != num_nodes:
            raise ValueError("hotness must have one entry per node")
        # stable order so equal-hotness ties are deterministic across runs
        order = np.argsort(-hotness, kind="stable")[:capacity]
        self.cached_ids = np.ascontiguousarray(order.astype(np.int64))
        self.capacity = capacity
        self.feat_dim = int(feat_dim)
        # bytes one feature row occupies on the wire (transfer dtype)
        self.row_bytes = int(feat_dim) * np.dtype(
            np.float32 if transfer_dtype == "float32" else transfer_dtype
        ).itemsize
        self.slot_of = np.full(num_nodes, -1, dtype=np.int32)
        self.slot_of[self.cached_ids] = np.arange(capacity, dtype=np.int32)
        host_rows = source.take(self.cached_ids)
        if transfer_dtype != "float32":
            import jax.numpy as jnp
            host_rows = host_rows.astype(jnp.dtype(transfer_dtype))
        self._host_rows = np.ascontiguousarray(host_rows)
        self._device_data: Dict[int, jax.Array] = {}
        self._expected_hit_rate = (float(hotness[self.cached_ids].sum())
                                   / max(float(hotness.sum()), 1e-12))
        self.stats = CacheStats()

    # ------------------------------------------------------------- plumbing

    @property
    def nbytes(self) -> int:
        """Device bytes pinned by the hot block (per trainer device)."""
        return self._host_rows.nbytes

    @property
    def expected_hit_rate(self) -> float:
        """Design-time hit-rate estimate (hotness mass covered) — feeds the
        performance model's Eq. 7/8 cache term before any measurement."""
        return self._expected_hit_rate

    def measured_hit_rate(self) -> float:
        return self.stats.hit_rate

    def data_on(self, device) -> jax.Array:
        """The [K, F] hot block resident on ``device`` (placed once)."""
        key = id(device)
        if key not in self._device_data:
            self._device_data[key] = jax.device_put(self._host_rows, device)
        return self._device_data[key]

    # --------------------------------------------------------------- lookup

    def lookup(self, ids: np.ndarray) -> CacheLookup:
        """Vectorized id->slot partition of one frontier."""
        ids = np.asarray(ids, dtype=np.int64)
        slots = self.slot_of[ids]
        is_miss = slots < 0
        # rank of each miss among the misses = its row in the miss block
        miss_index = np.cumsum(is_miss, dtype=np.int32)
        miss_index = np.where(is_miss, miss_index - 1, 0).astype(np.int32)
        miss_ids = ids[is_miss]
        look = CacheLookup(ids=ids, slots=slots, miss_index=miss_index,
                           miss_ids=miss_ids)
        self.stats.merge(CacheStats(
            lookups=1, hit_rows=look.num_hit, miss_rows=look.num_miss,
            saved_bytes=look.num_hit * self.row_bytes))
        return look


def build_cache(dataset, fraction: float,
                transfer_dtype: str = "float32") -> Optional[FeatureCache]:
    """Cache of ``fraction`` of the dataset's nodes (None when <= 0)."""
    if fraction <= 0.0:
        return None
    capacity = int(round(dataset.num_nodes * min(fraction, 1.0)))
    if capacity == 0:
        return None
    return FeatureCache(dataset.feature_source, dataset.feature_hotness(),
                        capacity, transfer_dtype=transfer_dtype)
