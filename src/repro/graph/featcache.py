"""Device-resident hot-feature cache (static, degree-ordered) + frontier
deduplication.

HyScale-GNN hides host->device feature traffic behind prefetching; the
complementary levers (DistDGL-style hybrid systems, and the dominant ones
on feature-traffic-bound workloads) are to *not send* rows at all:

  * power-law frontiers are dominated by hub nodes, so pinning the top-K
    hottest node features in device memory converts most of each
    iteration's gather into a device-local lookup, and
  * with-replacement neighbor sampling re-references the same vertices
    many times per mini-batch, so gathering/shipping one row per *unique*
    node id (the paper's Feature-Duplicator rationale, Section IV-C:
    fetch once, duplicate locally) removes the remaining redundancy.

The cache is static: hotness is the expected gather frequency under
neighbor sampling (``GraphDataset.feature_hotness`` — in-edge mass + 1),
known at dataset-build time, so there is no invalidation protocol and the
id->slot table never changes during training.  A dynamic refresh policy is
future work (see ROADMAP).

Components:

  * ``slot_of``  — vectorized id->slot lookup, one int32 per node, -1 for
    uncached.  4 B/node of host memory buys O(1) batch partitioning
    (papers100M scale: ~440 MB, far below the feature matrix it indexes).
  * ``data_on(device)`` — the [K, F] hot-row block, placed once per
    trainer device and reused every iteration.
  * ``compact_lookup(ids)`` — cache-free frontier deduplication: unique
    ids + int32 inverse map, shared by cached and uncached transfer paths.
  * ``lookup(ids, dedup=True)`` — deduplicates the frontier, classifies
    only the uniques against the cache, and returns (slots, miss_index,
    miss_ids) where ``miss_ids`` holds one entry per *unique* miss and the
    positional tables point many frontier positions at one shipped row.

The loader (``featload.FeatureLoader``) gathers only ``miss_ids`` on the
host; the transfer stage ships the unique misses and a combine step
(Pallas tiled ``cache_combine`` kernel or its jnp reference) expands them
back into the dense positional layer-0 input on device — the duplication
happens after the interconnect, for free.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import numpy as np

from .storage import FeatureSource, as_feature_source

__all__ = ["CacheLookup", "CacheStats", "FeatureCache", "build_cache",
           "compact_lookup", "wire_row_bytes"]


def wire_row_bytes(feat_dim: int, transfer_dtype: str) -> int:
    """Bytes one feature row occupies on the wire (the transfer dtype) —
    the single definition both the cache and the loader account with."""
    return int(feat_dim) * np.dtype(
        np.float32 if transfer_dtype == "float32" else transfer_dtype
    ).itemsize


@dataclasses.dataclass
class CacheLookup:
    """Result of partitioning one frontier against the cache.

    The positional tables (``slots``/``miss_index``) always describe the
    full [N]-row frontier the GNN consumes.  Under deduplication the miss
    block is compacted to one row per unique miss id, so several positions
    share a ``miss_index`` entry — the on-device combine expands them.
    """
    ids: np.ndarray         # int64 [N] the queried node ids (positional)
    slots: np.ndarray       # int32 [N] cache slot per position, -1 = miss
    miss_index: np.ndarray  # int32 [N] row into the miss block (0 for hits)
    miss_ids: np.ndarray    # int64 [M] node ids to gather on the host
    unique_ids: np.ndarray  # int64 [U] deduped frontier (sorted; == ids
                            #   when dedup is off)
    inverse: np.ndarray     # int32 [N] position -> row in unique_ids

    @property
    def num_rows(self) -> int:
        return int(self.ids.shape[0])

    @property
    def num_unique(self) -> int:
        return int(self.unique_ids.shape[0])

    @property
    def num_miss(self) -> int:
        """Rows in the miss block (unique misses under dedup)."""
        return int(self.miss_ids.shape[0])

    @property
    def num_hit(self) -> int:
        """Frontier *positions* served by the cache."""
        return int(np.count_nonzero(self.slots >= 0))

    @property
    def miss_positions(self) -> int:
        return self.num_rows - self.num_hit

    @property
    def dup_miss_rows(self) -> int:
        """Positional miss rows that alias an already-shipped unique row."""
        return self.miss_positions - self.num_miss

    @property
    def hit_rate(self) -> float:
        return self.num_hit / max(self.num_rows, 1)

    @property
    def dup_factor(self) -> float:
        """Frontier duplication factor (positions per unique id, >= 1)."""
        return self.num_rows / max(self.num_unique, 1)


@dataclasses.dataclass
class CacheStats:
    lookups: int = 0
    hit_rows: int = 0        # frontier positions served by the cache
    miss_rows: int = 0       # frontier positions not in the cache
    unique_rows: int = 0     # unique ids across lookups (== total when
                             #   dedup is off)
    saved_bytes: int = 0     # host->device bytes avoided by cache hits
    dedup_saved_bytes: int = 0  # bytes avoided by shipping unique misses

    @property
    def total_rows(self) -> int:
        return self.hit_rows + self.miss_rows

    @property
    def hit_rate(self) -> float:
        return self.hit_rows / max(self.total_rows, 1)

    def merge(self, other: "CacheStats") -> None:
        self.lookups += other.lookups
        self.hit_rows += other.hit_rows
        self.miss_rows += other.miss_rows
        self.unique_rows += other.unique_rows
        self.saved_bytes += other.saved_bytes
        self.dedup_saved_bytes += other.dedup_saved_bytes


def compact_lookup(ids: np.ndarray,
                   slot_of: Optional[np.ndarray] = None) -> CacheLookup:
    """Deduplicate a frontier and (optionally) classify it against a cache.

    Computes the frontier's unique ids + int32 inverse map once
    (``np.unique``-based), classifies only the uniques against ``slot_of``
    (all-miss when ``None``), and builds the positional ``slots`` /
    ``miss_index`` tables by broadcasting the per-unique verdicts back
    through the inverse map — so the miss block holds one row per unique
    miss and many positions point at the same shipped row.
    """
    ids = np.asarray(ids, dtype=np.int64)
    unique_ids, inverse = np.unique(ids, return_inverse=True)
    inverse = inverse.astype(np.int32)
    if slot_of is None:
        uniq_slots = np.full(unique_ids.shape[0], -1, dtype=np.int32)
    else:
        uniq_slots = slot_of[unique_ids]
    is_miss = uniq_slots < 0
    # rank of each unique miss among the misses = its row in the miss block
    uniq_miss_index = np.cumsum(is_miss, dtype=np.int32)
    uniq_miss_index = np.where(is_miss, uniq_miss_index - 1, 0
                               ).astype(np.int32)
    return CacheLookup(ids=ids, slots=uniq_slots[inverse],
                       miss_index=uniq_miss_index[inverse],
                       miss_ids=unique_ids[is_miss],
                       unique_ids=unique_ids, inverse=inverse)


class FeatureCache:
    """Static top-K hot-row cache over any ``FeatureSource``.

    ``capacity`` rows are chosen by descending ``hotness``; the hot block
    is materialized once on the host (in ``transfer_dtype``) and placed
    per device on first use.
    """

    def __init__(self, source: "FeatureSource | np.ndarray",
                 hotness: np.ndarray, capacity: int,
                 transfer_dtype: str = "float32"):
        source = as_feature_source(source)
        num_nodes, feat_dim = source.shape
        capacity = int(max(0, min(capacity, num_nodes)))
        hotness = np.asarray(hotness, dtype=np.float64)
        if hotness.shape[0] != num_nodes:
            raise ValueError("hotness must have one entry per node")
        # stable order so equal-hotness ties are deterministic across runs
        order = np.argsort(-hotness, kind="stable")[:capacity]
        self.cached_ids = np.ascontiguousarray(order.astype(np.int64))
        self.capacity = capacity
        self.feat_dim = int(feat_dim)
        self.row_bytes = wire_row_bytes(feat_dim, transfer_dtype)
        self.slot_of = np.full(num_nodes, -1, dtype=np.int32)
        self.slot_of[self.cached_ids] = np.arange(capacity, dtype=np.int32)
        host_rows = source.take(self.cached_ids)
        if transfer_dtype != "float32":
            import jax.numpy as jnp
            host_rows = host_rows.astype(jnp.dtype(transfer_dtype))
        self._host_rows = np.ascontiguousarray(host_rows)
        self._device_data: Dict[int, jax.Array] = {}
        self._expected_hit_rate = (float(hotness[self.cached_ids].sum())
                                   / max(float(hotness.sum()), 1e-12))
        self.stats = CacheStats()

    # ------------------------------------------------------------- plumbing

    @property
    def nbytes(self) -> int:
        """Device bytes pinned by the hot block (per trainer device)."""
        return self._host_rows.nbytes

    @property
    def expected_hit_rate(self) -> float:
        """Design-time hit-rate estimate (hotness mass covered) — feeds the
        performance model's Eq. 7/8 cache term before any measurement."""
        return self._expected_hit_rate

    def measured_hit_rate(self) -> float:
        return self.stats.hit_rate

    def data_on(self, device) -> jax.Array:
        """The [K, F] hot block resident on ``device`` (placed once)."""
        key = id(device)
        if key not in self._device_data:
            self._device_data[key] = jax.device_put(self._host_rows, device)
        return self._device_data[key]

    # --------------------------------------------------------------- lookup

    def lookup(self, ids: np.ndarray, dedup: bool = True) -> CacheLookup:
        """Partition one frontier into cached slots and miss rows.

        ``dedup=True`` (the default) classifies only the frontier's unique
        ids and compacts the miss block to one row per unique miss;
        ``dedup=False`` reproduces the legacy positional path (one miss
        row per frontier position, in frontier order).

        Hit/miss stats always count frontier *positions* so the measured
        ``hit_rate`` stays comparable to ``expected_hit_rate`` regardless
        of dedup; the bytes dedup avoids are in ``dedup_saved_bytes``.
        """
        ids = np.asarray(ids, dtype=np.int64)
        if dedup:
            look = compact_lookup(ids, self.slot_of)
        else:
            slots = self.slot_of[ids]
            is_miss = slots < 0
            miss_index = np.cumsum(is_miss, dtype=np.int32)
            miss_index = np.where(is_miss, miss_index - 1, 0
                                  ).astype(np.int32)
            look = CacheLookup(
                ids=ids, slots=slots, miss_index=miss_index,
                miss_ids=ids[is_miss], unique_ids=ids,
                inverse=np.arange(ids.shape[0], dtype=np.int32))
        self.stats.merge(CacheStats(
            lookups=1, hit_rows=look.num_hit,
            miss_rows=look.miss_positions, unique_rows=look.num_unique,
            saved_bytes=look.num_hit * self.row_bytes,
            dedup_saved_bytes=look.dup_miss_rows * self.row_bytes))
        return look


def build_cache(dataset, fraction: float,
                transfer_dtype: str = "float32") -> Optional[FeatureCache]:
    """Cache of ``fraction`` of the dataset's nodes (None when <= 0)."""
    if fraction <= 0.0:
        return None
    capacity = int(round(dataset.num_nodes * min(fraction, 1.0)))
    if capacity == 0:
        return None
    return FeatureCache(dataset.feature_source, dataset.feature_hotness(),
                        capacity, transfer_dtype=transfer_dtype)
