"""Device-resident hot-feature cache (static, degree-ordered) + frontier
deduplication.

HyScale-GNN hides host->device feature traffic behind prefetching; the
complementary levers (DistDGL-style hybrid systems, and the dominant ones
on feature-traffic-bound workloads) are to *not send* rows at all:

  * power-law frontiers are dominated by hub nodes, so pinning the top-K
    hottest node features in device memory converts most of each
    iteration's gather into a device-local lookup, and
  * with-replacement neighbor sampling re-references the same vertices
    many times per mini-batch, so gathering/shipping one row per *unique*
    node id (the paper's Feature-Duplicator rationale, Section IV-C:
    fetch once, duplicate locally) removes the remaining redundancy.

The cache *boots* static: hotness is the expected gather frequency under
neighbor sampling (``GraphDataset.feature_hotness`` — in-edge mass + 1),
known at dataset-build time.  On workloads where the sampled hub set
drifts (or on graphs whose degree distribution is a poor hotness proxy)
the boot-time snapshot decays, so the cache also supports DistDGL-style
*dynamic admission*: with hotness tracking enabled (opt-in), every lookup
accumulates per-slot hit counters and a decayed hotness estimate for the
uncached ids it missed on, and
``refresh()`` evicts the coldest slots in favor of strictly-hotter
uncached nodes — updating the device-resident block in place with the
``cache_update`` scatter kernel (one aligned row-block DMA per admitted
node) instead of re-uploading all K rows.

Refreshing while the TFP pipeline has batches in flight needs a
consistency protocol: a lookup classified against the slot table at
version v must be combined against the *version-v* device block, or the
positional slot indices would read rows that were since evicted.  The
cache therefore keeps a monotonically increasing ``version``; every
``CacheLookup`` records the version it was classified against, old
versions are reconstructable for the last ``keep_versions`` bumps (sized
to the pipeline depth by the trainer), and ``data_on(device,
version=...)`` serves the matching block.  A refresh can thus never
corrupt batches already past the load stage.  Retention is an
O(swapped_rows) *undo log*, not full blocks: each version bump stores
only the evicted rows (slot indices + old row values), and an old host
block is rebuilt on demand by applying the log backwards from the
current one — device blocks already placed for an in-flight version stay
memoized until the pin protocol (or the ``keep_versions`` window)
retires them.

Components:

  * ``slot_of``  — vectorized id->slot lookup, one int32 per node, -1 for
    uncached.  4 B/node of host memory buys O(1) batch partitioning
    (papers100M scale: ~440 MB, far below the feature matrix it indexes).
    Refresh swaps in a rebuilt table atomically; lookups snapshot the
    reference, so a concurrent refresh can never tear a classification.
  * ``data_on(device, version=None)`` — the [K, F] hot-row block resident
    on ``device`` at the requested (default: current) version.
  * ``stage()`` / ``commit()`` — the refresh split into its expensive and
    cheap halves: ``stage`` plans the evict-coldest / admit-hottest swap
    (with an admission-hysteresis margin against boundary thrash) and
    gathers the admitted rows from the FeatureSource *outside* the cache
    lock — on the disk tier that gather used to block an iteration
    boundary, and can now run in a background thread; ``commit`` only
    swaps tables / scatter-updates device blocks, bumps ``version`` and
    resets the epoch stats window.  ``refresh()`` = stage + commit.
  * ``compact_lookup(ids)`` — cache-free frontier deduplication: unique
    ids + int32 inverse map, shared by cached and uncached transfer paths.
  * ``lookup(ids, dedup=True)`` — deduplicates the frontier, classifies
    only the uniques against the cache, and returns (slots, miss_index,
    miss_ids) where ``miss_ids`` holds one entry per *unique* miss and the
    positional tables point many frontier positions at one shipped row.

The loader (``featload.FeatureLoader``) gathers only ``miss_ids`` on the
host; the transfer stage ships the unique misses and a combine step
(Pallas tiled ``cache_combine`` kernel or its jnp reference) expands them
back into the dense positional layer-0 input on device — the duplication
happens after the interconnect, for free.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.analysis.annotations import guarded_by, requires_lock

from .storage import FeatureSource, as_feature_source

__all__ = ["CacheLookup", "CacheStats", "FeatureCache", "ShardLookup",
           "ShardPlacement", "ShardedFeatureCache", "UnionLookup",
           "build_cache", "build_sharded_cache", "compact_lookup",
           "wire_row_bytes"]


def wire_row_bytes(feat_dim: int, transfer_dtype: str) -> int:
    """Bytes one feature row occupies on the wire (the transfer dtype) —
    the single definition both the cache and the loader account with."""
    return int(feat_dim) * np.dtype(
        np.float32 if transfer_dtype == "float32" else transfer_dtype
    ).itemsize


@dataclasses.dataclass
class CacheLookup:
    """Result of partitioning one frontier against the cache.

    The positional tables (``slots``/``miss_index``) always describe the
    full [N]-row frontier the GNN consumes.  Under deduplication the miss
    block is compacted to one row per unique miss id, so several positions
    share a ``miss_index`` entry — the on-device combine expands them.
    """
    ids: np.ndarray         # int64 [N] the queried node ids (positional)
    slots: np.ndarray       # int32 [N] cache slot per position, -1 = miss
    miss_index: np.ndarray  # int32 [N] row into the miss block (0 for hits)
    miss_ids: np.ndarray    # int64 [M] node ids to gather on the host
    unique_ids: np.ndarray  # int64 [U] deduped frontier (sorted; == ids
                            #   when dedup is off)
    inverse: np.ndarray     # int32 [N] position -> row in unique_ids
    version: int = 0        # cache version this lookup was classified
                            #   against — the combine stage must pair the
                            #   slot table with the same-version device
                            #   block (0 for cache-less lookups)

    @property
    def num_rows(self) -> int:
        return int(self.ids.shape[0])

    @property
    def num_unique(self) -> int:
        return int(self.unique_ids.shape[0])

    @property
    def num_miss(self) -> int:
        """Rows in the miss block (unique misses under dedup)."""
        return int(self.miss_ids.shape[0])

    @property
    def num_hit(self) -> int:
        """Frontier *positions* served by the cache."""
        return int(np.count_nonzero(self.slots >= 0))

    @property
    def miss_positions(self) -> int:
        return self.num_rows - self.num_hit

    @property
    def dup_miss_rows(self) -> int:
        """Positional miss rows that alias an already-shipped unique row."""
        return self.miss_positions - self.num_miss

    @property
    def hit_rate(self) -> float:
        return self.num_hit / max(self.num_rows, 1)

    @property
    def dup_factor(self) -> float:
        """Frontier duplication factor (positions per unique id, >= 1)."""
        return self.num_rows / max(self.num_unique, 1)


@dataclasses.dataclass
class CacheStats:
    lookups: int = 0
    hit_rows: int = 0        # frontier positions served by the cache
    miss_rows: int = 0       # frontier positions not in the cache
    unique_rows: int = 0     # unique ids across lookups (== total when
                             #   dedup is off)
    saved_bytes: int = 0     # host->device bytes avoided by cache hits
    dedup_saved_bytes: int = 0  # bytes avoided by shipping unique misses

    @property
    def total_rows(self) -> int:
        return self.hit_rows + self.miss_rows

    @property
    def hit_rate(self) -> float:
        return self.hit_rows / max(self.total_rows, 1)

    def merge(self, other: "CacheStats") -> None:
        self.lookups += other.lookups
        self.hit_rows += other.hit_rows
        self.miss_rows += other.miss_rows
        self.unique_rows += other.unique_rows
        self.saved_bytes += other.saved_bytes
        self.dedup_saved_bytes += other.dedup_saved_bytes


def compact_lookup(ids: np.ndarray,
                   slot_of: Optional[np.ndarray] = None) -> CacheLookup:
    """Deduplicate a frontier and (optionally) classify it against a cache.

    Computes the frontier's unique ids + int32 inverse map once
    (``np.unique``-based), classifies only the uniques against ``slot_of``
    (all-miss when ``None``), and builds the positional ``slots`` /
    ``miss_index`` tables by broadcasting the per-unique verdicts back
    through the inverse map — so the miss block holds one row per unique
    miss and many positions point at the same shipped row.
    """
    ids = np.asarray(ids, dtype=np.int64)
    unique_ids, inverse = np.unique(ids, return_inverse=True)
    inverse = inverse.astype(np.int32)
    if slot_of is None:
        uniq_slots = np.full(unique_ids.shape[0], -1, dtype=np.int32)
    else:
        uniq_slots = slot_of[unique_ids]
    is_miss = uniq_slots < 0
    # rank of each unique miss among the misses = its row in the miss block
    uniq_miss_index = np.cumsum(is_miss, dtype=np.int32)
    uniq_miss_index = np.where(is_miss, uniq_miss_index - 1, 0
                               ).astype(np.int32)
    return CacheLookup(ids=ids, slots=uniq_slots[inverse],
                       miss_index=uniq_miss_index[inverse],
                       miss_ids=unique_ids[is_miss],
                       unique_ids=unique_ids, inverse=inverse)


@dataclasses.dataclass
class _StagedRefresh:
    """A planned-and-gathered refresh awaiting its cheap ``commit()``.

    ``base_version`` pins the slot table the plan was computed against: a
    commit (from any path) bumps the version, so a plan staged against an
    older table is stale and discarded instead of applied."""
    base_version: int
    top: np.ndarray       # admitted candidate ids (may be empty)
    cold: np.ndarray      # victim slot indices, int64, same length
    rows: np.ndarray      # gathered admitted rows in transfer dtype


# one lock covers the (slot_of, version) pair, the hotness counters, the
# stats windows, the staged plan and the version-retention state (undo
# log + floor + memoized device blocks).
# Deliberately undeclared: capacity/feat_dim/row_bytes (immutable),
# track_hotness/keep_versions/use_pallas_update/kernel_pipeline_depth/
# refresh_* (config knobs, set before any worker thread starts).
@guarded_by("_lock", "slot_of", "version", "cached_ids", "stats",
            "epoch_stats", "stage_failures", "refreshes",
            "refresh_swapped_rows", "_staged", "_slot_hot", "_node_hot",
            "_host_rows", "_undo", "_floor", "_device_data", "_devices",
            "_inflight")
class FeatureCache:
    """Top-K hot-row cache over any ``FeatureSource``.

    Boots static: ``capacity`` rows are chosen by descending ``hotness``
    and the hot block is materialized once on the host (in
    ``transfer_dtype``) and placed per device on first use.  From there
    every lookup feeds decayed hotness counters, and ``refresh()`` adapts
    the resident set to the *observed* access distribution (DistDGL-style
    admission) with versioned device snapshots for in-flight consistency.
    """

    def __init__(self, source: "FeatureSource | np.ndarray",
                 hotness: np.ndarray, capacity: int,
                 transfer_dtype: str = "float32",
                 refresh_decay: float = 0.5,
                 max_refresh_frac: float = 0.25,
                 refresh_hysteresis: float = 1.25):
        source = as_feature_source(source)
        num_nodes, feat_dim = source.shape
        capacity = int(max(0, min(capacity, num_nodes)))
        hotness = np.asarray(hotness, dtype=np.float64)
        if hotness.shape[0] != num_nodes:
            raise ValueError("hotness must have one entry per node")
        # stable order so equal-hotness ties are deterministic across runs
        order = np.argsort(-hotness, kind="stable")[:capacity]
        self.source = source
        self.transfer_dtype = transfer_dtype
        self.cached_ids = np.ascontiguousarray(order.astype(np.int64))
        self.capacity = capacity
        self.num_nodes = int(num_nodes)
        self.feat_dim = int(feat_dim)
        self.row_bytes = wire_row_bytes(feat_dim, transfer_dtype)
        self.slot_of = np.full(num_nodes, -1, dtype=np.int32)
        self.slot_of[self.cached_ids] = np.arange(capacity, dtype=np.int32)
        # the boot gather is maintenance, not load-stage traffic: exclude
        # it from a storage tier's stall/prefetch-hit counters
        self._host_rows = np.ascontiguousarray(
            self._cast_rows(self._maintenance_take(self.cached_ids)))
        self._expected_hit_rate = (float(hotness[self.cached_ids].sum())
                                   / max(float(hotness.sum()), 1e-12))
        self.stats = CacheStats()        # lifetime totals (traffic accounting)
        self.epoch_stats = CacheStats()  # since the last refresh (feedback)
        # ---- dynamic-refresh state -------------------------------------
        # one lock covers the (slot_of, version) pair, the hotness
        # counters, and the stats windows: lookups snapshot the table +
        # version together, refresh swaps them together
        self._lock = threading.RLock()
        self.version = 0
        self.keep_versions = 2           # trainer sizes this to tfp_depth+2
        self.use_pallas_update = False   # scatter-update kernel dispatch
        self.kernel_pipeline_depth = 1   # >1: multi-buffered scatter DMAs
        self.refresh_decay = float(refresh_decay)
        self.max_refresh_frac = float(max_refresh_frac)
        # admission hysteresis: a candidate must be hotter than its victim
        # by this factor to swap — a hub set oscillating right at the
        # admission boundary would otherwise thrash (swap in/out every
        # window).  1.0 reproduces the plain strictly-hotter policy.
        self.refresh_hysteresis = float(refresh_hysteresis)
        self.refreshes = 0               # refresh() calls that moved rows
        self.refresh_swapped_rows = 0
        self.fault_injector = None       # optional FaultInjector (hook:
                                         #   "refresh.stage")
        self.stage_failures = 0          # stage() attempts that raised
        self._staged: Optional[_StagedRefresh] = None
        # decayed hotness estimates: frontier *positions* observed per
        # cached slot / per uncached node since (decay-weighted) forever.
        # float32 keeps the uncached estimate at 4 B/node — same budget as
        # slot_of.  Tracking is opt-in (refresh-aware paths — the trainer
        # under its cache_refresh knob, the policy benchmark — switch it
        # on): a static cache pays neither the per-lookup scattered adds
        # nor the full-length estimate, which allocates lazily on the
        # first tracked lookup.
        self.track_hotness = False
        self._slot_hot = np.zeros(capacity, dtype=np.float32)
        self._node_hot: Optional[np.ndarray] = None
        # version retention: an O(swapped_rows) undo log instead of full
        # [K, F] blocks per version.  ``_undo[v]`` holds (victim slots,
        # their version-v row values) — the delta that rebuilds the
        # version-v host block from version v+1.  ``_floor`` is the
        # lowest still-reconstructable version; a device that never
        # placed a block before a refresh can still materialize any
        # retained version an in-flight lookup was classified against.
        self._undo: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        self._floor = 0
        self._device_data: Dict[Tuple[int, int], jax.Array] = {}
        self._devices: Dict[int, Any] = {}   # id(device) -> device handle
        # in-flight lookup pins: version -> count of pinned lookups not
        # yet released.  Pinning (lookup(pin=True) + release_lookup) is
        # the opt-in eager-retirement protocol: once every pin at a
        # version is released and a newer version exists, its full [K, F]
        # blocks are retired immediately instead of lingering for the
        # whole keep_versions window (ROADMAP undo-log item, cheap half).
        # keep_versions stays the hard retention bound either way, so
        # callers that never pin keep the PR-4 semantics exactly.
        self._inflight: Dict[int, int] = {}
        self._pin_used = False

    def _cast_rows(self, rows: np.ndarray) -> np.ndarray:
        if self.transfer_dtype != "float32":
            import jax.numpy as jnp
            rows = rows.astype(jnp.dtype(self.transfer_dtype))
        return rows

    def _maintenance_take(self, rows: np.ndarray) -> np.ndarray:
        """Gather rows as cache maintenance: on sources with stall
        accounting (``MmapFeatures``), excluded from the cold/warm and
        prefetch-hit counters — boot and refresh-admission gathers are
        not load-stage traffic and must not skew the stall metrics the
        task mapping re-prices on."""
        ctx = getattr(self.source, "untracked_gathers", None)
        if ctx is None:
            return self.source.take(rows)
        with ctx():
            return self.source.take(rows)

    # ------------------------------------------------------------- plumbing

    @property
    def nbytes(self) -> int:
        """Device bytes pinned by the hot block (per trainer device)."""
        with self._lock:
            return self._host_rows.nbytes

    @property
    def expected_hit_rate(self) -> float:
        """Design-time hit-rate estimate (hotness mass covered) — feeds the
        performance model's Eq. 7/8 cache term before any measurement."""
        return self._expected_hit_rate

    def measured_hit_rate(self) -> float:
        """Measured positional hit rate over the *current epoch window*
        (reset by ``refresh()``), so feedback consumers see the
        post-refresh rate instead of a lifetime average that still carries
        pre-refresh epochs; lifetime totals stay in ``stats``.

        Snapshotted under the cache lock: ``record_lookup`` merges the
        windows from the pipeline's load-stage thread, and an unlocked
        read could observe a half-merged (hit_rows bumped, miss_rows not
        yet) window — a torn hit rate that feedback consumers would act
        on."""
        with self._lock:
            if self.epoch_stats.total_rows:
                return self.epoch_stats.hit_rate
            return self.stats.hit_rate

    def slot_hotness(self) -> np.ndarray:
        """Decayed per-slot hotness estimate (copy, for tests/policy)."""
        with self._lock:
            return self._slot_hot.copy()

    def uncached_hotness(self, ids: np.ndarray) -> np.ndarray:
        """Decayed hotness estimate of (uncached) node ids (copy)."""
        ids = np.asarray(ids, dtype=np.int64)
        with self._lock:
            if self._node_hot is None:
                return np.zeros(ids.shape[0], dtype=np.float32)
            return self._node_hot[ids].copy()

    def data_on(self, device, version: Optional[int] = None) -> jax.Array:
        """The [K, F] hot block resident on ``device`` at ``version``
        (default: current).  Blocks are placed lazily: an old version's
        host block is rebuilt by applying the O(swapped_rows) undo log
        backwards from the current block — a device that never placed a
        block before a refresh can still materialize any retained version
        an in-flight lookup was classified against.  Versions older than
        the retention floor are gone for good: asking for one is a
        consistency bug and raises instead of silently serving
        mismatched rows."""
        with self._lock:
            ver = self.version if version is None else int(version)
            key = (id(device), ver)
            arr = self._device_data.get(key)
            if arr is None:
                if ver < self._floor or ver > self.version:
                    raise RuntimeError(
                        f"cache version {ver} retired (current "
                        f"{self.version}, keep_versions="
                        f"{self.keep_versions}): a lookup outlived the "
                        f"refresh retention window — raise keep_versions")
                host = self._host_rows
                if ver < self.version:
                    # walk the undo log backwards: each entry restores
                    # the rows its version bump evicted
                    host = host.copy()
                    for v in range(self.version - 1, ver - 1, -1):
                        slots, old_rows = self._undo[v]
                        host[slots] = old_rows
                # deliberate device dispatch under the lock: lazy
                # placement is memoized, so this runs once per (device,
                # version) — serializing it prevents two threads from
                # shipping the same [K, F] block twice
                arr = jax.device_put(host, device)  # noqa: RPR103 - memoized once per (device, version)
                self._device_data[key] = arr
                self._devices[id(device)] = device
        return arr

    # --------------------------------------------------------------- lookup

    def lookup(self, ids: np.ndarray, dedup: bool = True,
               record: bool = True, pin: bool = False) -> CacheLookup:
        """Partition one frontier into cached slots and miss rows.

        ``dedup=True`` (the default) classifies only the frontier's unique
        ids and compacts the miss block to one row per unique miss;
        ``dedup=False`` reproduces the legacy positional path (one miss
        row per frontier position, in frontier order).

        Hit/miss stats always count frontier *positions* so the measured
        ``hit_rate`` stays comparable to ``expected_hit_rate`` regardless
        of dedup; the bytes dedup avoids are in ``dedup_saved_bytes``.

        The (slot table, version) pair is snapshotted atomically, so a
        concurrent ``refresh()`` can never tear a classification; the
        returned lookup's ``version`` tells the combine stage which device
        snapshot to pair it with.  Each lookup also feeds the refresh
        policy's decayed hotness counters (positions per slot / per
        uncached id) — unless ``record=False``, in which case the caller
        classifies first and accounts later via ``record_lookup`` (the
        loader uses this so a gather that fails mid-way never leaves
        half-recorded stats behind).

        ``pin=True`` additionally registers the classification version as
        *in flight* — atomically with the snapshot, so a concurrent
        commit can never land between the two — and the caller promises
        exactly one ``release_lookup(look)`` once the dependent combine
        consumed its device block.  Pinned versions retire eagerly on
        release (see ``release_lookup``); unpinned callers keep the plain
        ``keep_versions`` retention window.
        """
        ids = np.asarray(ids, dtype=np.int64)
        slot_of, ver = self.snapshot(pin=1 if pin else 0)
        if dedup:
            look = compact_lookup(ids, slot_of)
        else:
            slots = slot_of[ids]
            is_miss = slots < 0
            miss_index = np.cumsum(is_miss, dtype=np.int32)
            miss_index = np.where(is_miss, miss_index - 1, 0
                                  ).astype(np.int32)
            look = CacheLookup(
                ids=ids, slots=slots, miss_index=miss_index,
                miss_ids=ids[is_miss], unique_ids=ids,
                inverse=np.arange(ids.shape[0], dtype=np.int32))
        look.version = ver
        if record:
            self.record_lookup(look)
        return look

    def snapshot(self, pin: int = 0) -> Tuple[np.ndarray, int]:
        """Atomically snapshot the (slot table, version) pair.  ``pin``
        registers that many in-flight references at the snapshot version
        (each owing one ``release_version``) — atomic with the snapshot,
        so a concurrent commit can never land between the two.  The
        sharded plane snapshots every shard once per union lookup and
        pins one reference per trainer."""
        with self._lock:
            if pin:
                self._pin_used = True
                self._inflight[self.version] = \
                    self._inflight.get(self.version, 0) + int(pin)
            # refresh swaps the slot_of reference, never mutates the
            # array in place, so the returned table is immutable
            return self.slot_of, self.version

    def release_lookup(self, look: CacheLookup) -> None:
        """Release one ``lookup(pin=True)`` registration.

        When the last pin at a version drops and a newer version exists,
        every retained block/undo entry of versions below the minimum
        still-in-flight one is retired immediately — the pipelined
        trainer holds at most tfp_depth lookups in flight, so device
        memory returns to one block per device as soon as the pipeline
        drains instead of after ``keep_versions`` further refreshes.
        Idempotence is the caller's job (exactly one release per pinned
        lookup); releasing an unpinned lookup is a no-op."""
        self.release_version(int(look.version))

    def release_version(self, version: int) -> None:
        """Release one pinned reference at ``version`` (the primitive
        behind ``release_lookup``; the sharded plane releases per-shard
        pins through it directly)."""
        with self._lock:
            ver = int(version)
            n = self._inflight.get(ver)
            if n is None:
                return
            if n > 1:
                self._inflight[ver] = n - 1
            else:
                del self._inflight[ver]
            self._retire_below_floor()

    @requires_lock("_lock")
    def _retire_below_floor(self) -> None:
        # caller holds _lock.  Retire versions no pinned lookup can still
        # reference; without any pinning opt-in the keep_versions window
        # in commit() remains the only retirement (PR-4 semantics).
        if not self._pin_used:
            return
        floor = min(self._inflight) if self._inflight else self.version
        floor = min(floor, self.version)   # never retire the current block
        if floor > self._floor:
            self._floor = floor
        for key in [k for k in self._device_data if k[1] < self._floor]:
            del self._device_data[key]
        for v in [v for v in self._undo if v < self._floor]:
            del self._undo[v]

    def retained_versions(self) -> list:
        """Sorted cache versions still reconstructable (the current one
        always included) — observability for tests/health."""
        with self._lock:
            return list(range(self._floor, self.version + 1))

    def retained_bytes(self) -> int:
        """Host bytes held by the version-retention undo log —
        O(swapped_rows per retained version), NOT full [K, F] blocks.
        The live current block is working state, not retention, and is
        excluded."""
        with self._lock:
            return sum(slots.nbytes + rows.nbytes
                       for slots, rows in self._undo.values())

    def record_lookup(self, look: CacheLookup) -> None:
        """Account one classified lookup: stats windows + hotness
        counters, applied atomically under the cache lock.  Split out of
        ``lookup`` so deferred-accounting callers (``record=False``) can
        commit the stats only once the dependent gather succeeded."""
        delta = CacheStats(
            lookups=1, hit_rows=look.num_hit,
            miss_rows=look.miss_positions, unique_rows=look.num_unique,
            saved_bytes=look.num_hit * self.row_bytes,
            dedup_saved_bytes=look.dup_miss_rows * self.row_bytes)
        hit = look.slots >= 0
        with self._lock:
            self.stats.merge(delta)
            self.epoch_stats.merge(delta)
            # hotness accounting: one count per frontier *position* (the
            # quantity the measured hit rate is defined over).  A lookup
            # classified at an older version lands its counts on the
            # current tables — bounded noise, the admission policy only
            # compares decayed estimates.  Gated so static-cache runs
            # (refresh off) keep the old lookup cost and never allocate
            # the full-length estimate.
            if self.track_hotness:
                if self._node_hot is None:
                    self._node_hot = np.zeros(self.num_nodes,
                                              dtype=np.float32)
                if self.capacity:
                    np.add.at(self._slot_hot, look.slots[hit],
                              np.float32(1.0))
                np.add.at(self._node_hot, look.ids[~hit], np.float32(1.0))

    def record_access(self, hit_slots: np.ndarray, hit_counts: np.ndarray,
                      miss_ids: np.ndarray, miss_counts: np.ndarray,
                      lookups: int = 1) -> None:
        """Account a pre-aggregated, position-weighted access pattern.

        The sharded plane classifies whole frontiers against their owner
        shards and records each shard's share in one call: ``hit_slots``
        / ``miss_ids`` are unique entries, ``*_counts`` carry how many
        frontier positions referenced each — the same position-weighted
        quantities ``record_lookup`` derives from a ``CacheLookup``, so
        hit rates and hotness estimates stay comparable across modes."""
        hit_rows = int(hit_counts.sum()) if hit_counts.size else 0
        miss_rows = int(miss_counts.sum()) if miss_counts.size else 0
        delta = CacheStats(
            lookups=int(lookups), hit_rows=hit_rows, miss_rows=miss_rows,
            unique_rows=int(hit_slots.shape[0] + miss_ids.shape[0]),
            saved_bytes=hit_rows * self.row_bytes)
        with self._lock:
            self.stats.merge(delta)
            self.epoch_stats.merge(delta)
            if self.track_hotness:
                if self._node_hot is None:
                    self._node_hot = np.zeros(self.num_nodes,
                                              dtype=np.float32)
                if self.capacity and hit_slots.size:
                    np.add.at(self._slot_hot, hit_slots,
                              hit_counts.astype(np.float32))
                if miss_ids.size:
                    np.add.at(self._node_hot, miss_ids,
                              miss_counts.astype(np.float32))

    def stats_snapshot(self) -> Tuple[CacheStats, CacheStats]:
        """(lifetime, epoch-window) stats copies, taken atomically —
        aggregation across shards must not observe half-merged windows."""
        with self._lock:
            return (dataclasses.replace(self.stats),
                    dataclasses.replace(self.epoch_stats))

    # -------------------------------------------------------------- refresh

    @property
    def staged_ready(self) -> bool:
        """True when a staged refresh awaits its ``commit()``."""
        with self._lock:
            return self._staged is not None

    @property
    def staged_swaps(self) -> int:
        """Swap count of the currently staged plan (0 when none)."""
        with self._lock:
            return 0 if self._staged is None else \
                int(self._staged.top.shape[0])

    def stage(self, max_swap: Optional[int] = None) -> int:
        """Plan the next refresh and gather its admitted rows OFF the
        critical path.

        Everything expensive happens here: the candidate scan + pairing
        under the lock (cheap), then the admitted-row gather from the
        ``FeatureSource`` with the lock RELEASED — on the disk tier that
        gather is the part that used to block an iteration boundary, and
        it can now run in a background thread while lookups proceed.  The
        plan is pinned to the slot-table version it was computed against;
        if another commit lands before the gather finishes, the stale
        plan is discarded (never applied against a reshuffled table).

        Candidate policy (unchanged from the one-shot ``refresh()``): the
        hottest uncached candidates pair hottest-first against the
        coldest-first slots; a pair swaps only while the candidate is
        hotter than ``refresh_hysteresis`` × its victim (the hysteresis
        margin keeps a boundary hub set from thrashing), so a refresh
        never replaces a row with a hotter-or-equal one evicted.  At most
        ``max_swap`` rows move (default ``max_refresh_frac`` of
        capacity).  Returns the planned swap count.

        Failure model: a stage that raises (source gather failure, or an
        injected ``refresh.stage`` fault) increments ``stage_failures``
        and leaves NO staged plan behind — the cache keeps serving the
        current version and a supervising trainer simply retries at the
        next drift boundary."""
        if self.fault_injector is not None:
            try:
                self.fault_injector.fire("refresh.stage")
            except BaseException:
                # counted under the lock: health() reads this from the
                # main thread while an async stage runs in the background
                with self._lock:
                    self.stage_failures += 1
                raise
        with self._lock:
            if self.capacity == 0:
                return 0
            cap = self.capacity
            k_max = max(1, int(round(cap * self.max_refresh_frac)))
            if max_swap is not None:
                k_max = int(max_swap)
            k_max = max(0, min(k_max, cap))
            # candidates: observed-miss ids that are (still) uncached
            if self._node_hot is None:       # no tracked traffic yet
                cand = np.zeros(0, dtype=np.int64)
            else:
                cand = np.flatnonzero(self._node_hot > 0.0).astype(np.int64)
                cand = cand[self.slot_of[cand] < 0]
            top = cold = np.zeros(0, dtype=np.int64)
            n_swap = 0
            if k_max and cand.shape[0]:
                k = min(k_max, cand.shape[0])
                top = cand[np.argpartition(-self._node_hot[cand], k - 1)[:k]]
                # hottest first, ties broken by id for determinism
                top = top[np.lexsort((top, -self._node_hot[top]))]
                # coldest slots first, ties broken by cached id
                cold = np.lexsort((self.cached_ids, self._slot_hot)
                                  )[:k].astype(np.int64)
                # admit_hot desc vs evict_hot asc: the hotter-by-a-factor
                # predicate is monotone, so the swap set is a prefix
                n_swap = int(np.count_nonzero(
                    self._node_hot[top] > np.float32(self.refresh_hysteresis)
                    * self._slot_hot[cold]))
            top, cold = top[:n_swap], cold[:n_swap]
            base = self.version
            host_dtype = self._host_rows.dtype
        # EXPENSIVE: the admitted-row gather runs OUTSIDE the lock —
        # concurrent lookups never wait on the storage tier (and it is
        # maintenance traffic: excluded from the load-stall counters it
        # would otherwise race when staged in a background thread)
        if n_swap:
            try:
                rows = np.ascontiguousarray(
                    self._cast_rows(self._maintenance_take(top)))
            except Exception:
                # failed admission gather: count it and propagate with no
                # staged plan left behind (the old version keeps serving)
                with self._lock:
                    self.stage_failures += 1
                raise
        else:
            rows = np.zeros((0, self.feat_dim), host_dtype)
        with self._lock:
            if self.version != base:
                # a commit landed while we gathered: victims/candidates
                # were computed against a retired table — drop the plan
                self._staged = None
                return 0
            self._staged = _StagedRefresh(base, top, cold, rows)
            return n_swap

    def discard_staged(self) -> int:
        """Drop a staged-but-uncommitted refresh plan (degraded-mode
        cleanup after a failed/suspect stage): the cache keeps serving
        the current version unchanged.  Returns the number of swaps
        discarded (0 when nothing was staged)."""
        with self._lock:
            plan, self._staged = self._staged, None
            return 0 if plan is None else int(plan.top.shape[0])

    def commit(self) -> int:
        """Apply the staged refresh: the cheap synchronous half.

        Only table swaps and device row-block scatters happen here — no
        FeatureSource access, so on the disk tier an iteration boundary
        pays O(swapped rows) DMAs instead of a storage gather.  The
        admission predicate is re-validated pair-by-pair against the
        *commit-time* counters (lookups kept accumulating while the
        staged gather ran), so the never-admit-colder guarantee holds at
        the moment the swap becomes visible.  Every commit of a staged
        plan is a hotness window boundary (counters decay); a stale or
        absent plan returns 0 and changes nothing.

        When rows move: ``version`` is bumped, each device-resident
        current-version block is scatter-updated in place (one aligned
        row-block DMA per admitted node via ``kernels.ops
        .update_cache_rows``; snapshots older than ``keep_versions`` are
        retired), and the epoch stats window resets so measured-rate
        consumers see the post-refresh rate.  Returns the number of rows
        swapped."""
        from repro.kernels.ops import update_cache_rows
        with self._lock:
            plan, self._staged = self._staged, None
            if plan is None or plan.base_version != self.version:
                return 0
            top, cold, rows = plan.top, plan.cold, plan.rows
            n_swap = int(top.shape[0])
            if n_swap:
                # re-validate against commit-time counters: a pair whose
                # victim heated up (or candidate cooled) past the
                # hysteresis margin while the gather ran no longer swaps
                keep = (self._node_hot[top]
                        > np.float32(self.refresh_hysteresis)
                        * self._slot_hot[cold])
                top, cold, rows = top[keep], cold[keep], rows[keep]
                n_swap = int(top.shape[0])
            if n_swap:
                evicted = self.cached_ids[cold].copy()
                new_slot_of = self.slot_of.copy()
                new_slot_of[evicted] = -1
                new_slot_of[top] = cold.astype(np.int32)
                new_cached = self.cached_ids.copy()
                new_cached[cold] = top
                # copy-on-write, never in place: on the CPU backend
                # jax.device_put can alias the host buffer, so mutating
                # _host_rows would corrupt previously-placed (old-version)
                # device blocks that in-flight payloads still combine with
                new_host = self._host_rows.copy()
                new_host[cold] = rows
                # O(swapped) undo entry: the evicted rows at their victim
                # slots rebuild this (old) version from the new block
                slots32 = cold.astype(np.int32)
                self._undo[self.version] = (
                    slots32, self._host_rows[cold].copy())
                # estimates travel with their nodes
                admit_est = self._node_hot[top].copy()
                self._node_hot[evicted] = self._slot_hot[cold]
                self._slot_hot[cold] = admit_est
                self._node_hot[top] = 0.0
                new_ver = self.version + 1
                # deliberate device dispatch under the lock: commit IS
                # the designed cheap half — O(swapped rows) scatter DMAs
                # that must be atomic with the table/version swap, or a
                # concurrent lookup could pair the new table with an
                # un-updated block
                for dev_key, dev in self._devices.items():
                    cur = self._device_data.get((dev_key, self.version))
                    if cur is not None:
                        self._device_data[(dev_key, new_ver)] = \
                            update_cache_rows(
                                cur, jax.device_put(rows, dev), slots32,  # noqa: RPR103 - atomic O(swap) commit by design
                                use_pallas=self.use_pallas_update,
                                pipeline_depth=self.kernel_pipeline_depth)
                self.slot_of = new_slot_of
                self.cached_ids = new_cached
                self._host_rows = new_host
                self.version = new_ver
                # retire snapshots no in-flight lookup can still reference
                low = new_ver - max(int(self.keep_versions), 1) + 1
                if low > self._floor:
                    self._floor = low
                for key in [key for key in self._device_data
                            if key[1] < self._floor]:
                    del self._device_data[key]
                for v in [v for v in self._undo if v < self._floor]:
                    del self._undo[v]
                # pins that leaked past the retention window (a batch
                # dropped by a pipeline failure never reaches its
                # release) can no longer be served anyway — age them out
                # so one leak does not disable eager retirement forever
                for v in [v for v in self._inflight if v < low]:
                    del self._inflight[v]
                # pinned-lookup protocol: drained versions retire NOW
                # instead of aging out of the keep_versions window
                self._retire_below_floor()
                self.epoch_stats = CacheStats()
                self.refreshes += 1
                self.refresh_swapped_rows += n_swap
            # window boundary: old hotness fades relative to the next epoch
            self._slot_hot *= np.float32(self.refresh_decay)
            if self._node_hot is not None:
                self._node_hot *= np.float32(self.refresh_decay)
            return n_swap

    def refresh(self, max_swap: Optional[int] = None) -> int:
        """One-shot refresh: ``stage()`` + ``commit()`` back to back.

        Semantics are unchanged from the pre-staged implementation (same
        plan, same swap, one counter decay per call); the split exists so
        ``async_refresh`` runs the expensive ``stage()`` gather in a
        background thread and keeps only the cheap ``commit()`` on the
        iteration boundary.  Returns the number of rows swapped."""
        self.stage(max_swap)
        return self.commit()


def build_cache(dataset, fraction: float,
                transfer_dtype: str = "float32",
                refresh_decay: float = 0.5,
                max_refresh_frac: float = 0.25,
                refresh_hysteresis: float = 1.25) -> Optional[FeatureCache]:
    """Cache of ``fraction`` of the dataset's nodes (None when <= 0)."""
    if fraction <= 0.0:
        return None
    capacity = int(round(dataset.num_nodes * min(fraction, 1.0)))
    if capacity == 0:
        return None
    return FeatureCache(dataset.feature_source, dataset.feature_hotness(),
                        capacity, transfer_dtype=transfer_dtype,
                        refresh_decay=refresh_decay,
                        max_refresh_frac=max_refresh_frac,
                        refresh_hysteresis=refresh_hysteresis)


# ====================================================================
# Sharded hot-feature plane: disjoint per-accelerator shards + the
# union-gather classification (DistDGL/P3 partitioned feature server
# collapsed into one node).
# ====================================================================


def _mix64(x: np.ndarray) -> np.ndarray:
    """SplitMix64 finalizer: deterministic avalanching id hash so hash
    placement spreads hub nodes uniformly across shards (consecutive ids
    land on unrelated shards)."""
    z = x.astype(np.uint64) + np.uint64(0x9E3779B97F4A7C15)
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


class ShardPlacement:
    """Disjoint, exhaustive node-id -> shard ownership.

    ``hash``: SplitMix64-mixed id modulo ``n_shards`` — hubs spread
    uniformly, so every shard caches a same-shaped slice of the hot set
    (the default; best effective capacity at equal per-shard size).
    ``degree``: contiguous hotness-rank ranges — shard 0 owns the
    hottest ceil(N/n) nodes, shard 1 the next range, and so on
    (locality-style placement; per-shard hit rates are skewed by
    construction, trainers on high shards serve mostly peers).

    Both are pure functions of (num_nodes, n_shards, policy, hotness):
    every shard and every trainer derives the identical owner table."""

    POLICIES = ("hash", "degree")

    def __init__(self, num_nodes: int, n_shards: int,
                 policy: str = "hash",
                 hotness: Optional[np.ndarray] = None):
        if policy not in self.POLICIES:
            raise ValueError(f"unknown shard placement {policy!r} "
                             f"(choose from {self.POLICIES})")
        self.num_nodes = int(num_nodes)
        self.n_shards = int(max(1, n_shards))
        self.policy = policy
        if policy == "hash":
            ids = np.arange(self.num_nodes, dtype=np.uint64)
            owner = (_mix64(ids) % np.uint64(self.n_shards)).astype(np.int32)
        else:
            if hotness is None:
                raise ValueError("degree placement needs a hotness vector")
            hotness = np.asarray(hotness, dtype=np.float64)
            # stable order: equal-hotness ties deterministic across runs
            rank = np.argsort(-hotness, kind="stable")
            span = max(1, -(-self.num_nodes // self.n_shards))
            owner = np.empty(self.num_nodes, dtype=np.int32)
            owner[rank] = (np.arange(self.num_nodes) // span
                           ).astype(np.int32)
        self.owner = owner

    def owner_of(self, ids: np.ndarray) -> np.ndarray:
        """Owning shard ordinal per id (int32, vectorized)."""
        return self.owner[np.asarray(ids, dtype=np.int64)]


@dataclasses.dataclass
class ShardLookup:
    """One trainer's frontier classified against the sharded plane.

    ``look`` is a ``CacheLookup`` against the trainer's LOCAL shard:
    ``slots`` index the local [K_me, F] device block (-1 otherwise),
    ``miss_index`` points into the combined transfer source
    ``[peer rows (ring order) | fresh host rows]`` and ``miss_ids``
    holds only the FRESH unique ids the host must gather.
    ``peer_requests`` name the rows to pull over ICI from each peer
    shard, pinned at that shard's classification version."""
    look: CacheLookup
    shard: int                    # the trainer's own shard ordinal
    peer_requests: List[Tuple[int, np.ndarray, int]]
    pinned: List[Tuple[int, int]]  # (shard, version) pins to release
    peer_rows: int = 0            # unique rows pulled over ICI
    peer_positions: int = 0       # frontier positions served by peers
    local_positions: int = 0      # frontier positions served locally


@dataclasses.dataclass
class UnionLookup:
    """All trainers' classifications for one pipeline batch, plus the
    per-shard accounting payload deferred until the union gather
    succeeds (mirrors the ``record=False`` protocol of ``lookup``)."""
    per_trainer: Dict[str, ShardLookup]
    record_payload: List[tuple]


# the lock only covers the memoized merged slot table; the shards guard
# their own state, and placement/row_bytes/shards are immutable after
# construction.
@guarded_by("_lock", "_merged_key", "_merged_table")
class ShardedFeatureCache:
    """Partitioned hot-feature plane: ``n_shards`` disjoint per-device
    ``FeatureCache`` shards over one source, giving n× effective
    capacity at the same per-device budget.

    A frontier position resolves in priority order: local shard hit
    (device-resident) → peer shard hit (one row hop over ICI via
    ``repro.dist.collectives.exchange_peer_rows``) → host miss.  Host
    misses are gathered once for the *union* of all trainers'
    fresh-miss sets (``FeatureLoader.load_union``) and each row is
    multicast only to the devices that need it.

    Each shard keeps its own version/pin protocol; a union lookup
    snapshots every shard once and pins one reference per trainer, so a
    mid-pipeline refresh of any shard stays semantically invisible
    exactly as in the replicated plane."""

    def __init__(self, source: "FeatureSource | np.ndarray",
                 hotness: np.ndarray, capacity_per_shard: int,
                 n_shards: int, placement: str = "hash",
                 transfer_dtype: str = "float32", **refresh_kw):
        source = as_feature_source(source)
        num_nodes, feat_dim = source.shape
        hotness = np.asarray(hotness, dtype=np.float64)
        if hotness.shape[0] != num_nodes:
            raise ValueError("hotness must have one entry per node")
        self.num_nodes = int(num_nodes)
        self.feat_dim = int(feat_dim)
        self.n_shards = int(max(1, n_shards))
        self.transfer_dtype = transfer_dtype
        self.row_bytes = wire_row_bytes(feat_dim, transfer_dtype)
        self.placement = ShardPlacement(num_nodes, self.n_shards,
                                        placement, hotness)
        hmin = float(hotness.min()) if num_nodes else 0.0
        self.shards: List[FeatureCache] = []
        for d in range(self.n_shards):
            owned = self.placement.owner == d
            # shift owned hotness strictly positive and zero the rest:
            # the shard's top-K pick can then never leak a non-owned id
            # (disjointness by construction), capped at the owned count
            h_d = np.where(owned, hotness - hmin + 1.0, 0.0)
            cap_d = int(min(int(capacity_per_shard), int(owned.sum())))
            self.shards.append(
                FeatureCache(source, h_d, cap_d,
                             transfer_dtype=transfer_dtype, **refresh_kw))
        mass = sum(float(hotness[s.cached_ids].sum()) for s in self.shards)
        self._expected_hit_rate = mass / max(float(hotness.sum()), 1e-12)
        self._lock = threading.RLock()
        self._merged_key: Optional[tuple] = None
        self._merged_table: Optional[np.ndarray] = None

    # ------------------------------------------------------------ plumbing

    @property
    def capacity(self) -> int:
        """Total resident rows across shards (the n× effective capacity)."""
        return sum(s.capacity for s in self.shards)

    @property
    def nbytes(self) -> int:
        """Device bytes pinned across ALL shards (one shard per device;
        the per-device budget is a single shard's block)."""
        return sum(s.nbytes for s in self.shards)

    @property
    def expected_hit_rate(self) -> float:
        """Hotness mass covered by the UNION of the shards — the plane's
        design-time (local + peer) hit estimate for Eq. 7/8."""
        return self._expected_hit_rate

    @property
    def version(self) -> int:
        """Monotone aggregate version (sum of shard versions): bumps
        whenever any shard refreshes, for drift/metrics consumers."""
        return sum(s.snapshot()[1] for s in self.shards)

    @property
    def slot_of(self) -> np.ndarray:
        """Merged id -> slot table (slot within the OWNER shard's block;
        >= 0 means resident somewhere in the plane).  Consumers — the
        prefetch submit filter, the dup-factor probe — only ask "cached
        anywhere?"; memoized per shard-version vector."""
        snaps = [s.snapshot() for s in self.shards]
        key = tuple(v for _, v in snaps)
        with self._lock:
            if key == self._merged_key and self._merged_table is not None:
                return self._merged_table
        merged = np.full(self.num_nodes, -1, dtype=np.int32)
        for table, _ in snaps:
            resident = table >= 0
            # shards own disjoint id sets: blind scatter cannot collide
            merged[resident] = table[resident]
        with self._lock:
            self._merged_key, self._merged_table = key, merged
            return self._merged_table

    # config knobs forwarded to every shard ------------------------------

    @property
    def keep_versions(self) -> int:
        return self.shards[0].keep_versions

    @keep_versions.setter
    def keep_versions(self, value: int) -> None:
        for s in self.shards:
            s.keep_versions = value

    @property
    def track_hotness(self) -> bool:
        return self.shards[0].track_hotness

    @track_hotness.setter
    def track_hotness(self, value: bool) -> None:
        for s in self.shards:
            s.track_hotness = value

    @property
    def use_pallas_update(self) -> bool:
        return self.shards[0].use_pallas_update

    @use_pallas_update.setter
    def use_pallas_update(self, value: bool) -> None:
        for s in self.shards:
            s.use_pallas_update = value

    @property
    def kernel_pipeline_depth(self) -> int:
        return self.shards[0].kernel_pipeline_depth

    @kernel_pipeline_depth.setter
    def kernel_pipeline_depth(self, value: int) -> None:
        for s in self.shards:
            s.kernel_pipeline_depth = value

    @property
    def fault_injector(self):
        return self.shards[0].fault_injector

    @fault_injector.setter
    def fault_injector(self, value) -> None:
        for s in self.shards:
            s.fault_injector = value

    # aggregated health/observability ------------------------------------

    @property
    def stage_failures(self) -> int:
        return sum(s.stage_failures for s in self.shards)

    @property
    def refreshes(self) -> int:
        return sum(s.refreshes for s in self.shards)

    @property
    def refresh_swapped_rows(self) -> int:
        return sum(s.refresh_swapped_rows for s in self.shards)

    @property
    def staged_ready(self) -> bool:
        return any(s.staged_ready for s in self.shards)

    def measured_hit_rate(self) -> float:
        """Aggregate positional (local + peer) hit rate over the shards'
        current epoch windows, falling back to lifetime totals — the
        same feedback quantity the replicated cache reports."""
        epoch_hit = epoch_tot = life_hit = life_tot = 0
        for s in self.shards:
            life, epoch = s.stats_snapshot()
            epoch_hit += epoch.hit_rows
            epoch_tot += epoch.total_rows
            life_hit += life.hit_rows
            life_tot += life.total_rows
        if epoch_tot:
            return epoch_hit / epoch_tot
        return life_hit / max(life_tot, 1)

    def retained_versions(self) -> Dict[int, list]:
        """Per-shard retained-version ranges (observability)."""
        return {d: s.retained_versions()
                for d, s in enumerate(self.shards)}

    def retained_bytes(self) -> int:
        """Undo-log retention bytes summed across shards."""
        return sum(s.retained_bytes() for s in self.shards)

    # ------------------------------------------------------ union lookup

    def lookup_union(self, frontiers: Dict[str, np.ndarray],
                     ordinals: Dict[str, int], pin: bool = False,
                     record: bool = True) -> UnionLookup:
        """Classify every trainer's frontier against the plane in one
        pass: local-shard hits, peer-shard hits (grouped per owner in
        ring order from each trainer's ordinal) and fresh host misses.

        Every shard is snapshotted once (atomically per shard) and, with
        ``pin=True``, pinned once per trainer — the trainer releases all
        of a batch's pins via ``release_union`` after its combine.  With
        ``record=False`` the per-shard stats/hotness accounting is
        returned in the payload and applied later by ``record_union``
        (the loader defers it past the union gather, mirroring the
        replicated ``record=False`` protocol)."""
        from repro.dist.collectives import ring_order
        npin = len(frontiers) if pin else 0
        snaps = [s.snapshot(pin=npin) for s in self.shards]
        tables = [t for t, _ in snaps]
        vers = [v for _, v in snaps]
        owner_all = self.placement.owner
        acc = [{"hs": [], "hc": [], "mi": [], "mc": [], "lk": 0}
               for _ in range(self.n_shards)]
        per: Dict[str, ShardLookup] = {}
        for name in sorted(frontiers):
            me = int(ordinals[name])
            ids = np.asarray(frontiers[name], dtype=np.int64)
            uniq, inverse = np.unique(ids, return_inverse=True)
            inverse = inverse.astype(np.int32)
            counts = np.bincount(inverse, minlength=uniq.shape[0])
            owner = owner_all[uniq]
            uslots = np.full(uniq.shape[0], -1, dtype=np.int32)
            for d in range(self.n_shards):
                sel = owner == d
                if sel.any():
                    uslots[sel] = tables[d][uniq[sel]]
            hit = uslots >= 0
            # combined transfer-source index per unique: peer rows first
            # (ring order from me, each group in sorted-id order), then
            # the fresh host-gathered rows — deterministic layout shared
            # with the transfer stage's source concatenation
            u_midx = np.zeros(uniq.shape[0], dtype=np.int32)
            base = 0
            peer_requests: List[Tuple[int, np.ndarray, int]] = []
            peer_rows = peer_pos = 0
            for p in ring_order(self.n_shards, me):
                sel = hit & (owner == p)
                k = int(np.count_nonzero(sel))
                if k:
                    u_midx[sel] = base + np.arange(k, dtype=np.int32)
                    peer_requests.append(
                        (p, uslots[sel].astype(np.int32), vers[p]))
                    peer_rows += k
                    peer_pos += int(counts[sel].sum())
                    base += k
            fresh = ~hit
            n_fresh = int(np.count_nonzero(fresh))
            if n_fresh:
                u_midx[fresh] = base + np.arange(n_fresh, dtype=np.int32)
            local_sel = hit & (owner == me)
            slots_u = np.where(local_sel, uslots,
                               np.int32(-1)).astype(np.int32)
            look = CacheLookup(
                ids=ids, slots=slots_u[inverse],
                miss_index=u_midx[inverse], miss_ids=uniq[fresh],
                unique_ids=uniq, inverse=inverse, version=vers[me])
            per[name] = ShardLookup(
                look=look, shard=me, peer_requests=peer_requests,
                pinned=([(d, vers[d]) for d in range(self.n_shards)]
                        if pin else []),
                peer_rows=peer_rows, peer_positions=peer_pos,
                local_positions=int(counts[local_sel].sum()))
            # hotness/stats land on the OWNER shard (position-weighted):
            # refresh admission then only ever considers owned ids, so
            # shard disjointness survives every refresh
            for d in range(self.n_shards):
                seld = owner == d
                h = seld & hit
                m = seld & fresh
                a = acc[d]
                a["lk"] += 1
                if h.any():
                    a["hs"].append(uslots[h])
                    a["hc"].append(counts[h])
                if m.any():
                    a["mi"].append(uniq[m])
                    a["mc"].append(counts[m])
        payload = []
        for d, a in enumerate(acc):
            payload.append((
                d,
                np.concatenate(a["hs"]) if a["hs"] else
                np.zeros(0, dtype=np.int32),
                np.concatenate(a["hc"]) if a["hc"] else
                np.zeros(0, dtype=np.int64),
                np.concatenate(a["mi"]) if a["mi"] else
                np.zeros(0, dtype=np.int64),
                np.concatenate(a["mc"]) if a["mc"] else
                np.zeros(0, dtype=np.int64),
                a["lk"]))
        union = UnionLookup(per_trainer=per, record_payload=payload)
        if record:
            self.record_union(union)
        return union

    def record_union(self, union: UnionLookup) -> None:
        """Apply a deferred union lookup's per-shard accounting."""
        for d, hs, hc, mi, mc, lk in union.record_payload:
            self.shards[d].record_access(hs, hc, mi, mc, lookups=lk)
        union.record_payload = []

    def release_union(self, shard_look: ShardLookup) -> None:
        """Release one trainer's per-shard pins for one batch."""
        for d, ver in shard_look.pinned:
            self.shards[d].release_version(ver)
        shard_look.pinned = []

    # ------------------------------------------------------------ refresh

    def stage(self, max_swap: Optional[int] = None) -> int:
        return sum(s.stage(max_swap) for s in self.shards)

    def commit(self) -> int:
        return sum(s.commit() for s in self.shards)

    def discard_staged(self) -> int:
        return sum(s.discard_staged() for s in self.shards)

    def refresh(self, max_swap: Optional[int] = None) -> int:
        self.stage(max_swap)
        return self.commit()


def build_sharded_cache(dataset, fraction: float, n_shards: int,
                        placement: str = "hash",
                        transfer_dtype: str = "float32",
                        refresh_decay: float = 0.5,
                        max_refresh_frac: float = 0.25,
                        refresh_hysteresis: float = 1.25
                        ) -> Optional[ShardedFeatureCache]:
    """Sharded plane at the SAME per-device budget as ``build_cache``:
    ``fraction`` of the dataset's nodes *per shard*, so n shards hold up
    to n× the replicated row count (None when the budget rounds to 0)."""
    if fraction <= 0.0 or n_shards < 1:
        return None
    capacity = int(round(dataset.num_nodes * min(fraction, 1.0)))
    if capacity == 0:
        return None
    return ShardedFeatureCache(
        dataset.feature_source, dataset.feature_hotness(), capacity,
        n_shards, placement=placement, transfer_dtype=transfer_dtype,
        refresh_decay=refresh_decay, max_refresh_frac=max_refresh_frac,
        refresh_hysteresis=refresh_hysteresis)
