from .storage import (CSRGraph, GraphDataset, HashedFeatures, DATASET_STATS,
                      make_dataset, synth_powerlaw_graph)
from .sampler import MiniBatch, NumpySampler, sample_minibatch_jax, frontier_sizes
from .featload import FeatureLoader, LoadStats
from .models import GNNConfig, init_params, forward, loss_fn, param_count

__all__ = [
    "CSRGraph", "GraphDataset", "HashedFeatures", "DATASET_STATS",
    "make_dataset", "synth_powerlaw_graph",
    "MiniBatch", "NumpySampler", "sample_minibatch_jax", "frontier_sizes",
    "FeatureLoader", "LoadStats",
    "GNNConfig", "init_params", "forward", "loss_fn", "param_count",
]
