# Graph data layer — architecture note
#
# storage.py   FeatureSource protocol + backends (dense / hashed /
#              partitioned / mmap out-of-core); gather-only interface.
#              MmapFeatures spills any source to per-partition disk blobs
#              (one partition of RAM, ever) and maps windows lazily.
# featcache.py device-resident top-K hot-row cache over any FeatureSource
#              (boots hotness-ordered; vectorized id->slot lookup; dynamic
#              refresh swaps cold slots for observed-hot uncached nodes
#              with versioned device snapshots for in-flight consistency).
#              ShardedFeatureCache partitions the hot set into disjoint
#              per-accelerator shards (hash / degree-range placement) and
#              classifies union lookups into local / peer / host tiers.
# featload.py  host gather stage: full-frontier loads for CPU trainers,
#              miss-only loads for cache-backed accelerator trainers.
# prefetch.py  WindowPrefetcher: background thread pre-faulting the NEXT
#              batch's mmap partition windows (lookahead from the TFP
#              sample stage) so the load stage gathers warm pages;
#              supervised (restart budget) with graceful degradation.
# faults.py    deterministic fault injection for the data plane: seeded,
#              schedulable FaultInjector raising transient/permanent
#              OSErrors, delaying I/O, or killing background workers at
#              named hooks — chaos tests replay exact failure schedules.
# sampler.py   fixed-shape neighbor sampling (numpy host / jit device).
# models.py    GCN / GraphSAGE on sampled blocks (dense/segsum/pallas agg).
#
# Data flows sampler -> loader -> transfer -> (on-device cache combine /
# dedup expansion) -> model; only *unique miss* rows ever cross the
# host->device interconnect — frontiers are deduplicated before the cache
# lookup and the positional layout is rebuilt on device.
from .storage import (CSRGraph, DenseFeatures, FeatureSource, GraphDataset,
                      HashedFeatures, MmapFeatures, PartitionedFeatures,
                      DATASET_STATS, as_feature_source, make_dataset,
                      synth_powerlaw_graph)
from .sampler import MiniBatch, NumpySampler, sample_minibatch_jax, frontier_sizes
from .featcache import (CacheLookup, CacheStats, FeatureCache, ShardLookup,
                        ShardPlacement, ShardedFeatureCache, UnionLookup,
                        build_cache, build_sharded_cache, compact_lookup)
from .featload import FeatureLoader, LoadStats, MissBlock, ShardMissBlock
from .prefetch import WindowPrefetcher
from .faults import FaultInjector, FaultSpec, WorkerKilled
from .models import GNNConfig, init_params, forward, loss_fn, param_count

__all__ = [
    "CSRGraph", "GraphDataset", "HashedFeatures", "DenseFeatures",
    "PartitionedFeatures", "MmapFeatures", "FeatureSource",
    "as_feature_source",
    "DATASET_STATS", "make_dataset", "synth_powerlaw_graph",
    "MiniBatch", "NumpySampler", "sample_minibatch_jax", "frontier_sizes",
    "CacheLookup", "CacheStats", "FeatureCache", "ShardLookup",
    "ShardPlacement", "ShardedFeatureCache", "UnionLookup", "build_cache",
    "build_sharded_cache", "compact_lookup",
    "FeatureLoader", "LoadStats", "MissBlock", "ShardMissBlock",
    "WindowPrefetcher",
    "FaultInjector", "FaultSpec", "WorkerKilled",
    "GNNConfig", "init_params", "forward", "loss_fn", "param_count",
]
