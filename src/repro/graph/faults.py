"""Deterministic fault injection for the storage / prefetch / refresh /
pipeline data plane.

Failure model & degraded modes
==============================

PRs 1-6 grew a deep asynchronous data plane (mmap storage tier, background
``WindowPrefetcher``, staged async cache refresh, ``PrefetchPipeline``
worker threads, thread-pool gathers).  This module is the chaos half of
its robustness story: a **seeded, schedulable** ``FaultInjector`` that the
data-plane components consult at well-defined hook points, so every
failure mode has a deterministic, replayable test.  The protocol the
faults exercise:

  * **retries** — transient storage I/O errors (``OSError`` from an mmap
    gather or a prefetch read) are retried with bounded, jittered
    exponential backoff inside ``MmapFeatures`` (``io_retries`` /
    ``io_retry_seconds`` counters).  A fault that clears within the
    retry budget is invisible to training: losses stay bit-identical.
  * **degrades** — advisory background components never kill a run.  A
    prefetch worker that dies is restarted within a budget; past the
    budget the trainer stops submitting, prices ``prefetch_overlap`` at
    0 and continues with synchronous (cold) loads.  A failed async
    refresh ``stage()`` discards its plan, keeps serving the old cache
    version and retries at the next drift boundary (a failure budget
    disables refresh for good).  A permanently unreadable window blob
    falls back to a bounded gather from the spill's backing
    ``FeatureSource``.  madvise/fadvise hint failures only increment
    counters.  Degraded state surfaces through the trainer's
    ``health()`` report — never through silence.
  * **raises** — correctness-critical failures still raise: a load-path
    gather whose retries AND fallback are exhausted, and a pipeline
    stage wedged past the ``PrefetchPipeline`` watchdog deadline (a
    diagnostic ``PipelineStallError`` naming the stage and queue depths
    instead of a silent hang).

Hook points (``FaultSpec.op``):

  ====================  ====================================================
  ``storage.take``      each per-partition window read in ``MmapFeatures
                        .take`` (one fire per retry attempt)
  ``storage.prefetch``  each per-partition pre-fault in ``prefetch_rows``
  ``storage.madvise``   each madvise hint (failure increments
                        ``madvise_failures``)
  ``storage.fadvise``   each posix_fadvise in ``drop_page_cache`` (failure
                        increments ``fadvise_failures``)
  ``storage.spill``     each partition write in ``MmapFeatures.spill``
                        (ENOSPC path: partial blobs are cleaned up)
  ``prefetch.worker``   each ``WindowPrefetcher`` work item (``kill``
                        terminates the worker thread)
  ``refresh.stage``     each ``FeatureCache.stage()`` call
  ``pipeline.<stage>``  each ``PrefetchPipeline`` stage invocation
                        (``delay`` wedges a stage for the watchdog;
                        long delays force queue-full storms upstream)
  ====================  ====================================================

Determinism: every hook keeps a **per-op call counter** under a lock, and
a spec matches by call index (``start`` / ``count``), so a schedule fires
on exactly the same calls in every run regardless of thread interleaving.
Probabilistic specs (``probability < 1``) draw from a per-spec
``np.random.default_rng`` seeded from ``(seed, op, spec index)`` — still a
pure function of the per-op call index.
"""
from __future__ import annotations

import dataclasses
import errno as _errno
import json
import threading
import time
from typing import Any, ClassVar, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.analysis.annotations import guarded_by

__all__ = ["FaultSpec", "FaultInjector", "WorkerKilled"]


class WorkerKilled(BaseException):
    """Injected hard death of a background worker thread.

    Deliberately a ``BaseException``: ordinary per-item ``except
    Exception`` recovery must not swallow it — it models the thread
    dying (OOM-kill, segfaulted native gather), not a failed work item.
    Supervisors detect the dead thread and restart within their budget.
    """


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault: fire on calls ``start .. start+count-1`` of
    hook ``op`` (per-op call indices, 0-based).

    ``kind``:
      * ``"transient"`` — raise ``OSError(errno)`` on the matching calls
        (a retry after the window succeeds),
      * ``"permanent"`` — raise ``OSError(errno)`` on every call from
        ``start`` on (``count`` ignored),
      * ``"delay"``     — sleep ``delay`` seconds (I/O latency injection /
        queue-full storms / watchdog wedges),
      * ``"kill"``      — raise ``WorkerKilled`` (terminates the worker
        thread that hit it).

    ``probability < 1`` fires only on that fraction of matching calls,
    drawn deterministically from the injector seed.
    """
    op: str
    kind: str = "transient"
    start: int = 0
    count: int = 1
    delay: float = 0.0
    errno: int = _errno.EIO
    probability: float = 1.0
    message: str = ""

    _KINDS: ClassVar[Tuple[str, ...]] = (
        "transient", "permanent", "delay", "kill")

    def __post_init__(self) -> None:
        if self.kind not in self._KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"have {self._KINDS}")

    def matches(self, call_index: int) -> bool:
        if call_index < self.start:
            return False
        if self.kind == "permanent":
            return True
        return call_index < self.start + self.count

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "FaultSpec":
        return cls(**{k: v for k, v in d.items()
                      if k in {f.name for f in dataclasses.fields(cls)}})


# schedule/seed/_by_op/_rngs are immutable after __init__ (to_json
# and the spec lookups read them lock-free by design); everything
# mutable is declared below.
@guarded_by("_lock", "calls", "injected", "faults_raised",
            "delays_injected", "total_delay_seconds")
class FaultInjector:
    """Seeded, schedulable fault injector consulted at data-plane hooks.

    Components hold an optional ``fault_injector`` attribute and call
    ``fire(op)`` at their hook point; with no schedule entry for ``op``
    the call is a dict lookup and a counter increment.  All mutation is
    under one lock, so concurrent hooks (pool threads, the prefetch
    worker, pipeline stages) each see a consistent per-op call index.

    Observability: ``calls`` (per-op hook invocations), ``injected``
    (per-op faults applied), ``faults_raised`` / ``delays_injected`` /
    ``total_delay_seconds`` aggregates, and ``report()`` for the whole
    picture.
    """

    def __init__(self,
                 schedule: Sequence[Union[FaultSpec,
                                          Dict[str, Any]]] = (),
                 seed: int = 0) -> None:
        self.seed = int(seed)
        self.schedule: List[FaultSpec] = [
            s if isinstance(s, FaultSpec) else FaultSpec.from_dict(s)
            for s in schedule]
        self._by_op: Dict[str, List[Tuple[int, FaultSpec]]] = {}
        for i, spec in enumerate(self.schedule):
            self._by_op.setdefault(spec.op, []).append((i, spec))
        self._lock = threading.Lock()
        self.calls: Dict[str, int] = {}
        self.injected: Dict[str, int] = {}
        self.faults_raised = 0
        self.delays_injected = 0
        self.total_delay_seconds = 0.0
        # per-spec deterministic rng for probabilistic specs: seeded from
        # (seed, op, spec index) so decisions depend only on the per-op
        # call order, never on wall clock or thread identity
        self._rngs: Dict[int, np.random.Generator] = {
            i: np.random.default_rng(
                np.random.SeedSequence((self.seed, hash(s.op) & 0x7FFFFFFF,
                                        i)))
            for i, s in enumerate(self.schedule) if s.probability < 1.0}

    # ------------------------------------------------------------- loading

    @classmethod
    def from_json(cls,
                  path_or_obj: Union[str, Dict[str, Any],
                                     List[Dict[str, Any]]],
                  seed: Optional[int] = None) -> "FaultInjector":
        """Build from a JSON schedule: either a list of FaultSpec dicts or
        ``{"seed": int, "schedule": [...]}`` (a file path or a parsed
        object)."""
        obj = path_or_obj
        if isinstance(obj, str):
            with open(obj) as fh:
                obj = json.load(fh)
        if isinstance(obj, dict):
            sched = obj.get("schedule", [])
            seed = obj.get("seed", 0) if seed is None else seed
        else:
            sched = obj
        return cls(sched, seed=seed or 0)

    def to_json(self) -> str:
        return json.dumps({"seed": self.seed,
                           "schedule": [s.to_dict() for s in self.schedule]})

    # -------------------------------------------------------------- firing

    def fire(self, op: str) -> None:
        """Consult the schedule for one call of hook ``op``.

        May sleep (``delay``), raise ``OSError`` (``transient`` /
        ``permanent``) or raise ``WorkerKilled`` (``kill``); returns
        normally when no spec matches this call index.  When several
        specs match the same call, delays apply first (latency precedes
        the error a slow device eventually returns), then the first
        raising spec in schedule order wins.
        """
        with self._lock:
            idx = self.calls.get(op, 0)
            self.calls[op] = idx + 1
            specs = self._by_op.get(op)
            if not specs:
                return
            actions: List[FaultSpec] = []
            for spec_i, spec in specs:
                if not spec.matches(idx):
                    continue
                if spec.probability < 1.0 and \
                        self._rngs[spec_i].random() >= spec.probability:
                    continue
                actions.append(spec)
            if not actions:
                return
            delay = sum(s.delay for s in actions if s.kind == "delay")
            raising = next((s for s in actions if s.kind != "delay"), None)
            self.injected[op] = self.injected.get(op, 0) + len(actions)
            if delay:
                self.delays_injected += 1
                self.total_delay_seconds += delay
            if raising is not None:
                self.faults_raised += 1
        # act OUTSIDE the lock: a long injected delay must not serialize
        # every other hook in the process behind it
        if delay:
            time.sleep(delay)
        if raising is None:
            return
        msg = raising.message or (
            f"injected {raising.kind} fault on {op} (call {idx})")
        if raising.kind == "kill":
            raise WorkerKilled(msg)
        raise OSError(raising.errno, msg)

    # ----------------------------------------------------------- reporting

    def report(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "calls": dict(self.calls),
                "injected": dict(self.injected),
                "faults_raised": self.faults_raised,
                "delays_injected": self.delays_injected,
                "total_delay_seconds": self.total_delay_seconds,
            }
