"""Graph storage substrate — the pluggable FeatureSource data layer.

The paper stores the input graph topology + feature matrix in *CPU (host)
memory* (Section III-B): device memory (16-64 GB) cannot hold graphs like
MAG240M (202 GB of features).  Everything in this module is therefore
host-side numpy; device code only ever sees gathered mini-batch tensors.

Feature storage is behind the ``FeatureSource`` protocol — a minimal
row-gather interface (``take(rows)`` + shape/dtype metadata) with three
interchangeable backends:

  * ``DenseFeatures``       — one materialized ndarray (small graphs),
  * ``HashedFeatures``      — lazily computed rows (papers100M-scale runs
                              on small hosts; nothing is materialized),
  * ``PartitionedFeatures`` — fixed-size row partitions gathered per
                              partition; the stepping stone to an
                              mmap/out-of-core backend, since each
                              partition is an independent blob.

All backends return byte-identical rows for the same node ids
(property-tested), so the choice is purely a capacity/locality knob.  The
device-side hot-row cache (``featcache.FeatureCache``) and the miss-only
``FeatureLoader`` (``featload``) sit on top of this protocol and never see
a concrete backend.

Datasets are synthetic, size-parameterized power-law graphs standing in for
ogbn-products / ogbn-papers100M / MAG240M (homo).  The *full* Table-III stats
are kept in the registry; smoke/bench runs instantiate scaled-down versions
with the same degree-distribution shape.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Protocol, Sequence, Tuple

import numpy as np

__all__ = [
    "CSRGraph",
    "FeatureSource",
    "DenseFeatures",
    "HashedFeatures",
    "PartitionedFeatures",
    "as_feature_source",
    "GraphDataset",
    "synth_powerlaw_graph",
    "make_dataset",
    "DATASET_STATS",
]


@dataclasses.dataclass
class CSRGraph:
    """Compressed-sparse-row adjacency (out-neighbors), host resident."""

    indptr: np.ndarray   # int64 [num_nodes + 1]
    indices: np.ndarray  # int32/int64 [num_edges]

    @property
    def num_nodes(self) -> int:
        return int(self.indptr.shape[0] - 1)

    @property
    def num_edges(self) -> int:
        return int(self.indices.shape[0])

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]:self.indptr[v + 1]]

    def nbytes(self) -> int:
        return self.indptr.nbytes + self.indices.nbytes


class FeatureSource(Protocol):
    """Minimal host-side feature storage interface.

    ``take`` must return a fresh ``[len(rows), feat_dim]`` array in
    ``dtype`` for any int array of node ids (duplicates and arbitrary
    order allowed).  Implementations are host-resident; device code only
    ever sees the gathered result.
    """

    shape: Tuple[int, int]

    @property
    def dtype(self) -> np.dtype: ...

    def take(self, rows: np.ndarray) -> np.ndarray: ...


class DenseFeatures:
    """FeatureSource over one materialized host ndarray."""

    def __init__(self, array: np.ndarray):
        if array.ndim != 2:
            raise ValueError(f"expected [N, F] features, got {array.shape}")
        self.array = array
        self.shape = tuple(array.shape)

    @property
    def dtype(self) -> np.dtype:
        return self.array.dtype

    @property
    def nbytes(self) -> int:
        return self.array.nbytes

    def take(self, rows: np.ndarray) -> np.ndarray:
        return np.take(self.array, np.asarray(rows, dtype=np.int64), axis=0)

    def __getitem__(self, rows):
        return self.take(np.atleast_1d(rows))


class PartitionedFeatures:
    """FeatureSource split into fixed-size row partitions.

    The feature matrix is stored as ``ceil(N / partition_rows)`` independent
    blobs; a gather groups the requested rows by partition, gathers within
    each touched partition, and scatters results back into request order.
    This is the layout an mmap/out-of-core backend needs (each partition is
    one file / one madvise window) and bounds the working set of a gather
    to the touched partitions only.
    """

    def __init__(self, parts: List[np.ndarray], partition_rows: int,
                 num_rows: int):
        if not parts:
            raise ValueError("need at least one partition")
        self.parts = parts
        self.partition_rows = int(partition_rows)
        self.shape = (int(num_rows), int(parts[0].shape[1]))

    @classmethod
    def from_source(cls, src: "FeatureSource | np.ndarray",
                    partition_rows: int = 65536) -> "PartitionedFeatures":
        src = as_feature_source(src)
        n = src.shape[0]
        partition_rows = max(1, int(partition_rows))
        parts = [src.take(np.arange(lo, min(lo + partition_rows, n),
                                    dtype=np.int64))
                 for lo in range(0, n, partition_rows)]
        return cls(parts, partition_rows, n)

    @property
    def dtype(self) -> np.dtype:
        return self.parts[0].dtype

    @property
    def num_partitions(self) -> int:
        return len(self.parts)

    @property
    def nbytes(self) -> int:
        return sum(p.nbytes for p in self.parts)

    def take(self, rows: np.ndarray) -> np.ndarray:
        rows = np.asarray(rows, dtype=np.int64)
        part_id = rows // self.partition_rows
        offset = rows - part_id * self.partition_rows
        out = np.empty((rows.shape[0], self.shape[1]), dtype=self.dtype)
        for pid in np.unique(part_id):
            sel = part_id == pid
            out[sel] = np.take(self.parts[pid], offset[sel], axis=0)
        return out

    def __getitem__(self, rows):
        return self.take(np.atleast_1d(rows))


def as_feature_source(features) -> "FeatureSource":
    """Normalize legacy feature containers (bare ndarray) to the protocol."""
    if isinstance(features, np.ndarray):
        return DenseFeatures(features)
    if hasattr(features, "take") and hasattr(features, "shape"):
        return features
    raise TypeError(f"not a FeatureSource: {type(features)!r}")


class HashedFeatures:
    """Deterministic lazily-computed node features.

    For graphs whose feature matrix would not fit in this container's RAM we
    never materialize X; rows are computed on demand from the node id with a
    cheap integer hash.  This keeps the system honest about the paper's
    central constraint (features are fetched row-by-row from host storage)
    while staying runnable at papers100M scale on a laptop.
    """

    def __init__(self, num_nodes: int, feat_dim: int, seed: int = 0,
                 dtype=np.float32):
        self.shape = (num_nodes, feat_dim)
        self.dtype = np.dtype(dtype)
        self._seed = np.uint64((seed * 0x9E3779B97F4A7C15 + 0xDEADBEEF)
                               & 0xFFFFFFFFFFFFFFFF)
        self._cols = np.arange(feat_dim, dtype=np.uint64)

    @property
    def nbytes_virtual(self) -> int:
        return self.shape[0] * self.shape[1] * self.dtype.itemsize

    def take(self, rows: np.ndarray) -> np.ndarray:
        """Gather feature rows (vectorized splitmix-style hash -> [-1, 1])."""
        rows = np.asarray(rows, dtype=np.uint64)
        x = (rows[:, None] * np.uint64(0x9E3779B97F4A7C15)
             + self._cols[None, :] * np.uint64(0xBF58476D1CE4E5B9)
             + self._seed)
        x ^= x >> np.uint64(31)
        x *= np.uint64(0x94D049BB133111EB)
        x ^= x >> np.uint64(29)
        # map to [-1, 1)
        return ((x >> np.uint64(11)).astype(np.float64)
                / float(1 << 53) * 2.0 - 1.0).astype(self.dtype)

    def __getitem__(self, rows):
        return self.take(np.atleast_1d(rows))


@dataclasses.dataclass
class GraphDataset:
    name: str
    graph: CSRGraph
    features: "FeatureSource | np.ndarray"
    labels: np.ndarray          # int32 [num_nodes]
    num_classes: int
    feat_dim: int
    # GNN-layer dims straight from Table III: (f0, f1, f2)
    layer_dims: Tuple[int, int, int]

    @property
    def num_nodes(self) -> int:
        return self.graph.num_nodes

    @property
    def num_edges(self) -> int:
        return self.graph.num_edges

    @property
    def feature_source(self) -> "FeatureSource":
        return as_feature_source(self.features)

    def take_features(self, rows: np.ndarray) -> np.ndarray:
        return self.feature_source.take(rows)

    def feature_hotness(self) -> np.ndarray:
        """Expected per-node gather frequency under neighbor sampling.

        A node enters the loaded frontier either as a sampled neighbor
        (proportional to how often it appears as an edge endpoint, i.e.
        its in-edge mass under the CSR out-adjacency) or as a uniformly
        drawn batch target (+1).  This is exactly the distribution the
        device-side hot cache should rank by.
        """
        counts = np.bincount(
            np.asarray(self.graph.indices, dtype=np.int64),
            minlength=self.num_nodes).astype(np.float64)
        return counts + 1.0


def synth_powerlaw_graph(num_nodes: int, avg_degree: float,
                         seed: int = 0, hub_exponent: float = 2.5,
                         ) -> CSRGraph:
    """Vectorized synthetic power-law multigraph.

    Out-degrees are ~Zipf-shaped (clipped); destination endpoints are drawn
    with preference toward "hub" nodes via the inverse-CDF trick
    ``dst = floor(N * u**hub_exponent)`` mapped through a random permutation,
    giving the heavy-tailed in-degree distribution characteristic of
    ogbn-style graphs.  O(E) time and memory.
    """
    rng = np.random.default_rng(seed)
    n = int(num_nodes)
    target_edges = int(round(n * avg_degree))
    # Zipf-ish out-degree: pareto + 1, rescaled to hit the target edge count.
    raw = rng.pareto(1.3, size=n) + 1.0
    deg = np.maximum(1, np.round(raw * (target_edges / raw.sum()))
                     ).astype(np.int64)
    # clamp extreme hubs to keep sampler buffers sane
    np.minimum(deg, max(8, n // 4), out=deg)
    m = int(deg.sum())
    u = rng.random(m)
    hub_rank = np.minimum((u ** hub_exponent * n).astype(np.int64), n - 1)
    perm = rng.permutation(n).astype(np.int64)
    dst = perm[hub_rank]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(deg, out=indptr[1:])
    idx_dtype = np.int32 if n < 2**31 else np.int64
    return CSRGraph(indptr=indptr, indices=dst.astype(idx_dtype))


# name -> (num_nodes, num_edges, f0, f1, f2, num_classes)   [Table III]
DATASET_STATS: Dict[str, Tuple[int, int, int, int, int, int]] = {
    "ogbn-products":    (2_449_029,    61_859_140,   100, 256,  47,  47),
    "ogbn-papers100M":  (111_059_956,  1_615_685_872, 128, 256, 172, 172),
    "mag240m-homo":     (121_751_666,  1_297_748_926, 756, 256, 153, 153),
}

# training-split sizes (OGB official splits; an "epoch" iterates these)
TRAIN_SPLIT: Dict[str, int] = {
    "ogbn-products": 196_615,
    "ogbn-papers100M": 1_207_179,
    "mag240m-homo": 1_112_392,
}


def make_dataset(name: str, scale: float = 1.0, seed: int = 0,
                 materialize_features: Optional[bool] = None,
                 feature_backend: str = "auto",
                 partition_rows: int = 65536) -> GraphDataset:
    """Instantiate a (possibly scaled-down) Table-III dataset.

    ``scale`` shrinks |V| while preserving avg degree and feature dims, so a
    ``scale=1e-3`` papers100M has ~111k nodes / ~1.6M edges but identical
    per-row feature traffic — the quantity the paper's performance model
    (Eq. 7/8) depends on.

    ``feature_backend`` picks the FeatureSource implementation: 'dense' |
    'hashed' | 'partitioned' | 'auto' (dense when the matrix fits 2 GiB,
    hashed otherwise; same policy as the legacy ``materialize_features``).
    """
    if name not in DATASET_STATS:
        raise KeyError(f"unknown dataset {name!r}; have {list(DATASET_STATS)}")
    nv, ne, f0, f1, f2, ncls = DATASET_STATS[name]
    n = max(1000, int(nv * scale))
    avg_deg = ne / nv
    graph = synth_powerlaw_graph(n, avg_deg, seed=seed)
    if materialize_features is not None:     # legacy knob
        feature_backend = "dense" if materialize_features else "hashed"
    if feature_backend == "auto":
        feature_backend = "dense" if n * f0 * 4 <= 2 * 2**30 else "hashed"
    hashed = HashedFeatures(n, f0, seed=seed)
    if feature_backend == "dense":
        # bare ndarray (not DenseFeatures) kept for backward compatibility:
        # callers index ds.features directly
        feats: "FeatureSource | np.ndarray" = hashed.take(np.arange(n))
    elif feature_backend == "hashed":
        feats = hashed
    elif feature_backend == "partitioned":
        feats = PartitionedFeatures.from_source(hashed,
                                                partition_rows=partition_rows)
    else:
        raise ValueError(f"unknown feature_backend {feature_backend!r}")
    rng = np.random.default_rng(seed + 1)
    labels = rng.integers(0, ncls, size=n, dtype=np.int32)
    return GraphDataset(name=name, graph=graph, features=feats,
                        labels=labels, num_classes=ncls, feat_dim=f0,
                        layer_dims=(f0, f1, f2))
