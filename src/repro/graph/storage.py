"""Graph storage substrate — the pluggable FeatureSource data layer.

The paper stores the input graph topology + feature matrix in *CPU (host)
memory* (Section III-B): device memory (16-64 GB) cannot hold graphs like
MAG240M (202 GB of features).  Everything in this module is therefore
host-side numpy; device code only ever sees gathered mini-batch tensors.

Feature storage is behind the ``FeatureSource`` protocol — a minimal
row-gather interface (``take(rows)`` + shape/dtype metadata) with four
interchangeable backends:

  * ``DenseFeatures``       — one materialized ndarray (small graphs),
  * ``HashedFeatures``      — lazily computed rows (papers100M-scale runs
                              on small hosts; nothing is materialized),
  * ``PartitionedFeatures`` — fixed-size row partitions gathered per
                              partition; each partition is an independent
                              RAM blob,
  * ``MmapFeatures``        — the out-of-core tier: the same fixed-size
                              row partitions spilled to per-partition disk
                              blobs and opened lazily as read-only
                              ``np.memmap`` windows.  The spill writer
                              buffers at most ONE partition at a time, so
                              a feature matrix larger than host RAM (the
                              MAG240M 202 GB case) streams through a
                              bounded buffer, and a gather's working set
                              is only the touched partition windows.

All backends return byte-identical rows for the same node ids
(property-tested), so the choice is purely a capacity/locality knob.  The
device-side hot-row cache (``featcache.FeatureCache``) and the miss-only
``FeatureLoader`` (``featload``) sit on top of this protocol and never see
a concrete backend; composing ``FeatureCache`` over ``MmapFeatures`` gives
the full three-tier hierarchy the paper targets (hot rows pinned on
device, warm rows in the OS page cache, cold rows on disk).

Backend selection is ``make_dataset(feature_backend=...)``: ``"dense"`` |
``"hashed"`` | ``"partitioned"`` | ``"mmap"`` (with ``spill_dir=`` to place
the blobs; a private temp dir, removed on GC/exit, is used otherwise) |
``"auto"``.

Datasets are synthetic, size-parameterized power-law graphs standing in for
ogbn-products / ogbn-papers100M / MAG240M (homo).  The *full* Table-III stats
are kept in the registry; smoke/bench runs instantiate scaled-down versions
with the same degree-distribution shape.

Failure model & degraded modes (``MmapFeatures``)
-------------------------------------------------

A transient ``OSError`` from a window gather (``take`` / ``prefetch_rows``)
is retried with bounded, jittered exponential backoff under a per-call
deadline (knobs ``io_retry_attempts`` / ``io_retry_base`` /
``io_retry_max_delay`` / ``io_retry_deadline``; counters ``io_retries``,
``io_retry_seconds``, ``io_errors``).  A *permanently* unreadable window
on the ``take`` path falls back to a bounded re-gather from the spill's
backing source (``fallback_source``, set by ``spill()``; counters
``fallback_gathers`` / ``fallback_rows``, hard cap
``fallback_row_budget`` — past it the original error is raised).
madvise/fadvise hint failures are advisory: they increment
``madvise_failures`` / ``fadvise_failures`` and never fail a gather.  An
``OSError`` (e.g. ENOSPC) during ``spill()`` removes the partial
partition blobs (no orphaned tempdirs) and raises an error naming the
spill dir and bytes written.  Deterministic fault injection hooks:
``storage.take``, ``storage.prefetch``, ``storage.madvise``,
``storage.fadvise``, ``storage.spill`` (see ``graph/faults.py``).
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import tempfile
import threading
import time
from typing import Callable, Dict, List, Optional, Protocol, Sequence, Tuple

import numpy as np

from repro.analysis.annotations import guarded_by, requires_lock

__all__ = [
    "CSRGraph",
    "FeatureSource",
    "DenseFeatures",
    "HashedFeatures",
    "PartitionedFeatures",
    "MmapFeatures",
    "as_feature_source",
    "GraphDataset",
    "synth_powerlaw_graph",
    "make_dataset",
    "DATASET_STATS",
]


@dataclasses.dataclass
class CSRGraph:
    """Compressed-sparse-row adjacency (out-neighbors), host resident."""

    indptr: np.ndarray   # int64 [num_nodes + 1]
    indices: np.ndarray  # int32/int64 [num_edges]

    @property
    def num_nodes(self) -> int:
        return int(self.indptr.shape[0] - 1)

    @property
    def num_edges(self) -> int:
        return int(self.indices.shape[0])

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]:self.indptr[v + 1]]

    def nbytes(self) -> int:
        return self.indptr.nbytes + self.indices.nbytes


class FeatureSource(Protocol):
    """Minimal host-side feature storage interface.

    ``take`` must return a fresh ``[len(rows), feat_dim]`` array in
    ``dtype`` for any int array of node ids (duplicates and arbitrary
    order allowed).  Implementations are host-resident; device code only
    ever sees the gathered result.
    """

    shape: Tuple[int, int]

    @property
    def dtype(self) -> np.dtype: ...

    def take(self, rows: np.ndarray) -> np.ndarray: ...


class DenseFeatures:
    """FeatureSource over one materialized host ndarray."""

    def __init__(self, array: np.ndarray):
        if array.ndim != 2:
            raise ValueError(f"expected [N, F] features, got {array.shape}")
        self.array = array
        self.shape = tuple(array.shape)

    @property
    def dtype(self) -> np.dtype:
        return self.array.dtype

    @property
    def nbytes(self) -> int:
        return self.array.nbytes

    def take(self, rows: np.ndarray) -> np.ndarray:
        return np.take(self.array, np.asarray(rows, dtype=np.int64), axis=0)

    def __getitem__(self, rows):
        return self.take(np.atleast_1d(rows))


class PartitionedFeatures:
    """FeatureSource split into fixed-size row partitions.

    The feature matrix is stored as ``ceil(N / partition_rows)`` independent
    blobs; a gather groups the requested rows by partition, gathers within
    each touched partition, and scatters results back into request order.
    This is the layout an mmap/out-of-core backend needs (each partition is
    one file / one madvise window) and bounds the working set of a gather
    to the touched partitions only.
    """

    def __init__(self, parts: List[np.ndarray], partition_rows: int,
                 num_rows: int):
        if not parts:
            raise ValueError("need at least one partition")
        self.parts = parts
        self.partition_rows = int(partition_rows)
        self.shape = (int(num_rows), int(parts[0].shape[1]))

    @classmethod
    def from_source(cls, src: "FeatureSource | np.ndarray",
                    partition_rows: int = 65536) -> "PartitionedFeatures":
        src = as_feature_source(src)
        n = src.shape[0]
        partition_rows = max(1, int(partition_rows))
        parts = [src.take(np.arange(lo, min(lo + partition_rows, n),
                                    dtype=np.int64))
                 for lo in range(0, n, partition_rows)]
        return cls(parts, partition_rows, n)

    @property
    def dtype(self) -> np.dtype:
        return self.parts[0].dtype

    @property
    def num_partitions(self) -> int:
        return len(self.parts)

    @property
    def nbytes(self) -> int:
        return sum(p.nbytes for p in self.parts)

    def take(self, rows: np.ndarray) -> np.ndarray:
        rows = np.asarray(rows, dtype=np.int64)
        part_id = rows // self.partition_rows
        offset = rows - part_id * self.partition_rows
        out = np.empty((rows.shape[0], self.shape[1]), dtype=self.dtype)
        for pid in np.unique(part_id):
            sel = part_id == pid
            out[sel] = np.take(self.parts[pid], offset[sel], axis=0)
        return out

    def __getitem__(self, rows):
        return self.take(np.atleast_1d(rows))


_MMAP_MANIFEST = "manifest.json"
_MMAP_FORMAT = "mmap-features-v1"
_PAGE_BYTES = 4096          # granularity of the touched-page accounting


# Deliberately UNGUARDED shared state (left out of the declarations, so
# the lint does not police it):
#   * _page_touched — gather-side updates only ever SET bits, so the
#     concurrent chunked gathers stay correct lock-free (see __init__);
#     evictions clear a window's bits under _win_lock anyway.
#   * last_gather_page_bytes — documented last-writer-wins monitor.
#   * spill_peak_buffered_rows / fallback_source / fault_injector /
#     lru_windows / io_retry_* knobs — configured before threads exist.
@guarded_by("_win_lock", "_parts", "_prefetched", "_pinned",
            "pin_blocked_evictions", "madvise_calls",
            "madvise_dontneed_calls", "madvise_failures",
            "window_evictions", "evicted_window_bytes",
            "prefetched_window_bytes", "cold_fault_page_bytes",
            "cold_gather_seconds", "warm_gather_seconds",
            "prefetch_hit_windows", "prefetch_miss_windows")
@guarded_by("_io_lock", "io_retries", "io_retry_seconds", "io_errors",
            "fallback_gathers", "fallback_rows", "fadvise_failures",
            "_retry_rng")
class MmapFeatures:
    """Out-of-core FeatureSource: row partitions in per-partition disk blobs.

    The feature matrix is stored as ``ceil(N / partition_rows)`` raw binary
    files plus a JSON manifest, created by the chunked spill writer
    (``MmapFeatures.spill``) which buffers AT MOST one partition of rows at
    a time — so any ``FeatureSource`` (e.g. lazily-computed
    ``HashedFeatures`` at MAG240M scale) can be materialized to disk with
    bounded host RAM.  Partitions are opened lazily as read-only
    ``np.memmap`` windows, hinted ``madvise(MADV_RANDOM)`` at open
    (guarded for platforms without madvise) so the kernel does not read
    ahead past the touched rows; ``take`` groups the requested rows by
    partition,
    so a gather faults only the touched windows (and, at page granularity,
    only the touched rows within them) instead of paging the whole matrix.

    Accounting used by ``benchmarks/bench_outofcore.py`` and the tier-1
    smoke:

      * ``spill_peak_buffered_rows`` — max rows the spill writer ever held
        (must be <= ``partition_rows``: the bounded-RAM guarantee),
      * ``resident_window_bytes``    — bytes of mapped (lazily opened)
        partition windows: address space, an upper bound on residency,
      * ``touched_page_bytes``       — cumulative unique 4 KiB pages the
        gathers actually faulted (page-granular residency estimate; the
        quantity that stays O(touched rows) instead of O(N*F)).

    Bounded page cache (``lru_windows > 0``): open windows live in a
    small LRU; opening one past the bound evicts the least-recently-used
    window by hinting its pages ``MADV_DONTNEED`` (clean, file-backed —
    the kernel drops them immediately instead of waiting for reclaim) and
    dropping the map reference (the underlying mmap closes once no
    in-flight gather still holds it, so a concurrent gather on an evicted
    window simply re-faults pages and stays bit-identical).  Page-cache
    residency is therefore O(lru_windows × window_bytes) instead of
    "whatever the kernel keeps".  Eviction clears the window's touch
    bits: its pages are gone, a future gather re-faults them cold.

    Background prefetch (``prefetch_rows``): pre-faults exactly the pages
    a future ``take(rows)`` will touch (readahead gather through the same
    LRU, result discarded) so the consumer's gather hits warm pages.  ``take`` accounts which of its pages were
    already faulted (by a prefetch or an earlier gather) vs faulted cold
    on the critical path:

      * ``prefetched_window_bytes`` — page bytes newly faulted by
        ``prefetch_rows`` calls,
      * ``evicted_window_bytes``    — bytes of windows evicted by the LRU,
      * ``cold_fault_page_bytes``   — page bytes ``take`` had to fault
        itself (the load-stage stall a prefetcher exists to hide), with
        the wall time spent on such cold windows in
        ``cold_gather_seconds``,
      * ``prefetch_hit_rate``       — fraction of ``take`` window touches
        served by a still-warm prefetched window.

    Reopening an existing spill directory is just ``MmapFeatures(path)``.
    """

    is_disk_resident = True   # the perf model prices loads at storage bw

    def __init__(self, spill_dir: str, lru_windows: int = 0):
        self.spill_dir = str(spill_dir)
        path = os.path.join(self.spill_dir, _MMAP_MANIFEST)
        with open(path) as fh:
            m = json.load(fh)
        if m.get("format") != _MMAP_FORMAT:
            raise ValueError(f"{path}: not a {_MMAP_FORMAT} spill directory")
        self.shape = (int(m["num_rows"]), int(m["feat_dim"]))
        self._dtype = np.dtype(str(m["dtype"]))
        self.partition_rows = int(m["partition_rows"])
        self.num_partitions = int(m["num_partitions"])
        # lazily opened windows in LRU order (insertion order = recency:
        # _part() reinserts on access); guarded by _win_lock because the
        # loader's pool threads, the background WindowPrefetcher and the
        # consumer all open/evict concurrently
        self._parts: Dict[int, np.memmap] = {}
        self._win_lock = threading.Lock()
        self.lru_windows = int(lru_windows)      # 0 = unbounded (legacy)
        self._prefetched: set = set()            # warm (prefetched) pids
        # prefetch-pinned windows: prefetched but not yet gathered from.
        # The LRU trim skips them so a tight lru_windows bound cannot
        # throw away prefetch work before its consumer arrives; the pin
        # releases on the first post-prefetch take() touching the window
        self._pinned: set = set()
        self.pin_blocked_evictions = 0           # trims blocked on pins
        self.spill_peak_buffered_rows = 0        # set by spill()
        self.madvise_calls = 0                   # windows hinted MADV_RANDOM
        self.madvise_dontneed_calls = 0          # evictions that dropped pages
        self.window_evictions = 0
        self.evicted_window_bytes = 0            # bytes of evicted windows
        self.prefetched_window_bytes = 0         # page bytes prefetch faulted
        self.cold_fault_page_bytes = 0           # page bytes take() faulted
        self.cold_gather_seconds = 0.0           # take() time on cold windows
        self.warm_gather_seconds = 0.0           # take() time on warm windows
        self.prefetch_hit_windows = 0            # take() touches of warm pids
        self.prefetch_miss_windows = 0
        self.gather_windows_touched = 0          # take() window touches
                                                 #   (load-stage working-set
                                                 #   signal for knob tuning)
        # per-thread exclusion from the stall/prefetch counters: background
        # maintenance gathers (cache boot, staged-refresh admission) are
        # not load-stage traffic and must not skew the stall metrics the
        # task mapping re-prices on (page-touch accounting still applies —
        # the pages really do become warm)
        self._untracked = threading.local()
        # ---- fault tolerance (see module docstring: failure model) ----
        self.fault_injector = None               # optional FaultInjector
        self.io_retry_attempts = 3               # tries per window gather
        self.io_retry_base = 0.005               # first backoff (seconds)
        self.io_retry_max_delay = 0.25           # per-sleep cap
        self.io_retry_deadline = 5.0             # per-call retry budget
        self.io_retries = 0                      # sleeps taken before success
        self.io_retry_seconds = 0.0              # wall time spent backing off
        self.io_errors = 0                       # OSErrors seen (incl retried)
        self.fallback_source = None              # spill() sets the backing src
        self.fallback_row_budget = 1 << 20       # max rows served by fallback
        self.fallback_gathers = 0                # window gathers that fell back
        self.fallback_rows = 0                   # rows served by the fallback
        self.madvise_failures = 0                # madvise hints that errored
        self.fadvise_failures = 0                # posix_fadvise that errored
        self._io_lock = threading.Lock()
        # deterministic jitter: backoff sleeps are reproducible run-to-run
        self._retry_rng = np.random.default_rng(0x10C0FFEE)
        self._owned_tmp: Optional[tempfile.TemporaryDirectory] = None
        self._row_bytes = self.shape[1] * self._dtype.itemsize
        # pages per partition *file* (files are page-aligned independently)
        self._pages_per_part = (
            -(-self.partition_rows * self._row_bytes // _PAGE_BYTES) + 1)
        # cumulative touched-page bitmap: one byte per 4 KiB page, i.e.
        # 1/4096 of the matrix size — bookkeeping stays negligible next to
        # the one-partition spill buffer even at MAG240M scale.  Updates
        # only ever set bits, so concurrent take() calls (the loader's
        # chunked gather) stay correct without a lock.
        self._page_touched = np.zeros(
            max(self.num_partitions, 0) * self._pages_per_part, dtype=bool)
        # pages of the most recent take() CALL — under the loader's
        # multi-threaded chunked gather each chunk is its own take(), so
        # this is per-chunk and last-writer-wins there; for a whole-gather
        # working set, diff touched_page_bytes around the gather or call
        # take() directly (as bench_outofcore does)
        self.last_gather_page_bytes = 0

    # --------------------------------------------------------- spill writer

    @classmethod
    def spill(cls, src: "FeatureSource | np.ndarray",
              spill_dir: Optional[str] = None,
              partition_rows: int = 65536,
              lru_windows: int = 0,
              fault_injector=None) -> "MmapFeatures":
        """Materialize ``src`` into per-partition disk blobs, one partition
        buffered at a time, and return the mmap-backed view.

        ``spill_dir=None`` spills into a private temporary directory that
        is removed when the returned object is garbage-collected (or at
        interpreter exit).

        An ``OSError`` while writing (ENOSPC being the canonical case)
        removes every partition blob written so far — and the owned
        temp dir, when the writer created one — then re-raises with the
        spill dir and bytes written named, so a failed spill never
        leaves orphaned blob files behind.  The backing ``src`` is kept
        as ``fallback_source`` on the returned view: a window blob that
        later turns unreadable degrades to a bounded re-gather from it.
        """
        src = as_feature_source(src)
        n, f = src.shape
        partition_rows = max(1, int(partition_rows))
        owned = None
        if spill_dir is None:
            owned = tempfile.TemporaryDirectory(prefix="repro-featspill-")
            spill_dir = owned.name
        os.makedirs(spill_dir, exist_ok=True)
        num_parts = -(-n // partition_rows)
        peak = 0
        bytes_written = 0
        pid = -1
        try:
            for pid in range(num_parts):
                lo = pid * partition_rows
                hi = min(lo + partition_rows, n)
                # the ONLY RAM the writer holds: one partition's rows
                buf = np.ascontiguousarray(
                    src.take(np.arange(lo, hi, dtype=np.int64)))
                peak = max(peak, buf.shape[0])
                if fault_injector is not None:
                    fault_injector.fire("storage.spill")
                buf.tofile(os.path.join(spill_dir, cls._part_name(pid)))
                bytes_written += int(buf.nbytes)
                dtype = buf.dtype
                del buf
        except OSError as e:
            # no orphans: drop every blob this spill managed to write
            for q in range(pid + 1):
                with contextlib.suppress(OSError):
                    os.remove(os.path.join(spill_dir, cls._part_name(q)))
            if owned is not None:
                with contextlib.suppress(OSError):
                    owned.cleanup()
            raise OSError(
                e.errno,
                f"feature spill to {spill_dir!r} failed at partition "
                f"{max(pid, 0)}/{num_parts} after {bytes_written} bytes "
                f"written: {e.strerror or e}") from e
        if num_parts == 0:
            dtype = np.dtype(src.dtype)
        manifest = {"format": _MMAP_FORMAT, "num_rows": int(n),
                    "feat_dim": int(f), "dtype": np.dtype(dtype).str,
                    "partition_rows": partition_rows,
                    "num_partitions": num_parts}
        with open(os.path.join(spill_dir, _MMAP_MANIFEST), "w") as fh:
            json.dump(manifest, fh)
        out = cls(spill_dir, lru_windows=lru_windows)
        out.spill_peak_buffered_rows = peak
        out._owned_tmp = owned
        out.fallback_source = src
        out.fault_injector = fault_injector
        return out

    @staticmethod
    def _part_name(pid: int) -> str:
        return f"part-{pid:05d}.bin"

    # -------------------------------------------------------------- gathers

    @property
    def dtype(self) -> np.dtype:
        return self._dtype

    @property
    def nbytes_on_disk(self) -> int:
        return self.shape[0] * self.shape[1] * self._dtype.itemsize

    @property
    def resident_window_bytes(self) -> int:
        """Bytes of currently mapped (touched) partition windows."""
        with self._win_lock:
            return sum(int(p.nbytes) for p in self._parts.values())

    @property
    def open_windows(self) -> int:
        """Currently mapped partition windows (<= ``lru_windows`` when the
        LRU bound is set)."""
        with self._win_lock:
            return len(self._parts)

    @property
    def window_bytes(self) -> int:
        """Bytes of one full partition window (the LRU bound's unit)."""
        return self.partition_rows * self._row_bytes

    @property
    def touched_page_bytes(self) -> int:
        """Unique pages faulted by gathers and still accounted resident
        (page-granular residency estimate; an LRU eviction clears its
        window's bits — those pages were dropped).  Cumulative when
        ``lru_windows == 0`` (the legacy meaning)."""
        return int(np.count_nonzero(self._page_touched)) * _PAGE_BYTES

    @property
    def prefetch_hit_rate(self) -> float:
        """Fraction of ``take`` window touches whose window was warm from
        a prior ``prefetch_rows`` (and not since evicted).  Snapshotted
        under ``_win_lock`` so a concurrent gather cannot tear the
        hit/total pair (a rate > 1.0 would be possible otherwise)."""
        with self._win_lock:
            hits = self.prefetch_hit_windows
            tot = hits + self.prefetch_miss_windows
        return hits / max(tot, 1)

    def reset_touch_stats(self) -> None:
        self._page_touched[:] = False
        self.last_gather_page_bytes = 0

    def set_lru_windows(self, n: int) -> None:
        """Re-bound the window LRU at runtime (DRM knob auto-tuning) and
        trim immediately when tightening — ``_part()`` would trim on the
        next access anyway, but an immediate trim makes the page-cache
        effect of an accepted knob move visible within its trial window
        rather than one gather later."""
        self.lru_windows = max(0, int(n))
        with self._win_lock:
            if self.lru_windows <= 0:
                return
            while len(self._parts) > self.lru_windows:
                old = next((p for p in self._parts
                            if p not in self._pinned), None)
                if old is None:
                    self.pin_blocked_evictions += 1
                    break
                self._evict_window(old, self._parts[old])

    @contextlib.contextmanager
    def untracked_gathers(self):
        """Context manager: this thread's ``take`` calls are excluded
        from the cold/warm stall and prefetch-hit counters (maintenance
        gathers — the cache boot block, staged-refresh admission rows —
        are not load-stage traffic).  Touch/residency accounting still
        applies: the gathered pages genuinely become warm.  Reentrant
        (restores the previous flag, not False)."""
        prev = getattr(self._untracked, "flag", False)
        self._untracked.flag = True
        try:
            yield
        finally:
            self._untracked.flag = prev

    def reset_prefetch_stats(self) -> None:
        """Zero the prefetch/stall counters (not the touch bitmap)."""
        with self._win_lock:
            self.prefetched_window_bytes = 0
            self.cold_fault_page_bytes = 0
            self.cold_gather_seconds = 0.0
            self.warm_gather_seconds = 0.0
            self.prefetch_hit_windows = 0
            self.prefetch_miss_windows = 0

    # ------------------------------------------------- retrying I/O plumbing

    def _retry_io(self, fn: Callable[[], "np.ndarray"], op: str):
        """Run one window I/O operation with bounded, jittered exponential
        backoff on transient ``OSError``: up to ``io_retry_attempts``
        tries within a per-call ``io_retry_deadline``.  Every error is
        counted in ``io_errors``; every backoff sleep in ``io_retries`` /
        ``io_retry_seconds``.  Jitter comes from a seeded rng, so backoff
        timing is reproducible run-to-run.  The fault-injection hook
        fires inside the attempt (before ``fn``), so a scheduled
        transient fault is consumed by the attempt it targets and the
        next attempt proceeds clean."""
        deadline = time.monotonic() + self.io_retry_deadline
        backoff = self.io_retry_base
        attempts = max(1, int(self.io_retry_attempts))
        for attempt in range(attempts):
            try:
                if self.fault_injector is not None:
                    self.fault_injector.fire(op)
                return fn()
            except OSError:
                with self._io_lock:
                    self.io_errors += 1
                    jitter = 1.0 + float(self._retry_rng.random())
                budget = deadline - time.monotonic()
                if attempt == attempts - 1 or budget <= 0:
                    raise
                sleep = min(backoff * jitter, self.io_retry_max_delay, budget)
                time.sleep(sleep)
                with self._io_lock:
                    self.io_retries += 1
                    self.io_retry_seconds += sleep
                backoff *= 2.0

    def _fallback_gather(self, pid: int, offset: np.ndarray,
                         err: OSError) -> np.ndarray:
        """Degraded path for a window unreadable past the retry budget:
        re-gather the rows from the spill's backing ``fallback_source``
        (global ids reconstructed from the partition coordinates), under
        a hard ``fallback_row_budget`` so a totally broken storage tier
        still fails loudly instead of silently re-running the whole
        spill's source forever."""
        src = self.fallback_source
        if src is None:
            raise err
        n = int(offset.shape[0])
        with self._io_lock:
            if self.fallback_rows + n > self.fallback_row_budget:
                raise OSError(
                    err.errno,
                    f"window {pid} under {self.spill_dir!r} is unreadable "
                    f"and the fallback gather budget is exhausted "
                    f"({self.fallback_rows} rows served, "
                    f"{n} more requested > fallback_row_budget="
                    f"{self.fallback_row_budget}): {err}") from err
            self.fallback_gathers += 1
            self.fallback_rows += n
        rows = pid * self.partition_rows + np.asarray(offset, dtype=np.int64)
        return np.ascontiguousarray(src.take(rows), dtype=self._dtype)

    def _gather_window(self, pid: int, offset: np.ndarray, op: str
                       ) -> Tuple[np.ndarray, bool]:
        """One window gather with retries, then the bounded fallback.
        Returns ``(rows, used_fallback)`` — fallback rows never came from
        the blob, so the caller must skip page-touch accounting."""
        try:
            return self._retry_io(
                lambda: np.take(self._part(pid), offset, axis=0), op), False
        except OSError as e:
            return self._fallback_gather(pid, offset, e), True

    @requires_lock("_win_lock")
    def _madvise(self, mm: np.memmap, advice_name: str) -> bool:
        """Issue one madvise hint on a window (caller holds ``_win_lock``).
        Purely advisory and guarded — platforms without ``mmap.madvise``
        (or numpy builds not exposing the underlying map) skip, and a
        kernel that rejects the hint only increments ``madvise_failures``;
        gather results are identical either way (property-tested)."""
        import mmap as _mmap
        advice = getattr(_mmap, advice_name, None)
        base = getattr(mm, "_mmap", None)
        if advice is None or base is None:
            return False
        try:
            if self.fault_injector is not None:
                self.fault_injector.fire("storage.madvise")
            base.madvise(advice)
            return True
        except (OSError, ValueError):
            # advisory failure: counted, never raised — the gather works
            # without the hint, just with worse readahead behaviour
            self.madvise_failures += 1
            return False

    @requires_lock("_win_lock")
    def _madvise_random(self, mm: np.memmap) -> None:
        """``MADV_RANDOM`` disables readahead, so a sparse gather faults
        only the touched pages instead of dragging untouched neighbour
        rows into the page cache.  Caller holds ``_win_lock``."""
        if self._madvise(mm, "MADV_RANDOM"):
            self.madvise_calls += 1

    @requires_lock("_win_lock")
    def _evict_window(self, pid: int, mm: np.memmap) -> None:
        """Drop one window from the LRU (held under ``_win_lock``):
        ``MADV_DONTNEED`` releases its clean file-backed pages immediately
        (instead of trusting kernel reclaim), then the map reference is
        dropped — the underlying mmap closes once no in-flight gather
        still holds it, so a gather racing the eviction just re-faults
        pages and stays bit-identical."""
        if self._madvise(mm, "MADV_DONTNEED"):
            self.madvise_dontneed_calls += 1
        self.window_evictions += 1
        self.evicted_window_bytes += int(mm.nbytes)
        self._prefetched.discard(pid)
        self._pinned.discard(pid)
        # the pages are gone: a future gather faults them cold again
        base = pid * self._pages_per_part
        self._page_touched[base:base + self._pages_per_part] = False
        del self._parts[pid]

    def _part(self, pid: int) -> np.memmap:
        with self._win_lock:
            mm = self._parts.pop(pid, None)
            if mm is None:
                lo = pid * self.partition_rows
                rows = min(self.partition_rows, self.shape[0] - lo)
                mm = np.memmap(
                    os.path.join(self.spill_dir, self._part_name(pid)),
                    dtype=self._dtype, mode="r",
                    shape=(rows, self.shape[1]))
                self._madvise_random(mm)
            self._parts[pid] = mm              # (re)insert at the MRU end
            # trim on every access, not just opens: lru_windows may have
            # been tightened after windows were already mapped (e.g. the
            # cache boot gather runs before the trainer sets the bound)
            if self.lru_windows > 0:
                while len(self._parts) > self.lru_windows:
                    # LRU-ordered victim scan, skipping the newcomer and
                    # prefetch-pinned windows (not-yet-consumed prefetch
                    # work must survive even a bound == working-set size)
                    old = next((p for p in self._parts
                                if p != pid and p not in self._pinned),
                               None)
                    if old is None:
                        # every candidate is pinned: run over-bound until
                        # their gathers release them (counted, not silent)
                        self.pin_blocked_evictions += 1
                        break
                    self._evict_window(old, self._parts[old])
            return mm

    @requires_lock("_win_lock")
    def _note_touch_window(self, pid: int, offset: np.ndarray
                           ) -> Tuple[int, int]:
        """Mark one window's pages touched by ``offset`` rows; returns
        (page bytes this call spans, page bytes newly faulted).  Caller
        holds ``_win_lock`` (both gather paths account under it)."""
        off_b = offset * self._row_bytes
        first = off_b // _PAGE_BYTES
        last = (off_b + self._row_bytes - 1) // _PAGE_BYTES
        base = pid * self._pages_per_part
        # a row spans first..last inclusive — wide rows (> 2 pages) touch
        # interior pages too, so enumerate the whole span
        span = self._row_bytes // _PAGE_BYTES + 1
        parts = []
        for j in range(span + 1):
            pg = first + j
            parts.append(np.where(pg <= last, base + pg, np.int64(-1)))
        pages = np.unique(np.concatenate(parts))
        pages = pages[pages >= 0]
        fresh = int(np.count_nonzero(~self._page_touched[pages]))
        self._page_touched[pages] = True
        return int(pages.shape[0]) * _PAGE_BYTES, fresh * _PAGE_BYTES

    def _split_parts(self, rows: np.ndarray
                     ) -> Tuple[np.ndarray, np.ndarray]:
        if rows.min() < 0 or rows.max() >= self.shape[0]:
            raise IndexError(
                f"row ids out of range [0, {self.shape[0]})")
        part_id = rows // self.partition_rows
        return part_id, rows - part_id * self.partition_rows

    def prefetch_rows(self, rows: np.ndarray) -> int:
        """Pre-fault the pages a future ``take(rows)`` will touch.

        Groups the rows by partition, opens each touched window through
        the LRU and runs a readahead gather of exactly the requested rows
        (result discarded) so precisely the needed pages are resident
        when the consumer's gather arrives.  Deliberately NOT a
        whole-window ``MADV_WILLNEED``: an untargeted hint covers the
        entire mapping, so the kernel would stream the full window blob
        and the background thread would compete for the very storage
        bandwidth it exists to hide (the windows stay ``MADV_RANDOM``
        from open).  Safe to call concurrently with ``take`` (this is
        the WindowPrefetcher's worker-thread entry point).  Returns the
        page bytes newly faulted (also accumulated into
        ``prefetched_window_bytes``)."""
        rows = np.asarray(rows, dtype=np.int64)
        if rows.shape[0] == 0:
            return 0
        part_id, offset = self._split_parts(rows)
        total_new = 0
        for pid in np.unique(part_id):
            pid = int(pid)
            sel = part_id == pid
            # readahead gather, discarded; transient I/O errors retried
            self._retry_io(
                lambda p=pid, o=offset[sel]: np.take(self._part(p), o,
                                                     axis=0),
                "storage.prefetch")
            with self._win_lock:
                _, new = self._note_touch_window(pid, offset[sel])
                self._prefetched.add(pid)
                self._pinned.add(pid)
                self.prefetched_window_bytes += new
            total_new += new
        return total_new

    def take(self, rows: np.ndarray) -> np.ndarray:
        rows = np.asarray(rows, dtype=np.int64)
        out = np.empty((rows.shape[0], self.shape[1]), dtype=self._dtype)
        if rows.shape[0] == 0:
            return out
        part_id, offset = self._split_parts(rows)
        tracked = not getattr(self._untracked, "flag", False)
        gather_pages = 0
        for pid in np.unique(part_id):
            pid = int(pid)
            sel = part_id == pid
            # snapshot warmth under the lock: the prefetch worker adds to
            # _prefetched and the LRU discards from it concurrently, and a
            # set mutating mid-__contains__ has no defined answer
            with self._win_lock:
                warm = pid in self._prefetched
            t0 = time.perf_counter()
            block, fell_back = self._gather_window(pid, offset[sel],
                                                   "storage.take")
            out[sel] = block
            dt = time.perf_counter() - t0
            if fell_back:
                # rows came from the backing source, not the blob: no
                # pages were faulted here, so skip touch/stall accounting
                continue
            with self._win_lock:
                touched, fresh = self._note_touch_window(pid, offset[sel])
                gather_pages += touched
                # first post-prefetch gather: the prefetched data reached
                # its consumer, the window is evictable again
                self._pinned.discard(pid)
                if not tracked:
                    continue
                # stall accounting: pages nobody faulted before this
                # gather are the cold reads a prefetcher exists to hide
                self.gather_windows_touched += 1
                self.cold_fault_page_bytes += fresh
                if warm:
                    self.prefetch_hit_windows += 1
                else:
                    self.prefetch_miss_windows += 1
                if fresh:
                    self.cold_gather_seconds += dt
                else:
                    self.warm_gather_seconds += dt
        self.last_gather_page_bytes = gather_pages
        return out

    def __getitem__(self, rows):
        return self.take(np.atleast_1d(rows))

    def drop_page_cache(self) -> None:
        """Best-effort page-cache drop of every partition blob
        (``posix_fadvise(POSIX_FADV_DONTNEED)`` on the files, guarded) —
        used by benchmarks to measure genuinely cold gathers right after
        a spill wrote (and therefore page-cached) the blobs."""
        fadvise = getattr(os, "posix_fadvise", None)
        dontneed = getattr(os, "POSIX_FADV_DONTNEED", None)
        if fadvise is None or dontneed is None:  # pragma: no cover
            return
        for pid in range(self.num_partitions):
            path = os.path.join(self.spill_dir, self._part_name(pid))
            try:
                if self.fault_injector is not None:
                    self.fault_injector.fire("storage.fadvise")
                fd = os.open(path, os.O_RDONLY)
                try:
                    os.fsync(fd)
                    fadvise(fd, 0, 0, dontneed)
                finally:
                    os.close(fd)
            except OSError:
                # advisory: a file we cannot re-open/fadvise just stays
                # page-cached — counted so chaos tests can see it happened
                with self._io_lock:
                    self.fadvise_failures += 1

    def close(self) -> None:
        """Drop all mapped windows (their pages become reclaimable)."""
        with self._win_lock:
            self._parts.clear()
            self._prefetched.clear()
            self._pinned.clear()


def as_feature_source(features) -> "FeatureSource":
    """Normalize legacy feature containers (bare ndarray) to the protocol."""
    if isinstance(features, np.ndarray):
        return DenseFeatures(features)
    if hasattr(features, "take") and hasattr(features, "shape"):
        return features
    raise TypeError(f"not a FeatureSource: {type(features)!r}")


class HashedFeatures:
    """Deterministic lazily-computed node features.

    For graphs whose feature matrix would not fit in this container's RAM we
    never materialize X; rows are computed on demand from the node id with a
    cheap integer hash.  This keeps the system honest about the paper's
    central constraint (features are fetched row-by-row from host storage)
    while staying runnable at papers100M scale on a laptop.
    """

    def __init__(self, num_nodes: int, feat_dim: int, seed: int = 0,
                 dtype=np.float32):
        self.shape = (num_nodes, feat_dim)
        self.dtype = np.dtype(dtype)
        self._seed = np.uint64((seed * 0x9E3779B97F4A7C15 + 0xDEADBEEF)
                               & 0xFFFFFFFFFFFFFFFF)
        self._cols = np.arange(feat_dim, dtype=np.uint64)

    @property
    def nbytes_virtual(self) -> int:
        return self.shape[0] * self.shape[1] * self.dtype.itemsize

    def take(self, rows: np.ndarray) -> np.ndarray:
        """Gather feature rows (vectorized splitmix-style hash -> [-1, 1])."""
        rows = np.asarray(rows, dtype=np.uint64)
        x = (rows[:, None] * np.uint64(0x9E3779B97F4A7C15)
             + self._cols[None, :] * np.uint64(0xBF58476D1CE4E5B9)
             + self._seed)
        x ^= x >> np.uint64(31)
        x *= np.uint64(0x94D049BB133111EB)
        x ^= x >> np.uint64(29)
        # map to [-1, 1)
        return ((x >> np.uint64(11)).astype(np.float64)
                / float(1 << 53) * 2.0 - 1.0).astype(self.dtype)

    def __getitem__(self, rows):
        return self.take(np.atleast_1d(rows))


@dataclasses.dataclass
class GraphDataset:
    name: str
    graph: CSRGraph
    features: "FeatureSource | np.ndarray"
    labels: np.ndarray          # int32 [num_nodes]
    num_classes: int
    feat_dim: int
    # GNN-layer dims straight from Table III: (f0, f1, f2)
    layer_dims: Tuple[int, int, int]

    @property
    def num_nodes(self) -> int:
        return self.graph.num_nodes

    @property
    def num_edges(self) -> int:
        return self.graph.num_edges

    @property
    def feature_source(self) -> "FeatureSource":
        return as_feature_source(self.features)

    def take_features(self, rows: np.ndarray) -> np.ndarray:
        return self.feature_source.take(rows)

    def feature_hotness(self) -> np.ndarray:
        """Expected per-node gather frequency under neighbor sampling.

        A node enters the loaded frontier either as a sampled neighbor
        (proportional to how often it appears as an edge endpoint, i.e.
        its in-edge mass under the CSR out-adjacency) or as a uniformly
        drawn batch target (+1).  This is exactly the distribution the
        device-side hot cache should rank by.
        """
        counts = np.bincount(
            np.asarray(self.graph.indices, dtype=np.int64),
            minlength=self.num_nodes).astype(np.float64)
        return counts + 1.0


def synth_powerlaw_graph(num_nodes: int, avg_degree: float,
                         seed: int = 0, hub_exponent: float = 2.5,
                         ) -> CSRGraph:
    """Vectorized synthetic power-law multigraph.

    Out-degrees are ~Zipf-shaped (clipped); destination endpoints are drawn
    with preference toward "hub" nodes via the inverse-CDF trick
    ``dst = floor(N * u**hub_exponent)`` mapped through a random permutation,
    giving the heavy-tailed in-degree distribution characteristic of
    ogbn-style graphs.  O(E) time and memory.
    """
    rng = np.random.default_rng(seed)
    n = int(num_nodes)
    target_edges = int(round(n * avg_degree))
    # Zipf-ish out-degree: pareto + 1, rescaled to hit the target edge count.
    raw = rng.pareto(1.3, size=n) + 1.0
    deg = np.maximum(1, np.round(raw * (target_edges / raw.sum()))
                     ).astype(np.int64)
    # clamp extreme hubs to keep sampler buffers sane
    np.minimum(deg, max(8, n // 4), out=deg)
    m = int(deg.sum())
    u = rng.random(m)
    hub_rank = np.minimum((u ** hub_exponent * n).astype(np.int64), n - 1)
    perm = rng.permutation(n).astype(np.int64)
    dst = perm[hub_rank]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(deg, out=indptr[1:])
    idx_dtype = np.int32 if n < 2**31 else np.int64
    return CSRGraph(indptr=indptr, indices=dst.astype(idx_dtype))


# name -> (num_nodes, num_edges, f0, f1, f2, num_classes)   [Table III]
DATASET_STATS: Dict[str, Tuple[int, int, int, int, int, int]] = {
    "ogbn-products":    (2_449_029,    61_859_140,   100, 256,  47,  47),
    "ogbn-papers100M":  (111_059_956,  1_615_685_872, 128, 256, 172, 172),
    "mag240m-homo":     (121_751_666,  1_297_748_926, 756, 256, 153, 153),
}

# training-split sizes (OGB official splits; an "epoch" iterates these)
TRAIN_SPLIT: Dict[str, int] = {
    "ogbn-products": 196_615,
    "ogbn-papers100M": 1_207_179,
    "mag240m-homo": 1_112_392,
}


def make_dataset(name: str, scale: float = 1.0, seed: int = 0,
                 materialize_features: Optional[bool] = None,
                 feature_backend: str = "auto",
                 partition_rows: int = 65536,
                 spill_dir: Optional[str] = None,
                 mmap_lru_windows: int = 0) -> GraphDataset:
    """Instantiate a (possibly scaled-down) Table-III dataset.

    ``scale`` shrinks |V| while preserving avg degree and feature dims, so a
    ``scale=1e-3`` papers100M has ~111k nodes / ~1.6M edges but identical
    per-row feature traffic — the quantity the paper's performance model
    (Eq. 7/8) depends on.

    ``feature_backend`` picks the FeatureSource implementation: 'dense' |
    'hashed' | 'partitioned' | 'mmap' (out-of-core: features spilled to
    per-partition blobs under ``spill_dir`` — a private temp dir when
    None — with bounded spill RAM and lazily mapped windows) | 'auto'
    (dense when the matrix fits 2 GiB, hashed otherwise; same policy as
    the legacy ``materialize_features``).

    ``mmap_lru_windows`` bounds the mmap backend's simultaneously open
    partition windows (0 = unbounded): the LRU evicts with
    ``MADV_DONTNEED`` so page-cache residency stays
    O(lru_windows × window_bytes).
    """
    if name not in DATASET_STATS:
        raise KeyError(f"unknown dataset {name!r}; have {list(DATASET_STATS)}")
    nv, ne, f0, f1, f2, ncls = DATASET_STATS[name]
    n = max(1000, int(nv * scale))
    avg_deg = ne / nv
    graph = synth_powerlaw_graph(n, avg_deg, seed=seed)
    if materialize_features is not None:     # legacy knob
        feature_backend = "dense" if materialize_features else "hashed"
    if feature_backend == "auto":
        feature_backend = "dense" if n * f0 * 4 <= 2 * 2**30 else "hashed"
    hashed = HashedFeatures(n, f0, seed=seed)
    if feature_backend == "dense":
        # bare ndarray (not DenseFeatures) kept for backward compatibility:
        # callers index ds.features directly
        feats: "FeatureSource | np.ndarray" = hashed.take(np.arange(n))
    elif feature_backend == "hashed":
        feats = hashed
    elif feature_backend == "partitioned":
        feats = PartitionedFeatures.from_source(hashed,
                                                partition_rows=partition_rows)
    elif feature_backend == "mmap":
        feats = MmapFeatures.spill(hashed, spill_dir=spill_dir,
                                   partition_rows=partition_rows,
                                   lru_windows=mmap_lru_windows)
    else:
        raise ValueError(f"unknown feature_backend {feature_backend!r}")
    rng = np.random.default_rng(seed + 1)
    labels = rng.integers(0, ncls, size=n, dtype=np.int32)
    return GraphDataset(name=name, graph=graph, features=feats,
                        labels=labels, num_classes=ncls, feat_dim=f0,
                        layer_dims=(f0, f1, f2))
