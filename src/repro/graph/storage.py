"""Graph storage substrate.

The paper stores the input graph topology + feature matrix in *CPU (host)
memory* (Section III-B): device memory (16-64 GB) cannot hold graphs like
MAG240M (202 GB of features).  Everything in this module is therefore
host-side numpy; device code only ever sees gathered mini-batch tensors.

Datasets are synthetic, size-parameterized power-law graphs standing in for
ogbn-products / ogbn-papers100M / MAG240M (homo).  The *full* Table-III stats
are kept in the registry; smoke/bench runs instantiate scaled-down versions
with the same degree-distribution shape.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import numpy as np

__all__ = [
    "CSRGraph",
    "HashedFeatures",
    "GraphDataset",
    "synth_powerlaw_graph",
    "make_dataset",
    "DATASET_STATS",
]


@dataclasses.dataclass
class CSRGraph:
    """Compressed-sparse-row adjacency (out-neighbors), host resident."""

    indptr: np.ndarray   # int64 [num_nodes + 1]
    indices: np.ndarray  # int32/int64 [num_edges]

    @property
    def num_nodes(self) -> int:
        return int(self.indptr.shape[0] - 1)

    @property
    def num_edges(self) -> int:
        return int(self.indices.shape[0])

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]:self.indptr[v + 1]]

    def nbytes(self) -> int:
        return self.indptr.nbytes + self.indices.nbytes


class HashedFeatures:
    """Deterministic lazily-computed node features.

    For graphs whose feature matrix would not fit in this container's RAM we
    never materialize X; rows are computed on demand from the node id with a
    cheap integer hash.  This keeps the system honest about the paper's
    central constraint (features are fetched row-by-row from host storage)
    while staying runnable at papers100M scale on a laptop.
    """

    def __init__(self, num_nodes: int, feat_dim: int, seed: int = 0,
                 dtype=np.float32):
        self.shape = (num_nodes, feat_dim)
        self.dtype = np.dtype(dtype)
        self._seed = np.uint64(seed * 0x9E3779B97F4A7C15 + 0xDEADBEEF)
        self._cols = np.arange(feat_dim, dtype=np.uint64)

    @property
    def nbytes_virtual(self) -> int:
        return self.shape[0] * self.shape[1] * self.dtype.itemsize

    def take(self, rows: np.ndarray) -> np.ndarray:
        """Gather feature rows (vectorized splitmix-style hash -> [-1, 1])."""
        rows = np.asarray(rows, dtype=np.uint64)
        x = (rows[:, None] * np.uint64(0x9E3779B97F4A7C15)
             + self._cols[None, :] * np.uint64(0xBF58476D1CE4E5B9)
             + self._seed)
        x ^= x >> np.uint64(31)
        x *= np.uint64(0x94D049BB133111EB)
        x ^= x >> np.uint64(29)
        # map to [-1, 1)
        return ((x >> np.uint64(11)).astype(np.float64)
                / float(1 << 53) * 2.0 - 1.0).astype(self.dtype)

    def __getitem__(self, rows):
        return self.take(np.atleast_1d(rows))


@dataclasses.dataclass
class GraphDataset:
    name: str
    graph: CSRGraph
    features: "HashedFeatures | np.ndarray"
    labels: np.ndarray          # int32 [num_nodes]
    num_classes: int
    feat_dim: int
    # GNN-layer dims straight from Table III: (f0, f1, f2)
    layer_dims: Tuple[int, int, int]

    @property
    def num_nodes(self) -> int:
        return self.graph.num_nodes

    @property
    def num_edges(self) -> int:
        return self.graph.num_edges

    def take_features(self, rows: np.ndarray) -> np.ndarray:
        if isinstance(self.features, np.ndarray):
            return np.take(self.features, rows, axis=0)
        return self.features.take(rows)


def synth_powerlaw_graph(num_nodes: int, avg_degree: float,
                         seed: int = 0, hub_exponent: float = 2.5,
                         ) -> CSRGraph:
    """Vectorized synthetic power-law multigraph.

    Out-degrees are ~Zipf-shaped (clipped); destination endpoints are drawn
    with preference toward "hub" nodes via the inverse-CDF trick
    ``dst = floor(N * u**hub_exponent)`` mapped through a random permutation,
    giving the heavy-tailed in-degree distribution characteristic of
    ogbn-style graphs.  O(E) time and memory.
    """
    rng = np.random.default_rng(seed)
    n = int(num_nodes)
    target_edges = int(round(n * avg_degree))
    # Zipf-ish out-degree: pareto + 1, rescaled to hit the target edge count.
    raw = rng.pareto(1.3, size=n) + 1.0
    deg = np.maximum(1, np.round(raw * (target_edges / raw.sum()))
                     ).astype(np.int64)
    # clamp extreme hubs to keep sampler buffers sane
    np.minimum(deg, max(8, n // 4), out=deg)
    m = int(deg.sum())
    u = rng.random(m)
    hub_rank = np.minimum((u ** hub_exponent * n).astype(np.int64), n - 1)
    perm = rng.permutation(n).astype(np.int64)
    dst = perm[hub_rank]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(deg, out=indptr[1:])
    idx_dtype = np.int32 if n < 2**31 else np.int64
    return CSRGraph(indptr=indptr, indices=dst.astype(idx_dtype))


# name -> (num_nodes, num_edges, f0, f1, f2, num_classes)   [Table III]
DATASET_STATS: Dict[str, Tuple[int, int, int, int, int, int]] = {
    "ogbn-products":    (2_449_029,    61_859_140,   100, 256,  47,  47),
    "ogbn-papers100M":  (111_059_956,  1_615_685_872, 128, 256, 172, 172),
    "mag240m-homo":     (121_751_666,  1_297_748_926, 756, 256, 153, 153),
}

# training-split sizes (OGB official splits; an "epoch" iterates these)
TRAIN_SPLIT: Dict[str, int] = {
    "ogbn-products": 196_615,
    "ogbn-papers100M": 1_207_179,
    "mag240m-homo": 1_112_392,
}


def make_dataset(name: str, scale: float = 1.0, seed: int = 0,
                 materialize_features: Optional[bool] = None) -> GraphDataset:
    """Instantiate a (possibly scaled-down) Table-III dataset.

    ``scale`` shrinks |V| while preserving avg degree and feature dims, so a
    ``scale=1e-3`` papers100M has ~111k nodes / ~1.6M edges but identical
    per-row feature traffic — the quantity the paper's performance model
    (Eq. 7/8) depends on.
    """
    if name not in DATASET_STATS:
        raise KeyError(f"unknown dataset {name!r}; have {list(DATASET_STATS)}")
    nv, ne, f0, f1, f2, ncls = DATASET_STATS[name]
    n = max(1000, int(nv * scale))
    avg_deg = ne / nv
    graph = synth_powerlaw_graph(n, avg_deg, seed=seed)
    if materialize_features is None:
        materialize_features = n * f0 * 4 <= 2 * 2**30  # <= 2 GiB
    if materialize_features:
        feats: "HashedFeatures | np.ndarray" = (
            HashedFeatures(n, f0, seed=seed).take(np.arange(n)))
    else:
        feats = HashedFeatures(n, f0, seed=seed)
    rng = np.random.default_rng(seed + 1)
    labels = rng.integers(0, ncls, size=n, dtype=np.int32)
    return GraphDataset(name=name, graph=graph, features=feats,
                        labels=labels, num_classes=ncls, feat_dim=f0,
                        layer_dims=(f0, f1, f2))
