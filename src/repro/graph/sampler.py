"""Mini-batch Sampler (paper Section III-A).

Implements the GraphSAGE neighbor sampler (fanouts default (25, 10), batch
1024 — the paper's evaluation setup).  Two interchangeable backends:

* ``NumpySampler`` — host-side, vectorized numpy.  This is the paper's
  "Sampling on CPU" stage and the default for large graphs whose topology
  lives in host memory.
* ``sample_minibatch_jax`` — jit-able fixed-shape sampler for graphs whose
  topology fits in device memory; this is the paper's "Sampling on
  Accelerator" option.  Both produce identical ``MiniBatch`` pytrees.

Shape discipline: every array in a ``MiniBatch`` has a size that depends only
on (batch_size, fanouts), never on the sampled data — a requirement both for
jit and for the fixed-latency pipeline stages of the training protocol.
Sampling is *with replacement* (as in PyG's NeighborSampler fast path);
zero-degree vertices fall back to self-loops.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .storage import CSRGraph

__all__ = ["MiniBatch", "NumpySampler", "sample_minibatch_jax",
           "frontier_sizes"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MiniBatch:
    """A fixed-shape L-hop sampled block structure.

    frontiers[l] (global vertex ids) for l = 0..L; frontier 0 is the batch
    targets, frontier ``l`` = concat(frontier l-1, sampled srcs of hop l) —
    so a vertex's own entry is always present (needed by GraphSAGE's
    self-concat and GCN's self-loop).

    hop ``l`` (1-based) has exactly ``len(frontier[l-1]) * fanout[l-1]``
    edges: ``dst local index = i // fanout``, src local index = position in
    frontier ``l`` = ``len(frontier[l-1]) + i``.  We store only the sampled
    source *global ids* plus per-hop degree vectors; everything else is
    implied by the regular layout.
    """

    targets: jax.Array          # [B] int
    labels: jax.Array           # [B] int
    hop_src: Tuple[jax.Array, ...]     # hop l: [B * prod(fanouts[:l])] global ids
    hop_src_deg: Tuple[jax.Array, ...]  # same shape: true degree of each *dst* (for GCN norm)
    hop_dst_deg: Tuple[jax.Array, ...]
    fanouts: Tuple[int, ...] = dataclasses.field(metadata=dict(static=True))

    @property
    def batch_size(self) -> int:
        return int(self.targets.shape[0])

    def frontier(self, l: int) -> jax.Array:
        """Global ids of frontier ``l`` (0 = targets), concatenated layout."""
        parts = [self.targets]
        for h in range(l):
            parts.append(self.hop_src[h])
        return jnp.concatenate(parts) if len(parts) > 1 else self.targets

    def num_frontier(self, l: int) -> int:
        return frontier_sizes(self.batch_size, self.fanouts)[l]

    def edges_traversed(self) -> int:
        """Total sampled edges (the paper's MTEPS numerator, Eq. 5)."""
        return sum(int(s.shape[0]) for s in self.hop_src)


def frontier_sizes(batch: int, fanouts: Sequence[int]) -> Tuple[int, ...]:
    # frontier l size = batch * prod_{h<l}(1 + f_h)
    out = [batch]
    cur = batch
    for f in fanouts:
        cur = cur * (1 + f)
        out.append(cur)
    return tuple(out)


class NumpySampler:
    """Host-side vectorized neighbor sampler (paper's CPU Sampler thread)."""

    def __init__(self, graph: CSRGraph, fanouts: Sequence[int] = (25, 10),
                 seed: int = 0):
        self.graph = graph
        self.fanouts = tuple(int(f) for f in fanouts)
        self._rng = np.random.default_rng(seed)
        self._deg = np.diff(graph.indptr)

    def _sample_hop(self, frontier: np.ndarray, fanout: int
                    ) -> Tuple[np.ndarray, np.ndarray]:
        deg = self._deg[frontier]
        safe_deg = np.maximum(deg, 1)
        r = self._rng.integers(0, 1 << 31,
                               size=(frontier.shape[0], fanout))
        offs = (r % safe_deg[:, None]) + self.graph.indptr[frontier][:, None]
        src = self.graph.indices[offs].astype(np.int64)
        # zero-degree fallback: self loop
        src = np.where(deg[:, None] == 0, frontier[:, None], src)
        return src.reshape(-1), deg

    def sample(self, targets: np.ndarray, labels: np.ndarray) -> MiniBatch:
        frontier = np.asarray(targets, dtype=np.int64)
        hop_src, hop_sdeg, hop_ddeg = [], [], []
        for f in self.fanouts:
            src, dst_deg = self._sample_hop(frontier, f)
            hop_src.append(src)
            hop_ddeg.append(np.repeat(dst_deg, f))
            hop_sdeg.append(self._deg[src])
            frontier = np.concatenate([frontier, src])
        return MiniBatch(
            targets=jnp.asarray(np.asarray(targets, np.int64)),
            labels=jnp.asarray(np.asarray(labels, np.int32)),
            hop_src=tuple(jnp.asarray(s) for s in hop_src),
            hop_src_deg=tuple(jnp.asarray(d) for d in hop_sdeg),
            hop_dst_deg=tuple(jnp.asarray(d) for d in hop_ddeg),
            fanouts=self.fanouts,
        )


def sample_minibatch_jax(key: jax.Array, indptr: jax.Array,
                         indices: jax.Array, targets: jax.Array,
                         labels: jax.Array,
                         fanouts: Tuple[int, ...]) -> MiniBatch:
    """jit-able sampler — the paper's "Sampling on Accelerator" path.

    Requires the CSR topology on device.  Identical semantics to
    ``NumpySampler`` (uniform with replacement, self-loop fallback).
    """
    deg_all = jnp.diff(indptr)

    def hop(carry, fanout):
        key, frontier = carry
        key, sub = jax.random.split(key)
        deg = deg_all[frontier]
        safe = jnp.maximum(deg, 1)
        r = jax.random.randint(sub, (frontier.shape[0], fanout), 0, 1 << 30)
        offs = (r % safe[:, None]) + indptr[frontier][:, None]
        src = indices[offs]
        src = jnp.where(deg[:, None] == 0, frontier[:, None], src)
        src = src.reshape(-1)
        return (key, jnp.concatenate([frontier, src])), (src, deg_all[src],
                                                         jnp.repeat(deg, fanout))

    carry = (key, jnp.asarray(targets))
    hop_src, hop_sdeg, hop_ddeg = [], [], []
    for f in fanouts:  # python loop: fanouts are static, frontier grows
        carry, (src, sdeg, ddeg) = hop(carry, f)
        hop_src.append(src)
        hop_sdeg.append(sdeg)
        hop_ddeg.append(ddeg)
    return MiniBatch(targets=jnp.asarray(targets),
                     labels=jnp.asarray(labels),
                     hop_src=tuple(hop_src), hop_src_deg=tuple(hop_sdeg),
                     hop_dst_deg=tuple(hop_ddeg), fanouts=tuple(fanouts))
