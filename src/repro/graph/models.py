"""GNN models in the aggregate-update paradigm (paper Section II-A).

Two evaluation models, exactly as in the paper:

* **GCN** (Eq. 3):  a_v = Σ h_u / sqrt(D̃(u) D̃(v));  h'_v = ReLU(a_v W + b)
* **GraphSAGE** (Eq. 4): a_v = h_v ‖ Mean(h_u);       h'_v = ReLU(a_v W + b)

Operating on the fixed-shape sampled ``MiniBatch`` blocks.  Because each dst
has exactly ``fanout`` sampled neighbors, neighbor aggregation admits two
equivalent layouts:

* ``dense``  — reshape to [n_dst, fanout, f] and reduce axis 1 (regular,
  MXU-friendly; the default on TPU),
* ``segsum`` — flat edge list + ``jax.ops.segment_sum`` (the irregular path
  the paper's FPGA kernel targets),
* ``pallas`` — the fused gather-aggregate(+update) Pallas kernel
  (``repro.kernels``), the TPU adaptation of the paper's scatter-gather PE +
  systolic-array datapath.

All three are allclose-tested against each other; the choice is a pure
performance knob, matching the paper's claim that its optimizations do not
alter training semantics.

Neighbor sampling is with replacement, so GCN's Σ over the true neighborhood
is estimated by ``(deg_v / fanout) * Σ_sampled`` (unbiased); GraphSAGE's Mean
needs no correction.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .sampler import MiniBatch, frontier_sizes

__all__ = ["GNNConfig", "init_params", "forward", "loss_fn", "param_count"]

Params = Dict[str, jax.Array]


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    model: str = "sage"                 # "sage" | "gcn"
    layer_dims: Tuple[int, ...] = (100, 256, 47)   # (f0, f1, f2) Table III
    fanouts: Tuple[int, ...] = (25, 10)
    num_classes: int = 47
    agg_impl: str = "dense"             # "dense" | "segsum" | "pallas"

    @property
    def num_layers(self) -> int:
        return len(self.layer_dims) - 1

    def dims_in_out(self) -> Sequence[Tuple[int, int]]:
        return list(zip(self.layer_dims[:-1], self.layer_dims[1:]))


def param_count(params: Params) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))


def init_params(key: jax.Array, cfg: GNNConfig, dtype=jnp.float32) -> Params:
    params: Params = {}
    for l, (fin, fout) in enumerate(cfg.dims_in_out(), start=1):
        key, k1 = jax.random.split(key)
        fan_in = 2 * fin if cfg.model == "sage" else fin
        w = jax.random.normal(k1, (fan_in, fout), dtype) / jnp.sqrt(fan_in)
        params[f"w{l}"] = w
        params[f"b{l}"] = jnp.zeros((fout,), dtype)
    return params


# ---------------------------------------------------------------- aggregation


def _agg_dense(x_self: jax.Array, x_nbr: jax.Array, w_edge: jax.Array | None,
               fanout: int) -> jax.Array:
    """Regular layout reduce.  x_nbr: [n_dst*fanout, f] -> [n_dst, f]."""
    n_dst = x_self.shape[0]
    xn = x_nbr.reshape(n_dst, fanout, -1)
    if w_edge is None:                       # SAGE mean
        return xn.mean(axis=1)
    we = w_edge.reshape(n_dst, fanout, 1)    # GCN weighted sum
    return (xn * we).sum(axis=1)


def _agg_segsum(x_self: jax.Array, x_nbr: jax.Array, w_edge: jax.Array | None,
                fanout: int) -> jax.Array:
    n_dst = x_self.shape[0]
    seg = jnp.repeat(jnp.arange(n_dst), fanout, total_repeat_length=n_dst * fanout)
    contrib = x_nbr if w_edge is None else x_nbr * w_edge[:, None]
    s = jax.ops.segment_sum(contrib, seg, num_segments=n_dst)
    return s / fanout if w_edge is None else s


def _aggregate(cfg: GNNConfig, x_self, x_nbr, w_edge, fanout):
    if cfg.agg_impl == "dense":
        return _agg_dense(x_self, x_nbr, w_edge, fanout)
    if cfg.agg_impl == "segsum":
        return _agg_segsum(x_self, x_nbr, w_edge, fanout)
    if cfg.agg_impl == "pallas":
        from repro.kernels import ops as kops
        we = (jnp.full((x_nbr.shape[0],), 1.0 / fanout, x_nbr.dtype)
              if w_edge is None else w_edge)
        return kops.segment_weighted_sum_regular(x_nbr, we, fanout)
    raise ValueError(cfg.agg_impl)


def _fused_layer(params: Params, cfg: GNNConfig, layer: int, x_self, x_nbr,
                 w_edge, self_scale, fanout: int) -> jax.Array:
    """Whole GNN layer through the fused Pallas kernel (agg never hits HBM)."""
    from repro.kernels import ops as kops
    w = params[f"w{layer}"]
    b = params[f"b{layer}"]
    fin = x_self.shape[-1]
    if cfg.model == "sage":
        # concat(x_self, mean_nbrs) @ W == x_self @ W[:fin] + mean @ W[fin:]
        we = jnp.full((x_nbr.shape[0],), 1.0 / fanout, x_nbr.dtype)
        ones = jnp.ones((x_self.shape[0],), x_self.dtype)
        return kops.fused_gnn_update(x_self, x_nbr, we, ones,
                                     w[:fin], w[fin:], b, fanout)
    # gcn: (agg + self_scale*x_self) @ W  — same W on both terms
    return kops.fused_gnn_update(x_self, x_nbr, w_edge, self_scale,
                                 w, w, b, fanout)


# ------------------------------------------------------------------- forward


def forward(params: Params, cfg: GNNConfig, batch: MiniBatch,
            x0: jax.Array) -> jax.Array:
    """Returns logits/embeddings for the batch targets [B, f_L].

    ``x0``: features of the innermost frontier (layer-0 inputs),
    shape [frontier_sizes(B, fanouts)[-1], f0].
    """
    L = cfg.num_layers
    assert L == len(batch.fanouts), (L, batch.fanouts)
    sizes = frontier_sizes(batch.batch_size, batch.fanouts)
    x = x0.astype(params["w1"].dtype)
    # layer 1 consumes hop L (innermost), layer L consumes hop 1
    for layer in range(1, L + 1):
        hop = L - layer          # 0-based hop index whose edges we consume
        n_dst = sizes[hop]
        fanout = batch.fanouts[hop]
        x_self = x[:n_dst]
        x_nbr = x[n_dst:]
        if cfg.model == "gcn":
            sdeg = batch.hop_src_deg[hop].astype(x.dtype)
            ddeg = batch.hop_dst_deg[hop].astype(x.dtype)
            norm = 1.0 / jnp.sqrt((sdeg + 1.0) * (ddeg + 1.0))
            # unbiased estimate of the true-neighborhood sum
            w_edge = norm * (ddeg / fanout)
            self_w = 1.0 / (ddeg.reshape(n_dst, fanout)[:, 0] + 1.0)
        else:
            w_edge = None
            self_w = None
        if cfg.agg_impl == "pallas_fused":
            h = _fused_layer(params, cfg, layer, x_self, x_nbr, w_edge,
                             self_w, fanout)
        else:
            if cfg.model == "gcn":
                agg = _aggregate(cfg, x_self, x_nbr, w_edge, fanout)
                a = agg + x_self * self_w[:, None]
            else:  # sage
                agg = _aggregate(cfg, x_self, x_nbr, None, fanout)
                a = jnp.concatenate([x_self, agg], axis=-1)
            h = a @ params[f"w{layer}"] + params[f"b{layer}"]
        x = jax.nn.relu(h) if layer < L else h
    return x  # [B, f_L]


def loss_fn(params: Params, cfg: GNNConfig, batch: MiniBatch,
            x0: jax.Array) -> Tuple[jax.Array, jax.Array]:
    logits = forward(params, cfg, batch, x0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, batch.labels[:, None].astype(jnp.int32),
                               axis=-1).mean()
    acc = (logits.argmax(-1) == batch.labels).mean()
    return nll, acc
