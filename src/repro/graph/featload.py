"""Feature Loader (paper Section III-A) — cache-aware host gather.

Runs on the host ("Feature Loading is only performed on the CPUs ... the
feature matrix X is stored in the CPU memory").  Given a sampled MiniBatch
it gathers the innermost frontier's feature rows from the dataset's
``FeatureSource`` into a contiguous buffer ready for the Data Transfer
stage.

Two gather modes:

  * ``load``        — the full frontier (legacy path; CPU trainers, whose
    "device" is host memory, and cache-disabled runs),
  * ``load_misses`` — only the rows absent from the device-resident
    ``FeatureCache``: the frontier is partitioned by the cache's
    vectorized id->slot table and just the miss block crosses PCIe.  The
    transfer stage ships (miss rows, slots, miss_index) and the on-device
    combine step reassembles the dense layer-0 input.

Supports optional on-the-fly down-cast to bf16 ("data quantization to
relieve the stress on the PCIe bandwidth" — the paper's §VIII future-work
item) and reports rows/bytes statistics consumed by the DRM engine and the
performance model.  ``stats.bytes`` counts only bytes actually *shipped*
(the quantity Eq. 7/8 model); cache savings are in ``stats.saved_bytes``.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional

import numpy as np

import jax.numpy as jnp

from .featcache import CacheLookup, FeatureCache
from .sampler import MiniBatch
from .storage import GraphDataset

__all__ = ["FeatureLoader", "LoadStats", "MissBlock"]

_BF16 = jnp.bfloat16  # numpy-compatible via ml_dtypes under the hood


@dataclasses.dataclass
class LoadStats:
    rows: int = 0            # rows shipped (gathered misses + any padding)
    bytes: int = 0           # bytes shipped host->device
    seconds: float = 0.0
    total_rows: int = 0      # frontier rows requested (hits + misses)
    hit_rows: int = 0        # rows served from the device cache
    saved_bytes: int = 0     # transfer bytes avoided by cache hits
    padding_bytes: int = 0   # share of `bytes` that is shape-bucket padding

    @property
    def hit_rate(self) -> float:
        return self.hit_rows / max(self.total_rows, 1)

    def merge(self, other: "LoadStats") -> None:
        self.rows += other.rows
        self.bytes += other.bytes
        self.seconds += other.seconds
        self.total_rows += other.total_rows
        self.hit_rows += other.hit_rows
        self.saved_bytes += other.saved_bytes
        self.padding_bytes += other.padding_bytes


@dataclasses.dataclass
class MissBlock:
    """Host-side output of a cache-aware load, ready for transfer.

    ``rows`` is the [M, F] miss block; ``lookup`` carries the slot /
    miss-index arrays the on-device combine consumes (see
    ``kernels.ops.assemble_features``).
    """
    rows: np.ndarray
    lookup: CacheLookup

    @property
    def num_rows(self) -> int:
        return self.lookup.num_rows


class FeatureLoader:
    def __init__(self, dataset: GraphDataset, transfer_dtype: str = "float32",
                 num_threads: int = 1,
                 cache: Optional[FeatureCache] = None):
        self.dataset = dataset
        self.source = dataset.feature_source
        self.transfer_dtype = transfer_dtype
        self.num_threads = max(1, int(num_threads))  # DRM's balance_thread knob
        self.cache = cache
        self.stats = LoadStats()       # transfer path (rows that cross PCIe)
        self.host_stats = LoadStats()  # CPU-trainer direct host reads
        # the load and transfer pipeline stages run in different threads
        # and both account into `stats` (gathers vs bucket padding)
        self._stats_lock = threading.Lock()

    def _account(self, dest: LoadStats, delta: LoadStats) -> None:
        with self._stats_lock:
            dest.merge(delta)

    def _gather(self, rows: np.ndarray) -> np.ndarray:
        if self.num_threads == 1 or rows.shape[0] < 2 * self.num_threads:
            return self.source.take(rows)
        # chunked gather: with >1 OS threads numpy gathers overlap page faults
        import concurrent.futures as cf
        chunks = np.array_split(rows, self.num_threads)
        with cf.ThreadPoolExecutor(self.num_threads) as pool:
            parts = list(pool.map(self.source.take, chunks))
        return np.concatenate(parts, axis=0)

    def _cast(self, x: np.ndarray) -> np.ndarray:
        if self.transfer_dtype == "bfloat16":
            return x.astype(_BF16)
        return x

    def _frontier(self, batch: MiniBatch) -> np.ndarray:
        return np.asarray(batch.frontier(len(batch.fanouts)))

    def load(self, batch: MiniBatch, to_device: bool = True) -> np.ndarray:
        """Gather features for the innermost frontier (layer-0 inputs).

        ``to_device=False`` marks a CPU-trainer load: the rows are consumed
        in place from host memory and never cross the interconnect, so they
        are accounted in ``host_stats`` instead of the transfer-path
        ``stats``.
        """
        t0 = time.perf_counter()
        frontier = self._frontier(batch)
        x = self._cast(self._gather(frontier))
        dt = time.perf_counter() - t0
        dest = self.stats if to_device else self.host_stats
        self._account(dest, LoadStats(rows=x.shape[0], bytes=x.nbytes,
                                      seconds=dt, total_rows=x.shape[0]))
        return x

    def note_transfer_padding(self, rows: int, nbytes: int) -> None:
        """Account padding rows the transfer stage ships beyond the gathered
        misses (shape-bucketing): they cross PCIe, so they count as shipped
        traffic even though no host gather produced them."""
        self._account(self.stats, LoadStats(rows=rows, bytes=nbytes,
                                            padding_bytes=nbytes))

    def load_misses(self, batch: MiniBatch) -> MissBlock:
        """Gather only the frontier rows the device cache does not hold."""
        if self.cache is None:
            raise RuntimeError("load_misses requires a FeatureCache")
        t0 = time.perf_counter()
        look = self.cache.lookup(self._frontier(batch))
        rows = self._cast(self._gather(look.miss_ids))
        dt = time.perf_counter() - t0
        self._account(self.stats, LoadStats(
            rows=rows.shape[0], bytes=rows.nbytes, seconds=dt,
            total_rows=look.num_rows, hit_rows=look.num_hit,
            saved_bytes=look.num_hit * self.cache.row_bytes))
        return MissBlock(rows=rows, lookup=look)
