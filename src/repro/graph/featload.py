"""Feature Loader (paper Section III-A) — cache- and dedup-aware host gather.

Runs on the host ("Feature Loading is only performed on the CPUs ... the
feature matrix X is stored in the CPU memory").  Given a sampled MiniBatch
it gathers feature rows from the dataset's ``FeatureSource`` into a
contiguous buffer ready for the Data Transfer stage.  When the source is
partitioned (``PartitionedFeatures`` / out-of-core ``MmapFeatures``) the
multi-threaded gather splits the request at partition boundaries so each
thread faults a disjoint set of mmap windows in parallel.

The unit of the transfer path is the *unique node id*, not the frontier
position: with-replacement sampling on power-law graphs makes most frontier
positions duplicates of a small hub set, so the loader gathers and ships
one row per unique id and lets the on-device combine step duplicate rows
back into the positional [frontier, F] layer-0 layout (the paper's Feature
Duplicator, moved to the far side of the interconnect).

Gather modes:

  * ``load``         — the full positional frontier (CPU trainers, whose
    "device" is host memory and who read rows in place, and legacy
    dedup-off/cache-off accelerator runs),
  * ``load_compact`` — the deduped transfer path: unique ids are computed
    once per mini-batch (``featcache.compact_lookup``), only uniques are
    classified against the optional device-resident ``FeatureCache``, and
    only *unique miss* rows are gathered and shipped.  The transfer stage
    sends (unique miss rows, slots, miss_index); the combine expands them.
  * ``load_misses``  — back-compat alias of ``load_compact`` that requires
    a cache (honours the loader's ``dedup`` flag).

Supports optional on-the-fly down-cast to bf16 ("data quantization to
relieve the stress on the PCIe bandwidth" — the paper's §VIII future-work
item) and reports rows/bytes statistics consumed by the DRM engine and the
performance model.  ``stats.bytes`` counts only bytes actually *shipped*
(the quantity Eq. 7/8 model); cache savings are in ``stats.saved_bytes``
and dedup savings in ``stats.dedup_saved_bytes`` — the three always sum
back to the legacy one-row-per-position baseline (plus bucket padding,
tracked separately in ``padding_bytes``).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional

import numpy as np

import jax.numpy as jnp

from repro.analysis.annotations import guarded_by

from .featcache import (CacheLookup, FeatureCache, compact_lookup,
                        wire_row_bytes)
from .sampler import MiniBatch
from .storage import GraphDataset

__all__ = ["FeatureLoader", "LoadStats", "MissBlock"]

_BF16 = jnp.bfloat16  # numpy-compatible via ml_dtypes under the hood


@dataclasses.dataclass
class LoadStats:
    rows: int = 0            # rows shipped (gathered uniques + any padding)
    bytes: int = 0           # bytes shipped host->device
    seconds: float = 0.0
    total_rows: int = 0      # frontier positions requested (hits + misses)
    unique_rows: int = 0     # unique ids among the requested positions
    hit_rows: int = 0        # positions served from the device cache
    saved_bytes: int = 0     # transfer bytes avoided by cache hits
    dedup_saved_bytes: int = 0  # transfer bytes avoided by deduplication
    padding_bytes: int = 0   # share of `bytes` that is shape-bucket padding
    stall_seconds: float = 0.0  # aggregate gather-thread seconds spent
                             #   faulting cold storage pages (disk-tier
                             #   mmap gathers the window prefetcher did
                             #   not pre-warm); summed across the chunked
                             #   gather's pool threads, so it can exceed
                             #   the wall-clock `seconds`.  0 on
                             #   RAM-resident sources

    @property
    def hit_rate(self) -> float:
        return self.hit_rows / max(self.total_rows, 1)

    @property
    def dup_factor(self) -> float:
        """Measured duplication factor (positions per unique id, >= 1)."""
        return self.total_rows / max(self.unique_rows, 1)

    def merge(self, other: "LoadStats") -> None:
        self.rows += other.rows
        self.bytes += other.bytes
        self.seconds += other.seconds
        self.total_rows += other.total_rows
        self.unique_rows += other.unique_rows
        self.hit_rows += other.hit_rows
        self.saved_bytes += other.saved_bytes
        self.dedup_saved_bytes += other.dedup_saved_bytes
        self.padding_bytes += other.padding_bytes
        self.stall_seconds += other.stall_seconds


@dataclasses.dataclass
class MissBlock:
    """Host-side output of a compact (dedup/cache-aware) load.

    ``rows`` is the [M, F] unique-miss block; ``lookup`` carries the
    positional slot / miss-index tables the on-device combine consumes
    (see ``kernels.ops.assemble_features``) — under dedup many positions
    point at the same row of ``rows``.
    """
    rows: np.ndarray
    lookup: CacheLookup

    @property
    def num_rows(self) -> int:
        return self.lookup.num_rows


# the load and transfer pipeline stages run in different threads and both
# account into the same stats windows; every merge resolves its
# destination and runs under _stats_lock
@guarded_by("_stats_lock", "stats", "window", "host_stats")
class FeatureLoader:
    def __init__(self, dataset: GraphDataset, transfer_dtype: str = "float32",
                 num_threads: int = 1,
                 cache: Optional[FeatureCache] = None,
                 dedup: bool = True):
        self.dataset = dataset
        self.source = dataset.feature_source
        self.transfer_dtype = transfer_dtype
        self.num_threads = max(1, int(num_threads))  # DRM's balance_thread knob
        self.cache = cache
        self.dedup = dedup
        self.stats = LoadStats()       # transfer path (rows that cross PCIe)
        self.window = LoadStats()      # transfer path since the last cache
                                       #   refresh (windowed feedback: the
                                       #   perf-model re-pricing must see
                                       #   the post-refresh rate, not a
                                       #   lifetime average)
        self.host_stats = LoadStats()  # CPU-trainer direct host reads
        # the load and transfer pipeline stages run in different threads
        # and both account into `stats` (gathers vs bucket padding)
        self._stats_lock = threading.Lock()
        # chunked-gather pool: created lazily, reused across load calls
        # (executor construction/teardown per call costs more than the
        # chunked gather saves on small frontiers)
        self._pool = None
        self._pool_size = 0
        self._row_bytes = wire_row_bytes(dataset.feat_dim, transfer_dtype)

    def _account(self, dest: str, delta: LoadStats) -> None:
        # `dest` names the window ("stats" / "host_stats") instead of
        # passing the object: resolving it under the lock keeps even the
        # destination *read* inside the guarded region (reset_window may
        # rebind `window` concurrently)
        with self._stats_lock:
            target: LoadStats = getattr(self, dest)
            target.merge(delta)
            if dest == "stats":        # transfer path also feeds the window
                self.window.merge(delta)

    def reset_window(self) -> None:
        """Start a fresh measurement window (called after a cache refresh
        so drift/feedback consumers see only post-refresh traffic)."""
        with self._stats_lock:
            self.window = LoadStats()

    def _get_pool(self):
        import concurrent.futures as cf
        if self._pool is None or self._pool_size != self.num_threads:
            if self._pool is not None:
                self._pool.shutdown(wait=False)
            self._pool = cf.ThreadPoolExecutor(
                self.num_threads, thread_name_prefix="featload")
            self._pool_size = self.num_threads
        return self._pool

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=False)
            self._pool = None
            self._pool_size = 0

    def __del__(self):  # pragma: no cover - GC timing dependent
        try:
            self.close()
        except Exception:
            pass

    def _source_stall(self) -> float:
        """Cumulative cold-page-fault seconds reported by the source (0
        for RAM-resident sources) — deltas around a gather give the share
        of its wall time that was a storage stall.  Pool threads finish
        inside the gather call, so the delta is race-free as long as
        loads run from one stage thread (the pipeline's contract)."""
        return float(getattr(self.source, "cold_gather_seconds", 0.0))

    def _split_chunks(self, rows: np.ndarray):
        """Split a gather into per-thread chunks.

        For partitioned/mmap sources (anything exposing ``partition_rows``)
        the split is *partition-aligned*: rows are grouped by partition and
        cut only at partition boundaries, so each pool thread faults a
        disjoint set of mmap windows (the point of the chunked gather —
        naive ``array_split`` on an arbitrary-order frontier makes every
        thread touch every window).  Returns ``(chunks, order)`` where
        ``order`` is the permutation that sorted the rows (``None`` for the
        legacy order-preserving split).
        """
        prows = int(getattr(self.source, "partition_rows", 0) or 0)
        if prows <= 0:
            return np.array_split(rows, self.num_threads), None
        part_id = rows // prows
        order = np.argsort(part_id, kind="stable")
        sorted_rows = rows[order]
        n = rows.shape[0]
        # candidate cut positions = partition boundaries in the sorted
        # stream; pick the one at/after each equal-share target
        bounds = np.flatnonzero(np.diff(part_id[order])) + 1
        cand = np.concatenate([bounds, [n]])
        targets = np.arange(1, self.num_threads) * n // self.num_threads
        cuts = np.unique(cand[np.searchsorted(cand, targets)])
        chunks = [c for c in np.split(sorted_rows, cuts) if c.shape[0]]
        return chunks, order

    def _gather(self, rows: np.ndarray) -> np.ndarray:
        if self.num_threads == 1 or rows.shape[0] < 2 * self.num_threads:
            return self.source.take(rows)
        # chunked gather: with >1 OS threads numpy gathers overlap page faults
        chunks, order = self._split_chunks(rows)
        parts = list(self._get_pool().map(self.source.take, chunks))
        gathered = np.concatenate(parts, axis=0)
        if order is None:
            return gathered
        out = np.empty_like(gathered)
        out[order] = gathered      # scatter back into request order
        return out

    def _cast(self, x: np.ndarray) -> np.ndarray:
        if self.transfer_dtype == "bfloat16":
            return x.astype(_BF16)
        return x

    def _frontier(self, batch: MiniBatch) -> np.ndarray:
        return np.asarray(batch.frontier(len(batch.fanouts)))

    def load(self, batch: MiniBatch, to_device: bool = True) -> np.ndarray:
        """Gather features for the innermost frontier (layer-0 inputs).

        ``to_device=False`` marks a CPU-trainer load: the rows are consumed
        in place from host memory and never cross the interconnect, so they
        are accounted in ``host_stats`` instead of the transfer-path
        ``stats``.
        """
        t0 = time.perf_counter()
        stall0 = self._source_stall()
        frontier = self._frontier(batch)
        x = self._cast(self._gather(frontier))
        dt = time.perf_counter() - t0
        dest = "stats" if to_device else "host_stats"
        self._account(dest, LoadStats(rows=x.shape[0], bytes=x.nbytes,
                                      seconds=dt, total_rows=x.shape[0],
                                      unique_rows=x.shape[0],
                                      stall_seconds=self._source_stall()
                                      - stall0))
        return x

    def note_transfer_padding(self, rows: int, nbytes: int) -> None:
        """Account padding rows the transfer stage ships beyond the gathered
        misses (shape-bucketing): they cross PCIe, so they count as shipped
        traffic even though no host gather produced them."""
        self._account("stats", LoadStats(rows=rows, bytes=nbytes,
                                         padding_bytes=nbytes))

    def load_compact(self, batch: MiniBatch, pin: bool = False) -> MissBlock:
        """Deduped transfer-path load: gather one row per unique miss id.

        Works with or without a device cache.  With a cache, only the
        frontier's unique ids are classified against it and only unique
        *miss* rows are gathered; without one, every unique id is a miss.
        When the loader was built with ``dedup=False`` (legacy positional
        path) a cache is required and one row per miss position ships.

        Failure model: the lookup only *classifies* here
        (``record=False``); cache stats/hotness and loader stats are
        committed together after the miss gather succeeded.  A gather
        that raises (storage fault past the retry/fallback budget, a
        pool-thread exception) therefore surfaces exactly once and
        leaves every stats window untouched — no half-recorded batch.

        ``pin=True`` registers the classification version as in flight
        (``FeatureCache.lookup`` pinning protocol); the consumer of the
        returned block must call ``cache.release_lookup(block.lookup)``
        exactly once after the combine — the pipelined trainer does this
        in its transfer stage so drained versions retire eagerly.
        """
        t0 = time.perf_counter()
        stall0 = self._source_stall()
        frontier = self._frontier(batch)
        if self.cache is not None:
            look = self.cache.lookup(frontier, dedup=self.dedup,
                                     record=False, pin=pin)
            row_bytes = self.cache.row_bytes
        else:
            if not self.dedup:
                raise RuntimeError(
                    "load_compact without a FeatureCache requires dedup")
            look = compact_lookup(frontier)
            row_bytes = self._row_bytes
        rows = self._cast(self._gather(look.miss_ids))
        dt = time.perf_counter() - t0
        if self.cache is not None:
            self.cache.record_lookup(look)
        self._account("stats", LoadStats(
            rows=rows.shape[0], bytes=rows.nbytes, seconds=dt,
            total_rows=look.num_rows, unique_rows=look.num_unique,
            hit_rows=look.num_hit,
            saved_bytes=look.num_hit * row_bytes,
            dedup_saved_bytes=look.dup_miss_rows * row_bytes,
            stall_seconds=self._source_stall() - stall0))
        return MissBlock(rows=rows, lookup=look)

    def load_misses(self, batch: MiniBatch) -> MissBlock:
        """Gather only the frontier rows the device cache does not hold
        (deduped unless the loader was built with ``dedup=False``)."""
        if self.cache is None:
            raise RuntimeError("load_misses requires a FeatureCache")
        return self.load_compact(batch)
