"""Feature Loader (paper Section III-A) — cache- and dedup-aware host gather.

Runs on the host ("Feature Loading is only performed on the CPUs ... the
feature matrix X is stored in the CPU memory").  Given a sampled MiniBatch
it gathers feature rows from the dataset's ``FeatureSource`` into a
contiguous buffer ready for the Data Transfer stage.  When the source is
partitioned (``PartitionedFeatures`` / out-of-core ``MmapFeatures``) the
multi-threaded gather splits the request at partition boundaries so each
thread faults a disjoint set of mmap windows in parallel.

The unit of the transfer path is the *unique node id*, not the frontier
position: with-replacement sampling on power-law graphs makes most frontier
positions duplicates of a small hub set, so the loader gathers and ships
one row per unique id and lets the on-device combine step duplicate rows
back into the positional [frontier, F] layer-0 layout (the paper's Feature
Duplicator, moved to the far side of the interconnect).

Gather modes:

  * ``load``         — the full positional frontier (CPU trainers, whose
    "device" is host memory and who read rows in place, and legacy
    dedup-off/cache-off accelerator runs),
  * ``load_compact`` — the deduped transfer path: unique ids are computed
    once per mini-batch (``featcache.compact_lookup``), only uniques are
    classified against the optional device-resident ``FeatureCache``, and
    only *unique miss* rows are gathered and shipped.  The transfer stage
    sends (unique miss rows, slots, miss_index); the combine expands them.
  * ``load_misses``  — back-compat alias of ``load_compact`` that requires
    a cache (honours the loader's ``dedup`` flag).

Two further levers stack on top of the compact path:

  * ``load_union`` — the sharded-plane load: ALL accelerator trainers'
    frontiers are classified against the ``ShardedFeatureCache`` in one
    union lookup, the host gathers the *union* of their fresh-miss sets
    once, and each union row is multicast only to the devices that need
    it.  Accounting models the physical route: a union row crosses PCIe
    once (``stats.bytes``); its extra device copies and the peer-shard
    row hops ride the accelerator interconnect (``ici_bytes``).  The
    PCIe bytes the union dedup avoids vs per-trainer gathers land in
    ``union_saved_bytes``; peer-shard hits in ``peer_saved_bytes``.
  * recent-rows LRU (``recent_batches`` > 0 + a ``recent_key``) —
    cross-iteration device-side dedup: ``load_compact`` remembers the
    unique ids shipped to each consumer over the last few batches, skips
    re-gathering/re-shipping rows still resident on the device (their
    device arrays are re-read by the combine), and drops the history
    whenever the cache version moves.  Savings in
    ``recent_saved_bytes``.

Supports optional on-the-fly down-cast to bf16 ("data quantization to
relieve the stress on the PCIe bandwidth" — the paper's §VIII future-work
item) and reports rows/bytes statistics consumed by the DRM engine and the
performance model.  ``stats.bytes`` counts only bytes actually *shipped*
host->device (the quantity Eq. 7/8 model); every avoided ship is
attributed to exactly one counter (``saved_bytes`` cache hits,
``peer_saved_bytes`` peer-shard hits, ``dedup_saved_bytes`` in-batch
duplicates, ``union_saved_bytes`` cross-trainer union dedup,
``recent_saved_bytes`` cross-iteration residency) — the counters always
sum back to the legacy one-row-per-position baseline (plus bucket
padding, tracked separately in ``padding_bytes``).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

import jax.numpy as jnp

from repro.analysis.annotations import guarded_by

from .featcache import (CacheLookup, FeatureCache, ShardLookup,
                        compact_lookup, wire_row_bytes)
from .sampler import MiniBatch
from .storage import GraphDataset

__all__ = ["FeatureLoader", "LoadStats", "MissBlock", "ShardMissBlock"]

_BF16 = jnp.bfloat16  # numpy-compatible via ml_dtypes under the hood


@dataclasses.dataclass
class LoadStats:
    rows: int = 0            # rows shipped (gathered uniques + any padding)
    bytes: int = 0           # bytes shipped host->device
    seconds: float = 0.0
    total_rows: int = 0      # frontier positions requested (hits + misses)
    unique_rows: int = 0     # unique ids among the requested positions
    hit_rows: int = 0        # positions served from the device cache
    saved_bytes: int = 0     # transfer bytes avoided by LOCAL cache hits
    dedup_saved_bytes: int = 0  # transfer bytes avoided by deduplication
    padding_bytes: int = 0   # share of `bytes` that is shape-bucket padding
    peer_rows: int = 0       # unique rows pulled from peer shards over ICI
    peer_saved_bytes: int = 0   # PCIe bytes avoided by peer-shard hits
    union_saved_bytes: int = 0  # PCIe bytes avoided by the cross-trainer
                             #   union gather (each shared row ships once)
    ici_bytes: int = 0       # bytes crossing the accelerator interconnect
                             #   (peer row hops + multicast fan-out copies)
    recent_rows: int = 0     # unique rows skipped: still device-resident
                             #   from a recent batch (cross-iteration LRU)
    recent_saved_bytes: int = 0  # PCIe bytes those skips avoided
    stall_seconds: float = 0.0  # aggregate gather-thread seconds spent
                             #   faulting cold storage pages (disk-tier
                             #   mmap gathers the window prefetcher did
                             #   not pre-warm); summed across the chunked
                             #   gather's pool threads, so it can exceed
                             #   the wall-clock `seconds`.  0 on
                             #   RAM-resident sources

    @property
    def hit_rate(self) -> float:
        return self.hit_rows / max(self.total_rows, 1)

    @property
    def dup_factor(self) -> float:
        """Measured duplication factor (positions per unique id, >= 1)."""
        return self.total_rows / max(self.unique_rows, 1)

    def merge(self, other: "LoadStats") -> None:
        self.rows += other.rows
        self.bytes += other.bytes
        self.seconds += other.seconds
        self.total_rows += other.total_rows
        self.unique_rows += other.unique_rows
        self.hit_rows += other.hit_rows
        self.saved_bytes += other.saved_bytes
        self.dedup_saved_bytes += other.dedup_saved_bytes
        self.padding_bytes += other.padding_bytes
        self.peer_rows += other.peer_rows
        self.peer_saved_bytes += other.peer_saved_bytes
        self.union_saved_bytes += other.union_saved_bytes
        self.ici_bytes += other.ici_bytes
        self.recent_rows += other.recent_rows
        self.recent_saved_bytes += other.recent_saved_bytes
        self.stall_seconds += other.stall_seconds


@dataclasses.dataclass
class _ShippedBlock:
    """Recent-rows LRU entry: the unique ids one batch freshly shipped to
    a consumer device, plus (once the transfer stage ran) the device
    array holding them.  ``array`` is written exactly once by the
    transfer stage and only read by LATER batches' transfer stages —
    pipeline stages process batches in order, so a batch that matched
    this entry at load time is guaranteed to find ``array`` filled by
    the time its own combine runs."""
    ids: np.ndarray          # sorted unique ids of the shipped fresh rows
    version: int             # cache version the ship was classified at
    array: Optional[object] = None  # [>=len(ids), F] device rows


@dataclasses.dataclass
class MissBlock:
    """Host-side output of a compact (dedup/cache-aware) load.

    ``rows`` is the [M, F] unique-miss block; ``lookup`` carries the
    positional slot / miss-index tables the on-device combine consumes
    (see ``kernels.ops.assemble_features``) — under dedup many positions
    point at the same row of ``rows``.

    With the recent-rows LRU active, ``miss_index`` addresses the
    combined source ``[recent segments... | fresh rows]``: ``recent``
    lists (entry, row indices) pairs to re-read from device-resident
    arrays of earlier batches, and ``shipped`` is this batch's own LRU
    entry whose ``array`` the transfer stage must fill.
    """
    rows: np.ndarray
    lookup: CacheLookup
    recent: List[Tuple[_ShippedBlock, np.ndarray]] = \
        dataclasses.field(default_factory=list)
    shipped: Optional[_ShippedBlock] = None

    @property
    def num_rows(self) -> int:
        return self.lookup.num_rows


@dataclasses.dataclass
class ShardMissBlock(MissBlock):
    """Per-trainer output of the sharded-plane ``load_union``: ``rows``
    holds only the trainer's slice of the union gather (its fresh host
    misses), ``lookup`` indexes the local shard block + the combined
    ``[peer rows | fresh rows]`` source, and ``shard`` carries the peer
    requests and per-shard version pins the transfer stage resolves."""
    shard: Optional[ShardLookup] = None


# the load and transfer pipeline stages run in different threads and both
# account into the same stats windows; every merge resolves its
# destination and runs under _stats_lock
@guarded_by("_stats_lock", "stats", "window", "host_stats")
class FeatureLoader:
    def __init__(self, dataset: GraphDataset, transfer_dtype: str = "float32",
                 num_threads: int = 1,
                 cache: Optional[FeatureCache] = None,
                 dedup: bool = True, recent_batches: int = 0):
        self.dataset = dataset
        self.source = dataset.feature_source
        self.transfer_dtype = transfer_dtype
        self.num_threads = max(1, int(num_threads))  # DRM's balance_thread knob
        self.cache = cache   # FeatureCache or ShardedFeatureCache (union path)
        self.dedup = dedup
        self.recent_batches = max(0, int(recent_batches))
        # cross-iteration residency history: consumer key -> deque of the
        # last `recent_batches` _ShippedBlock entries.  The structure is
        # touched by the load stage (match/append/invalidate) and by
        # drop_recent (failure cleanup from other threads), so the tiny
        # dedicated _recent_lock guards the dict/deques; the entries'
        # `array` field is deliberately outside it (single writer — the
        # transfer stage, in batch order — and only read by later batches
        # of that same stage).
        self._recent: Dict[object, deque] = {}
        self._recent_lock = threading.Lock()
        self.stats = LoadStats()       # transfer path (rows that cross PCIe)
        self.window = LoadStats()      # transfer path since the last cache
                                       #   refresh (windowed feedback: the
                                       #   perf-model re-pricing must see
                                       #   the post-refresh rate, not a
                                       #   lifetime average)
        self.host_stats = LoadStats()  # CPU-trainer direct host reads
        # the load and transfer pipeline stages run in different threads
        # and both account into `stats` (gathers vs bucket padding)
        self._stats_lock = threading.Lock()
        # chunked-gather pool: created lazily, reused across load calls
        # (executor construction/teardown per call costs more than the
        # chunked gather saves on small frontiers)
        self._pool = None
        self._pool_size = 0
        self._row_bytes = wire_row_bytes(dataset.feat_dim, transfer_dtype)

    def _account(self, dest: str, delta: LoadStats) -> None:
        # `dest` names the window ("stats" / "host_stats") instead of
        # passing the object: resolving it under the lock keeps even the
        # destination *read* inside the guarded region (reset_window may
        # rebind `window` concurrently)
        with self._stats_lock:
            target: LoadStats = getattr(self, dest)
            target.merge(delta)
            if dest == "stats":        # transfer path also feeds the window
                self.window.merge(delta)

    def reset_window(self) -> None:
        """Start a fresh measurement window (called after a cache refresh
        so drift/feedback consumers see only post-refresh traffic)."""
        with self._stats_lock:
            self.window = LoadStats()

    def snapshot_stats(self) -> LoadStats:
        """Consistent copy of the cumulative transfer-path stats — the
        knob autotuner diffs consecutive snapshots to get per-window
        traffic without resetting the window the drift feedback reads."""
        with self._stats_lock:
            return dataclasses.replace(self.stats)

    def _get_pool(self):
        import concurrent.futures as cf
        if self._pool is None or self._pool_size != self.num_threads:
            if self._pool is not None:
                self._pool.shutdown(wait=False)
            self._pool = cf.ThreadPoolExecutor(
                self.num_threads, thread_name_prefix="featload")
            self._pool_size = self.num_threads
        return self._pool

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=False)
            self._pool = None
            self._pool_size = 0

    def __del__(self):  # pragma: no cover - GC timing dependent
        try:
            self.close()
        except Exception:
            pass

    def _source_stall(self) -> float:
        """Cumulative cold-page-fault seconds reported by the source (0
        for RAM-resident sources) — deltas around a gather give the share
        of its wall time that was a storage stall.  Pool threads finish
        inside the gather call, so the delta is race-free as long as
        loads run from one stage thread (the pipeline's contract)."""
        return float(getattr(self.source, "cold_gather_seconds", 0.0))

    def _split_chunks(self, rows: np.ndarray):
        """Split a gather into per-thread chunks.

        For partitioned/mmap sources (anything exposing ``partition_rows``)
        the split is *partition-aligned*: rows are grouped by partition and
        cut only at partition boundaries, so each pool thread faults a
        disjoint set of mmap windows (the point of the chunked gather —
        naive ``array_split`` on an arbitrary-order frontier makes every
        thread touch every window).  Returns ``(chunks, order)`` where
        ``order`` is the permutation that sorted the rows (``None`` for the
        legacy order-preserving split).
        """
        prows = int(getattr(self.source, "partition_rows", 0) or 0)
        if prows <= 0:
            return np.array_split(rows, self.num_threads), None
        part_id = rows // prows
        order = np.argsort(part_id, kind="stable")
        sorted_rows = rows[order]
        n = rows.shape[0]
        # candidate cut positions = partition boundaries in the sorted
        # stream; pick the one at/after each equal-share target
        bounds = np.flatnonzero(np.diff(part_id[order])) + 1
        cand = np.concatenate([bounds, [n]])
        targets = np.arange(1, self.num_threads) * n // self.num_threads
        cuts = np.unique(cand[np.searchsorted(cand, targets)])
        chunks = [c for c in np.split(sorted_rows, cuts) if c.shape[0]]
        return chunks, order

    def _gather(self, rows: np.ndarray) -> np.ndarray:
        if self.num_threads == 1 or rows.shape[0] < 2 * self.num_threads:
            return self.source.take(rows)
        # chunked gather: with >1 OS threads numpy gathers overlap page faults
        chunks, order = self._split_chunks(rows)
        parts = list(self._get_pool().map(self.source.take, chunks))
        gathered = np.concatenate(parts, axis=0)
        if order is None:
            return gathered
        out = np.empty_like(gathered)
        out[order] = gathered      # scatter back into request order
        return out

    def _cast(self, x: np.ndarray) -> np.ndarray:
        if self.transfer_dtype == "bfloat16":
            return x.astype(_BF16)
        return x

    def _frontier(self, batch: MiniBatch) -> np.ndarray:
        return np.asarray(batch.frontier(len(batch.fanouts)))

    def load(self, batch: MiniBatch, to_device: bool = True) -> np.ndarray:
        """Gather features for the innermost frontier (layer-0 inputs).

        ``to_device=False`` marks a CPU-trainer load: the rows are consumed
        in place from host memory and never cross the interconnect, so they
        are accounted in ``host_stats`` instead of the transfer-path
        ``stats``.
        """
        t0 = time.perf_counter()
        stall0 = self._source_stall()
        frontier = self._frontier(batch)
        x = self._cast(self._gather(frontier))
        dt = time.perf_counter() - t0
        dest = "stats" if to_device else "host_stats"
        self._account(dest, LoadStats(rows=x.shape[0], bytes=x.nbytes,
                                      seconds=dt, total_rows=x.shape[0],
                                      unique_rows=x.shape[0],
                                      stall_seconds=self._source_stall()
                                      - stall0))
        return x

    def note_transfer_padding(self, rows: int, nbytes: int) -> None:
        """Account padding rows the transfer stage ships beyond the gathered
        misses (shape-bucketing): they cross PCIe, so they count as shipped
        traffic even though no host gather produced them."""
        self._account("stats", LoadStats(rows=rows, bytes=nbytes,
                                         padding_bytes=nbytes))

    def drop_recent(self, key: object = None) -> None:
        """Drop the recent-rows residency history for ``key`` (all
        consumers when ``None``) — failure cleanup: a consumer whose
        transfer stage stopped filling its entries must never be matched
        against again."""
        with self._recent_lock:
            if key is None:
                self._recent.clear()
            else:
                self._recent.pop(key, None)

    def _match_recent(self, key: object, look: CacheLookup):
        """Split ``look``'s unique misses into device-resident rows (in
        the consumer's recent shipped blocks, at the SAME cache version)
        and fresh ids, and remap the positional ``miss_index`` onto the
        combined ``[recent segments... | fresh]`` source layout.  Pure
        planning — ``look`` itself is not mutated here."""
        miss = look.miss_ids
        with self._recent_lock:
            dq = self._recent.get(key)
            entries = [e for e in (dq or ())
                       if e.version == look.version and e.ids.shape[0]]
            if dq is not None and len(entries) != len(dq):
                # a cache refresh moved the version: the old rows are
                # value-identical (the source is immutable) but the
                # conservative contract invalidates residency across
                # refreshes — accounting must never outlive its pricing
                dq.clear()
                dq.extend(entries)
        taken = np.zeros(miss.shape[0], dtype=bool)
        combined = np.empty(miss.shape[0], dtype=np.int32)
        sources: List[Tuple[_ShippedBlock, np.ndarray]] = []
        base = 0
        # newest entry first: consecutive batches share the most rows
        for e in reversed(entries):
            if bool(taken.all()):
                break
            pos = np.searchsorted(e.ids, miss)
            pos = np.minimum(pos, e.ids.shape[0] - 1)
            m = (~taken) & (e.ids[pos] == miss)
            k = int(np.count_nonzero(m))
            if not k:
                continue
            sources.append((e, pos[m].astype(np.int32)))
            combined[m] = base + np.arange(k, dtype=np.int32)
            base += k
            taken |= m
        fresh_mask = ~taken
        n_fresh = int(np.count_nonzero(fresh_mask))
        combined[fresh_mask] = base + np.arange(n_fresh, dtype=np.int32)
        new_miss_index = np.where(
            look.slots >= 0, np.int32(0),
            combined[look.miss_index]).astype(np.int32)
        return miss[fresh_mask], sources, new_miss_index

    def load_compact(self, batch: MiniBatch, pin: bool = False,
                     recent_key: object = None) -> MissBlock:
        """Deduped transfer-path load: gather one row per unique miss id.

        Works with or without a device cache.  With a cache, only the
        frontier's unique ids are classified against it and only unique
        *miss* rows are gathered; without one, every unique id is a miss.
        When the loader was built with ``dedup=False`` (legacy positional
        path) a cache is required and one row per miss position ships.

        Failure model: the lookup only *classifies* here
        (``record=False``); cache stats/hotness and loader stats are
        committed together after the miss gather succeeded.  A gather
        that raises (storage fault past the retry/fallback budget, a
        pool-thread exception) therefore surfaces exactly once and
        leaves every stats window untouched — no half-recorded batch.

        ``pin=True`` registers the classification version as in flight
        (``FeatureCache.lookup`` pinning protocol); the consumer of the
        returned block must call ``cache.release_lookup(block.lookup)``
        exactly once after the combine — the pipelined trainer does this
        in its transfer stage so drained versions retire eagerly.

        ``recent_key`` (with ``recent_batches`` > 0) engages the
        cross-iteration device-side dedup: unique misses still resident
        on the consumer's device from its last few batches are split off
        and NOT gathered/shipped again — the block's ``recent`` list
        tells the combine where to re-read them, and ``shipped``
        registers this batch's fresh rows for future reuse.
        """
        t0 = time.perf_counter()
        stall0 = self._source_stall()
        frontier = self._frontier(batch)
        if self.cache is not None:
            look = self.cache.lookup(frontier, dedup=self.dedup,
                                     record=False, pin=pin)
            row_bytes = self.cache.row_bytes
        else:
            if not self.dedup:
                raise RuntimeError(
                    "load_compact without a FeatureCache requires dedup")
            look = compact_lookup(frontier)
            row_bytes = self._row_bytes
        use_recent = (recent_key is not None and self.recent_batches > 0
                      and self.dedup)
        if use_recent:
            fresh_ids, recent_src, new_miss_index = \
                self._match_recent(recent_key, look)
        else:
            fresh_ids, recent_src, new_miss_index = look.miss_ids, [], None
        rows = self._cast(self._gather(fresh_ids))
        dt = time.perf_counter() - t0
        # deferred accounting commits only after the gather succeeded,
        # and against the ORIGINAL classification — the recent-LRU split
        # below only rewrites the transfer plan, not the hit/miss truth
        if self.cache is not None:
            self.cache.record_lookup(look)
        n_recent = look.num_miss - int(fresh_ids.shape[0])
        self._account("stats", LoadStats(
            rows=rows.shape[0], bytes=rows.nbytes, seconds=dt,
            total_rows=look.num_rows, unique_rows=look.num_unique,
            hit_rows=look.num_hit,
            saved_bytes=look.num_hit * row_bytes,
            dedup_saved_bytes=look.dup_miss_rows * row_bytes,
            recent_rows=n_recent,
            recent_saved_bytes=n_recent * row_bytes,
            stall_seconds=self._source_stall() - stall0))
        shipped = None
        if use_recent:
            # rewrite the lookup onto the combined source layout and
            # register this batch's fresh rows for future reuse
            look.miss_ids = fresh_ids
            look.miss_index = new_miss_index
            shipped = _ShippedBlock(ids=fresh_ids, version=look.version)
            with self._recent_lock:
                dq = self._recent.get(recent_key)
                if dq is None or dq.maxlen != self.recent_batches:
                    dq = deque(dq or (), maxlen=self.recent_batches)
                    self._recent[recent_key] = dq
                dq.append(shipped)
        return MissBlock(rows=rows, lookup=look, recent=recent_src,
                         shipped=shipped)

    def load_union(self, batches: Dict[str, MiniBatch],
                   ordinals: Dict[str, int],
                   pin: bool = False) -> Dict[str, "ShardMissBlock"]:
        """Sharded-plane load: ONE host gather for the union of every
        accelerator trainer's fresh-miss set.

        Requires the loader's cache to be a ``ShardedFeatureCache``.
        All frontiers are classified in one ``lookup_union`` (local /
        peer / fresh per trainer, every shard pinned once per trainer
        when ``pin``), the union of the fresh sets is gathered once, and
        each trainer's block receives only its slice (the multicast:
        each union row is replicated only to the devices that need it).

        Accounting models the physical route on real hardware: a union
        row crosses PCIe once (``bytes``); the extra copies for trainers
        sharing it, and the peer-shard row hops, ride the accelerator
        interconnect (``ici_bytes``).  ``union_saved_bytes`` is the PCIe
        traffic avoided vs n independent per-trainer dedup gathers —
        the quantity the bench/CI gate compares.  Deferred accounting:
        per-shard stats/hotness commit only after the gather succeeded
        (``record_union``), mirroring ``load_compact``."""
        cache = self.cache
        if cache is None or not hasattr(cache, "lookup_union"):
            raise RuntimeError("load_union requires a ShardedFeatureCache")
        t0 = time.perf_counter()
        stall0 = self._source_stall()
        frontiers = {name: self._frontier(b) for name, b in batches.items()}
        union = cache.lookup_union(frontiers, ordinals, pin=pin,
                                   record=False)
        fresh_sets = [sl.look.miss_ids
                      for sl in union.per_trainer.values()
                      if sl.look.miss_ids.shape[0]]
        if fresh_sets:
            union_ids = np.unique(np.concatenate(fresh_sets))
        else:
            union_ids = np.zeros(0, dtype=np.int64)
        rows = self._cast(self._gather(union_ids))
        dt = time.perf_counter() - t0
        cache.record_union(union)
        row_bytes = cache.row_bytes
        out: Dict[str, ShardMissBlock] = {}
        tot_pos = tot_uniq = tot_local = 0
        tot_peer_pos = tot_peer_rows = tot_fresh = dup_pos = 0
        for name in sorted(union.per_trainer):
            sl = union.per_trainer[name]
            look = sl.look
            # the trainer's multicast slice: union rows are sorted by id
            # and miss_ids is a sorted subset, so searchsorted is exact
            idx = np.searchsorted(union_ids, look.miss_ids)
            out[name] = ShardMissBlock(rows=rows[idx], lookup=look,
                                       shard=sl)
            tot_pos += look.num_rows
            tot_uniq += look.num_unique
            tot_local += look.num_hit
            tot_peer_pos += sl.peer_positions
            tot_peer_rows += sl.peer_rows
            tot_fresh += look.num_miss
            dup_pos += (look.miss_positions - sl.peer_positions
                        - look.num_miss)
        multicast_extra = tot_fresh - int(union_ids.shape[0])
        self._account("stats", LoadStats(
            rows=int(union_ids.shape[0]), bytes=rows.nbytes, seconds=dt,
            total_rows=tot_pos, unique_rows=tot_uniq,
            hit_rows=tot_local + tot_peer_pos,
            saved_bytes=tot_local * row_bytes,
            dedup_saved_bytes=dup_pos * row_bytes,
            peer_rows=tot_peer_rows,
            peer_saved_bytes=tot_peer_pos * row_bytes,
            union_saved_bytes=multicast_extra * row_bytes,
            ici_bytes=(tot_peer_rows + multicast_extra) * row_bytes,
            stall_seconds=self._source_stall() - stall0))
        return out

    def load_misses(self, batch: MiniBatch) -> MissBlock:
        """Gather only the frontier rows the device cache does not hold
        (deduped unless the loader was built with ``dedup=False``)."""
        if self.cache is None:
            raise RuntimeError("load_misses requires a FeatureCache")
        return self.load_compact(batch)
