"""Feature Loader (paper Section III-A).

Runs on the host ("Feature Loading is only performed on the CPUs ... the
feature matrix X is stored in the CPU memory").  Given a sampled MiniBatch it
gathers the innermost frontier's feature rows from host storage into a
contiguous buffer ready for the Data Transfer stage.

Supports optional on-the-fly down-cast to bf16 ("data quantization to relieve
the stress on the PCIe bandwidth" — the paper's §VIII future-work item) and
reports bytes/rows statistics consumed by the DRM engine and the performance
model.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

import jax.numpy as jnp

from .sampler import MiniBatch
from .storage import GraphDataset

__all__ = ["FeatureLoader", "LoadStats"]

_BF16 = jnp.bfloat16  # numpy-compatible via ml_dtypes under the hood


@dataclasses.dataclass
class LoadStats:
    rows: int = 0
    bytes: int = 0
    seconds: float = 0.0

    def merge(self, other: "LoadStats") -> None:
        self.rows += other.rows
        self.bytes += other.bytes
        self.seconds += other.seconds


class FeatureLoader:
    def __init__(self, dataset: GraphDataset, transfer_dtype: str = "float32",
                 num_threads: int = 1):
        self.dataset = dataset
        self.transfer_dtype = transfer_dtype
        self.num_threads = max(1, int(num_threads))  # DRM's balance_thread knob
        self.stats = LoadStats()

    def _gather(self, rows: np.ndarray) -> np.ndarray:
        if self.num_threads == 1:
            return self.dataset.take_features(rows)
        # chunked gather: with >1 OS threads numpy gathers overlap page faults
        import concurrent.futures as cf
        chunks = np.array_split(rows, self.num_threads)
        with cf.ThreadPoolExecutor(self.num_threads) as pool:
            parts = list(pool.map(self.dataset.take_features, chunks))
        return np.concatenate(parts, axis=0)

    def load(self, batch: MiniBatch) -> np.ndarray:
        """Gather features for the innermost frontier (layer-0 inputs)."""
        t0 = time.perf_counter()
        frontier = np.asarray(batch.frontier(len(batch.fanouts)))
        x = self._gather(frontier)
        if self.transfer_dtype == "bfloat16":
            x = x.astype(_BF16)
        dt = time.perf_counter() - t0
        self.stats.merge(LoadStats(rows=x.shape[0], bytes=x.nbytes, seconds=dt))
        return x
