"""Background storage-I/O prefetch (async partition-window pre-faulting).

HyScale-GNN's two-stage prefetch (paper §IV-B) overlaps the Feature
Loader and Data Transfer with accelerator compute, but on the disk tier
the load stage itself still blocks on cold mmap page faults.  The TFP
pipeline *knows* batch i+1's frontier (its sample stage runs while batch
i loads — paper Fig. 7), so a DistDGL-style background I/O thread can
pre-fault the windows batch i+1 will touch while batch i's gather runs:
by the time the load stage reaches batch i+1, its pages are warm and the
gather never waits on the storage device.

``WindowPrefetcher`` is that thread.  It wraps any FeatureSource
exposing ``prefetch_rows`` (the out-of-core ``MmapFeatures``) and:

  * ``submit(rows)`` — enqueue one future gather's row ids.  Non-blocking
    and lossy by design: a full queue drops the request (``dropped``
    counter) rather than ever stalling the sample stage — prefetch is
    advisory, the consumer's gather is always correct without it.
  * cross-batch dedup (``dedup_history > 0``): consecutive frontiers
    overlap heavily (hub nodes recur in nearly every batch), so the
    prefetcher remembers the ids of the last few submits and strips
    already-warm rows from each new one before it reaches the worker —
    the background read volume drops by the cross-batch duplication
    factor.  ``resubmitted_rows_skipped`` counts the stripped rows.  The
    memory is advisory like everything else here: any LRU eviction on
    the source invalidates the warm assumption, so the history clears
    whenever ``source.window_evictions`` moves.
  * the worker thread drains the queue calling
    ``source.prefetch_rows`` (a readahead gather of exactly the rows a
    future ``take`` will touch).
  * ``close()`` is idempotent and safe with a half-drained queue: the
    stop flag makes the worker skip remaining work, a sentinel ends it,
    and a second ``close()`` returns immediately.

Failure model & degraded modes
------------------------------

Two failure classes, handled differently:

  * a prefetch *item* fails (``source.prefetch_rows`` raised — e.g. a
    spill blob deleted mid-run, past the storage tier's own retries):
    the error is latched in ``error`` (appended to ``errors``), the
    worker keeps draining so a blocked producer / ``close()`` never
    deadlocks, and supervision decides what happens next;
  * the worker *thread* dies (``WorkerKilled`` from fault injection, or
    any raise escaping the item handler): detected by ``submit`` via the
    dead thread.

Supervision runs inline at each ``submit`` (``_supervise``): a failed or
dead worker is restarted with exponential backoff up to
``restart_budget`` times (``restarts`` counter).  Past the budget the
prefetcher goes permanently ``failed``: with the legacy strict contract
(``raise_on_failure=True``, the class default) the next ``submit``
raises with the first error chained; under a supervising trainer
(``raise_on_failure=False``) ``submit`` just returns False forever — the
trainer degrades to synchronous loads and re-prices ``prefetch_overlap``
to 0, surfacing the state through ``health()``/``healthy`` instead of an
exception.  The default ``restart_budget=0`` keeps PR-5 semantics
exactly: first failure latches, next submit raises.

``wait_idle`` exists for tests/benchmarks that need the asynchronous
pre-fault to have *happened* before measuring (the trainer never calls
it — overlapping is the whole point).  Its predicate also releases on a
dead worker, so an injected kill cannot wedge a waiting test.
"""
from __future__ import annotations

import collections
import queue
import threading
import time
from typing import List, Optional

import numpy as np

from repro.analysis.annotations import guarded_by

__all__ = ["WindowPrefetcher"]

_SENTINEL = object()


# Deliberately UNGUARDED shared state (not declared below, so the lint
# does not police it):
#   * error / errors / failed / restarts — the failure latch: written by
#     the worker, read by the single-producer supervisor.  A torn read is
#     impossible (reference assignment) and the supervisor re-checks
#     under its own control flow; taking _cv in the hot submit path for
#     an advisory latch is not worth it.
#   * _history / _evictions_seen / resubmitted_rows_skipped / dropped /
#     max_queue — producer-side only: submit() is single-producer by
#     contract, and resize() runs on the same (training) thread at
#     iteration boundaries.
@guarded_by("_cv", "_pending", "completed", "submitted")
class WindowPrefetcher:
    """Background thread pre-faulting partition windows for future gathers."""

    def __init__(self, source, max_queue: int = 4,
                 dedup_history: int = 0,
                 name: str = "window-prefetch",
                 restart_budget: int = 0,
                 restart_backoff: float = 0.02,
                 raise_on_failure: bool = True,
                 fault_injector=None):
        if not hasattr(source, "prefetch_rows"):
            raise TypeError(
                f"{type(source).__name__} has no prefetch_rows: the window "
                "prefetcher only serves page-faulting (mmap) sources")
        self.source = source
        self._name = name
        self.max_queue = max(1, int(max_queue))
        self._q: "queue.Queue" = queue.Queue(maxsize=self.max_queue)
        self._cv = threading.Condition()
        self._pending = 0              # submitted but not yet processed
        self._stop = threading.Event()
        self._closed = False
        self.fault_injector = fault_injector
        self.restart_budget = int(restart_budget)
        self.restart_backoff = float(restart_backoff)
        self.raise_on_failure = bool(raise_on_failure)
        self.error: Optional[BaseException] = None
        self.errors: List[BaseException] = []   # every failure, in order
        self.restarts = 0              # worker respawns performed
        self.failed = False            # permanently degraded (budget spent)
        self.submitted = 0
        self.completed = 0
        self.dropped = 0               # queue-full discards (by design)
        self.resubmitted_rows_skipped = 0   # cross-batch dedup strips
        # last N successfully-submitted id sets (producer-side only:
        # submit() is single-producer, so no lock is needed)
        self._history: "collections.deque" = collections.deque(
            maxlen=max(0, int(dedup_history)) or None)
        self._dedup = int(dedup_history) > 0
        self._evictions_seen = int(getattr(source, "window_evictions", 0))
        self._thread = self._spawn()

    def _spawn(self) -> threading.Thread:
        t = threading.Thread(target=self._run, daemon=True, name=self._name)
        t.start()
        return t

    # ------------------------------------------------------------- worker

    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is _SENTINEL:
                return
            # after a failure (or during close) keep draining without
            # working, so a blocked producer / close() never deadlocks
            if self.error is None and not self._stop.is_set():
                try:
                    if self.fault_injector is not None:
                        self.fault_injector.fire("prefetch.worker")
                    self.source.prefetch_rows(item)
                    with self._cv:
                        self.completed += 1
                except Exception as e:
                    # item failure: latch, keep the thread draining
                    self.errors.append(e)
                    self.error = e
                except BaseException as e:
                    # thread death (injected WorkerKilled): record it and
                    # END the thread — a per-item handler must not absorb
                    # it.  The pending count still drops so waiters
                    # release; supervision respawns within its budget.
                    self.errors.append(e)
                    self.error = e
                    with self._cv:
                        self._pending -= 1
                        self._cv.notify_all()
                    return
            with self._cv:
                self._pending -= 1
                self._cv.notify_all()

    # ------------------------------------------------------- supervision

    @property
    def healthy(self) -> bool:
        """True while the prefetcher can still serve submits (possibly
        after a restart); False once permanently failed or closed."""
        return not self.failed and not self._closed

    def _supervise(self) -> bool:
        """Inline supervisor, run at each submit: restart a failed/dead
        worker within ``restart_budget`` (exponential backoff between
        restarts), else mark the prefetcher permanently ``failed``.
        Returns True when the worker is (again) serviceable."""
        if self.failed:
            return False
        dead = not self._thread.is_alive() and not self._closed
        if self.error is None and not dead:
            return True
        if self.restarts >= self.restart_budget:
            self.failed = True
            return False
        # budgeted restart: back off, clear the latch, respawn if needed
        time.sleep(self.restart_backoff * (2.0 ** self.restarts))
        self.restarts += 1
        self.error = None
        if not self._thread.is_alive():
            # the dead worker abandoned whatever sat in the queue; any
            # such items were already un-counted from _pending only if
            # processed — drain leftovers so the new worker starts clean
            leftovers = 0
            while True:
                try:
                    item = self._q.get_nowait()
                except queue.Empty:
                    break
                if item is not _SENTINEL:
                    leftovers += 1
            if leftovers:
                with self._cv:
                    self._pending -= leftovers
                    self._cv.notify_all()
            self._thread = self._spawn()
        return True

    # ----------------------------------------------------------- producer

    def submit(self, rows: np.ndarray) -> bool:
        """Enqueue one future gather's rows for background pre-faulting.

        Returns True when enqueued, False when dropped (queue full,
        prefetcher closed, or permanently failed with
        ``raise_on_failure=False``).  With the strict contract
        (``raise_on_failure=True``) a prefetcher that failed past its
        restart budget raises — the advisory thread must not hide a
        broken storage tier from an unsupervised caller."""
        if not self._supervise():
            if self.raise_on_failure:
                raise RuntimeError(
                    "window prefetch worker failed; storage tier is broken"
                ) from (self.errors[0] if self.errors else self.error)
            return False
        if self._closed:
            return False
        rows = np.asarray(rows)
        work = rows
        if self._dedup:
            # an eviction on the source means some remembered window is
            # cold again — the whole memory is suspect, drop it
            ev = int(getattr(self.source, "window_evictions", 0))
            if ev != self._evictions_seen:
                self._history.clear()
                self._evictions_seen = ev
            if self._history:
                warm = np.concatenate(list(self._history))
                work = rows[~np.isin(rows, warm)]
                # the worker may have evicted a window while the strip was
                # computed (prefetch_rows -> source LRU runs concurrently);
                # a moved eviction counter means the warm assumption behind
                # the strip is stale, so fall back to the full row set
                # rather than enqueue a prefetch that skips cold rows
                ev = int(getattr(self.source, "window_evictions", 0))
                if ev != self._evictions_seen:
                    self._history.clear()
                    self._evictions_seen = ev
                    work = rows
                else:
                    self.resubmitted_rows_skipped += rows.size - work.size
            if work.size == 0:
                # everything is already warm: the submit succeeded without
                # touching the worker; refresh the rows' recency
                self._history.append(rows)
                with self._cv:
                    self.submitted += 1
                return True
        with self._cv:
            try:
                self._q.put_nowait(work)
            except queue.Full:
                self.dropped += 1
                return False
            self._pending += 1
            self.submitted += 1
        if self._dedup:
            # remember the ORIGINAL ids (stripped rows are warm via an
            # earlier entry, and this entry must keep them warm once that
            # one ages out) — and only on enqueue: a dropped submit
            # prefetches nothing, so it must not poison the memory
            self._history.append(rows)
        return True

    def resize(self, max_queue: int) -> None:
        """Change the queue depth in place (DRM knob auto-tuning).
        Queued work is never discarded: shrinking only makes the queue
        stop accepting new submits (drops, by the advisory contract)
        until it drains below the new bound.  queue.Queue re-reads
        ``maxsize`` under its own mutex on every put, so swapping it
        there is exactly the synchronization the queue itself uses."""
        depth = max(1, int(max_queue))
        with self._q.mutex:
            self._q.maxsize = depth
            self._q.not_full.notify_all()
        self.max_queue = depth

    def wait_idle(self, timeout: Optional[float] = None) -> bool:
        """Block until every submitted request was processed (or failed,
        or the worker died).  Test/benchmark hook — the training path
        never waits."""
        with self._cv:
            # the predicate lambda runs with _cv re-acquired by wait_for
            return self._cv.wait_for(
                lambda: (self._pending == 0  # noqa: RPR101 - locked by wait_for
                         or self.error is not None
                         or not self._thread.is_alive()),
                timeout)

    def close(self) -> None:
        """Stop the worker (idempotent; safe under a half-drained queue:
        remaining requests are drained unprocessed, never worked; safe
        after an injected worker death: no sentinel is forced into a
        possibly-full queue nobody drains)."""
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        if self._thread.is_alive():
            try:
                self._q.put_nowait(_SENTINEL)
            except queue.Full:
                # full queue with a live worker: it is mid-drain, a
                # blocking put resolves as soon as it takes the next item
                self._q.put(_SENTINEL)
            self._thread.join(timeout=30.0)

    def __del__(self):  # pragma: no cover - GC timing dependent
        try:
            self.close()
        except Exception:
            pass
