"""Background storage-I/O prefetch (async partition-window pre-faulting).

HyScale-GNN's two-stage prefetch (paper §IV-B) overlaps the Feature
Loader and Data Transfer with accelerator compute, but on the disk tier
the load stage itself still blocks on cold mmap page faults.  The TFP
pipeline *knows* batch i+1's frontier (its sample stage runs while batch
i loads — paper Fig. 7), so a DistDGL-style background I/O thread can
pre-fault the windows batch i+1 will touch while batch i's gather runs:
by the time the load stage reaches batch i+1, its pages are warm and the
gather never waits on the storage device.

``WindowPrefetcher`` is that thread.  It wraps any FeatureSource
exposing ``prefetch_rows`` (the out-of-core ``MmapFeatures``) and:

  * ``submit(rows)`` — enqueue one future gather's row ids.  Non-blocking
    and lossy by design: a full queue drops the request (``dropped``
    counter) rather than ever stalling the sample stage — prefetch is
    advisory, the consumer's gather is always correct without it.
  * cross-batch dedup (``dedup_history > 0``): consecutive frontiers
    overlap heavily (hub nodes recur in nearly every batch), so the
    prefetcher remembers the ids of the last few submits and strips
    already-warm rows from each new one before it reaches the worker —
    the background read volume drops by the cross-batch duplication
    factor.  ``resubmitted_rows_skipped`` counts the stripped rows.  The
    memory is advisory like everything else here: any LRU eviction on
    the source invalidates the warm assumption, so the history clears
    whenever ``source.window_evictions`` moves.
  * the worker thread drains the queue calling
    ``source.prefetch_rows`` (a readahead gather of exactly the rows a
    future ``take`` will touch).
  * errors are latched, never swallowed: a failing prefetch (e.g. a
    spill blob deleted mid-run) marks the prefetcher failed, the worker
    keeps draining (so ``close()`` can never deadlock on a full queue),
    and the *next* ``submit`` raises with the original exception chained
    — inside the TFP pipeline that surfaces through the stage-failure
    protocol on the current ``run()`` without wedging the feeder.
  * ``close()`` is idempotent and safe with a half-drained queue: the
    stop flag makes the worker skip remaining work, a sentinel ends it,
    and a second ``close()`` returns immediately.

``wait_idle`` exists for tests/benchmarks that need the asynchronous
pre-fault to have *happened* before measuring (the trainer never calls
it — overlapping is the whole point).
"""
from __future__ import annotations

import collections
import queue
import threading
from typing import Optional

import numpy as np

__all__ = ["WindowPrefetcher"]

_SENTINEL = object()


class WindowPrefetcher:
    """Background thread pre-faulting partition windows for future gathers."""

    def __init__(self, source, max_queue: int = 4,
                 dedup_history: int = 0,
                 name: str = "window-prefetch"):
        if not hasattr(source, "prefetch_rows"):
            raise TypeError(
                f"{type(source).__name__} has no prefetch_rows: the window "
                "prefetcher only serves page-faulting (mmap) sources")
        self.source = source
        self._q: "queue.Queue" = queue.Queue(maxsize=max(1, int(max_queue)))
        self._cv = threading.Condition()
        self._pending = 0              # submitted but not yet processed
        self._stop = threading.Event()
        self._closed = False
        self.error: Optional[BaseException] = None
        self.submitted = 0
        self.completed = 0
        self.dropped = 0               # queue-full discards (by design)
        self.resubmitted_rows_skipped = 0   # cross-batch dedup strips
        # last N successfully-submitted id sets (producer-side only:
        # submit() is single-producer, so no lock is needed)
        self._history: "collections.deque" = collections.deque(
            maxlen=max(0, int(dedup_history)) or None)
        self._dedup = int(dedup_history) > 0
        self._evictions_seen = int(getattr(source, "window_evictions", 0))
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=name)
        self._thread.start()

    # ------------------------------------------------------------- worker

    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is _SENTINEL:
                return
            # after a failure (or during close) keep draining without
            # working, so a blocked producer / close() never deadlocks
            if self.error is None and not self._stop.is_set():
                try:
                    self.source.prefetch_rows(item)
                    self.completed += 1
                except BaseException as e:
                    self.error = e
            with self._cv:
                self._pending -= 1
                self._cv.notify_all()

    # ----------------------------------------------------------- producer

    def submit(self, rows: np.ndarray) -> bool:
        """Enqueue one future gather's rows for background pre-faulting.

        Returns True when enqueued, False when dropped (queue full or
        prefetcher closed).  Raises if a previous prefetch failed — the
        advisory thread must not hide a broken storage tier."""
        if self.error is not None:
            raise RuntimeError(
                "window prefetch worker failed; storage tier is broken"
            ) from self.error
        if self._closed:
            return False
        rows = np.asarray(rows)
        work = rows
        if self._dedup:
            # an eviction on the source means some remembered window is
            # cold again — the whole memory is suspect, drop it
            ev = int(getattr(self.source, "window_evictions", 0))
            if ev != self._evictions_seen:
                self._history.clear()
                self._evictions_seen = ev
            if self._history:
                warm = np.concatenate(list(self._history))
                work = rows[~np.isin(rows, warm)]
                self.resubmitted_rows_skipped += rows.size - work.size
            if work.size == 0:
                # everything is already warm: the submit succeeded without
                # touching the worker; refresh the rows' recency
                self._history.append(rows)
                self.submitted += 1
                return True
        with self._cv:
            try:
                self._q.put_nowait(work)
            except queue.Full:
                self.dropped += 1
                return False
            self._pending += 1
            self.submitted += 1
        if self._dedup:
            # remember the ORIGINAL ids (stripped rows are warm via an
            # earlier entry, and this entry must keep them warm once that
            # one ages out) — and only on enqueue: a dropped submit
            # prefetches nothing, so it must not poison the memory
            self._history.append(rows)
        return True

    def wait_idle(self, timeout: Optional[float] = None) -> bool:
        """Block until every submitted request was processed (or failed).
        Test/benchmark hook — the training path never waits."""
        with self._cv:
            return self._cv.wait_for(
                lambda: self._pending == 0 or self.error is not None,
                timeout)

    def close(self) -> None:
        """Stop the worker (idempotent; safe under a half-drained queue:
        remaining requests are drained unprocessed, never worked)."""
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        self._q.put(_SENTINEL)      # worker is alive until it sees this
        self._thread.join(timeout=30.0)

    def __del__(self):  # pragma: no cover - GC timing dependent
        try:
            self.close()
        except Exception:
            pass
