"""Per-cell (arch × shape) step functions, abstract arguments and sharding
specs for the dry-run and roofline harnesses.

Nothing here touches real device memory: parameters, optimizer state and
decode caches are ``jax.eval_shape`` trees; data inputs are
``ShapeDtypeStruct`` stand-ins from ``configs.input_specs``.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ShapeSpec, input_specs
from repro.dist import (current_policy, params_shardings, pspec, use_mesh,
                        use_policy)
from repro.models import (ModelConfig, init_decode_cache, init_params,
                          make_prefill_step, make_serve_step,
                          make_train_step)
from repro.optim import adamw

__all__ = ["build_cell", "Cell"]


def _batch_shardable(global_batch: int, mesh: Mesh) -> bool:
    dp = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            dp *= mesh.shape[ax]
    return global_batch % dp == 0


def _model_size(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


def _batch_pspecs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                  batch_tree) -> Dict[str, P]:
    bshard = _batch_shardable(shape.global_batch, mesh)
    bdim = ("pod", "data") if bshard else None
    with use_mesh(mesh):
        out = {}
        for k, v in batch_tree.items():
            dims = [bdim] + [None] * (v.ndim - 1)
            out[k] = pspec(*dims)
        return out


def _cache_pspec(path, leaf, cfg: ModelConfig, shape: ShapeSpec,
                 mesh: Mesh) -> P:
    """Sharding rule for decode-cache leaves (see DESIGN.md §5)."""
    keys = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
    name = keys[-1] if keys else ""
    bshard = _batch_shardable(shape.global_batch, mesh)
    msize = _model_size(mesh)
    nd = leaf.ndim
    b_ax = ("pod", "data") if bshard else None
    seq_ax = None if bshard else ("pod", "data")

    def spec(*tail):
        lead = nd - len(tail)
        return pspec(*([None] * lead), *tail)

    if name in ("k", "v"):            # [..., B, C, Hkv, hd]
        h_ax = "model" if leaf.shape[-2] % msize == 0 else None
        c_ax = None
        if not bshard and leaf.shape[-3] % 32 == 0:
            c_ax = seq_ax            # long_500k: batch=1, split the stream
        elif h_ax is None and leaf.shape[-3] % msize == 0:
            # kv heads don't divide |model|: split the cache LENGTH over
            # the model axis instead (flash-decoding-style split-KV) —
            # without this, 32k-token caches replicate 16x and blow HBM.
            c_ax = "model"
        if current_policy() == "serve2d":
            # batch keeps only 'pod'; the freed 'data' axis splits the
            # cache length together with 'model' (256-way split-KV)
            c_ax = (("data", c_ax) if isinstance(c_ax, str)
                    else ("data",) if c_ax is None else c_ax)
        return spec(b_ax, c_ax, h_ax, None)
    if name == "conv":                # [..., B, conv_dim, K]
        c_ax = "model" if leaf.shape[-2] % msize == 0 else None
        return spec(b_ax, c_ax, None)
    if name == "h":                   # [..., B, H, P, N]
        h_ax = "model" if leaf.shape[-3] % msize == 0 else None
        return spec(b_ax, h_ax, None, None)
    if name == "s":                   # [..., B, H, K, V]
        h_ax = "model" if leaf.shape[-3] % msize == 0 else None
        return spec(b_ax, h_ax, None, None)
    if name in ("tm_x", "cm_x"):      # [..., B, d]
        d_ax = "model" if leaf.shape[-1] % msize == 0 else None
        return spec(b_ax, d_ax)
    return pspec(*([None] * nd))      # slot_pos, pos: replicated


def _prefill_out_pspec(path, leaf, cfg, shape, mesh) -> P:
    keys = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
    bshard = _batch_shardable(shape.global_batch, mesh)
    b_ax = ("pod", "data") if bshard else None
    msize = _model_size(mesh)
    nd = leaf.ndim
    if "attn_kv" in keys and nd >= 4:   # [L?, B, S, Hkv, hd]
        h_ax = "model" if leaf.shape[-2] % msize == 0 else None
        # split-KV: when kv heads don't divide |model|, shard the sequence
        # dim instead — otherwise 32k prefill caches replicate 16x
        s_ax = ("model" if h_ax is None and leaf.shape[-3] % msize == 0
                else None)
        lead = nd - 4
        return pspec(*([None] * lead), b_ax, s_ax, h_ax, None)
    return pspec(*([None] * nd))


class Cell:
    """A lowered-compile-ready (arch × shape × mesh) cell."""

    def __init__(self, cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                 microbatches: int = 1, policy: str = "tp2d"):
        self.cfg, self.shape, self.mesh = cfg, shape, mesh
        self.microbatches = microbatches
        self.policy = policy
        key = jax.random.PRNGKey(0)
        self.batch = input_specs(cfg, shape)
        with use_mesh(mesh), use_policy(policy):
            params_shape = jax.eval_shape(
                functools.partial(init_params, cfg=cfg), key)
            self.p_shard = params_shardings(params_shape, mesh)
            bspec = _batch_pspecs(cfg, shape, mesh, self.batch)
            self.b_shard = {k: NamedSharding(mesh, s)
                            for k, s in bspec.items()}

            if shape.step == "train":
                opt = adamw(3e-4)
                opt_shape = jax.eval_shape(opt.init, params_shape)
                self.o_shard = params_shardings(opt_shape, mesh)
                # scalar 'step' leaf: replicated
                self.o_shard = jax.tree.map(
                    lambda s, l: (NamedSharding(mesh, P())
                                  if l.ndim == 0 else s),
                    self.o_shard, opt_shape)
                self.fn = make_train_step(cfg, opt,
                                          microbatches=microbatches)
                self.args = (params_shape, opt_shape, self.batch)
                self.in_shardings = (self.p_shard, self.o_shard, self.b_shard)
                self.out_shardings = (self.p_shard, self.o_shard, None)
                self.donate = (0, 1)
            elif shape.step == "prefill":
                self.fn = make_prefill_step(cfg)
                self.args = (params_shape, self.batch)
                self.in_shardings = (self.p_shard, self.b_shard)
                out_shape = jax.eval_shape(self.fn, params_shape, self.batch)
                logits_spec = NamedSharding(mesh, pspec(
                    ("pod", "data") if _batch_shardable(shape.global_batch,
                                                        mesh) else None,
                    None, "model"))
                cache_spec = (jax.tree_util.tree_map_with_path(
                    lambda p, l: NamedSharding(mesh, _prefill_out_pspec(
                        p, l, cfg, shape, mesh)), out_shape[1])
                    if out_shape[1] is not None else None)
                self.out_shardings = (logits_spec, cache_spec)
                self.donate = ()
            else:  # decode
                cache_shape = jax.eval_shape(
                    lambda: init_decode_cache(cfg, shape.global_batch,
                                              shape.seq_len))
                self.c_shard = jax.tree_util.tree_map_with_path(
                    lambda p, l: NamedSharding(mesh, _cache_pspec(
                        p, l, cfg, shape, mesh)), cache_shape)
                self.fn = make_serve_step(cfg)
                self.args = (params_shape, cache_shape, self.batch)
                logits_spec = NamedSharding(mesh, pspec(
                    ("pod", "data") if _batch_shardable(shape.global_batch,
                                                        mesh) else None,
                    None, "model"))
                self.in_shardings = (self.p_shard, self.c_shard, self.b_shard)
                self.out_shardings = (logits_spec, self.c_shard)
                self.donate = (1,)

    def lower(self):
        with use_mesh(self.mesh), use_policy(self.policy):
            jitted = jax.jit(self.fn, in_shardings=self.in_shardings,
                             out_shardings=self.out_shardings,
                             donate_argnums=self.donate)
            return jitted.lower(*self.args)


def build_cell(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
               microbatches: int = 1, policy: str = "tp2d") -> Cell:
    return Cell(cfg, shape, mesh, microbatches=microbatches, policy=policy)


def microbatch_ladder(shape: ShapeSpec, mesh: Mesh):
    """Valid gradient-accumulation factors for a train cell: n must divide
    the global batch and keep the per-microbatch batch shardable."""
    if shape.step != "train":
        return [1]
    dp = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            dp *= mesh.shape[ax]
    out = []
    for n in (1, 2, 4, 8, 16):
        b = shape.global_batch
        if b % n == 0 and (b // n) % dp == 0:
            out.append(n)
    return out or [1]
