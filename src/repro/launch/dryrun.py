import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count on first init).  The 512 placeholder host devices exist ONLY for the
# dry-run: they let jax.make_mesh build the production meshes so every
# (architecture × input-shape × mesh) combination can be lowered + compiled
# and its memory/cost/collective schedule extracted — without TPU hardware.

import argparse       # noqa: E402
import json           # noqa: E402
import time           # noqa: E402
import traceback      # noqa: E402

import jax            # noqa: E402

from repro.configs import ARCHS, SHAPES, cell_applicable, get_arch  # noqa: E402
from repro.launch.analysis import (Roofline, collective_bytes,       # noqa: E402
                                   model_flops_total)
from repro.launch.cellspecs import build_cell, microbatch_ladder     # noqa: E402
from repro.launch.costmodel import count_fn_cost                     # noqa: E402
from repro.launch.mesh import make_production_mesh                   # noqa: E402

_FIT_BYTES = 16 * 2**30   # v5e HBM


def resolve_policy(cfg, shape, n_chips: int) -> tuple[str, str]:
    """Design-time task mapping (the paper's performance-model idea applied
    to parallelism selection): returns (policy, attn_impl).

      * decode of dense/MoE archs -> 'serve2d' (weight-stationary
        partial-sum decoding; kills per-token FSDP weight gathers),
      * small archs (<= 2B active) whose global batch divides the chip
        count -> 'dp' (16-way TP only buys all-reduces at this scale) +
        the Pallas flash kernel for full-attention archs,
      * otherwise -> 'tp2d' (FSDP x TP x sequence-sharded activations).
    """
    from repro.models import active_param_count
    if shape.step == "decode" and cfg.kind in ("dense", "moe"):
        return "serve2d", cfg.attn_impl
    if (active_param_count(cfg) <= 2e9
            and shape.global_batch % n_chips == 0):
        attn = ("flash" if cfg.window == 0 and cfg.kind in ("dense", "moe")
                else cfg.attn_impl)
        return "dp", attn
    if cfg.kind == "moe" and cfg.moe_experts % 16 == 0:
        return "ep", cfg.attn_impl   # exact expert parallelism (llama4)
    return "tp2d", cfg.attn_impl


def run_cell(arch: str, shape_name: str, mesh, *, verbose: bool = True,
             save_hlo: str | None = None, microbatches: int | None = None,
             policy: str = "tp2d") -> dict:
    """Lower+compile one cell.  For train shapes that exceed 16 GB/device,
    walk the gradient-accumulation ladder until the cell fits."""
    import dataclasses
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    if policy == "auto":
        policy, attn = resolve_policy(cfg, shape, mesh.size)
        if attn != cfg.attn_impl:
            cfg = dataclasses.replace(cfg, attn_impl=attn)
    ok, reason = cell_applicable(cfg, shape)
    if not ok:
        if verbose:
            print(f"[skip] {arch} × {shape_name}: {reason}")
        return {"arch": arch, "shape": shape_name,
                "mesh": list(mesh.shape.values()), "chips": mesh.size,
                "status": "skipped", "reason": reason}
    ladder = ([microbatches] if microbatches
              else microbatch_ladder(shape, mesh))
    attempts = []
    result = {}
    for n_mb in ladder:
        result = _compile_cell(arch, cfg, shape, mesh, n_mb, policy,
                               verbose=verbose, save_hlo=save_hlo)
        attempts.append({"microbatches": n_mb,
                         "status": result["status"],
                         "bytes_per_device": result.get("bytes_per_device")})
        if result["status"] != "ok" or result["fits_16gb"]:
            break
    result["microbatch_ladder"] = attempts
    return result


def _compile_cell(arch, cfg, shape, mesh, n_mb, policy="tp2d", *,
                  verbose=True, save_hlo=None) -> dict:
    n_chips = mesh.size
    result = {"arch": arch, "shape": shape.name,
              "mesh": list(mesh.shape.values()), "chips": n_chips,
              "microbatches": n_mb, "policy": policy,
              "status": "skipped", "reason": ""}
    t0 = time.time()
    try:
        cell = build_cell(cfg, shape, mesh, microbatches=n_mb,
                          policy=policy)
        lowered = cell.lower()
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
        if save_hlo:
            with open(save_hlo, "w") as f:
                f.write(hlo)
        # analytic (trip-count-exact) FLOPs/bytes; XLA's cost_analysis
        # counts while bodies once, so it is kept only as a reference.
        analytic = count_fn_cost(cell.fn, *cell.args)
        coll = collective_bytes(hlo)
        roof = Roofline(flops=analytic.flops / n_chips,
                        hbm_bytes=analytic.bytes / n_chips,
                        coll_bytes=float(coll["total"]),
                        model_flops=model_flops_total(cfg, shape) / n_chips)
        mem_dict = {}
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                mem_dict[attr] = int(v)
        # per-device steady-state bytes: args (params+opt+cache) + temps
        live = (mem_dict.get("argument_size_in_bytes", 0)
                + mem_dict.get("temp_size_in_bytes", 0)
                + mem_dict.get("output_size_in_bytes", 0)
                - mem_dict.get("alias_size_in_bytes", 0))
        result.update({
            "status": "ok",
            "t_lower_s": round(t_lower, 2), "t_compile_s": round(t_compile, 2),
            "memory_analysis": mem_dict,
            "bytes_per_device": int(live),
            "fits_16gb": bool(live < _FIT_BYTES),
            "cost_analysis_raw": {k: float(v) for k, v in cost.items()
                                  if isinstance(v, (int, float))
                                  and k in ("flops", "bytes accessed",
                                            "transcendentals")},
            "collectives": {k: int(v) for k, v in coll.items()},
            "roofline": roof.as_dict(),
        })
        if verbose:
            print(f"[ok]   {arch} × {shape.name} × {tuple(mesh.shape.values())} "
                  f"mb={n_mb} lower={t_lower:.1f}s compile={t_compile:.1f}s")
            print(f"       memory_analysis: {mem_dict} "
                  f"-> {live/2**30:.2f} GiB/device (fits 16GB: {live < _FIT_BYTES})")
            print(f"       cost_analysis: flops={roof.flops:.3e} "
                  f"bytes={roof.hbm_bytes:.3e} coll_bytes={roof.coll_bytes:.3e}")
            print(f"       roofline: compute={roof.t_compute*1e3:.2f}ms "
                  f"memory={roof.t_memory*1e3:.2f}ms "
                  f"collective={roof.t_collective*1e3:.2f}ms "
                  f"bottleneck={roof.bottleneck} "
                  f"useful={roof.useful_ratio:.2f} "
                  f"roofline_frac={roof.roofline_fraction:.3f}")
    except Exception as e:  # a failing cell is a bug in the system
        result.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]})
        if verbose:
            print(f"[FAIL] {arch} × {shape.name}: {type(e).__name__}: {e}")
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all' (see repro.configs.ARCHS)")
    ap.add_argument("--shape", default="all",
                    help="shape id or 'all' (train_4k/prefill_32k/...)")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--policy", default="tp2d",
                    choices=["tp2d", "dp", "serve2d", "auto"])
    ap.add_argument("--out", default=None, help="write results JSON here")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    for multi_pod in meshes:
        mesh = make_production_mesh(multi_pod=multi_pod)
        for arch in archs:
            for shape in shapes:
                results.append(run_cell(arch, shape, mesh,
                                        verbose=not args.quiet,
                                        policy=args.policy))
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\ndry-run summary: {n_ok} ok, {n_skip} skipped, {n_err} failed "
          f"of {len(results)} cells")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"results -> {args.out}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
