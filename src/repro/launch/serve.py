"""Batched serving driver: prefill a batch of prompts, then decode tokens
step by step against the per-layer cache.

``python -m repro.launch.serve --arch smollm-135m --reduced --batch 4
--prompt-len 32 --gen 16``
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import (init_decode_cache, init_params, make_serve_step)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch, reduced=args.reduced)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)
    step = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

    total = args.prompt_len + args.gen
    cache = init_decode_cache(cfg, args.batch, seq_len=total)
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len),
                           dtype=np.int32)

    # prefill by stepping the decode cache (prompt tokens are "forced")
    t0 = time.perf_counter()
    logits = None
    for t in range(args.prompt_len):
        logits, cache = step(params, cache,
                             {"tokens": jnp.asarray(prompts[:, t:t + 1])})
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    out_tokens = []
    t0 = time.perf_counter()
    tok = None
    for i in range(args.gen):
        key, sub = jax.random.split(key)
        lg = logits[:, -1, :cfg.vocab].astype(jnp.float32)
        if args.temperature > 0:
            tok = jax.random.categorical(sub, lg / args.temperature, axis=-1)
        else:
            tok = lg.argmax(-1)
        tok = tok[:, None].astype(jnp.int32)
        out_tokens.append(np.asarray(tok))
        logits, cache = step(params, cache, {"tokens": tok})
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0

    gen = np.concatenate(out_tokens, axis=1)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"prefill: {t_prefill*1e3:.1f} ms "
          f"({args.batch*args.prompt_len/t_prefill:.0f} tok/s)")
    print(f"decode:  {t_decode*1e3:.1f} ms "
          f"({args.batch*args.gen/t_decode:.0f} tok/s)")
    print("sampled token ids (first row):", gen[0].tolist())


if __name__ == "__main__":
    main()
