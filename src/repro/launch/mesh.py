"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run launcher must be able to set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before jax init.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: Optional[int] = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = jax.device_count()
    data = data if data is not None else max(1, n // model)
    return jax.make_mesh((data, model), ("data", "model"))
