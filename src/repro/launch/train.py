"""LM training driver: ``python -m repro.launch.train --arch smollm-135m
--reduced --steps 50``.

Integrates the paper's system pieces end-to-end on the LM substrate:
  * two-stage prefetching input pipeline (repro.data.TokenPipeline),
  * perf-model-style share quantization is not needed here (homogeneous
    devices) but the DRM-style straggler log is kept per step,
  * checkpoint/restart (elastic: restore re-shards onto the current mesh),
  * optional local mesh (data×model) when multiple devices exist.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_arch
from repro.data.tokens import TokenPipeline
from repro.dist import params_shardings, use_mesh
from repro.launch.mesh import make_local_mesh
from repro.models import init_params, make_train_step, param_count
from repro.optim import adamw, cosine_warmup_schedule


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced smoke config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--prefetch-depth", type=int, default=2,
                    help="TFP window; 0 disables the two-stage prefetch")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch, reduced=args.reduced)
    mesh = (make_local_mesh(model=args.model_parallel)
            if jax.device_count() > 1 else None)
    print(f"arch={cfg.name} devices={jax.device_count()} "
          f"mesh={None if mesh is None else dict(mesh.shape)}")

    with use_mesh(mesh):
        key = jax.random.PRNGKey(args.seed)
        params = init_params(key, cfg)
        if mesh is not None:
            params = jax.device_put(params, params_shardings(params, mesh))
        sched = cosine_warmup_schedule(args.lr, args.steps // 10 + 1,
                                       args.steps)
        opt = adamw(sched)
        opt_state = opt.init(params)
        print(f"params: {param_count(params)/1e6:.1f}M")

        step_fn = jax.jit(make_train_step(cfg, opt,
                                          microbatches=args.microbatches),
                          donate_argnums=(0, 1))

        start_step = 0
        mgr = None
        if args.ckpt_dir:
            mgr = CheckpointManager(args.ckpt_dir, keep=2)
            restored = mgr.restore_latest({"params": params,
                                           "opt": opt_state})
            if restored is not None:
                start_step, tree = restored
                params, opt_state = tree["params"], tree["opt"]
                print(f"restored checkpoint at step {start_step}")

        pipe = TokenPipeline(cfg, args.batch, args.seq, seed=args.seed,
                             depth=args.prefetch_depth)
        times = []
        t_prev = time.perf_counter()
        for step, batch in enumerate(pipe.batches(args.steps - start_step),
                                     start=start_step):
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            now = time.perf_counter()
            dt = now - t_prev
            t_prev = now
            times.append(dt)
            tok_s = args.batch * args.seq / dt
            if step % 5 == 0 or step == args.steps - 1:
                print(f"step {step:5d}  loss {loss:.4f}  "
                      f"{dt*1e3:7.1f} ms/step  {tok_s:9.0f} tok/s")
            if mgr and (step + 1) % args.ckpt_every == 0:
                mgr.save(step + 1, {"params": params, "opt": opt_state})
        if mgr:
            mgr.save(args.steps, {"params": params, "opt": opt_state})
            mgr.finalize()
        med = float(np.median(times[2:])) if len(times) > 3 else float("nan")
        print(f"done: median {med*1e3:.1f} ms/step, final loss {loss:.4f}")


if __name__ == "__main__":
    main()
