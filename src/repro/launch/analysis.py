"""Compiled-HLO analysis: roofline terms from the dry-run artifacts.

The compiled module on the 512-device host platform is a *per-device* SPMD
program, so ``cost_analysis()`` FLOPs/bytes and the collective operand bytes
parsed from the HLO text are per-chip quantities:

    compute  term = flops_per_chip / peak_flops_per_chip
    memory   term = bytes_per_chip / hbm_bw
    collective term = collective_operand_bytes_per_chip / link_bw

Hardware constants (TPU v5e, per prompt): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

__all__ = ["HW", "Roofline", "collective_bytes", "roofline_from_compiled",
           "model_flops_total"]

# TPU v5e per-chip constants
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
LINK_BW = 50e9             # bytes/s per ICI link
HW = {"peak_flops": PEAK_FLOPS, "hbm_bw": HBM_BW, "link_bw": LINK_BW}

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(?:\(?)(\w+)\[([\d,]*)\][^=]*?\b"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_WHILE_RE = re.compile(
    r"=.*?\bwhile\(.*?condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)"
    r"(?:.*?known_trip_count[\"':{ ]+n[\"': ]+(\d+))?")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")


def _shape_bytes(dtype: str, dims: str) -> int:
    nbytes = _DTYPE_BYTES.get(dtype)
    if nbytes is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * nbytes


def _split_computations(hlo_text: str) -> Dict[str, str]:
    """computation name -> body text (optimized-HLO text format)."""
    comps: Dict[str, list] = {}
    cur: Optional[str] = None
    entry_alias = None
    for line in hlo_text.splitlines():
        m = _COMP_HDR_RE.match(line.strip())
        if m and line.rstrip().endswith("{") and not line.startswith(" "):
            cur = m.group(1)
            comps[cur] = []
            if line.strip().startswith("ENTRY"):
                entry_alias = cur
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
                continue
            comps[cur].append(line)
    out = {name: "\n".join(body) for name, body in comps.items()}
    if entry_alias is not None:
        out["__entry__"] = out[entry_alias]
    return out


def _trip_count(cond_body: str) -> float:
    """Heuristic: scan-lowered conds compare the ind-var to a constant."""
    consts = [int(m.group(1)) for m in
              re.finditer(r"constant\((\d+)\)", cond_body)]
    return float(max(consts)) if consts else 1.0


def _direct_collective_bytes(body: str) -> Dict[str, int]:
    """Operand bytes of collectives appearing directly in one computation.

    Optimized HLO prints operands as bare names, so operand size is derived
    from the RESULT shape per collective semantics:
      all-reduce / all-to-all / collective-permute: operand == result;
      all-gather: operand = result / group_size;
      reduce-scatter: operand = result × group_size.
    """
    out = {k: 0 for k in _COLLECTIVES}
    for line in body.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        dtype, dims, kind, start = m.group(1), m.group(2), m.group(3), m.group(4)
        res = _shape_bytes(dtype, dims)
        gm = _GROUPS_RE.search(line)
        if gm:
            gsize = int(gm.group(2))
        else:
            ge = _GROUPS_EXPL_RE.search(line)
            gsize = len(ge.group(1).split(",")) if ge else 1
        if kind == "all-gather":
            res = res // max(gsize, 1)
        elif kind == "reduce-scatter":
            res = res * max(gsize, 1)
        if "_promoted" in line and dtype == "f32":
            # XLA's all-reduce-promotion pass wraps bf16 reductions in
            # f32 converts on this backend; TPUs all-reduce bf16 natively,
            # so the logical payload is half the printed f32 shape.
            res //= 2
        out[kind] += res
    return out


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Collective operand bytes with while-loop trip multiplication.

    Walks the computation graph: each computation's total = its direct
    collectives + Σ (trip_count × body total) for nested while ops +
    called-computation totals (calls/conditionals; fusions cannot contain
    collectives).
    """
    comps = _split_computations(hlo_text)
    memo: Dict[str, Dict[str, float]] = {}

    def total(name: str, stack=()) -> Dict[str, float]:
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return {k: 0.0 for k in _COLLECTIVES}
        body = comps[name]
        acc = {k: float(v) for k, v in _direct_collective_bytes(body).items()}
        for m in _WHILE_RE.finditer(body):
            cond, wbody, known = m.group(1), m.group(2), m.group(3)
            trips = (float(known) if known
                     else _trip_count(comps.get(cond, "")))
            sub = total(wbody, stack + (name,))
            for k in _COLLECTIVES:
                acc[k] += trips * sub[k]
        # non-while calls (conditional branches, custom calls with
        # to_apply) — rare in our programs; count once
        for cm in re.finditer(r"(?:call|conditional)\(.*?to_apply=%?([\w.\-]+)",
                              body):
            sub = total(cm.group(1), stack + (name,))
            for k in _COLLECTIVES:
                acc[k] += sub[k]
        memo[name] = acc
        return acc

    acc = total("__entry__")
    out = {k: int(v) for k, v in acc.items()}
    out["total"] = int(sum(acc.values()))
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-chip HLO flops
    hbm_bytes: float             # per-chip bytes accessed
    coll_bytes: float            # per-chip collective operand bytes
    model_flops: float           # 6·N_active·tokens / chips ("useful")

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful-compute time / bound time (1.0 = at the roofline)."""
        t_useful = self.model_flops / PEAK_FLOPS
        return t_useful / self.t_bound if self.t_bound else 0.0

    def as_dict(self) -> Dict[str, float]:
        return {
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "coll_bytes_per_chip": self.coll_bytes,
            "model_flops_per_chip": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def roofline_from_compiled(compiled, n_chips: int, model_flops_total: float,
                           hlo_text: Optional[str] = None) -> Roofline:
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)["total"]
    return Roofline(flops=flops, hbm_bytes=hbm, coll_bytes=float(coll),
                    model_flops=model_flops_total / n_chips)


def model_flops_total(cfg, shape) -> float:
    """6·N_active·D tokens convention for train; 2·N_active·D for
    inference steps (no backward)."""
    from repro.models import active_param_count
    n_active = active_param_count(cfg)
    if shape.step == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.step == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
