"""Analytic cost model — jaxpr-level FLOP / traffic counting.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified:
a 10-trip ``lax.scan`` of matmuls reports 1/10 of the true FLOPs), which
makes it useless for scan-over-layers programs.  This module walks the
jaxpr instead, multiplying nested ``scan`` bodies by their trip count, so
FLOPs are exact for the program as written (including remat recompute,
which appears as duplicated ops in the backward jaxpr).

Byte counting is a *post-fusion traffic model*: we count
  * dot_general operand + output bytes (matmul-boundary traffic),
  * scan carry + xs/ys bytes per trip (loop-boundary traffic),
  * top-level inputs/outputs once,
and assume elementwise chains fuse (their intermediates stay in
VMEM/registers).  This matches how a TPU executes the program far better
than either raw-jaxpr-sum (counts every temp) or XLA's loop-blind number.

Reported quantities are GLOBAL; divide by chip count for per-chip terms
(assumes balanced SPMD — see EXPERIMENTS.md §Roofline for the caveat on
unshardable head counts).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import numpy as np
from jax import core as jcore

__all__ = ["CostEstimate", "jaxpr_cost", "count_fn_cost"]


@dataclasses.dataclass
class CostEstimate:
    flops: float = 0.0
    bytes: float = 0.0

    def __iadd__(self, other: "CostEstimate"):
        self.flops += other.flops
        self.bytes += other.bytes
        return self

    def scaled(self, k: float) -> "CostEstimate":
        return CostEstimate(self.flops * k, self.bytes * k)


def _nbytes(aval) -> float:
    try:
        return float(np.prod(aval.shape) * aval.dtype.itemsize)
    except Exception:
        return 0.0


def _nelems(aval) -> float:
    try:
        return float(np.prod(aval.shape))
    except Exception:
        return 0.0


def _dot_cost(eqn) -> CostEstimate:
    dims = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dims
    lhs = eqn.invars[0].aval
    out = eqn.outvars[0].aval
    k = 1.0
    for d in lc:
        k *= lhs.shape[d]
    flops = 2.0 * _nelems(out) * k
    bytes_ = (_nbytes(eqn.invars[0].aval) + _nbytes(eqn.invars[1].aval)
              + _nbytes(out))
    return CostEstimate(flops, bytes_)


_CALL_PARAM_KEYS = ("jaxpr", "call_jaxpr", "fun_jaxpr")


def _sub_jaxprs(eqn):
    """(jaxpr, multiplier) pairs nested under an eqn."""
    name = eqn.primitive.name
    if name == "scan":
        yield eqn.params["jaxpr"], float(eqn.params["length"])
        return
    if name == "while":
        # not produced by our models (scan covers loops); assume 1 trip
        yield eqn.params["body_jaxpr"], 1.0
        yield eqn.params["cond_jaxpr"], 1.0
        return
    if name == "cond":
        for br in eqn.params["branches"]:
            yield br, 1.0  # upper bound: all branches counted
        return
    for key in _CALL_PARAM_KEYS:
        if key in eqn.params:
            yield eqn.params[key], 1.0
            return


def jaxpr_cost(jaxpr) -> CostEstimate:
    """Recursive cost of a (Closed)Jaxpr."""
    if hasattr(jaxpr, "jaxpr"):  # ClosedJaxpr
        jaxpr = jaxpr.jaxpr
    total = CostEstimate()
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            total += _dot_cost(eqn)
            continue
        if name == "pallas_call":
            # kernel IO is the HBM truth for Pallas ops; FLOPs for the
            # flash-attention kernel = 2 causal matmuls
            io = (sum(_nbytes(v.aval) for v in eqn.invars)
                  + sum(_nbytes(v.aval) for v in eqn.outvars))
            flops = 0.0
            if "flash" in str(eqn.params.get("name", "")):
                b_, s_, hkv_, g_, d_ = eqn.invars[0].aval.shape
                flops = 2 * 2 * b_ * hkv_ * g_ * s_ * s_ * d_ * 0.5
            total += CostEstimate(flops, io)
            continue
        subs = list(_sub_jaxprs(eqn))
        if subs:
            inner = CostEstimate()
            for sub, mult in subs:
                inner += jaxpr_cost(sub).scaled(mult)
            total += inner
            if name == "scan":
                # loop-boundary traffic: carries are written+read each trip.
                # xs/ys slices are NOT counted here — they are consumed /
                # produced by ops counted inside the body (dot operands),
                # and counting them again double-bills e.g. a decode KV
                # cache (once as scan xs, once as attention operand).
                n = float(eqn.params["length"])
                n_carry = eqn.params["num_carry"]
                n_const = eqn.params["num_consts"]
                carry_bytes = sum(_nbytes(v.aval)
                                  for v in eqn.invars[n_const:n_const + n_carry])
                total.bytes += 2.0 * n * carry_bytes
            continue
        # elementwise / reduction / gather etc: 1 flop per output element,
        # bytes assumed fused away
        total.flops += sum(_nelems(v.aval) for v in eqn.outvars)
    return total


def count_fn_cost(fn, *abstract_args) -> CostEstimate:
    """Cost of ``fn(*args)`` traced with ShapeDtypeStruct arguments."""
    closed = jax.make_jaxpr(fn)(*abstract_args)
    cost = jaxpr_cost(closed)
    io_bytes = (sum(_nbytes(v.aval) for v in closed.jaxpr.invars)
                + sum(_nbytes(v.aval) for v in closed.jaxpr.outvars))
    cost.bytes += io_bytes
    return cost
