"""Zero-cost concurrency annotations read by the ``repro.analysis`` linter.

The data plane (storage window LRU, cache refresh staging, prefetch worker,
pipeline stages) mutates shared state from several host threads.  Each lock
protects a *declared family of attributes*; the declaration lives on the
class as a decorator so the static analyzer (``repro.analysis``) can check,
purely syntactically, that every read/write of a guarded attribute happens
inside a ``with self.<lock>:`` block.

The decorators attach metadata and return the class/function **unchanged**
— no wrappers, no per-call overhead, importable from any module without
pulling in the analyzer itself.

Annotation pattern for a new threaded module
--------------------------------------------

::

    from repro.analysis.annotations import guarded_by, requires_lock

    @guarded_by("_lock", "pending", "completed", "errors")
    @guarded_by("_io_lock", "io_retries")        # one decorator per lock
    class ShardServer:
        def __init__(self):
            self._lock = threading.Lock()        # __init__ is exempt:
            self.pending = 0                     # the object is not yet
            self._io_lock = threading.Lock()     # visible to other threads
            self.io_retries = 0

        def submit(self, n):
            with self._lock:
                self.pending += n                # OK: under the right lock

        @requires_lock("_lock")
        def _drain_locked(self):
            # caller holds _lock (convention enforced at call sites)
            self.pending = 0                     # OK: declared held

        def peek(self):
            return self.pending                  # RPR101: read outside lock

What the analyzer enforces (see docs/static-analysis.md for the catalog):

* RPR101 / RPR104 — guarded attribute read / write outside the lock.
* RPR303 — ``+=`` on a guarded stats counter outside the lock (the
  accounting-symmetry rule: lost updates silently corrupt ``health()``).
* RPR102 — lock acquisition order inversions across declared locks.
* RPR103 — blocking calls (jax dispatch, ``.take()`` gathers, file I/O,
  sleeps) inside a ``with <lock>:`` body.

False positives are suppressed per line with a reason::

    self.version = v  # noqa: RPR1xx - benign: single writer (use the real
                      # three-digit rule id; placeholder shown here so this
                      # docstring is not itself parsed as a suppression)

Deliberately *undeclared* attributes (single-producer history deques,
last-writer-wins monitors) are simply left out of the ``guarded_by`` list;
the declaration is the opt-in.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple, TypeVar

__all__ = ["guarded_by", "requires_lock"]

_C = TypeVar("_C", bound=type)
_F = TypeVar("_F", bound=Callable[..., object])


def guarded_by(lock: str, *attrs: str) -> Callable[[_C], _C]:
    """Declare that ``lock`` (an attribute name, e.g. ``"_lock"``) protects
    the named instance attributes.  Stack one decorator per lock.

    The analyzer reads the declaration from the AST; at runtime this only
    records a ``__guarded_by__`` mapping on the class for introspection.
    """
    if not lock or not all(isinstance(a, str) and a for a in attrs):
        raise ValueError("guarded_by(lock, *attrs) takes non-empty strings")

    def deco(cls: _C) -> _C:
        merged: Dict[str, Tuple[str, ...]] = dict(
            getattr(cls, "__guarded_by__", {}))
        merged[lock] = tuple(dict.fromkeys(merged.get(lock, ()) + attrs))
        cls.__guarded_by__ = merged  # type: ignore[attr-defined]
        return cls

    return deco


def requires_lock(*locks: str) -> Callable[[_F], _F]:
    """Declare that every caller of this method already holds ``locks``.

    The analyzer treats the method body as if it were inside
    ``with self.<lock>:`` for each named lock; the docstring should say the
    same for human readers.  Runtime cost: one attribute set at class
    definition time, nothing per call.
    """
    if not locks or not all(isinstance(k, str) and k for k in locks):
        raise ValueError("requires_lock(*locks) takes non-empty strings")

    def deco(fn: _F) -> _F:
        fn.__requires_lock__ = tuple(locks)  # type: ignore[attr-defined]
        return fn

    return deco
