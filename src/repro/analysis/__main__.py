"""``python -m repro.analysis`` entry point."""
import sys

from .engine import main

sys.exit(main())
