"""Repo-specific static analyzer (the ``RPR`` rule set).

Run with ``python -m repro.analysis [paths...]`` or ``scripts/lint.sh``.
Rule catalog and suppression conventions: docs/static-analysis.md.

Families:

* ``RPR000`` — unused ``# noqa`` suppression (meta-rule).
* ``RPR1xx`` — lock discipline over ``guarded_by`` annotations
  (:mod:`repro.analysis.rules_locks`).
* ``RPR2xx`` — Pallas kernel invariants
  (:mod:`repro.analysis.rules_kernels`).
* ``RPR3xx`` — determinism & accounting
  (:mod:`repro.analysis.rules_determinism` + RPR303 in rules_locks).
"""
from .annotations import guarded_by, requires_lock
from .engine import Engine, Finding, Rule, default_rules, main, run_paths

__all__ = ["Engine", "Finding", "Rule", "default_rules", "main",
           "run_paths", "guarded_by", "requires_lock"]
