"""RPR3xx determinism & accounting rules.

* **RPR301** — bare ``np.random.*`` global-state call (``seed``,
  ``randint``, ``shuffle``, ...).  The repo's determinism contract is
  seeded ``np.random.default_rng``/``Generator`` instances everywhere:
  global-state draws make losses depend on import order and thread
  interleaving.
* **RPR302** — an ``except:`` / ``except BaseException:`` handler that
  can swallow ``WorkerKilled``.  The fault injector's kill faults derive
  from ``BaseException`` *on purpose* so that ordinary ``except
  Exception`` resilience code passes them through; a handler broad
  enough to catch them must either re-raise or record the bound
  exception (``except BaseException as e: ... e ...``) — silently
  dropping it turns an injected worker death into a hang.
* **RPR303** — counter accounting under the declared guard; emitted by
  the lock-discipline state machine (see ``rules_locks``), documented
  here with its family.

``except Exception`` is deliberately *not* flagged: it cannot catch
``WorkerKilled`` and is the recommended resilience idiom.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import List, Optional

from .engine import FileContext, Rule

__all__ = ["DeterminismRules"]

_ALLOWED_NP_RANDOM = {"default_rng", "Generator", "SeedSequence",
                      "PCG64", "Philox", "MT19937", "BitGenerator"}


def _is_broad_handler(h: ast.ExceptHandler) -> bool:
    t = h.type
    if t is None:
        return True
    if isinstance(t, ast.Name):
        return t.id == "BaseException"
    if isinstance(t, ast.Attribute):
        return t.attr == "BaseException"
    if isinstance(t, ast.Tuple):
        return any(_is_broad_handler(
            ast.ExceptHandler(type=e, name=None, body=[])) for e in t.elts)
    return False


@dataclasses.dataclass
class _Handler:
    node: ast.ExceptHandler
    bound: Optional[str]
    saved: bool = False


class DeterminismRules(Rule):
    types = (ast.Call, ast.ExceptHandler, ast.Raise, ast.Name)

    def __init__(self) -> None:
        self._handlers: List[_Handler] = []

    def begin_file(self, ctx: FileContext) -> None:
        self._handlers = []

    def visit(self, node: ast.AST, ctx: FileContext) -> None:
        if isinstance(node, ast.Call):
            self._check_np_random(node, ctx)
        elif isinstance(node, ast.ExceptHandler):
            if _is_broad_handler(node):
                self._handlers.append(_Handler(node, node.name))
        elif isinstance(node, ast.Raise):
            if self._handlers:
                self._handlers[-1].saved = True
        elif isinstance(node, ast.Name):
            for h in self._handlers:
                if h.bound is not None and node.id == h.bound:
                    h.saved = True

    def leave(self, node: ast.AST, ctx: FileContext) -> None:
        if isinstance(node, ast.ExceptHandler) and self._handlers \
                and self._handlers[-1].node is node:
            h = self._handlers.pop()
            if not h.saved:
                what = ("bare 'except:'" if node.type is None
                        else "'except BaseException'")
                ctx.report(
                    "RPR302", node,
                    f"{what} can swallow WorkerKilled (a BaseException "
                    f"by contract) without re-raising or recording it",
                    "narrow to 'except Exception', or bind the exception "
                    "and record/re-raise it")

    @staticmethod
    def _check_np_random(node: ast.Call, ctx: FileContext) -> None:
        f = node.func
        if not (isinstance(f, ast.Attribute)
                and isinstance(f.value, ast.Attribute)
                and f.value.attr == "random"
                and isinstance(f.value.value, ast.Name)
                and f.value.value.id in ("np", "numpy")):
            return
        if f.attr in _ALLOWED_NP_RANDOM:
            return
        ctx.report("RPR301", node,
                   f"global-state 'np.random.{f.attr}(...)' call "
                   f"(import-order / thread-interleaving dependent)",
                   "draw from a seeded np.random.default_rng(...) "
                   "Generator instead")
