"""Single-pass AST lint engine for the repo-specific ``RPR`` rule set.

Design: the engine parses each file once and walks the tree exactly once,
maintaining a node stack.  Rules subscribe to node *types*; for every node
the engine dispatches ``visit`` (pre-order) and ``leave`` (post-order) to
the subscribed rules.  Rules emit :class:`Finding` objects through the
shared :class:`FileContext`; cross-file rules additionally collect state
and emit from ``finish()`` after every file has been walked.

Suppression: a physical line may carry ``# noqa: RPR###[, RPR###...]``.
Findings on that line with a listed code are dropped and the suppression
is marked used; suppressions that match no finding are themselves reported
as ``RPR000`` (unused suppression), so stale noqas cannot accumulate.
``RPR000`` itself cannot be suppressed.  Blanket ``# noqa`` without codes
is not honored — list the codes.

``--changed`` support: :func:`run` accepts ``report_only`` so cross-file
rules still see the whole project while findings are reported only for the
changed subset.
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import re
import sys
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple, Type

__all__ = ["Finding", "Rule", "FileContext", "Engine", "main",
           "default_rules", "run_paths"]

_NOQA_RE = re.compile(r"#\s*noqa:\s*(RPR\d{3}(?:\s*,\s*RPR\d{3})*)",
                      re.IGNORECASE)


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One lint result: stable sort order is (path, line, rule)."""
    path: str
    line: int
    rule: str
    message: str
    hint: str = ""

    def render(self) -> str:
        out = f"{self.path}:{self.line}: {self.rule}: {self.message}"
        if self.hint:
            out += f"  [fix: {self.hint}]"
        return out


class FileContext:
    """Per-file state shared by every rule during the walk.

    ``node_stack`` holds the ancestry of the node currently being visited
    (the node itself is last); ``parent()`` gives the immediate parent.
    """

    def __init__(self, path: str, tree: ast.Module, source: str) -> None:
        self.path = path
        self.tree = tree
        self.source = source
        self.lines = source.splitlines()
        self.node_stack: List[ast.AST] = []
        self.findings: List[Finding] = []

    def parent(self, back: int = 1) -> Optional[ast.AST]:
        i = len(self.node_stack) - 1 - back
        return self.node_stack[i] if i >= 0 else None

    def report(self, rule: str, node: ast.AST, message: str,
               hint: str = "") -> None:
        line = int(getattr(node, "lineno", 1))
        self.findings.append(Finding(self.path, line, rule, message, hint))


class Rule:
    """Base class.  Subclasses set ``types`` (node classes to receive) and
    override ``visit``/``leave``; cross-file rules override ``finish``."""

    #: node types this rule wants ``visit``/``leave`` callbacks for
    types: Tuple[Type[ast.AST], ...] = ()

    def begin_file(self, ctx: FileContext) -> None:
        pass

    def visit(self, node: ast.AST, ctx: FileContext) -> None:
        pass

    def leave(self, node: ast.AST, ctx: FileContext) -> None:
        pass

    def end_file(self, ctx: FileContext) -> None:
        pass

    def finish(self) -> List[Finding]:
        """Cross-file findings, emitted after every file has been walked."""
        return []


class Engine:
    """Walks each file once, dispatching node events to subscribed rules."""

    def __init__(self, rules: Sequence[Rule]) -> None:
        self.rules = list(rules)
        self._by_type: Dict[Type[ast.AST], List[Rule]] = {}
        for rule in self.rules:
            for t in rule.types:
                self._by_type.setdefault(t, []).append(rule)
        #: per-file noqa maps kept for suppressing finish()-phase findings
        self._noqa: Dict[str, Dict[int, Set[str]]] = {}
        self.visited_nodes = 0  # instrumentation for the walker property test

    # ------------------------------------------------------------ per file

    @staticmethod
    def _collect_noqa(source: str) -> Dict[int, Set[str]]:
        out: Dict[int, Set[str]] = {}
        for i, line in enumerate(source.splitlines(), start=1):
            m = _NOQA_RE.search(line)
            if m:
                codes = {c.strip().upper()
                         for c in m.group(1).split(",") if c.strip()}
                out[i] = codes
        return out

    def check_file(self, path: str, source: Optional[str] = None,
                   raw: bool = False) -> List[Finding]:
        """Walk one file.  By default returns *suppression-filtered*
        findings plus RPR000 for unused suppressions; ``raw=True`` returns
        unfiltered findings (``run()`` applies suppression after the
        cross-file ``finish()`` phase instead)."""
        if source is None:
            source = Path(path).read_text()
        try:
            tree = ast.parse(source, filename=path)
        except SyntaxError as e:
            return [Finding(path, int(e.lineno or 1), "RPR999",
                            f"syntax error: {e.msg}")]
        noqa = self._collect_noqa(source)
        self._noqa[path] = noqa
        ctx = FileContext(path, tree, source)
        for rule in self.rules:
            rule.begin_file(ctx)
        self._walk(tree, ctx)
        for rule in self.rules:
            rule.end_file(ctx)
        if raw:
            return ctx.findings
        return self._apply_noqa(ctx.findings, noqa, path)

    def _walk(self, node: ast.AST, ctx: FileContext) -> None:
        self.visited_nodes += 1
        ctx.node_stack.append(node)
        for rule in self._by_type.get(type(node), ()):
            rule.visit(node, ctx)
        for child in ast.iter_child_nodes(node):
            self._walk(child, ctx)
        for rule in self._by_type.get(type(node), ()):
            rule.leave(node, ctx)
        ctx.node_stack.pop()

    # -------------------------------------------------------- suppression

    @staticmethod
    def _apply_noqa(findings: List[Finding], noqa: Dict[int, Set[str]],
                    path: str, used: Optional[Set[Tuple[int, str]]] = None,
                    emit_unused: bool = True) -> List[Finding]:
        used = set() if used is None else used
        kept: List[Finding] = []
        for f in findings:
            codes = noqa.get(f.line, set())
            if f.rule in codes and f.rule != "RPR000":
                used.add((f.line, f.rule))
            else:
                kept.append(f)
        if emit_unused:
            for line, codes in sorted(noqa.items()):
                for code in sorted(codes):
                    if code == "RPR000" or (line, code) not in used:
                        kept.append(Finding(
                            path, line, "RPR000",
                            f"unused suppression: no {code} finding on "
                            f"this line",
                            "delete the stale noqa (RPR000 itself cannot "
                            "be suppressed)" if code == "RPR000"
                            else "delete the stale noqa"))
        return kept

    # ------------------------------------------------------------ project

    def run(self, paths: Iterable[str],
            report_only: Optional[Set[str]] = None) -> List[Finding]:
        """Analyze ``paths``; report findings for every path unless
        ``report_only`` restricts the reported subset (cross-file rules
        still see everything)."""
        path_list = sorted(str(p) for p in paths)
        by_path: Dict[str, List[Finding]] = {}
        for p in path_list:
            by_path[p] = self.check_file(p, raw=True)
        for rule in self.rules:
            for f in rule.finish():
                by_path.setdefault(f.path, []).append(f)
        findings: List[Finding] = []
        for p, raw in by_path.items():
            if report_only is not None and p not in report_only:
                continue
            findings.extend(self._apply_noqa(raw, self._noqa.get(p, {}), p))
        return sorted(findings)


def default_rules() -> List[Rule]:
    from .rules_determinism import DeterminismRules
    from .rules_kernels import KernelInvariantRules
    from .rules_locks import LockDisciplineRules
    return [LockDisciplineRules(), KernelInvariantRules(),
            DeterminismRules()]


def iter_py_files(root: Path) -> List[str]:
    return sorted(str(p) for p in root.rglob("*.py"))


def run_paths(paths: Sequence[str],
              report_only: Optional[Set[str]] = None) -> List[Finding]:
    engine = Engine(default_rules())
    return engine.run(paths, report_only=report_only)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Repo-specific static analyzer (RPR rule set).")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to analyze (default: src)")
    ap.add_argument("--report-only", nargs="*", default=None, metavar="PATH",
                    help="analyze all PATHS for cross-file context but "
                         "report findings only for these files")
    args = ap.parse_args(argv)

    files: List[str] = []
    for p in args.paths:
        path = Path(p)
        if path.is_dir():
            files.extend(iter_py_files(path))
        else:
            files.append(str(path))
    report_only = (None if args.report_only is None
                   else {str(Path(p)) for p in args.report_only})
    findings = run_paths(files, report_only=report_only)
    for f in findings:
        print(f.render())
    n = len(findings)
    print(f"repro.analysis: {n} finding{'s' if n != 1 else ''} "
          f"in {len(files)} files")
    return 1 if findings else 0


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())
