"""RPR2xx Pallas kernel invariant rules.

Kernel bodies (functions named ``*_kernel`` or taking ``*_ref`` params, in
modules that import ``jax.experimental.pallas``) trace to device programs:
Python side effects inside them either silently bake trace-time state into
the compiled kernel or desync interpret mode from compiled mode.

* **RPR201** — side effect in a kernel body: ``global``/``nonlocal``,
  ``np.random.*``, ``time.*``, ``print``, ``open``.
* **RPR202** — a function issuing a ``pallas_call`` with
  ``input_output_aliases`` whose callers do not go through the keep-last
  dedupe contract.  Aliased-output scatters require unique target slots
  (concurrent per-row write DMAs have unspecified order on duplicates);
  the contract is that some caller within two hops either calls
  ``np.unique`` or documents "keep-last"/"last writer" in its docstring
  (``ops.update_cache_rows`` is the canonical wrapper).  Cross-file rule.
* **RPR203** — a DMA ``.start()`` whose semaphore never sees a
  ``.wait()`` anywhere in the same kernel.  Matching is by semaphore
  *root name* (``rd_sem`` in ``rd_sem.at[slot]``), including DMAs built
  by local helper functions that return ``make_async_copy(...)``; true
  per-control-path analysis is out of scope (Pallas control flow is
  ``pl.when``/``fori_loop``, where lexical containment is the only
  tractable approximation — documented in docs/static-analysis.md).
* **RPR204** — a call-wrapper taking a ``depth``/``pipeline_depth``
  parameter that issues a ``pallas_call`` without sizing its scratch via
  ``check_vmem_scratch`` (the 8 MiB VMEM budget guard).
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from .engine import FileContext, Finding, Rule

__all__ = ["KernelInvariantRules"]

_DEPTH_PARAMS = {"depth", "pipeline_depth"}
_DOC_MARKERS = ("keep-last", "last writer")


def _root_name(expr: ast.expr) -> Optional[str]:
    while isinstance(expr, (ast.Attribute, ast.Subscript, ast.Call)):
        expr = getattr(expr, "value", None) or getattr(expr, "func", None)
        if expr is None:
            return None
    return expr.id if isinstance(expr, ast.Name) else None


def _call_name(func: ast.expr) -> Optional[str]:
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return None


def _is_make_async_copy(func: ast.expr) -> bool:
    return _call_name(func) == "make_async_copy"


def _arg_names(node: ast.FunctionDef) -> List[str]:
    a = node.args
    return [x.arg for x in (a.posonlyargs + a.args + a.kwonlyargs)]


@dataclasses.dataclass
class _Func:
    node: ast.FunctionDef
    name: str
    is_kernel: bool
    depth_param: bool
    doc_marked: bool
    helpers: Dict[str, str] = dataclasses.field(default_factory=dict)
    started: Dict[str, int] = dataclasses.field(default_factory=dict)
    waited: Set[str] = dataclasses.field(default_factory=set)
    calls: Set[str] = dataclasses.field(default_factory=set)
    has_unique: bool = False
    has_alias_kw: bool = False
    has_pallas_call: bool = False
    has_scratch_check: bool = False
    dma_helper_sem: Optional[str] = None


@dataclasses.dataclass
class _FuncInfo:
    path: str
    line: int
    marked: bool
    calls: Set[str]
    aliasing: bool


class KernelInvariantRules(Rule):
    types = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Call,
             ast.Return, ast.Global, ast.Nonlocal)

    def __init__(self) -> None:
        self._stack: List[_Func] = []
        self._pallas_file = False
        # RPR202 cross-file call graph: bare name -> merged info
        self._funcs: Dict[str, _FuncInfo] = {}

    # ----------------------------------------------------------- lifecycle

    def begin_file(self, ctx: FileContext) -> None:
        self._stack = []
        self._pallas_file = any(
            ("pallas" in (getattr(n, "module", "") or "")) or
            any("pallas" in a.name for a in getattr(n, "names", []))
            for n in ctx.tree.body
            if isinstance(n, (ast.Import, ast.ImportFrom)))

    # ------------------------------------------------------------- events

    def visit(self, node: ast.AST, ctx: FileContext) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            args = _arg_names(node)
            is_kernel = self._pallas_file and (
                node.name.endswith("_kernel") or
                any(a.endswith("_ref") for a in args))
            doc = ast.get_docstring(node) or ""
            doc_norm = " ".join(doc.split()).lower()
            self._stack.append(_Func(
                node, node.name, is_kernel,
                depth_param=any(a in _DEPTH_PARAMS for a in args),
                doc_marked=any(m in doc_norm for m in _DOC_MARKERS)))
        elif isinstance(node, (ast.Global, ast.Nonlocal)):
            if self._kernel_ancestor() is not None:
                kw = "global" if isinstance(node, ast.Global) else "nonlocal"
                ctx.report("RPR201", node,
                           f"'{kw}' inside a Pallas kernel body "
                           f"(side effects bake trace-time state into the "
                           f"compiled kernel)",
                           "thread state through refs/closures instead")
        elif isinstance(node, ast.Return):
            self._on_return(node)
        elif isinstance(node, ast.Call):
            self._on_call(node, ctx)

    def leave(self, node: ast.AST, ctx: FileContext) -> None:
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return
        if not self._stack or self._stack[-1].node is not node:
            return
        rec = self._stack.pop()
        # a nested DMA-builder helper registers with its enclosing kernel
        if rec.dma_helper_sem is not None:
            k = self._kernel_ancestor()
            if k is not None:
                k.helpers[rec.name] = rec.dma_helper_sem
        if rec.is_kernel:
            for sem in sorted(set(rec.started) - rec.waited):
                ctx.report("RPR203", rec.node,
                           f"kernel '{rec.name}' starts DMA(s) on "
                           f"semaphore '{sem}' but never waits on it",
                           f"add a matching make_async_copy(..., {sem}"
                           f".at[...]).wait() before the slot is reused")
        if (self._pallas_file and rec.depth_param and rec.has_pallas_call
                and not rec.has_scratch_check):
            ctx.report("RPR204", rec.node,
                       f"'{rec.name}' takes a pipeline depth parameter "
                       f"and issues a pallas_call without sizing VMEM "
                       f"scratch via check_vmem_scratch",
                       "call check_vmem_scratch(depth * block_bytes, ...) "
                       "before the pallas_call")
        if not self._stack:  # module-level def: record for the call graph
            prev = self._funcs.get(rec.name)
            info = _FuncInfo(ctx.path, rec.node.lineno,
                             rec.has_unique or rec.doc_marked,
                             set(rec.calls), rec.has_alias_kw)
            if prev is not None:  # same bare name elsewhere: merge (rare)
                info.marked = info.marked or prev.marked
                info.calls |= prev.calls
                info.aliasing = info.aliasing or prev.aliasing
            self._funcs[rec.name] = info
        else:
            # nested defs contribute their calls to the enclosing function
            self._stack[0].calls |= rec.calls
            self._stack[0].has_unique |= rec.has_unique

    # ------------------------------------------------------------- helpers

    def _kernel_ancestor(self) -> Optional[_Func]:
        for rec in reversed(self._stack):
            if rec.is_kernel:
                return rec
        return None

    def _on_return(self, node: ast.Return) -> None:
        if not self._stack:
            return
        v = node.value
        if isinstance(v, ast.Call) and _is_make_async_copy(v.func) and v.args:
            sem = _root_name(v.args[-1])
            if sem is not None:
                self._stack[-1].dma_helper_sem = sem

    def _sem_of_dma_expr(self, call: ast.Call) -> Optional[str]:
        """Semaphore root for ``<X>.start()``/``.wait()`` receivers: X is
        either ``make_async_copy(...)`` directly or a call to a local
        helper that returns one."""
        if _is_make_async_copy(call.func) and call.args:
            return _root_name(call.args[-1])
        name = _call_name(call.func)
        if name is not None:
            k = self._kernel_ancestor()
            if k is not None and name in k.helpers:
                return k.helpers[name]
        return None

    def _on_call(self, node: ast.Call, ctx: FileContext) -> None:
        rec = self._stack[-1] if self._stack else None
        f = node.func
        name = _call_name(f)
        if rec is not None and name is not None:
            rec.calls.add(name)
        # DMA start/wait accounting, credited to the enclosing kernel
        if isinstance(f, ast.Attribute) and f.attr in ("start", "wait") \
                and isinstance(f.value, ast.Call):
            sem = self._sem_of_dma_expr(f.value)
            k = self._kernel_ancestor()
            if sem is not None and k is not None:
                if f.attr == "start":
                    k.started[sem] = k.started.get(sem, 0) + 1
                else:
                    k.waited.add(sem)
        # side effects inside kernel bodies
        k = self._kernel_ancestor()
        if k is not None:
            root = _root_name(f) if isinstance(f, ast.Attribute) else None
            if isinstance(f, ast.Name) and f.id in ("print", "open"):
                ctx.report("RPR201", node,
                           f"'{f.id}(...)' inside Pallas kernel body "
                           f"'{k.name}'",
                           "kernels must be side-effect-free")
            elif isinstance(f, ast.Attribute) and root in ("np", "numpy") \
                    and isinstance(f.value, ast.Attribute) \
                    and f.value.attr == "random":
                ctx.report("RPR201", node,
                           f"np.random call inside Pallas kernel body "
                           f"'{k.name}' (trace-time randomness bakes into "
                           f"the compiled program)",
                           "pass randomness in as an operand")
            elif isinstance(f, ast.Attribute) and root == "time":
                ctx.report("RPR201", node,
                           f"time.{f.attr}() inside Pallas kernel body "
                           f"'{k.name}'",
                           "kernels must be side-effect-free")
        if rec is not None:
            if name == "unique" and isinstance(f, ast.Attribute) \
                    and _root_name(f.value) in ("np", "numpy"):
                rec.has_unique = True
            if name == "pallas_call":
                rec.has_pallas_call = True
            if name == "check_vmem_scratch":
                rec.has_scratch_check = True
            if any(kw.arg == "input_output_aliases" for kw in node.keywords):
                rec.has_alias_kw = True

    # ------------------------------------------------------------- project

    def finish(self) -> List[Finding]:
        out: List[Finding] = []
        callers: Dict[str, Set[str]] = {}
        for fname, info in self._funcs.items():
            for callee in info.calls:
                if callee in self._funcs:
                    callers.setdefault(callee, set()).add(fname)

        def marked(n: str) -> bool:
            return self._funcs[n].marked

        for wname, winfo in sorted(self._funcs.items()):
            if not winfo.aliasing or marked(wname):
                continue
            for c in sorted(callers.get(wname, ())):
                if marked(c):
                    continue
                c2 = callers.get(c, set())
                if c2 and all(marked(x) for x in c2):
                    continue
                ci = self._funcs[c]
                out.append(Finding(
                    ci.path, ci.line, "RPR202",
                    f"'{c}' reaches aliased-output kernel wrapper "
                    f"'{wname}' (input_output_aliases) without the "
                    f"keep-last dedupe contract within two caller hops",
                    "route through ops.update_cache_rows or dedupe slots "
                    "keep-last (np.unique on the reversed slot list) "
                    "before the aliased scatter"))
        return out
