"""RPR1xx lock-discipline rules (plus RPR303 counter accounting).

Classes opt in with ``@guarded_by("<lock>", "<attr>", ...)`` from
``repro.analysis.annotations`` (read *syntactically* — the analyzer never
imports the code under analysis).  Within an annotated class:

* **RPR101** — read of a guarded attribute outside ``with self.<lock>:``.
* **RPR104** — write (assignment / del) of a guarded attribute outside
  the lock.
* **RPR303** — augmented assignment (``+=`` et al.) on a guarded stats
  counter outside the lock: the accounting-symmetry rule.  Split from
  RPR104 because lost counter updates corrupt ``health()`` silently
  rather than breaking correctness loudly.
* **RPR102** — lock acquisition order inversion: ``with self.A: with
  self.B:`` observed in one place and ``with self.B: with self.A:`` in
  another (same class) is a deadlock waiting for a scheduler.
* **RPR103** — blocking call (jax dispatch, ``.take()`` gathers, file
  I/O, sleeps, joins) inside a ``with <lock>:`` body — the bug class the
  PR 5 off-lock staged gather fixed by hand.

Scope model: each function body is a frame with its own held-lock set.
``__init__``/``__del__`` are exempt (the object is not yet / no longer
shared).  Nested ``def``/``lambda`` bodies start with *no* held locks —
a closure created under a lock may run on another thread after the lock
is released, so inheriting the lexical lock set would be unsound.
``@requires_lock("<lock>")`` marks helpers whose contract is that the
caller already holds the lock.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from .engine import FileContext, Finding, Rule

__all__ = ["LockDisciplineRules"]

#: attribute-call names considered blocking when a declared lock is held
_BLOCKING_ATTRS = {"take", "tofile", "fsync", "block_until_ready",
                   "device_put", "sleep", "join", "result"}
#: receivers whose ``.take`` is a cheap in-memory gather, not storage I/O
_CHEAP_TAKE_RECEIVERS = {"np", "numpy", "jnp"}
_EXEMPT_METHODS = {"__init__", "__del__"}


@dataclasses.dataclass
class _ClassInfo:
    name: str
    guarded: Dict[str, str]        # attr -> lock name
    locks: Set[str]                # every declared lock name


@dataclasses.dataclass
class _Frame:
    node: ast.AST
    cls: Optional[_ClassInfo]
    exempt: bool
    held: List[str] = dataclasses.field(default_factory=list)
    # with-nodes to the number of locks they pushed, for the leave pop
    with_counts: List[Tuple[ast.AST, int]] = dataclasses.field(
        default_factory=list)


def _decorator_call(dec: ast.expr, name: str) -> Optional[ast.Call]:
    if isinstance(dec, ast.Call):
        f = dec.func
        if (isinstance(f, ast.Name) and f.id == name) or \
           (isinstance(f, ast.Attribute) and f.attr == name):
            return dec
    return None


def _str_args(call: ast.Call) -> List[str]:
    out = []
    for a in call.args:
        if isinstance(a, ast.Constant) and isinstance(a.value, str):
            out.append(a.value)
    return out


def _root_name(expr: ast.expr) -> Optional[str]:
    while isinstance(expr, (ast.Attribute, ast.Subscript)):
        expr = expr.value
    return expr.id if isinstance(expr, ast.Name) else None


class LockDisciplineRules(Rule):
    types = (ast.ClassDef, ast.FunctionDef, ast.AsyncFunctionDef,
             ast.Lambda, ast.With, ast.Attribute, ast.Call)

    def __init__(self) -> None:
        # (class, inner_first, outer_first) -> first (path, line) observed
        self._order: Dict[Tuple[str, str, str], Tuple[str, int]] = {}
        self._class_stack: List[Optional[_ClassInfo]] = []
        self._frames: List[_Frame] = []

    # ----------------------------------------------------------- lifecycle

    def begin_file(self, ctx: FileContext) -> None:
        self._class_stack = []
        self._frames = []

    # --------------------------------------------------------------- class

    def visit(self, node: ast.AST, ctx: FileContext) -> None:
        if isinstance(node, ast.ClassDef):
            self._class_stack.append(self._parse_class(node))
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            self._enter_function(node, ctx)
        elif isinstance(node, ast.Lambda):
            cls = self._frames[-1].cls if self._frames else None
            self._frames.append(_Frame(node, cls, exempt=False))
        elif isinstance(node, ast.With):
            self._enter_with(node, ctx)
        elif isinstance(node, ast.Attribute):
            self._check_attribute(node, ctx)
        elif isinstance(node, ast.Call):
            self._check_blocking(node, ctx)

    def leave(self, node: ast.AST, ctx: FileContext) -> None:
        if isinstance(node, ast.ClassDef):
            self._class_stack.pop()
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.Lambda)):
            if self._frames and self._frames[-1].node is node:
                self._frames.pop()
        elif isinstance(node, ast.With):
            fr = self._frames[-1] if self._frames else None
            if fr and fr.with_counts and fr.with_counts[-1][0] is node:
                _, n = fr.with_counts.pop()
                for _ in range(n):
                    fr.held.pop()

    # ------------------------------------------------------------- helpers

    @staticmethod
    def _parse_class(node: ast.ClassDef) -> Optional[_ClassInfo]:
        guarded: Dict[str, str] = {}
        locks: Set[str] = set()
        for dec in node.decorator_list:
            call = _decorator_call(dec, "guarded_by")
            if call is not None:
                names = _str_args(call)
                if names:
                    lock, attrs = names[0], names[1:]
                    locks.add(lock)
                    for a in attrs:
                        guarded[a] = lock
        if not locks:
            return None
        return _ClassInfo(node.name, guarded, locks)

    def _enter_function(self, node: ast.FunctionDef,
                        ctx: FileContext) -> None:
        parent = ctx.parent()
        is_method = isinstance(parent, ast.ClassDef) and \
            bool(self._class_stack) and self._class_stack[-1] is not None
        cls = self._class_stack[-1] if is_method else (
            self._frames[-1].cls if self._frames else None)
        exempt = is_method and node.name in _EXEMPT_METHODS
        frame = _Frame(node, cls, exempt)
        if cls is not None:
            for dec in node.decorator_list:
                call = _decorator_call(dec, "requires_lock")
                if call is not None:
                    frame.held.extend(_str_args(call))
        self._frames.append(frame)

    def _enter_with(self, node: ast.With, ctx: FileContext) -> None:
        fr = self._frames[-1] if self._frames else None
        if fr is None or fr.cls is None:
            return
        acquired = []
        for item in node.items:
            e = item.context_expr
            if isinstance(e, ast.Attribute) and \
                    isinstance(e.value, ast.Name) and e.value.id == "self" \
                    and e.attr in fr.cls.locks:
                acquired.append(e.attr)
        if not acquired:
            return
        for new in acquired:
            for outer in fr.held:
                if outer != new:
                    key = (fr.cls.name, outer, new)
                    self._order.setdefault(key, (ctx.path, node.lineno))
            fr.held.append(new)
        fr.with_counts.append((node, len(acquired)))

    def _check_attribute(self, node: ast.Attribute,
                         ctx: FileContext) -> None:
        fr = self._frames[-1] if self._frames else None
        if fr is None or fr.cls is None or fr.exempt:
            return
        if not (isinstance(node.value, ast.Name) and node.value.id == "self"):
            return
        lock = fr.cls.guarded.get(node.attr)
        if lock is None or lock in fr.held:
            return
        parent = ctx.parent()
        if isinstance(parent, ast.AugAssign) and parent.target is node:
            ctx.report("RPR303", node,
                       f"augmented update of guarded counter "
                       f"'self.{node.attr}' outside 'with self.{lock}:' "
                       f"(lost-update race)",
                       f"move the += under 'with self.{lock}:'")
        elif isinstance(node.ctx, (ast.Store, ast.Del)):
            ctx.report("RPR104", node,
                       f"write to guarded attribute 'self.{node.attr}' "
                       f"outside 'with self.{lock}:'",
                       f"wrap in 'with self.{lock}:'")
        else:
            ctx.report("RPR101", node,
                       f"read of guarded attribute 'self.{node.attr}' "
                       f"outside 'with self.{lock}:'",
                       f"wrap in 'with self.{lock}:' or snapshot under "
                       f"the lock")

    def _check_blocking(self, node: ast.Call, ctx: FileContext) -> None:
        fr = self._frames[-1] if self._frames else None
        if fr is None or not fr.held:
            return
        f = node.func
        name: Optional[str] = None
        if isinstance(f, ast.Name) and f.id == "open":
            name = "open"
        elif isinstance(f, ast.Attribute) and f.attr in _BLOCKING_ATTRS:
            recv = _root_name(f.value)
            if f.attr == "take" and recv in _CHEAP_TAKE_RECEIVERS:
                return
            # '...'.join(seq) string building and os.path.join are pure
            # CPU — only thread/process/pool joins block
            if f.attr == "join" and (isinstance(f.value, ast.Constant)
                                     or recv == "os"):
                return
            name = f.attr
        if name is not None:
            held = ", ".join(f"self.{k}" for k in fr.held)
            ctx.report("RPR103", node,
                       f"blocking call '{name}(...)' while holding {held}",
                       "stage the slow work outside the lock and publish "
                       "the result under it (PR 5 staged-gather pattern)")

    # ------------------------------------------------------------- project

    def finish(self) -> List[Finding]:
        out: List[Finding] = []
        for (cls, a, b), (path, line) in sorted(self._order.items()):
            if a < b and (cls, b, a) in self._order:
                other_path, other_line = self._order[(cls, b, a)]
                out.append(Finding(
                    path, line, "RPR102",
                    f"lock order inversion in {cls}: self.{a} -> self.{b} "
                    f"here but self.{b} -> self.{a} at "
                    f"{other_path}:{other_line}",
                    "pick one global acquisition order for these locks"))
                out.append(Finding(
                    other_path, other_line, "RPR102",
                    f"lock order inversion in {cls}: self.{b} -> self.{a} "
                    f"here but self.{a} -> self.{b} at {path}:{line}",
                    "pick one global acquisition order for these locks"))
        return out
