"""Two-stage feature prefetching / pipelined runtime (paper Section IV-B).

Generic bounded-queue pipeline: each stage runs in its own host thread and
communicates through ``queue.Queue(maxsize=depth)``.  ``depth`` is the
prefetch window — with the paper's default (2) the Feature Loader works on
mini-batch i+2 while the Data Transfer stage ships mini-batch i+1 and the
accelerator executes mini-batch i (paper Fig. 7).

The stages overlap because they use different resources (host RAM channel,
PCIe channel, device compute) and mini-batches are independent.  Disabling
TFP (``depth=0``) degenerates to sequential stage execution — that is the
ablation baseline of Fig. 11.

Every item carries a ``timings`` dict; each stage records its service time,
which the Runtime feeds to the DRM engine.  Pipelined stages additionally
record ``<stage>_wait`` — the time the worker sat starved on its input
queue before this item arrived (0 in sequential mode).  Wait times are
the pipeline-level stall signal: a stage whose upstream is the bottleneck
shows large waits, a stage that IS the bottleneck shows none.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Dict, Iterable, Iterator, List, Optional

__all__ = ["PipelineItem", "Stage", "PrefetchPipeline"]

_SENTINEL = object()


@dataclasses.dataclass
class PipelineItem:
    seq: int
    payload: Any
    timings: Dict[str, float] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class Stage:
    name: str
    fn: Callable[[PipelineItem], PipelineItem]   # mutates/returns the item


class PrefetchPipeline:
    """Chains stages over bounded queues; ``depth=0`` means fully sequential."""

    def __init__(self, stages: List[Stage], depth: int = 2):
        self.stages = stages
        self.depth = int(depth)
        # last completed run's failure (observability only): every run()
        # threads its OWN error holder + stop event through its workers,
        # so threads left over from an abandoned earlier run can never
        # contaminate a later run's state
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------ sequential

    def _run_sequential(self, items: Iterable[PipelineItem]
                        ) -> Iterator[PipelineItem]:
        for item in items:
            for st in self.stages:
                t0 = time.perf_counter()
                item = st.fn(item)
                item.timings[st.name] = time.perf_counter() - t0
            yield item

    # ------------------------------------------------------------- pipelined

    def _worker(self, st: Stage, q_in: "queue.Queue", q_out: "queue.Queue",
                state: Dict[str, Optional[BaseException]],
                stop: threading.Event):
        failed = False
        while True:
            t_wait = time.perf_counter()
            item = q_in.get()
            wait = time.perf_counter() - t_wait
            if item is _SENTINEL:
                q_out.put(_SENTINEL)
                return
            if failed:
                continue            # drain so the feeder never blocks
            try:
                item.timings[st.name + "_wait"] = wait
                t0 = time.perf_counter()
                item = st.fn(item)
                item.timings[st.name] = time.perf_counter() - t0
            except BaseException as e:  # propagate to consumer
                state["error"] = e
                stop.set()          # feeder: stop pulling new payloads
                failed = True       # keep draining until the sentinel
                continue
            q_out.put(item)

    def run(self, items: Iterable[PipelineItem]) -> Iterator[PipelineItem]:
        # a pipeline object is reusable: a clean run must not re-raise a
        # stale exception, so failure state is PER RUN (closed over below)
        self._error = None
        if self.depth <= 0:
            yield from self._run_sequential(items)
            return
        state: Dict[str, Optional[BaseException]] = {"error": None}
        stop = threading.Event()
        qs: List["queue.Queue"] = [queue.Queue(maxsize=self.depth)
                                   for _ in range(len(self.stages) + 1)]
        threads = [threading.Thread(target=self._worker,
                                    args=(st, qs[i], qs[i + 1], state, stop),
                                    daemon=True)
                   for i, st in enumerate(self.stages)]
        for t in threads:
            t.start()

        def feed():
            try:
                for item in items:
                    if stop.is_set():
                        break       # a stage died: don't consume payloads
                    qs[0].put(item)
            finally:
                qs[0].put(_SENTINEL)

        feeder = threading.Thread(target=feed, daemon=True)
        feeder.start()
        while True:
            item = qs[-1].get()
            if item is _SENTINEL:
                break
            yield item
        feeder.join()
        for t in threads:
            t.join()
        if state["error"] is not None:
            self._error = state["error"]
            raise state["error"]
