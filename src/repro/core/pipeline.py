"""Two-stage feature prefetching / pipelined runtime (paper Section IV-B).

Generic bounded-queue pipeline: each stage runs in its own host thread and
communicates through ``queue.Queue(maxsize=depth)``.  ``depth`` is the
prefetch window — with the paper's default (2) the Feature Loader works on
mini-batch i+2 while the Data Transfer stage ships mini-batch i+1 and the
accelerator executes mini-batch i (paper Fig. 7).

The stages overlap because they use different resources (host RAM channel,
PCIe channel, device compute) and mini-batches are independent.  Disabling
TFP (``depth=0``) degenerates to sequential stage execution — that is the
ablation baseline of Fig. 11.

Every item carries a ``timings`` dict; each stage records its service time,
which the Runtime feeds to the DRM engine.  Pipelined stages additionally
record ``<stage>_wait`` — the time the worker sat starved on its input
queue before this item arrived (0 in sequential mode).  Wait times are
the pipeline-level stall signal: a stage whose upstream is the bottleneck
shows large waits, a stage that IS the bottleneck shows none.

Failure model & degraded modes
------------------------------

A stage that *raises* already fails fast: the error latches, the feeder
stops consuming payloads, every worker drains to its sentinel, and the
current ``run()`` re-raises — no deadlock, no stale state on the next
run.  A stage that *wedges* (a gather stuck on a dead NFS mount, an
injected 30 s delay) used to hang the consumer forever; with
``watchdog_seconds > 0`` the consumer polls its output queue and checks
per-stage heartbeats: a stage busy on one item (or the feeder's batch
generator stuck) past the deadline raises ``PipelineStallError`` naming
the wedged stage, how long it has been stuck, every queue depth and
per-stage completion counts — a diagnosis instead of a hang.  The
watchdog never fires while items keep arriving, and 0 (the default)
keeps the legacy blocking behaviour.  Deterministic fault hook:
``pipeline.<stage>`` fires before each stage invocation (injected
delays wedge the stage and back queues up into a queue-full storm;
injected errors exercise the stage-failure protocol).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Dict, Iterable, Iterator, List, Optional

__all__ = ["PipelineItem", "Stage", "PrefetchPipeline", "PipelineStallError"]

_SENTINEL = object()


@dataclasses.dataclass
class PipelineItem:
    seq: int
    payload: Any
    timings: Dict[str, float] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class Stage:
    name: str
    fn: Callable[[PipelineItem], PipelineItem]   # mutates/returns the item


class PipelineStallError(RuntimeError):
    """A pipeline stage (or the feeder) made no progress past the
    watchdog deadline.  Carries the wedged stage's name plus a queue /
    completion snapshot for diagnosis."""

    def __init__(self, stage: str, stalled_seconds: float,
                 watchdog_seconds: float, queue_depths: Dict[str, int],
                 completed: Dict[str, int]):
        self.stage = stage
        self.stalled_seconds = stalled_seconds
        self.watchdog_seconds = watchdog_seconds
        self.queue_depths = dict(queue_depths)
        self.completed = dict(completed)
        super().__init__(
            f"pipeline stage {stage!r} wedged: no progress for "
            f"{stalled_seconds:.1f}s (watchdog {watchdog_seconds:.1f}s); "
            f"queue depths {queue_depths}; items completed per stage "
            f"{completed}")


class PrefetchPipeline:
    """Chains stages over bounded queues; ``depth=0`` means fully sequential.

    Concurrency note (why there is no ``guarded_by`` declaration here,
    unlike the other threaded modules — the annotation is opt-in and this
    class deliberately has nothing to declare): every ``run()`` threads
    its own per-run locals (queues, heartbeat dicts, error holder, stop
    event) through the workers it spawns, and all cross-thread handoffs
    ride the bounded ``queue.Queue``s, whose put/get pairs establish the
    happens-before edges.  The heartbeat dicts are single-writer (their
    own stage thread); the watchdog only ever *reads* them, and a torn
    read costs one poll tick, not correctness.  ``self._error`` is
    observability-only, written after the run's threads are joined."""

    def __init__(self, stages: List[Stage], depth: int = 2,
                 watchdog_seconds: float = 0.0,
                 fault_injector=None):
        self.stages = stages
        self.depth = int(depth)
        self.watchdog_seconds = float(watchdog_seconds)
        self.fault_injector = fault_injector
        # last completed run's failure (observability only): every run()
        # threads its OWN error holder + stop event through its workers,
        # so threads left over from an abandoned earlier run can never
        # contaminate a later run's state
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------ sequential

    def _run_sequential(self, items: Iterable[PipelineItem]
                        ) -> Iterator[PipelineItem]:
        for item in items:
            for st in self.stages:
                if self.fault_injector is not None:
                    self.fault_injector.fire(f"pipeline.{st.name}")
                t0 = time.perf_counter()
                item = st.fn(item)
                item.timings[st.name] = time.perf_counter() - t0
            yield item

    # ------------------------------------------------------------- pipelined

    def _worker(self, st: Stage, q_in: "queue.Queue", q_out: "queue.Queue",
                state: Dict[str, Optional[BaseException]],
                stop: threading.Event, hb: Dict[str, Any]):
        failed = False
        while True:
            t_wait = time.perf_counter()
            item = q_in.get()
            wait = time.perf_counter() - t_wait
            if item is _SENTINEL:
                q_out.put(_SENTINEL)
                return
            if failed or stop.is_set():
                continue            # drain so the feeder never blocks
            try:
                item.timings[st.name + "_wait"] = wait
                # heartbeat: the watchdog reads (busy, since) to tell a
                # wedged stage from an idle one
                hb["since"] = time.perf_counter()
                hb["busy"] = True
                if self.fault_injector is not None:
                    self.fault_injector.fire(f"pipeline.{st.name}")
                t0 = time.perf_counter()
                item = st.fn(item)
                item.timings[st.name] = time.perf_counter() - t0
                hb["busy"] = False
                hb["done"] += 1
            except BaseException as e:  # propagate to consumer
                hb["busy"] = False
                state["error"] = e
                stop.set()          # feeder: stop pulling new payloads
                failed = True       # keep draining until the sentinel
                continue
            q_out.put(item)

    def _check_stall(self, beats: List[Dict[str, Any]],
                     qs: List["queue.Queue"],
                     stop: threading.Event) -> None:
        """Raise ``PipelineStallError`` if any busy stage (or the feeder's
        generator pull) exceeded the watchdog deadline."""
        now = time.perf_counter()
        for hb in beats:
            if hb["busy"] and now - hb["since"] > self.watchdog_seconds:
                stop.set()
                depths = {}
                for i, q in enumerate(qs):
                    label = (self.stages[i].name if i < len(self.stages)
                             else "output") + "_in"
                    depths[label] = q.qsize()
                completed = {hb2["name"]: hb2["done"] for hb2 in beats}
                raise PipelineStallError(
                    hb["name"], now - hb["since"], self.watchdog_seconds,
                    depths, completed)

    def run(self, items: Iterable[PipelineItem]) -> Iterator[PipelineItem]:
        # a pipeline object is reusable: a clean run must not re-raise a
        # stale exception, so failure state is PER RUN (closed over below)
        self._error = None
        if self.depth <= 0:
            yield from self._run_sequential(items)
            return
        state: Dict[str, Optional[BaseException]] = {"error": None}
        stop = threading.Event()
        qs: List["queue.Queue"] = [queue.Queue(maxsize=self.depth)
                                   for _ in range(len(self.stages) + 1)]
        beats: List[Dict[str, Any]] = [
            {"name": st.name, "busy": False, "since": 0.0, "done": 0}
            for st in self.stages]
        feed_hb: Dict[str, Any] = {"name": "feed", "busy": False,
                                   "since": 0.0, "done": 0}
        threads = [threading.Thread(target=self._worker,
                                    args=(st, qs[i], qs[i + 1], state, stop,
                                          beats[i]),
                                    daemon=True)
                   for i, st in enumerate(self.stages)]
        for t in threads:
            t.start()

        def feed():
            try:
                it = iter(items)
                while True:
                    if stop.is_set():
                        break       # a stage died: don't consume payloads
                    # the generator pull is heartbeat-tracked too: a
                    # wedged batch source (not just a wedged stage) must
                    # also be diagnosable
                    feed_hb["since"] = time.perf_counter()
                    feed_hb["busy"] = True
                    try:
                        item = next(it)
                    except StopIteration:
                        feed_hb["busy"] = False
                        break
                    feed_hb["busy"] = False
                    feed_hb["done"] += 1
                    qs[0].put(item)
            finally:
                feed_hb["busy"] = False
                qs[0].put(_SENTINEL)

        feeder = threading.Thread(target=feed, daemon=True)
        feeder.start()
        wd = self.watchdog_seconds
        poll = min(0.2, wd / 5.0) if wd > 0 else None
        while True:
            if poll is None:
                item = qs[-1].get()
            else:
                try:
                    item = qs[-1].get(timeout=poll)
                except queue.Empty:
                    # nothing arrived this tick: is someone wedged?  (the
                    # stalled stage's thread stays stuck inside st.fn —
                    # nothing can unstick it — so raise a diagnosis
                    # instead of inheriting its hang)
                    self._check_stall(beats + [feed_hb], qs, stop)
                    continue
            if item is _SENTINEL:
                break
            yield item
        feeder.join()
        for t in threads:
            t.join()
        if state["error"] is not None:
            self._error = state["error"]
            raise state["error"]
