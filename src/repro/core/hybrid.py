"""Hybrid GNN training system (paper Sections III + IV glued together).

``HybridGNNTrainer`` wires every logical component of Fig. 3/4 into the
pipelined runtime:

  Mini-batch Sampler (CPU numpy / accelerator jit)      -> Stage "sample"
  Feature Loader (host gather, thread knob)             -> Stage "load"
  Data Transfer (host->device, per accelerator)         -> Stage "transfer"
  GNN Trainers (CPU + n accelerators, unequal shares)   -> consumer
  Synchronizer (weighted all-reduce, Listing-1 handshake)
  Runtime + DRM (per-stage times -> next-iteration assignment)

Ablation knobs reproduce Fig. 11 exactly:
  * ``hybrid=False``                       -> the "baseline" (accel-only),
  * ``hybrid=True,  use_drm=False``        -> "+hybrid" (static perf-model map),
  * ``use_drm=True``                       -> "+DRM",
  * ``tfp_depth>=1``                       -> "+TFP" (two-stage prefetch),
  * ``cache_fraction>0``                   -> "+cache": top-K hot node
    features pinned per accelerator (graph/featcache.py); the load stage
    gathers only cache misses, the transfer stage ships them, and the
    on-device combine (kernels cache_combine / its jnp ref) assembles the
    dense layer-0 input.  The perf model's Eq. 7/8 carry the matching
    (1 - hit_rate) traffic term, so the initial task mapping already
    leans on the cheaper transfer; the DRM then refines from measured
    times as usual.
  * ``dedup=True`` (default)               -> the unit of the whole
    host->device feature path is the *unique node id*: the load stage
    deduplicates each frontier once (np.unique + int32 inverse map),
    classifies only uniques against the cache, gathers/ships only unique
    miss rows, and the on-device combine expands them back into the
    positional [frontier, F] layer-0 layout (the paper's §IV-C Feature
    Duplicator, moved to the far side of the interconnect).  A probe
    mini-batch measures the duplication factor alpha at design time so
    Eq. 7/8 price load/transfer off deduped traffic.  Works with or
    without the cache; ``dedup=False`` reproduces the legacy positional
    path bit-for-bit.

  * ``cache_refresh=True``                 -> dynamic cache: lookups feed
    decayed hotness counters and, on the measured-vs-priced drift signal,
    the coldest cache slots are swapped for strictly-hotter observed
    uncached nodes (DistDGL-style admission).  The device block is
    scatter-updated in place (cache_update kernel: one aligned row-block
    DMA per admitted node) and every in-flight TFP payload combines
    against the cache *version* its lookup was classified at, so a
    refresh can never corrupt batches already past the load stage —
    losses are bit-identical with refresh on or off.

  * ``cache_sharding="sharded"``           -> the distributed hot-feature
    plane: each accelerator pins a *disjoint* hot shard (hash or
    degree-range placement), n× effective capacity at the same per-device
    budget.  A frontier row missing locally is pulled from the peer shard
    owning it over the accelerator interconnect (ring-ordered
    ``dist.collectives.exchange_peer_rows``) before falling back to the
    host, and the load stage gathers the *union* of all trainers' miss
    sets once, multicasting each row only to the devices that need it
    (one host gather instead of n).  Losses stay bit-identical to the
    replicated plane — only where bytes travel changes.

  * ``recent_rows_batches>0``              -> cross-iteration device-side
    dedup (replicated path): unique rows shipped in the last N batches
    stay addressable on their device and are re-gathered there instead
    of re-shipped over PCIe; invalidated by any cache refresh.

  * ``prefetch_windows>0`` / ``mmap_lru_windows>0`` / ``async_refresh``
    -> the background storage-I/O subsystem for the disk tier: the sample
    stage hands batch i+1's frontier to a ``WindowPrefetcher`` thread
    that pre-faults its mmap partition windows while batch i loads (so
    the load stage never blocks on cold disk reads; the residual stall
    is DRM-visible as ``StageTimes.t_load_stall``), the window LRU evicts
    with MADV_DONTNEED to bound page-cache residency, and the dynamic
    cache refresh stages its admitted-row gather in a background thread —
    the iteration boundary only pays the cheap ``commit()``.  All three
    are bit-invisible to training losses.

Measured-hit-rate feedback: when the loader's measured cache hit rate
(over the post-refresh window) drifts more than ``cache_drift_threshold``
from the estimate the task mapping was priced with, the initial task
mapping is re-run with the measured rate (and measured alpha) and the
refreshed shares handed to the runtime — the DRM keeps fine-tuning from
there.

On this container all logical devices are CPU cores; the protocol, queues and
measurements are identical to a real multi-accelerator host — device kind
only changes the programming layer underneath (paper Section III-C).

Failure model & degraded modes
------------------------------

With ``degrade_on_failure=True`` (default) the trainer survives permanent
failures of its *advisory* background subsystems instead of dying
mid-run: a prefetch worker dead past ``prefetch_restart_budget`` restarts
stops being fed (loads degrade to synchronous cold gathers and the
mapping's ``prefetch_overlap`` re-prices to 0 via the usual overlap-drift
feedback); a failed refresh ``stage()`` discards its plan, keeps serving
the old cache version and retries at the next drift boundary, until
``refresh_failure_budget`` consecutive failures disable refresh for the
run; the storage tier retries transient I/O and falls back to the spill's
backing source for unreadable blobs (see ``graph/storage.py``).  Every
degradation is recorded and surfaced through ``health()`` — never silent.
``degrade_on_failure=False`` restores the legacy fail-fast raises.
``pipeline_watchdog_seconds > 0`` converts a wedged TFP stage into a
diagnostic ``PipelineStallError`` naming the stage and queue depths.
Deterministic chaos testing injects faults at every one of these seams
via the ``fault_injector`` constructor hook (``graph/faults.py``).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.annotations import guarded_by
from repro.dist.collectives import exchange_peer_rows
from repro.graph import (FeatureLoader, GNNConfig, GraphDataset, MiniBatch,
                         MissBlock, NumpySampler, ShardMissBlock,
                         WindowPrefetcher, build_cache, build_sharded_cache,
                         compact_lookup, init_params, loss_fn,
                         sample_minibatch_jax)
from repro.kernels.ops import assemble_features, assemble_features_sharded
from repro.optim import (CompressionSpec, adamw, compress_grads,
                         decompress_grads)
from repro.optim.optimizers import apply_updates

from .drm import Assignment, KnobAutoTuner, StageTimes
from .perfmodel import (PLATFORMS, CalibratedKnobModel, KnobBounds,
                        KnobState, SignalSnapshot, initial_task_mapping)
from .pipeline import PipelineItem, PrefetchPipeline, Stage
from .protocol import Runtime, Synchronizer, TrainerHandle

__all__ = ["HybridConfig", "HybridGNNTrainer", "IterationMetrics"]

PyTree = Any


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    total_batch: int = 1024
    n_accel: int = 1
    hybrid: bool = True               # CPU trainer participates
    use_drm: bool = True
    tfp_depth: int = 2                # 0 = sequential (no TFP)
    use_accel_sampler: bool = True
    compression: str = "none"         # sync-path gradient compression
    feature_dtype: str = "float32"    # transfer-path compression ("bfloat16")
    cache_fraction: float = 0.0       # device hot-feature cache (0 = off)
    cache_sharding: str = "replicated"  # "replicated" = one identical cache
                                      #   per accelerator (legacy, bit-exact);
                                      #   "sharded" = disjoint hot shard per
                                      #   accelerator (n× effective capacity,
                                      #   peer rows over ICI, union-gather
                                      #   multicast).  Falls back to
                                      #   replicated below 2 accelerators.
    shard_placement: str = "hash"     # sharded-plane placement policy:
                                      #   "hash" (SplitMix64 of the node id)
                                      #   or "degree" (contiguous
                                      #   hotness-rank ranges)
    recent_rows_batches: int = 0      # cross-iteration device-side dedup:
                                      #   rows shipped in the last N batches
                                      #   stay addressable on the device and
                                      #   are not re-shipped (0 = off;
                                      #   replicated/dedup path only)
    cache_assemble: str = "auto"      # "auto" | "jnp" | "pallas" combine path
    kernel_pipeline_depth: int = 1    # Pallas combine/scatter DMA pipeline
                                      #   depth: 1 = single-buffered, 2..4 =
                                      #   multi-buffered DMA/compute overlap
                                      #   (bit-identical output either way)
    cache_refresh: bool = False       # dynamic cache refresh (DistDGL-style
                                      #   admission on the drift signal)
    cache_refresh_frac: float = 0.25  # max fraction of slots swapped per
                                      #   refresh
    cache_refresh_decay: float = 0.5  # hotness-counter decay per refresh
                                      #   window
    cache_drift_threshold: float = 0.05  # measured-vs-priced hit-rate drift
                                      #   (points) that triggers a cache
                                      #   refresh and a mapping re-price
    cache_refresh_hysteresis: float = 1.25  # admit only when hotter than the
                                      #   victim by this factor (boundary
                                      #   hub sets stop thrashing)
    async_refresh: bool = False       # stage the refresh gather in a
                                      #   background thread; the iteration
                                      #   boundary only pays the cheap
                                      #   table/device-block commit()
    prefetch_windows: int = 0         # background window prefetch queue
                                      #   depth: the sample stage enqueues
                                      #   batch i+1's frontier so its mmap
                                      #   windows are warm when the load
                                      #   stage gathers (0 = off; needs the
                                      #   mmap feature backend)
    prefetch_dedup_history: int = 2   # cross-batch prefetch dedup: remember
                                      #   the last N submitted frontiers and
                                      #   strip already-warm rows from new
                                      #   submits (0 = off)
    mmap_lru_windows: int = 0         # bound on simultaneously open mmap
                                      #   windows; LRU eviction issues
                                      #   MADV_DONTNEED so page-cache use
                                      #   stays O(lru * window_bytes)
                                      #   (0 = unbounded)
    dedup: bool = True                # ship unique rows only (False = legacy
                                      #   one-row-per-frontier-position)
    degrade_on_failure: bool = True   # advisory background subsystems
                                      #   (prefetcher, async refresh) degrade
                                      #   on permanent failure instead of
                                      #   killing the run; False = legacy
                                      #   fail-fast raises
    prefetch_restart_budget: int = 2  # background prefetch-worker respawns
                                      #   (with backoff) before the
                                      #   prefetcher is declared dead
    refresh_failure_budget: int = 3   # consecutive refresh stage() failures
                                      #   before dynamic refresh is disabled
                                      #   for the rest of the run
    pipeline_watchdog_seconds: float = 0.0  # TFP stage-stall watchdog: a
                                      #   stage busy on one item past this
                                      #   deadline raises PipelineStallError
                                      #   instead of hanging (0 = off)
    cache_refresh_period: int = 1     # iteration boundaries between drift
                                      #   checks (refresh cadence; 1 = every
                                      #   boundary, the legacy behaviour)
    auto_tune: bool = False           # model-predictive knob search: the
                                      #   DRM proposes bounded moves in the
                                      #   performance knobs (prefetch queue,
                                      #   window LRU, stage threads, refresh
                                      #   cadence/fraction) from the
                                      #   calibrated Eq. 7/8 model, applies
                                      #   them through the re-price/refresh
                                      #   machinery and rolls back measured
                                      #   regressions.  Never touches RNG
                                      #   streams, batch composition or
                                      #   workload shares: losses stay
                                      #   bit-identical to a static-knob run
    autotune_interval: int = 3        # iterations per measurement window
    autotune_hysteresis: float = 0.10 # measured regression (relative) that
                                      #   rolls a trial move back
    autotune_min_gain: float = 0.02   # predicted gain required to try a move
    autotune_warmup_windows: int = 1  # windows observed before the first
                                      #   proposal (JIT warmup pollutes the
                                      #   earliest measurements)
    initial_threads: Optional[Tuple[int, int, int]] = None
                                      # (sample, load, train) stage-thread
                                      #   start point; None = (2, 2, 2).
                                      #   Benchmarks use this to start the
                                      #   autotuner from a skewed layout
    lr: float = 1e-3
    share_quantum: int = 64
    drm_damping: float = 0.25
    seed: int = 0
    host_platform: str = "epyc-7763"
    accel_platform: str = "tpu-v5e"
    ckpt_every: int = 0               # 0 = disabled
    ckpt_dir: Optional[str] = None


@dataclasses.dataclass
class IterationMetrics:
    iteration: int
    loss: float
    acc: float
    times: StageTimes
    t_sync: float
    edges: int
    assignment: Tuple[int, int]       # (cpu_batch, accel_batch_each)
    cache_hit_rate: float = 0.0       # measured (epoch-window) cache hit rate
    cache_version: int = 0            # cache version after this iteration
                                      #   (> 0 once a dynamic refresh fired)

    @property
    def iter_time(self) -> float:
        return self.times.iteration_time()

    @property
    def mteps(self) -> float:
        t = self.iter_time
        return self.edges / t / 1e6 if t > 0 else 0.0


class _TrainerFailure(RuntimeError):
    pass


# Deliberately UNGUARDED shared state: _fail_at (written once before the
# run by the failure-injection test hook, read-only during it),
# _refresh_failures / _refresh_disabled / _staged_feedback /
# _refresh_thread (only ever touched at iteration boundaries on the
# training thread — the refresh worker writes nothing but
# _refresh_error, which IS declared), and everything the pipeline hands
# through PipelineItem payloads (queue happens-before).
@guarded_by("_state_lock", "_failed", "_degraded", "_refresh_error")
class HybridGNNTrainer:
    def __init__(self, dataset: GraphDataset, gnn_cfg: GNNConfig,
                 cfg: HybridConfig, fault_injector=None):
        self.dataset = dataset
        self.gnn_cfg = gnn_cfg
        self.cfg = cfg
        self.fault_injector = fault_injector
        self._rng = np.random.default_rng(cfg.seed)
        self._epoch_perm = self._rng.permutation(dataset.num_nodes)
        self._cursor = 0
        self._failed: set = set()
        self._fail_at: Dict[str, int] = {}
        # degraded-mode record: component -> event dict, surfaced by
        # health(); idempotent per component (first failure wins)
        self._degraded: Dict[str, Dict[str, Any]] = {}
        # guards the failure/degradation record: trainer worker threads
        # add to _failed, pipeline stage threads note degradation, the
        # refresh worker latches _refresh_error — while the training
        # thread (and health()) iterate the same containers
        self._state_lock = threading.Lock()
        self._refresh_failures = 0        # consecutive stage() failures
        self._refresh_disabled = False    # budget spent: refresh is off

        devices = jax.devices()
        self.cpu_device = devices[0]
        self.accel_devices = [devices[i % len(devices)]
                              for i in range(1, 1 + cfg.n_accel)]

        # --- parameters / optimizer (single authoritative copy) -------------
        key = jax.random.PRNGKey(cfg.seed)
        self.params = init_params(key, gnn_cfg)
        self.optimizer = adamw(cfg.lr)
        self.opt_state = self.optimizer.init(self.params)
        self.compression = CompressionSpec(cfg.compression)

        # --- samplers --------------------------------------------------------
        self.cpu_sampler = NumpySampler(dataset.graph, gnn_cfg.fanouts,
                                        seed=cfg.seed + 1)
        self._dev_topology = None
        if cfg.use_accel_sampler and dataset.graph.nbytes() < (1 << 30):
            self._dev_topology = (jnp.asarray(dataset.graph.indptr),
                                  jnp.asarray(dataset.graph.indices))
            self._jax_sample = jax.jit(partial(sample_minibatch_jax,
                                               fanouts=gnn_cfg.fanouts))
        self._sample_key = jax.random.PRNGKey(cfg.seed + 2)

        # --- background storage I/O (disk tier) ------------------------------
        # the window LRU bounds the page cache; the prefetcher pre-faults
        # batch i+1's windows while batch i trains.  Both are no-ops on
        # RAM-resident sources (nothing to fault, nothing to evict).
        # Wired BEFORE the cache: its boot gather streams through the
        # source and must already respect the window bound.
        src = dataset.feature_source
        if cfg.mmap_lru_windows > 0 and hasattr(src, "lru_windows"):
            src.lru_windows = int(cfg.mmap_lru_windows)
        if fault_injector is not None and hasattr(src, "fault_injector"):
            src.fault_injector = fault_injector
        self.prefetcher: Optional[WindowPrefetcher] = \
            self._build_prefetcher(cfg.prefetch_windows)

        # --- feature store: device hot cache + dedup/miss-only loader --------
        # "sharded" partitions the hot set across the accelerators
        # (disjoint per-device shards, peer rows over ICI, one union
        # gather per batch); below 2 accelerators there is nothing to
        # partition and the plane falls back to the replicated cache.
        if (cfg.cache_sharding == "sharded" and cfg.n_accel >= 2
                and cfg.cache_fraction > 0.0):
            self.cache = build_sharded_cache(
                dataset, cfg.cache_fraction, n_shards=cfg.n_accel,
                placement=cfg.shard_placement,
                transfer_dtype=cfg.feature_dtype,
                refresh_decay=cfg.cache_refresh_decay,
                max_refresh_frac=cfg.cache_refresh_frac,
                refresh_hysteresis=cfg.cache_refresh_hysteresis)
        else:
            self.cache = build_cache(dataset, cfg.cache_fraction,
                                     transfer_dtype=cfg.feature_dtype,
                                     refresh_decay=cfg.cache_refresh_decay,
                                     max_refresh_frac=cfg.cache_refresh_frac,
                                     refresh_hysteresis=cfg
                                     .cache_refresh_hysteresis)
        self._sharded = self.cache is not None and hasattr(self.cache,
                                                           "shards")
        self.loader = FeatureLoader(dataset, transfer_dtype=cfg.feature_dtype,
                                    cache=self.cache, dedup=cfg.dedup,
                                    recent_batches=cfg.recent_rows_batches)
        # design-time Eq. 7 overlap estimate: a running prefetcher is
        # assumed to hide the storage stream (the same design assumption
        # TFP makes for the whole load stage); re-pricing uses the
        # measured prefetch hit rate instead, and an overlap drift alone
        # (an underperforming prefetcher with a stable cache rate) also
        # triggers a re-price — see _maybe_refresh_mapping
        self.prefetch_overlap = 1.0 if self.prefetcher is not None else 0.0
        self._model_prefetch_overlap = self.prefetch_overlap
        # async staged refresh: one stage() gather in flight at most
        self._refresh_thread: Optional[threading.Thread] = None
        self._refresh_error: Optional[BaseException] = None
        self._staged_feedback: Optional[Tuple[float, float]] = None
        self._assemble_pallas = (cfg.cache_assemble == "pallas"
                                 or (cfg.cache_assemble == "auto"
                                     and jax.default_backend() == "tpu"))
        if self.cache is not None:
            if fault_injector is not None:
                self.cache.fault_injector = fault_injector
            self.cache.use_pallas_update = self._assemble_pallas
            self.cache.kernel_pipeline_depth = cfg.kernel_pipeline_depth
            # hotness tracking costs two scattered adds per lookup and a
            # 4 B/node estimate array: only pay it when the refresh policy
            # will consume it
            self.cache.track_hotness = cfg.cache_refresh
            # a refresh must retain every device snapshot an in-flight
            # payload can still reference: with TFP depth d at most d
            # batches sit between load (classification) and transfer
            # (combine), and at most one refresh fires per consumed
            # iteration, so d+2 versions always cover the window
            self.cache.keep_versions = max(2, cfg.tfp_depth + 2)
        # out-of-core features (MmapFeatures) gather through host storage,
        # not RAM: Eq. 7 must be priced at storage bandwidth
        self.feature_tier = ("disk" if getattr(self.loader.source,
                                               "is_disk_resident", False)
                             else "ram")
        # measured duplication factor alpha = unique-miss / positional-miss
        # frontier rows, from one probe mini-batch classified against the
        # cache (dedicated sampler + rng so the probe never perturbs the
        # training-path RNG streams: dedup on/off runs stay bit-identical).
        # Only the hybrid task mapping consumes alpha, so accel-only runs
        # skip the probe cost.
        self.measured_dedup_alpha = (
            self._probe_dup_factor() if (cfg.dedup and cfg.hybrid) else 1.0)

        # --- initial task mapping from the performance model (design time) ---
        host = PLATFORMS[cfg.host_platform]
        accel = PLATFORMS[cfg.accel_platform]
        hit_rate = self.cache.expected_hit_rate if self.cache else 0.0
        self._model_hit_rate = hit_rate   # rate the current mapping is priced on
        if cfg.hybrid and cfg.n_accel == 0:
            # CPU-only degenerate case: the model would otherwise assign
            # work to phantom accelerators (their stages cost nothing in
            # Eq. 7/8) and leave the CPU trainer with an empty share
            mapping = {"cpu": cfg.total_batch, "accel_each": 0}
        elif cfg.hybrid:
            mapping = initial_task_mapping(
                host, accel, cfg.n_accel, cfg.total_batch,
                gnn_cfg.fanouts, gnn_cfg.layer_dims, model=gnn_cfg.model,
                cache_hit_rate=hit_rate,
                dedup_factor=self.measured_dedup_alpha,
                feature_tier=self.feature_tier,
                prefetch_overlap=self.prefetch_overlap)
        else:
            mapping = {"cpu": 0,
                       "accel_each": cfg.total_batch // max(cfg.n_accel, 1)}
        thr = cfg.initial_threads or (2, 2, 2)
        assignment = Assignment(
            cpu_batch=mapping["cpu"], accel_batch=mapping["accel_each"],
            n_accel=cfg.n_accel, sample_frac_accel=0.5 if self._dev_topology
            else 0.0,
            threads={"sample": int(thr[0]), "load": int(thr[1]),
                     "train": int(thr[2])})
        self.runtime = Runtime(assignment, use_drm=cfg.use_drm,
                               damping=cfg.drm_damping,
                               share_quantum=cfg.share_quantum)

        # --- model-predictive knob auto-tuning (closes the DRM loop) ---------
        # refresh cadence / admission bookkeeping exists with or without
        # the autotuner: Eq. 7/8 carry the admission term whenever the
        # dynamic cache runs
        self._refresh_period = max(1, int(cfg.cache_refresh_period))
        self._iters_done = 0
        self._iters_since_refresh = 0
        self._refresh_bytes_per_iter = 0.0
        self._hit_decay_per_iter = 0.0
        self._last_load_stats = self.loader.snapshot_stats()
        self._last_windows_touched = int(
            getattr(src, "gather_windows_touched", 0))
        self.autotuner: Optional[KnobAutoTuner] = None
        self._knobs = KnobState(
            prefetch_windows=(cfg.prefetch_windows
                              if self.prefetcher is not None else 0),
            mmap_lru_windows=int(getattr(src, "lru_windows", 0)),
            sample_threads=int(thr[0]), load_threads=int(thr[1]),
            train_threads=int(thr[2]),
            refresh_period=self._refresh_period,
            refresh_frac=float(cfg.cache_refresh_frac))
        if cfg.auto_tune:
            can_prefetch = hasattr(src, "prefetch_rows")
            can_lru = hasattr(src, "lru_windows")
            lru0 = self._knobs.mmap_lru_windows
            refresh_on = cfg.cache_refresh and self.cache is not None
            bounds = KnobBounds(
                prefetch_windows=(0, 64) if can_prefetch else (0, 0),
                # lru == 0 means unbounded: the search may bound it, but
                # never below one window
                mmap_lru_windows=(1, 4096) if can_lru else (lru0, lru0),
                min_stage_threads=1,
                total_threads=self._knobs.total_threads,
                refresh_period=((1, 16) if refresh_on
                                else (self._refresh_period,
                                      self._refresh_period)),
                refresh_frac=((0.05, 0.5) if refresh_on
                              else (self._knobs.refresh_frac,
                                    self._knobs.refresh_frac)))
            self.autotuner = KnobAutoTuner(
                self.runtime.drm, bounds,
                interval=cfg.autotune_interval,
                hysteresis=cfg.autotune_hysteresis,
                min_gain=cfg.autotune_min_gain,
                warmup_windows=cfg.autotune_warmup_windows)

        # --- jit'd gradient function (shared across trainers/devices) --------
        def _grad(params, batch: MiniBatch, x0):
            (loss, acc), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, gnn_cfg, batch, x0)
            return grads, {"loss": loss, "acc": acc}

        self._grad_jit = jax.jit(_grad)
        self.history: List[IterationMetrics] = []
        self._ckpt_cb: Optional[Callable[[int, PyTree, PyTree], None]] = None

    # ------------------------------------------------------------ utilities

    def _build_prefetcher(self, windows: int) -> Optional[WindowPrefetcher]:
        """Construct the background window prefetcher (or None when the
        knob is off / the source cannot page-fault).  Shared by __init__
        and the knob autotuner's prefetch_windows moves."""
        src = self.dataset.feature_source
        if windows <= 0 or not hasattr(src, "prefetch_rows"):
            return None
        return WindowPrefetcher(
            src, max_queue=int(windows),
            dedup_history=self.cfg.prefetch_dedup_history,
            restart_budget=self.cfg.prefetch_restart_budget,
            raise_on_failure=not self.cfg.degrade_on_failure,
            fault_injector=self.fault_injector)

    def _probe_dup_factor(self) -> float:
        """Measure alpha = unique-miss / positional-miss frontier rows from
        one probe mini-batch at the accel-only share (the transfer-path
        batch size Eq. 7/8 price).  The probe frontier is classified
        against the device cache exactly like the transfer path: hub ids
        are both the most-cached and the most-duplicated, so the naive
        unique/total ratio would double-count the overlap the model's
        (1 - h) cache term already removed (the definition
        ``_maybe_refresh_mapping`` uses at runtime — both mappings price
        the same alpha for the same traffic).  Uses a throwaway
        sampler/rng so training RNG streams are untouched."""
        probe_n = max(1, self.cfg.total_batch // max(self.cfg.n_accel, 1))
        rng = np.random.default_rng(self.cfg.seed + 17)
        tgt = rng.integers(0, self.dataset.num_nodes, probe_n)
        sampler = NumpySampler(self.dataset.graph, self.gnn_cfg.fanouts,
                               seed=self.cfg.seed + 17)
        mb = sampler.sample(tgt, self.dataset.labels[tgt])
        frontier = np.asarray(mb.frontier(len(self.gnn_cfg.fanouts)))
        look = compact_lookup(
            frontier, self.cache.slot_of if self.cache is not None else None)
        if look.miss_positions == 0:      # fully cached probe: no traffic
            return 1.0
        return look.num_miss / look.miss_positions

    def inject_failure(self, trainer_name: str, at_iteration: int) -> None:
        """Fault-tolerance test hook: trainer dies at the given iteration."""
        self._fail_at[trainer_name] = at_iteration

    def set_checkpoint_callback(self, cb) -> None:
        self._ckpt_cb = cb

    def _next_targets(self, n: int) -> np.ndarray:
        if self._cursor + n > len(self._epoch_perm):
            self._epoch_perm = self._rng.permutation(self.dataset.num_nodes)
            self._cursor = 0
        out = self._epoch_perm[self._cursor:self._cursor + n]
        self._cursor += n
        return out

    def _active_trainers(self) -> List[Tuple[str, str]]:
        """[(name, kind)] excluding failed trainers."""
        out = []
        cpu_b, accel_b = self.runtime.quantized_shares()
        with self._state_lock:
            failed = set(self._failed)
        if cpu_b > 0 and "cpu" not in failed:
            out.append(("cpu", "cpu"))
        for i in range(self.cfg.n_accel):
            name = f"accel{i}"
            if name not in failed and accel_b > 0:
                out.append((name, "accel"))
        return out

    # ------------------------------------------------------- pipeline stages

    def _make_payload(self, it: int) -> PipelineItem:
        cpu_b, accel_b = self.runtime.quantized_shares()
        shares: Dict[str, int] = {}
        for name, kind in self._active_trainers():
            shares[name] = cpu_b if kind == "cpu" else accel_b
        payload = {"iteration": it, "shares": shares, "targets": {},
                   "minibatch": {}, "features": {}, "t": {}}
        for name, n in shares.items():
            payload["targets"][name] = self._next_targets(n)
        return PipelineItem(seq=it, payload=payload)

    def _stage_sample(self, item: PipelineItem) -> PipelineItem:
        p = item.payload
        frac = self.runtime.assignment.sample_frac_accel
        names = list(p["targets"].keys())
        n_accel_sampled = (int(round(frac * len(names)))
                           if self._dev_topology is not None else 0)
        t_sc = t_sa = 0.0
        for i, name in enumerate(names):
            tgt = p["targets"][name]
            labels = self.dataset.labels[tgt]
            t0 = time.perf_counter()
            if i < n_accel_sampled:
                self._sample_key, sub = jax.random.split(self._sample_key)
                mb = self._jax_sample(sub, *self._dev_topology,
                                      jnp.asarray(tgt), jnp.asarray(labels))
                mb = jax.block_until_ready(mb)
                t_sa += time.perf_counter() - t0
            else:
                mb = self.cpu_sampler.sample(tgt, labels)
                t_sc += time.perf_counter() - t0
            p["minibatch"][name] = mb
        p["t"]["t_sc"], p["t"]["t_sa"] = t_sc, t_sa
        # TFP lookahead -> background storage I/O: this batch's frontier
        # is known here, one pipeline stage BEFORE its load-stage gather
        # runs, so hand the ids the gather will actually touch (unique,
        # minus rows the device cache will serve) to the window
        # prefetcher.  By the time _stage_load reaches this batch its
        # mmap windows are warm and the gather never blocks on cold disk
        # reads.  submit() never blocks (full queue = drop).  Failure
        # handling depends on degrade_on_failure: legacy fail-fast raises
        # here (surfacing through the pipeline's stage-failure protocol);
        # under degradation a worker that died past its restart budget
        # just stops being fed — loads fall back to synchronous (cold)
        # gathers, the overlap term re-prices to 0, and health() reports
        # the component.
        # snapshot the prefetcher reference: the knob autotuner may swap
        # or drop it from the training thread while this stage runs in a
        # pipeline thread (submit() on a closed prefetcher safely drops)
        pf = self.prefetcher
        if pf is not None and p["minibatch"] and not pf.failed:
            depth = len(self.gnn_cfg.fanouts)
            parts = []
            for name, mb in p["minibatch"].items():
                ids = np.unique(np.asarray(mb.frontier(depth)))
                # the device cache only serves accelerator trainers (the
                # CPU trainer reads its FULL frontier from the source),
                # so only accel frontiers drop their cache-hit rows
                if name != "cpu" and self.cache is not None:
                    ids = ids[self.cache.slot_of[ids] < 0]
                parts.append(ids)
            pf.submit(np.unique(np.concatenate(parts)))
            if pf.failed:
                self._note_degraded(
                    "prefetcher",
                    pf.errors[0] if pf.errors else None,
                    action="window prefetch disabled; loads run "
                           "synchronously and prefetch_overlap re-prices "
                           "to 0")
        return item

    def _stage_load(self, item: PipelineItem) -> PipelineItem:
        p = item.payload
        self.loader.num_threads = self.runtime.assignment.threads.get("load", 1)
        t0 = time.perf_counter()
        stall0 = self.loader.stats.stall_seconds \
            + self.loader.host_stats.stall_seconds
        # sharded plane: ONE union lookup + host gather covers every
        # accelerator trainer of this batch (each unique miss row is
        # gathered/shipped once and multicast to the devices needing it)
        accel_mbs = {n: mb for n, mb in p["minibatch"].items() if n != "cpu"}
        if self._sharded and accel_mbs:
            ordinals = {n: int(n[len("accel"):]) for n in accel_mbs}
            p["features"].update(
                self.loader.load_union(accel_mbs, ordinals, pin=True))
        for name, mb in p["minibatch"].items():
            if self._sharded and name != "cpu":
                continue      # served by the union gather above
            # accelerator trainers get the compact transfer path (unique
            # miss rows against the on-device hot cache, or plain unique
            # rows when uncached); the CPU trainer's "device" is host
            # memory, so it reads the full positional frontier straight
            # from the FeatureSource and nothing crosses an interconnect.
            if name != "cpu" and (self.cache is not None or self.cfg.dedup):
                # pin the classification version while the block is in
                # flight: the transfer stage releases it after the
                # combine, so drained versions retire device blocks
                # eagerly instead of aging out of keep_versions
                p["features"][name] = self.loader.load_compact(
                    mb, pin=self.cache is not None,
                    recent_key=(name if self.cfg.recent_rows_batches > 0
                                else None))
            else:
                p["features"][name] = self.loader.load(
                    mb, to_device=(name != "cpu"))
        p["t"]["t_load"] = time.perf_counter() - t0
        # storage-I/O stall share of the load stage (cold mmap faults the
        # prefetcher did not hide) — DRM-visible via StageTimes
        p["t"]["t_load_stall"] = (self.loader.stats.stall_seconds
                                  + self.loader.host_stats.stall_seconds
                                  - stall0)
        return item

    def _assemble(self, block: MissBlock, dev) -> jax.Array:
        """Ship the unique-miss rows + index tables; combine with the
        cached rows and expand back into the dense positional layer-0
        input on the destination device (the on-device duplication step).

        The unique-miss count varies per mini-batch, so the block is
        padded up to a 128-row bucket: the jit'd combine sees a handful of
        distinct shapes instead of one per iteration (sampling noise moves
        the unique-miss count by far less than a bucket), while padding
        waste stays bounded by the bucket size.  Padding rows are zeros no
        miss_index entry points at, and they are charged to the
        shipped-byte stats.
        """
        look = block.lookup
        rows = block.rows
        m = rows.shape[0]
        # never pad beyond the frontier size: the bucket must stay strictly
        # cheaper than the legacy full-frontier transfer
        bucket = min(-(-m // 128) * 128, look.num_rows)
        if m < bucket:
            pad = bucket - m
            rows = np.concatenate(
                [rows, np.zeros((pad, rows.shape[1]), rows.dtype)], 0)
            # padding rows cross PCIe too: keep the shipped-byte stats honest
            self.loader.note_transfer_padding(
                pad, pad * rows.shape[1] * rows.dtype.itemsize)
        miss = jax.device_put(rows, dev)
        if block.shipped is not None:
            # publish the device-resident rows for the recent-rows LRU:
            # a later batch's load stage plans against the ids/version
            # (already registered at load time); only the transfer stage
            # — strictly in pipeline order — reads this array, so the
            # single-writer fill is race-free.  Padding rows sit past
            # every recent index (< len(shipped.ids)).
            block.shipped.array = miss
        if block.recent:
            # rows still resident from recent batches: re-gather them on
            # the device instead of re-shipping over PCIe, and lay them
            # out ahead of the fresh block ([recent segments | fresh] —
            # the combined layout load_compact's miss_index addresses)
            segs = [jnp.take(e.array, jnp.asarray(idx), axis=0)
                    for e, idx in block.recent]
            miss = jnp.concatenate(segs + [miss], axis=0)
        # pin the combine to the cache version the lookup was classified
        # against: a dynamic refresh between _stage_load and here must not
        # re-bind the slot indices to a newer (reshuffled) device block
        cache_data = (self.cache.data_on(dev, version=look.version)
                      if self.cache else None)
        if self.cache is not None:
            # the combine holds its own reference to the version block;
            # releasing the pin here lets a fully-drained old version
            # retire its [K, F] snapshots immediately
            self.cache.release_lookup(look)
        # slots / miss_index stay host numpy: the Pallas path derives its
        # DMA schedule from them before they ever reach the device
        return assemble_features(cache_data, miss, look.slots,
                                 look.miss_index,
                                 use_pallas=self._assemble_pallas,
                                 pipeline_depth=self.cfg
                                 .kernel_pipeline_depth)

    def _assemble_sharded(self, block: ShardMissBlock, dev) -> jax.Array:
        """Sharded-plane combine: the dense layer-0 input is assembled
        from the LOCAL shard block (slot hits), rows pulled from peer
        shards over the ICI (ring order), and the fresh host rows the
        union gather shipped — the combined transfer source layout
        ``[peer rows | fresh rows]`` the union lookup's miss_index
        addresses.  Every shard block is resolved at the version the
        lookup pinned, so refreshes mid-pipeline stay bit-invisible."""
        sl = block.shard
        look = block.lookup
        rows = block.rows
        m = rows.shape[0]
        bucket = min(-(-m // 128) * 128, max(look.num_rows, 1))
        if m < bucket:
            pad = bucket - m
            rows = np.concatenate(
                [rows, np.zeros((pad, rows.shape[1]), rows.dtype)], 0)
            self.loader.note_transfer_padding(
                pad, pad * rows.shape[1] * rows.dtype.itemsize)
        miss = jax.device_put(rows, dev)
        me = sl.shard
        local = self.cache.shards[me].data_on(dev, version=look.version)
        # pull peer rows: gather on the owner's device at the pinned
        # version, ship only the requested rows here (the ICI hop)
        peers = exchange_peer_rows(
            sl.peer_requests,
            lambda p, v: self.cache.shards[p].data_on(
                self._accel_device(f"accel{p}"), version=v),
            dev, use_pallas=self._assemble_pallas,
            pipeline_depth=self.cfg.kernel_pipeline_depth)
        x = assemble_features_sharded(local, peers + [miss], look.slots,
                                      look.miss_index,
                                      use_pallas=self._assemble_pallas,
                                      pipeline_depth=self.cfg
                                      .kernel_pipeline_depth)
        # combine + peer gathers hold their own block references: release
        # every shard pin so drained versions retire eagerly
        self.cache.release_union(sl)
        return x

    def _accel_device(self, name: str):
        """Device of accelerator trainer ``name`` ("accelN" -> ordinal N).

        Indexed by the trainer's own ordinal, not its position in the
        active-trainer list: that list starts with the CPU trainer when it
        is active, which used to shift every accelerator onto its
        neighbour's device.
        """
        ordinal = int(name[len("accel"):])
        return self.accel_devices[ordinal % max(len(self.accel_devices), 1)]

    def _stage_transfer(self, item: PipelineItem) -> PipelineItem:
        p = item.payload
        t0 = time.perf_counter()
        # iterate the payload's own trainer set, not _active_trainers():
        # with TFP prefetch in flight the DRM may have re-quantized a
        # share to 0 since this batch was sampled — the batch still
        # belongs to the trainers it was sampled for
        with self._state_lock:
            failed = set(self._failed)
        for name in list(p["features"]):
            if name in failed:
                continue
            kind = "cpu" if name == "cpu" else "accel"
            dev = (self.cpu_device if kind == "cpu"
                   else self._accel_device(name))
            feat = p["features"][name]
            if isinstance(feat, ShardMissBlock):
                x = self._assemble_sharded(feat, dev)
            elif isinstance(feat, MissBlock):
                x = self._assemble(feat, dev)
            else:
                x = jax.device_put(feat, dev)
            mb = jax.device_put(p["minibatch"][name], dev)
            p["features"][name] = x
            p["minibatch"][name] = mb
        jax.block_until_ready([p["features"][n] for n in p["features"]])
        p["t"]["t_tran"] = time.perf_counter() - t0
        return item

    # ------------------------------------------------------------- training

    def _run_trainers(self, item: PipelineItem
                      ) -> Tuple[PyTree, Dict[str, float], Dict[str, float]]:
        p = item.payload
        # the payload records which trainers this batch was sampled for
        # (and their shares at sampling time); run exactly those, minus
        # any that have since failed.  Intersecting with the *current*
        # assignment instead can come up empty when the DRM re-quantizes
        # a share to 0 while prefetched batches are still in flight.
        with self._state_lock:
            failed = set(self._failed)
        active = [(n, "cpu" if n == "cpu" else "accel")
                  for n in p["minibatch"] if n not in failed]
        if not active:        # every trainer of this batch has died
            zero = jax.tree.map(jnp.zeros_like, self.params)
            return (zero, {"t_tc": 0.0, "t_ta": 0.0},
                    {"loss": float("nan"), "acc": float("nan")})
        sync = Synchronizer(len(active))
        results: Dict[str, Dict[str, Any]] = {}

        def work(idx: int, name: str, kind: str):
            if self._fail_at.get(name) == p["iteration"]:
                with self._state_lock:
                    self._failed.add(name)
                zero = jax.tree.map(jnp.zeros_like, self.params)
                sync.submit(idx, zero, 0.0)     # dead trainer: zero weight
                results[name] = {"loss": jnp.nan, "acc": jnp.nan,
                                 "t_train": 0.0, "failed": True}
                return
            handle = TrainerHandle(name=name, kind=kind, device=None,
                                   grad_fn=self._grad_jit, index=idx)
            weight = float(p["shares"][name])
            metrics = handle.run(sync, self.params, weight,
                                 p["minibatch"][name], p["features"][name])
            results[name] = metrics

        threads = [threading.Thread(target=work, args=(i, n, k))
                   for i, (n, k) in enumerate(active)]
        for t in threads:
            t.start()
        avg = sync.all_reduce()
        for t in threads:
            t.join()

        # stage-time bookkeeping for the DRM engine
        t_tc = max((m["t_train"] for n, m in results.items()
                    if n == "cpu"), default=0.0)
        t_ta = max((m["t_train"] for n, m in results.items()
                    if n != "cpu"), default=0.0)
        ok = {n: m for n, m in results.items() if not m.get("failed")}
        w = {n: float(p["shares"][n]) for n in ok}
        wsum = max(sum(w.values()), 1e-9)
        loss = float(sum(float(m["loss"]) * w[n] for n, m in ok.items()) / wsum)
        acc = float(sum(float(m["acc"]) * w[n] for n, m in ok.items()) / wsum)
        return avg, {"t_tc": t_tc, "t_ta": t_ta}, {"loss": loss, "acc": acc}

    def _window_alpha(self, stats) -> float:
        """Eq. 7/8 alpha from measured window stats: unique-miss /
        positional-miss rows (hub ids are both the most-cached and the
        most-duplicated, so the naive unique/total ratio would
        double-count the overlap the model's (1 - h) cache term already
        removed)."""
        miss_positions = stats.total_rows - stats.hit_rows
        if not (self.cfg.dedup and miss_positions > 0):
            return 1.0
        dedup_saved_rows = stats.dedup_saved_bytes // self.cache.row_bytes
        return 1.0 - dedup_saved_rows / miss_positions

    def _measured_prefetch_overlap(self) -> float:
        """Eq. 7 overlap term from measurement: the fraction of load-stage
        window touches the background prefetcher served warm (falls back
        to the design-time estimate before any disk-tier traffic)."""
        if self.prefetcher is None:
            return 0.0
        if self.prefetcher.failed:
            # a dead prefetcher hides nothing: every future disk touch is
            # a cold fault, so the mapping must price the full storage
            # penalty (this is what drives the re-price-to-0 on failure)
            return 0.0
        src = self.loader.source
        touches = (getattr(src, "prefetch_hit_windows", 0)
                   + getattr(src, "prefetch_miss_windows", 0))
        if touches == 0:
            return self.prefetch_overlap
        return float(src.prefetch_hit_rate)

    def _sharded_pricing(self, measured: float) -> Tuple[float, float, float]:
        """Split the measured hit rate into (local, peer) components and
        derive the union multicast factor from window stats — the
        sharded-plane Eq. 7/8 terms.  The window's ``hit_rate`` counts
        local AND peer-served positions (neither touches the host), so
        the model's ``cache_hit_rate`` gets only the local share."""
        if not self._sharded:
            return measured, 0.0, 1.0
        win = self.loader.window
        if win.total_rows == 0:
            return measured, 0.0, 1.0
        rb = self.cache.row_bytes
        peer = (win.peer_saved_bytes / rb) / win.total_rows
        shipped = win.bytes - win.padding_bytes
        denom = shipped + win.union_saved_bytes
        uf = shipped / denom if denom > 0 else 1.0
        return max(measured - peer, 0.0), peer, uf

    def _reprice_mapping(self, measured: float, alpha: float) -> None:
        """Re-run the initial task mapping with a measured hit rate +
        alpha and hand the refreshed shares to the runtime (the DRM keeps
        fine-tuning from there)."""
        overlap = self._measured_prefetch_overlap()
        local, peer, uf = self._sharded_pricing(measured)
        mapping = initial_task_mapping(
            PLATFORMS[self.cfg.host_platform],
            PLATFORMS[self.cfg.accel_platform],
            self.cfg.n_accel, self.cfg.total_batch,
            self.gnn_cfg.fanouts, self.gnn_cfg.layer_dims,
            model=self.gnn_cfg.model, cache_hit_rate=local,
            dedup_factor=alpha, feature_tier=self.feature_tier,
            prefetch_overlap=overlap, peer_hit_rate=peer,
            union_factor=uf,
            refresh_bytes_per_iter=self._refresh_bytes_per_iter)
        self._model_prefetch_overlap = overlap
        a = self.runtime.assignment
        n = max(self.cfg.n_accel, 1)
        a.accel_batch = mapping["accel_each"]
        a.cpu_batch = self.cfg.total_batch - a.accel_batch * n
        self._model_hit_rate = measured
        self.measured_dedup_alpha = alpha

    def _maybe_refresh_cache(self) -> bool:
        """Dynamic cache refresh on the drift signal (tentpole of the
        refresh subsystem): when the *windowed* measured hit rate drifts
        past ``cache_drift_threshold`` from the rate the mapping was
        priced with — the same signal ``_maybe_refresh_mapping`` acts on —
        the static snapshot no longer matches the observed access
        distribution, so swap the coldest slots for the hottest observed
        uncached nodes.  When rows actually move the mapping is re-priced
        *immediately* on the drifted (pre-refresh) measurement — under
        sustained drift the window resets every refresh, so deferring the
        re-price to ``_maybe_refresh_mapping`` would starve it forever —
        and then the measurement window resets so subsequent feedback
        sees only post-refresh traffic.  Returns True when the refresh
        moved rows.
        """
        if self.cache is None or not self.cfg.cache_refresh \
                or self._refresh_disabled:
            return False
        if self.cfg.async_refresh:
            return self._async_refresh_step()
        win = self.loader.window
        if win.total_rows == 0:
            return False
        measured = win.hit_rate
        if abs(measured - self._model_hit_rate) <= \
                self.cfg.cache_drift_threshold:
            return False
        try:
            swapped = self.cache.refresh()
        except Exception as e:
            # degraded mode: keep serving the current cache version and
            # retry at the next drift boundary (bounded by the budget)
            self._handle_refresh_failure(e)
            return False
        self._refresh_failures = 0
        self._finish_refresh(swapped, measured, self._window_alpha(win))
        return swapped > 0

    def _handle_refresh_failure(self, err: BaseException,
                                context: Optional[str] = None) -> None:
        """Shared refresh-failure protocol (sync and async paths): discard
        any staged plan (the current cache version keeps serving), count
        the consecutive failure, and either re-raise (legacy fail-fast,
        ``degrade_on_failure=False``) or degrade — retry at the next
        drift boundary until ``refresh_failure_budget`` consecutive
        failures disable dynamic refresh for the rest of the run."""
        self._refresh_failures += 1
        if self.cache is not None:
            self.cache.discard_staged()
        if not self.cfg.degrade_on_failure:
            if context is not None:
                raise RuntimeError(context) from err
            raise err
        if self._refresh_failures >= self.cfg.refresh_failure_budget \
                and not self._refresh_disabled:
            self._refresh_disabled = True
            self._note_degraded(
                "refresh", err,
                action=f"dynamic cache refresh disabled after "
                       f"{self._refresh_failures} consecutive stage "
                       f"failures; serving cache version "
                       f"{self.cache.version if self.cache else 0}")

    def _finish_refresh(self, swapped: int, measured: float,
                        alpha: float) -> None:
        """Post-refresh bookkeeping shared by the sync and async paths:
        re-price the mapping (or anchor the drift signal) and reset the
        measurement window when rows moved."""
        with self._state_lock:
            any_failed = bool(self._failed)
        reprice = (self.cfg.hybrid and self.cfg.n_accel > 0
                   and not any_failed)
        if swapped:
            # Eq. 7/8 admission term + staleness signal, both measured:
            # the swapped rows crossed host->device once, amortized over
            # the iterations since the previous refresh; the hit-rate gap
            # the refresh just closed, per iteration, is how fast the
            # cached set goes stale at the current cadence
            iters = max(self._iters_since_refresh, 1)
            self._refresh_bytes_per_iter = (
                swapped * self.cache.row_bytes / iters)
            self._hit_decay_per_iter = (
                max(self._model_hit_rate - measured, 0.0) / iters)
            self._iters_since_refresh = 0
            if reprice:
                self._reprice_mapping(measured, alpha)
            else:
                # accel-only (or degenerate) runs have no mapping to
                # re-price; still anchor the drift signal on the measured
                # rate so a converged cache stops re-triggering
                self._model_hit_rate = measured
            self.loader.reset_window()
        elif not reprice:
            # nothing was hotter uncached: the cache already matches the
            # observed distribution, so anchor the drift signal here too —
            # otherwise the armed signal re-runs the O(num_nodes) candidate
            # scan every iteration forever.  Hybrid runs skip this: the
            # mapping feedback (called right after) must still see the
            # drift, and its re-price anchors the same signal.
            self._model_hit_rate = measured

    def _async_refresh_step(self) -> bool:
        """One iteration-boundary step of the staged (off-critical-path)
        refresh.  State machine:

          idle + drift       -> snapshot the drifted measurement, kick the
                                expensive ``stage()`` gather in a
                                background thread, return (no stall);
          stage in flight    -> return (the boundary pays nothing);
          stage finished     -> ``commit()`` (cheap table/device swap) and
                                run the usual post-refresh bookkeeping on
                                the measurement snapshotted at stage time.

        Losses are bit-identical to the sync path (and to refresh off):
        whatever iteration the commit lands on, in-flight TFP payloads
        combine against the cache version their lookup was classified at.
        """
        t = self._refresh_thread
        if t is not None:
            if t.is_alive():
                return False
            self._refresh_thread = None
            with self._state_lock:
                err, self._refresh_error = self._refresh_error, None
            if err is not None:
                self._staged_feedback = None
                self._handle_refresh_failure(
                    err, context="async cache-refresh stage() failed")
                return False
            measured, alpha = self._staged_feedback
            self._staged_feedback = None
            swapped = self.cache.commit()
            self._refresh_failures = 0
            self._finish_refresh(swapped, measured, alpha)
            return swapped > 0
        win = self.loader.window
        if win.total_rows == 0:
            return False
        measured = win.hit_rate
        if abs(measured - self._model_hit_rate) <= \
                self.cfg.cache_drift_threshold:
            return False
        self._staged_feedback = (measured, self._window_alpha(win))

        def run_stage():
            try:
                self.cache.stage()
            except BaseException as e:  # surfaced at the next boundary
                with self._state_lock:
                    self._refresh_error = e

        self._refresh_thread = threading.Thread(
            target=run_stage, daemon=True, name="cache-refresh-stage")
        self._refresh_thread.start()
        return False

    def _maybe_refresh_mapping(self) -> bool:
        """Measured-hit-rate feedback into the perf model (ROADMAP item).

        Eq. 7/8 were priced with the design-time ``expected_hit_rate``;
        when the loader's *measured* transfer-path hit rate drifts more
        than ``cache_drift_threshold`` from the rate the current mapping
        used, re-run ``initial_task_mapping`` with the measured rate (and
        measured duplication factor) and hand the refreshed shares to the
        runtime.  The DRM keeps fine-tuning from the refreshed point.
        The measurement is the post-refresh *window*, not the lifetime
        average: a dynamic cache refresh resets the window, so the mapping
        is re-priced on the rate the refreshed cache actually serves.
        The measured prefetch overlap carries its own drift trigger: an
        underperforming prefetcher (queue-full drops, windows evicted
        before their gather) must re-price the storage penalty even when
        the cache hit rate sits rock-stable inside its threshold.
        Returns True when a refresh happened.
        """
        with self._state_lock:
            any_failed = bool(self._failed)
        if not (self.cfg.hybrid and self.cache is not None) or any_failed:
            return False
        stats = self.loader.window
        if stats.total_rows == 0:
            return False
        measured = stats.hit_rate
        hit_drift = abs(measured - self._model_hit_rate) > \
            self.cfg.cache_drift_threshold
        overlap_drift = (
            self.prefetcher is not None
            and abs(self._measured_prefetch_overlap()
                    - self._model_prefetch_overlap)
            > self.cfg.cache_drift_threshold)
        if not (hit_drift or overlap_drift):
            return False
        self._reprice_mapping(measured, self._window_alpha(stats))
        return True

    # ------------------------------------------- model-predictive knob loop

    def _build_knob_model(self, mean_times: StageTimes,
                          iters: int) -> CalibratedKnobModel:
        """Calibrate the Eq. 7/8 knob model on one measured window: the
        mean stage times anchor the model at the CURRENT knob state, and
        the measured traffic signals (dup factor, prefetch hit/drop
        rates, touched windows, refresh admission, hit-rate decay) let
        ``predict`` re-price only the knob-sensitive components."""
        src = self.loader.source
        cum = self.loader.snapshot_stats()
        prev = self._last_load_stats
        self._last_load_stats = cum
        d_total = max(cum.total_rows - prev.total_rows, 0)
        d_unique = max(cum.unique_rows - prev.unique_rows, 1)
        d_hit = max(cum.hit_rows - prev.hit_rows, 0)
        wt = int(getattr(src, "gather_windows_touched", 0))
        d_windows = max(wt - self._last_windows_touched, 0)
        self._last_windows_touched = wt
        pf = self.prefetcher
        drop_rate = 0.0
        if pf is not None and pf.submitted + pf.dropped > 0:
            drop_rate = pf.dropped / (pf.submitted + pf.dropped)
        row_bytes = (self.cache.row_bytes if self.cache is not None
                     else self.dataset.feat_dim * 4)
        return CalibratedKnobModel(
            host=PLATFORMS[self.cfg.host_platform],
            accel=PLATFORMS[self.cfg.accel_platform],
            ref=self._knobs,
            signals=SignalSnapshot(
                t_sc=mean_times.t_sc, t_sa=mean_times.t_sa,
                t_load=mean_times.t_load,
                t_load_stall=mean_times.t_load_stall,
                t_tran=mean_times.t_tran, t_tc=mean_times.t_tc,
                t_ta=mean_times.t_ta,
                dup_factor=(d_total / d_unique if d_total else 1.0),
                hit_rate=(d_hit / d_total if d_total else 0.0),
                prefetch_hit_rate=self._measured_prefetch_overlap(),
                prefetch_drop_rate=drop_rate,
                touched_windows=max(d_windows // max(iters, 1), 1),
                loaded_rows_per_iter=d_unique / max(iters, 1),
                refresh_bytes_per_iter=self._refresh_bytes_per_iter,
                hit_decay_per_iter=self._hit_decay_per_iter,
                row_bytes=int(row_bytes),
                disk_tier=(self.feature_tier == "disk")))

    def _apply_knobs(self, k: KnobState) -> None:
        """Apply one accepted (or rolled-back) knob state through the
        existing machinery: stage threads via the assignment (the loader
        pool rebuilds on its next gather), prefetch queue via
        resize/rebuild/close, window LRU via the source's immediate
        trim, refresh cadence/fraction via the boundary gate and the
        cache's admission bound.  Deliberately never touches workload
        shares, RNG streams or batch composition — losses must stay
        bit-identical to a static-knob run."""
        prev, self._knobs = self._knobs, k
        a = self.runtime.assignment
        a.threads["sample"] = k.sample_threads
        a.threads["load"] = k.load_threads
        a.threads["train"] = k.train_threads
        src = self.loader.source
        if k.mmap_lru_windows != prev.mmap_lru_windows:
            if hasattr(src, "set_lru_windows"):
                src.set_lru_windows(k.mmap_lru_windows)
            elif hasattr(src, "lru_windows"):
                src.lru_windows = int(k.mmap_lru_windows)
        if k.prefetch_windows != prev.prefetch_windows:
            with self._state_lock:
                pf_dead = "prefetcher" in self._degraded
            if k.prefetch_windows <= 0:
                pf, self.prefetcher = self.prefetcher, None
                if pf is not None:
                    pf.close()
            elif self.prefetcher is not None:
                self.prefetcher.resize(k.prefetch_windows)
            elif not pf_dead:
                self.prefetcher = self._build_prefetcher(k.prefetch_windows)
        self._refresh_period = max(1, k.refresh_period)
        if (self.cache is not None
                and k.refresh_frac != prev.refresh_frac):
            shards = self.cache.shards if self._sharded else [self.cache]
            for sh in shards:
                sh.max_refresh_frac = float(k.refresh_frac)

    def _maybe_autotune(self, times: StageTimes) -> None:
        """One iteration-boundary step of the knob autotuner: feed the
        measured StageTimes; when a window closes the tuner may hand back
        a knob state to apply — a new trial move, or the exact pre-move
        state of a trial whose measured iteration time regressed past the
        hysteresis band (rollback)."""
        if self.autotuner is None:
            return
        nxt = self.autotuner.step(times, self._build_knob_model,
                                  self._knobs)
        if nxt is not None:
            self._apply_knobs(nxt)

    def autotune_report(self) -> Dict[str, Any]:
        """Autotuner trajectory + the knob state it converged to."""
        out: Dict[str, Any] = {
            "enabled": self.autotuner is not None,
            "knobs": dataclasses.asdict(self._knobs),
        }
        if self.autotuner is not None:
            out.update(self.autotuner.report())
        return out

    def _apply_update(self, grads: PyTree) -> float:
        t0 = time.perf_counter()
        if self.compression.method != "none":
            comp = compress_grads(grads, self.compression)
            grads = decompress_grads(comp, self.compression, self.params)
        updates, self.opt_state = self.optimizer.update(
            grads, self.opt_state, self.params)
        self.params = apply_updates(self.params, updates)
        jax.block_until_ready(self.params)
        return time.perf_counter() - t0

    # ----------------------------------------------------------------- train

    def train(self, num_iterations: int) -> List[IterationMetrics]:
        stages = [Stage("sample", self._stage_sample),
                  Stage("load", self._stage_load),
                  Stage("transfer", self._stage_transfer)]
        pipe = PrefetchPipeline(
            stages, depth=self.cfg.tfp_depth,
            watchdog_seconds=self.cfg.pipeline_watchdog_seconds,
            fault_injector=self.fault_injector)
        payloads = (self._make_payload(i) for i in range(num_iterations))

        for item in pipe.run(payloads):
            p = item.payload
            grads, ttimes, metrics = self._run_trainers(item)
            t_sync = self._apply_update(grads)
            times = StageTimes(
                t_sa=p["t"].get("t_sa", 0.0), t_sc=p["t"].get("t_sc", 0.0),
                t_load=p["t"].get("t_load", 0.0),
                t_tran=p["t"].get("t_tran", 0.0),
                t_tc=ttimes["t_tc"], t_ta=ttimes["t_ta"],
                t_load_stall=p["t"].get("t_load_stall", 0.0))
            # account for failures: drop trainers, DRM rebalances the rest
            with self._state_lock:
                failed = set(self._failed)
            if failed:
                a = self.runtime.assignment
                dead_accel = sum(1 for n in failed if n != "cpu")
                if dead_accel and a.n_accel > self.cfg.n_accel - dead_accel:
                    a.cpu_batch += a.accel_batch * dead_accel
                    a.n_accel = self.cfg.n_accel - dead_accel
                # a dead trainer's recent-rows history will never be
                # matched (or filled) again: free it
                for n in failed:
                    self.loader.drop_recent(n)
            self.runtime.end_iteration(times)
            self._iters_done += 1
            self._iters_since_refresh += 1
            # refresh the cache first: when it moves rows it resets the
            # measurement window, so the mapping re-price (next iterations)
            # sees the post-refresh rate instead of a stale average.  The
            # cadence knob gates how often the drift check runs at all
            # (legacy period 1 = every boundary).
            if self._iters_done % self._refresh_period == 0:
                self._maybe_refresh_cache()
            self._maybe_refresh_mapping()
            self._maybe_autotune(times)
            edges = sum(mb.edges_traversed()
                        for mb in p["minibatch"].values())
            m = IterationMetrics(
                iteration=p["iteration"], loss=metrics["loss"],
                acc=metrics["acc"], times=times, t_sync=t_sync, edges=edges,
                assignment=self.runtime.quantized_shares(),
                cache_hit_rate=(self.cache.measured_hit_rate()
                                if self.cache else 0.0),
                cache_version=self.cache.version if self.cache else 0)
            self.history.append(m)
            if (self.cfg.ckpt_every and self._ckpt_cb
                    and (p["iteration"] + 1) % self.cfg.ckpt_every == 0):
                self._ckpt_cb(p["iteration"], self.params, self.opt_state)
        # a background failure after the last iteration boundary (final
        # staged gather, final prefetch) would otherwise vanish
        self._raise_background_errors()
        return self.history

    def _raise_background_errors(self) -> None:
        """Surface latched background-I/O failures — a prefetch worker or
        an async ``stage()`` gather that died after its last chance to
        raise in-line (e.g. during the final iterations).  Called at the
        end of ``train()`` and by ``close()``.  Legacy fail-fast mode
        raises (a broken storage tier must never fail silently); in
        degraded mode (``degrade_on_failure=True``) the failures are
        consumed into the ``health()`` record instead — the advisory
        subsystems already degraded, the run is complete, and the state
        is visible rather than fatal."""
        if (self._refresh_thread is None
                or not self._refresh_thread.is_alive()):
            self._refresh_thread = None
            with self._state_lock:
                err, self._refresh_error = self._refresh_error, None
            if err is not None:
                self._handle_refresh_failure(
                    err, context="async cache-refresh stage() failed")
        if self.prefetcher is not None and self.prefetcher.error is not None:
            if not self.cfg.degrade_on_failure:
                err, self.prefetcher.error = self.prefetcher.error, None
                raise RuntimeError(
                    "window prefetch worker failed; storage tier is broken"
                ) from err
            if self.prefetcher.failed:
                self._note_degraded(
                    "prefetcher",
                    self.prefetcher.errors[0] if self.prefetcher.errors
                    else self.prefetcher.error,
                    action="window prefetch disabled; loads run "
                           "synchronously")

    def close(self) -> None:
        """Release background resources (loader pool, window prefetcher,
        any in-flight staged-refresh thread), then surface any failure
        they latched.  Idempotent once the latched errors have raised."""
        if self.prefetcher is not None:
            self.prefetcher.close()
        t = self._refresh_thread
        if t is not None:
            t.join(timeout=30.0)
            self._refresh_thread = None
        self.loader.close()
        self._raise_background_errors()

    # ------------------------------------------------------------- reporting

    def _note_degraded(self, component: str,
                       error: Optional[BaseException],
                       action: str = "") -> None:
        """Record one component's permanent degradation (idempotent: the
        first failure per component wins).  The record feeds ``health()``
        — degraded mode must be visible, never silent.  Callable from any
        thread (pipeline stages note failures too): the check-and-insert
        is atomic under the state lock."""
        with self._state_lock:
            if component in self._degraded:
                return
            self._degraded[component] = {
                "component": component,
                "error": repr(error) if error is not None else "",
                "action": action,
                "iteration": len(self.history),
            }

    def health(self) -> Dict[str, Any]:
        """Degraded-mode / fault-tolerance report.

        ``status`` is ``"ok"`` until any component permanently degraded,
        then ``"degraded"``; ``events`` carries one record per degraded
        component (error, mitigation, iteration).  ``components`` holds
        live per-subsystem counters: prefetcher supervision (restarts /
        errors / healthy), dynamic-refresh failure budget, and the
        storage tier's retry/fallback/hint-failure counters."""
        comp: Dict[str, Any] = {}
        if self.prefetcher is not None:
            comp["prefetcher"] = {
                "healthy": self.prefetcher.healthy,
                "failed": self.prefetcher.failed,
                "restarts": int(self.prefetcher.restarts),
                "errors": len(self.prefetcher.errors),
            }
        if self.cache is not None and self.cfg.cache_refresh:
            comp["refresh"] = {
                "enabled": not self._refresh_disabled,
                "stage_failures": int(self.cache.stage_failures),
                "consecutive_failures": int(self._refresh_failures),
            }
        src = self.loader.source
        if hasattr(src, "io_retries"):
            comp["storage"] = {
                "io_errors": int(src.io_errors),
                "io_retries": int(src.io_retries),
                "io_retry_seconds": float(src.io_retry_seconds),
                "fallback_gathers": int(src.fallback_gathers),
                "fallback_rows": int(src.fallback_rows),
                "madvise_failures": int(src.madvise_failures),
                "fadvise_failures": int(src.fadvise_failures),
            }
        # snapshot under the lock: a trainer thread adding to _failed (or
        # a pipeline stage noting degradation) while this iterates would
        # raise "changed size during iteration"
        with self._state_lock:
            failed = sorted(self._failed)
            degraded = sorted(self._degraded)
            events = [dict(e) for e in self._degraded.values()]
        if failed:
            comp["trainers"] = {"failed": failed}
        return {
            "status": "degraded" if degraded else "ok",
            "degraded": degraded,
            "events": events,
            "components": comp,
        }

    def storage_io(self) -> Dict[str, float]:
        """Background storage-I/O accounting (zeros on RAM tiers):
        prefetch/eviction counters from the mmap source plus the
        cumulative load-stage stall the prefetcher did not hide."""
        src = self.loader.source
        out = {
            "load_stall_seconds": self.loader.stats.stall_seconds
            + self.loader.host_stats.stall_seconds,
            "cold_fault_page_bytes":
                float(getattr(src, "cold_fault_page_bytes", 0)),
            "prefetched_window_bytes":
                float(getattr(src, "prefetched_window_bytes", 0)),
            "evicted_window_bytes":
                float(getattr(src, "evicted_window_bytes", 0)),
            "window_evictions": float(getattr(src, "window_evictions", 0)),
            "pin_blocked_evictions":
                float(getattr(src, "pin_blocked_evictions", 0)),
            "open_windows": float(getattr(src, "open_windows", 0)),
            "prefetch_hit_rate":
                float(getattr(src, "prefetch_hit_rate", 0.0)),
            # fault-tolerance counters (module docstring: failure model)
            "io_retries": float(getattr(src, "io_retries", 0)),
            "io_retry_seconds": float(getattr(src, "io_retry_seconds", 0.0)),
            "io_errors": float(getattr(src, "io_errors", 0)),
            "fallback_gathers": float(getattr(src, "fallback_gathers", 0)),
            "fallback_rows": float(getattr(src, "fallback_rows", 0)),
            "madvise_failures": float(getattr(src, "madvise_failures", 0)),
            "fadvise_failures": float(getattr(src, "fadvise_failures", 0)),
        }
        if self.prefetcher is not None:
            out["prefetch_submitted"] = float(self.prefetcher.submitted)
            out["prefetch_completed"] = float(self.prefetcher.completed)
            out["prefetch_dropped"] = float(self.prefetcher.dropped)
            out["resubmitted_rows_skipped"] = float(
                self.prefetcher.resubmitted_rows_skipped)
        return out

    def mean_mteps(self, skip: int = 2) -> float:
        hist = self.history[skip:] or self.history
        return float(np.mean([m.mteps for m in hist]))

    def mean_iter_time(self, skip: int = 2) -> float:
        hist = self.history[skip:] or self.history
        return float(np.mean([m.iter_time for m in hist]))

    def feature_traffic(self) -> Dict[str, float]:
        """Cumulative feature-movement accounting for the whole run.

        ``shipped_bytes`` is what actually crossed host->device (gathered
        unique misses plus any shape-bucket padding); ``saved_bytes`` is
        what the device cache absorbed; ``dedup_saved_bytes`` what
        frontier deduplication absorbed; ``host_read_bytes`` is the CPU
        trainer's direct host-memory reads (never on PCIe, tracked
        separately).  ``hit_rate``/``reduction`` therefore describe the
        transfer path only; gathered + cache-saved + dedup-saved bytes
        always reconstruct the legacy one-row-per-position baseline.
        """
        s = self.loader.stats
        # legacy baseline = every requested frontier position shipped
        # (= gathered unique-miss bytes + bytes the cache absorbed + bytes
        # dedup absorbed + bytes peer shards / the union multicast / the
        # recent-rows LRU absorbed; padding is an artifact of the compact
        # path, not part of the baseline).  The sharded/recent terms are 0
        # on the replicated path, so legacy runs reconstruct exactly.
        baseline = ((s.bytes - s.padding_bytes) + s.saved_bytes
                    + s.dedup_saved_bytes + s.peer_saved_bytes
                    + s.union_saved_bytes + s.recent_saved_bytes)
        return {
            "shipped_rows": float(s.rows),
            "shipped_bytes": float(s.bytes),
            "saved_bytes": float(s.saved_bytes),
            "dedup_saved_bytes": float(s.dedup_saved_bytes),
            "peer_rows": float(s.peer_rows),
            "peer_saved_bytes": float(s.peer_saved_bytes),
            "union_saved_bytes": float(s.union_saved_bytes),
            "ici_bytes": float(s.ici_bytes),
            "recent_rows": float(s.recent_rows),
            "recent_saved_bytes": float(s.recent_saved_bytes),
            "padding_bytes": float(s.padding_bytes),
            "host_read_bytes": float(self.loader.host_stats.bytes),
            "hit_rate": s.hit_rate,
            "dup_factor": s.dup_factor,
            "reduction": baseline / max(s.bytes, 1),
        }
