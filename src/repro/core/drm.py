"""Dynamic Resource Management (paper Section IV-A, Algorithm 1).

A bottleneck-guided runtime optimizer.  Inputs: measured per-stage times of
the previous iteration.  Outputs: the next iteration's workload assignment
(mini-batch rows per trainer) and thread assignment (threads per CPU stage).

Faithful to Algorithm 1:

* ``T_Accel = max(T_Tran, T_TA)`` (transfer and accel-training are bundled —
  their times co-vary with the accelerator's workload share),
* bottleneck = slowest of {T_SC, T_SA, T_Load, T_TC, T_Accel},
* accelerator-side bottlenecks -> ``balance_work``,
* Feature-Loader bottleneck -> ``balance_thread``,
* CPU Sampler / CPU Trainer bottlenecks -> ``balance_work`` if the fastest
  (or fastest+second) stages are accelerator-side, else ``balance_thread``.

Invariants (property-tested): the total mini-batch size is conserved by
``balance_work`` and the total CPU thread count is conserved by
``balance_thread``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

__all__ = ["StageTimes", "Assignment", "DRMEngine"]


@dataclasses.dataclass
class StageTimes:
    """Execution times (seconds) collected by the Runtime for one iteration."""
    t_sa: float = 0.0    # Sampling on Accelerator
    t_sc: float = 0.0    # Sampling on CPU
    t_load: float = 0.0  # Feature Loading (CPU)
    t_tran: float = 0.0  # Data Transfer (PCIe)
    t_tc: float = 0.0    # Training on CPU
    t_ta: float = 0.0    # Training on Accelerator
    # storage-I/O stall inside the load stage: aggregate gather-thread
    # seconds spent faulting cold (unprefetched) mmap pages.  Summed
    # across the loader's pool threads, so under a multi-threaded chunked
    # gather it can exceed the wall-clock t_load — compare magnitudes,
    # not as a strict subset.  Kept separate so the DRM (and anything
    # reading StageTimes) can tell a compute-bound Feature Loader from
    # one starved on the storage tier; the background window prefetcher
    # exists to drive this toward 0.
    t_load_stall: float = 0.0

    @property
    def t_accel(self) -> float:
        return max(self.t_tran, self.t_ta)

    def iteration_time(self) -> float:
        return max(self.t_sa, self.t_sc, self.t_load, self.t_tran,
                   self.t_tc, self.t_ta)


@dataclasses.dataclass
class Assignment:
    """Mutable workload/thread state the DRM engine fine-tunes."""
    cpu_batch: int                    # rows trained by the CPU trainer
    accel_batch: int                  # rows trained by EACH accelerator
    n_accel: int
    sample_frac_accel: float          # share of sampling done on accel
    threads: Dict[str, int]           # {"sample": k, "load": k, "train": k}

    @property
    def total_batch(self) -> int:
        return self.cpu_batch + self.accel_batch * self.n_accel

    def copy(self) -> "Assignment":
        return Assignment(self.cpu_batch, self.accel_batch, self.n_accel,
                          self.sample_frac_accel, dict(self.threads))


class DRMEngine:
    def __init__(self, assignment: Assignment, damping: float = 0.25,
                 min_accel_batch: int = 0, history: int = 2):
        self.assign = assignment
        self.damping = damping
        self.min_accel_batch = min_accel_batch
        self.history = history
        self.log: List[Tuple[StageTimes, str, Assignment]] = []

    # -------------------------------------------------------------- actions

    def _balance_work_train(self, times: StageTimes) -> str:
        """Move mini-batch rows between the CPU trainer and accelerators."""
        a = self.assign
        if a.n_accel <= 0:
            # no accelerator to trade rows with: any delta added to
            # accel_batch contributes accel_batch * 0 to total_batch, so
            # the conservation invariant would silently lose rows
            return "balance_work train: no accelerators (no-op)"
        slow_is_cpu = times.t_tc > times.t_accel
        t_slow = max(times.t_tc, times.t_accel)
        t_fast = max(min(times.t_tc, times.t_accel), 1e-9)
        imbalance = (t_slow - t_fast) / (t_slow + t_fast)
        if slow_is_cpu:
            delta = max(1, int(a.cpu_batch * imbalance * self.damping))
            delta = min(delta, a.cpu_batch)
            a.cpu_batch -= delta
            # spread over accelerators, conserving the total
            per = delta // max(a.n_accel, 1)
            rem = delta - per * max(a.n_accel, 1)
            a.accel_batch += per
            a.cpu_batch += rem  # leftover stays on CPU: exact conservation
            return f"balance_work train: cpu->accel {delta - rem} rows"
        else:
            delta = max(1, int(a.accel_batch * imbalance * self.damping))
            delta = min(delta, max(0, a.accel_batch - self.min_accel_batch))
            a.accel_batch -= delta
            a.cpu_batch += delta * max(a.n_accel, 1)
            return f"balance_work train: accel->cpu {delta}x{a.n_accel} rows"

    def _balance_work_sample(self, times: StageTimes) -> str:
        """Shift sampling share between CPU and accelerator samplers."""
        a = self.assign
        t_slow = max(times.t_sc, times.t_sa)
        t_fast = max(min(times.t_sc, times.t_sa), 1e-9)
        step = self.damping * (t_slow - t_fast) / (t_slow + t_fast)
        if times.t_sc > times.t_sa:
            a.sample_frac_accel = min(1.0, a.sample_frac_accel + step)
            return f"balance_work sample: cpu->accel {step:.3f}"
        a.sample_frac_accel = max(0.0, a.sample_frac_accel - step)
        return f"balance_work sample: accel->cpu {step:.3f}"

    def _balance_thread(self, fastest_stage: str, bottleneck_stage: str) -> str:
        """Move one thread from the fastest CPU task to the bottleneck."""
        a = self.assign
        src = fastest_stage
        dst = bottleneck_stage
        if src == dst or a.threads.get(src, 0) <= 1:
            return "balance_thread: no-op (src exhausted)"
        a.threads[src] -= 1
        a.threads[dst] = a.threads.get(dst, 0) + 1
        return f"balance_thread: {src}->{dst}"

    # ------------------------------------------------------------ Algorithm 1

    def step(self, times: StageTimes) -> Assignment:
        t_accel = times.t_accel                          # line 1
        # Balance on the load stage's *compute* time: the storage-stall
        # share (t_load_stall) is seconds the gather threads sat faulting
        # cold mmap pages, which no thread/row rebalance can shrink — the
        # prefetcher exists for that.  Folding it in made a stall-bound
        # loader look like the system bottleneck, stealing threads (or
        # rows, via the fastest-cpu-task ranking) from trainers that were
        # not actually slow.  Stall is pool-thread-summed and can exceed
        # the wall-clock t_load, hence the clamp at 0.
        t_load_eff = max(times.t_load - times.t_load_stall, 0.0)
        stages = {"t_sc": times.t_sc, "t_sa": times.t_sa,
                  "t_load": t_load_eff, "t_tc": times.t_tc,
                  "t_accel": t_accel}
        # stages with zero time are inactive (e.g. no accelerator sampler)
        # and cannot be "fastest" — Algorithm 1 assumes all stages exist.
        active = {k: v for k, v in stages.items() if v > 0.0} or stages
        ranked = sorted(active.items(), key=lambda kv: kv[1], reverse=True)
        bottleneck = ranked[0][0]                        # line 5
        fastest = ranked[-1][0]                          # line 3
        second = ranked[-2][0] if len(ranked) > 1 else fastest  # line 4
        cpu_stages = {"t_sc": "sample", "t_load": "load", "t_tc": "train"}
        cpu_ranked = sorted(((k, stages[k]) for k in cpu_stages),
                            key=lambda kv: kv[1])
        fastest_cpu_task = cpu_ranked[0][0]              # line 8

        if bottleneck == "t_sa":                         # line 11
            action = self._balance_work_sample(times)
        elif bottleneck == "t_accel":                    # line 13
            action = self._balance_work_train(times)
        elif bottleneck == "t_load":                     # line 15
            action = self._balance_thread(cpu_stages[fastest_cpu_task], "load")
        elif bottleneck == "t_sc":                       # line 17
            if fastest == "t_sa":
                action = self._balance_work_sample(times)
            elif fastest == "t_accel" and second == "t_sa":
                action = self._balance_work_sample(times)
            else:
                action = self._balance_thread(cpu_stages[fastest_cpu_task],
                                              "sample")
        elif bottleneck == "t_tc":                       # line 25
            if fastest == "t_accel":
                action = self._balance_work_train(times)
            elif fastest == "t_sa" and second == "t_accel":
                action = self._balance_work_train(times)
            else:
                action = self._balance_thread(cpu_stages[fastest_cpu_task],
                                              "train")
        else:  # pragma: no cover
            action = "no-op"

        self.log.append((times, action, self.assign.copy()))
        if len(self.log) > 512:
            del self.log[:-256]
        return self.assign
