"""Dynamic Resource Management (paper Section IV-A, Algorithm 1).

A bottleneck-guided runtime optimizer.  Inputs: measured per-stage times of
the previous iteration.  Outputs: the next iteration's workload assignment
(mini-batch rows per trainer) and thread assignment (threads per CPU stage).

Faithful to Algorithm 1:

* ``T_Accel = max(T_Tran, T_TA)`` (transfer and accel-training are bundled —
  their times co-vary with the accelerator's workload share),
* bottleneck = slowest of {T_SC, T_SA, T_Load, T_TC, T_Accel},
* accelerator-side bottlenecks -> ``balance_work``,
* Feature-Loader bottleneck -> ``balance_thread``,
* CPU Sampler / CPU Trainer bottlenecks -> ``balance_work`` if the fastest
  (or fastest+second) stages are accelerator-side, else ``balance_thread``.

Invariants (property-tested): the total mini-batch size is conserved by
``balance_work`` and the total CPU thread count is conserved by
``balance_thread``.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, List, Optional, Tuple

from .perfmodel import CalibratedKnobModel, KnobBounds, KnobState

__all__ = ["StageTimes", "Assignment", "DRMEngine", "KnobProposal",
           "KnobAutoTuner", "knob_neighbors"]


@dataclasses.dataclass
class StageTimes:
    """Execution times (seconds) collected by the Runtime for one iteration."""
    t_sa: float = 0.0    # Sampling on Accelerator
    t_sc: float = 0.0    # Sampling on CPU
    t_load: float = 0.0  # Feature Loading (CPU)
    t_tran: float = 0.0  # Data Transfer (PCIe)
    t_tc: float = 0.0    # Training on CPU
    t_ta: float = 0.0    # Training on Accelerator
    # storage-I/O stall inside the load stage: aggregate gather-thread
    # seconds spent faulting cold (unprefetched) mmap pages.  Summed
    # across the loader's pool threads, so under a multi-threaded chunked
    # gather it can exceed the wall-clock t_load — compare magnitudes,
    # not as a strict subset.  Kept separate so the DRM (and anything
    # reading StageTimes) can tell a compute-bound Feature Loader from
    # one starved on the storage tier; the background window prefetcher
    # exists to drive this toward 0.
    t_load_stall: float = 0.0

    @property
    def t_accel(self) -> float:
        return max(self.t_tran, self.t_ta)

    def iteration_time(self) -> float:
        return max(self.t_sa, self.t_sc, self.t_load, self.t_tran,
                   self.t_tc, self.t_ta)


@dataclasses.dataclass
class Assignment:
    """Mutable workload/thread state the DRM engine fine-tunes."""
    cpu_batch: int                    # rows trained by the CPU trainer
    accel_batch: int                  # rows trained by EACH accelerator
    n_accel: int
    sample_frac_accel: float          # share of sampling done on accel
    threads: Dict[str, int]           # {"sample": k, "load": k, "train": k}

    @property
    def total_batch(self) -> int:
        return self.cpu_batch + self.accel_batch * self.n_accel

    def copy(self) -> "Assignment":
        return Assignment(self.cpu_batch, self.accel_batch, self.n_accel,
                          self.sample_frac_accel, dict(self.threads))


class DRMEngine:
    def __init__(self, assignment: Assignment, damping: float = 0.25,
                 min_accel_batch: int = 0, history: int = 2):
        self.assign = assignment
        self.damping = damping
        self.min_accel_batch = min_accel_batch
        self.history = history
        self.log: List[Tuple[StageTimes, str, Assignment]] = []

    # -------------------------------------------------------------- actions

    def _balance_work_train(self, times: StageTimes) -> str:
        """Move mini-batch rows between the CPU trainer and accelerators."""
        a = self.assign
        if a.n_accel <= 0:
            # no accelerator to trade rows with: any delta added to
            # accel_batch contributes accel_batch * 0 to total_batch, so
            # the conservation invariant would silently lose rows
            return "balance_work train: no accelerators (no-op)"
        slow_is_cpu = times.t_tc > times.t_accel
        t_slow = max(times.t_tc, times.t_accel)
        t_fast = max(min(times.t_tc, times.t_accel), 1e-9)
        imbalance = (t_slow - t_fast) / (t_slow + t_fast)
        if slow_is_cpu:
            delta = max(1, int(a.cpu_batch * imbalance * self.damping))
            delta = min(delta, a.cpu_batch)
            a.cpu_batch -= delta
            # spread over accelerators, conserving the total
            per = delta // max(a.n_accel, 1)
            rem = delta - per * max(a.n_accel, 1)
            a.accel_batch += per
            a.cpu_batch += rem  # leftover stays on CPU: exact conservation
            return f"balance_work train: cpu->accel {delta - rem} rows"
        else:
            delta = max(1, int(a.accel_batch * imbalance * self.damping))
            delta = min(delta, max(0, a.accel_batch - self.min_accel_batch))
            a.accel_batch -= delta
            a.cpu_batch += delta * max(a.n_accel, 1)
            return f"balance_work train: accel->cpu {delta}x{a.n_accel} rows"

    def _balance_work_sample(self, times: StageTimes) -> str:
        """Shift sampling share between CPU and accelerator samplers."""
        a = self.assign
        if times.t_sc == times.t_sa:
            # balanced pair (including both 0 in a probe iteration): any
            # move is drift.  Without this, the 1e-9 clamp on t_fast made
            # step negative and the t_sc > t_sa branch below — False at
            # equality — *added* damping to the accel share every call.
            return "balance_work sample: balanced (no-op)"
        t_slow = max(times.t_sc, times.t_sa)
        t_fast = max(min(times.t_sc, times.t_sa), 1e-9)
        step = self.damping * (t_slow - t_fast) / (t_slow + t_fast)
        if times.t_sc > times.t_sa:
            a.sample_frac_accel = min(1.0, a.sample_frac_accel + step)
            return f"balance_work sample: cpu->accel {step:.3f}"
        a.sample_frac_accel = max(0.0, a.sample_frac_accel - step)
        return f"balance_work sample: accel->cpu {step:.3f}"

    def _balance_thread(self, fastest_stage: str, bottleneck_stage: str) -> str:
        """Move one thread from the fastest CPU task to the bottleneck."""
        a = self.assign
        src = fastest_stage
        dst = bottleneck_stage
        if src == dst or a.threads.get(src, 0) <= 1:
            return "balance_thread: no-op (src exhausted)"
        a.threads[src] -= 1
        a.threads[dst] = a.threads.get(dst, 0) + 1
        return f"balance_thread: {src}->{dst}"

    # ------------------------------------------------------------ Algorithm 1

    def step(self, times: StageTimes) -> Assignment:
        t_accel = times.t_accel                          # line 1
        # Balance on the load stage's *compute* time: the storage-stall
        # share (t_load_stall) is seconds the gather threads sat faulting
        # cold mmap pages, which no thread/row rebalance can shrink — the
        # prefetcher exists for that.  Folding it in made a stall-bound
        # loader look like the system bottleneck, stealing threads (or
        # rows, via the fastest-cpu-task ranking) from trainers that were
        # not actually slow.  Stall is pool-thread-summed and can exceed
        # the wall-clock t_load, hence the clamp at 0.
        t_load_eff = max(times.t_load - times.t_load_stall, 0.0)
        stages = {"t_sc": times.t_sc, "t_sa": times.t_sa,
                  "t_load": t_load_eff, "t_tc": times.t_tc,
                  "t_accel": t_accel}
        # stages with zero time are inactive (e.g. no accelerator sampler)
        # and cannot be "fastest" — Algorithm 1 assumes all stages exist.
        active = {k: v for k, v in stages.items() if v > 0.0} or stages
        ranked = sorted(active.items(), key=lambda kv: kv[1], reverse=True)
        bottleneck = ranked[0][0]                        # line 5
        fastest = ranked[-1][0]                          # line 3
        second = ranked[-2][0] if len(ranked) > 1 else fastest  # line 4
        cpu_stages = {"t_sc": "sample", "t_load": "load", "t_tc": "train"}
        # thread-donor ranking over ACTIVE CPU stages only, judged on the
        # raw measured time (a stage that never ran — t_tc == 0 with no
        # CPU trainer — must not donate forever), but ranked on the
        # effective value so a stall-clamped loader still donates (its
        # threads sat faulting pages, not computing)
        raw = {"t_sc": times.t_sc, "t_load": times.t_load,
               "t_tc": times.t_tc}
        cpu_active = [(k, stages[k]) for k in cpu_stages if raw[k] > 0.0]
        cpu_ranked = sorted(cpu_active
                            or [(k, stages[k]) for k in cpu_stages],
                            key=lambda kv: kv[1])
        fastest_cpu_task = cpu_ranked[0][0]              # line 8

        if bottleneck == "t_sa":                         # line 11
            action = self._balance_work_sample(times)
        elif bottleneck == "t_accel":                    # line 13
            action = self._balance_work_train(times)
        elif bottleneck == "t_load":                     # line 15
            action = self._balance_thread(cpu_stages[fastest_cpu_task], "load")
        elif bottleneck == "t_sc":                       # line 17
            if fastest == "t_sa":
                action = self._balance_work_sample(times)
            elif fastest == "t_accel" and second == "t_sa":
                action = self._balance_work_sample(times)
            else:
                action = self._balance_thread(cpu_stages[fastest_cpu_task],
                                              "sample")
        elif bottleneck == "t_tc":                       # line 25
            if fastest == "t_accel":
                action = self._balance_work_train(times)
            elif fastest == "t_sa" and second == "t_accel":
                action = self._balance_work_train(times)
            else:
                action = self._balance_thread(cpu_stages[fastest_cpu_task],
                                              "train")
        else:  # pragma: no cover
            action = "no-op"

        self.log.append((times, action, self.assign.copy()))
        if len(self.log) > 512:
            del self.log[:-256]
        return self.assign

    # ----------------------------------------------- online knob search

    def propose_knobs(self, model: CalibratedKnobModel, current: KnobState,
                      bounds: KnobBounds, min_gain: float = 0.02,
                      veto: Optional[set] = None
                      ) -> Optional["KnobProposal"]:
        """One step of the model-predictive knob search: enumerate the
        bounded single-knob neighborhood of ``current``, price each
        candidate with the calibrated Eq. 7/8 model, and return the best
        move — or None when nothing beats the current knobs by at least
        ``min_gain`` (relative).  Pure search: applying (and verifying,
        and possibly rolling back) the proposal is the caller's job —
        see ``KnobAutoTuner``.  ``veto`` names move keys temporarily
        blocked after a measured rollback."""
        baseline = model.predict(current)
        best: Optional[Tuple[float, str, KnobState]] = None
        for move, cand in knob_neighbors(current, bounds):
            if veto and move in veto:
                continue
            pred = model.predict(cand)
            if best is None or pred < best[0]:
                best = (pred, move, cand)
        if best is None:
            return None
        pred, move, cand = best
        if pred > baseline * (1.0 - min_gain):
            return None
        return KnobProposal(knobs=cand, move=move, predicted=pred,
                            baseline=baseline)


def knob_neighbors(k: KnobState, b: KnobBounds
                   ) -> List[Tuple[str, KnobState]]:
    """Bounded single-knob moves from ``k``: geometric steps on the
    queue/window/cadence knobs (the useful scales span orders of
    magnitude) and one-thread transfers between stages (conserving the
    total, like balance_thread).  Every returned state satisfies
    ``b.contains``; move keys are direction-stable ("knob:up") so a
    vetoed direction stays vetoed across magnitudes."""
    out: List[Tuple[str, KnobState]] = []

    def add(move: str, **delta) -> None:
        cand = dataclasses.replace(k, **delta)
        if cand != k and b.contains(cand):
            out.append((move, cand))

    p = k.prefetch_windows
    add("prefetch_windows:up", prefetch_windows=min(
        max(2 * p, 1), b.prefetch_windows[1]))
    add("prefetch_windows:down", prefetch_windows=max(
        p // 2, b.prefetch_windows[0]))
    w = k.mmap_lru_windows
    add("mmap_lru_windows:up", mmap_lru_windows=min(
        max(2 * w, 1), b.mmap_lru_windows[1]))
    add("mmap_lru_windows:down", mmap_lru_windows=max(
        w // 2, b.mmap_lru_windows[0]))
    r = k.refresh_period
    add("refresh_period:up", refresh_period=min(
        max(2 * r, 1), b.refresh_period[1]))
    add("refresh_period:down", refresh_period=max(
        r // 2, b.refresh_period[0]))
    f = k.refresh_frac
    add("refresh_frac:up", refresh_frac=min(2.0 * f, b.refresh_frac[1]))
    add("refresh_frac:down", refresh_frac=max(f / 2.0, b.refresh_frac[0]))
    stages = ("sample", "load", "train")
    for src, dst in itertools.permutations(stages, 2):
        s_val = getattr(k, f"{src}_threads")
        if s_val <= b.min_stage_threads:
            continue
        add(f"threads:{src}->{dst}",
            **{f"{src}_threads": s_val - 1,
               f"{dst}_threads": getattr(k, f"{dst}_threads") + 1})
    return out


@dataclasses.dataclass(frozen=True)
class KnobProposal:
    """One bounded knob move with its model pricing."""
    knobs: KnobState
    move: str                      # direction-stable key, e.g. "threads:sample->load"
    predicted: float               # model iteration time at `knobs`
    baseline: float                # model iteration time at current knobs


@dataclasses.dataclass
class _Trial:
    """A proposal applied but not yet verified against measurement."""
    prev: KnobState                # exact pre-move state (rollback target)
    knobs: KnobState
    move: str
    baseline_wall: float           # measured mean iter time before the move
    predicted: float
    baseline_predicted: float
    measured_wall: float = 0.0     # filled when the trial window closes


class KnobAutoTuner:
    """Closes the DRM loop over the hand-set knobs: measure a window,
    calibrate the Eq. 7/8 model on it, apply the best bounded single-knob
    move, verify against the next *measured* window, keep or roll back.

    State machine, advanced once per iteration boundary by ``step``:

      MEASURE  — accumulate ``interval`` iterations of StageTimes;
      DECIDE   — window closed: if a trial is pending, accept it (keep
                 the knobs) unless the measured mean regressed past
                 ``baseline_wall x (1 + hysteresis)``, in which case the
                 exact pre-move KnobState is returned for re-application
                 and the move direction is vetoed for ``veto_windows``
                 windows; then (either way) calibrate a fresh model via
                 ``model_fn`` and search for the next proposal.

    The tuner never touches workload shares, RNG streams or batch
    composition — every knob it moves is performance-only, so losses
    stay bit-identical to a static-knob run (the bench_autotune gate).

    Threading: driven only from the training thread at iteration
    boundaries; no internal locks by design (single-caller contract,
    like the DRMEngine it extends).
    """

    def __init__(self, engine: DRMEngine, bounds: KnobBounds,
                 interval: int = 3, hysteresis: float = 0.10,
                 min_gain: float = 0.02, warmup_windows: int = 1,
                 veto_windows: int = 4):
        self.engine = engine
        self.bounds = bounds
        self.interval = max(1, int(interval))
        self.hysteresis = float(hysteresis)
        self.min_gain = float(min_gain)
        self.warmup_windows = max(0, int(warmup_windows))
        self.veto_windows = max(1, int(veto_windows))
        self._win: List[StageTimes] = []
        self._windows_seen = 0
        self._trial: Optional[_Trial] = None
        self._veto: Dict[str, int] = {}      # move key -> windows left
        self.accepted: List[_Trial] = []
        self.rollbacks = 0
        self.trials = 0
        self.log: List[Tuple[str, str]] = []  # (event, move/detail)

    @staticmethod
    def _mean_times(win: List[StageTimes]) -> StageTimes:
        n = max(len(win), 1)
        return StageTimes(
            t_sa=sum(t.t_sa for t in win) / n,
            t_sc=sum(t.t_sc for t in win) / n,
            t_load=sum(t.t_load for t in win) / n,
            t_tran=sum(t.t_tran for t in win) / n,
            t_tc=sum(t.t_tc for t in win) / n,
            t_ta=sum(t.t_ta for t in win) / n,
            t_load_stall=sum(t.t_load_stall for t in win) / n)

    def step(self, times: StageTimes,
             model_fn: Callable[[StageTimes, int], CalibratedKnobModel],
             current: KnobState) -> Optional[KnobState]:
        """Feed one iteration's measured times; returns a KnobState the
        caller must apply (a new proposal OR an exact rollback), or None.
        ``model_fn(mean_times, window_iters)`` builds the calibrated
        model from the window's measured signals."""
        self._win.append(times)
        if len(self._win) < self.interval:
            return None
        mean = self._mean_times(self._win)
        wall = sum(t.iteration_time() for t in self._win) / len(self._win)
        iters = len(self._win)
        self._win = []
        self._windows_seen += 1
        for key in [m for m, left in self._veto.items() if left <= 1]:
            del self._veto[key]
        for key in self._veto:
            self._veto[key] -= 1
        if self._trial is not None:
            tr, self._trial = self._trial, None
            tr.measured_wall = wall
            if wall > tr.baseline_wall * (1.0 + self.hysteresis):
                # measured regression: restore the exact pre-move state
                # and veto the direction so the search does not thrash
                self.rollbacks += 1
                self._veto[tr.move] = self.veto_windows
                self.log.append(("rollback", tr.move))
                return tr.prev
            self.accepted.append(tr)
            self.log.append(("accept", tr.move))
        if self._windows_seen <= self.warmup_windows:
            return None
        model = model_fn(mean, iters)
        prop = self.engine.propose_knobs(model, current, self.bounds,
                                         min_gain=self.min_gain,
                                         veto=set(self._veto))
        if prop is None:
            return None
        self.trials += 1
        self._trial = _Trial(prev=current, knobs=prop.knobs,
                             move=prop.move, baseline_wall=wall,
                             predicted=prop.predicted,
                             baseline_predicted=prop.baseline)
        self.log.append(("try", prop.move))
        return prop.knobs

    def report(self) -> Dict[str, object]:
        """Summary for benches/drivers: counts, the accepted trajectory
        (with model pricing) and the live veto set."""
        return {
            "trials": self.trials,
            "accepted": len(self.accepted),
            "rollbacks": self.rollbacks,
            "moves": [{"move": t.move,
                       "predicted": t.predicted,
                       "baseline_predicted": t.baseline_predicted,
                       "baseline_wall": t.baseline_wall,
                       "measured_wall": t.measured_wall}
                      for t in self.accepted],
            "vetoed": sorted(self._veto),
        }
