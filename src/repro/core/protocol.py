"""Processor-Accelerator Training Protocol (paper Section III-C, Listing 1).

Defines how processors and accelerators interact and synchronize:

* ``Synchronizer`` — the condition-variable DONE handshake of Listing 1:
  each Trainer increments DONE when its gradients are staged; when DONE
  equals the number of Trainers the Synchronizer gathers, averages
  (weighted by mini-batch share — sync SGD over unequal shares), and the
  averaged gradients are broadcast back.
* ``TrainerHandle`` — one logical GNN Trainer bound to a device and a jit'd
  gradient function; ``kind`` distinguishes the CPU trainer from
  accelerator trainers (the protocol's application layer is accelerator
  agnostic — GPU/FPGA/TPU only changes the programming layer underneath,
  which for us is always XLA).
* ``Runtime`` — collects per-stage execution times each iteration and feeds
  the DRM engine (Section IV-A), exactly as in Fig. 5 ("the Runtime system
  collects the execution time of each stage to fine-tune the workload
  assignment in the next iteration").
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.analysis.annotations import guarded_by

from .drm import Assignment, DRMEngine, StageTimes

__all__ = ["Synchronizer", "TrainerHandle", "Runtime"]

PyTree = Any


@guarded_by("_cond", "_done", "_slots")
class Synchronizer:
    """Listing-1 handshake: pthread cond/mutex -> threading.Condition."""

    def __init__(self, n_trainers: int) -> None:
        self.n_trainers = n_trainers
        self._cond = threading.Condition()
        self._done = 0
        self._slots: List[Optional[Tuple[PyTree, float]]] = [None] * n_trainers

    def submit(self, trainer_idx: int, grads: PyTree, weight: float) -> None:
        """Trainer side: stage gradients, increment DONE, signal."""
        with self._cond:
            self._slots[trainer_idx] = (grads, weight)
            self._done += 1
            self._cond.notify_all()

    def all_reduce(self) -> PyTree:
        """Synchronizer side: wait until DONE == n, then weighted-average.

        Weighted by mini-batch share so that hybrid training with unequal
        shares is algorithmically identical to single-device large-batch
        SGD (paper Section II-B).
        """
        with self._cond:
            while self._done != self.n_trainers:       # Listing 1 line 24
                self._cond.wait()
            slots = list(self._slots)                  # gather_data()
            self._done = 0
            self._slots = [None] * self.n_trainers
        total_w = sum(w for _, w in slots)
        scaled = [jax.tree.map(lambda g: g * (w / total_w), g)
                  for g, w in slots]
        avg = scaled[0]
        for s in scaled[1:]:                            # average_gradients()
            avg = jax.tree.map(lambda a, b: a + b, avg, s)
        return avg


@dataclasses.dataclass
class TrainerHandle:
    """One logical GNN Trainer (paper Section III-A)."""
    name: str
    kind: str                    # "cpu" | "accel"
    device: Any                  # jax.Device
    grad_fn: Callable[..., Tuple[PyTree, Dict[str, Any]]]
    index: int

    def run(self, sync: Synchronizer, params: PyTree, weight: float,
            *args: Any) -> Dict[str, Any]:
        t0 = time.perf_counter()
        grads, metrics = self.grad_fn(params, *args)
        grads = jax.block_until_ready(grads)
        dt = time.perf_counter() - t0
        sync.submit(self.index, grads, weight)          # DONE++, signal
        metrics = dict(metrics)
        metrics["t_train"] = dt
        return metrics


class Runtime:
    """Collects stage times, runs the DRM engine between iterations."""

    def __init__(self, assignment: Assignment, use_drm: bool = True,
                 damping: float = 0.25, share_quantum: int = 64) -> None:
        self.drm = DRMEngine(assignment, damping=damping)
        self.use_drm = use_drm
        self.share_quantum = max(1, int(share_quantum))
        self.history: List[StageTimes] = []

    @property
    def assignment(self) -> Assignment:
        return self.drm.assign

    def quantized_shares(self) -> Tuple[int, int]:
        """(cpu_batch, accel_batch_each), rounded to the share quantum.

        Quantization bounds the number of distinct mini-batch shapes the
        jit cache must hold (an XLA-specific constraint the paper's
        CUDA/HLS trainers do not have); the total batch is conserved by
        folding the remainder into the CPU share.
        """
        a = self.drm.assign
        q = self.share_quantum
        accel = (a.accel_batch // q) * q
        cpu = a.total_batch - accel * a.n_accel
        return cpu, accel

    def end_iteration(self, times: StageTimes) -> Assignment:
        self.history.append(times)
        if self.use_drm:
            return self.drm.step(times)
        return self.drm.assign

    def mean_iteration_time(self, skip: int = 1) -> float:
        xs = [t.iteration_time() for t in self.history[skip:]] or [0.0]
        return float(np.mean(xs))
