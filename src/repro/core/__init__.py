# The paper's primary contribution: the hybrid training system —
# protocol, two-stage feature prefetching, DRM, performance model, and the
# hybrid (CPU + accelerators) trainer orchestration.
from .drm import (Assignment, DRMEngine, KnobAutoTuner, KnobProposal,
                  StageTimes, knob_neighbors)
from .perfmodel import (PLATFORMS, CalibratedKnobModel, KnobBounds,
                        KnobState, PlatformSpec, SignalSnapshot,
                        StagePrediction, WorkloadSpec, calibrate_sampling,
                        initial_task_mapping, mteps, predict,
                        predict_epoch_time)
from .pipeline import (PipelineItem, PipelineStallError, PrefetchPipeline,
                       Stage)
from .protocol import Runtime, Synchronizer, TrainerHandle
from .hybrid import HybridConfig, HybridGNNTrainer, IterationMetrics

__all__ = [
    "Assignment", "DRMEngine", "KnobAutoTuner", "KnobProposal",
    "StageTimes", "knob_neighbors",
    "CalibratedKnobModel", "KnobBounds", "KnobState", "SignalSnapshot",
    "PLATFORMS", "PlatformSpec", "StagePrediction", "WorkloadSpec",
    "calibrate_sampling", "initial_task_mapping", "mteps", "predict",
    "predict_epoch_time",
    "PipelineItem", "PipelineStallError", "PrefetchPipeline", "Stage",
    "Runtime", "Synchronizer", "TrainerHandle",
    "HybridConfig", "HybridGNNTrainer", "IterationMetrics",
]
