"""Performance model (paper Section V, Eqs. 5-13).

Predicts per-stage times from algorithmic parameters (mini-batch edge/vertex
counts, layer dims) and platform metadata (Table II + TPU v5e), and derives
the *initial* coarse-grained task mapping (CPU vs accelerator mini-batch
shares) used by the hybrid trainer at design time.  The DRM engine then
fine-tunes that mapping at runtime.

Throughput metric: MTEPS — million traversed edges per second (Eq. 5).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional, Sequence, Tuple

import numpy as np

__all__ = ["PlatformSpec", "PLATFORMS", "WorkloadSpec", "StagePrediction",
           "predict", "initial_task_mapping", "mteps",
           "calibrate_sampling", "predict_epoch_time",
           "KnobState", "KnobBounds", "SignalSnapshot",
           "CalibratedKnobModel"]


@dataclasses.dataclass(frozen=True)
class PlatformSpec:
    """One compute device + its memory/interconnect (paper Table II rows)."""
    name: str
    peak_tflops: float          # fp32 for CPU/FPGA/GPU rows; bf16 for TPU
    mem_bw_gbps: float          # device-local memory bandwidth (GB/s)
    interconnect_gbps: float    # PCIe (accelerators) / n.a. for CPU
    onchip_mb: float
    mac_parallelism: int        # N in Eq. 12 (MACs per cycle)
    freq_ghz: float
    pipelined_agg_update: bool  # the ⊕ operator in Eq. 10: True -> max
    # host storage (NVMe/SSD) read bandwidth, for disk-resident features
    # (the out-of-core MmapFeatures tier).  0 = knob unset: Eq. 7 falls
    # back to memory bandwidth, i.e. features are assumed RAM-resident.
    storage_bw_gbps: float = 0.0
    # accelerator-to-accelerator interconnect (ICI/NVLink) bandwidth, used
    # by the sharded feature plane to price peer-shard row hops separately
    # from host PCIe.  0 = knob unset: peer traffic falls back to the PCIe
    # figure (interconnect_gbps), i.e. no fast device fabric.
    ici_gbps: float = 0.0


PLATFORMS: Dict[str, PlatformSpec] = {
    # paper Table II (effective PCIe bandwidths: gen4 x16 burst ~16 GB/s;
    # host storage: one PCIe gen4 x4 NVMe, ~7 GB/s sequential read)
    "epyc-7763":  PlatformSpec("epyc-7763", 3.6, 205.0, 0.0, 256.0,
                               1472, 2.45, False, storage_bw_gbps=7.0),
    "rtx-a5000":  PlatformSpec("rtx-a5000", 27.8, 768.0, 16.0, 6.0,
                               13900, 2.0, False),
    "alveo-u250": PlatformSpec("alveo-u250", 0.6, 77.0, 16.0, 54.0,
                               2048, 0.3, True),
    # target hardware for the dry-run/roofline (TPU v5e per prompt constants)
    "tpu-v5e":    PlatformSpec("tpu-v5e", 197.0, 819.0, 16.0, 128.0,
                               4 * 128 * 128, 0.94, True, ici_gbps=200.0),
}


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Algorithmic parameters of one training iteration (per trainer)."""
    batch_size: int
    fanouts: Tuple[int, ...]          # (25, 10)
    layer_dims: Tuple[int, ...]       # (f0, f1, f2)
    feat_bytes: int = 4               # S_feat
    model: str = "sage"
    # fraction of loaded rows served by the device-resident feature cache
    # (featcache.FeatureCache): scales the Eq. 7/8 gather/transfer traffic
    # by (1 - h).  0 reproduces the paper's uncached equations exactly.
    # At design time this is the cache's expected_hit_rate; at runtime the
    # feedback loop re-prices with the measured rate over the
    # *post-refresh window* (the loader's window stats reset when a
    # dynamic cache refresh moves rows), so a refreshed cache is priced at
    # the rate it actually serves rather than a lifetime average.
    cache_hit_rate: float = 0.0
    # frontier duplication factor alpha = unique-miss rows / positional
    # miss rows: the deduped transfer path gathers/ships one row per
    # unique miss, so Eq. 7/8 traffic scales by alpha on top of (1 - h).
    # Both the design-time probe (HybridGNNTrainer._probe_dup_factor,
    # which classifies one probe frontier against the cache) and the
    # runtime loader stats (_maybe_refresh_mapping) use this same
    # unique-miss/miss-positions definition — hub ids are both the
    # most-cached and the most-duplicated, so the naive unique/total
    # ratio would double-count the overlap the cache term (1 - h)
    # already removed.  1 reproduces the paper's positional
    # (one-row-per-position) equations exactly.
    dedup_factor: float = 1.0
    # where the feature matrix lives on the host: "ram" (the paper's
    # baseline) or "disk" (out-of-core MmapFeatures) — Eq. 7 prices the
    # gather at min(memory, storage) bandwidth for the disk tier.
    feature_tier: str = "ram"
    # fraction of the disk tier's storage stream hidden by the background
    # window prefetcher (it pre-faults batch i+1's partition windows
    # while batch i trains, the way TFP hides the whole load stage behind
    # compute).  Eq. 7's storage penalty — the gap between pricing at
    # storage vs memory bandwidth — is discounted by this factor: 0 (no
    # prefetcher) reproduces the plain disk-tier pricing, 1 means the
    # storage stream fully overlaps and only the RAM-speed gather stays
    # exposed.  At runtime the feedback loop re-prices with the measured
    # prefetch hit rate.  Ignored on the "ram" tier.
    prefetch_overlap: float = 0.0
    # sharded hot-feature plane (ShardedFeatureCache): fraction of loaded
    # rows served from a *peer* device's shard over the accelerator
    # interconnect instead of the local shard or the host.  Peer rows
    # never touch the host gather or PCIe (Eqs. 7/8) but do cross the
    # ICI, so t_trans prices them at ici_gbps.  0 = replicated cache.
    peer_hit_rate: float = 0.0
    # union-gather multicast factor: unique rows in the *union* of all
    # trainers' miss sets / sum of per-trainer unique misses.  The host
    # gathers and ships the union once (Eq. 7 and the PCIe leg of Eq. 8
    # scale by this), then the rows a trainer needs but did not receive
    # directly are fanned out over ICI.  1 = per-trainer dedup only
    # (replicated plane); < 1 only when trainers' frontiers overlap.
    union_factor: float = 1.0
    # dynamic-cache refresh admission traffic, amortized per iteration:
    # swapped_rows x row_bytes / iterations-between-refreshes.  The
    # admission gather streams from the same host tier the load stage
    # reads (Eq. 7) and the scatter-update block crosses PCIe to every
    # device (Eq. 8) — the term the static equations were missing once
    # the cache became dynamic.  0 reproduces the static-cache pricing.
    refresh_bytes_per_iter: float = 0.0

    def frontier_sizes(self) -> Tuple[int, ...]:
        out = [self.batch_size]
        cur = self.batch_size
        for f in self.fanouts:
            cur = cur * (1 + f)
            out.append(cur)
        return tuple(out)

    def edges_per_layer(self) -> Tuple[int, ...]:
        """|E^l| for hop l consumed by GNN layer L-l (sampled edge counts)."""
        sizes = self.frontier_sizes()
        return tuple(sizes[l] * self.fanouts[l] for l in range(len(self.fanouts)))

    def total_edges(self) -> int:
        return sum(self.edges_per_layer())

    def loaded_rows(self) -> int:
        return self.frontier_sizes()[-1]

    def miss_rows(self) -> float:
        """Expected rows actually gathered+shipped after local cache hits,
        peer-shard hits and frontier deduplication (unique misses only)."""
        miss = max(1.0 - self.cache_hit_rate - self.peer_hit_rate, 0.0)
        return self.loaded_rows() * miss * self.dedup_factor

    def peer_rows(self) -> float:
        """Expected rows served from peer shards over the ICI (deduped the
        same way as host misses — one hop per unique peer row)."""
        return self.loaded_rows() * self.peer_hit_rate * self.dedup_factor

    def model_bytes(self) -> int:
        """Σ_l f^{l-1} × f^l × S_feat (Eq. 13 numerator)."""
        tot = 0
        for fin, fout in zip(self.layer_dims[:-1], self.layer_dims[1:]):
            fin_eff = 2 * fin if self.model == "sage" else fin
            tot += fin_eff * fout
        return tot * self.feat_bytes


@dataclasses.dataclass
class StagePrediction:
    t_samp: float
    t_load: float
    t_trans: float
    t_prop: float
    t_sync: float

    @property
    def t_execution(self) -> float:       # Eq. 6
        return max(self.t_samp, self.t_load, self.t_trans, self.t_prop)

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self) | {"t_execution": self.t_execution}


def t_load(w: WorkloadSpec, host: PlatformSpec, n_trainers: int) -> float:
    """Eq. 7 extended with the cache term: only the expected cache-miss
    rows are gathered from host memory (hit rows live on-device).

    For disk-resident features (``w.feature_tier == "disk"``, the
    out-of-core MmapFeatures tier) the gather streams through the host
    storage device, so the stage is priced at min(memory, storage)
    bandwidth; a platform without the ``storage_bw_gbps`` knob falls back
    to memory bandwidth (RAM-resident assumption).  The background window
    prefetcher overlaps the storage stream with the previous iteration's
    compute, so only ``(1 - prefetch_overlap)`` of the storage *penalty*
    (the excess over the RAM-speed gather) stays exposed on the load
    stage — the same discount TFP applies to the stage as a whole.

    With the union-gather multicast (sharded plane) the host gathers the
    *union* of the trainers' miss sets once instead of each trainer's set
    separately, so the per-trainer traffic scales by ``union_factor``.

    ``refresh_bytes_per_iter`` (dynamic-cache admission traffic) rides
    the same host gather stream once per plane — the refresh gathers the
    admitted rows from the very tier (RAM or disk) the load stage reads,
    so it is priced inside the tier term, storage penalty and prefetch
    discount included."""
    num = (n_trainers * w.miss_rows() * w.layer_dims[0] * w.feat_bytes
           * min(max(w.union_factor, 0.0), 1.0)
           + max(w.refresh_bytes_per_iter, 0.0))
    t_mem = num / (host.mem_bw_gbps * 1e9)
    if w.feature_tier == "disk" and host.storage_bw_gbps > 0.0:
        bw = min(host.mem_bw_gbps, host.storage_bw_gbps)
        t_disk = num / (bw * 1e9)
        overlap = min(max(w.prefetch_overlap, 0.0), 1.0)
        return t_mem + (t_disk - t_mem) * (1.0 - overlap)
    return t_mem


def t_trans(w: WorkloadSpec, accel: PlatformSpec) -> float:
    """Eq. 8 extended with the cache and sharding terms.

    PCIe leg: only the union share of the miss rows is shipped from the
    host (the union-gather sends each unique row once, to one device).
    ICI leg: the multicast fan-out copies (rows this trainer needs that
    arrived on another device first) plus the peer-shard row hops cross
    the accelerator interconnect, priced at ``ici_gbps`` (falling back to
    PCIe bandwidth when the platform has no fast fabric).  The two legs
    use different links and overlap, so the stage time is their max.

    ``refresh_bytes_per_iter`` (dynamic-cache admission traffic) lands on
    the PCIe leg: the scatter-update block of every refresh crosses the
    host->device link on top of the miss stream it competes with."""
    row_bytes = w.layer_dims[0] * w.feat_bytes
    uf = min(max(w.union_factor, 0.0), 1.0)
    t_pcie = ((w.miss_rows() * uf * row_bytes
               + max(w.refresh_bytes_per_iter, 0.0))
              / (accel.interconnect_gbps * 1e9))
    ici_rows = w.miss_rows() * (1.0 - uf) + w.peer_rows()
    if ici_rows <= 0.0:
        return t_pcie
    ici_bw = accel.ici_gbps if accel.ici_gbps > 0.0 else accel.interconnect_gbps
    t_ici = ici_rows * row_bytes / (ici_bw * 1e9)
    return max(t_pcie, t_ici)


def t_aggregate(w: WorkloadSpec, dev: PlatformSpec, layer: int) -> float:
    """Eq. 11 — |E^{l-1}| × f^l × S_feat / BW_mem  (hop edge traffic)."""
    edges = w.edges_per_layer()[::-1]  # GNN layer l consumes hop L-l
    f_in = w.layer_dims[layer - 1]
    return edges[layer - 1] * f_in * w.feat_bytes / (dev.mem_bw_gbps * 1e9)


def t_update(w: WorkloadSpec, dev: PlatformSpec, layer: int) -> float:
    """Eq. 12 — |V^l| × f^l × f^{l+1} / (N × freq)."""
    sizes = w.frontier_sizes()[::-1]   # V^l for GNN layer l output
    v_l = sizes[layer]
    f_in = w.layer_dims[layer - 1] * (2 if w.model == "sage" else 1)
    f_out = w.layer_dims[layer]
    return v_l * f_in * f_out / (dev.mac_parallelism * dev.freq_ghz * 1e9)


def t_trainer(w: WorkloadSpec, dev: PlatformSpec) -> float:
    """Eq. 10 — forward + backward over L layers; ⊕ = max when pipelined."""
    L = len(w.layer_dims) - 1
    op = max if dev.pipelined_agg_update else (lambda a, b: a + b)
    fwd = sum(op(t_aggregate(w, dev, l), t_update(w, dev, l))
              for l in range(1, L + 1))
    bwd = t_update(w, dev, 1) + sum(op(t_aggregate(w, dev, l),
                                       t_update(w, dev, l))
                                    for l in range(2, L + 1))
    return fwd + bwd


def t_sync(w: WorkloadSpec, accel: PlatformSpec,
           compression_ratio: float = 1.0) -> float:
    """Eq. 13 — model gathered+scattered over PCIe (factor 2)."""
    return 2 * w.model_bytes() * compression_ratio / (
        accel.interconnect_gbps * 1e9)


def predict(host: PlatformSpec, accel: PlatformSpec, n_accel: int,
            w_cpu: WorkloadSpec, w_accel: WorkloadSpec,
            t_samp: float = 0.0,
            compression_ratio: float = 1.0) -> StagePrediction:
    """Full-system prediction for one iteration (n_accel accelerator
    trainers, each running ``w_accel``, plus one CPU trainer w/ ``w_cpu``)."""
    # the CPU trainer reads host memory directly and never benefits from
    # the device cache, so its load term is priced with its own workload
    # (cache_hit_rate belongs to w_accel only)
    tl = (t_load(w_accel, host, n_accel)
          + t_load(w_cpu, host, 1 if w_cpu.batch_size > 0 else 0))
    tt = t_trans(w_accel, accel) if n_accel else 0.0
    prop_cpu = t_trainer(w_cpu, host) if w_cpu.batch_size > 0 else 0.0
    prop_acc = t_trainer(w_accel, accel) if n_accel else 0.0
    tp = max(prop_cpu, prop_acc) + t_sync(w_accel, accel, compression_ratio)
    return StagePrediction(t_samp=t_samp, t_load=tl, t_trans=tt, t_prop=tp,
                           t_sync=t_sync(w_accel, accel, compression_ratio))


def mteps(total_edges: int, t_execution: float) -> float:
    """Eq. 5 — million traversed edges per second."""
    return total_edges / t_execution / 1e6


def initial_task_mapping(host: PlatformSpec, accel: PlatformSpec,
                         n_accel: int, total_batch: int,
                         fanouts: Tuple[int, ...],
                         layer_dims: Tuple[int, ...],
                         model: str = "sage",
                         cache_hit_rate: float = 0.0,
                         dedup_factor: float = 1.0,
                         feature_tier: str = "ram",
                         prefetch_overlap: float = 0.0,
                         peer_hit_rate: float = 0.0,
                         union_factor: float = 1.0,
                         refresh_bytes_per_iter: float = 0.0
                         ) -> Dict[str, int]:
    """Coarse-grained design-time mapping (paper §IV-A first paragraph).

    Chooses the CPU trainer's mini-batch share so the predicted CPU
    propagation time matches the accelerators' bundled transfer+propagation
    time; solved by scanning the (integer) share space with the performance
    model — robust for any platform pair, no closed form needed.

    ``cache_hit_rate`` is the device cache's design-time hit estimate
    (``FeatureCache.expected_hit_rate``) and ``dedup_factor`` the measured
    frontier duplication factor alpha (unique-miss rows / positional miss
    rows — the same definition at design time, from a cache-classified
    probe mini-batch, and at runtime, from measured loader stats): both
    shrink the accelerators' load/transfer terms, which shifts the optimum
    toward larger accelerator shares.  The CPU trainer reads host memory
    directly and benefits from neither (its rows never cross PCIe).

    ``feature_tier="disk"`` prices every trainer's load stage (CPU and
    accelerator alike — they gather from the same host FeatureSource) at
    the host's storage bandwidth, shifting work toward whichever side
    hides the slower gather better; ``prefetch_overlap`` discounts the
    disk tier's storage penalty by the fraction the background window
    prefetcher hides (both trainer kinds gather through the same
    prefetched page cache, so both carry it).

    ``peer_hit_rate`` and ``union_factor`` are the sharded-plane terms
    (peer-shard service rate and union-gather multicast factor): both
    shrink the accelerators' host-side load/PCIe terms (peer rows ride
    the ICI instead), again shifting the optimum toward larger
    accelerator shares.  The CPU trainer carries neither.

    ``refresh_bytes_per_iter`` is the dynamic cache's measured admission
    traffic (swapped rows x row bytes amortized over the drift interval):
    it taxes the host gather and the PCIe leg the accelerators depend on,
    shifting the optimum toward the CPU trainer under refresh churn.
    """
    best: Tuple[float, int] = (float("inf"), 0)
    step = max(1, total_batch // 64)
    for cpu_share in range(0, total_batch // 2 + 1, step):
        accel_share = (total_batch - cpu_share) // max(n_accel, 1)
        w_cpu = WorkloadSpec(cpu_share, fanouts, layer_dims, model=model,
                             feature_tier=feature_tier,
                             prefetch_overlap=prefetch_overlap)
        w_acc = WorkloadSpec(accel_share, fanouts, layer_dims, model=model,
                             cache_hit_rate=cache_hit_rate,
                             dedup_factor=dedup_factor,
                             feature_tier=feature_tier,
                             prefetch_overlap=prefetch_overlap,
                             peer_hit_rate=peer_hit_rate,
                             union_factor=union_factor,
                             refresh_bytes_per_iter=refresh_bytes_per_iter)
        pred = predict(host, accel, n_accel, w_cpu, w_acc)
        if pred.t_execution < best[0]:
            best = (pred.t_execution, cpu_share)
    cpu_share = best[1]
    return {"cpu": cpu_share,
            "accel_each": (total_batch - cpu_share) // max(n_accel, 1)}


# --------------------------------------------------------------------------
# Knob-space model for the online DRM autotuner (docs/drm-autotuning.md)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KnobState:
    """The knob vector the DRM's online autotuner searches.

    Every knob here is performance-only: none touches RNG streams, batch
    composition or assembled feature values, so any trajectory through
    knob space leaves training losses bit-identical to a static run.
    Workload *shares* (cpu/accel batch split) are deliberately absent —
    those stay with Algorithm 1's balance_work and the mapping re-price.
    """
    prefetch_windows: int = 0     # WindowPrefetcher queue depth (0 = off)
    mmap_lru_windows: int = 0     # MmapFeatures window bound (0 = unbounded)
    sample_threads: int = 2       # Assignment.threads["sample"]
    load_threads: int = 2         # Assignment.threads["load"] (gather pool)
    train_threads: int = 2        # Assignment.threads["train"]
    refresh_period: int = 1       # iterations between refresh drift checks
    refresh_frac: float = 0.25    # max fraction of cache slots swapped

    @property
    def total_threads(self) -> int:
        return self.sample_threads + self.load_threads + self.train_threads


@dataclasses.dataclass(frozen=True)
class KnobBounds:
    """Hard feasibility box for autotuner proposals.

    Defaults freeze every subsystem-dependent knob (``lo == hi``): the
    trainer widens exactly the ranges whose subsystems exist (a prefetch
    range only when the source can ``prefetch_rows``, refresh ranges only
    with a dynamic cache).  Thread knobs are bounded by conservation —
    the proposal must keep the total thread count and give every stage at
    least ``min_stage_threads`` — matching balance_thread's invariant.
    """
    prefetch_windows: Tuple[int, int] = (0, 0)
    mmap_lru_windows: Tuple[int, int] = (0, 0)
    min_stage_threads: int = 1
    total_threads: int = 6
    refresh_period: Tuple[int, int] = (1, 1)
    refresh_frac: Tuple[float, float] = (0.25, 0.25)

    def contains(self, k: KnobState) -> bool:
        def _in(v, box):
            return box[0] <= v <= box[1]
        return (_in(k.prefetch_windows, self.prefetch_windows)
                and _in(k.mmap_lru_windows, self.mmap_lru_windows)
                and _in(k.refresh_period, self.refresh_period)
                and _in(k.refresh_frac, self.refresh_frac)
                and min(k.sample_threads, k.load_threads,
                        k.train_threads) >= self.min_stage_threads
                and k.total_threads == self.total_threads)


@dataclasses.dataclass(frozen=True)
class SignalSnapshot:
    """Measured signals for one autotune window (stage-time means plus
    counter deltas), the calibration input of ``CalibratedKnobModel``.

    Time fields mirror ``drm.StageTimes`` (kept scalar here so the model
    layer stays import-free of the DRM layer).  Counter-derived fields
    are window deltas normalized per iteration where noted.
    """
    t_sc: float = 0.0
    t_sa: float = 0.0
    t_load: float = 0.0
    t_load_stall: float = 0.0     # exposed storage stall inside t_load
    t_tran: float = 0.0
    t_tc: float = 0.0
    t_ta: float = 0.0
    dup_factor: float = 1.0       # LoadStats.dup_factor over the window
    hit_rate: float = 0.0         # cache hit rate over the window
    prefetch_hit_rate: float = 0.0   # warm window touches / all touches
    prefetch_drop_rate: float = 0.0  # queue-full drops / submits
    touched_windows: float = 0.0  # mmap windows the load stage touches/iter
    loaded_rows_per_iter: float = 0.0
    refresh_bytes_per_iter: float = 0.0  # admission traffic at ref knobs
    hit_decay_per_iter: float = 0.0      # hit-rate points lost per
                                         # iteration since the last refresh
    row_bytes: int = 4
    disk_tier: bool = False


@dataclasses.dataclass(frozen=True)
class CalibratedKnobModel:
    """Eq. 7/8-grounded predictor over the autotuner's knob space.

    Anchored on measurement: stage times come from a real window at the
    reference knobs ``ref`` and only the knob-sensitive *components* are
    re-priced —

      * CPU-stage compute scales inversely with the stage's thread share
        (balance_thread's own assumption),
      * the exposed storage stall is split out of ``t_load`` and scaled
        by the prefetch subsystem's predicted coverage: queue depth sets
        the drop rate of the advisory (lossy) submit path, and the window
        LRU must hold the per-iteration working set or a prefetched
        window is evicted before its gather (Eq. 7's storage penalty x
        (1 - overlap) term, with overlap now a function of the knobs),
      * refresh cadence/frac trade the measured admission traffic
        (priced at the tier and PCIe bandwidths — the Eq. 7/8 refresh
        term) against hit-rate staleness (a slower cadence lets the
        measured decay run longer, and the extra unique misses are
        priced as load + transfer traffic).

    The predictor is advisory: the autotuner verifies every accepted move
    against *measured* iteration time and rolls back past the hysteresis
    band, so a mis-calibrated sensitivity costs one trial window, never a
    run.
    """
    host: PlatformSpec
    accel: PlatformSpec
    ref: KnobState
    signals: SignalSnapshot
    overlap_cap: float = 0.95     # prefetch can never hide the last 5%

    # ------------------------------------------------------------ pricing

    def _load_bw(self) -> float:
        s = self.signals
        bw = self.host.mem_bw_gbps
        if s.disk_tier and self.host.storage_bw_gbps > 0.0:
            bw = min(bw, self.host.storage_bw_gbps)
        return max(bw, 1e-3) * 1e9

    def _pcie_bw(self) -> float:
        return max(self.accel.interconnect_gbps, 1e-3) * 1e9

    def _coverage(self, k: KnobState) -> float:
        """Predicted fraction of the storage stall the prefetch subsystem
        hides at knobs ``k`` (the Eq. 7 overlap term as a knob function)."""
        if k.prefetch_windows <= 0:
            return 0.0
        s, r = self.signals, self.ref
        if r.prefetch_windows > 0 and s.prefetch_drop_rate > 0.0:
            # the submit path is lossy: a full queue drops the request.
            # Halving the depth roughly doubles the measured drop rate,
            # doubling it halves it (M/M/1-ish occupancy scaling).
            drop = min(s.prefetch_drop_rate
                       * r.prefetch_windows / k.prefetch_windows, 1.0)
        else:
            # no measurement at this depth yet: saturating prior — each
            # extra queue slot halves the chance a submit finds it full
            drop = 0.5 ** k.prefetch_windows
        depth_term = max(1.0 - drop, 0.0)
        # a prefetched window must survive until its gather: an LRU bound
        # below the per-iteration working set evicts it first
        ws = max(self.signals.touched_windows, 1.0)
        lru_term = (1.0 if k.mmap_lru_windows <= 0
                    else min(1.0, k.mmap_lru_windows / ws))
        return self.overlap_cap * depth_term * lru_term

    def _stall(self, k: KnobState) -> float:
        """Predicted exposed storage stall (seconds) at knobs ``k``."""
        s, r = self.signals, self.ref
        exposed = min(max(s.t_load_stall, 0.0), max(s.t_load, 0.0))
        if exposed <= 0.0:
            return 0.0
        # reconstruct the *full* storage penalty from the exposed share:
        # at the reference knobs the prefetcher already hid
        # prefetch_hit_rate of the window touches
        full = exposed
        if r.prefetch_windows > 0:
            hidden = min(max(s.prefetch_hit_rate, 0.0), self.overlap_cap)
            full = exposed / max(1.0 - hidden, 1.0 - self.overlap_cap)
        return full * (1.0 - self._coverage(k))

    def _admission_scale(self, k: KnobState) -> float:
        """Admission bytes/iter at ``k`` relative to the reference: a
        longer period amortizes further, a larger frac swaps more rows."""
        r = self.ref
        return ((r.refresh_period / max(k.refresh_period, 1))
                * (k.refresh_frac / max(r.refresh_frac, 1e-9)))

    def _staleness_rows(self, k: KnobState) -> float:
        """Extra unique miss rows per iteration from cache staleness at
        cadence ``k.refresh_period`` relative to the reference (negative
        = a faster cadence recovers hits).  Calibrated from the measured
        per-iteration hit decay; 0 when no decay was observed."""
        s, r = self.signals, self.ref
        if s.hit_decay_per_iter <= 0.0 or s.loaded_rows_per_iter <= 0.0:
            return 0.0
        # average staleness ~ period/2 iterations of decay
        d_hit = s.hit_decay_per_iter * (k.refresh_period
                                        - r.refresh_period) / 2.0
        d_hit = min(max(d_hit, -(1.0 - s.hit_rate)), s.hit_rate)
        return s.loaded_rows_per_iter * d_hit / max(s.dup_factor, 1.0)

    # ------------------------------------------------------------ predict

    def predict(self, k: KnobState) -> float:
        """Predicted iteration time (max over stages, Eq. 6) at ``k``."""
        s, r = self.signals, self.ref

        def scale(ref_n: int, new_n: int) -> float:
            return ref_n / max(new_n, 1)

        t_sc = s.t_sc * scale(r.sample_threads, k.sample_threads)
        t_tc = s.t_tc * scale(r.train_threads, k.train_threads)
        stall_ref = min(max(s.t_load_stall, 0.0), max(s.t_load, 0.0))
        gather = ((s.t_load - stall_ref)
                  * scale(r.load_threads, k.load_threads))
        adm_bytes = (max(s.refresh_bytes_per_iter, 0.0)
                     * self._admission_scale(k))
        stale_bytes = self._staleness_rows(k) * s.row_bytes
        t_load_k = max(gather + self._stall(k)
                       + (adm_bytes + stale_bytes) / self._load_bw(), 0.0)
        t_tran_k = max(s.t_tran
                       + (adm_bytes + stale_bytes) / self._pcie_bw(), 0.0)
        return max(s.t_sa, t_sc, t_load_k, t_tran_k, t_tc, s.t_ta)


def calibrate_sampling(sampler_fn: Callable[[int], None],
                       batch_sizes: Sequence[int],
                       repeats: int = 3) -> Dict[int, float]:
    """T_samp is measured, not modeled (paper §V): run the sampling
    algorithm at each batch size during the design phase."""
    table: Dict[int, float] = {}
    for b in batch_sizes:
        sampler_fn(b)  # warmup
        t0 = time.perf_counter()
        for _ in range(repeats):
            sampler_fn(b)
        table[b] = (time.perf_counter() - t0) / repeats
    return table


def predict_epoch_time(num_nodes: int, total_batch: int,
                       pred: StagePrediction) -> float:
    iters = int(np.ceil(num_nodes / total_batch))
    return iters * pred.t_execution
