"""Parameter-sharding rule table (FSDP x TP).

``param_pspec(path, leaf)`` maps one parameter (or optimizer-state) leaf to
a ``PartitionSpec`` against the ambient mesh:

  * norm scales / biases / 0-1D leaves: replicated,
  * >=2-D weights: last dim over ``model`` (tensor parallelism), the
    second-to-last dim over the data-parallel axes (FSDP) — each only when
    the dim size divides the axis product,
  * under the 'dp' policy everything is replicated (classic DP),
  * with no ambient mesh every spec degrades to fully-replicated ``None``s
    (the rule table itself is exercised in the multi-device dry-run).

Stacked-layer leading dims ([L, ...] from the per-layer vmap) are never
sharded: the layer scan iterates that axis, so sharding it would gather a
layer per step.
"""
from __future__ import annotations

from typing import Any, Tuple

from jax.sharding import PartitionSpec as P

from . import current_mesh, current_policy

__all__ = ["param_pspec"]

_REPLICATED_NAMES = ("ln", "norm", "scale", "bias", "step", "count")


def _path_keys(path) -> Tuple[str, ...]:
    return tuple(str(getattr(p, "key", getattr(p, "name", p))) for p in path)


def param_pspec(path: Any, leaf: Any) -> P:
    nd = int(leaf.ndim)
    mesh = current_mesh()
    if mesh is None or mesh.size == 1 or nd < 2 \
            or current_policy() == "dp":
        return P(*([None] * nd))
    keys = _path_keys(path)
    name = keys[-1] if keys else ""
    if any(name.startswith(r) or r in name for r in _REPLICATED_NAMES):
        return P(*([None] * nd))

    dims: list = [None] * nd
    shape = getattr(leaf, "shape", None)
    msize = mesh.shape.get("model", 1)
    dp_axes = tuple(n for n in ("pod", "data") if n in mesh.axis_names)
    dp_size = 1
    for n in dp_axes:
        dp_size *= mesh.shape[n]
    if msize > 1 and (shape is None or shape[-1] % msize == 0):
        dims[-1] = "model"
    if dp_size > 1 and (shape is None or shape[-2] % dp_size == 0):
        dims[-2] = dp_axes[0] if len(dp_axes) == 1 else dp_axes
    return P(*dims)
