"""Hierarchical gradient-mean collective schedule.

A flat ``psum`` over every device sends whole-gradient traffic across the
slow pod interconnect.  The hierarchical schedule does the classic three
phases instead:

  1. reduce-scatter *within* each pod (over the fast local axes), so each
     device owns a 1/k shard of the local sum,
  2. all-reduce the shards *across* pods (only 1/k of the bytes cross the
     slow links),
  3. all-gather within the pod to rebuild the full mean.

Leaves whose leading dim the local axes do not divide (scalars, small
biases) fall back to a flat psum — same result, negligible bytes.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import current_mesh, shard_map_compat

__all__ = ["hierarchical_psum_mean"]


def hierarchical_psum_mean(tree: Any) -> Any:
    """Mean of the per-device values of ``tree`` (replicated in, replicated
    out), scheduled reduce-scatter -> cross-pod all-reduce -> all-gather.

    Must run under ``use_mesh`` (jit-traced against the ambient mesh).
    """
    mesh = current_mesh()
    if mesh is None or mesh.size == 1:
        return tree
    local = tuple(n for n in mesh.axis_names if n != "pod")
    pod = "pod" if "pod" in mesh.axis_names else None
    local_size = 1
    for n in local:
        local_size *= mesh.shape[n]
    n_total = mesh.size

    def body(*leaves):
        out = []
        for v in leaves:
            if (local and local_size > 1 and v.ndim >= 1
                    and v.shape[0] % local_size == 0):
                s = jax.lax.psum_scatter(v, local, scatter_dimension=0,
                                         tiled=True)
                if pod is not None:
                    s = jax.lax.psum(s, pod)
                s = jax.lax.all_gather(s, local, axis=0, tiled=True)
            else:
                axes = local + ((pod,) if pod is not None else ())
                s = jax.lax.psum(v, axes)
            out.append((s / n_total).astype(v.dtype))
        return tuple(out)

    leaves, treedef = jax.tree.flatten(tree)
    specs = tuple(P() for _ in leaves)
    fn = shard_map_compat(body, mesh, in_specs=specs, out_specs=specs)
    return jax.tree.unflatten(treedef, fn(*leaves))
