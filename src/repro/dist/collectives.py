"""Hierarchical gradient-mean collective schedule.

A flat ``psum`` over every device sends whole-gradient traffic across the
slow pod interconnect.  The hierarchical schedule does the classic three
phases instead:

  1. reduce-scatter *within* each pod (over the fast local axes), so each
     device owns a 1/k shard of the local sum,
  2. all-reduce the shards *across* pods (only 1/k of the bytes cross the
     slow links),
  3. all-gather within the pod to rebuild the full mean.

Leaves whose leading dim the local axes do not divide (scalars, small
biases) fall back to a flat psum — same result, negligible bytes.

This module also hosts the *peer feature exchange* for the sharded
hot-feature plane (``graph.featcache.ShardedFeatureCache``): each
accelerator pins a disjoint hot shard, and a frontier row that misses
locally but is resident on a peer shard is served with one on-peer
gather plus one row hop over the accelerator interconnect (ICI) instead
of a host PCIe ship.  ``exchange_peer_rows`` walks the requests in
deterministic ring order (me+1, me+2, ..., wrap) — the schedule every
trainer derives identically, so an all-to-all of such exchanges never
deadlocks and the combined transfer-source layout is reproducible.
"""
from __future__ import annotations

from typing import Any, Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import current_mesh, shard_map_compat

__all__ = ["exchange_peer_rows", "hierarchical_psum_mean",
           "peer_gather_rows", "ring_order"]


def ring_order(n: int, me: int) -> List[int]:
    """Deterministic ring schedule of the other ``n - 1`` ordinals as
    seen from ``me``: (me+1) % n, (me+2) % n, ...  Step s of the
    all-to-all pairs every trainer with a distinct peer (i talks to
    i+s while i-s talks to i), so no link is oversubscribed and every
    participant derives the same global schedule locally."""
    n = int(n)
    me = int(me) % max(n, 1)
    return [(me + s) % n for s in range(1, n)]


def peer_gather_rows(block: jax.Array, slots, dest_device,
                     use_pallas: bool = False,
                     pipeline_depth: int = 1) -> jax.Array:
    """Serve one peer request: gather ``slots`` rows out of the owner
    shard's device-resident ``block`` (on the owner's device — the
    Pallas path reuses the tiled combine machinery via
    ``kernels.ops.gather_rows``), then ship only those rows to
    ``dest_device`` in one hop (the ICI transfer; on the CPU test mesh
    the hop is a same-backend ``device_put``)."""
    from repro.kernels.ops import gather_rows
    rows = gather_rows(block, slots, use_pallas=use_pallas,
                       pipeline_depth=pipeline_depth)
    return jax.device_put(rows, dest_device)


def exchange_peer_rows(requests: Sequence[Tuple[int, Any, int]],
                       block_of: Callable[[int, int], jax.Array],
                       dest_device,
                       use_pallas: bool = False,
                       pipeline_depth: int = 1) -> List[jax.Array]:
    """Pull the requested rows from each peer shard, in the ring order
    the requests were built in.

    ``requests`` is one trainer's ``ShardLookup.peer_requests`` —
    ``(peer ordinal, slots into the peer block, peer version)`` tuples —
    and ``block_of(peer, version)`` resolves the peer shard's
    device-resident block at the pinned version (the caller holds the
    pins, so the block cannot be retired mid-exchange).  Returns one
    row-block per request, in request order: exactly the leading
    segments of the combined transfer source the union lookup's
    ``miss_index`` addresses."""
    out: List[jax.Array] = []
    for peer, slots, version in requests:
        block = block_of(int(peer), int(version))
        out.append(peer_gather_rows(block, slots, dest_device,
                                    use_pallas=use_pallas,
                                    pipeline_depth=pipeline_depth))
    return out


def hierarchical_psum_mean(tree: Any) -> Any:
    """Mean of the per-device values of ``tree`` (replicated in, replicated
    out), scheduled reduce-scatter -> cross-pod all-reduce -> all-gather.

    Must run under ``use_mesh`` (jit-traced against the ambient mesh).
    """
    mesh = current_mesh()
    if mesh is None or mesh.size == 1:
        return tree
    local = tuple(n for n in mesh.axis_names if n != "pod")
    pod = "pod" if "pod" in mesh.axis_names else None
    local_size = 1
    for n in local:
        local_size *= mesh.shape[n]
    n_total = mesh.size

    def body(*leaves):
        out = []
        for v in leaves:
            if (local and local_size > 1 and v.ndim >= 1
                    and v.shape[0] % local_size == 0):
                s = jax.lax.psum_scatter(v, local, scatter_dimension=0,
                                         tiled=True)
                if pod is not None:
                    s = jax.lax.psum(s, pod)
                s = jax.lax.all_gather(s, local, axis=0, tiled=True)
            else:
                axes = local + ((pod,) if pod is not None else ())
                s = jax.lax.psum(v, axes)
            out.append((s / n_total).astype(v.dtype))
        return tuple(out)

    leaves, treedef = jax.tree.flatten(tree)
    specs = tuple(P() for _ in leaves)
    fn = shard_map_compat(body, mesh, in_specs=specs, out_specs=specs)
    return jax.tree.unflatten(treedef, fn(*leaves))
