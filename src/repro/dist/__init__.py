"""Sharding context + constraint helpers shared by every model layer.

The model code never talks to ``jax.sharding`` directly: layers call
``constrain``/``constrain_act``/``constrain_proj`` with *logical* axis
tuples (e.g. ``("pod", "data")`` for the batch dim) and this module decides
what survives on the current mesh:

  * axes absent from the active mesh are dropped (a single-host run with no
    mesh turns every constraint into the identity — zero overhead on the
    CPU container),
  * a mesh axis is never used twice inside one ``PartitionSpec`` (first
    occurrence wins), so composed specs like ``(("pod","data"), ("data",
    "model"))`` stay valid on any mesh shape,
  * dims whose size the mesh does not divide fall back to replicated.

The active mesh and parallelism policy are ambient context (``use_mesh`` /
``use_policy``), mirroring how the launch layer builds cells: the same
model source lowers to pure-DP, FSDPxTP ("tp2d"), weight-stationary decode
("serve2d") or expert-parallel ("ep") programs purely by context.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "use_mesh", "current_mesh", "use_policy", "current_policy",
    "pspec", "constrain", "constrain_act", "constrain_act_serve",
    "constrain_proj", "params_shardings", "shard_map_compat",
]

AxisDim = Union[None, str, Tuple[str, ...]]

_ctx = threading.local()


def _stack(name: str) -> list:
    st = getattr(_ctx, name, None)
    if st is None:
        st = []
        setattr(_ctx, name, st)
    return st


def current_mesh() -> Optional[Mesh]:
    """The ambient mesh set by ``use_mesh`` (None on single-host runs)."""
    st = _stack("mesh")
    return st[-1] if st else None


def current_policy() -> str:
    """The ambient parallelism policy ('tp2d' | 'dp' | 'serve2d' | 'ep')."""
    st = _stack("policy")
    return st[-1] if st else "tp2d"


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    """Set the ambient mesh.  ``use_mesh(None)`` is a supported no-op so
    callers can wrap single-device paths unconditionally."""
    _stack("mesh").append(mesh)
    try:
        yield mesh
    finally:
        _stack("mesh").pop()


@contextlib.contextmanager
def use_policy(policy: str):
    _stack("policy").append(policy)
    try:
        yield policy
    finally:
        _stack("policy").pop()


# ------------------------------------------------------------------- pspec


def _norm_dim(dim: AxisDim, mesh: Optional[Mesh], used: set) -> AxisDim:
    """Filter one PartitionSpec entry against the mesh + already-used axes."""
    if dim is None or mesh is None:
        return None
    names = (dim,) if isinstance(dim, str) else tuple(dim)
    names = tuple(n for n in names
                  if n in mesh.axis_names and n not in used)
    used.update(names)
    if not names:
        return None
    return names[0] if len(names) == 1 else names


def pspec(*dims: AxisDim) -> P:
    """Build a ``PartitionSpec``, dropping axes the current mesh lacks and
    deduplicating axes across dims (first occurrence wins).  With no
    ambient mesh every entry degrades to ``None`` (fully replicated)."""
    mesh = current_mesh()
    used: set = set()
    return P(*(_norm_dim(d, mesh, used) for d in dims))


def _axes_size(mesh: Mesh, dim: AxisDim) -> int:
    if dim is None:
        return 1
    names = (dim,) if isinstance(dim, str) else dim
    size = 1
    for n in names:
        size *= mesh.shape[n]
    return size


def _fit_spec(mesh: Mesh, shape: Sequence[int], spec: P) -> P:
    """Replace entries that do not divide the dim size with None."""
    out = []
    for size, dim in zip(shape, tuple(spec) + (None,) * len(shape)):
        out.append(dim if dim is None or size % _axes_size(mesh, dim) == 0
                   else None)
    return P(*out)


# --------------------------------------------------------------- constrain


def constrain(x: jax.Array, *dims: AxisDim) -> jax.Array:
    """``with_sharding_constraint`` against the ambient mesh; identity when
    no mesh is active (or the mesh is trivial)."""
    mesh = current_mesh()
    if mesh is None or mesh.size == 1:
        return x
    spec = _fit_spec(mesh, x.shape, pspec(*dims))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_proj(x: jax.Array, n_heads: int) -> jax.Array:
    """Constraint for attention projections [B, S, H*hd]: the head dim is
    model-sharded only when the head count divides the model axis."""
    mesh = current_mesh()
    if mesh is None or mesh.size == 1:
        return x
    msize = mesh.shape.get("model", 1)
    h_ax = "model" if msize > 1 and n_heads % msize == 0 else None
    return constrain(x, ("pod", "data"), None, h_ax)


def constrain_act(x: jax.Array) -> jax.Array:
    """Block-boundary activation constraint for [B, S, d] streams.

    tp2d: batch over (pod, data) and sequence over model — the remat
    residuals each layer saves shrink by 1/(dp*tp).  When the batch does
    not divide the dp axes (long-context, batch=1) the sequence absorbs
    them instead.  'dp' keeps activations batch-sharded only.
    """
    mesh = current_mesh()
    if mesh is None or mesh.size == 1 or x.ndim < 3:
        return x
    policy = current_policy()
    b, s = x.shape[0], x.shape[1]
    dp_size = _axes_size(mesh, tuple(n for n in ("pod", "data")
                                     if n in mesh.axis_names))
    if b % max(dp_size, 1) == 0:
        b_ax: AxisDim = ("pod", "data")
        s_ax: AxisDim = None if policy == "dp" else "model"
    else:
        b_ax = None
        s_ax = (("pod", "data") if policy == "dp"
                else ("pod", "data", "model"))
    return constrain(x, b_ax, s_ax, *([None] * (x.ndim - 3)))


def constrain_act_serve(x: jax.Array) -> jax.Array:
    """Decode-time activation constraint for [B, 1, d] token streams.

    Under 'serve2d' the batch keeps only the pod axis (the freed data axis
    splits the KV-cache length, see launch/cellspecs._cache_pspec);
    otherwise the batch spans (pod, data).
    """
    mesh = current_mesh()
    if mesh is None or mesh.size == 1:
        return x
    b_ax: AxisDim = (("pod",) if current_policy() == "serve2d"
                     else ("pod", "data"))
    return constrain(x, b_ax, *([None] * (x.ndim - 1)))


# ------------------------------------------------------- parameter shardings


def params_shardings(tree: Any, mesh: Mesh) -> Any:
    """NamedSharding pytree for parameters / optimizer state: the
    ``sharding.param_pspec`` rule table applied leaf-by-leaf."""
    from .sharding import param_pspec
    with use_mesh(mesh):
        return jax.tree_util.tree_map_with_path(
            lambda path, leaf: NamedSharding(mesh, param_pspec(path, leaf)),
            tree)


# ---------------------------------------------------------------- shard_map


def shard_map_compat(f, mesh, in_specs, out_specs):
    """Version-portable shard_map with replication checking disabled
    (jax<=0.4 spells the kwarg ``check_rep``, newer jax ``check_vma``)."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False)
        except TypeError:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)
