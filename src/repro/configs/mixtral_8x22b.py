"""mixtral-8x22b [moe] — 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, SWA.  [arXiv:2401.04088; hf]"""
from repro.models import ModelConfig

FULL = ModelConfig(
    name="mixtral-8x22b", kind="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv=8, d_ff=16384,
    vocab=32768, moe_experts=8, moe_top_k=2,
    window=4096,                      # sliding-window attention
    rope_theta=1e6,
)

REDUCED = ModelConfig(
    name="mixtral-8x22b-reduced", kind="moe",
    n_layers=4, d_model=128, n_heads=8, n_kv=2, d_ff=256,
    vocab=512, moe_experts=4, moe_top_k=2, window=64,
    dtype="float32", remat=False, q_block=32,
)
