"""musicgen-medium [audio] — 48L d_model=1536 24H (kv=24) d_ff=6144
vocab=2048 — decoder-only over EnCodec tokens.  [arXiv:2306.05284; hf]

The EnCodec frontend is a STUB: ``input_specs`` provides precomputed frame
embeddings (B, S, d_model); the transformer backbone is what we build.
GELU (non-gated) MLP, d_ff = 4·d_model.
"""
from repro.models import ModelConfig

FULL = ModelConfig(
    name="musicgen-medium", kind="dense",
    n_layers=48, d_model=1536, n_heads=24, n_kv=24, d_ff=6144,
    vocab=2048, mlp="gelu", frontend="audio_stub",
)

REDUCED = ModelConfig(
    name="musicgen-reduced", kind="dense",
    n_layers=4, d_model=128, n_heads=4, n_kv=4, d_ff=512,
    vocab=256, mlp="gelu", frontend="audio_stub",
    dtype="float32", remat=False, q_block=32,
)
