"""minitron-4b [dense] — 32L d_model=3072 24H (GQA kv=8) d_ff=9216
vocab=256000 — pruned nemotron.  [arXiv:2407.14679; hf]

The 256k vocab makes the embedding + head the dominant parameter block —
exercises the host-offloaded-embedding path (DESIGN.md §4).
"""
from repro.models import ModelConfig

FULL = ModelConfig(
    name="minitron-4b", kind="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv=8, d_ff=9216,
    vocab=256000,
)

REDUCED = ModelConfig(
    name="minitron-reduced", kind="dense",
    n_layers=4, d_model=128, n_heads=4, n_kv=2, d_ff=384,
    vocab=1024, dtype="float32", remat=False, q_block=32,
)
