"""internvl2-1b [vlm] — 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655 — InternViT + InternLM2.  [arXiv:2404.16821; hf]

The InternViT frontend is a STUB: ``input_specs`` provides 256 precomputed
patch embeddings per sample, prepended to the text tokens; only the
InternLM2-style language backbone is built.  vocab (151655) is padded to a
multiple of 128 for even mesh sharding; padded logits are masked in the
loss.
"""
from repro.models import ModelConfig

FULL = ModelConfig(
    name="internvl2-1b", kind="dense",
    n_layers=24, d_model=896, n_heads=14, n_kv=2, d_ff=4864,
    vocab=151655, frontend="vision_stub", vision_tokens=256,
)

REDUCED = ModelConfig(
    name="internvl2-reduced", kind="dense",
    n_layers=4, d_model=128, n_heads=4, n_kv=2, d_ff=320,
    vocab=512, frontend="vision_stub", vision_tokens=8,
    dtype="float32", remat=False, q_block=32,
)
