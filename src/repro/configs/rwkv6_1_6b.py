"""rwkv6-1.6b [ssm] — 24L d_model=2048 (attn-free) d_ff=7168 vocab=65536 —
Finch: data-dependent per-channel decay.  [arXiv:2404.05892; unverified]

Heads of size 64 (n_heads = d_model/64 = 32); n_kv mirrors n_heads (the
field is unused by the RWKV block but keeps the config uniform).
"""
from repro.models import ModelConfig

FULL = ModelConfig(
    name="rwkv6-1.6b", kind="rwkv",
    n_layers=24, d_model=2048, n_heads=32, n_kv=32, d_ff=7168,
    vocab=65536, head_dim=64,
)

REDUCED = ModelConfig(
    name="rwkv6-reduced", kind="rwkv",
    n_layers=4, d_model=128, n_heads=4, n_kv=4, d_ff=448,
    vocab=512, head_dim=32, dtype="float32", remat=False, q_block=32,
)
