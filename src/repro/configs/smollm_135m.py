"""smollm-135m [dense] — 30L d_model=576 9H (GQA kv=3) d_ff=1536
vocab=49152 — llama-arch small.  [hf:HuggingFaceTB/SmolLM-135M; hf]"""
from repro.models import ModelConfig

FULL = ModelConfig(
    name="smollm-135m", kind="dense",
    n_layers=30, d_model=576, n_heads=9, n_kv=3, d_ff=1536,
    vocab=49152,
)

REDUCED = ModelConfig(
    name="smollm-135m-reduced", kind="dense",
    n_layers=4, d_model=96, n_heads=3, n_kv=1, d_ff=256,
    vocab=512, dtype="float32", remat=False, q_block=32,
)
