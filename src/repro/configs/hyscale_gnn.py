"""The paper's own evaluation configs (Table III + Section VI-A2):
GCN / GraphSAGE, 2 layers, hidden 256, neighbor fanouts (25, 10),
mini-batch 1024, on ogbn-products / ogbn-papers100M / MAG240M(homo)."""
from repro.graph import GNNConfig

# name -> (dataset, GNNConfig)
PAPER_CONFIGS = {
    "gcn-products": ("ogbn-products",
                     GNNConfig(model="gcn", layer_dims=(100, 256, 47),
                               fanouts=(25, 10), num_classes=47)),
    "sage-products": ("ogbn-products",
                      GNNConfig(model="sage", layer_dims=(100, 256, 47),
                                fanouts=(25, 10), num_classes=47)),
    "gcn-papers100m": ("ogbn-papers100M",
                       GNNConfig(model="gcn", layer_dims=(128, 256, 172),
                                 fanouts=(25, 10), num_classes=172)),
    "sage-papers100m": ("ogbn-papers100M",
                        GNNConfig(model="sage", layer_dims=(128, 256, 172),
                                  fanouts=(25, 10), num_classes=172)),
    "gcn-mag240m": ("mag240m-homo",
                    GNNConfig(model="gcn", layer_dims=(756, 256, 153),
                              fanouts=(25, 10), num_classes=153)),
    "sage-mag240m": ("mag240m-homo",
                     GNNConfig(model="sage", layer_dims=(756, 256, 153),
                               fanouts=(25, 10), num_classes=153)),
}

PAPER_BATCH = 1024
PAPER_FANOUTS = (25, 10)
