"""Architecture registry: ``--arch <id>`` resolution for launchers/tests."""
from __future__ import annotations

from typing import Dict, Tuple

from repro.models import ModelConfig

from . import (internvl2_1b, llama3_2_1b, llama4_scout_17b_a16e,
               minitron_4b, mixtral_8x22b, musicgen_medium, rwkv6_1_6b,
               smollm_135m, smollm_360m, zamba2_7b)
from .shapes import SHAPES, ShapeSpec, cell_applicable, input_specs

__all__ = ["ARCHS", "get_arch", "SHAPES", "ShapeSpec", "cell_applicable",
           "input_specs", "all_cells"]

_MODULES = {
    "mixtral-8x22b": mixtral_8x22b,
    "llama4-scout-17b-a16e": llama4_scout_17b_a16e,
    "zamba2-7b": zamba2_7b,
    "musicgen-medium": musicgen_medium,
    "smollm-135m": smollm_135m,
    "smollm-360m": smollm_360m,
    "minitron-4b": minitron_4b,
    "llama3.2-1b": llama3_2_1b,
    "rwkv6-1.6b": rwkv6_1_6b,
    "internvl2-1b": internvl2_1b,
}

ARCHS: Dict[str, Tuple[ModelConfig, ModelConfig]] = {
    name: (mod.FULL, mod.REDUCED) for name, mod in _MODULES.items()
}


def get_arch(name: str, reduced: bool = False) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    full, red = ARCHS[name]
    return red if reduced else full


def all_cells():
    """Yield every (arch_name, cfg, shape_spec, runnable, skip_reason)."""
    for name, (full, _) in ARCHS.items():
        for shape in SHAPES.values():
            ok, reason = cell_applicable(full, shape)
            yield name, full, shape, ok, reason
