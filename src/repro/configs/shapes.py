"""Assigned input-shape set (one per LM arch, 4 shapes = 40 cells total).

  train_4k     seq 4,096   global_batch 256   lowers train_step
  prefill_32k  seq 32,768  global_batch 32    lowers prefill_step
  decode_32k   seq 32,768  global_batch 128   lowers serve_step (1 new token,
                                              KV/state cache of seq_len)
  long_500k    seq 524,288 global_batch 1     lowers serve_step; requires a
                                              sub-quadratic arch (SWA / SSM /
                                              hybrid / linear-attn) — skipped
                                              for pure full-attention archs
                                              (see DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import ModelConfig, init_decode_cache, init_params

__all__ = ["ShapeSpec", "SHAPES", "input_specs", "cell_applicable"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    step: str           # 'train' | 'prefill' | 'decode'


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k":    ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def cell_applicable(cfg: ModelConfig, shape: ShapeSpec
                    ) -> Tuple[bool, str]:
    """(runnable?, reason-if-skipped) for an (arch, shape) cell."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: 500k-token decode needs a "
                       "sub-quadratic mechanism (SWA/SSM/linear); skipped "
                       "per DESIGN.md §4")
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, object]:
    """ShapeDtypeStruct stand-ins for the *data* inputs of one step.

    Weak-type-correct, shardable, no device allocation.  Params and decode
    caches are built separately via ``jax.eval_shape`` in the launcher.
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if shape.step == "decode":
        return {"tokens": sds((b, 1), i32)}
    if cfg.frontend == "audio_stub":
        # EnCodec frontend stub: precomputed frame embeddings
        batch = {"embeds": sds((b, s, cfg.d_model), cfg.jdtype)}
        if shape.step == "train":
            batch["labels"] = sds((b, s), i32)
        return batch
    if cfg.frontend == "vision_stub":
        nv = cfg.vision_tokens
        batch = {"tokens": sds((b, s - nv), i32),
                 "vision_embeds": sds((b, nv, cfg.d_model), cfg.jdtype)}
        if shape.step == "train":
            batch["labels"] = sds((b, s - nv), i32)
        return batch
    batch = {"tokens": sds((b, s), i32)}
    if shape.step == "train":
        batch["labels"] = sds((b, s), i32)
    return batch
