"""llama4-scout-17b-a16e [moe] — 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.models import ModelConfig

FULL = ModelConfig(
    name="llama4-scout-17b-a16e", kind="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv=8, d_ff=8192,
    vocab=202048, moe_experts=16, moe_top_k=1,
    rope_theta=5e5,
)

REDUCED = ModelConfig(
    name="llama4-scout-reduced", kind="moe",
    n_layers=4, d_model=128, n_heads=8, n_kv=2, d_ff=192,
    vocab=640, moe_experts=4, moe_top_k=1,
    dtype="float32", remat=False, q_block=32,
)
