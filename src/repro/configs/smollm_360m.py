"""smollm-360m [dense] — 32L d_model=960 15H (GQA kv=5) d_ff=2560
vocab=49152 — llama-arch small.  [hf:HuggingFaceTB/SmolLM-135M; hf]"""
from repro.models import ModelConfig

FULL = ModelConfig(
    name="smollm-360m", kind="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv=5, d_ff=2560,
    vocab=49152,
)

REDUCED = ModelConfig(
    name="smollm-360m-reduced", kind="dense",
    n_layers=4, d_model=128, n_heads=4, n_kv=2, d_ff=320,
    vocab=512, dtype="float32", remat=False, q_block=32,
)
