from .registry import (ARCHS, SHAPES, ShapeSpec, all_cells, cell_applicable,
                       get_arch, input_specs)
from .hyscale_gnn import PAPER_CONFIGS, PAPER_BATCH, PAPER_FANOUTS

__all__ = ["ARCHS", "SHAPES", "ShapeSpec", "all_cells", "cell_applicable",
           "get_arch", "input_specs", "PAPER_CONFIGS", "PAPER_BATCH",
           "PAPER_FANOUTS"]
