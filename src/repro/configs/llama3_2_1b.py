"""llama3.2-1b [dense] — 16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256 — small llama3.  [hf:meta-llama/Llama-3.2-1B; unverified]"""
from repro.models import ModelConfig

FULL = ModelConfig(
    name="llama3.2-1b", kind="dense",
    n_layers=16, d_model=2048, n_heads=32, n_kv=8, d_ff=8192,
    vocab=128256, rope_theta=5e5,
)

REDUCED = ModelConfig(
    name="llama3.2-reduced", kind="dense",
    n_layers=4, d_model=128, n_heads=8, n_kv=2, d_ff=512,
    vocab=512, dtype="float32", remat=False, q_block=32,
)
