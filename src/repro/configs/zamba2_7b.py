"""zamba2-7b [hybrid] — 81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000,
ssm_state=64 — Mamba2 backbone + shared attention block applied every 6
Mamba layers (weights shared across sites).  [arXiv:2411.15242; unverified]

Structure here: 13 super-blocks of (6 Mamba-2 layers + shared attn+FFN) plus
3 tail Mamba layers = 81 Mamba layers total.
"""
from repro.models import ModelConfig

FULL = ModelConfig(
    name="zamba2-7b", kind="zamba",
    n_layers=81, d_model=3584, n_heads=32, n_kv=32, d_ff=14336,
    vocab=32000, ssm_state=64, ssm_head_dim=64, mamba_per_attn=6,
)

REDUCED = ModelConfig(
    name="zamba2-reduced", kind="zamba",
    n_layers=7, d_model=128, n_heads=4, n_kv=4, d_ff=256,
    vocab=512, ssm_state=16, ssm_head_dim=32, mamba_per_attn=3,
    dtype="float32", remat=False, q_block=32,
)
