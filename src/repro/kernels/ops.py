"""jit'd public wrappers around the Pallas kernels.

Handles padding to MXU-aligned tile multiples, 2-D reshaping of vector
operands (TPU lanes want >=2-D), and dispatch between the Pallas path and
the pure-jnp reference (``use_pallas=False`` or non-TPU-friendly shapes).

On this CPU container kernels run in ``interpret=True`` mode (the kernel
body executes in Python for correctness validation); on a real TPU the same
``pallas_call`` compiles to Mosaic.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .flash_attention import flash_attention_call
from .gather_scatter_mm import (cache_combine_kernel_call,
                                cache_combine_pipelined_kernel_call,
                                cache_combine_tiled_kernel_call,
                                cache_update_kernel_call,
                                cache_update_pipelined_kernel_call,
                                fused_update_kernel_call,
                                segment_sum_kernel_call)

__all__ = ["segment_weighted_sum_regular", "fused_gnn_update",
           "flash_attention", "assemble_features",
           "assemble_features_sharded", "gather_rows",
           "update_cache_rows"]

_INTERPRET = jax.default_backend() != "tpu"


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pick_tile(dim: int, pref: int = 128, floor: int = 8) -> int:
    """Largest power-of-two tile <= pref that keeps padding waste < 2x."""
    t = pref
    while t > floor and _round_up(dim, t) >= 2 * dim and dim > 0:
        t //= 2
    return max(t, floor)


def assemble_features(cache: Optional[jax.Array], miss: jax.Array,
                      slots, miss_index, use_pallas: bool = False,
                      pipeline_depth: int = 1) -> jax.Array:
    """Assemble the dense positional layer-0 feature block from the
    device-resident hot cache + the transferred unique-miss rows (see
    graph/featcache.py).  Under frontier dedup the index tables point many
    positions at one shipped row, so this step *is* the paper's Feature
    Duplicator, run on the destination device after the interconnect.

    ``cache=None`` marks the cache-less dedup path (every position reads
    the miss block).

    ``slots``/``miss_index`` are accepted as host numpy (they are
    host-produced by the cache lookup); the Pallas path derives its DMA
    schedule from them on the host before anything touches the device.

    No VJP needed: layer-0 inputs are data, not parameters, so this sits
    outside the autodiff region of the train step.

    ``use_pallas`` dispatches to the multi-row tiled combine kernel (the
    real TPU path); the default jnp path (XLA gather + select) is faster
    under interpret mode on CPU, where each Pallas grid step runs in
    Python.

    ``pipeline_depth`` (Pallas path only) selects how many tile windows
    the combine kernel keeps in flight: 1 = the single-buffered
    BlockSpec-driven kernel (DMAs serialized before each tile's compute),
    2-4 = the multi-buffered kernel that overlaps tile i+1's window copy
    with tile i's MXU expansion.  All depths are bit-identical.
    """
    if not use_pallas:
        return _assemble_ref(cache, miss, jnp.asarray(slots),
                             jnp.asarray(miss_index))
    return _assemble_tiled(cache, miss, np.asarray(slots),
                           np.asarray(miss_index),
                           depth=int(pipeline_depth))


def gather_rows(block: jax.Array, slots, use_pallas: bool = False,
                pipeline_depth: int = 1) -> jax.Array:
    """Gather ``slots`` rows out of a device-resident [K, F] block —
    the peer-serve half of the sharded plane's row exchange (the owner
    shard reads the requested rows before the ICI hop).

    The jnp path is one XLA take.  ``use_pallas`` reuses the tiled
    combine machinery as a pure gather: every requested row is a "cache
    hit" of the block, the miss source is empty, so the sort-by-rank
    schedule, 4W VMEM window and multi-buffered DMA pipeline all apply
    unchanged (bit-identical across paths and depths).
    """
    slots = np.asarray(slots, dtype=np.int32)
    if not use_pallas or slots.shape[0] == 0:
        return _gather_ref(block, jnp.asarray(slots))
    miss_index = np.zeros(slots.shape[0], dtype=np.int32)
    return _assemble_tiled(block,
                           jnp.zeros((1, block.shape[1]), block.dtype),
                           slots, miss_index, depth=int(pipeline_depth))


@jax.jit
def _gather_ref(block: jax.Array, slots: jax.Array) -> jax.Array:
    return jnp.take(block, slots, axis=0)


def assemble_features_sharded(cache: Optional[jax.Array], sources,
                              slots, miss_index, use_pallas: bool = False,
                              pipeline_depth: int = 1) -> jax.Array:
    """Shard-aware assemble: like ``assemble_features`` but the miss
    source arrives as an ordered list of device-resident row blocks —
    the peer-fetched segments (ring order) followed by the fresh
    host-shipped rows.  They are concatenated on device into the one
    combined source the union lookup's ``miss_index`` addresses, then
    dispatched through the same combine machinery; ``cache`` is the
    trainer's LOCAL shard block."""
    sources = [s for s in sources if int(s.shape[0])]
    if not sources:
        miss = None
    elif len(sources) == 1:
        miss = sources[0]
    else:
        miss = jnp.concatenate(sources, axis=0)
    if miss is None:
        f = cache.shape[1] if cache is not None else 1
        dtype = cache.dtype if cache is not None else jnp.float32
        miss = jnp.zeros((1, f), dtype)
    return assemble_features(cache, miss, slots, miss_index,
                             use_pallas=use_pallas,
                             pipeline_depth=pipeline_depth)


@jax.jit
def _assemble_ref(cache: Optional[jax.Array], miss: jax.Array,
                  slots: jax.Array, miss_index: jax.Array) -> jax.Array:
    if cache is None:
        cache = jnp.zeros((1, miss.shape[1]), miss.dtype)
    if miss.shape[0] == 0:
        # keep the gather well-defined when every row hits the cache
        miss = jnp.zeros((1, cache.shape[1]), cache.dtype)
    return ref.assemble_features(cache, miss, slots, miss_index)


def _assemble_tiled(cache: Optional[jax.Array], miss: jax.Array,
                    slots: np.ndarray, miss_index: np.ndarray,
                    depth: int = 1) -> jax.Array:
    """Host-side sort-by-source-row schedule for the tiled combine kernel.

    The positional gather is recast as a *dense-rank expansion*: the
    distinct cache slots the batch references are compacted to ranks
    [0, H) and the distinct referenced miss rows to ranks [Hp, Hp+M) (two
    device-local ``take``s of unique rows — U-scale work, not N-scale).
    Every rank below the bounded pad gaps is referenced by >= 1 position, so
    after sorting positions by rank each T_N output tile reads a monotone
    rank run whose whole span provably fits in four aligned W-row blocks
    of the dense source — the scalar-prefetched per-tile ``base`` block
    index steers those DMAs and ``local`` addresses rows inside the 4W
    VMEM window.  The kernel writes sorted rows; one XLA take un-permutes
    (each positional row is produced exactly once, a bandwidth-bound
    copy).  All schedule tables are cheap O(N log N) host numpy, part of
    the load stage like the paper's edge sorting.
    """
    n = int(slots.shape[0])
    f = int(miss.shape[1])
    hit = slots >= 0
    w = _pick_tile(n, 128)
    t_f = _pick_tile(f)
    # dense ranks: distinct referenced cache rows first, then distinct
    # referenced miss rows — density is *constructed* (not assumed of the
    # caller), so every rank below the bounded pad gaps is referenced.
    # Both compact blocks are bucketed to W multiples so jit recompiles
    # stay bounded; each pad gap is unreferenced and <= W-1 rows.
    distinct_hit = np.unique(slots[hit]).astype(np.int32)
    h = int(distinct_hit.shape[0])
    hp = _round_up(h, w)
    hit_table = np.zeros(hp, np.int32)
    hit_table[:h] = distinct_hit
    distinct_miss = np.unique(miss_index[~hit]).astype(np.int32)
    dm = int(distinct_miss.shape[0])
    mp = _round_up(dm, w)
    miss_table = np.zeros(mp, np.int32)
    miss_table[:dm] = distinct_miss
    rank = np.empty(n, np.int32)
    rank[hit] = np.searchsorted(distinct_hit, slots[hit]).astype(np.int32)
    rank[~hit] = hp + np.searchsorted(
        distinct_miss, miss_index[~hit]).astype(np.int32)
    order = np.argsort(rank, kind="stable")
    n_pad = _round_up(n, w)
    # pad sorted ranks by repeating the max: keeps the last tile monotone
    srank = np.pad(rank[order], (0, n_pad - n), mode="edge")
    tiles = srank.reshape(n_pad // w, w)
    base = (tiles[:, 0] // w).astype(np.int32)   # rows sorted: min is first
    local = (tiles - base[:, None] * w).astype(np.int32)
    # the dense-rank construction guarantees every tile fits its window
    assert local.max(initial=0) < 4 * w, "tiled combine window overflow"
    inv = np.empty(n, np.int32)     # permutation inverse via O(N) scatter
    inv[order] = np.arange(n, dtype=np.int32)
    return _assemble_tiled_device(cache, miss, hit_table, miss_table, base,
                                  local, inv, w=w, t_f=t_f, depth=depth)


@functools.partial(jax.jit, static_argnames=("w", "t_f", "depth"))
def _assemble_tiled_device(cache, miss, hit_table, miss_table, base,
                           local, inv, w: int, t_f: int,
                           depth: int = 1) -> jax.Array:
    f = miss.shape[1]
    if cache is None:
        compact = jnp.zeros((0, f), miss.dtype)
    else:
        compact = jnp.take(cache, hit_table, axis=0)
    src = jnp.concatenate([compact, jnp.take(miss, miss_table, axis=0)],
                          axis=0)
    # three spare blocks past the last referenced row so the kernel's
    # base..base+3 window always exists, columns padded to the F tile
    sp = _round_up(int(src.shape[0]), w) + 4 * w
    fp = _round_up(f, t_f)
    src = jnp.pad(src, ((0, sp - src.shape[0]), (0, fp - f)))
    if depth > 1:
        out = cache_combine_pipelined_kernel_call(
            src, base, local, t_n=w, t_f=t_f, depth=depth,
            interpret=_INTERPRET)
    else:
        out = cache_combine_tiled_kernel_call(src, base, local, t_n=w,
                                              t_f=t_f, interpret=_INTERPRET)
    return jnp.take(out, inv, axis=0)[:, :f]


def update_cache_rows(cache: jax.Array, rows, slots,
                      use_pallas: bool = False,
                      pipeline_depth: int = 1) -> jax.Array:
    """Scatter admitted rows into a device-resident hot block during a
    dynamic cache refresh: ``out = cache; out[slots[i]] = rows[i]`` (last
    writer wins on aliased slots — all paths and the oracle agree).

    ``rows``/``slots`` are accepted as host numpy (refresh builds them on
    the host); an empty update returns the input block unchanged so a
    no-op refresh never touches the device.  The Pallas path issues one
    aligned row-block DMA per admitted node with the cache aliased into
    the output; the jnp path compacts aliased slots to their last writer
    on the host so its XLA scatter (duplicate-index order unspecified)
    stays deterministic.

    ``pipeline_depth > 1`` (Pallas path only) batches the admitted rows
    into multi-row block reads held in ``depth`` VMEM slots, overlapped
    with the per-row aliased write-back.  The pipelined kernel's write
    DMAs within a block are concurrent, so aliased slots are compacted
    keep-last on the host first (same dedupe the jnp path needs) — the
    result stays bit-identical to the sequential kernel and the oracle.
    """
    slots = np.asarray(slots, dtype=np.int32)
    if slots.shape[0] == 0:
        return cache
    rows = jnp.asarray(rows, dtype=cache.dtype)
    if not use_pallas or pipeline_depth > 1:
        # keep-last dedupe: unique() keeps the first occurrence, so scan
        # the reversed slot list and map indices back
        _, first_in_rev = np.unique(slots[::-1], return_index=True)
        keep = np.sort(slots.shape[0] - 1 - first_in_rev)
        if not use_pallas:
            return _update_ref(cache, rows[keep], jnp.asarray(slots[keep]))
        return _update_pallas_pipelined(cache, rows[keep],
                                        jnp.asarray(slots[keep]),
                                        depth=int(pipeline_depth))
    return _update_pallas(cache, rows, jnp.asarray(slots))


@jax.jit
def _update_ref(cache: jax.Array, rows: jax.Array,
                slots: jax.Array) -> jax.Array:
    return cache.at[slots].set(rows)


@jax.jit
def _update_pallas(cache: jax.Array, rows: jax.Array,
                   slots: jax.Array) -> jax.Array:
    f = cache.shape[1]
    t_f = _pick_tile(f)
    fp = _round_up(f, t_f)
    cp = jnp.pad(cache, ((0, 0), (0, fp - f)))
    rp = jnp.pad(rows, ((0, 0), (0, fp - f)))
    out = cache_update_kernel_call(cp, rp, slots, t_f=t_f,
                                   interpret=_INTERPRET)
    return out[:, :f]


_UPDATE_ROW_BLOCK = 8      # rows per block DMA in the pipelined scatter


@functools.partial(jax.jit, static_argnames=("depth",))
def _update_pallas_pipelined(cache: jax.Array, rows: jax.Array,
                             slots: jax.Array, depth: int) -> jax.Array:
    f = cache.shape[1]
    t_f = _pick_tile(f)
    fp = _round_up(f, t_f)
    b = _UPDATE_ROW_BLOCK
    mp = _round_up(rows.shape[0], b)
    cp = jnp.pad(cache, ((0, 0), (0, fp - f)))
    # pad rows up to the block multiple: pad rows stream through the block
    # reads but are never written back (the kernel guards on the live count)
    rp = jnp.pad(rows, ((0, mp - rows.shape[0]), (0, fp - f)))
    out = cache_update_pipelined_kernel_call(cp, rp, slots, t_f=t_f,
                                             depth=depth, row_block=b,
                                             interpret=_INTERPRET)
    return out[:, :f]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def segment_weighted_sum_regular(x_nbr: jax.Array, w_edge: jax.Array,
                                 fanout: int) -> jax.Array:
    """Pallas-backed regular-layout weighted segment sum.

    x_nbr: [D*fanout, F]; w_edge: [D*fanout] -> [D, F].
    Differentiable: backward pass is analytic (broadcast + reduce), so the
    kernel composes with ``jax.grad`` in the training step.
    """
    return _segsum_fwd_impl(x_nbr, w_edge, fanout)


@functools.partial(jax.jit, static_argnames=("fanout",))
def _segsum_fwd_impl(x_nbr: jax.Array, w_edge: jax.Array,
                     fanout: int) -> jax.Array:
    d = x_nbr.shape[0] // fanout
    f = x_nbr.shape[1]
    t_d = _pick_tile(d, 128 if d >= 128 else 8)
    t_f = _pick_tile(f)
    dp, fp = _round_up(d, t_d), _round_up(f, t_f)
    xn = jnp.pad(x_nbr.reshape(d, fanout, f),
                 ((0, dp - d), (0, 0), (0, fp - f))).reshape(dp * fanout, fp)
    we = jnp.pad(w_edge.reshape(d, fanout), ((0, dp - d), (0, 0))
                 ).reshape(dp * fanout, 1)
    out = segment_sum_kernel_call(xn, we, fanout, t_d=t_d, t_f=t_f,
                                  interpret=_INTERPRET)
    return out[:d, :f]


def _segsum_vjp_fwd(x_nbr, w_edge, fanout):
    return _segsum_fwd_impl(x_nbr, w_edge, fanout), (x_nbr, w_edge)


def _segsum_vjp_bwd(fanout, res, g):
    x_nbr, w_edge = res
    d = x_nbr.shape[0] // fanout
    g_rep = jnp.repeat(g, fanout, axis=0,
                       total_repeat_length=d * fanout).astype(jnp.float32)
    d_xn = (g_rep * w_edge.astype(jnp.float32)[:, None]).astype(x_nbr.dtype)
    d_we = (g_rep * x_nbr.astype(jnp.float32)).sum(-1).astype(w_edge.dtype)
    return d_xn, d_we


segment_weighted_sum_regular.defvjp(_segsum_vjp_fwd, _segsum_vjp_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(7,))
def fused_gnn_update(x_self: jax.Array, x_nbr: jax.Array, w_edge: jax.Array,
                     self_scale: jax.Array, w_self: jax.Array,
                     w_agg: jax.Array, bias: Optional[jax.Array],
                     fanout: int) -> jax.Array:
    """Fused aggregate+update GNN layer (paper Section IV-C datapath).

    out = (self_scale ⊙ x_self) @ w_self + segsum(w_edge ⊙ x_nbr) @ w_agg + b
    Differentiable via an analytic custom VJP (forward runs the fused Pallas
    kernel; backward re-aggregates once and uses plain matmuls).
    """
    return _fused_fwd_impl(x_self, x_nbr, w_edge, self_scale, w_self, w_agg,
                           bias, fanout)


@functools.partial(jax.jit, static_argnames=("fanout",))
def _fused_fwd_impl(x_self: jax.Array, x_nbr: jax.Array, w_edge: jax.Array,
                    self_scale: jax.Array, w_self: jax.Array,
                    w_agg: jax.Array, bias: Optional[jax.Array],
                    fanout: int) -> jax.Array:
    d, f = x_self.shape
    o = w_self.shape[1]
    t_d = _pick_tile(d, 128 if d >= 128 else 8)
    t_f = _pick_tile(f)
    t_o = _pick_tile(o)
    dp, fp, op = _round_up(d, t_d), _round_up(f, t_f), _round_up(o, t_o)

    xs = jnp.pad(x_self, ((0, dp - d), (0, fp - f)))
    xn = jnp.pad(x_nbr.reshape(d, fanout, f),
                 ((0, dp - d), (0, 0), (0, fp - f))).reshape(dp * fanout, fp)
    we = jnp.pad(w_edge.reshape(d, fanout), ((0, dp - d), (0, 0))
                 ).reshape(dp * fanout, 1)
    ss = jnp.pad(self_scale.reshape(d, 1), ((0, dp - d), (0, 0)))
    ws = jnp.pad(w_self, ((0, fp - f), (0, op - o)))
    wa = jnp.pad(w_agg, ((0, fp - f), (0, op - o)))
    b = (jnp.zeros((1, op), x_self.dtype) if bias is None
         else jnp.pad(bias.reshape(1, o), ((0, 0), (0, op - o))))
    out = fused_update_kernel_call(xs, xn, we, ss, ws, wa, b, fanout,
                                   t_d=t_d, t_f=t_f, t_o=t_o,
                                   interpret=_INTERPRET)
    return out[:d, :o]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    q_block: int = 512, pos0: int = 0) -> jax.Array:
    """Causal flash attention (Pallas fwd kernel, analytic jnp bwd).

    q: [B, S, Hkv, G, D]; k/v: [B, S, Hkv, D] -> [B, S, Hkv, G, D].
    """
    return flash_attention_call(q, k, v, q_block=q_block, pos0=pos0,
                                interpret=_INTERPRET)


def _attn_probs(q, k, pos0):
    s = q.shape[1]
    scale = 1.0 / (q.shape[-1] ** 0.5)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    pos = pos0 + jnp.arange(s)
    mask = pos[None, :] <= pos[:, None]
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    return jax.nn.softmax(scores, axis=-1)


def _flash_vjp_fwd(q, k, v, q_block, pos0):
    return flash_attention(q, k, v, q_block, pos0), (q, k, v)


def _flash_vjp_bwd(q_block, pos0, res, g):
    # standard attention backward with recompute (scores re-materialized
    # by XLA here; a bwd flash kernel is a further perf iteration)
    q, k, v = res
    p = _attn_probs(q, k, pos0)                                   # [B,H,G,S,S]
    g32 = g.astype(jnp.float32)
    d_v = jnp.einsum("bhgqk,bqhgd->bkhd", p, g32).astype(v.dtype)
    d_p = jnp.einsum("bqhgd,bkhd->bhgqk", g32, v.astype(jnp.float32))
    row = jnp.sum(d_p * p, axis=-1, keepdims=True)
    d_s = p * (d_p - row)
    scale = 1.0 / (q.shape[-1] ** 0.5)
    d_q = (jnp.einsum("bhgqk,bkhd->bqhgd", d_s, k.astype(jnp.float32))
           * scale).astype(q.dtype)
    d_k = (jnp.einsum("bhgqk,bqhgd->bkhd", d_s, q.astype(jnp.float32))
           * scale).astype(k.dtype)
    return d_q, d_k, d_v


flash_attention.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def _fused_vjp_fwd(x_self, x_nbr, w_edge, self_scale, w_self, w_agg, bias,
                   fanout):
    out = _fused_fwd_impl(x_self, x_nbr, w_edge, self_scale, w_self, w_agg,
                          bias, fanout)
    return out, (x_self, x_nbr, w_edge, self_scale, w_self, w_agg,
                 bias is not None)


def _fused_vjp_bwd(fanout, res, g):
    x_self, x_nbr, w_edge, self_scale, w_self, w_agg, has_bias = res
    d = x_self.shape[0]
    g32 = g.astype(jnp.float32)
    xs32 = x_self.astype(jnp.float32)
    ss32 = self_scale.astype(jnp.float32)
    # recompute the aggregation once (cheap relative to matmuls)
    agg = ref.segment_weighted_sum_regular(x_nbr, w_edge, fanout
                                           ).astype(jnp.float32)
    gws = g32 @ w_self.astype(jnp.float32).T            # [D, F]
    d_xs = (gws * ss32[:, None]).astype(x_self.dtype)
    d_ss = (gws * xs32).sum(-1).astype(self_scale.dtype)
    d_wself = ((xs32 * ss32[:, None]).T @ g32).astype(w_self.dtype)
    d_wagg = (agg.T @ g32).astype(w_agg.dtype)
    d_agg = g32 @ w_agg.astype(jnp.float32).T           # [D, F]
    d_agg_rep = jnp.repeat(d_agg, fanout, axis=0,
                           total_repeat_length=d * fanout)
    d_xn = (d_agg_rep * w_edge.astype(jnp.float32)[:, None]
            ).astype(x_nbr.dtype)
    d_we = (d_agg_rep * x_nbr.astype(jnp.float32)).sum(-1
            ).astype(w_edge.dtype)
    d_b = g32.sum(0).astype(w_self.dtype) if has_bias else None
    return d_xs, d_xn, d_we, d_ss, d_wself, d_wagg, d_b


fused_gnn_update.defvjp(_fused_vjp_fwd, _fused_vjp_bwd)
