"""Pure-jnp oracles for the Pallas kernels (ground truth for allclose tests).

Semantics mirror the paper's FPGA datapath (Section IV-C):

* ``segment_weighted_sum_regular`` — the scatter-gather aggregation stage:
  each destination vertex owns exactly ``fanout`` contiguous edge slots
  (edges pre-sorted by destination, the TPU analogue of the paper's
  sort-by-source reuse trick), weighted-summed into one row.
* ``fused_gnn_update`` — aggregation fused with the systolic-array update:
  ``out = (self_scale ⊙ x_self) @ w_self + agg @ w_agg + bias`` with the
  aggregated intermediate never materialized to HBM.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["segment_weighted_sum_regular", "fused_gnn_update",
           "assemble_features", "expand_rows", "cache_update"]


def assemble_features(cache: jax.Array, miss: jax.Array, slots: jax.Array,
                      miss_index: jax.Array) -> jax.Array:
    """Cache-combine oracle: ``out[i] = cache[slots[i]]`` when
    ``slots[i] >= 0`` else ``miss[miss_index[i]]``.

    Many-to-one is part of the contract: under frontier dedup several
    positions ``i`` carry the same ``slots``/``miss_index`` value, so one
    shipped row fans out into every positional copy (the paper's Feature
    Duplicator, applied on-device).

    cache: [K, F]; miss: [M, F] (M >= 1); slots: int32 [N] (-1 = miss);
    miss_index: int32 [N] -> [N, F].
    """
    hit = slots >= 0
    from_cache = jnp.take(cache, jnp.maximum(slots, 0), axis=0)
    from_miss = jnp.take(miss, miss_index, axis=0)
    return jnp.where(hit[:, None], from_cache, from_miss)


def expand_rows(rows: jax.Array, inverse: jax.Array) -> jax.Array:
    """Dedup-expansion oracle: ``out[i] = rows[inverse[i]]`` — rebuilds the
    positional [N, F] layout from a [U, F] unique-row block.  Equivalent
    to ``assemble_features`` with no cache (all slots -1)."""
    return jnp.take(rows, inverse, axis=0)


def cache_update(cache: jax.Array, rows: jax.Array,
                 slots: jax.Array) -> jax.Array:
    """Cache scatter-update oracle: ``out = cache; out[slots[i]] = rows[i]``
    with updates applied in index order, so an update set that aliases the
    same slot resolves to the LAST writer — the sequential-grid semantics
    of ``cache_update_kernel_call``.  (A plain ``cache.at[slots].set(rows)``
    leaves duplicate-index order unspecified, hence the explicit loop.)

    cache: [K, F]; rows: [M, F]; slots: int32 [M] -> [K, F].
    """
    f = cache.shape[1]
    if slots.shape[0] == 0:       # loop body is untraceable on 0 rows
        return cache

    def body(i, acc):
        row = jax.lax.dynamic_slice(rows, (i, 0), (1, f)).astype(acc.dtype)
        return jax.lax.dynamic_update_slice(acc, row, (slots[i], 0))

    return jax.lax.fori_loop(0, slots.shape[0], body, cache)


def segment_weighted_sum_regular(x_nbr: jax.Array, w_edge: jax.Array,
                                 fanout: int) -> jax.Array:
    """x_nbr: [D*fanout, F]; w_edge: [D*fanout]; -> [D, F]."""
    d = x_nbr.shape[0] // fanout
    xn = x_nbr.reshape(d, fanout, -1)
    we = w_edge.reshape(d, fanout, 1)
    return (xn.astype(jnp.float32) * we.astype(jnp.float32)).sum(axis=1
        ).astype(x_nbr.dtype)


def fused_gnn_update(x_self: jax.Array, x_nbr: jax.Array, w_edge: jax.Array,
                     self_scale: jax.Array, w_self: jax.Array,
                     w_agg: jax.Array, bias: Optional[jax.Array],
                     fanout: int) -> jax.Array:
    """out = (self_scale ⊙ x_self) @ w_self + segsum(w ⊙ x_nbr) @ w_agg + b.

    x_self: [D, F]; x_nbr: [D*fanout, F]; w_edge: [D*fanout];
    self_scale: [D]; w_self/w_agg: [F, O]; bias: [O] -> [D, O] (f32 accum).
    """
    agg = segment_weighted_sum_regular(x_nbr, w_edge, fanout)
    xs = x_self.astype(jnp.float32) * self_scale.astype(jnp.float32)[:, None]
    out = (xs @ w_self.astype(jnp.float32)
           + agg.astype(jnp.float32) @ w_agg.astype(jnp.float32))
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return out.astype(x_self.dtype)
