# Pallas TPU kernels for the paper's compute hot-spot (Section IV-C):
# fused scatter-gather aggregation + systolic update.  ops.py = jit'd
# wrappers; ref.py = pure-jnp oracles; gather_scatter_mm.py = pallas_call.
from . import ops, ref

__all__ = ["ops", "ref"]
