"""Flash-attention (fwd) Pallas TPU kernel — online-softmax attention whose
score matrix never leaves VMEM (Dao et al., arXiv:2205.14135, adapted to
the TPU memory hierarchy: q/k/v tiles DMA'd HBM->VMEM, MXU matmuls, f32
running (m, l, acc) in VMEM scratch).

Grid: (B, Hkv, G, S/qb); each step owns one grouped-query block and loops
over kv tiles with ``jax.lax.fori_loop``, masking causally by global
position.  HBM traffic is exactly q+k+v read + o written — which is what
``launch/costmodel.py`` charges for it (pallas_call operands/outputs),
versus the blocked-jnp path whose [qb, S] score tensors are materialized
by XLA between the two matmuls.

Backward runs as recompute through the reference path (``ops.py`` defines
the custom VJP) — a bwd kernel is a further perf iteration.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_call"]

_NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  kv_tile: int, n_kv_tiles: int, qb: int, pos0: int):
    # q_ref: [qb, D]; k_ref/v_ref: [S, D] (full kv stream for this head);
    # o_ref: [qb, D]; scratch: acc [qb, D] f32, m/l [qb, 1] f32
    iq = pl.program_id(3)
    q = q_ref[...].astype(jnp.float32)
    d = q.shape[-1]
    scale = 1.0 / (d ** 0.5)
    q_pos = pos0 + iq * qb + jax.lax.broadcasted_iota(jnp.int32, (qb, 1), 0)

    m_ref[...] = jnp.full_like(m_ref, _NEG)
    l_ref[...] = jnp.zeros_like(l_ref)
    acc_ref[...] = jnp.zeros_like(acc_ref)

    def body(t, _):
        start = t * kv_tile
        k = k_ref[pl.ds(start, kv_tile), :].astype(jnp.float32)
        v = v_ref[pl.ds(start, kv_tile), :].astype(jnp.float32)
        kv_pos = pos0 + start + jax.lax.broadcasted_iota(
            jnp.int32, (1, kv_tile), 1)
        s = (q @ k.T) * scale                         # [qb, kv_tile]
        s = jnp.where(kv_pos <= q_pos, s, _NEG)       # causal
        m_new = jnp.maximum(m_ref[...], s.max(-1, keepdims=True))
        alpha = jnp.exp(m_ref[...] - m_new)
        p = jnp.exp(s - m_new)                        # [qb, kv_tile]
        l_ref[...] = l_ref[...] * alpha + p.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + p @ v
        m_ref[...] = m_new
        return ()

    # only kv tiles at or before this q block contribute (causal)
    n_live = jnp.minimum((iq + 1) * qb + kv_tile - 1, n_kv_tiles * kv_tile
                         ) // kv_tile
    jax.lax.fori_loop(0, n_live, body, ())
    o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                  ).astype(o_ref.dtype)


def flash_attention_call(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         q_block: int = 512, kv_tile: int = 512,
                         pos0: int = 0, interpret: bool = True) -> jax.Array:
    """q: [B, S, Hkv, G, D]; k/v: [B, S, Hkv, D] -> [B, S, Hkv, G, D]."""
    b, s, hkv, g, d = q.shape
    qb = min(q_block, s)
    kvt = min(kv_tile, s)
    assert s % qb == 0 and s % kvt == 0
    grid = (b, hkv, g, s // qb)
    kernel = functools.partial(_flash_kernel, kv_tile=kvt,
                               n_kv_tiles=s // kvt, qb=qb, pos0=pos0)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, qb, None, None, d),
                         lambda ib, ih, ig, iq: (ib, iq, ih, ig, 0)),
            pl.BlockSpec((None, s, None, d),
                         lambda ib, ih, ig, iq: (ib, 0, ih, 0)),
            pl.BlockSpec((None, s, None, d),
                         lambda ib, ih, ig, iq: (ib, 0, ih, 0)),
        ],
        out_specs=pl.BlockSpec((None, qb, None, None, d),
                               lambda ib, ih, ig, iq: (ib, iq, ih, ig, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((qb, d), jnp.float32),
                        pltpu.VMEM((qb, 1), jnp.float32),
                        pltpu.VMEM((qb, 1), jnp.float32)],
        interpret=interpret,
    )(q, k, v)
