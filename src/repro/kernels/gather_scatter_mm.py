"""Pallas TPU kernels — the paper's hardware kernel design (Section IV-C),
adapted from FPGA scatter-gather PEs + systolic MLP to the TPU memory
hierarchy (HBM -> VMEM -> MXU).

Mapping of the paper's ideas:

* *Edges sorted so same-vertex features are reused back-to-back; the Feature
  Duplicator keeps the fetched feature in PE-local memory* -> edges arrive
  destination-sorted in a regular ``fanout`` layout; each grid step DMAs one
  (T_D × fanout, T_F) tile of neighbor rows HBM->VMEM **once** and reuses it
  across the whole output tile (VMEM plays the PE-local memory role).
* *Systolic-array update kernel* -> the MXU matmul, fed directly from the
  VMEM-resident aggregation result.
* *Customized datapath: intermediate results never written back to external
  memory* -> the aggregated tile is consumed by the matmul inside the same
  kernel invocation; only the final update output is written to HBM.  The
  f32 accumulator lives in a VMEM scratch buffer across the F-reduction grid
  axis.

Tile sizes default to MXU-aligned 128×128 blocks; callers (ops.py) pad
inputs to tile multiples.  Grid iteration order is (D, O, F) with F
innermost, so each output tile's accumulator stays resident in VMEM for the
whole reduction — the TPU analogue of the paper's (n, m) PE parallelism
knobs (Table IV).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["segment_sum_kernel_call", "fused_update_kernel_call",
           "cache_combine_kernel_call", "cache_combine_tiled_kernel_call",
           "cache_combine_pipelined_kernel_call",
           "cache_update_kernel_call", "cache_update_pipelined_kernel_call",
           "VMEM_SCRATCH_BUDGET_BYTES", "check_vmem_scratch"]


# Multi-buffered kernels hold ``depth`` in-flight tile windows in VMEM
# scratch.  Half of a 16 MB TPU VMEM is reserved for scratch; the other
# half stays available to the pipeline machinery (output tiles, scalar
# tables).  The budget is enforced at call time so a misconfigured
# (depth, tile, feature-width) combination fails loudly instead of
# spilling on a real device.
VMEM_SCRATCH_BUDGET_BYTES = 8 * 1024 * 1024


def check_vmem_scratch(nbytes: int, what: str) -> None:
    """Raise when a pipelined kernel's scratch would not fit the VMEM
    scratch budget (callers shrink depth or tile sizes instead)."""
    if nbytes > VMEM_SCRATCH_BUDGET_BYTES:
        raise ValueError(
            f"{what}: {nbytes} B of VMEM scratch exceeds the "
            f"{VMEM_SCRATCH_BUDGET_BYTES} B budget; lower pipeline_depth "
            "or the tile sizes")


# --------------------------------------------------------- segment sum only


def _segsum_kernel(x_ref, w_ref, o_ref, *, fanout: int):
    # x_ref: [T_D * fanout, T_F]; w_ref: [T_D * fanout, 1]; o_ref: [T_D, T_F]
    td = o_ref.shape[0]
    x = x_ref[...].astype(jnp.float32).reshape(td, fanout, -1)
    w = w_ref[...].astype(jnp.float32).reshape(td, fanout, 1)
    o_ref[...] = (x * w).sum(axis=1).astype(o_ref.dtype)


def segment_sum_kernel_call(x_nbr: jax.Array, w_edge2d: jax.Array,
                            fanout: int, t_d: int = 128, t_f: int = 128,
                            interpret: bool = True) -> jax.Array:
    """x_nbr: [D*fanout, F] (D % t_d == 0, F % t_f == 0); w: [D*fanout, 1]."""
    d = x_nbr.shape[0] // fanout
    f = x_nbr.shape[1]
    grid = (d // t_d, f // t_f)
    return pl.pallas_call(
        functools.partial(_segsum_kernel, fanout=fanout),
        grid=grid,
        in_specs=[
            pl.BlockSpec((t_d * fanout, t_f), lambda i, j: (i, j)),
            pl.BlockSpec((t_d * fanout, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((t_d, t_f), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d, f), x_nbr.dtype),
        interpret=interpret,
    )(x_nbr, w_edge2d)


# ------------------------------------------------- fused aggregate + update


def _fused_kernel(xs_ref, xn_ref, we_ref, ss_ref, ws_ref, wa_ref, b_ref,
                  o_ref, acc_ref, *, fanout: int, nf: int):
    # grid = (D, O, F); F innermost (accumulation axis)
    f_idx = pl.program_id(2)

    @pl.when(f_idx == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    td = o_ref.shape[0]
    # aggregation stage (scatter-gather PEs): VMEM-resident weighted reduce
    xn = xn_ref[...].astype(jnp.float32).reshape(td, fanout, -1)
    we = we_ref[...].astype(jnp.float32).reshape(td, fanout, 1)
    agg = (xn * we).sum(axis=1)                       # [T_D, T_F]
    xs = xs_ref[...].astype(jnp.float32) * ss_ref[...].astype(jnp.float32)
    # update stage (systolic array -> MXU), fused: agg never leaves VMEM
    acc_ref[...] += jax.lax.dot(
        xs, ws_ref[...].astype(jnp.float32),
        precision=jax.lax.Precision.HIGHEST)
    acc_ref[...] += jax.lax.dot(
        agg, wa_ref[...].astype(jnp.float32),
        precision=jax.lax.Precision.HIGHEST)

    @pl.when(f_idx == nf - 1)
    def _flush():
        o_ref[...] = (acc_ref[...]
                      + b_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def fused_update_kernel_call(x_self: jax.Array, x_nbr: jax.Array,
                             w_edge2d: jax.Array, self_scale2d: jax.Array,
                             w_self: jax.Array, w_agg: jax.Array,
                             bias2d: jax.Array, fanout: int,
                             t_d: int = 128, t_f: int = 128, t_o: int = 128,
                             interpret: bool = True) -> jax.Array:
    """Fused GNN layer tile kernel.

    x_self: [D, F]; x_nbr: [D*fanout, F]; w_edge2d: [D*fanout, 1];
    self_scale2d: [D, 1]; w_self/w_agg: [F, O]; bias2d: [1, O] -> [D, O].
    All dims must be multiples of their tile sizes (ops.py pads).
    """
    d, f = x_self.shape
    o = w_self.shape[1]
    grid = (d // t_d, o // t_o, f // t_f)
    nf = grid[2]
    return pl.pallas_call(
        functools.partial(_fused_kernel, fanout=fanout, nf=nf),
        grid=grid,
        in_specs=[
            pl.BlockSpec((t_d, t_f), lambda i, j, k: (i, k)),            # x_self
            pl.BlockSpec((t_d * fanout, t_f), lambda i, j, k: (i, k)),   # x_nbr
            pl.BlockSpec((t_d * fanout, 1), lambda i, j, k: (i, 0)),     # w_edge
            pl.BlockSpec((t_d, 1), lambda i, j, k: (i, 0)),              # self_scale
            pl.BlockSpec((t_f, t_o), lambda i, j, k: (k, j)),            # w_self
            pl.BlockSpec((t_f, t_o), lambda i, j, k: (k, j)),            # w_agg
            pl.BlockSpec((1, t_o), lambda i, j, k: (0, j)),              # bias
        ],
        out_specs=pl.BlockSpec((t_d, t_o), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d, o), x_self.dtype),
        # f32 accumulator resident in VMEM across the F reduction axis
        scratch_shapes=[pltpu.VMEM((t_d, t_o), jnp.float32)],
        interpret=interpret,
    )(x_self, x_nbr, w_edge2d, self_scale2d, w_self, w_agg, bias2d)


# -------------------------------------------- cache combine (hot + misses)


def _cache_combine_kernel(sel_ref, row_ref, cache_ref, miss_ref, o_ref):
    # one output row per grid step; the BlockSpec index maps (driven by
    # the scalar-prefetched sel/row tables) already DMA'd the right cache
    # row and miss row — the body just picks the live one.
    i = pl.program_id(0)
    take_cache = sel_ref[i] == 0
    o_ref[...] = jnp.where(take_cache, cache_ref[...], miss_ref[...])


def cache_combine_kernel_call(cache: jax.Array, miss: jax.Array,
                              sel: jax.Array, row: jax.Array,
                              interpret: bool = True) -> jax.Array:
    """Legacy one-row-per-grid-step combine (kept as a parity baseline —
    the trainer path uses ``cache_combine_tiled_kernel_call``).

    The TPU analogue of the paper's Feature-Duplicator gather PEs applied
    to the device-resident hot cache: ``out[i] = cache[row[i]]`` when
    ``sel[i] == 0`` else ``miss[row[i]]``.  ``sel``/``row`` arrive via
    scalar prefetch so each grid step's BlockSpec index map can steer the
    HBM->VMEM DMA at *row* granularity — a data-dependent gather the
    dense BlockSpec machinery cannot express.  Both sources stay in HBM;
    only the selected row per step is pulled into VMEM.

    cache: [K, F]; miss: [M, F] (M >= 1; callers pad empty miss blocks);
    sel/row: int32 [N] -> out [N, F].
    """
    n = sel.shape[0]
    f = cache.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n,),
        in_specs=[
            pl.BlockSpec(
                (1, f),
                lambda i, sel_ref, row_ref: (
                    jnp.where(sel_ref[i] == 0, row_ref[i], 0), 0)),
            pl.BlockSpec(
                (1, f),
                lambda i, sel_ref, row_ref: (
                    jnp.where(sel_ref[i] == 0, 0, row_ref[i]), 0)),
        ],
        out_specs=pl.BlockSpec((1, f), lambda i, sel_ref, row_ref: (i, 0)),
    )
    return pl.pallas_call(
        _cache_combine_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, f), cache.dtype),
        interpret=interpret,
    )(sel, row, cache, miss)


# ----------------------------------- cache scatter update (refresh path)


def _cache_update_kernel(slots_ref, rows_ref, cache_ref, o_ref):
    # grid = (M, F tiles): step (i, j) overwrites the F-tile j of cache row
    # slots[i] with the matching tile of update row i.  The cache operand
    # is aliased to the output, so rows no update points at keep their
    # bytes without ever being re-DMA'd — the whole refresh moves exactly
    # M * F elements.  Grid steps run sequentially, so an update set that
    # aliases the same slot resolves to the last writer (the jnp reference
    # in ref.cache_update applies updates in the same order).
    o_ref[...] = rows_ref[...]


def cache_update_kernel_call(cache: jax.Array, rows: jax.Array,
                             slots: jax.Array, t_f: int = 128,
                             interpret: bool = True) -> jax.Array:
    """In-place scatter of admitted rows into the device-resident hot block:
    ``out = cache; out[slots[i]] = rows[i]``.

    The dynamic cache refresh admits a handful of rows per epoch; this
    kernel updates the [K, F] device block with one aligned (1, T_F)
    row-block DMA per admitted node instead of re-uploading all K rows
    over PCIe.  ``slots`` arrives via scalar prefetch so each grid step's
    output BlockSpec index map steers the write to a data-dependent row —
    the scatter dual of the combine kernels' gather above.

    cache: [K, F] (F % t_f == 0, callers pad); rows: [M, F] (M >= 1 —
    callers shortcut empty updates); slots: int32 [M] -> out [K, F].
    Duplicate slots resolve to the last writer (grid order).
    """
    m = slots.shape[0]
    f = cache.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m, f // t_f),
        in_specs=[
            pl.BlockSpec((1, t_f), lambda i, j, s: (i, j)),
            pl.BlockSpec((1, t_f), lambda i, j, s: (s[i], j)),
        ],
        out_specs=pl.BlockSpec((1, t_f), lambda i, j, s: (s[i], j)),
    )
    return pl.pallas_call(
        _cache_update_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(cache.shape, cache.dtype),
        # operand order is (slots, rows, cache): alias the cache into the
        # output so untouched rows are preserved, not recomputed
        input_output_aliases={2: 0},
        interpret=interpret,
    )(slots, rows, cache)


# ------------------------------------ tiled cache combine (multi-row DMA)


def _cache_combine_tiled_kernel(base_ref, loc_ref,
                                s0_ref, s1_ref, s2_ref, s3_ref, o_ref,
                                *, window: int):
    # One grid step materializes T_N output rows from a 4W-row VMEM window
    # (four consecutive aligned W-blocks of the dense source — enough to
    # cover any tile's monotone rank span, see
    # cache_combine_tiled_kernel_call).  The expansion itself is a one-hot
    # matmul so the duplication of shipped rows back into the positional
    # layout runs on the MXU instead of as a scalar gather.
    g = pl.program_id(0)
    win = jnp.concatenate([s0_ref[...], s1_ref[...],
                           s2_ref[...], s3_ref[...]], axis=0)   # [4W, T_F]
    loc = loc_ref[g]                                            # [T_N] int32
    onehot = (loc[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (loc.shape[0], 4 * window), 1)).astype(jnp.float32)
    o_ref[...] = jax.lax.dot(
        onehot, win.astype(jnp.float32),
        precision=jax.lax.Precision.HIGHEST).astype(o_ref.dtype)


def cache_combine_tiled_kernel_call(src: jax.Array, base: jax.Array,
                                    local: jax.Array,
                                    t_n: int = 128, t_f: int = 128,
                                    interpret: bool = True) -> jax.Array:
    """Multi-row tiled Feature-Duplicator expansion: T_N rows per grid step.

    Replaces the one-row-per-step combine on the trainer path.  ``src`` is
    the *dense* per-batch source (the distinct referenced cache rows
    compacted ahead of the unique shipped misses, see
    ops.assemble_features): every source row below the per-source pad gaps
    is referenced by at least one output position.  With output positions
    pre-sorted by source rank, a tile of T_N rows reads monotonically
    nondecreasing ranks with at most T_N distinct values, and density
    means its whole span (distinct rows + at most one bounded pad gap)
    fits inside four consecutive aligned W-row blocks (W = T_N).  Per tile
    the caller scalar-prefetches the aligned block index of the window
    plus a T_N row table of offsets into it; the body expands the 4W-row
    VMEM window through a one-hot MXU matmul.  Grid steps drop from N to
    N/T_N (~128x less grid overhead) and every DMA is a dense MXU-aligned
    (W, T_F) block instead of a single row.

    src: [Sp, Fp] with Sp % W == 0 and >= (base.max() + 4) * W rows (the
    caller pads three spare blocks past the last referenced row so blocks
    b..b+3 always exist); base: int32 [G] aligned W-block index of each
    tile's window; local: int32 [G, T_N] offsets into the 4W window
    -> out [G*T_N, Fp].
    """
    g = base.shape[0]
    fp = src.shape[1]
    w = t_n
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(g, fp // t_f),
        in_specs=[
            pl.BlockSpec((w, t_f), lambda i, j, b, loc: (b[i], j)),
            pl.BlockSpec((w, t_f), lambda i, j, b, loc: (b[i] + 1, j)),
            pl.BlockSpec((w, t_f), lambda i, j, b, loc: (b[i] + 2, j)),
            pl.BlockSpec((w, t_f), lambda i, j, b, loc: (b[i] + 3, j)),
        ],
        out_specs=pl.BlockSpec((t_n, t_f), lambda i, j, b, loc: (i, j)),
    )
    return pl.pallas_call(
        functools.partial(_cache_combine_tiled_kernel, window=w),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((g * t_n, fp), src.dtype),
        interpret=interpret,
    )(base, local, src, src, src, src)


# ------------------- multi-buffered pipelined combine (DMA/compute overlap)


def _cache_combine_pipelined_kernel(base_ref, loc_ref, src_ref, o_ref,
                                    win_ref, sem_ref, *, window: int,
                                    t_f: int, depth: int, nf: int,
                                    nsteps: int):
    # Same math as _cache_combine_tiled_kernel, but the window DMAs are
    # issued by hand: ``src`` stays in HBM (memory_space=ANY) and each
    # grid step's 4W-row window is copied into one of ``depth`` VMEM
    # scratch slots by an async copy started ``depth`` steps ahead.  The
    # TPU grid runs steps sequentially, so while step s's one-hot matmul
    # occupies the MXU the copy for step s+1..s+depth-1 is already in
    # flight — the DMA latency the single-buffered kernel serializes
    # before every tile is hidden behind the previous tiles' compute.
    i = pl.program_id(0)
    j = pl.program_id(1)
    s = i * nf + j

    def window_dma(step, slot):
        ti = step // nf
        tj = jax.lax.rem(step, nf)
        return pltpu.make_async_copy(
            src_ref.at[pl.ds(base_ref[ti] * window, 4 * window),
                       pl.ds(tj * t_f, t_f)],
            win_ref.at[slot], sem_ref.at[slot])

    @pl.when(s == 0)
    def _warmup():      # fill every slot before the first compute
        for d in range(min(depth, nsteps)):
            window_dma(jnp.int32(d), d).start()

    slot = jax.lax.rem(s, depth)
    window_dma(s, slot).wait()
    loc = loc_ref[i]                                          # [T_N] int32
    onehot = (loc[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (loc.shape[0], 4 * window), 1)).astype(jnp.float32)
    o_ref[...] = jax.lax.dot(
        onehot, win_ref[slot].astype(jnp.float32),
        precision=jax.lax.Precision.HIGHEST).astype(o_ref.dtype)

    @pl.when(s + depth < nsteps)
    def _prefetch_next():   # the slot is free again: refill depth ahead
        window_dma(s + depth, slot).start()


def cache_combine_pipelined_kernel_call(src: jax.Array, base: jax.Array,
                                        local: jax.Array,
                                        t_n: int = 128, t_f: int = 128,
                                        depth: int = 2,
                                        interpret: bool = True) -> jax.Array:
    """Multi-buffered tiled Feature-Duplicator expansion (paper §IV
    two-stage prefetching applied *inside* the kernel).

    Contract and output are identical to
    ``cache_combine_tiled_kernel_call`` (bit-identical: the same one-hot
    f32 MXU matmul over the same window values), but instead of four
    BlockSpec-driven block DMAs serialized before each tile's compute,
    ``depth`` (2-4) tile windows are held in VMEM scratch and tile
    s+depth's HBM->VMEM copy is started as soon as its slot frees — i.e.
    while tiles s+1..s+depth-1 still compute.  ``depth=1`` degenerates to
    issue-wait-compute per tile; callers (ops.assemble_features) keep the
    single-buffered kernel selectable for that.

    src: [Sp, Fp] dense padded source (see cache_combine_tiled_kernel_call
    for the window guarantees); base: int32 [G]; local: int32 [G, T_N]
    -> out [G*T_N, Fp].
    """
    if depth < 1:
        raise ValueError(f"pipeline depth must be >= 1, got {depth}")
    g = base.shape[0]
    fp = src.shape[1]
    w = t_n
    nf = fp // t_f
    check_vmem_scratch(
        depth * 4 * w * t_f * src.dtype.itemsize,
        f"cache_combine_pipelined(depth={depth}, t_n={t_n}, t_f={t_f})")
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(g, nf),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((t_n, t_f), lambda i, j, b, loc: (i, j)),
        scratch_shapes=[pltpu.VMEM((depth, 4 * w, t_f), src.dtype),
                        pltpu.SemaphoreType.DMA((depth,))],
    )
    return pl.pallas_call(
        functools.partial(_cache_combine_pipelined_kernel, window=w,
                          t_f=t_f, depth=depth, nf=nf, nsteps=g * nf),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((g * t_n, fp), src.dtype),
        interpret=interpret,
    )(base, local, src)


# ------------------ multi-buffered pipelined scatter update (refresh path)


def _cache_update_pipelined_kernel(slots_ref, rows_ref, cache_ref, o_ref,
                                   blk_ref, rd_sem, wr_sem, *, row_block: int,
                                   t_f: int, depth: int, nf: int,
                                   nsteps: int, m: int):
    # The single-buffered scatter kernel moves one row per grid step:
    # DMA in, DMA out, wait, repeat.  Here admitted rows are batched into
    # ``row_block``-row block reads held in ``depth`` VMEM slots — block
    # b+depth's read is in flight while block b's per-row write-back DMAs
    # scatter into the aliased cache.  Callers guarantee ``slots`` are
    # unique (ops.update_cache_rows dedupes keep-last on the host), so
    # the write-backs of one block are mutually independent: start all,
    # wait all, then the slot can be refilled.
    bi = pl.program_id(0)
    j = pl.program_id(1)
    s = bi * nf + j

    def block_read(step, slot):
        tb = step // nf
        tj = jax.lax.rem(step, nf)
        return pltpu.make_async_copy(
            rows_ref.at[pl.ds(tb * row_block, row_block),
                        pl.ds(tj * t_f, t_f)],
            blk_ref.at[slot], rd_sem.at[slot])

    @pl.when(s == 0)
    def _warmup():
        for d in range(min(depth, nsteps)):
            block_read(jnp.int32(d), d).start()

    slot = jax.lax.rem(s, depth)
    block_read(s, slot).wait()
    for r in range(row_block):       # scatter the block's live rows

        @pl.when(bi * row_block + r < m)
        def _start_write():
            pltpu.make_async_copy(
                blk_ref.at[slot, pl.ds(r, 1), :],
                o_ref.at[pl.ds(slots_ref[bi * row_block + r], 1),
                         pl.ds(j * t_f, t_f)],
                wr_sem.at[r]).start()

    for r in range(row_block):       # block's writes drain before reuse

        @pl.when(bi * row_block + r < m)
        def _wait_write():
            pltpu.make_async_copy(
                blk_ref.at[slot, pl.ds(r, 1), :],
                o_ref.at[pl.ds(slots_ref[bi * row_block + r], 1),
                         pl.ds(j * t_f, t_f)],
                wr_sem.at[r]).wait()

    @pl.when(s + depth < nsteps)
    def _prefetch_next():
        block_read(s + depth, slot).start()


def cache_update_pipelined_kernel_call(cache: jax.Array, rows: jax.Array,
                                       slots: jax.Array, t_f: int = 128,
                                       depth: int = 2, row_block: int = 8,
                                       interpret: bool = True) -> jax.Array:
    """Multi-buffered in-place scatter of admitted rows into the hot block.

    Semantics match ``cache_update_kernel_call`` for *unique* slots
    (``out = cache; out[slots[i]] = rows[i]``; callers pre-dedupe aliased
    slots keep-last — ops.update_cache_rows does), but rows move as
    ``row_block``-row block DMAs through ``depth`` VMEM slots: block
    b+depth streams HBM->VMEM while block b's rows scatter VMEM->HBM into
    the aliased cache, instead of one serialized row round-trip per grid
    step.

    cache: [K, Fp] (Fp % t_f == 0); rows: [Mp, Fp] with Mp a row_block
    multiple padded past M = slots.shape[0] (pad rows are never written);
    slots: int32 [M], unique -> out [K, Fp].
    """
    if depth < 1:
        raise ValueError(f"pipeline depth must be >= 1, got {depth}")
    m = slots.shape[0]
    mp = rows.shape[0]
    if mp % row_block != 0 or mp < m:
        raise ValueError(
            f"rows must be padded to the {row_block}-row block (got "
            f"{mp} rows for {m} slots)")
    fp = cache.shape[1]
    nf = fp // t_f
    nb = mp // row_block
    check_vmem_scratch(
        depth * row_block * t_f * cache.dtype.itemsize,
        f"cache_update_pipelined(depth={depth}, row_block={row_block}, "
        f"t_f={t_f})")
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, nf),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY),
                  pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        scratch_shapes=[pltpu.VMEM((depth, row_block, t_f), cache.dtype),
                        pltpu.SemaphoreType.DMA((depth,)),
                        pltpu.SemaphoreType.DMA((row_block,))],
    )
    return pl.pallas_call(
        functools.partial(_cache_update_pipelined_kernel,
                          row_block=row_block, t_f=t_f, depth=depth,
                          nf=nf, nsteps=nb * nf, m=m),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(cache.shape, cache.dtype),
        # operand order is (slots, rows, cache): alias cache -> output
        input_output_aliases={2: 0},
        interpret=interpret,
    )(slots, rows, cache)
