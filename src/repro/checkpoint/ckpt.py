"""Sharded, elastic, integrity-checked checkpointing (no orbax available).

Layout:  <dir>/step_<N>/
            manifest.json     {step, leaves: {path: {shape, dtype, file,
                               sha256, bytes}}, meta}
            <leaf>.bin        raw little-endian bytes per leaf

Properties needed for 1000+-node runnability:

* **Elastic**: leaves are stored as *full* (unsharded) host arrays; restore
  re-shards onto whatever mesh/device-count the restoring job has
  (``device_put`` with the new NamedSharding) — a job can come back with a
  different pod count after a failure.
* **Async**: ``save_async`` snapshots to host memory synchronously (cheap)
  and writes to disk on a background thread so the training loop is not
  blocked on I/O.
* **Integrity**: per-leaf sha256 recorded and verified on restore; a save is
  only visible once its manifest is atomically renamed into place, so a
  crash mid-write can never produce a half-readable checkpoint.
* **Rotation**: ``keep`` most-recent steps are retained.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.analysis.annotations import guarded_by

__all__ = ["save", "save_async", "restore", "latest_step", "CheckpointManager"]

PyTree = Any


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_into(template: PyTree, flat: Dict[str, np.ndarray]) -> PyTree:
    paths_leaves = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths_leaves[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        want_shape = tuple(leaf.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"template {want_shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(paths_leaves[1], leaves)


def _dtype_str(a: np.ndarray) -> str:
    return a.dtype.name  # 'bfloat16' round-trips via ml_dtypes


def _np_dtype(name: str):
    if name == "bfloat16":
        import ml_dtypes
        return np.dtype(ml_dtypes.bfloat16)
    return np.dtype(name)


def save(directory: str, step: int, tree: PyTree,
         meta: Optional[Dict[str, Any]] = None) -> str:
    """Synchronous checkpoint write; returns the checkpoint path."""
    flat = _flatten(jax.device_get(tree))
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    manifest: Dict[str, Any] = {"step": step, "meta": meta or {},
                                "leaves": {}}
    for i, (key, arr) in enumerate(sorted(flat.items())):
        fname = f"leaf_{i:05d}.bin"
        raw = np.ascontiguousarray(arr).tobytes()
        with open(os.path.join(tmp, fname), "wb") as f:
            f.write(raw)
        manifest["leaves"][key] = {
            "shape": list(arr.shape), "dtype": _dtype_str(arr),
            "file": fname, "bytes": len(raw),
            "sha256": hashlib.sha256(raw).hexdigest(),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)   # atomic publish
    return final


@guarded_by("_lock", "_thread")
class _AsyncSaver:
    """One in-flight background save at most.  The module-level singleton
    is reachable from any thread (``save_async`` / ``wait_for_async``),
    so the handle swap is locked; the ``join`` itself happens outside the
    lock — a second caller must never block on the writer's disk time
    just to learn there is nothing to wait for."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()

    def submit(self, directory, step, tree, meta):
        self.wait()
        host_tree = jax.device_get(tree)   # snapshot now, write later
        t = threading.Thread(
            target=save, args=(directory, step, host_tree, meta), daemon=True)
        with self._lock:
            self._thread = t
        t.start()

    def wait(self):
        with self._lock:
            t, self._thread = self._thread, None
        if t is not None:
            t.join()


_SAVER = _AsyncSaver()


def save_async(directory: str, step: int, tree: PyTree,
               meta: Optional[Dict[str, Any]] = None) -> None:
    _SAVER.submit(directory, step, tree, meta)


def wait_for_async() -> None:
    _SAVER.wait()


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(directory: str, step: Optional[int], template: PyTree,
            shardings: Optional[PyTree] = None, verify: bool = True
            ) -> Tuple[int, PyTree]:
    """Restore into ``template``'s structure; re-shard onto ``shardings``
    (elastic: the restoring job's mesh may differ from the saving job's)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat: Dict[str, np.ndarray] = {}
    for key, info in manifest["leaves"].items():
        with open(os.path.join(path, info["file"]), "rb") as f:
            raw = f.read()
        if verify:
            digest = hashlib.sha256(raw).hexdigest()
            if digest != info["sha256"]:
                raise IOError(f"checkpoint corruption in {key}: "
                              f"sha256 mismatch")
        flat[key] = np.frombuffer(raw, dtype=_np_dtype(info["dtype"])
                                  ).reshape(info["shape"])
    tree = _unflatten_into(template, flat)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree,
                            shardings)
    return manifest["step"], tree


class CheckpointManager:
    """Rotation + async orchestration for a training loop."""

    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        os.makedirs(directory, exist_ok=True)

    def save(self, step: int, tree: PyTree,
             meta: Optional[Dict[str, Any]] = None) -> None:
        if self.async_save:
            save_async(self.directory, step, tree, meta)
        else:
            save(self.directory, step, tree, meta)
        self._rotate()

    def _rotate(self) -> None:
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.directory)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def restore_latest(self, template: PyTree,
                       shardings: Optional[PyTree] = None
                       ) -> Optional[Tuple[int, PyTree]]:
        wait_for_async()
        step = latest_step(self.directory)
        if step is None:
            return None
        return restore(self.directory, step, template, shardings)

    def finalize(self) -> None:
        wait_for_async()
