"""Gradient / feature compression for the synchronization and transfer paths.

The paper (§VIII) names "data quantization to relieve the stress on the PCIe
bandwidth" as the remedy for Data-Transfer-bound configurations; we implement
it: int8 (per-tensor absmax scale) and bf16 compression usable on

* the Synchronizer's gradient all-reduce path (halves/quarters Eq. 13's
  numerator), and
* the Feature Loader -> Data Transfer path (halves Eq. 8's numerator).

Compression is lossy; it is therefore OFF by default (the paper's headline
claim is that its optimizations do not alter training semantics) and is
reported separately in benchmarks as a beyond-paper option.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class CompressionSpec:
    method: str = "none"          # "none" | "bf16" | "int8"

    @property
    def ratio(self) -> float:
        """Compression ratio vs fp32 (for the performance model)."""
        return {"none": 1.0, "bf16": 0.5, "int8": 0.25}[self.method]


def _q_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32))), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def compress_grads(grads: PyTree, spec: CompressionSpec) -> PyTree:
    if spec.method == "none":
        return grads
    if spec.method == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
    if spec.method == "int8":
        return jax.tree.map(_q_int8, grads)
    raise ValueError(spec.method)


def decompress_grads(comp: PyTree, spec: CompressionSpec,
                     like: PyTree) -> PyTree:
    if spec.method == "none":
        return comp
    if spec.method == "bf16":
        return jax.tree.map(lambda g, l: g.astype(l.dtype), comp, like)
    if spec.method == "int8":
        return jax.tree.map(
            lambda ql, l: (ql[0].astype(jnp.float32) * ql[1]).astype(l.dtype),
            comp, like, is_leaf=lambda x: isinstance(x, tuple))
    raise ValueError(spec.method)
