from .optimizers import (Optimizer, sgd, adam, adamw, clip_by_global_norm,
                         cosine_warmup_schedule)
from .compression import (compress_grads, decompress_grads, CompressionSpec)

__all__ = ["Optimizer", "sgd", "adam", "adamw", "clip_by_global_norm",
           "cosine_warmup_schedule", "compress_grads", "decompress_grads",
           "CompressionSpec"]
